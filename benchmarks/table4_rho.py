"""Table 4: ρ-approximate DBSCAN (grid/cell engine) vs plain DBSCAN —
reproducing the paper's finding (C5) that the cell structure is pure
overhead in high dimensions (slower than brute force even at ρ=1)."""

from __future__ import annotations

from repro.core.baselines import rho_approx_dbscan
from repro.core.dbscan import dbscan_parallel

from .common import EPS_TAU, prepare, save_json, timed


def run(profile: str = "standard", scales=(1 / 3, 2 / 3, 1.0)):
    rows = []
    for scale in scales:
        prep = prepare("ms", profile, scale=scale)
        for eps, tau in EPS_TAU[:2]:
            t_rho, _ = timed(
                rho_approx_dbscan, prep.test, eps, tau, rho=1.0, engine="cell"
            )
            t_db, _ = timed(dbscan_parallel, prep.test, eps, tau)
            rows.append({
                "n": len(prep.test), "eps": eps, "tau": tau,
                "rho_approx_s": t_rho, "dbscan_s": t_db,
                "slowdown": t_rho / max(t_db, 1e-9),
            })
    save_json("table4_rho", rows)
    return rows


def summarize(rows):
    lines = ["table4: rho-approximate (cell engine) vs DBSCAN (t1/t2 as in paper)"]
    for r in rows:
        lines.append(
            f"  n={r['n']:6d} eps={r['eps']} tau={r['tau']}: "
            f"{r['rho_approx_s']:.2f}s / {r['dbscan_s']:.2f}s "
            f"(rho-approx {r['slowdown']:.2f}x slower)"
        )
    ok = all(r["slowdown"] > 1.0 for r in rows)
    lines.append(f"  claim C5 (cell structure slower in high-d): {'CONFIRMED' if ok else 'NOT confirmed'}")
    return "\n".join(lines)
