"""The method roster every benchmark table shares — one entry per method
in the paper's evaluation (DBSCAN is the ground truth, not in tables)."""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.baselines import block_dbscan, knn_block_dbscan
from repro.core.dbscan import dbscan_parallel
from repro.core.dbscan_pp import auto_sample_fraction, dbscan_pp, laf_dbscan_pp
from repro.core.laf_dbscan import laf_dbscan

from .common import Prepared, timed


def run_method(
    method: str, prep: Prepared, eps: float, tau: int, *, alpha=None, delta=0.2
):
    """-> (elapsed_s, DBSCANResult)."""
    test = prep.test
    alpha = prep.alpha if alpha is None else alpha
    if method == "DBSCAN":
        return timed(dbscan_parallel, test, eps, tau)
    if method == "LAF-DBSCAN":
        def run():
            pred = prep.pipeline.predict_counts(test, eps)
            return laf_dbscan(test, eps, tau, alpha, pred, seed=0)
        return timed(run)
    if method == "DBSCAN++":
        def run():
            pred = prep.pipeline.predict_counts(test, eps)
            p = auto_sample_fraction(pred, tau, alpha, delta)
            return dbscan_pp(test, eps, tau, p, seed=0)
        return timed(run)
    if method == "LAF-DBSCAN++":
        def run():
            pred = prep.pipeline.predict_counts(test, eps)
            p = auto_sample_fraction(pred, tau, alpha, delta)
            n = len(test)
            rng = np.random.default_rng(0)
            m = max(1, int(round(p * n)))
            sample_idx = np.sort(rng.choice(n, size=m, replace=False))
            return laf_dbscan_pp(
                test, eps, tau, p, pred[sample_idx], alpha=1.0,
                sample_idx=sample_idx, seed=0,
            )
        return timed(run)
    if method == "KNN-BLOCK":
        return timed(
            knn_block_dbscan, test, eps, tau, n_proj=6,
            window=max(tau, int(0.3 * len(test) / 2)), seed=0,
        )
    if method == "BLOCK-DBSCAN":
        return timed(block_dbscan, test, eps, tau, rnt=10, seed=0)
    raise KeyError(method)


APPROX_METHODS = ["KNN-BLOCK", "BLOCK-DBSCAN", "DBSCAN++", "LAF-DBSCAN", "LAF-DBSCAN++"]
