"""Table 6: fully-missed cluster analysis (claim C4) — clusters whose
core points are ALL false-negative predictions vanish entirely; the
paper shows they are tiny (3-7 points avg, 1-6% of non-noise points)."""

from __future__ import annotations

import numpy as np

from repro.core.laf_dbscan import laf_dbscan

from .common import ground_truth, prepare, save_json


def run(profile: str = "standard", datasets=("nyt", "glove", "ms")):
    rows = []
    settings = {"nyt": (0.5, 3), "glove": (0.55, 5), "ms": (0.55, 5)}  # paper's worst cases
    for ds in datasets:
        eps, tau = settings[ds]
        prep = prepare(ds, profile)
        gt = ground_truth(prep, eps, tau)
        if gt.n_clusters < 2:
            continue
        pred = prep.pipeline.predict_counts(prep.test, eps)
        res = laf_dbscan(prep.test, eps, tau, prep.alpha, pred, seed=0)
        # fully missed: ground-truth clusters none of whose members are
        # non-noise in the LAF result
        missed_sizes = []
        for c in range(gt.n_clusters):
            members = gt.labels == c
            if (res.labels[members] == -1).all():
                missed_sizes.append(int(members.sum()))
        tpc = int((gt.labels >= 0).sum())
        rows.append({
            "dataset": ds, "eps": eps, "tau": tau,
            "MC": len(missed_sizes), "TC": gt.n_clusters,
            "MP": int(sum(missed_sizes)), "TPC": tpc,
            "ASMC": float(np.mean(missed_sizes)) if missed_sizes else 0.0,
            "missed_point_frac": sum(missed_sizes) / max(tpc, 1),
        })
    save_json("table6_missed", rows)
    return rows


def summarize(rows):
    lines = ["table6: fully missed clusters (MC/TC, MP/TPC, ASMC)"]
    for r in rows:
        lines.append(
            f"  {r['dataset']} (eps={r['eps']}, tau={r['tau']}): "
            f"MC/TC={r['MC']}/{r['TC']}  MP/TPC={r['MP']}/{r['TPC']} "
            f"({100 * r['missed_point_frac']:.1f}%)  ASMC={r['ASMC']:.1f}"
        )
    ok = all(r["missed_point_frac"] < 0.10 for r in rows)
    lines.append(f"  claim C4 (missed clusters tiny): {'CONFIRMED' if ok else 'NOT confirmed'}")
    return "\n".join(lines)
