"""Kernel microbenchmarks: ``name,us_per_call,derived`` CSV rows.

CPU timings of the jnp oracles (the Pallas kernels execute via
interpret=True here, which measures Python, not TPU — so the CSV times
the *reference* computation and derives the kernel's TPU roofline bound
from its analytic FLOPs/bytes instead)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import sample_uniform_sphere

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run():
    rows = []
    rng = np.random.default_rng(0)

    # range_count: 4096 queries x 65536 db x 768-d
    q = jnp.asarray(sample_uniform_sphere(rng, 1024, 768))
    db = jnp.asarray(sample_uniform_sphere(rng, 16384, 768))
    from repro.core.range_query import range_counts

    us = _time(lambda a, b: range_counts(a, b, 0.5), q, db)
    flops = 2 * 1024 * 16384 * 768
    bound_us = max(flops / PEAK_FLOPS, (q.nbytes + db.nbytes + 1024 * 4) / HBM_BW) * 1e6
    rows.append(("range_count_1024x16384x768", us, f"tpu_bound_us={bound_us:.1f}"))

    # rmi_mlp: batch 4096 through the paper's 4-layer net
    from repro.core.cardinality.rmi import init_mlp, mlp_apply

    params = init_mlp(jax.random.PRNGKey(0), 769, (512, 512, 256, 128))
    x = jax.random.normal(jax.random.PRNGKey(1), (4096, 769))
    us = _time(lambda p, xx: mlp_apply(p, xx), params, x)
    flops = 2 * 4096 * (769 * 512 + 512 * 512 + 512 * 256 + 256 * 128 + 128)
    rows.append(("rmi_mlp_4096x769", us, f"tpu_bound_us={flops / PEAK_FLOPS * 1e6:.1f}"))

    # label_prop round: 8192 nodes
    from repro.core.range_query import pack_bitmap
    from repro.kernels.label_prop.ref import label_prop_round_ref

    adj = rng.random((2048, 2048)) < 0.005
    adj |= adj.T
    bm = jnp.asarray(pack_bitmap(adj))
    labels = jnp.arange(2048, dtype=jnp.int32)
    big = jnp.int32(np.iinfo(np.int32).max)
    us = _time(lambda l, b: label_prop_round_ref(l, b, big), labels, bm)
    byts = bm.nbytes * 32 + 2048 * 4 * 2
    rows.append(("label_prop_2048", us, f"tpu_bound_us={byts / HBM_BW * 1e6:.1f}"))

    # embedding_bag: 8192 bags of 32 from a 1M-row table
    from repro.kernels.embedding_bag.ref import embedding_bag_ref

    table = jax.random.normal(jax.random.PRNGKey(2), (100000, 64))
    ids = jnp.asarray(rng.integers(0, 100000, (8192, 32)).astype(np.int32))
    us = _time(lambda t, i: embedding_bag_ref(t, i), table, ids)
    byts = 8192 * 32 * 64 * 4 + 8192 * 64 * 4
    rows.append(("embedding_bag_8192x32x64", us, f"tpu_bound_us={byts / HBM_BW * 1e6:.1f}"))

    # flash attention forward: 4x8 heads x 1024 x 64
    from repro.kernels.flash_attention.ref import attention_ref

    qk = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 1024, 64))
    us = _time(lambda a: attention_ref(a, a, a, causal=True), qk)
    flops = 4 * 8 * (2 * 1024 * 1024 * 64 * 2) / 2  # causal half
    rows.append(("flash_attn_4x8x1024x64", us, f"tpu_bound_us={flops / PEAK_FLOPS * 1e6:.1f}"))
    return rows


def summarize(rows):
    return "\n".join(f"{n},{us:.1f},{d}" for n, us, d in rows)
