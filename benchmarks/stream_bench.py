"""Streaming ingest benchmark: amortized partial_fit vs full refit, plus
serving-grade ``assign`` latency.

Scenario (the ISSUE-4 acceptance shape): a database of ``--n0`` rows is
already clustered; live traffic then streams ``--n - --n0`` more rows
in ``--batches`` batches.  For each batch we time the incremental path
(``StreamingLAF.partial_fit``: index append + new-vs-all range queries +
promotions).  The baseline is what the repo had to do before this
subsystem existed — a **full refit** at the final size: rebuild the
index and recluster all n rows from scratch (timed through the same
streaming code path, one n-row batch, so the comparison is engine-fair).
Quality is checked by ARI between the streamed labels and the refit
labels.  Serving latency is measured per single-query ``assign`` call
(p50/p95 over ``--queries`` calls) against the final snapshot.

``--failover`` instead benchmarks the durable plane
(``repro.stream.durability``): the same traffic rides a
``DurableStream`` (WAL per batch + periodic snapshots), the primary
"dies" with a snapshot lag and an un-rotated WAL tail, and a replica is
recovered from disk.  Reported: snapshot overhead (durable vs plain
ingest p50), recovery time, WAL replay throughput, and the ARI of the
recovered labels vs the uninterrupted run (the kill-restore parity
acceptance — 1.0 means the replica is label-identical).

  PYTHONPATH=src python -m benchmarks.stream_bench                    # 20k -> 40k, d=768
  PYTHONPATH=src python -m benchmarks.stream_bench --n0 2000 --n 4000 --d 64 --n-bits 128
  PYTHONPATH=src python -m benchmarks.stream_bench --json BENCH_PR4.json   # CI artifact
  PYTHONPATH=src python -m benchmarks.stream_bench --failover --json BENCH_PR10.json
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

N_CLUSTERS = 80
NOISE_FRAC = 0.35


def _dataset(n: int, d: int, seed: int):
    from repro.data.synthetic import make_angular_clusters

    data, _ = make_angular_clusters(
        n, d, N_CLUSTERS, kappa=(d - 1) / 0.30, noise_frac=NOISE_FRAC, seed=seed
    )
    return data[np.random.default_rng(seed).permutation(n)]


def _fresh_stream(args):
    from repro.stream import StreamingLAF

    return StreamingLAF(
        args.eps, args.tau,
        backend="random_projection", device=args.device,
        n_bits=args.n_bits, seed=0,
    )


def run(args) -> dict:
    from repro import obs
    from repro.core.metrics import adjusted_rand_index

    from .common import timed

    data = _dataset(args.n, args.d, seed=0)

    # -- streaming path: n0 warm rows, then batches to n -------------------
    stream = _fresh_stream(args)
    warm_s, _ = timed(stream.partial_fit, data[: args.n0], _name="bench.warm_ingest")
    step = -(-(args.n - args.n0) // args.batches)
    batches = []
    for start in range(args.n0, args.n, step):
        rows = data[start : start + step]
        rep = stream.partial_fit(rows)
        batches.append(
            dict(
                n_after=rep.n_points,
                rows=len(rows),
                seconds=rep.elapsed_s,
                rows_per_s=len(rows) / max(rep.elapsed_s, 1e-9),
                n_promoted=rep.n_promoted,
            )
        )
        print(
            f"  batch -> n={rep.n_points:>7d}  {len(rows)} rows in "
            f"{rep.elapsed_s:6.2f}s  ({batches[-1]['rows_per_s']:,.0f} rows/s, "
            f"{rep.n_promoted} promoted)"
        )
    stream_labels = stream.labels()

    # -- baseline: full refit at the final size -----------------------------
    refit = _fresh_stream(args)
    refit_s, _ = timed(refit.partial_fit, data, _name="bench.refit")
    refit_labels = refit.labels()
    ari = adjusted_rand_index(stream_labels, refit_labels)

    mean_batch_s = float(np.mean([b["seconds"] for b in batches]))
    last_batch_s = batches[-1]["seconds"]
    amortized_speedup = refit_s / mean_batch_s
    print(
        f"refit {args.n} rows: {refit_s:.2f}s | mean batch: {mean_batch_s:.2f}s "
        f"(last {last_batch_s:.2f}s) -> amortized speedup {amortized_speedup:.1f}x | "
        f"ARI stream-vs-refit {ari:.4f}"
    )

    # -- serving latency ----------------------------------------------------
    rng = np.random.default_rng(7)
    member = np.nonzero(stream_labels >= 0)[0]
    qidx = rng.choice(member, size=args.queries, replace=len(member) < args.queries)
    noise = 0.02 * rng.standard_normal((args.queries, args.d)).astype(np.float32)
    queries = data[qidx] + noise
    stream.snapshot()  # build the serving snapshot outside the timed region
    # latency percentiles come from the obs log-bucket histogram that
    # serve.assign feeds (the serving process's own SLO instrument),
    # not a benchmark-side sample array
    was_on = obs.metrics_enabled()
    obs.metrics.enable()
    hist = obs.metrics.histogram("serve.assign.latency_s")
    hist._reset()
    for i in range(args.queries):
        stream.assign(queries[i : i + 1])
    s = hist.summary()
    if not was_on:
        obs.metrics.disable()
    p50, p95, p99 = (float(s[k] * 1e3) for k in ("p50", "p95", "p99"))
    print(
        f"assign latency over {args.queries} single queries: "
        f"p50 {p50:.2f} ms, p95 {p95:.2f} ms, p99 {p99:.2f} ms"
    )

    return dict(
        n0=args.n0, n=args.n, d=args.d, n_bits=args.n_bits,
        eps=args.eps, tau=args.tau, device=args.device, batches=batches,
        warm_ingest_seconds=warm_s,
        refit_seconds=refit_s,
        mean_batch_seconds=mean_batch_s,
        last_batch_seconds=last_batch_s,
        amortized_speedup=amortized_speedup,
        ari_stream_vs_refit=float(ari),
        n_clusters=int(stream.n_clusters),
        assign=dict(
            p50_ms=p50, p95_ms=p95, p99_ms=p99, n_queries=args.queries,
            mean_ms=float(s["sum"] / max(s["count"], 1) * 1e3),
        ),
    )


def run_failover(args) -> dict:
    import tempfile
    import time

    from repro.core.metrics import adjusted_rand_index
    from repro.stream import DurableStream

    data = _dataset(args.n, args.d, seed=0)
    step = -(-args.n // args.batches)
    batches = [data[i : i + step] for i in range(0, args.n, step)]
    fsync = not args.no_fsync

    # -- plain ingest baseline: per-batch p50 + reference labels -----------
    bare = _fresh_stream(args)
    bare_s = [bare.partial_fit(b).elapsed_s for b in batches]
    ingest_p50 = float(np.median(bare_s))
    ref_labels = bare.labels()

    with tempfile.TemporaryDirectory() as root:
        # -- durable primary: WAL per batch + periodic snapshots -----------
        primary = DurableStream(
            _fresh_stream(args), root,
            snapshot_every=args.snapshot_every, fsync=fsync,
        )
        dur_s = []
        for b in batches:
            t0 = time.perf_counter()
            primary.partial_fit(b)
            dur_s.append(time.perf_counter() - t0)
        durable_p50 = float(np.median(dur_s))
        # the primary dies here: no close(), the WAL tail past the last
        # snapshot is what recovery must replay
        replica = DurableStream.recover(
            root, lambda: _fresh_stream(args), fsync=fsync
        )
        info = dict(replica.recovery_info)
        ari = adjusted_rand_index(replica.labels(), ref_labels)
        replica.close()
        primary.close()

    replay_rate = info["wal_rows"] / max(info["replay_s"], 1e-9)
    overhead = durable_p50 / max(ingest_p50, 1e-9) - 1.0
    print(
        f"failover: {args.n} rows / {args.batches} batches, snapshot every "
        f"{args.snapshot_every} (fsync={fsync})\n"
        f"  ingest p50 {ingest_p50 * 1e3:.1f} ms -> durable p50 "
        f"{durable_p50 * 1e3:.1f} ms (snapshot overhead {overhead:+.1%})\n"
        f"  recovery {info['recovery_s']:.3f}s = restore {info['restore_s']:.3f}s "
        f"(snapshot step {info['snapshot_step']}) + replay "
        f"{info['replay_s']:.3f}s ({info['wal_records']} records, "
        f"{info['wal_rows']} rows, {replay_rate:,.0f} rows/s)\n"
        f"  ARI recovered-vs-uninterrupted: {ari:.4f}"
    )

    return dict(
        mode="failover",
        n=args.n, d=args.d, n_bits=args.n_bits, eps=args.eps, tau=args.tau,
        device=args.device, n_batches=args.batches,
        failover=dict(
            snapshot_every=args.snapshot_every,
            fsync=fsync,
            ingest_p50_s=ingest_p50,
            durable_p50_s=durable_p50,
            snapshot_overhead=overhead,
            recovery_s=float(info["recovery_s"]),
            restore_s=float(info["restore_s"]),
            replay_s=float(info["replay_s"]),
            snapshot_step=int(info["snapshot_step"]),
            seq=int(info["seq"]),
            wal_records=int(info["wal_records"]),
            wal_rows=int(info["wal_rows"]),
            wal_replay_rows_per_s=float(replay_rate),
            ari_recovered=float(ari),
        ),
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n0", type=int, default=20000, help="warm database size")
    ap.add_argument("--n", type=int, default=40000, help="final database size")
    ap.add_argument("--d", type=int, default=768)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--n-bits", type=int, default=512)
    ap.add_argument("--eps", type=float, default=0.3)
    ap.add_argument("--tau", type=int, default=5)
    ap.add_argument("--device", default="auto")
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--failover", action="store_true",
                    help="benchmark the durable plane: snapshot overhead, "
                    "recovery time, WAL replay throughput, recovered-ARI")
    ap.add_argument("--snapshot-every", type=int, default=3,
                    help="failover: batches between snapshots (a non-divisor "
                    "of --batches leaves a WAL tail for recovery to replay)")
    ap.add_argument("--no-fsync", action="store_true",
                    help="failover: skip per-append fsync (CI-runner mode)")
    ap.add_argument("--json", type=Path, default=None)
    args = ap.parse_args()

    payload = run_failover(args) if args.failover else run(args)
    if args.json:
        args.json.write_text(json.dumps(payload, indent=2))
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
