"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows for the kernel micro-bench
plus per-table summaries, and writes JSON artifacts under
``artifacts/benchmarks/``.

  PYTHONPATH=src python -m benchmarks.run                 # standard profile
  PYTHONPATH=src python -m benchmarks.run --profile quick
  PYTHONPATH=src python -m benchmarks.run --only fig1_time,table6_missed
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (
    fig1_time,
    fig23_tradeoff,
    index_bench,
    kernel_bench,
    table2_noise,
    table3_quality,
    table4_rho,
    table5_scalability,
    table6_missed,
)

TABLES = {
    "kernel_bench": kernel_bench,
    "index_bench": index_bench,
    "table2_noise": table2_noise,
    "table3_quality": table3_quality,
    "fig1_time": fig1_time,
    "table4_rho": table4_rho,
    "table5_scalability": table5_scalability,
    "fig23_tradeoff": fig23_tradeoff,
    "table6_missed": table6_missed,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="standard", choices=["quick", "standard", "large"])
    ap.add_argument("--only", default=None, help="comma-separated table names")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(TABLES)
    for name in names:
        mod = TABLES[name]
        t0 = time.time()
        print(f"\n=== {name} (profile={args.profile}) ===", flush=True)
        if name == "kernel_bench":
            rows = mod.run()
        else:
            rows = mod.run(profile=args.profile)
        print(mod.summarize(rows), flush=True)
        print(f"[{name} done in {time.time() - t0:.1f}s]", flush=True)


if __name__ == "__main__":
    main()
