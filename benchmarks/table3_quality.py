"""Table 3: ARI/AMI of the approximate methods on the three datasets at
the paper's (ε, τ) settings, vs exact-DBSCAN ground truth."""

from __future__ import annotations

from .common import EPS_TAU, ground_truth, prepare, quality, save_json
from .methods import APPROX_METHODS, run_method


def run(profile: str = "standard", datasets=("nyt", "glove", "ms")):
    rows = []
    for ds in datasets:
        prep = prepare(ds, profile)
        for eps, tau in EPS_TAU:
            gt = ground_truth(prep, eps, tau)
            if gt.n_clusters < 2:
                continue
            for method in APPROX_METHODS:
                t, res = run_method(method, prep, eps, tau)
                q = quality(res.labels, gt.labels)
                rows.append({
                    "dataset": ds, "eps": eps, "tau": tau, "method": method,
                    "ARI": q["ARI"], "AMI": q["AMI"], "time_s": t,
                    "n_clusters": res.n_clusters,
                    "gt_clusters": gt.n_clusters,
                    "queries": res.n_range_queries,
                })
    save_json("table3_quality", rows)
    return rows


def summarize(rows):
    lines = ["table3: method quality (ARI / AMI), higher is better"]
    for ds in sorted({r["dataset"] for r in rows}):
        for eps, tau in sorted({(r["eps"], r["tau"]) for r in rows}):
            sub = [r for r in rows if r["dataset"] == ds and r["eps"] == eps and r["tau"] == tau]
            if not sub:
                continue
            lines.append(f"  {ds} (eps={eps}, tau={tau}):")
            for r in sorted(sub, key=lambda r: -r["ARI"]):
                lines.append(
                    f"    {r['method']:13s} ARI={r['ARI']:.4f} AMI={r['AMI']:.4f} "
                    f"t={r['time_s']:.2f}s"
                )
    return "\n".join(lines)
