"""Index benchmark: recall / speedup / clustering quality of the
``repro.index`` range backends across (n, d, eps).

For each operating point the same query sweep runs through the exact
blocked-matmul backend and the random-projection ANN backend
(interleaved block by block so recall is measured pair-exactly without
materializing an n^2 adjacency), then LAF-DBSCAN runs end-to-end on
both backends with an oracle cardinality estimator so the ARI delta
isolates the index, not the estimator.

  PYTHONPATH=src python -m benchmarks.index_bench                  # 20k x 768
  PYTHONPATH=src python -m benchmarks.index_bench --grid           # n x d x eps sweep
  PYTHONPATH=src python -m benchmarks.index_bench --n 5000 --d 256
  PYTHONPATH=src python -m benchmarks.index_bench \
      --n 2000 --d 64 --device device --json BENCH_PR2.json        # CI trajectory
  PYTHONPATH=src python -m benchmarks.index_bench \
      --n 2000 --d 64 --mesh 4 --json BENCH_PR3.json  # sharded index plane
  PYTHONPATH=src python -m benchmarks.index_bench \
      --n 40000 --d 768 --sweep --json BENCH_PR5.json # sweep engine
  PYTHONPATH=src python -m benchmarks.index_bench \
      --n 2000 --d 64 --sweep --mesh 4 --json BENCH_PR5.json

``--device device`` routes the ANN backend through the fused Pallas
``hamming_filter`` tile (interpret mode off-accelerator), so the CI
artifact tracks the kernel path's recall/speedup/ARI, not just the
host oracle's.  ``--mesh N`` forces N host devices (the flag must be
set before jax initializes, which is why the repro imports below are
deferred into the functions) and runs the same sweep through the
shard_mapped index plane — the row payload then carries both the
sharded and single-device fused sweep times plus per-device shard
numbers.

``--sweep`` benchmarks the device-resident sweep engine
(``repro.index.sweep``) instead: the legacy per-chunk dispatch loop
(one kernel launch + one synchronous device→host round-trip per chunk)
vs the one-launch engine on a whole-database sweep, plus — under
``--mesh N`` — the serialized plane vs the double-buffered
(software-pipelined) plane, with LAF-DBSCAN end-to-end ARI vs the
exact backend through the engine-backed index in the same payload.

``--cluster`` benchmarks cluster *formation* (BENCH_PR8.json): the
same engine-backed index runs LAF-DBSCAN twice, once with
``cluster_device=False`` (the PR 5 path — device sweep, then host
unpack + union-find per block) and once with ``cluster_device=True``
(the one-launch program: packed label propagation under a single
``lax.while_loop``, exactly one device→host transfer for the whole
clustering).  The row carries per-phase span costs, rounds-to-fixpoint
and the ``laf.cluster.device_get`` counter delta — the one-launch run
asserts that delta is exactly 1 — plus exact label parity between the
two paths.

  PYTHONPATH=src python -m benchmarks.index_bench \
      --n 2000 --d 64 --cluster --json BENCH_PR8.json
  PYTHONPATH=src python -m benchmarks.index_bench \
      --n 2000 --d 64 --cluster --mesh 4 --json BENCH_PR8.json
"""

from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

import numpy as np

N_CLUSTERS = 80
NOISE_FRAC = 0.35


def _dataset(n: int, d: int, seed: int):
    from repro.data.synthetic import make_angular_clusters

    # kappa = (d-1)/0.30 puts same-cluster pairs near d_cos ~ 0.3
    # (see benchmarks.common DATASETS rationale)
    return make_angular_clusters(
        n, d, N_CLUSTERS, kappa=(d - 1) / 0.30, noise_frac=NOISE_FRAC, seed=seed
    )


def bench_point(
    n: int,
    d: int,
    eps: float,
    tau: int,
    *,
    n_bits: int = 512,
    margin: float = 3.0,
    verify: str = "band",
    device: str = "host",
    mesh_devices: int = 0,
    seed: int = 0,
    block: int = 2048,
) -> dict:
    from repro.core.laf_dbscan import laf_dbscan
    from repro.core.metrics import adjusted_rand_index
    from repro.index import ExactBackend, RandomProjectionBackend

    from .common import timed

    data, _ = _dataset(n, d, seed)
    exact = ExactBackend().fit(data)
    mesh = None
    if mesh_devices > 1:
        import jax

        mesh = jax.make_mesh((mesh_devices,), ("data",))
    build_s, rp = timed(
        lambda: RandomProjectionBackend(
            n_bits=n_bits, margin=margin, verify=verify, seed=seed,
            # the plane is a device evaluator: --mesh implies the fused tile
            device=True if mesh is not None else (device == "device"), mesh=mesh,
        ).fit(data),
        _name="bench.build",
    )
    # same index configuration WITHOUT the mesh: the single-device fused
    # tile, so the sharded-vs-single sweep delta isolates the plane
    rp_single = None
    if mesh is not None:
        rp_single = RandomProjectionBackend(
            n_bits=n_bits, margin=margin, verify=verify, seed=seed, device=True,
        ).fit(data)

    counts = np.zeros(n, dtype=np.int64)
    shard_hits = None
    tp = pos = pred = 0
    t_exact = t_rp = t_rp_single = 0.0
    for start in range(0, n, block):
        rows = np.arange(start, min(start + block, n))
        dt, h_ex = timed(exact.query_hits, rows, eps, _name="bench.sweep_exact")
        t_exact += dt
        dt, h_rp = timed(rp.query_hits, rows, eps, _name="bench.sweep_rp")
        t_rp += dt
        if rp_single is not None:
            dt, _ = timed(
                rp_single.query_hits, rows, eps, _name="bench.sweep_rp_single"
            )
            t_rp_single += dt
            # per-device hit totals: slice the hit matrix at the plane's
            # shard boundaries (rows n_local*k .. n_local*(k+1) live on
            # device k)
            n_local = rp._plan.n_local
            if shard_hits is None:
                shard_hits = np.zeros(mesh_devices, dtype=np.int64)
            for k in range(mesh_devices):
                shard_hits[k] += int(h_rp[:, k * n_local : (k + 1) * n_local].sum())
        counts[rows] = h_ex.sum(axis=1)
        tp += int((h_ex & h_rp).sum())
        pos += int(h_ex.sum())
        pred += int(h_rp.sum())

    # end-to-end LAF-DBSCAN, oracle estimator, backend is the only delta
    t_laf_exact, res_ex = timed(
        laf_dbscan, data, eps, tau, 1.0, counts,
        seed=seed, backend=exact, _name="bench.laf_exact",
    )
    t_laf_rp, res_rp = timed(
        laf_dbscan, data, eps, tau, 1.0, counts,
        seed=seed, backend=rp, _name="bench.laf_rp",
    )

    row = {
        "n": n, "d": d, "eps": eps, "tau": tau,
        "n_bits": n_bits, "margin": margin, "verify": verify,
        # the evaluator that actually ran (--mesh forces the fused tile)
        "device": "device" if mesh is not None else device,
        "mesh": mesh_devices,
        "build_s": build_s,
        "sweep_exact_s": t_exact, "sweep_rp_s": t_rp,
        "sweep_speedup": t_exact / t_rp if t_rp else float("inf"),
        "recall": tp / pos if pos else 1.0,
        "precision": tp / pred if pred else 1.0,
        "laf_exact_s": t_laf_exact, "laf_rp_s": t_laf_rp,
        "laf_speedup": t_laf_exact / t_laf_rp if t_laf_rp else float("inf"),
        "ari_rp_vs_exact": adjusted_rand_index(res_ex.labels, res_rp.labels),
        "noise_exact": res_ex.noise_ratio, "noise_rp": res_rp.noise_ratio,
    }
    if mesh is not None:
        plan = rp._plan
        row["sweep_rp_single_s"] = t_rp_single
        # >1 means the plane beat the single-device tile (expect <1 on a
        # CPU runner: N interpret-mode kernels on 2 cores is a parity
        # harness, not a speed win — the trajectory tracks the ratio)
        row["sharded_speedup"] = t_rp_single / t_rp if t_rp else float("inf")
        row["per_device"] = [
            {
                "device": k,
                "rows": int(min(max(plan.n - k * plan.n_local, 0), plan.n_local)),
                "hits": int(shard_hits[k]),
            }
            for k in range(mesh_devices)
        ]
    return row


def bench_sweep_point(
    n: int,
    d: int,
    eps: float,
    tau: int,
    *,
    n_bits: int = 512,
    margin: float = 3.0,
    mesh_devices: int = 0,
    seed: int = 0,
    block: int = 2048,
    chunks_per_launch: int = 8,
    with_ari: bool = True,
    chunk: int = 256,
    q_tile: int = 128,
    db_tile: int = 256,
) -> dict:
    """Per-chunk loop vs one-launch sweep (vs the pipelined plane under
    ``--mesh``) on one whole-database query sweep.

    ``chunk``/``q_tile``/``db_tile`` apply to *both* variants (the
    comparison is per-chunk dispatch vs one launch at identical tiling);
    off-accelerator the interpreter's per-tile-step overhead dominates,
    so CPU runs of the big operating points should raise the tiles
    (e.g. ``--chunk 1024 --q-tile 256 --db-tile 2048``).
    """
    from repro.core.laf_dbscan import laf_dbscan
    from repro.core.metrics import adjusted_rand_index
    from repro.index import ExactBackend, RandomProjectionBackend

    from .common import timed

    data, _ = _dataset(n, d, seed)
    mesh = None
    if mesh_devices > 1:
        import jax

        mesh = jax.make_mesh((mesh_devices,), ("data",))
    cfg = dict(
        n_bits=n_bits, margin=margin, seed=seed, device=True, mesh=mesh,
        chunk=chunk, q_tile=q_tile, db_tile=db_tile,
    )
    variants = {
        "per_chunk": RandomProjectionBackend(sweep=False, **cfg),
        "one_launch": RandomProjectionBackend(
            sweep=True, chunks_per_launch=chunks_per_launch, pipeline_depth=1, **cfg
        ),
    }
    if mesh is not None:
        # under a mesh "one_launch" is the serialized (depth-1) plane;
        # the pipelined variant double-buffers chunk k's psum against
        # chunk k+1's shard-local popcount+verify
        variants["pipelined"] = RandomProjectionBackend(
            sweep=True, chunks_per_launch=chunks_per_launch, pipeline_depth=2, **cfg
        )
    times = {}
    for name, bk in variants.items():
        bk.fit(data)
        bk.query_hits(np.arange(min(block, n)), eps)  # warm/compile

        def _sweep_all(bk=bk):
            for start in range(0, n, block):
                rows = np.arange(start, min(start + block, n))
                bk.query_hits(rows, eps)

        times[name], _ = timed(_sweep_all, _name=f"bench.sweep_{name}")
        print(f"  sweep[{name}]: {times[name]:.2f}s", flush=True)

    row = {
        "n": n, "d": d, "eps": eps, "tau": tau,
        "n_bits": n_bits, "margin": margin, "mesh": mesh_devices,
        "chunks_per_launch": chunks_per_launch,
        "chunk": chunk, "q_tile": q_tile, "db_tile": db_tile,
        "sweep_per_chunk_s": times["per_chunk"],
        "sweep_one_launch_s": times["one_launch"],
        "one_launch_speedup": times["per_chunk"] / times["one_launch"],
    }
    if mesh is not None:
        row["sweep_pipelined_s"] = times["pipelined"]
        row["pipelined_speedup"] = times["per_chunk"] / times["pipelined"]
        row["pipelined_vs_serial_launch"] = times["one_launch"] / times["pipelined"]
    if with_ari:
        # LAF e2e through the engine-backed index, oracle estimator —
        # the sweep rewiring must not move a single label
        exact = ExactBackend().fit(data)
        pred = exact.query_counts(np.arange(n), eps)
        res_ex = laf_dbscan(data, eps, tau, 1.0, pred, seed=seed, backend=exact)
        eng = variants["pipelined" if mesh is not None else "one_launch"]
        res_sw = laf_dbscan(data, eps, tau, 1.0, pred, seed=seed, backend=eng)
        row["ari_sweep_vs_exact"] = adjusted_rand_index(res_ex.labels, res_sw.labels)
    return row


def bench_cluster_point(
    n: int,
    d: int,
    eps: float,
    tau: int,
    *,
    n_bits: int = 512,
    margin: float = 3.0,
    mesh_devices: int = 0,
    seed: int = 0,
    chunk: int = 256,
    q_tile: int = 128,
    db_tile: int = 256,
    chunks_per_launch: int = 8,
) -> dict:
    """Host union-find vs one-launch device clustering on one dataset.

    Both variants run through the *same* fitted engine-backed index
    with the *same* oracle predicted counts at ``alpha=1.0`` (so no
    point is rescued and the device path's single fetch is the only
    device→host transfer of the whole clustering) — the delta isolates
    cluster formation, not the index or the estimator.
    """
    from repro import obs
    from repro.core.laf_dbscan import laf_dbscan
    from repro.core.metrics import adjusted_rand_index
    from repro.index import ExactBackend, RandomProjectionBackend

    from .common import timed

    data, _ = _dataset(n, d, seed)
    mesh = None
    if mesh_devices > 1:
        import jax

        mesh = jax.make_mesh((mesh_devices,), ("data",))
    bk = RandomProjectionBackend(
        n_bits=n_bits, margin=margin, seed=seed, device=True, mesh=mesh,
        sweep=True, chunks_per_launch=chunks_per_launch,
        chunk=chunk, q_tile=q_tile, db_tile=db_tile,
    ).fit(data)
    # oracle predicted counts + alpha=1.0: pred >= true for every row,
    # so the skip rule never under-predicts and rescue stays empty
    pred = np.asarray(ExactBackend().fit(data).query_counts(np.arange(n), eps))

    obs.enable(trace=True, metrics_on=True)
    variants = {"host_union_find": False, "one_launch": True}
    phase_names = (
        "laf.pass1", "laf.union_find", "laf.label_prop", "laf.postprocess",
    )
    row = {
        "n": n, "d": d, "eps": eps, "tau": tau,
        "n_bits": n_bits, "margin": margin, "mesh": mesh_devices,
        "chunk": chunk, "q_tile": q_tile, "db_tile": db_tile,
    }
    results = {}
    for name, on_device in variants.items():
        kw = dict(seed=seed, backend=bk, cluster_device=on_device)
        laf_dbscan(data, eps, tau, 1.0, pred, **kw)  # warm/compile
        obs.clear_trace()
        c_get = obs.metrics.counter("laf.cluster.device_get").value
        c_rounds = obs.metrics.counter("laf.cluster.rounds").value
        c_launch = obs.metrics.counter("labelprop.launches").value
        t_e2e, res = timed(
            laf_dbscan, data, eps, tau, 1.0, pred, **kw,
            _name=f"bench.cluster_{name}",
        )
        results[name] = res
        phases = {
            p: sum(s.dur for s in obs.spans(p)) for p in phase_names
        }
        row[name] = {
            "e2e_s": t_e2e,
            "phases_s": {p: t for p, t in phases.items() if t > 0.0},
            "device_get": obs.metrics.counter("laf.cluster.device_get").value
            - c_get,
            "rounds": obs.metrics.counter("laf.cluster.rounds").value
            - c_rounds,
            "labelprop_launches": obs.metrics.counter(
                "labelprop.launches"
            ).value - c_launch,
            "n_rescued": res.extras["n_rescued"],
        }
        print(
            f"  cluster[{name}]: {t_e2e:.2f}s rounds={row[name]['rounds']} "
            f"device_get={row[name]['device_get']}", flush=True,
        )
    dev = row["one_launch"]
    assert dev["device_get"] == 1, (
        f"one-launch clustering did {dev['device_get']} device fetches, "
        "expected exactly 1"
    )
    assert dev["n_rescued"] == 0, (
        "oracle counts at alpha=1.0 must be rescue-free, got "
        f"{dev['n_rescued']}"
    )
    lab_host = results["host_union_find"].labels
    lab_dev = results["one_launch"].labels
    row["labels_exact_match"] = bool(np.array_equal(lab_host, lab_dev))
    row["ari_one_launch_vs_host"] = adjusted_rand_index(lab_host, lab_dev)
    row["cluster_speedup"] = (
        row["host_union_find"]["e2e_s"] / dev["e2e_s"]
        if dev["e2e_s"] else float("inf")
    )

    # device-telemetry overhead, warm-vs-warm on the one-launch path:
    # the telemetry flag is a compile-time static (each state owns its
    # executable), so warm both programs first, then time back to back.
    # The telemetry-on run must keep the single-fetch contract and move
    # no label — the counters ride the existing device_get.
    from repro.obs import device as obs_device

    was_on = obs_device.device_enabled()
    kw = dict(seed=seed, backend=bk, cluster_device=True)
    obs_device.disable_device()
    laf_dbscan(data, eps, tau, 1.0, pred, **kw)  # warm (telemetry off)
    obs_device.enable_device()
    try:
        laf_dbscan(data, eps, tau, 1.0, pred, **kw)  # compile+warm (on)
        base = {
            k: obs.metrics.counter(k).value
            for k in (
                "laf.cluster.device_get",
                "laf.telemetry.frontier", "laf.telemetry.changed",
                "laf.telemetry.hops", "laf.telemetry.shard_wins",
            )
        }
        t_on0, res_tele = timed(
            laf_dbscan, data, eps, tau, 1.0, pred, **kw,
            _name="bench.cluster_tele_on",
        )
        delta = {
            k: obs.metrics.counter(k).value - v for k, v in base.items()
        }
        # both programs are warm: the overhead ratio is gated in CI, so
        # measure it as interleaved min-of-N — at the tens-of-ms scale of
        # this operating point a single back-to-back pair carries more
        # scheduler noise than the 5% budget being measured
        t_offs, t_ons = [], [t_on0]
        for _ in range(4):
            obs_device.disable_device()
            t, _ = timed(
                laf_dbscan, data, eps, tau, 1.0, pred, **kw,
                _name="bench.cluster_tele_off",
            )
            t_offs.append(t)
            obs_device.enable_device()
            t, _ = timed(
                laf_dbscan, data, eps, tau, 1.0, pred, **kw,
                _name="bench.cluster_tele_on",
            )
            t_ons.append(t)
        t_off, t_on = min(t_offs), min(t_ons)
    finally:
        if not was_on:
            obs_device.disable_device()
    assert delta["laf.cluster.device_get"] == 1, (
        "telemetry-on one-launch clustering did "
        f"{delta['laf.cluster.device_get']} device fetches, expected 1"
    )
    assert np.array_equal(res_tele.labels, lab_dev), (
        "device telemetry moved clustering labels"
    )
    row["telemetry"] = {
        "off_s": t_off,
        "on_s": t_on,
        "telemetry_overhead": t_on / t_off - 1.0 if t_off else 0.0,
        "device_get": delta["laf.cluster.device_get"],
        "totals": {
            f: delta[f"laf.telemetry.{f}"]
            for f in obs_device.CLUSTER_ROUND_FIELDS
        },
    }
    print(
        f"  cluster[telemetry]: off {t_off:.2f}s on {t_on:.2f}s "
        f"overhead {row['telemetry']['telemetry_overhead']:+.1%}",
        flush=True,
    )
    return row


def run_cluster(
    *,
    ns=(2000,),
    ds=(64,),
    epss=(0.55,),
    tau: int = 5,
    n_bits: int = 512,
    margin: float = 3.0,
    mesh_devices: int = 0,
    seed: int = 0,
    chunk: int = 256,
    q_tile: int = 128,
    db_tile: int = 256,
):
    from .common import save_json

    rows = []
    for n in ns:
        for d in ds:
            for eps in epss:
                row = bench_cluster_point(
                    n, d, eps, tau, n_bits=n_bits, margin=margin,
                    mesh_devices=mesh_devices, seed=seed,
                    chunk=chunk, q_tile=q_tile, db_tile=db_tile,
                )
                rows.append(row)
                print(
                    f"  n={n} d={d} eps={eps}: one-launch "
                    f"x{row['cluster_speedup']:.2f} "
                    f"rounds={row['one_launch']['rounds']} "
                    f"exact_match={row['labels_exact_match']} "
                    f"ARI={row['ari_one_launch_vs_host']:.4f}",
                    flush=True,
                )
    save_json("index_bench_cluster", rows)
    return rows


def run_sweep(
    *,
    ns=(40000,),
    ds=(768,),
    epss=(0.55,),
    tau: int = 5,
    n_bits: int = 512,
    margin: float = 3.0,
    mesh_devices: int = 0,
    seed: int = 0,
    with_ari: bool = True,
    chunk: int = 256,
    q_tile: int = 128,
    db_tile: int = 256,
):
    from .common import save_json

    rows = []
    for n in ns:
        for d in ds:
            for eps in epss:
                row = bench_sweep_point(
                    n, d, eps, tau, n_bits=n_bits, margin=margin,
                    mesh_devices=mesh_devices, seed=seed, with_ari=with_ari,
                    chunk=chunk, q_tile=q_tile, db_tile=db_tile,
                )
                rows.append(row)
                extra = (
                    f" pipelined x{row['pipelined_speedup']:.2f}"
                    if "pipelined_speedup" in row else ""
                )
                ari = (
                    f" ARI={row['ari_sweep_vs_exact']:.4f}"
                    if "ari_sweep_vs_exact" in row else ""
                )
                print(
                    f"  n={n} d={d} eps={eps}: one-launch "
                    f"x{row['one_launch_speedup']:.2f}{extra}{ari}",
                    flush=True,
                )
    save_json("index_bench_sweep", rows)
    return rows


def run(
    profile: str = "standard",
    *,
    ns=(20000,),
    ds=(768,),
    epss=(0.55,),
    tau: int = 5,
    n_bits: int = 512,
    margin: float = 3.0,
    verify: str = "band",
    device: str = "host",
    mesh_devices: int = 0,
    seed: int = 0,
):
    from .common import save_json

    if profile == "quick":  # keep `-m benchmarks.run --profile quick` cheap
        ns, ds = tuple(min(x, 5000) for x in ns), tuple(min(x, 256) for x in ds)
    rows = []
    for n in ns:
        for d in ds:
            for eps in epss:
                row = bench_point(
                    n, d, eps, tau,
                    n_bits=n_bits, margin=margin, verify=verify, device=device,
                    mesh_devices=mesh_devices, seed=seed,
                )
                rows.append(row)
                extra = (
                    f" sharded speedup x{row['sharded_speedup']:.2f}"
                    if "sharded_speedup" in row else ""
                )
                print(
                    f"  n={n} d={d} eps={eps}: recall={row['recall']:.4f} "
                    f"sweep x{row['sweep_speedup']:.2f} laf x{row['laf_speedup']:.2f} "
                    f"ARI={row['ari_rp_vs_exact']:.4f}{extra}",
                    flush=True,
                )
    save_json("index_bench", rows)
    return rows


def summarize(rows) -> str:
    lines = [
        "index_bench: random_projection vs exact backend",
        f"{'n':>7} {'d':>5} {'eps':>5} | {'recall':>7} {'prec':>6} | "
        f"{'sweep x':>8} {'laf x':>6} | {'ARI':>6}",
    ]
    for r in rows:
        lines.append(
            f"{r['n']:>7} {r['d']:>5} {r['eps']:>5.2f} | {r['recall']:>7.4f} "
            f"{r['precision']:>6.3f} | {r['sweep_speedup']:>8.2f} "
            f"{r['laf_speedup']:>6.2f} | {r['ari_rp_vs_exact']:>6.3f}"
        )
    worst_recall = min(r["recall"] for r in rows)
    worst_ari = min(r["ari_rp_vs_exact"] for r in rows)
    lines.append(f"worst recall {worst_recall:.4f}; worst ARI vs exact {worst_ari:.4f}")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, nargs="+", default=[20000])
    ap.add_argument("--d", type=int, nargs="+", default=[768])
    ap.add_argument("--eps", type=float, nargs="+", default=[0.55])
    ap.add_argument("--tau", type=int, default=5)
    ap.add_argument("--n-bits", type=int, default=512)
    ap.add_argument("--margin", type=float, default=3.0)
    ap.add_argument("--verify", choices=["band", "full"], default="band")
    ap.add_argument(
        "--device", choices=["host", "device"], default="host",
        help="ANN backend evaluator: host numpy band logic or the fused "
        "Pallas hamming_filter tile (interpret mode off-accelerator)",
    )
    ap.add_argument(
        "--mesh", type=int, default=0, metavar="N",
        help="benchmark the sharded index plane on N forced host devices "
        "(sets --xla_force_host_platform_device_count before jax "
        "initializes; implies the device evaluator); rows then include "
        "the single-device fused sweep time and per-device shard numbers",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--json", type=Path, default=None,
        help="also write {rows, summary} to this path (CI perf-trajectory "
        "artifact, e.g. BENCH_PR2.json)",
    )
    ap.add_argument(
        "--grid", action="store_true",
        help="sweep n in {5000, 20000}, d in {256, 768}, eps in {0.5, 0.55, 0.6}",
    )
    ap.add_argument(
        "--sweep", action="store_true",
        help="benchmark the device-resident sweep engine: per-chunk loop "
        "vs one-launch (vs the double-buffered plane under --mesh), with "
        "LAF e2e ARI vs exact in the payload (BENCH_PR5.json)",
    )
    ap.add_argument(
        "--cluster", action="store_true",
        help="benchmark cluster formation: host unpack+union-find "
        "(cluster_device=False, the PR 5 path) vs the one-launch packed "
        "label-propagation program (cluster_device=True), with phase "
        "costs, rounds-to-fixpoint, the device_get==1 assertion and "
        "exact label parity (BENCH_PR8.json)",
    )
    ap.add_argument(
        "--no-ari", action="store_true",
        help="--sweep only: skip the exact-backend LAF e2e ARI pass "
        "(the O(n^2) part of the sweep benchmark)",
    )
    ap.add_argument(
        "--max-telemetry-overhead", type=float, default=None, metavar="FRAC",
        help="--cluster only: fail (exit 1) when the warm telemetry-on "
        "one-launch pass is more than FRAC slower than telemetry-off "
        "(CI passes 0.05)",
    )
    ap.add_argument("--chunk", type=int, default=256,
                    help="--sweep only: query rows per kernel pass")
    ap.add_argument("--q-tile", type=int, default=128,
                    help="--sweep only: kernel query tile")
    ap.add_argument("--db-tile", type=int, default=256,
                    help="--sweep only: kernel db tile")
    args = ap.parse_args(argv)
    if args.mesh > 1:
        # must land before the first jax import anywhere in the process
        # (the repro imports are deferred into the functions for this);
        # any inherited force-count is replaced, other flags are kept
        import sys

        assert "jax" not in sys.modules, "--mesh requires jax to be uninitialized"
        inherited = [
            f for f in os.environ.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")
        ]
        os.environ["XLA_FLAGS"] = " ".join(
            [f"--xla_force_host_platform_device_count={args.mesh}"] + inherited
        )
    ns, ds, epss = tuple(args.n), tuple(args.d), tuple(args.eps)
    if args.grid:
        ns, ds, epss = (5000, 20000), (256, 768), (0.5, 0.55, 0.6)
    if args.cluster:
        rows = run_cluster(
            ns=ns, ds=ds, epss=epss, tau=args.tau, n_bits=args.n_bits,
            margin=args.margin, mesh_devices=args.mesh, seed=args.seed,
            chunk=args.chunk, q_tile=args.q_tile, db_tile=args.db_tile,
        )
        worst_overhead = max(
            r["telemetry"]["telemetry_overhead"] for r in rows
        )
        if args.json is not None:
            payload = {
                "rows": rows,
                "best_cluster_speedup": max(r["cluster_speedup"] for r in rows),
                "worst_ari": min(r["ari_one_launch_vs_host"] for r in rows),
                "all_labels_exact": all(r["labels_exact_match"] for r in rows),
                "max_device_get": max(r["one_launch"]["device_get"] for r in rows),
                "max_rounds": max(r["one_launch"]["rounds"] for r in rows),
                "worst_telemetry_overhead": worst_overhead,
            }
            args.json.write_text(json.dumps(payload, indent=2, default=float))
            print(f"wrote {args.json}")
        if (
            args.max_telemetry_overhead is not None
            and worst_overhead > args.max_telemetry_overhead
        ):
            raise SystemExit(
                f"warm telemetry-on overhead {worst_overhead:.1%} exceeds "
                f"--max-telemetry-overhead {args.max_telemetry_overhead:.0%}"
            )
        return
    if args.sweep:
        rows = run_sweep(
            ns=ns, ds=ds, epss=epss, tau=args.tau, n_bits=args.n_bits,
            margin=args.margin, mesh_devices=args.mesh, seed=args.seed,
            with_ari=not args.no_ari,
            chunk=args.chunk, q_tile=args.q_tile, db_tile=args.db_tile,
        )
        if args.json is not None:
            payload = {
                "rows": rows,
                "best_one_launch_speedup": max(
                    r["one_launch_speedup"] for r in rows
                ),
            }
            if args.mesh > 1:
                payload["best_pipelined_speedup"] = max(
                    r["pipelined_speedup"] for r in rows
                )
            if not args.no_ari:
                payload["worst_ari"] = min(r["ari_sweep_vs_exact"] for r in rows)
            args.json.write_text(json.dumps(payload, indent=2, default=float))
            print(f"wrote {args.json}")
        return
    rows = run(
        ns=ns, ds=ds, epss=epss, tau=args.tau, n_bits=args.n_bits,
        margin=args.margin, verify=args.verify, device=args.device,
        mesh_devices=args.mesh, seed=args.seed,
    )
    print(summarize(rows))
    if args.json is not None:
        payload = {
            "rows": rows,
            "worst_recall": min(r["recall"] for r in rows),
            "worst_ari": min(r["ari_rp_vs_exact"] for r in rows),
            "best_sweep_speedup": max(r["sweep_speedup"] for r in rows),
        }
        if args.mesh > 1:
            payload["mesh_summary"] = {
                "mesh": args.mesh,
                "sweep_sharded_s": sum(r["sweep_rp_s"] for r in rows),
                "sweep_single_device_s": sum(r["sweep_rp_single_s"] for r in rows),
            }
        args.json.write_text(json.dumps(payload, indent=2, default=float))
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
