"""Figures 2 & 3: speed-quality trade-off curves (claim C3).

LAF-DBSCAN sweeps α (1.1 .. 15 per the paper); DBSCAN++/LAF-DBSCAN++
sweep the sample-fraction offset δ (0.1 .. 0.9); KNN-BLOCK sweeps the
candidate window.  eps=0.5, tau=3 as in §3.4."""

from __future__ import annotations

import numpy as np

from repro.core.baselines import knn_block_dbscan
from repro.core.dbscan_pp import auto_sample_fraction, dbscan_pp, laf_dbscan_pp
from repro.core.laf_dbscan import laf_dbscan

from .common import ground_truth, prepare, quality, save_json, timed

ALPHA_SWEEP = (1.1, 1.5, 2.0, 3.0, 5.0, 8.0, 15.0)
DELTA_SWEEP = (0.1, 0.3, 0.5, 0.7, 0.9)
WINDOW_FRACS = (0.02, 0.05, 0.1, 0.2, 0.3)


def run(profile: str = "standard", datasets=("ms", "glove")):
    eps, tau = 0.5, 3
    rows = []
    for ds in datasets:
        prep = prepare(ds, profile)
        gt = ground_truth(prep, eps, tau)
        if gt.n_clusters < 2:
            continue
        pred = prep.pipeline.predict_counts(prep.test, eps)
        for a in ALPHA_SWEEP:
            t, res = timed(laf_dbscan, prep.test, eps, tau, a, pred, seed=0)
            rows.append({"dataset": ds, "method": "LAF-DBSCAN", "knob": f"alpha={a}",
                         "time_s": t, **quality(res.labels, gt.labels)})
        for dlt in DELTA_SWEEP:
            p = auto_sample_fraction(pred, tau, prep.alpha, dlt)
            t, res = timed(dbscan_pp, prep.test, eps, tau, p, seed=0)
            rows.append({"dataset": ds, "method": "DBSCAN++", "knob": f"delta={dlt}",
                         "time_s": t, **quality(res.labels, gt.labels)})
            n = len(prep.test)
            rng = np.random.default_rng(0)
            m = max(1, int(round(p * n)))
            sample_idx = np.sort(rng.choice(n, size=m, replace=False))
            t, res = timed(
                laf_dbscan_pp, prep.test, eps, tau, p, pred[sample_idx],
                alpha=1.0, sample_idx=sample_idx, seed=0,
            )
            rows.append({"dataset": ds, "method": "LAF-DBSCAN++", "knob": f"delta={dlt}",
                         "time_s": t, **quality(res.labels, gt.labels)})
        for wf in WINDOW_FRACS:
            w = max(tau, int(wf * len(prep.test)))
            t, res = timed(knn_block_dbscan, prep.test, eps, tau, n_proj=6, window=w, seed=0)
            rows.append({"dataset": ds, "method": "KNN-BLOCK", "knob": f"window={w}",
                         "time_s": t, **quality(res.labels, gt.labels)})
    save_json("fig23_tradeoff", rows)
    return rows


def summarize(rows):
    lines = ["fig2/3: speed-quality trade-off (eps=0.5, tau=3)"]
    for ds in sorted({r["dataset"] for r in rows}):
        lines.append(f"  {ds}:")
        for m in ("LAF-DBSCAN", "LAF-DBSCAN++", "DBSCAN++", "KNN-BLOCK"):
            pts = [r for r in rows if r["dataset"] == ds and r["method"] == m]
            if not pts:
                continue
            curve = "  ".join(f"({r['time_s']:.1f}s,{r['AMI']:.2f})" for r in pts)
            lines.append(f"    {m:13s} {curve}")
        # claim C3: in the high-quality regime (AMI > 0.4) LAF methods are fastest
        hq = [r for r in rows if r["dataset"] == ds and r["AMI"] > 0.4]
        if hq:
            best = min(hq, key=lambda r: r["time_s"])
            lines.append(f"    fastest at AMI>0.4: {best['method']} ({best['time_s']:.1f}s)")
    return "\n".join(lines)
