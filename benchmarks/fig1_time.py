"""Figure 1: clustering time of all methods (incl. exact DBSCAN) on the
three datasets — the headline speedup claim (C1: LAF-DBSCAN up to 2.9x
over DBSCAN; faster than the approximate baselines)."""

from __future__ import annotations

from .common import EPS_TAU, prepare, save_json
from .methods import APPROX_METHODS, run_method


def run(profile: str = "standard", datasets=("nyt", "glove", "ms")):
    rows = []
    for ds in datasets:
        prep = prepare(ds, profile)
        for eps, tau in EPS_TAU:
            for method in ["DBSCAN"] + APPROX_METHODS:
                t, res = run_method(method, prep, eps, tau)
                rows.append({
                    "dataset": ds, "eps": eps, "tau": tau, "method": method,
                    "time_s": t, "queries": res.n_range_queries,
                    "n": len(prep.test),
                })
    save_json("fig1_time", rows)
    return rows


def summarize(rows):
    lines = ["fig1: clustering time (s) + executed range queries"]
    speedups = []
    for ds in sorted({r["dataset"] for r in rows}):
        for eps, tau in sorted({(r["eps"], r["tau"]) for r in rows}):
            sub = {r["method"]: r for r in rows
                   if r["dataset"] == ds and r["eps"] == eps and r["tau"] == tau}
            if "DBSCAN" not in sub:
                continue
            base = sub["DBSCAN"]["time_s"]
            lines.append(f"  {ds} (eps={eps}, tau={tau}): DBSCAN={base:.2f}s")
            for m, r in sub.items():
                if m == "DBSCAN":
                    continue
                sp = base / max(r["time_s"], 1e-9)
                lines.append(
                    f"    {m:13s} {r['time_s']:.2f}s  speedup x{sp:.2f}  "
                    f"queries {r['queries']}/{sub['DBSCAN']['queries']}"
                )
                if m == "LAF-DBSCAN":
                    speedups.append(sp)
    if speedups:
        lines.append(f"  LAF-DBSCAN speedup over DBSCAN: max x{max(speedups):.2f}, "
                     f"median x{sorted(speedups)[len(speedups)//2]:.2f}")
    return "\n".join(lines)
