"""Table 2: (noise ratio, number of clusters) grid over (ε, τ) — the
operating-point selection procedure of §3.2, run on our datasets to show
the chosen (ε, τ) land in the paper's regime (noise < 0.6, clusters > 20)."""

from __future__ import annotations

from repro.core.dbscan import dbscan_parallel

from .common import prepare, save_json

GRID = [(0.5, 3), (0.5, 5), (0.55, 5), (0.6, 5), (0.7, 5)]


def run(profile: str = "standard", datasets=("nyt", "glove", "ms")):
    rows = []
    for ds in datasets:
        prep = prepare(ds, profile)
        for eps, tau in GRID:
            res = dbscan_parallel(prep.test, eps, tau)
            rows.append({
                "dataset": ds, "eps": eps, "tau": tau,
                "noise_ratio": res.noise_ratio, "n_clusters": res.n_clusters,
                "proper": bool(res.noise_ratio < 0.6 and res.n_clusters > 20),
            })
    save_json("table2_noise", rows)
    return rows


def summarize(rows):
    lines = ["table2: (noise ratio, n_clusters) grid; * = proper operating point"]
    for ds in sorted({r["dataset"] for r in rows}):
        cells = [
            f"({r['eps']},{r['tau']}): ({r['noise_ratio']:.2f}, {r['n_clusters']})"
            + ("*" if r["proper"] else "")
            for r in rows if r["dataset"] == ds
        ]
        lines.append(f"  {ds}: " + "  ".join(cells))
    return "\n".join(lines)
