"""Table 5 + Figure 4: quality and time across dataset scales
(MS-50k/100k/150k analogue at 1/3, 2/3, 1x of the benchmark scale),
ε=0.55, τ=5 — claim C2/scalability: LAF methods' time grows slowest."""

from __future__ import annotations

from .common import ground_truth, prepare, quality, save_json
from .methods import APPROX_METHODS, run_method


def run(profile: str = "standard", scales=(1 / 3, 2 / 3, 1.0)):
    eps, tau = 0.55, 5
    rows = []
    for scale in scales:
        prep = prepare("ms", profile, scale=scale)
        gt = ground_truth(prep, eps, tau)
        _, base = run_method("DBSCAN", prep, eps, tau)
        t_db, _ = run_method("DBSCAN", prep, eps, tau)
        rows.append({"scale": scale, "n": len(prep.test), "method": "DBSCAN",
                     "time_s": t_db, "ARI": 1.0, "AMI": 1.0})
        for method in APPROX_METHODS:
            t, res = run_method(method, prep, eps, tau)
            q = quality(res.labels, gt.labels)
            rows.append({"scale": scale, "n": len(prep.test), "method": method,
                         "time_s": t, **q})
    save_json("table5_scalability", rows)
    return rows


def summarize(rows):
    lines = ["table5/fig4: scalability (eps=0.55, tau=5)"]
    scales = sorted({r["scale"] for r in rows})
    methods = ["DBSCAN"] + APPROX_METHODS
    for m in methods:
        sub = {r["scale"]: r for r in rows if r["method"] == m}
        if not sub:
            continue
        times = " -> ".join(f"{sub[s]['time_s']:.2f}s" for s in scales if s in sub)
        growth = (
            sub[scales[-1]]["time_s"] / max(sub[scales[0]]["time_s"], 1e-9)
            if scales[0] in sub and scales[-1] in sub else float("nan")
        )
        aris = " / ".join(f"{sub[s]['ARI']:.3f}" for s in scales if s in sub)
        lines.append(f"  {m:13s} time {times}  (x{growth:.1f} at 3x data)  ARI {aris}")
    return "\n".join(lines)
