"""Bench-trajectory consolidation + drift gate.

Every PR's benchmark steps emit JSON artifacts (``BENCH_PR*.json``, the
``artifacts/benchmarks/*.json`` payloads).  Those are point-in-time
snapshots; nothing so far remembered the *best the repo has ever
measured*, so a silent 2x regression would pass CI as long as the run
completed.  This module closes that loop:

* ``update`` extracts the known metrics from a bench payload and folds
  them into a checked-in history file
  (``benchmarks/history/trajectory.json``): per ``label:metric`` the
  best-known value, its direction, and the append-only history of
  observations;
* ``gate`` extracts the same metrics from a *fresh* payload and fails
  (exit 1) when any falls more than ``--tolerance`` (default 20%)
  behind best-known.  Timing-derived metrics (wall-clock speedups on a
  shared CI runner) are compared under ``--noisy-tolerance`` (default
  60%) — quality metrics (ARI, recall, precision, device_get, rounds)
  get the tight bound, where even a small drop means a real defect.

Labels keep comparisons like-for-like: the same bench command gates
against its own lineage, never against a different config's numbers
(``index_bench_sweep:one_launch_speedup`` at the CI point is a
different quantity than the 40k single-device row in ``BENCH_PR5``).

Usage (what CI runs)::

    python benchmarks/trajectory.py update BENCH_PR9.json --label pr9_cluster
    python benchmarks/trajectory.py gate BENCH_PR9.json --label pr9_cluster
    python benchmarks/trajectory.py show
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

HISTORY = Path(__file__).resolve().parent / "history" / "trajectory.json"

# metric name -> (direction, noisy).  direction: "higher" | "lower"
# (which way is better).  noisy: wall-clock-derived, gated under the
# loose tolerance.  Extraction walks the payload recursively, so these
# match wherever the key appears (top-level summary or per-row).
METRICS: Dict[str, Tuple[str, bool]] = {
    # quality / invariants — tight gate
    "worst_ari": ("higher", False),
    "ari_sweep_vs_exact": ("higher", False),
    "ari_one_launch_vs_host": ("higher", False),
    "ari_rp_vs_exact": ("higher", False),
    "recall": ("higher", False),
    "precision": ("higher", False),
    # NOT device_get/rounds: payloads mix host rows (0) with device rows
    # (1, >0), so a min over the payload is vacuous and a payload without
    # a host row would spuriously fail — the single-device_get invariant
    # is enforced by the bench gate + obs.slo, not the trajectory
    "max_device_get": ("lower", False),
    # NOT telemetry_overhead: a warm-vs-warm ratio hovering around zero
    # (negative on a quiet runner), so relative regression vs best-known
    # is ill-conditioned — index_bench --max-telemetry-overhead enforces
    # the absolute <5% bound instead
    "span_coverage": ("higher", False),
    "ari_recovered": ("higher", False),
    # wall-clock-derived — loose gate (shared CI runner)
    "recovery_s": ("lower", True),
    "wal_replay_rows_per_s": ("higher", True),
    "best_one_launch_speedup": ("higher", True),
    "best_pipelined_speedup": ("higher", True),
    "best_cluster_speedup": ("higher", True),
    "one_launch_speedup": ("higher", True),
    "pipelined_speedup": ("higher", True),
    "cluster_speedup": ("higher", True),
    "sweep_speedup": ("higher", True),
    "amortized_speedup": ("higher", True),
}


def _walk(node, out: Dict[str, List[float]]) -> None:
    if isinstance(node, dict):
        for k, v in node.items():
            if k in METRICS and isinstance(v, (int, float)) and not isinstance(v, bool):
                out.setdefault(k, []).append(float(v))
            else:
                _walk(v, out)
    elif isinstance(node, list):
        for v in node:
            _walk(v, out)


def extract_metrics(payload) -> Dict[str, float]:
    """Best value per known metric found anywhere in the payload (max
    for higher-better, min for lower-better — one payload may hold
    several rows/configs; the trajectory tracks its frontier)."""
    found: Dict[str, List[float]] = {}
    _walk(payload, found)
    out = {}
    for name, vals in found.items():
        direction, _ = METRICS[name]
        out[name] = max(vals) if direction == "higher" else min(vals)
    return out


def _better(direction: str, a: float, b: float) -> bool:
    """a strictly better than b."""
    return a > b if direction == "higher" else a < b


def _regression(direction: str, value: float, best: float) -> float:
    """Fractional regression of ``value`` vs ``best`` (0 = at or beyond
    best).  Relative to |best|; a zero best (e.g. device_get) regresses
    by the absolute gap."""
    gap = (best - value) if direction == "higher" else (value - best)
    if gap <= 0:
        return 0.0
    return gap / abs(best) if best else float("inf")


def load_history(path: Path = HISTORY) -> dict:
    if Path(path).exists():
        return json.loads(Path(path).read_text())
    return {"metrics": {}}


def save_history(hist: dict, path: Path = HISTORY) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(hist, indent=2, sort_keys=True) + "\n")


def update(
    payload, label: str, hist: dict, *, source: str = "", note: str = ""
) -> List[str]:
    """Fold one payload's metrics into the history; returns the
    ``label:metric`` keys whose best-known improved."""
    improved = []
    for name, value in extract_metrics(payload).items():
        direction, noisy = METRICS[name]
        key = f"{label}:{name}"
        ent = hist["metrics"].setdefault(
            key, {"direction": direction, "noisy": noisy, "best": None,
                  "history": []},
        )
        obs = {"value": value}
        if source:
            obs["source"] = source
        if note:
            obs["note"] = note
        ent["history"].append(obs)
        if ent["best"] is None or _better(direction, value, ent["best"]):
            ent["best"] = value
            improved.append(key)
    return improved


def gate(
    payload, label: str, hist: dict, *,
    tolerance: float = 0.20, noisy_tolerance: float = 0.60,
) -> List[str]:
    """Compare one payload against best-known; returns failure lines
    (empty = pass).  Metrics with no history are skipped (first
    observation seeds them via ``update``)."""
    failures = []
    for name, value in extract_metrics(payload).items():
        key = f"{label}:{name}"
        ent = hist["metrics"].get(key)
        if ent is None or ent.get("best") is None:
            continue
        direction, noisy = METRICS[name]
        tol = noisy_tolerance if noisy else tolerance
        reg = _regression(direction, value, ent["best"])
        if reg > tol:
            failures.append(
                f"{key}: {value:.6g} vs best-known {ent['best']:.6g} "
                f"({direction}-is-better) — {reg:.1%} regression "
                f"exceeds {tol:.0%} tolerance"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    for cmd in ("update", "gate"):
        p = sub.add_parser(cmd)
        p.add_argument("bench", nargs="+", help="bench JSON payload(s)")
        p.add_argument("--label", required=True,
                       help="lineage key (same bench command across PRs)")
        p.add_argument("--history", type=Path, default=HISTORY)
        p.add_argument("--note", default="")
        if cmd == "gate":
            p.add_argument("--tolerance", type=float, default=0.20)
            p.add_argument("--noisy-tolerance", type=float, default=0.60)
            p.add_argument("--update", action="store_true",
                           help="also fold the payload in after a pass")
    p = sub.add_parser("show")
    p.add_argument("--history", type=Path, default=HISTORY)
    args = ap.parse_args(argv)

    hist = load_history(args.history)
    if args.cmd == "show":
        for key in sorted(hist["metrics"]):
            ent = hist["metrics"][key]
            print(f"{key}: best={ent['best']:.6g} "
                  f"({ent['direction']}, n={len(ent['history'])}"
                  f"{', noisy' if ent.get('noisy') else ''})")
        return 0

    payloads = [(p, json.loads(Path(p).read_text())) for p in args.bench]
    if args.cmd == "update":
        for src, payload in payloads:
            improved = update(payload, args.label, hist, source=Path(src).name,
                              note=args.note)
            print(f"{src}: {len(improved)} best-known improved"
                  + (f" ({', '.join(improved)})" if improved else ""))
        save_history(hist, args.history)
        return 0

    # gate
    rc = 0
    for src, payload in payloads:
        failures = gate(payload, args.label, hist,
                        tolerance=args.tolerance,
                        noisy_tolerance=args.noisy_tolerance)
        if failures:
            rc = 1
            print(f"TRAJECTORY GATE FAIL: {src}")
            for line in failures:
                print(f"  {line}")
        else:
            print(f"trajectory gate ok: {src} "
                  f"({len(extract_metrics(payload))} metrics vs history)")
            if args.update:
                update(payload, args.label, hist, source=Path(src).name,
                       note=args.note)
    if args.cmd == "gate" and args.update and rc == 0:
        save_history(hist, args.history)
    return rc


if __name__ == "__main__":
    sys.exit(main())
