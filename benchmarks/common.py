"""Shared benchmark substrate: datasets at the paper's operating points
(scaled for the CPU container), trained estimators (cached), timing.

Paper datasets -> seeded vMF stand-ins (DESIGN.md §6):
    NYT-150k   (256-d, bag-of-words)   -> nyt:   d=256, looser clusters
    Glove-150k (200-d, word embeds)    -> glove: d=200
    MS-150k    (768-d, passage embeds) -> ms:    d=768, hardest (curse of dim)
Scale factor: --profile quick|standard|large (1/50, 1/10, 1/5 of 150k).
All methods run on the SAME test split with the SAME (ε, τ), mirroring
§3.1: estimator trains on the 80% split, evaluation on the 20% split.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.dbscan import DBSCANResult, dbscan_parallel
from repro.core.metrics import adjusted_mutual_info, adjusted_rand_index
from repro.core.pipeline import LAFPipeline
from repro.data.synthetic import make_angular_clusters, train_test_split

ART = Path("artifacts/benchmarks")

PROFILES = {
    "quick": dict(n=3000, epochs=3, eps_grid=(0.3, 0.4, 0.5, 0.6)),
    "standard": dict(n=15000, epochs=6, eps_grid=(0.2, 0.3, 0.4, 0.5, 0.6, 0.7)),
    "large": dict(n=30000, epochs=8, eps_grid=(0.2, 0.3, 0.4, 0.5, 0.6, 0.7)),
}

# operating points chosen (via the Table-2-style grid in table2_noise.py)
# to land in the paper's regime: low-to-mid noise ratio, >20 clusters.
# vMF concentration: within-cluster cosine distance concentrates near
# (d-1)/kappa, so kappa = (d-1)/0.30 puts typical same-cluster pairs at
# d_cos ~ 0.3 — inside the paper's eps range (0.5-0.6) with headroom,
# while inter-cluster/noise pairs sit near 1.0 (orthogonality in high d).
DATASETS = {
    "nyt": dict(d=256, n_clusters=80, kappa=850.0, noise_frac=0.35, seed=11),
    "glove": dict(d=200, n_clusters=80, kappa=660.0, noise_frac=0.35, seed=12),
    "ms": dict(d=768, n_clusters=80, kappa=2560.0, noise_frac=0.40, seed=13),
}

# paper Table 1 α values (ad-hoc per dataset); ours are re-tuned per
# dataset at benchmark scale by the same grid-search procedure (§3.2)
ALPHAS = {"nyt": 1.15, "glove": 2.0, "ms": 1.5}

EPS_TAU = [(0.5, 3), (0.55, 5), (0.6, 5)]


@dataclass
class Prepared:
    name: str
    train: np.ndarray
    test: np.ndarray
    pipeline: LAFPipeline
    alpha: float


_CACHE: Dict[str, Prepared] = {}


def prepare(name: str, profile: str = "standard", scale: float = 1.0) -> Prepared:
    key = f"{name}:{profile}:{scale}"
    if key in _CACHE:
        return _CACHE[key]
    prof = PROFILES[profile]
    spec = DATASETS[name]
    n = int(prof["n"] * scale)
    data, _ = make_angular_clusters(
        n, spec["d"], spec["n_clusters"], kappa=spec["kappa"],
        noise_frac=spec["noise_frac"], seed=spec["seed"],
    )
    train, test = train_test_split(data, 0.8, seed=0)
    pipe = LAFPipeline(eps_grid=prof["eps_grid"], epochs=prof["epochs"], seed=0)
    pipe.fit(train)
    prep = Prepared(name, train, test, pipe, ALPHAS[name])
    _CACHE[key] = prep
    return prep


def ground_truth(prep: Prepared, eps: float, tau: int) -> DBSCANResult:
    return dbscan_parallel(prep.test, eps, tau)


def quality(labels, gt_labels) -> Dict[str, float]:
    return {
        "ARI": adjusted_rand_index(labels, gt_labels),
        "AMI": adjusted_mutual_info(labels, gt_labels),
    }


def timed(fn: Callable, *args, _name: str = "bench.timed", **kw) -> Tuple[float, object]:
    """Synced wall time of one call.

    JAX dispatch is asynchronous: a bare ``perf_counter`` bracket around
    a device call measures *dispatch*, not execution.  This rides an obs
    span in ``force`` mode — it always measures (blocking on the
    returned pytree's jax leaves before closing) and, when tracing is
    enabled, the measurement also lands in the exported trace under
    ``_name``.
    """
    sp = obs.span(_name, force=True)
    with sp:
        out = fn(*args, **kw)
        sp.sync_on(out)
    return sp.dur, out


def save_json(name: str, obj) -> Path:
    ART.mkdir(parents=True, exist_ok=True)
    p = ART / f"{name}.json"
    p.write_text(json.dumps(obj, indent=2, default=float))
    return p
