"""Observability benchmark: one standard LAF-DBSCAN run under full
tracing + metrics, emitting the per-phase cost breakdown and a
Chrome/Perfetto trace as CI artifacts.

This is the PR-6 acceptance harness: a standard ``laf_dbscan`` run on a
``--mesh N`` forced-host-device mesh with ``repro.obs`` enabled must
produce a trace whose spans cover >= 95% of the run's wall time, and a
metrics snapshot that accounts for the run (per-phase seconds, sweep
recompile count, estimator fast-path skip rate, band occupancy).  The
JSON payload is the perf-trajectory artifact (``BENCH_PR6.json``); the
trace file loads straight into https://ui.perfetto.dev.

  PYTHONPATH=src python -m benchmarks.obs_bench --mesh 4 \
      --json BENCH_PR6.json --trace laf_trace.json
"""

from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

import numpy as np

N_CLUSTERS = 40
NOISE_FRAC = 0.35


def _phase_seconds(records, parent_id: int) -> dict:
    """Sum span durations by name among the direct children of one span."""
    out: dict = {}
    for r in records:
        if r.parent_id == parent_id:
            out[r.name] = out.get(r.name, 0.0) + r.dur
    return out


def run(args) -> dict:
    from repro import obs
    from repro.core.pipeline import LAFPipeline
    from repro.data.synthetic import make_angular_clusters
    from repro.index import RandomProjectionBackend

    # --cluster-device: the one-launch fused clustering config — device
    # telemetry on, engine-backed index, cluster formation under a
    # single lax.while_loop.  The fused interval (laf.label_prop) has no
    # host-observable phase boundaries, so its coverage is restored by
    # the synthetic per-round spans the telemetry harvest emits.
    obs.enable(trace=True, metrics_on=True, telemetry=args.cluster_device)
    obs.clear_trace()
    obs.metrics.reset()

    data, _ = make_angular_clusters(
        args.n, args.d, N_CLUSTERS, kappa=(args.d - 1) / 0.30,
        noise_frac=NOISE_FRAC, seed=args.seed,
    )
    mesh = None
    if args.mesh > 1:
        import jax

        mesh = jax.make_mesh((args.mesh,), ("data",))
    backend = RandomProjectionBackend(
        n_bits=args.n_bits, seed=args.seed,
        # cluster-device mode needs the engine's packed slabs on device
        device=True if (mesh is not None or args.cluster_device) else "auto",
        mesh=mesh,
    )
    pipe = LAFPipeline(
        eps_grid=(args.eps,), epochs=args.epochs, seed=args.seed,
        backend=backend,
    )
    cluster_kw = {"cluster_device": True} if args.cluster_device else {}
    test = pipe.fit_split(data)  # estimator training is NOT the traced run
    obs.clear_trace()  # the artifact traces the clustering run only
    out = pipe.cluster_laf_dbscan(test, args.eps, args.tau, args.alpha,
                                  **cluster_kw)

    records = obs.spans()
    root = next(r for r in reversed(records) if r.name == "laf.run")
    cluster = next(r for r in reversed(records) if r.name == "laf.cluster")
    cov_run = obs.coverage(root, records)
    cov_cluster = obs.coverage(cluster, records)
    run_kids = _phase_seconds(records, root.span_id)
    cluster_kids = _phase_seconds(records, cluster.span_id)
    cov_label_prop = round_spans = None
    if args.cluster_device:
        # the fused one-launch interval: without the synthetic per-round
        # telemetry spans its coverage is 0 (no host-observable phase
        # boundaries inside a single lax.while_loop)
        lp = next(
            (r for r in reversed(records) if r.name == "laf.label_prop"), None
        )
        if lp is None:
            raise SystemExit(
                "--cluster-device run never entered the fused label-prop "
                "pass (estimator predicted 0 core points at this operating "
                "point — raise --n/--epochs or lower --tau)"
            )
        cov_label_prop = obs.coverage(lp, records)
        round_spans = sum(
            1 for r in records
            if r.name == "laf.cluster.round" and r.parent_id == lp.span_id
        )

    predict_s = run_kids.get("laf.predict", 0.0)
    sweep_s = cluster_kids.get("laf.pass1", 0.0)
    post_s = (cluster_kids.get("laf.union_find", 0.0)
              + cluster_kids.get("laf.postprocess", 0.0))
    wall = root.dur

    snap = obs.metrics.snapshot()
    skipped = snap.get("laf.skipped", 0)
    executed = snap.get("laf.predicted_core", 0)

    trace_path = None
    if args.trace:
        obs.export_chrome_trace(args.trace)
        trace_path = str(args.trace)

    # instrumentation overhead, warm-vs-warm: the traced run above paid
    # every jit compile, so both passes here ride hot caches and the
    # delta isolates the obs layer itself
    disabled_wall = enabled_wall = None
    if not args.no_overhead_check:
        import time

        def _pass():
            bk = RandomProjectionBackend(
                n_bits=args.n_bits, seed=args.seed,
                device=True if (mesh is not None or args.cluster_device)
                else "auto",
                mesh=mesh,
            )
            t0 = time.perf_counter()
            pipe.cluster_laf_dbscan(test, args.eps, args.tau, args.alpha,
                                    backend=bk, **cluster_kw)
            return time.perf_counter() - t0

        obs.disable()
        disabled_wall = _pass()
        obs.enable(trace=True, metrics_on=True,
                   telemetry=args.cluster_device)
        enabled_wall = _pass()

    payload = {
        "n": args.n, "d": args.d, "eps": args.eps, "tau": args.tau,
        "alpha": args.alpha, "mesh": args.mesh, "n_bits": args.n_bits,
        "wall_s": wall,
        "phases": {
            "predict_s": predict_s,
            "fit_index_s": cluster_kids.get("laf.fit_index", 0.0),
            "sweep_s": sweep_s,
            "union_find_s": cluster_kids.get("laf.union_find", 0.0),
            "postprocess_s": cluster_kids.get("laf.postprocess", 0.0),
            "predict_frac": predict_s / wall if wall else 0.0,
            "sweep_frac": sweep_s / wall if wall else 0.0,
            "postprocess_frac": post_s / wall if wall else 0.0,
        },
        "coverage": {"laf.run": cov_run, "laf.cluster": cov_cluster},
        "span_coverage": cov_run,  # trajectory-gate key
        "recompiles": {
            "sweep": snap.get("sweep.recompiles", 0),
            "jax_backend_compiles": snap.get("jax.compile.events", 0),
        },
        "estimator_fast_path": {
            "skipped": skipped,
            "executed": executed,
            "skip_rate": skipped / (skipped + executed)
            if (skipped + executed) else 0.0,
            "rescued": snap.get("laf.rescued", 0),
        },
        "band_occupancy": {
            k.rsplit(".", 1)[1]: v
            for k, v in snap.items() if k.startswith("index.band.")
        },
        "result": {
            "n_clusters": int(out.result.n_clusters),
            "noise_ratio": float(out.result.noise_ratio),
        },
        "metrics": snap,
        "trace": trace_path,
        "spans_recorded": len(records),
    }
    if args.cluster_device:
        # ``snap`` was taken right after the traced run, before the
        # overhead passes bumped the counters again
        payload["cluster_device"] = {
            "coverage_label_prop": cov_label_prop,
            "round_spans": round_spans,
            "rounds": snap.get("laf.cluster.rounds", 0),
            "device_get": snap.get("laf.cluster.device_get", 0),
            "telemetry_totals": {
                k.rsplit(".", 1)[1]: v
                for k, v in snap.items() if k.startswith("laf.telemetry.")
            },
        }
    if disabled_wall is not None:
        payload["obs_disabled_wall_s"] = disabled_wall
        payload["obs_enabled_wall_s"] = enabled_wall
        payload["obs_overhead_frac"] = (enabled_wall - disabled_wall) / disabled_wall

    print(
        f"laf run {args.n}x{args.d} mesh={args.mesh}: {wall:.2f}s | "
        f"predict {payload['phases']['predict_frac']:.1%} "
        f"sweep {payload['phases']['sweep_frac']:.1%} "
        f"post {payload['phases']['postprocess_frac']:.1%} | "
        f"coverage run={cov_run:.3f} cluster={cov_cluster:.3f} | "
        f"skip_rate={payload['estimator_fast_path']['skip_rate']:.2f} "
        f"sweep_recompiles={payload['recompiles']['sweep']}"
    )
    if cov_label_prop is not None:
        print(
            f"  cluster-device: label_prop coverage {cov_label_prop:.3f} "
            f"({round_spans} synthetic round spans, "
            f"{payload['cluster_device']['rounds']} rounds)", flush=True,
        )
    if cov_run < args.min_coverage:
        raise SystemExit(
            f"span coverage {cov_run:.3f} below --min-coverage "
            f"{args.min_coverage} — an uninstrumented phase opened up"
        )
    if cov_label_prop is not None and cov_label_prop < args.min_coverage:
        raise SystemExit(
            f"fused label_prop coverage {cov_label_prop:.3f} below "
            f"--min-coverage {args.min_coverage} — the synthetic per-round "
            "telemetry spans stopped attributing the one-launch interval"
        )
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--eps", type=float, default=0.55)
    ap.add_argument("--tau", type=int, default=5)
    ap.add_argument("--alpha", type=float, default=1.2)
    ap.add_argument("--n-bits", type=int, default=128)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--mesh", type=int, default=4, metavar="N",
        help="force N host devices (set before jax initializes) and run "
        "the sweep through the sharded index plane; 0/1 = single device",
    )
    ap.add_argument("--json", type=Path, default=None,
                    help="write the payload here (BENCH_PR6.json in CI)")
    ap.add_argument("--trace", type=Path, default=None,
                    help="write the Chrome/Perfetto trace here")
    ap.add_argument(
        "--cluster-device", action="store_true",
        help="trace the one-launch fused clustering (cluster_device=True) "
        "with device telemetry on: the laf.label_prop coverage gate then "
        "rides the synthetic per-round spans (BENCH_PR9 leg)",
    )
    ap.add_argument("--min-coverage", type=float, default=0.95,
                    help="fail if laf.run span coverage drops below this "
                    "(under --cluster-device, also gates laf.label_prop)")
    ap.add_argument("--no-overhead-check", action="store_true",
                    help="skip the second (obs-disabled) clustering pass")
    args = ap.parse_args(argv)
    if args.mesh > 1:
        # must land before the first jax import anywhere in the process
        import sys

        assert "jax" not in sys.modules, "--mesh requires jax to be uninitialized"
        inherited = [
            f for f in os.environ.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")
        ]
        os.environ["XLA_FLAGS"] = " ".join(
            [f"--xla_force_host_platform_device_count={args.mesh}"] + inherited
        )
    payload = run(args)
    if args.json:
        args.json.write_text(json.dumps(payload, indent=2, default=float))
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
