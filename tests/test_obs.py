"""repro.obs: span nesting + Chrome-trace export roundtrip, histogram
quantile accuracy vs numpy, recompile accounting (the sweep engine's
once-per-capacity-doubling contract, the serving path's O(log n)
power-of-two bucket compiles), and device/host band-occupancy parity.
"""

import json
import math

import numpy as np
import pytest

from repro import obs
from repro.data.synthetic import make_angular_clusters
from repro.index import RandomProjectionBackend
from repro.index.random_projection import record_occupancy
from repro.obs import metrics
from repro.stream import StreamingLAF

EPS = 0.55


@pytest.fixture(autouse=True)
def obs_sandbox():
    """Clean, enabled obs state per test; the ambient switches (tier-1
    may run under REPRO_OBS=1) are restored afterwards."""
    was_trace, was_metrics = obs.trace_enabled(), obs.metrics_enabled()
    obs.enable(trace=True, metrics_on=True)
    obs.clear_trace()
    metrics.reset()
    yield
    obs.clear_trace()
    metrics.reset()
    if was_trace or was_metrics:
        obs.enable(trace=was_trace, metrics_on=was_metrics)
    else:
        obs.disable()


# ---------------------------------------------------------------------------
# spans: nesting, export roundtrip, the disabled fast path
# ---------------------------------------------------------------------------


def test_span_nesting_and_chrome_export_roundtrip(tmp_path):
    with obs.span("outer", a=1):
        with obs.span("inner.one"):
            pass
        with obs.span("inner.two", k="v"):
            pass
    recs = obs.spans()
    outer = next(r for r in recs if r.name == "outer")
    inners = [r for r in recs if r.name.startswith("inner")]
    assert outer.parent_id == 0
    assert len(inners) == 2
    assert all(r.parent_id == outer.span_id for r in inners)
    assert outer.dur >= max(r.dur for r in inners)

    p = tmp_path / "trace.json"
    doc = obs.export_chrome_trace(str(p))
    loaded = json.loads(p.read_text())  # the file IS valid JSON
    assert loaded == json.loads(json.dumps(doc, default=float))
    evs = loaded["traceEvents"]
    assert {e["name"] for e in evs} == {"outer", "inner.one", "inner.two"}
    for e in evs:  # Chrome trace_event "complete" records
        assert e["ph"] == "X"
        assert e["dur"] >= 0 and e["ts"] > 0
        assert {"name", "cat", "pid", "tid", "args"} <= set(e)
    by_name = {e["name"]: e for e in evs}
    # parent linkage and attributes survive the export through args
    assert (by_name["inner.one"]["args"]["parent_id"]
            == by_name["outer"]["args"]["span_id"])
    assert by_name["outer"]["args"]["a"] == 1
    assert by_name["inner.two"]["args"]["k"] == "v"


def test_disabled_span_is_shared_noop():
    obs.disable()
    s1, s2 = obs.span("x"), obs.span("y")
    assert s1 is s2  # the shared null object: no per-call allocation
    with s1:
        pass
    obs.enable(trace=True, metrics_on=True)
    assert obs.spans("x") == []


def test_force_span_measures_without_recording():
    obs.disable()
    sp = obs.span("bench.t", force=True)
    with sp:
        out = sum(range(10_000))
        sp.sync_on(out)  # numpy/python leaves pass through block_until_ready
    assert sp.dur > 0
    obs.enable(trace=True, metrics_on=True)
    assert obs.spans("bench.t") == []  # measured, never buffered


def test_coverage_is_union_of_child_intervals():
    root = obs.SpanRecord("r", t0=0.0, dur=10.0, span_id=1)
    kids = [
        obs.SpanRecord("a", t0=0.0, dur=4.0, span_id=2, parent_id=1),
        obs.SpanRecord("b", t0=3.0, dur=4.0, span_id=3, parent_id=1),  # overlap
        obs.SpanRecord("c", t0=9.0, dur=5.0, span_id=4, parent_id=1),  # clipped
    ]
    # union [0,7) + [9,10) clipped to the root = 8 of 10 seconds
    assert obs.coverage(root, [root] + kids) == pytest.approx(0.8)


# ---------------------------------------------------------------------------
# histogram: log-bucket quantiles vs exact numpy percentiles
# ---------------------------------------------------------------------------


def test_histogram_quantiles_match_numpy_within_bucket_width():
    rng = np.random.default_rng(0)
    # latency-like: log-normal spanning ~3 decades around a millisecond
    samples = rng.lognormal(mean=-6.5, sigma=1.2, size=5000)
    h = metrics.histogram("test.latency")
    for v in samples:
        h.observe(float(v))
    for q in (0.50, 0.95, 0.99):
        exact = float(np.quantile(samples, q))
        est = h.quantile(q)
        # the default layout is 20 buckets/decade: adjacent bounds differ
        # by 10^(1/20) ~ 1.122, the documented quantile resolution
        assert abs(est - exact) / exact < 0.13, (q, est, exact)
    s = h.summary()
    assert s["count"] == len(samples)
    assert s["min"] == pytest.approx(samples.min())
    assert s["max"] == pytest.approx(samples.max())
    assert s["sum"] == pytest.approx(samples.sum())
    assert s["p50"] <= s["p95"] <= s["p99"] <= s["max"]


def test_metrics_disabled_records_nothing():
    obs.disable()
    metrics.counter("test.c").inc(5)
    metrics.gauge("test.g").set(3.0)
    metrics.histogram("test.h").observe(1.0)
    assert metrics.counter("test.c").value == 0
    assert metrics.histogram("test.h").count == 0
    snap = metrics.snapshot("test.")
    assert snap["test.c"] == 0
    assert "test.g" in json.loads(metrics.to_json()) or True  # serializable


# ---------------------------------------------------------------------------
# recompile accounting: the sweep engine across partial_fit appends
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def obs_data():
    # 613: not a multiple of the chunk, the kernel tiles, or 32 (the
    # same shape discipline as test_sweep — every pad layer exercised)
    data, _ = make_angular_clusters(613, 32, 8, kappa=120, noise_frac=0.3, seed=2)
    return data


CFG = dict(n_bits=64, margin=3.0, seed=3, chunk=64, q_tile=32, db_tile=64)


def test_sweep_recompiles_once_per_capacity_doubling(obs_data):
    """Appends that fit in capacity re-launch cached executables; only a
    capacity doubling (new padded operand shape) compiles fresh ones."""
    bk = RandomProjectionBackend(device=True, interpret=True, sweep=True, **CFG)
    bk.fit(obs_data[:128])
    rows = np.arange(64)
    bk.query_counts(rows, EPS)  # first sweep pays the initial compile
    base_rc = metrics.counter("sweep.recompiles").value
    base_db = metrics.counter("index.capacity_doublings").value
    for start in range(128, 613, 97):
        bk.partial_fit(obs_data[start : start + 97])
        bk.query_counts(rows, EPS)  # same query shape: capacity is the
        # only thing that can change the jit signature
    doublings = metrics.counter("index.capacity_doublings").value - base_db
    recompiles = metrics.counter("sweep.recompiles").value - base_rc
    assert doublings >= 2  # 128 -> 613 must double at least twice
    assert recompiles == doublings


# ---------------------------------------------------------------------------
# recompile accounting: serving buckets are O(log n), reused across calls
# ---------------------------------------------------------------------------


def test_serve_assign_bucket_compiles_log_bounded(obs_data):
    bk = RandomProjectionBackend(device=True, interpret=True, sweep=True, **CFG)
    stream = StreamingLAF(0.35, 5, backend=bk, block_size=256)
    stream.partial_fit(obs_data)
    idx = stream.snapshot()

    rng = np.random.default_rng(7)
    member = np.nonzero(stream.labels() >= 0)[0]
    queries = obs_data[rng.choice(member, size=96)] + 0.02 * rng.standard_normal(
        (96, obs_data.shape[1])
    ).astype(np.float32)

    metrics.reset()
    for size in (1, 3, 17, 41, 96):  # ragged batches: many union sizes
        for s in range(0, 96, size):
            idx.assign(queries[s : s + size])
    compiles = metrics.counter("serve.bucket_compiles").value
    launches = metrics.counter("serve.verify_launches").value
    assert launches > 0 and compiles > 0
    # buckets are powers of two in [db_tile, 2^ceil(log2 n)], chunks
    # powers of two in [q_tile, chunk]: O(log n) distinct shapes total
    max_buckets = int(math.log2((1 << math.ceil(math.log2(len(obs_data)))) // CFG["db_tile"])) + 1
    max_chunks = int(math.log2(CFG["chunk"] // CFG["q_tile"])) + 1
    assert compiles <= max_buckets * max_chunks
    assert compiles < launches  # shapes are reused, not one per launch

    # a repeat of the same traffic compiles nothing new
    before = compiles
    for s in range(0, 96, 17):
        idx.assign(queries[s : s + 17])
    assert metrics.counter("serve.bucket_compiles").value == before
    assert metrics.counter("serve.assign.calls").value > 0
    assert metrics.histogram("serve.assign.latency_s").count > 0


# ---------------------------------------------------------------------------
# band occupancy: device kernel counters == host table on ragged n
# ---------------------------------------------------------------------------


def test_occupancy_device_matches_host_on_ragged_n(obs_data):
    """613 rows: the kernel's per-tile [accept, band, reject] counters
    run on the padded grid; after the pad corrections the device
    measurement must price exactly the same real pairs as one host
    Hamming sweep."""
    host = RandomProjectionBackend(device=False, **CFG).fit(obs_data)
    dev = RandomProjectionBackend(device=True, interpret=True, **CFG).fit(obs_data)
    rows = np.arange(0, len(obs_data), 7)

    metrics.reset()
    row_h = record_occupancy(host, EPS, rows)
    host_counts = {
        k: metrics.counter(f"index.band.{k}").value
        for k in ("accept", "band", "reject")
    }
    metrics.reset()
    row_d = record_occupancy(dev, EPS, rows)
    dev_counts = {
        k: metrics.counter(f"index.band.{k}").value
        for k in ("accept", "band", "reject")
    }

    assert sum(host_counts.values()) == len(rows) * len(obs_data)
    assert dev_counts == host_counts
    assert row_d["accept_frac"] == pytest.approx(row_h["accept_frac"])
    assert row_d["band_frac"] == pytest.approx(row_h["band_frac"])
    assert row_d["t_lo"] == row_h["t_lo"] and row_d["t_hi"] == row_h["t_hi"]


def test_band_lazily_records_occupancy_once_per_eps(obs_data):
    bk = RandomProjectionBackend(device=False, **CFG).fit(obs_data)
    metrics.reset()
    bk.band(EPS)
    accepted = metrics.counter("index.band.accept").value
    total = sum(
        metrics.counter(f"index.band.{k}").value
        for k in ("accept", "band", "reject")
    )
    assert total > 0  # one sampled measurement was taken
    bk.band(EPS)  # memoized per (backend, eps): no second measurement
    assert metrics.counter("index.band.accept").value == accepted
    bk.band(0.4)  # a new eps is a new measurement
    assert (
        sum(
            metrics.counter(f"index.band.{k}").value
            for k in ("accept", "band", "reject")
        )
        > total
    )
