"""Hypothesis property tests on the system's core invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.dbscan import dbscan_parallel, dbscan_sequential
from repro.core.laf_dbscan import laf_dbscan
from repro.core.metrics import adjusted_rand_index
from repro.core.range_query import range_counts
from repro.data.synthetic import make_angular_clusters, sample_uniform_sphere

FAST = settings(
    max_examples=12, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def clustered_data(draw):
    n = draw(st.integers(min_value=60, max_value=300))
    d = draw(st.sampled_from([8, 16, 24]))
    k = draw(st.integers(min_value=2, max_value=6))
    seed = draw(st.integers(min_value=0, max_value=10_000))
    noise = draw(st.floats(min_value=0.0, max_value=0.5))
    data, _ = make_angular_clusters(
        n, d, k, kappa=d / 0.2, noise_frac=noise, seed=seed
    )
    return data


@given(clustered_data(), st.floats(min_value=0.1, max_value=0.8),
       st.integers(min_value=2, max_value=8))
@FAST
def test_dbscan_core_invariants(data, eps, tau):
    """Core points are exactly counts>=tau; cores never noise; any two
    cores within eps share a label; labels partition correctly."""
    res = dbscan_parallel(data, eps, tau)
    counts = np.asarray(range_counts(data, data, eps))
    np.testing.assert_array_equal(res.core, counts >= tau)
    assert (res.labels[res.core] >= 0).all()
    core_idx = np.nonzero(res.core)[0]
    if len(core_idx):
        dots = data[core_idx] @ data[core_idx].T
        close = dots > 1 - eps
        li = res.labels[core_idx]
        assert ((li[:, None] == li[None, :]) | ~close).all()
    # cluster ids are exactly 0..k-1
    pos = np.unique(res.labels[res.labels >= 0])
    np.testing.assert_array_equal(pos, np.arange(len(pos)))


@given(clustered_data(), st.floats(min_value=0.15, max_value=0.6))
@FAST
def test_laf_oracle_alpha1_equals_dbscan(data, eps):
    """Perfect estimator + alpha=1: LAF == DBSCAN on every point class."""
    tau = 4
    counts = np.asarray(range_counts(data, data, eps)).astype(float)
    gt = dbscan_parallel(data, eps, tau)
    res = laf_dbscan(data, eps, tau, 1.0, counts)
    np.testing.assert_array_equal(res.core, gt.core)
    assert adjusted_rand_index(res.labels, gt.labels) == pytest.approx(1.0)
    assert res.n_range_queries == int(gt.core.sum())


@given(clustered_data(), st.integers(min_value=0, max_value=1000))
@FAST
def test_laf_noisy_estimator_never_invents_cores(data, seed):
    """Whatever the estimator says, a point labeled core by LAF is a true
    core (skips cause false negatives, never false positives)."""
    eps, tau = 0.3, 4
    rng = np.random.default_rng(seed)
    counts = np.asarray(range_counts(data, data, eps)).astype(float)
    noisy = counts * np.exp(rng.normal(0, 1.0, len(counts)))
    res = laf_dbscan(data, eps, tau, 1.5, noisy)
    assert not np.any(res.core & ~(counts >= tau))


@given(st.integers(min_value=2, max_value=40), st.integers(min_value=0, max_value=500))
@FAST
def test_counts_symmetry(n, seed):
    """Neighbor relation is symmetric: j in N(i) <=> i in N(j)."""
    rng = np.random.default_rng(seed)
    x = sample_uniform_sphere(rng, n, 6)
    dots = x @ x.T
    hits = dots > 1 - 0.5
    np.testing.assert_array_equal(hits, hits.T)


@given(clustered_data())
@FAST
def test_sequential_parallel_agree(data):
    """Engines agree exactly on cores and the core partition; border
    points (legally ambiguous between adjacent clusters) must land in a
    cluster owned by one of their core neighbors."""
    eps, tau = 0.3, 4
    seq = dbscan_sequential(data, eps, tau)
    par = dbscan_parallel(data, eps, tau)
    np.testing.assert_array_equal(seq.core, par.core)
    assert seq.n_clusters == par.n_clusters
    core = np.nonzero(seq.core)[0]
    if len(core):
        # identical partition of the CORE points
        assert adjusted_rand_index(seq.labels[core], par.labels[core]) == pytest.approx(1.0)
    # same noise set; borders attach to a genuine core neighbor's cluster
    np.testing.assert_array_equal(seq.labels == -1, par.labels == -1)
    border = np.nonzero((par.labels >= 0) & ~par.core)[0]
    for j in border:
        nbr = core[(data[core] @ data[j]) > 1 - eps]
        assert par.labels[j] in set(par.labels[nbr])


@given(st.integers(min_value=0, max_value=10_000))
@FAST
def test_band_mode_clustering_ari_matches_exact(seed):
    """verify="band" (sure-accept + band verify) clustering is
    indistinguishable from exact clustering on blob data across an eps
    grid: at margin=4 the prefilter's per-pair miss/false-accept
    probability (~Phi(-4)) is far below anything that could flip a core
    decision or a cluster link on concentrated vMF blobs."""
    from repro.index import RandomProjectionBackend

    data, _ = make_angular_clusters(
        220, 16, 4, kappa=16 / 0.05, noise_frac=0.0, seed=seed
    )
    for eps in (0.3, 0.45, 0.6):
        exact = dbscan_parallel(data, eps, 4)
        band = dbscan_parallel(
            data, eps, 4,
            backend=RandomProjectionBackend(
                n_bits=384, margin=4.0, verify="band", seed=seed % 13, device=False
            ),
        )
        assert adjusted_rand_index(exact.labels, band.labels) == pytest.approx(1.0)


@given(st.lists(st.integers(min_value=0, max_value=5), min_size=2, max_size=60),
       st.integers(min_value=0, max_value=5))
@settings(max_examples=30, deadline=None)
def test_ari_permutation_invariant(labels, shift):
    """ARI is invariant to relabeling."""
    a = np.asarray(labels)
    b = (a + shift) % 7  # injective relabel of the values present
    # only when the relabel is injective on the support:
    if len(np.unique(a)) == len(np.unique(b)):
        assert adjusted_rand_index(a, b) == pytest.approx(1.0)


@given(st.integers(min_value=1, max_value=12), st.integers(min_value=0, max_value=99))
@settings(max_examples=20, deadline=None)
def test_checkpoint_roundtrip_property(n_leaves, seed):
    import tempfile

    from repro.train.checkpoint import restore_checkpoint, save_checkpoint

    rng = np.random.default_rng(seed)
    tree = {
        f"leaf{i}": rng.standard_normal(rng.integers(1, 20, size=rng.integers(1, 3)))
        .astype(np.float32 if i % 2 else np.int32)
        for i in range(n_leaves)
    }
    with tempfile.TemporaryDirectory() as root:
        save_checkpoint(root, 0, tree)
        restored, _ = restore_checkpoint(root, template=tree)
    for k in tree:
        np.testing.assert_array_equal(tree[k], restored[k])
