"""Durable streaming plane: WAL framing + torn-tail semantics,
checkpoint crash-safety, snapshot/restore parity, kill-restore
(boundary, mid-batch, SIGKILL subprocess), corrupt-snapshot fallback,
failover clone/promote, and seeded fault injection with graceful
degradation to the host oracles."""

import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import obs
from repro.core.laf_dbscan import laf_dbscan
from repro.core.metrics import adjusted_rand_index
from repro.data.synthetic import make_angular_clusters
from repro.index import RandomProjectionBackend
from repro.obs import metrics
from repro.stream import DurableStream, StreamingLAF, clone_replica
from repro.stream.durability import (
    KIND_EVICT,
    KIND_INGEST,
    WalWriter,
    export_replica,
    import_replica,
    read_wal,
)
from repro.testing import faults
from repro.train.checkpoint import (
    CheckpointCorruptError,
    gc_checkpoints,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)

EPS, TAU = 0.35, 5


@pytest.fixture(scope="module")
def stream_data():
    data, _ = make_angular_clusters(700, 16, 8, kappa=120, noise_frac=0.3, seed=7)
    return data[np.random.default_rng(1).permutation(len(data))]


@pytest.fixture()
def obs_sandbox():
    """Clean, enabled metrics per test; ambient switches restored."""
    was_trace, was_metrics = obs.trace_enabled(), obs.metrics_enabled()
    obs.enable(trace=False, metrics_on=True)
    metrics.reset()
    yield
    metrics.reset()
    if was_trace or was_metrics:
        obs.enable(trace=was_trace, metrics_on=was_metrics)
    else:
        obs.disable()


def _factory():
    return StreamingLAF(EPS, TAU, block_size=256, backend="exact")


def _batches(data, k):
    step = -(-len(data) // k)
    return [data[i : i + step] for i in range(0, len(data), step)]


def _assert_replica_equal(a, b):
    """Bit-identical serving state: labels, owners, counts, core."""
    np.testing.assert_array_equal(a.labels(), b.labels())
    n = a.state.n
    assert n == b.state.n
    np.testing.assert_array_equal(a.state.counts[:n], b.state.counts[:n])
    np.testing.assert_array_equal(a.state.core[:n], b.state.core[:n])
    np.testing.assert_array_equal(a.state.owner[:n], b.state.owner[:n])
    np.testing.assert_array_equal(a.state.alive[:n], b.state.alive[:n])


# ---------------------------------------------------------------------------
# WAL framing
# ---------------------------------------------------------------------------


def test_wal_round_trip(tmp_path):
    p = tmp_path / "wal_000000000000.log"
    w = WalWriter(p)
    rows = np.arange(12, dtype=np.float32).reshape(3, 4)
    idx = np.array([1, 5], dtype=np.int64)
    w.append(1, KIND_INGEST, {"rows": rows})
    w.append(2, KIND_EVICT, {"idx": idx})
    w.close()
    recs = list(read_wal(p))
    assert [(s, k) for s, k, _ in recs] == [(1, KIND_INGEST), (2, KIND_EVICT)]
    np.testing.assert_array_equal(recs[0][2]["rows"], rows)
    np.testing.assert_array_equal(recs[1][2]["idx"], idx)


def test_wal_torn_tail_dropped_deterministically(tmp_path):
    p = tmp_path / "wal_000000000000.log"
    w = WalWriter(p)
    for s in range(1, 4):
        w.append(s, KIND_INGEST, {"rows": np.full((2, 3), s, dtype=np.float32)})
    w.close()
    full = p.read_bytes()
    # cut into the last record's payload: the torn tail must be dropped
    # and the surviving prefix returned, at every cut point
    last_len = len(full) - len(
        full[: full.rfind(b"PK")]  # crude: anywhere inside record 3
    )
    for cut in (1, last_len // 2, last_len - 1):
        p.write_bytes(full[: len(full) - cut])
        assert [s for s, _, _ in read_wal(p)] == [1, 2]
    # a clean file still yields everything
    p.write_bytes(full)
    assert [s for s, _, _ in read_wal(p)] == [1, 2, 3]


def test_wal_corrupt_record_stops_at_prior(tmp_path):
    p = tmp_path / "wal_000000000000.log"
    w = WalWriter(p)
    lens = [w.append(s, KIND_INGEST, {"rows": np.zeros((2, 2), np.float32)})
            for s in (1, 2)]
    w.close()
    # flip a byte inside record 2's payload: crc fails, replay stops at 1
    raw = bytearray(p.read_bytes())
    raw[8 + lens[0] + 20] ^= 0xFF
    p.write_bytes(bytes(raw))
    assert [s for s, _, _ in read_wal(p)] == [1]


def test_wal_missing_or_foreign_file_is_empty(tmp_path):
    assert list(read_wal(tmp_path / "nope.log")) == []
    p = tmp_path / "junk.log"
    p.write_bytes(b"not a wal at all")
    assert list(read_wal(p)) == []


# ---------------------------------------------------------------------------
# checkpoint crash-safety
# ---------------------------------------------------------------------------


def test_checkpoint_partial_dirs_invisible_and_collected(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32)}
    save_checkpoint(tmp_path, 1, tree, fsync=False)
    # a crash mid-write leaves a tmp- dir; a crashed legacy writer an
    # empty step dir with no manifest — neither is a restore candidate
    (tmp_path / "tmp-step_000000000002").mkdir()
    (tmp_path / "tmp-step_000000000002" / "shard_000000.npz").write_bytes(b"x")
    (tmp_path / "step_000000000003").mkdir()
    assert list_steps(tmp_path) == [1]
    gc_checkpoints(tmp_path, keep=3)
    assert not (tmp_path / "tmp-step_000000000002").exists()
    assert not (tmp_path / "step_000000000003").exists()
    assert list_steps(tmp_path) == [1]


def test_checkpoint_checksum_corruption_detected(tmp_path):
    tree = {"a": np.arange(128, dtype=np.float32), "b": np.ones(4, np.int64)}
    save_checkpoint(tmp_path, 1, tree, fsync=False)
    shard = next((tmp_path / "step_000000000001").glob("shard_*.npz"))
    faults.corrupt_file(shard, seed=0)
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(tmp_path, 1, template={"a": 0, "b": 0})


# ---------------------------------------------------------------------------
# snapshot / restore parity
# ---------------------------------------------------------------------------


def test_export_import_replica_round_trip(stream_data):
    src = _factory()
    for b in _batches(stream_data, 4):
        src.partial_fit(b)
    tree = export_replica(src, seq=4)
    dst = _factory()
    meta = import_replica(dst, tree)
    assert meta["seq"] == 4 and meta["backend"] == "exact"
    _assert_replica_equal(src, dst)
    # the planted serve snapshot answers without a rebuild
    q = stream_data[:16]
    np.testing.assert_array_equal(
        src.assign(q).labels, dst.assign(q).labels
    )


def test_import_replica_rejects_mismatched_operating_point(stream_data):
    src = _factory()
    src.partial_fit(stream_data[:128])
    tree = export_replica(src, seq=1)
    with pytest.raises(ValueError):
        import_replica(StreamingLAF(EPS, TAU + 1, backend="exact"), tree)
    with pytest.raises(ValueError):
        import_replica(
            StreamingLAF(EPS, TAU, backend="random_projection"), tree
        )


def test_durable_stream_is_label_identical_to_bare(stream_data, tmp_path):
    bare = _factory()
    d = DurableStream(_factory(), tmp_path, snapshot_every=2, fsync=False)
    for b in _batches(stream_data, 5):
        bare.partial_fit(b)
        d.partial_fit(b)
    _assert_replica_equal(bare, d.stream)
    d.close()


@pytest.mark.parametrize("kill_after", [1, 3, 4])
def test_kill_at_batch_boundary_bit_identical(stream_data, tmp_path, kill_after):
    batches = _batches(stream_data, 5)
    bare = _factory()
    for b in batches:
        bare.partial_fit(b)

    d = DurableStream(_factory(), tmp_path, snapshot_every=2, fsync=False)
    for b in batches[:kill_after]:
        d.partial_fit(b)
    # process dies here: no close(), no final snapshot
    d2 = DurableStream.recover(tmp_path, _factory, fsync=False)
    assert d2.seq == kill_after
    assert d2.recovery_info["wal_records"] + 0 >= 0
    for b in batches[kill_after:]:
        d2.partial_fit(b)
    _assert_replica_equal(bare, d2.stream)
    d.close()
    d2.close()


def test_kill_restore_random_projection_ari(stream_data, tmp_path):
    def rp_factory():
        return StreamingLAF(
            EPS, TAU, block_size=256, backend="random_projection"
        )

    batches = _batches(stream_data, 4)
    bare = rp_factory()
    for b in batches:
        bare.partial_fit(b)
    d = DurableStream(rp_factory(), tmp_path, snapshot_every=2, fsync=False)
    for b in batches[:3]:
        d.partial_fit(b)
    d2 = DurableStream.recover(tmp_path, rp_factory, fsync=False)
    d2.partial_fit(batches[3])
    assert adjusted_rand_index(d2.labels(), bare.labels()) >= 0.99
    d.close()
    d2.close()


def test_mid_batch_torn_tail_dropped(stream_data, tmp_path):
    batches = _batches(stream_data, 5)
    d = DurableStream(_factory(), tmp_path, snapshot_every=0, fsync=False)
    for b in batches[:3]:
        d.partial_fit(b)
    wal = d._wal.path
    d.close()
    # simulate a kill mid-append of batch 4: a torn record tail lands
    w = WalWriter(tmp_path / "scratch.log", fsync=False)
    w.append(4, KIND_INGEST, {"rows": batches[3]})
    w.close()
    rec = (tmp_path / "scratch.log").read_bytes()[8:]
    with open(wal, "ab") as f:
        f.write(rec[: len(rec) // 2])
    d2 = DurableStream.recover(tmp_path, _factory, fsync=False)
    assert d2.seq == 3  # the torn batch 4 was dropped deterministically
    ref = _factory()
    for b in batches[:3]:
        ref.partial_fit(b)
    _assert_replica_equal(ref, d2.stream)
    d2.close()


def test_corrupt_snapshot_falls_back_to_older(stream_data, tmp_path, obs_sandbox):
    batches = _batches(stream_data, 6)
    bare = _factory()
    d = DurableStream(_factory(), tmp_path, snapshot_every=2, fsync=False)
    for b in batches:
        bare.partial_fit(b)
        d.partial_fit(b)
    d.close()
    steps = list_steps(tmp_path)
    assert len(steps) >= 2
    newest = steps[-1]
    shard = next((tmp_path / f"step_{newest:012d}").glob("shard_*.npz"))
    faults.corrupt_file(shard, seed=1)
    d2 = DurableStream.recover(tmp_path, _factory, fsync=False)
    assert d2.recovery_info["snapshot_step"] < newest
    assert d2.seq == len(batches)  # WAL replay covered the gap
    _assert_replica_equal(bare, d2.stream)
    assert metrics.counter("durability.corrupt_snapshots").value >= 1
    d2.close()


def test_evict_through_wal_replay(stream_data, tmp_path):
    batches = _batches(stream_data, 4)
    evict_idx = np.arange(0, 120, 3, dtype=np.int64)
    bare = _factory()
    for b in batches[:3]:
        bare.partial_fit(b)
    bare.evict(evict_idx)
    bare.partial_fit(batches[3])

    d = DurableStream(_factory(), tmp_path, snapshot_every=2, fsync=False)
    for b in batches[:3]:
        d.partial_fit(b)
    d.evict(evict_idx)
    d2 = DurableStream.recover(tmp_path, _factory, fsync=False)
    d2.partial_fit(batches[3])
    _assert_replica_equal(bare, d2.stream)
    d.close()
    d2.close()


def test_sigkill_mid_run_then_recover(stream_data, tmp_path):
    """Real process death: the child SIGKILLs itself after 3 batches;
    recovery in this process must be bit-identical to an uninterrupted
    run over the surviving prefix + the remaining batches."""
    child = textwrap.dedent(
        """
        import os, signal, sys
        sys.path.insert(0, "src")
        import numpy as np
        from repro.data.synthetic import make_angular_clusters
        from repro.stream import DurableStream, StreamingLAF

        data, _ = make_angular_clusters(700, 16, 8, kappa=120,
                                        noise_frac=0.3, seed=7)
        data = data[np.random.default_rng(1).permutation(len(data))]
        step = -(-len(data) // 5)
        batches = [data[i:i + step] for i in range(0, len(data), step)]
        d = DurableStream(
            StreamingLAF(0.35, 5, block_size=256, backend="exact"),
            sys.argv[1], snapshot_every=2, fsync=True,
        )
        for b in batches[:3]:
            d.partial_fit(b)
        os.kill(os.getpid(), signal.SIGKILL)
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", child, str(tmp_path)],
        capture_output=True, text=True, timeout=300, cwd=".",
    )
    assert proc.returncode == -signal.SIGKILL, proc.stderr[-2000:]

    batches = _batches(stream_data, 5)
    d2 = DurableStream.recover(tmp_path, _factory, fsync=False)
    assert d2.seq == 3
    for b in batches[3:]:
        d2.partial_fit(b)
    bare = _factory()
    for b in batches:
        bare.partial_fit(b)
    _assert_replica_equal(bare, d2.stream)
    d2.close()


# ---------------------------------------------------------------------------
# failover: clone a read replica, promote after primary death
# ---------------------------------------------------------------------------


def test_failover_clone_then_promote(stream_data, tmp_path):
    batches = _batches(stream_data, 5)
    primary = DurableStream(_factory(), tmp_path, snapshot_every=2, fsync=False)
    for b in batches[:3]:
        primary.partial_fit(b)
    # clone a read replica from the published snapshot + WAL
    replica, seq, info = clone_replica(tmp_path, _factory)
    assert seq == 3 and info["recovery_s"] >= 0
    ref3 = _factory()
    for b in batches[:3]:
        ref3.partial_fit(b)
    _assert_replica_equal(ref3, replica)
    # primary writes two more batches, then dies
    for b in batches[3:]:
        primary.partial_fit(b)
    primary.close()
    promoted = DurableStream.promote(replica, tmp_path, seq, fsync=False)
    assert promoted.seq == 5
    assert promoted.recovery_info["wal_records"] == 2
    bare = _factory()
    for b in batches:
        bare.partial_fit(b)
    _assert_replica_equal(bare, promoted.stream)
    promoted.close()


def test_snapshot_gc_drops_covered_wal_files(stream_data, tmp_path):
    d = DurableStream(
        _factory(), tmp_path, snapshot_every=1, keep=2, fsync=False
    )
    for b in _batches(stream_data, 6):
        d.partial_fit(b)
    steps = list_steps(tmp_path)
    assert len(steps) <= 2
    oldest = steps[0]
    for f in tmp_path.glob("wal_*.log"):
        assert int(f.stem.split("_")[1]) >= oldest
    d.close()


# ---------------------------------------------------------------------------
# fault injection + graceful degradation
# ---------------------------------------------------------------------------


def test_fault_plan_grammar_and_determinism():
    plan = faults.FaultPlan.parse("seed=9,sweep.launch=0.5,cluster.launch=1.0:2")
    assert plan.seed == 9
    assert plan.rules["cluster.launch"].max_count == 2
    fires = [plan.should_fail("sweep.launch") for _ in range(64)]
    replay = faults.FaultPlan.parse("seed=9,sweep.launch=0.5,cluster.launch=1.0:2")
    assert fires == [replay.should_fail("sweep.launch") for _ in range(64)]
    assert 0 < sum(fires) < 64  # prob 0.5: some fire, some don't
    assert sum(plan.should_fail("cluster.launch") for _ in range(10)) == 2


# geometry deliberately disjoint from tests/test_obs.py's CFG (d=48,
# n_bits=128): these tests run before test_obs in the suite and would
# otherwise pre-warm the module-level sweep jit caches whose recompile
# count test_sweep_recompiles_once_per_capacity_doubling asserts on.
def _interp_backend(data=None):
    bk = RandomProjectionBackend(
        device=True, interpret=True, sweep=True,
        n_bits=128, margin=3.0, seed=3, chunk=64, q_tile=32, db_tile=64,
    )
    return bk if data is None else bk.fit(data)


@pytest.fixture(scope="module")
def small_angular():
    data, _ = make_angular_clusters(192, 48, 6, kappa=120, noise_frac=0.3, seed=2)
    return data


def test_degraded_sweep_matches_host_oracle(small_angular, obs_sandbox):
    data = small_angular
    rows = np.arange(64)
    host = RandomProjectionBackend(
        n_bits=128, margin=3.0, seed=3, chunk=64, device=False
    ).fit(data)
    bk = _interp_backend(data)
    with faults.inject("seed=5,sweep.launch=1.0"):
        counts = bk.query_counts(rows, 0.55)
        hits = bk.query_hits(rows, 0.55)
    np.testing.assert_array_equal(counts, host.query_counts(rows, 0.55))
    np.testing.assert_array_equal(hits, host.query_hits(rows, 0.55))
    assert metrics.counter("stream.degraded.events").value >= 2
    assert metrics.counter("stream.degraded.counts").value >= 1
    assert metrics.counter("stream.degraded.hits").value >= 1
    assert metrics.counter("slo.violations").value >= 1
    assert metrics.counter("faults.injected").value >= 2


def test_device_loss_sticky_breaker(small_angular, obs_sandbox):
    bk = _interp_backend(small_angular)
    rows = np.arange(32)
    with faults.inject("seed=5,sweep.launch=1.0"):
        for _ in range(3):
            bk.query_counts(rows, 0.55)
    assert bk._device_disabled
    assert not bk.use_device
    assert metrics.counter("stream.degraded.device_disabled").value == 1
    # device loss is sticky: the next query never launches (no new faults
    # are even consulted because the host path is taken outright)
    bk.query_counts(rows, 0.55)
    bk.reset_device()
    assert not bk._device_disabled


def test_on_device_fault_raise_surfaces(small_angular):
    bk = RandomProjectionBackend(
        device=True, interpret=True, sweep=True, n_bits=128, margin=3.0,
        seed=3, chunk=64, q_tile=32, db_tile=64, on_device_fault="raise",
        fault_retries=0,
    ).fit(small_angular)
    with faults.inject("seed=5,sweep.launch=1.0"):
        with pytest.raises(faults.InjectedFault):
            bk.query_counts(np.arange(16), 0.55)


def test_cluster_launch_degrades_to_host_pass(small_angular, obs_sandbox):
    data = small_angular
    pc = np.full(len(data), 10**9)
    ref = laf_dbscan(data, 0.45, 4, 1.0, pc, backend="exact",
                     cluster_device=False)
    with faults.inject("seed=3,cluster.launch=1.0"):
        deg = laf_dbscan(data, 0.45, 4, 1.0, pc, backend="exact",
                         cluster_device=True)
    np.testing.assert_array_equal(ref.labels, deg.labels)
    assert metrics.counter("stream.degraded.cluster").value == 1
    assert metrics.counter("slo.violations").value >= 1
    with faults.inject("seed=3,cluster.launch=1.0"):
        with pytest.raises(RuntimeError):
            laf_dbscan(data, 0.45, 4, 1.0, pc, backend="exact",
                       cluster_device=True, on_device_fault="raise")


def test_ingest_under_faults_is_exact(small_angular, obs_sandbox):
    """Seeded launch faults during streaming ingest degrade to the host
    oracle: final labels identical (ARI 1.0) with recorded evidence."""
    data = small_angular

    def run(spec):
        bk = _interp_backend()  # fresh unfit instance
        s = StreamingLAF(0.55, 4, block_size=64, backend=bk)
        ctx = faults.inject(spec) if spec else None
        if ctx:
            with ctx:
                for i in range(0, len(data), 64):
                    s.partial_fit(data[i : i + 64])
        else:
            for i in range(0, len(data), 64):
                s.partial_fit(data[i : i + 64])
        return s.labels()

    clean = run(None)
    faulty = run("seed=11,sweep.launch=0.5")
    assert adjusted_rand_index(clean, faulty) == 1.0
    assert metrics.counter("stream.degraded.events").value >= 1
    assert metrics.counter("slo.violations").value >= 1


def test_restore_is_recompile_free():
    """laf-lint's LAF108 probe: re-querying pre-crash shapes after a
    state_export/state_import round-trip compiles nothing new."""
    from repro.analysis.jaxpr_checks import _restore_probe_findings

    assert _restore_probe_findings() == []


def test_rebuild_counter_and_event(stream_data, obs_sandbox):
    s = _factory()
    s.partial_fit(stream_data[:400])
    core_idx = np.nonzero(s.state.core[: s.state.n])[0][:40]
    s.evict(core_idx.astype(np.int64))
    assert metrics.counter("stream.rebuilds").value >= 1
    reasons = (
        metrics.counter("stream.rebuilds.core_death").value
        + metrics.counter("stream.rebuilds.tombstone_frac").value
        + metrics.counter("stream.rebuilds.manual").value
    )
    assert reasons == metrics.counter("stream.rebuilds").value
