import numpy as np
import pytest

from repro.core.baselines import block_dbscan, knn_block_dbscan, rho_approx_dbscan
from repro.core.dbscan import dbscan_parallel
from repro.core.dbscan_pp import auto_sample_fraction, dbscan_pp, kcenter_sample, laf_dbscan_pp
from repro.core.metrics import adjusted_rand_index
from repro.core.range_query import range_counts


@pytest.fixture(scope="module")
def gt(small_clustered):
    data, _ = small_clustered
    return dbscan_parallel(data, 0.25, 5)


class TestDBSCANpp:
    def test_full_sample_equals_dbscan(self, small_clustered, gt):
        data, _ = small_clustered
        res = dbscan_pp(data, 0.25, 5, p=1.0)
        assert adjusted_rand_index(res.labels, gt.labels) > 0.99

    def test_partial_sample_quality(self, small_clustered, gt):
        data, _ = small_clustered
        res = dbscan_pp(data, 0.25, 5, p=0.4, seed=0)
        assert adjusted_rand_index(res.labels, gt.labels) > 0.7
        assert res.n_range_queries == int(round(0.4 * len(data)))

    def test_kcenter_sample(self, small_clustered):
        data, _ = small_clustered
        idx = kcenter_sample(data, 50, seed=0)
        assert len(np.unique(idx)) == 50

    def test_auto_sample_fraction(self):
        pred = np.array([10.0, 0.0, 0.0, 20.0])  # 50% predicted core at tau=5
        p = auto_sample_fraction(pred, 5, 1.0, delta=0.2)
        assert p == pytest.approx(0.7)

    def test_laf_pp_skips_and_matches(self, small_clustered, gt):
        data, _ = small_clustered
        n = len(data)
        rng = np.random.default_rng(0)
        p = 0.5
        m = int(round(p * n))
        sample_idx = np.sort(rng.choice(n, size=m, replace=False))
        counts = np.asarray(range_counts(data[sample_idx], data, 0.25)).astype(float)
        res = laf_dbscan_pp(
            data, 0.25, 5, p, counts, alpha=1.0, sample_idx=sample_idx, seed=0
        )
        # oracle estimator: executed = exactly the true-core samples
        assert res.n_range_queries == int((counts >= 5).sum())
        base = dbscan_pp(data, 0.25, 5, p, seed=0)
        assert adjusted_rand_index(res.labels, base.labels) > 0.95


class TestKNNBlock:
    def test_exact_window_matches_dbscan(self, small_clustered, gt):
        data, _ = small_clustered
        res = knn_block_dbscan(data, 0.25, 5, window=len(data))
        np.testing.assert_array_equal(res.core, gt.core)
        assert adjusted_rand_index(res.labels, gt.labels) > 0.999

    def test_approx_window_reasonable(self, small_clustered, gt):
        data, _ = small_clustered
        res = knn_block_dbscan(data, 0.25, 5, n_proj=6, window=300)
        assert adjusted_rand_index(res.labels, gt.labels) > 0.6
        # approximate core detection only misses, never invents
        assert not np.any(res.core & ~gt.core)


class TestBlockDBSCAN:
    def test_quality(self, small_clustered, gt):
        data, _ = small_clustered
        res = block_dbscan(data, 0.25, 5, rnt=10)
        assert adjusted_rand_index(res.labels, gt.labels) > 0.7
        # inner-core-block certification: every certified core is a true core
        assert res.extras["n_blocks"] > 0

    def test_core_certification_sound(self, tiny_clustered):
        """Inner-block members certified core must truly be core."""
        data, _ = tiny_clustered
        eps, tau = 0.3, 4
        res = block_dbscan(data, eps, tau)
        counts = np.asarray(range_counts(data, data, eps))
        true_core = counts >= tau
        assert not np.any(res.core & ~true_core)


class TestRhoApprox:
    def test_rho_zero_is_exact(self, small_clustered, gt):
        data, _ = small_clustered
        res = rho_approx_dbscan(data, 0.25, 5, rho=0.0, engine="direct")
        np.testing.assert_array_equal(res.core, gt.core)
        assert adjusted_rand_index(res.labels, gt.labels) > 0.999

    def test_rho_relaxation_merges(self, small_clustered):
        data, _ = small_clustered
        exact = rho_approx_dbscan(data, 0.25, 5, rho=0.0, engine="direct")
        relax = rho_approx_dbscan(data, 0.25, 5, rho=1.0, engine="direct")
        assert relax.n_clusters <= exact.n_clusters
        np.testing.assert_array_equal(exact.core, relax.core)

    def test_cell_engine_same_semantics(self, tiny_clustered):
        data, _ = tiny_clustered
        a = rho_approx_dbscan(data, 0.3, 4, rho=0.5, engine="cell")
        b = rho_approx_dbscan(data, 0.3, 4, rho=0.5, engine="direct")
        assert adjusted_rand_index(a.labels, b.labels) > 0.999
