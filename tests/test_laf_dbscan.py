import numpy as np
import pytest

from repro.core.dbscan import dbscan_sequential
from repro.core.laf_dbscan import laf_dbscan, laf_dbscan_sequential
from repro.core.metrics import adjusted_mutual_info, adjusted_rand_index
from repro.core.postprocess import PartialNeighborMap, post_processing, update_partial_neighbors
from repro.core.range_query import range_counts


@pytest.fixture(scope="module")
def gt(small_clustered):
    data, _ = small_clustered
    return dbscan_sequential(data, 0.25, 5)


def exact_counts(data, eps):
    return np.asarray(range_counts(data, data, eps)).astype(np.float64)


class TestOracleEstimator:
    """With a perfect estimator and alpha=1, LAF-DBSCAN == DBSCAN."""

    def test_sequential_exact(self, small_clustered, gt):
        data, _ = small_clustered
        counts = exact_counts(data, 0.25)
        res = laf_dbscan_sequential(data, 0.25, 5, 1.0, lambda i: counts[i])
        assert adjusted_rand_index(res.labels, gt.labels) == pytest.approx(1.0)
        np.testing.assert_array_equal(res.core, gt.core)

    def test_parallel_exact(self, small_clustered, gt):
        data, _ = small_clustered
        counts = exact_counts(data, 0.25)
        res = laf_dbscan(data, 0.25, 5, 1.0, counts)
        assert adjusted_rand_index(res.labels, gt.labels) == pytest.approx(1.0)
        np.testing.assert_array_equal(res.core, gt.core)

    def test_queries_saved(self, small_clustered, gt):
        """LAF executes range queries only for predicted-core points."""
        data, _ = small_clustered
        counts = exact_counts(data, 0.25)
        res = laf_dbscan(data, 0.25, 5, 1.0, counts)
        assert res.n_range_queries == int((counts >= 5).sum())
        assert res.n_range_queries < gt.n_range_queries


class TestNoisyEstimator:
    def _noisy(self, counts, seed=0, sigma=0.5):
        rng = np.random.default_rng(seed)
        return counts * np.exp(rng.normal(0.0, sigma, size=len(counts)))

    def test_seq_par_agree(self, small_clustered):
        data, _ = small_clustered
        noisy = self._noisy(exact_counts(data, 0.25))
        seq = laf_dbscan_sequential(data, 0.25, 5, 1.2, lambda i: noisy[i])
        par = laf_dbscan(data, 0.25, 5, 1.2, noisy)
        # identical skip decisions => identical executed-query count
        assert seq.n_range_queries == par.n_range_queries
        assert adjusted_rand_index(seq.labels, par.labels) > 0.99

    def test_quality_stays_high(self, small_clustered, gt):
        data, _ = small_clustered
        noisy = self._noisy(exact_counts(data, 0.25))
        par = laf_dbscan(data, 0.25, 5, 1.2, noisy)
        assert adjusted_rand_index(par.labels, gt.labels) > 0.9
        assert adjusted_mutual_info(par.labels, gt.labels) > 0.85

    def test_postprocessing_improves_quality(self, small_clustered, gt):
        """Dropping Algorithm 3 must not beat running it (usually strictly worse)."""
        data, _ = small_clustered
        # heavy under-estimation -> many false negatives -> rescues matter
        noisy = exact_counts(data, 0.25) * 0.5
        with_pp = laf_dbscan(data, 0.25, 5, 1.0, noisy)
        assert with_pp.extras["n_rescued"] > 0

    def test_alpha_tradeoff_monotone_queries(self, small_clustered):
        """Larger alpha -> more skips -> fewer executed range queries."""
        data, _ = small_clustered
        noisy = self._noisy(exact_counts(data, 0.25))
        q = [
            laf_dbscan(data, 0.25, 5, a, noisy).n_range_queries
            for a in (0.5, 1.0, 2.0, 4.0)
        ]
        assert q[0] >= q[1] >= q[2] >= q[3]


class TestPartialNeighbors:
    def test_update_partial_neighbors_alg2(self):
        emap = PartialNeighborMap()
        emap.register(3)
        emap.register(7)
        update_partial_neighbors(1, [2, 3, 7], emap)
        update_partial_neighbors(5, [3], emap)
        assert emap[3] == {1, 5}
        assert emap[7] == {1}
        assert 2 not in emap

    def test_postprocessing_merges_split_cluster(self):
        """Two halves split by a false-negative bridge point merge back."""
        labels = np.array([0, 0, 0, 1, 1, 1, -1])  # point 6 = FN bridge
        emap = PartialNeighborMap()
        emap.register(6)
        emap[6].update({0, 1, 3, 4})  # >= tau=3 partial neighbors
        out = post_processing(labels, emap, 3)
        assert out[0] == out[3]          # clusters merged
        assert out[6] == out[0]          # rescued point joins
        assert len(np.unique(out[out >= 0])) == 1

    def test_postprocessing_ignores_below_tau(self):
        labels = np.array([0, 0, 1, 1, -1])
        emap = PartialNeighborMap()
        emap.register(4)
        emap[4].update({0, 2})  # only 2 < tau=3
        out = post_processing(labels, emap, 3)
        assert out[0] != out[2]
        assert out[4] == -1

    def test_postprocessing_transitive_merge(self):
        """Chained rescues merge transitively (A-B via p5, B-C via p6)."""
        labels = np.array([0, 0, 1, 1, 2, -1, -1])
        emap = PartialNeighborMap()
        emap.register(5)
        emap[5].update({0, 1, 2})
        emap.register(6)
        emap[6].update({2, 3, 4})
        out = post_processing(labels, emap, 3)
        assert out[0] == out[2] == out[4]


class TestFullyMissedClusters:
    def test_missed_cluster_stats(self, small_clustered, gt):
        """Table 6 machinery: clusters fully missed when every core is FN."""
        data, _ = small_clustered
        counts = exact_counts(data, 0.25)
        # kill the estimator for points of one ground-truth cluster
        target = 0
        pred = counts.copy()
        members = gt.labels == target
        pred[members] = 0.0
        res = laf_dbscan(data, 0.25, 5, 1.0, pred)
        # rescue may re-find it via partial neighbors from outside; at
        # minimum the pipeline must not crash and others stay intact
        others = ~members
        assert adjusted_rand_index(res.labels[others], gt.labels[others]) > 0.95
