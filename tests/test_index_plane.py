"""The sharded index plane (``repro.distributed.index_plane``) and the
LAF lowering built on it.

Three layers of parity, all against the one shared ``band_hits``
contract:

* plane functions on a 1-device mesh == the plain kernel wrappers
  (in-process; the degenerate case ``index_device="auto"`` now relies
  on);
* plane functions on a forced 4-host-device mesh == host oracle ==
  single-device fused path, on a non-shard-multiple ``n`` (subprocess —
  the device count is locked at first jax init);
* ``build_laf_cluster`` with ``index_device="auto"`` on the 4-device
  mesh routes through the shard_mapped tile (meta says so) and its
  frontier round reproduces the dataflow lowering bit-for-bit, while
  end-to-end clustering through the plane-backed backend matches the
  exact backend at ARI == 1.0.
"""

import numpy as np
import pytest

from repro.data.synthetic import make_angular_clusters

EPS = 0.55


@pytest.fixture(scope="module")
def plane_data():
    # 613 is not a multiple of 4 shards (nor of 32): plane-level padding
    # and the padded-row corrections are exercised on every call
    data, _ = make_angular_clusters(613, 32, 8, kappa=120, noise_frac=0.3, seed=2)
    return data


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def test_data_axes_is_public_dp_spelling():
    import jax
    from jax.sharding import Mesh

    from repro.distributed.sharding import _dp_axes, data_axes

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    assert data_axes(mesh) == ("data",)
    assert _dp_axes is data_axes  # the private name is the same object


def test_shard_plan_alignment():
    import jax
    from jax.sharding import Mesh

    from repro.distributed.index_plane import shard_plan

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    plan = shard_plan(mesh, 613)
    assert plan.axes == ("data",)
    assert plan.n_shards == 1
    assert plan.n_padded % 32 == 0 and plan.n_padded >= 613
    plan_all = shard_plan(mesh, 613, axes=("data", "model"))
    assert plan_all.axes == ("data", "model")


def test_shard_signatures_places_and_pads(plane_data):
    import jax
    from jax.sharding import Mesh

    from repro.index import RandomProjectionBackend, shard_signatures

    bk = RandomProjectionBackend(n_bits=64, seed=3).fit(plane_data)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    placed = shard_signatures(mesh, bk.signatures, n_padded=640)
    assert placed.shape == (640, 2)
    got = np.asarray(placed)
    np.testing.assert_array_equal(got[:613], bk.signatures)
    assert not got[613:].any()  # zero-word padding


# ---------------------------------------------------------------------------
# 1-device degenerate case: the plane IS the plain wrapper
# ---------------------------------------------------------------------------


def test_plane_single_device_matches_plain_kernel(plane_data):
    import jax
    from jax.sharding import Mesh

    from repro.distributed.index_plane import (
        sharded_band_marginals,
        sharded_hamming_bitmap,
        sharded_hamming_count,
    )
    from repro.index import RandomProjectionBackend
    from repro.kernels.hamming_filter.ops import (
        hamming_filter_bitmap,
        hamming_filter_count,
    )

    bk = RandomProjectionBackend(n_bits=64, seed=3).fit(plane_data)
    t_lo, t_hi = bk.band(EPS)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    q, q_sig = plane_data[:48], bk.signatures[:48]
    kw = dict(t_lo=t_lo, q_tile=32, db_tile=64, interpret=True)

    ref_c = hamming_filter_count(q, plane_data, q_sig, bk.signatures, EPS, t_hi, **kw)
    ref_c2, ref_bm = hamming_filter_bitmap(
        q, plane_data, q_sig, bk.signatures, EPS, t_hi, **kw
    )
    got_c = sharded_hamming_count(
        q, plane_data, q_sig, bk.signatures, EPS, t_hi, mesh=mesh, **kw
    )
    got_c2, got_bm = sharded_hamming_bitmap(
        q, plane_data, q_sig, bk.signatures, EPS, t_hi, mesh=mesh, **kw
    )
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(ref_c))
    np.testing.assert_array_equal(np.asarray(got_c2), np.asarray(ref_c2))
    np.testing.assert_array_equal(np.asarray(got_bm), np.asarray(ref_bm))

    counts_m, partial = sharded_band_marginals(
        q, plane_data, q_sig, bk.signatures, EPS, t_hi, mesh=mesh, **kw
    )
    from repro.core.range_query import unpack_bitmap

    hits = unpack_bitmap(np.asarray(ref_bm), len(plane_data))
    np.testing.assert_array_equal(np.asarray(counts_m), hits.sum(axis=1))
    np.testing.assert_array_equal(np.asarray(partial), hits.sum(axis=0))


def test_backend_mesh_single_device_matches_host(plane_data):
    import jax
    from jax.sharding import Mesh

    from repro.index import RandomProjectionBackend

    cfg = dict(n_bits=64, margin=3.0, seed=3, chunk=64, q_tile=32, db_tile=64)
    host = RandomProjectionBackend(device=False, **cfg).fit(plane_data)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    plane = RandomProjectionBackend(
        device=True, interpret=True, mesh=mesh, **cfg
    ).fit(plane_data)
    rows = np.arange(80)
    for eps in (EPS, 1.2):  # eps > 1: plane-pad rows pass the dot test
        hh = host.query_hits(rows, eps)
        np.testing.assert_array_equal(plane.query_hits(rows, eps), hh)
        np.testing.assert_array_equal(plane.query_counts(rows, eps), hh.sum(axis=1))
        cols = np.arange(5, 600, 7)
        np.testing.assert_array_equal(
            plane.query_hits_subset(rows, cols, eps), hh[:, cols]
        )


# ---------------------------------------------------------------------------
# forced 4-host-device mesh (subprocess): real shards
# ---------------------------------------------------------------------------


def test_plane_4dev_parity_nonmultiple_n(forced_device_run):
    """Sharded-plane hits/counts == host oracle == single-device fused
    path on n = 613 (not a multiple of shards, kernel tiles, or 32)."""
    out = forced_device_run(
        """
        import numpy as np, jax
        from repro.data.synthetic import make_angular_clusters
        from repro.index import RandomProjectionBackend

        data, _ = make_angular_clusters(613, 32, 8, kappa=120, noise_frac=0.3, seed=2)
        mesh = jax.make_mesh((4,), ("data",))
        cfg = dict(n_bits=64, margin=3.0, seed=3, chunk=64, q_tile=32, db_tile=64)
        host = RandomProjectionBackend(device=False, **cfg).fit(data)
        single = RandomProjectionBackend(device=True, interpret=True, **cfg).fit(data)
        plane = RandomProjectionBackend(
            device=True, interpret=True, mesh=mesh, **cfg
        ).fit(data)
        assert plane._plan.n_shards == 4

        rows = np.arange(96)
        ok = {}
        for eps in (0.55, 1.2):
            hh = host.query_hits(rows, eps)
            np.testing.assert_array_equal(single.query_hits(rows, eps), hh)
            np.testing.assert_array_equal(plane.query_hits(rows, eps), hh)
            np.testing.assert_array_equal(
                plane.query_counts(rows, eps), hh.sum(axis=1)
            )
            np.testing.assert_array_equal(
                single.query_counts(rows, eps), hh.sum(axis=1)
            )
            ok[str(eps)] = True
        print("RESULT:" + json.dumps(ok))
        """
    )
    assert out["0.55"] and out["1.2"]


def test_laf_cluster_auto_routes_sharded_tile_4dev(forced_device_run):
    """Acceptance: on a forced 4-host-device mesh, ``index_device="auto"``
    routes the frontier round through the shard_mapped hamming_filter
    tile (no n_dev == 1 special case), reproduces the dataflow lowering
    bit-for-bit, and clustering through the plane-backed backend gives
    labels with ARI == 1.0 vs the exact backend."""
    out = forced_device_run(
        """
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp

        from repro.configs.registry import get_arch
        from repro.core.laf_dbscan import laf_dbscan
        from repro.core.metrics import adjusted_rand_index
        from repro.data.synthetic import make_angular_clusters
        from repro.index import ExactBackend, RandomProjectionBackend
        from repro.index.signatures import make_projection, sign_signatures
        from repro.launch import laf_cluster as L

        arch = get_arch("laf_dbscan")
        base = arch.make_reduced_config()
        shape = dataclasses.replace(
            arch.shapes["nyt_150k"], meta={"n_points": 512, "dim": 32}
        )
        mesh = jax.make_mesh((4,), ("data",))

        def cell_for(index_device):
            red = dataclasses.replace(
                base, backend="random_projection", index_device=index_device
            )
            a = dataclasses.replace(arch, make_config=lambda: red)
            return L.build_laf_cluster(a, shape, mesh)

        auto_cell = cell_for("auto")
        flow_cell = cell_for(False)
        meta = {
            "fused": bool(auto_cell.meta["fused_kernel"]),
            "sharded": bool(auto_cell.meta["sharded"]),
            "n_shards": int(auto_cell.meta["n_shards"]),
            "flow_fused": bool(flow_cell.meta["fused_kernel"]),
        }

        rng = np.random.default_rng(1)
        from repro.data.synthetic import sample_uniform_sphere
        data = sample_uniform_sphere(rng, 512, 32)
        queries = data[: base.frontier]
        db_sig = sign_signatures(data, make_projection(32, base.index_bits, seed=0))
        params = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), auto_cell.args[0]
        )
        args = (params, data, queries, jnp.asarray(db_sig))
        with mesh:
            fused = [np.asarray(o) for o in auto_cell.step_fn(*args)]
            flow = [np.asarray(o) for o in flow_cell.step_fn(*args)]
        meta["partial_sum"] = int(fused[1].sum())
        np.testing.assert_array_equal(fused[0], flow[0])
        np.testing.assert_array_equal(fused[1], flow[1])

        # end-to-end labels through the same plane: open-filter full
        # verify makes the indexed hit sets *equal* to exact, so the
        # partitions are identical, deterministically
        cdata, _ = make_angular_clusters(600, 32, 8, kappa=120, noise_frac=0.3, seed=5)
        exact = ExactBackend().fit(cdata)
        plane = RandomProjectionBackend(
            n_bits=64, margin=1e9, verify="full", seed=4,
            device=True, interpret=True, mesh=mesh, chunk=64,
            q_tile=32, db_tile=64,
        ).fit(cdata)
        pred = exact.query_counts(np.arange(len(cdata)), 0.55)
        res_ex = laf_dbscan(cdata, 0.55, 5, 1.0, pred, seed=0, backend=exact)
        res_pl = laf_dbscan(cdata, 0.55, 5, 1.0, pred, seed=0, backend=plane)
        meta["ari"] = float(adjusted_rand_index(res_ex.labels, res_pl.labels))
        print("RESULT:" + json.dumps(meta))
        """,
        timeout=600,
    )
    assert out["fused"] is True and out["sharded"] is True
    assert out["n_shards"] == 4
    assert out["flow_fused"] is False
    assert out["partial_sum"] > 0
    assert out["ari"] == 1.0


# ---------------------------------------------------------------------------
# kernel occupancy stats + margin auto-tune
# ---------------------------------------------------------------------------


def test_suggest_margin_host_device_agree(plane_data):
    from repro.index import RandomProjectionBackend, suggest_margin

    cfg = dict(n_bits=64, seed=3, q_tile=32, db_tile=64)
    host = RandomProjectionBackend(device=False, **cfg).fit(plane_data)
    dev = RandomProjectionBackend(device=True, interpret=True, **cfg).fit(plane_data)
    m_host, table = suggest_margin(host, EPS, report=True)
    m_dev = suggest_margin(dev, EPS)
    assert m_host == m_dev
    assert any(r["margin"] == m_host for r in table)
    # band width (and so its occupancy) grows with margin
    fracs = [r["band_frac"] for r in sorted(table, key=lambda r: r["margin"])]
    assert fracs == sorted(fracs)


def test_suggest_margin_budget_monotone(plane_data):
    from repro.index import RandomProjectionBackend, suggest_margin

    bk = RandomProjectionBackend(n_bits=64, seed=3, device=False).fit(plane_data)
    loose = suggest_margin(bk, EPS, max_band_frac=0.9)
    tight = suggest_margin(bk, EPS, max_band_frac=0.05)
    assert loose >= tight  # a bigger verify budget affords a wider band
