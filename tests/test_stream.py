"""repro.stream: streaming ingest parity vs from-scratch batch runs,
cluster-merge and core-promotion events, the estimator fast path,
eviction semantics, and the serving assignment API.
"""

import numpy as np
import pytest

from repro.core.dbscan import dbscan_parallel
from repro.core.laf_dbscan import laf_dbscan
from repro.core.metrics import adjusted_rand_index
from repro.core.pipeline import LAFPipeline
from repro.data.synthetic import make_angular_clusters
from repro.index import ExactBackend
from repro.stream import StreamingLAF, StreamingClusterState

EPS, TAU = 0.35, 5


@pytest.fixture(scope="module")
def stream_data():
    data, _ = make_angular_clusters(1500, 32, 12, kappa=200, noise_frac=0.3, seed=1)
    # shuffled arrival order (the ISSUE's k >= 4 shuffled batches)
    return data[np.random.default_rng(0).permutation(len(data))]


@pytest.fixture(scope="module")
def batch_reference(stream_data):
    """From-scratch LAF-DBSCAN on the full data, oracle estimator."""
    oracle = (
        ExactBackend()
        .fit(stream_data)
        .query_counts(np.arange(len(stream_data)), EPS)
        .astype(float)
    )
    return laf_dbscan(stream_data, EPS, TAU, 1.0, oracle, seed=0), oracle


def _ingest(data, k, **kw):
    s = StreamingLAF(EPS, TAU, block_size=512, **kw)
    step = -(-len(data) // k)
    for start in range(0, len(data), step):
        s.partial_fit(data[start : start + step])
    return s


# ---------------------------------------------------------------------------
# parity: streaming over k shuffled batches == from-scratch batch run
# ---------------------------------------------------------------------------


def test_streaming_parity_exact_backend(stream_data, batch_reference):
    ref, _ = batch_reference
    s = _ingest(stream_data, 5, backend="exact")
    labels = s.labels()
    assert adjusted_rand_index(labels, ref.labels) == 1.0
    # stronger than ARI: the maintained partition is point-identical
    # (same counts, same core set, same min-core-neighbor border rule)
    np.testing.assert_array_equal(labels, ref.labels)
    np.testing.assert_array_equal(s.state.core[: s.state.n], ref.core)


def test_streaming_parity_random_projection(stream_data, batch_reference):
    ref, _ = batch_reference
    s = _ingest(stream_data, 4, backend="random_projection")
    assert adjusted_rand_index(s.labels(), ref.labels) >= 0.99


def test_streaming_matches_dbscan_parallel(stream_data):
    ref = dbscan_parallel(stream_data, EPS, TAU)
    s = _ingest(stream_data, 6, backend="exact")
    np.testing.assert_array_equal(s.labels(), ref.labels)


def test_batch_count_invariance(stream_data):
    """The maintained state must not depend on how the stream is cut."""
    a = _ingest(stream_data, 4, backend="exact")
    b = _ingest(stream_data, 9, backend="exact")
    np.testing.assert_array_equal(a.labels(), b.labels())
    np.testing.assert_array_equal(
        a.state.counts[: a.state.n], b.state.counts[: b.state.n]
    )


def test_multi_block_batch_counts_exact(stream_data):
    """Regression: one batch larger than block_size is chunked over
    several query blocks — same-batch pairs spanning two blocks must not
    double-count for the earlier block's endpoint."""
    data = stream_data[:600]
    s = StreamingLAF(EPS, TAU, backend="exact", block_size=50)
    s.partial_fit(data)  # 12 blocks in one batch
    exact = ExactBackend().fit(data).query_counts(np.arange(len(data)), EPS)
    np.testing.assert_array_equal(s.state.counts[: s.state.n], exact)
    ref = dbscan_parallel(data, EPS, TAU)
    np.testing.assert_array_equal(s.labels(), ref.labels)


# ---------------------------------------------------------------------------
# structural events: cluster merge, border -> core promotion
# ---------------------------------------------------------------------------


def _on_circle(angles_deg, d=8):
    """Unit vectors at the given angles on a great circle (degrees)."""
    a = np.deg2rad(np.asarray(angles_deg, dtype=np.float64))
    out = np.zeros((len(a), d), dtype=np.float32)
    out[:, 0] = np.cos(a)
    out[:, 1] = np.sin(a)
    return out


def test_bridge_batch_merges_clusters():
    # eps=0.1 -> angular threshold arccos(0.9) ~ 25.8 degrees
    eps, tau = 0.1, 3
    s = StreamingLAF(eps, tau, backend="exact")
    s.partial_fit(_on_circle([0, 5, 10, 15, 20]))     # cluster A
    s.partial_fit(_on_circle([90, 95, 100, 105, 110]))  # cluster B
    assert s.n_clusters == 2
    lab = s.labels()
    assert lab[0] != lab[5]
    s.partial_fit(_on_circle([35, 50, 65, 80]))       # the bridge
    assert s.n_clusters == 1
    lab = s.labels()
    assert lab.min() == 0 and np.all(lab == 0)
    # parity with a from-scratch run on the accumulated data
    full = np.concatenate(
        [_on_circle([0, 5, 10, 15, 20]), _on_circle([90, 95, 100, 105, 110]),
         _on_circle([35, 50, 65, 80])]
    )
    ref = dbscan_parallel(full, eps, tau)
    np.testing.assert_array_equal(lab, ref.labels)


def test_batch_promotes_border_to_core():
    eps, tau = 0.1, 3
    s = StreamingLAF(eps, tau, backend="exact")
    # 0 and 40 are borders of 20's cluster (2 neighbors incl. self);
    # 20 is the only core (3 neighbors incl. self)
    s.partial_fit(_on_circle([0, 20, 40]))
    lab0 = s.labels()
    assert list(s.state.core[:3]) == [False, True, False]
    assert lab0[2] == lab0[1] >= 0  # 40 is a border, labeled via 20
    # 45 lands within eps of 40 (and 20): 40's count crosses tau -> core
    rep = s.partial_fit(_on_circle([45]))
    assert rep.n_promoted >= 1
    assert bool(s.state.core[2])
    ref = dbscan_parallel(_on_circle([0, 20, 40, 45]), eps, tau)
    np.testing.assert_array_equal(s.labels(), ref.labels)


# ---------------------------------------------------------------------------
# estimator fast path (online skip rule)
# ---------------------------------------------------------------------------


def test_estimator_fast_path_skips_and_stays_exact(stream_data, batch_reference):
    ref, oracle = batch_reference
    lookup = {stream_data[i].tobytes(): oracle[i] for i in range(len(stream_data))}
    est = lambda v: np.array([lookup[r.tobytes()] for r in v])
    s = _ingest(
        stream_data, 5, backend="exact",
        estimator=est, use_estimator=True, alpha=1.0,
    )
    skipped = int((~s.state.queried[: s.state.n]).sum())
    assert skipped > 0, "oracle at alpha=1 must skip the predicted-noise points"
    # with an oracle, skips are exactly the non-core points -> partition intact
    assert adjusted_rand_index(s.labels(), ref.labels) == 1.0


def test_estimator_fast_path_counts_are_lower_bounds(stream_data):
    est = lambda v: np.zeros(len(v))  # predict everything as noise
    s = _ingest(stream_data, 4, backend="exact", estimator=est, use_estimator=True)
    exact = ExactBackend().fit(stream_data).query_counts(np.arange(len(stream_data)), EPS)
    state_counts = s.state.counts[: s.state.n]
    assert np.all(state_counts <= exact), "skipped counts must never overcount"


# ---------------------------------------------------------------------------
# eviction / decay
# ---------------------------------------------------------------------------


def test_evict_noise_is_cheap_and_preserves_labels(stream_data):
    s = _ingest(stream_data, 4, backend="exact")
    before = s.labels()
    noise = np.nonzero(before < 0)[0][:25]
    rebuilt = s.evict(noise)
    assert not rebuilt
    after = s.labels()
    assert np.all(after[noise] == -1)
    keep = np.ones(len(before), dtype=bool)
    keep[noise] = False
    np.testing.assert_array_equal(after[keep], before[keep])


def test_evict_core_triggers_rebuild(stream_data):
    s = _ingest(stream_data, 4, backend="exact")
    core = np.nonzero(s.state.core[: s.state.n])[0][:5]
    live_before = np.nonzero(s.state.alive[: s.state.n])[0]
    rebuilt = s.evict(core)
    assert rebuilt
    # post-rebuild state is a from-scratch run on the surviving rows
    survivors = np.setdiff1d(live_before, core)
    ref = dbscan_parallel(stream_data[survivors], EPS, TAU)
    np.testing.assert_array_equal(s.labels(), ref.labels)


def test_re_evicting_dead_rows_is_idempotent(stream_data):
    """Regression: indices already tombstoned must not decrement the
    survivors' counts a second time when passed to evict again."""
    s = _ingest(stream_data, 4, backend="exact")
    noise = np.nonzero(s.labels() < 0)[0][:10]
    s.evict(noise[:5])
    counts_after = s.state.counts[: s.state.n].copy()
    s.evict(noise)  # overlaps the first five
    expect = counts_after.copy()
    # only the five newly evicted rows' hits may decrement anything
    fresh = noise[5:]
    dec = ExactBackend().fit(stream_data).query_hits(fresh, EPS).sum(axis=0)
    dec[fresh] = 0
    dec[noise[:5]] = 0  # columns already dead are masked out
    np.testing.assert_array_equal(s.state.counts[: s.state.n], expect - dec)


def test_evict_with_duplicate_indices_decrements_once(stream_data):
    s = _ingest(stream_data, 4, backend="exact")
    noise = np.nonzero(s.labels() < 0)[0][:4]
    t = _ingest(stream_data, 4, backend="exact")
    s.evict(np.repeat(noise, 3))  # [a,a,a,b,b,b,...]
    t.evict(noise)
    np.testing.assert_array_equal(
        s.state.counts[: s.state.n], t.state.counts[: t.state.n]
    )


def test_decay_hook_runs_per_batch(stream_data):
    calls = []

    def decay(state):
        calls.append(state.n)
        return None

    _ingest(stream_data[:600], 3, backend="exact", decay=decay)
    assert calls == [200, 400, 600]


# ---------------------------------------------------------------------------
# serving: assign()
# ---------------------------------------------------------------------------


def test_assign_members_and_noise(stream_data):
    s = _ingest(stream_data, 4, backend="random_projection")
    lab = s.labels()
    members = np.nonzero(lab >= 0)[0][:60]
    res = s.assign(stream_data[members])
    np.testing.assert_array_equal(res.labels, lab[members])
    assert np.all((res.confidence >= 0) & (res.confidence <= 1))
    assert np.all(res.n_hits[res.labels >= 0] >= 1)
    # a query with no eps-neighbor anywhere must come back noise
    far = np.zeros((1, stream_data.shape[1]), np.float32)
    far[0, -1] = 1.0
    assert not np.any(stream_data @ far[0] > 1.0 - EPS), "fixture drift: pick another far vector"
    r = s.assign(far)
    assert r.labels[0] == -1 and r.confidence[0] == 0.0 and r.n_hits[0] == 0


def test_assign_perturbed_members_match_exact_backend(stream_data):
    s = _ingest(stream_data, 4, backend="exact")
    lab = s.labels()
    members = np.nonzero(lab >= 0)[0][:40]
    rng = np.random.default_rng(3)
    q = stream_data[members] + 0.01 * rng.standard_normal((40, 32)).astype(np.float32)
    res = s.assign(q)
    assert np.mean(res.labels == lab[members]) >= 0.95


def test_assign_snapshot_invalidated_by_ingest(stream_data):
    s = _ingest(stream_data[:800], 2, backend="exact")
    snap1 = s.snapshot()
    assert s.snapshot() is snap1  # cached while the state is unchanged
    s.partial_fit(stream_data[800:1000])
    assert s.snapshot() is not snap1


def test_prefit_backend_warm_starts_the_stream(stream_data):
    """A constructed, already-fitted backend must not desync row indices
    — its rows are absorbed as batch zero."""
    bk = ExactBackend().fit(stream_data[:900])
    s = StreamingLAF(EPS, TAU, backend=bk)
    assert s.n_points == 900
    s.partial_fit(stream_data[900:1200])
    ref = dbscan_parallel(stream_data[:1200], EPS, TAU)
    np.testing.assert_array_equal(s.labels(), ref.labels)


def test_instance_backend_rejects_index_kwargs(stream_data):
    from repro.index import RandomProjectionBackend

    with pytest.raises(ValueError, match="constructed instance"):
        StreamingLAF(EPS, TAU, backend=RandomProjectionBackend(), n_bits=128)
    with pytest.raises(ValueError, match="constructed instance"):
        StreamingLAF(EPS, TAU, backend=RandomProjectionBackend(), device=False)


def test_pipeline_accepts_instance_backend(stream_data):
    """Regression: the pipeline must not forward its device default into
    a constructed backend instance (which keeps its own evaluator)."""
    pipe = LAFPipeline(backend=ExactBackend())
    rep = pipe.partial_fit(stream_data[:400], eps=EPS, tau=TAU)
    assert rep.n_points == 400
    ref = dbscan_parallel(stream_data[:400], EPS, TAU)
    np.testing.assert_array_equal(pipe.stream.labels(), ref.labels)


# ---------------------------------------------------------------------------
# LAFPipeline surface
# ---------------------------------------------------------------------------


def test_pipeline_partial_fit_assign(stream_data):
    pipe = LAFPipeline(backend="exact")
    with pytest.raises(ValueError):
        pipe.partial_fit(stream_data[:100])  # eps/tau must be fixed first
    for start in range(0, 1000, 250):
        rep = pipe.partial_fit(stream_data[start : start + 250], eps=EPS, tau=TAU)
    assert rep.n_points == 1000
    ref = dbscan_parallel(stream_data[:1000], EPS, TAU)
    np.testing.assert_array_equal(pipe.stream.labels(), ref.labels)
    members = np.nonzero(ref.labels >= 0)[0][:10]
    res = pipe.assign(stream_data[members])
    np.testing.assert_array_equal(res.labels, ref.labels[members])
    # changing the operating point mid-stream must be loud, not silent
    with pytest.raises(ValueError, match="operating-point-specific"):
        pipe.partial_fit(stream_data[1000:1100], eps=0.9, tau=2)
    with pytest.raises(ValueError, match="cannot be applied"):
        pipe.partial_fit(stream_data[1000:1100], eps=EPS, tau=TAU, block_size=64)


# ---------------------------------------------------------------------------
# state-level invariants
# ---------------------------------------------------------------------------


def test_state_grows_in_amortized_chunks():
    st = StreamingClusterState(0.3, 4)
    st.extend(10)
    cap0 = st.counts.shape[0]
    st.extend(5)
    assert st.n == 15
    assert st.counts.shape[0] >= 15
    # doubling: few reallocations across many tiny extends
    for _ in range(100):
        st.extend(1)
    assert st.counts.shape[0] >= st.n >= 115 and cap0 < st.counts.shape[0] <= 4 * 115


@pytest.mark.slow
def test_streaming_parity_large_random_projection():
    data, _ = make_angular_clusters(6000, 64, 30, kappa=420, noise_frac=0.35, seed=5)
    data = data[np.random.default_rng(1).permutation(len(data))]
    ref = dbscan_parallel(data, 0.4, 6, backend="random_projection")
    s = StreamingLAF(0.4, 6, backend="random_projection")
    for start in range(0, len(data), 1000):
        s.partial_fit(data[start : start + 1000])
    assert adjusted_rand_index(s.labels(), ref.labels) >= 0.99
