import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.range_query import (
    bitmap_row_to_indices,
    neighbor_lists,
    pack_bitmap,
    range_bitmap,
    range_counts,
    range_counts_and_bitmap,
    unpack_bitmap,
)
from repro.data.synthetic import sample_uniform_sphere


def brute_counts(q, db, eps):
    return ((q @ db.T) > (1.0 - eps)).sum(axis=1)


@pytest.mark.parametrize("nq,nd,d,block", [(7, 33, 8, 16), (32, 100, 24, 32), (5, 257, 16, 64)])
def test_counts_match_brute(nq, nd, d, block):
    rng = np.random.default_rng(0)
    q = sample_uniform_sphere(rng, nq, d)
    db = sample_uniform_sphere(rng, nd, d)
    for eps in (0.2, 0.5, 0.9):
        got = np.asarray(range_counts(q, db, eps, block_size=block))
        np.testing.assert_array_equal(got, brute_counts(q, db, eps))


def test_bitmap_roundtrip():
    rng = np.random.default_rng(1)
    hits = rng.random((13, 77)) < 0.3
    packed = pack_bitmap(hits)
    np.testing.assert_array_equal(unpack_bitmap(packed, 77), hits)


@pytest.mark.parametrize("nd", [31, 32, 33, 100])
def test_range_bitmap_matches_brute(nd):
    rng = np.random.default_rng(2)
    q = sample_uniform_sphere(rng, 9, 12)
    db = sample_uniform_sphere(rng, nd, 12)
    eps = 0.6
    bm = np.asarray(range_bitmap(q, db, eps, block_size=32))
    expect = (q @ db.T) > (1.0 - eps)
    np.testing.assert_array_equal(unpack_bitmap(bm, nd), expect)


def test_counts_and_bitmap_consistent():
    rng = np.random.default_rng(3)
    q = sample_uniform_sphere(rng, 11, 10)
    db = sample_uniform_sphere(rng, 67, 10)
    counts, bm = range_counts_and_bitmap(q, db, 0.5, block_size=32)
    counts = np.asarray(counts)
    bm = np.asarray(bm)
    np.testing.assert_array_equal(counts, unpack_bitmap(bm, 67).sum(axis=1))
    for i in range(11):
        idx = bitmap_row_to_indices(bm[i], 67)
        assert len(idx) == counts[i]


def test_neighbor_lists_self_included():
    rng = np.random.default_rng(4)
    db = sample_uniform_sphere(rng, 50, 8)
    lists = neighbor_lists(db, 0.4)
    for i, lst in enumerate(lists):
        assert i in lst  # d(P,P)=0 < eps


@given(st.integers(min_value=1, max_value=200), st.floats(min_value=0.05, max_value=1.5))
@settings(max_examples=20, deadline=None)
def test_counts_property(nd, eps):
    """Counts are between 1 (self) and nd, and monotone in eps."""
    rng = np.random.default_rng(nd)
    db = sample_uniform_sphere(rng, nd, 6)
    c1 = np.asarray(range_counts(db, db, eps, block_size=32))
    c2 = np.asarray(range_counts(db, db, min(eps + 0.2, 2.0), block_size=32))
    assert (c1 >= 1).all() and (c1 <= nd).all()
    assert (c2 >= c1).all()
