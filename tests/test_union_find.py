"""Direct coverage for ``core.union_find.UnionFind`` incremental
semantics — the streaming cluster state leans on interleaved
``union``/``find`` (path compression must not corrupt the forest),
``grow`` (existing components and their roots must survive the
universe expanding), and composition with the vectorized helpers
(``union_star`` mutates ``uf.parent`` in place).
"""

import numpy as np
import pytest

from repro.core.union_find import (
    UnionFind,
    compact_labels_from_parent,
    find_roots_vec,
    union_star,
)


def _partition(uf: UnionFind) -> dict:
    """root -> frozenset(members), independent of representative choice."""
    groups = {}
    for i in range(len(uf)):
        groups.setdefault(uf.find(i), set()).add(i)
    return {min(v): frozenset(v) for v in groups.values()}


def test_interleaved_union_find_matches_reference():
    """Random interleave of unions and finds vs a naive set-merge model."""
    rng = np.random.default_rng(0)
    n = 200
    uf = UnionFind(n)
    ref = {i: {i} for i in range(n)}  # representative -> members
    where = {i: i for i in range(n)}  # element -> representative
    for _ in range(500):
        a, b = rng.integers(0, n, 2)
        if rng.random() < 0.5:
            uf.union(int(a), int(b))
            ra, rb = where[int(a)], where[int(b)]
            if ra != rb:
                ref[ra] |= ref.pop(rb)
                for m in ref[ra]:
                    where[m] = ra
        else:
            # find mid-stream: same-set iff same root, and idempotent
            same = uf.find(int(a)) == uf.find(int(b))
            assert same == (where[int(a)] == where[int(b)])
            assert uf.find(int(a)) == uf.find(int(a))
    got = {frozenset(v) for v in _partition(uf).values()}
    want = {frozenset(v) for v in ref.values()}
    assert got == want


def test_path_compression_flattens_chain():
    uf = UnionFind(64)
    # build a deliberate chain 0 <- 1 <- 2 ... by direct parent edits
    uf.parent[1:] = np.arange(63)
    root = uf.find(63)
    assert root == 0
    # path halving must have shortened the traversed path
    assert uf.parent[63] != 62
    # every element on the chain still resolves to the same root
    assert all(uf.find(i) == 0 for i in range(64))


def test_roots_stability_after_growth():
    uf = UnionFind(10)
    uf.union(0, 1)
    uf.union(2, 3)
    uf.union(1, 3)
    before = uf.roots()
    uf.grow(20)
    assert len(uf) == 20
    after = uf.roots()
    # old components untouched: identical root structure on 0..9
    np.testing.assert_array_equal(after[:10], before)
    # new elements are singletons
    np.testing.assert_array_equal(after[10:], np.arange(10, 20))
    # growth is idempotent / monotone
    uf.grow(5)
    assert len(uf) == 20
    # unions across the old/new boundary work
    uf.union(3, 15)
    assert uf.find(15) == uf.find(0)
    assert uf.size[uf.find(0)] == 5


def test_grow_interleaved_with_union_star():
    """The streaming state's exact usage: star-unions on ``uf.parent``
    interleaved with growth, labels via compact_labels_from_parent."""
    uf = UnionFind(6)
    union_star(uf.parent, np.array([0, 2, 4]))
    uf.grow(12)
    union_star(uf.parent, np.array([4, 7, 11]))
    union_star(uf.parent, np.array([1, 3]))
    active = np.ones(12, dtype=bool)
    active[[5, 6, 8, 9, 10]] = False
    labels = compact_labels_from_parent(uf.parent.copy(), active)
    # {0,2,4,7,11} one cluster, {1,3} another; inactive -1
    assert labels[0] == labels[2] == labels[4] == labels[7] == labels[11]
    assert labels[1] == labels[3] != labels[0]
    assert set(labels[[5, 6, 8, 9, 10]]) == {-1}
    # find() agrees with the vectorized multi-find after external edits
    roots = find_roots_vec(uf.parent, np.arange(12))
    assert roots[7] == uf.find(0)


def test_union_by_size_and_find_bounds():
    uf = UnionFind(4)
    uf.union(0, 1)   # size 2 at root 0
    uf.union(2, 0)   # smaller (2) attaches under larger root
    assert uf.find(2) == uf.find(0)
    assert uf.size[uf.find(0)] == 3
    with pytest.raises(IndexError):
        uf.find(99)
