"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp ref.py oracles (the spec's kernel acceptance gate)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import sample_uniform_sphere

# ---------------------------------------------------------------------------
# range_count
# ---------------------------------------------------------------------------
from repro.kernels.range_count.ops import range_count, range_count_bitmap
from repro.kernels.range_count.ref import range_count_bitmap_ref, range_count_ref


@pytest.mark.parametrize("nq,nd,d", [(64, 128, 32), (100, 300, 64), (33, 1025, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("eps", [0.3, 0.7, 1.2])
def test_range_count_sweep(nq, nd, d, dtype, eps):
    rng = np.random.default_rng(nq + nd)
    q = jnp.asarray(sample_uniform_sphere(rng, nq, d), dtype)
    db = jnp.asarray(sample_uniform_sphere(rng, nd, d), dtype)
    got = np.asarray(range_count(q, db, eps, q_tile=32, db_tile=64))
    ref = np.asarray(range_count_ref(q, db, eps))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("nq,nd", [(40, 96), (64, 257)])
def test_range_count_bitmap_sweep(nq, nd):
    rng = np.random.default_rng(7)
    q = jnp.asarray(sample_uniform_sphere(rng, nq, 48))
    db = jnp.asarray(sample_uniform_sphere(rng, nd, 48))
    gc, gb = range_count_bitmap(q, db, 0.6, q_tile=32, db_tile=64)
    rc, rb = range_count_bitmap_ref(q, db, 0.6)
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(rb))


def test_range_count_agrees_with_core_engine():
    """Kernel vs the jnp engine used by the clustering core."""
    from repro.core.range_query import range_counts

    rng = np.random.default_rng(11)
    db = jnp.asarray(sample_uniform_sphere(rng, 500, 32))
    got = np.asarray(range_count(db, db, 0.4, q_tile=64, db_tile=128))
    ref = np.asarray(range_counts(db, db, 0.4))
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# rmi_mlp
# ---------------------------------------------------------------------------
from repro.core.cardinality.rmi import RMIConfig, init_mlp, init_rmi, mlp_apply
from repro.kernels.rmi_mlp.ops import rmi_mlp_forward, rmi_stage_forward


@pytest.mark.parametrize("d_in", [9, 65, 201, 257, 769])
@pytest.mark.parametrize("batch", [1, 100, 256, 300])
def test_rmi_mlp_sweep(d_in, batch):
    params = init_mlp(jax.random.PRNGKey(d_in), d_in, (512, 512, 256, 128))
    x = jax.random.normal(jax.random.PRNGKey(batch), (batch, d_in))
    got = np.asarray(rmi_mlp_forward(params, x, batch_tile=128))
    ref = np.asarray(mlp_apply(params, x))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_rmi_mlp_bf16_weights():
    params = init_mlp(jax.random.PRNGKey(0), 33, (512, 512, 256, 128), dtype=jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 33), jnp.bfloat16)
    got = np.asarray(rmi_mlp_forward(params, x, batch_tile=64))
    ref = np.asarray(mlp_apply([(w.astype(jnp.float32), b.astype(jnp.float32)) for w, b in params],
                               x.astype(jnp.float32)))
    np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2)


def test_rmi_stage_forward_matches_vmap():
    cfg = RMIConfig(input_dim=17)
    rmi = init_rmi(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (96, 17))
    got = np.asarray(rmi_stage_forward(rmi["stage2"], x, batch_tile=32))
    ref = np.asarray(jax.vmap(lambda p: mlp_apply(p, x))(rmi["stage2"]))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# label_prop
# ---------------------------------------------------------------------------
from repro.core.range_query import pack_bitmap
from repro.core.union_find import connected_components_host, label_propagation
from repro.kernels.label_prop.ops import label_prop_round, label_propagation_pallas
from repro.kernels.label_prop.ref import label_prop_round_ref


@pytest.mark.parametrize("n,p", [(100, 0.05), (300, 0.01), (515, 0.004)])
def test_label_prop_round_sweep(n, p):
    rng = np.random.default_rng(n)
    adj = rng.random((n, n)) < p
    adj = adj | adj.T
    np.fill_diagonal(adj, True)
    bitmap = jnp.asarray(pack_bitmap(adj))
    labels = jnp.asarray(rng.permutation(n).astype(np.int32))
    got = np.asarray(label_prop_round(labels, bitmap, row_tile=64, word_tile=4))
    ref = np.asarray(label_prop_round_ref(labels, bitmap, np.iinfo(np.int32).max))
    np.testing.assert_array_equal(got, ref)


def test_label_prop_full_cc_matches_host():
    rng = np.random.default_rng(5)
    n = 400
    adj = rng.random((n, n)) < 0.008
    adj = adj | adj.T
    np.fill_diagonal(adj, True)
    active = rng.random(n) < 0.8
    adj = adj & active[:, None] & active[None, :]
    bitmap = jnp.asarray(pack_bitmap(adj))
    got = np.asarray(label_propagation_pallas(bitmap, jnp.asarray(active), row_tile=64, word_tile=8))
    host = connected_components_host(n, zip(*np.nonzero(np.triu(adj))), active)
    from repro.core.metrics import adjusted_rand_index

    assert adjusted_rand_index(got[active], host[active]) == 1.0
    jnp_lp = np.asarray(label_propagation(bitmap, jnp.asarray(active)))
    np.testing.assert_array_equal(got, jnp_lp)


def test_label_prop_chain_graph():
    """Worst-case diameter: a path graph must still converge (pointer jumping)."""
    n = 257
    adj = np.zeros((n, n), bool)
    idx = np.arange(n - 1)
    adj[idx, idx + 1] = True
    adj = adj | adj.T
    bitmap = jnp.asarray(pack_bitmap(adj))
    got = np.asarray(
        label_propagation_pallas(bitmap, jnp.ones(n, bool), row_tile=64, word_tile=4)
    )
    assert (got == 0).all()


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref


@pytest.mark.parametrize("v,d,b,l", [(100, 8, 16, 4), (1000, 16, 37, 9), (5000, 64, 24, 39)])
@pytest.mark.parametrize("combiner", ["sum", "mean"])
def test_embedding_bag_sweep(v, d, b, l, combiner):
    rng = np.random.default_rng(v + b)
    table = jnp.asarray(rng.standard_normal((v, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, v, size=(b, l)).astype(np.int32))
    got = np.asarray(embedding_bag(table, ids, combiner=combiner, batch_tile=8))
    ref = np.asarray(embedding_bag_ref(table, ids, combiner=combiner))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_embedding_bag_all_padding_row():
    table = jnp.ones((10, 4), jnp.float32)
    ids = jnp.full((3, 5), -1, jnp.int32)
    got = np.asarray(embedding_bag(table, ids, batch_tile=1))
    np.testing.assert_allclose(got, 0.0)


def test_embedding_bag_bf16_table():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((50, 8)), jnp.bfloat16)
    ids = jnp.asarray(rng.integers(0, 50, size=(8, 3)).astype(np.int32))
    got = np.asarray(embedding_bag(table, ids, batch_tile=4))
    ref = np.asarray(embedding_bag_ref(table.astype(jnp.float32), ids))
    np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@pytest.mark.parametrize("s,d,causal", [(128, 32, False), (128, 64, True), (256, 64, True)])
def test_flash_attention_sweep(s, d, causal):
    keys = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q, k, v = (jax.random.normal(kk, (2, 2, s, d)) for kk in keys)
    got = np.asarray(flash_attention(q, k, v, causal=causal, q_block=64, kv_block=64))
    ref = np.asarray(attention_ref(q, k, v, causal=causal))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_flash_attention_gqa_and_window():
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(keys[0], (1, 8, 128, 32))
    k = jax.random.normal(keys[1], (1, 2, 128, 32))
    v = jax.random.normal(keys[2], (1, 2, 128, 32))
    got = np.asarray(
        flash_attention(q, k, v, causal=True, window=32, q_block=32, kv_block=32)
    )
    kr, vr = jnp.repeat(k, 4, axis=1), jnp.repeat(v, 4, axis=1)
    ref = np.asarray(attention_ref(q, kr, vr, causal=True, window=32))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_shape():
    """sq=1 against a long KV (the serve_step shape)."""
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(keys[0], (2, 4, 1, 64))
    k = jax.random.normal(keys[1], (2, 4, 512, 64))
    v = jax.random.normal(keys[2], (2, 4, 512, 64))
    got = np.asarray(flash_attention(q, k, v, causal=True, q_block=1, kv_block=128))
    ref = np.asarray(attention_ref(q, k, v, causal=True))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (jax.random.normal(kk, (1, 2, 128, 64), jnp.bfloat16) for kk in keys)
    got = np.asarray(flash_attention(q, k, v, causal=True, q_block=64, kv_block=64), np.float32)
    ref = np.asarray(attention_ref(q, k, v, causal=True), np.float32)
    np.testing.assert_allclose(got, ref, rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# hamming_filter (dual-threshold band kernel; interpret=True pinned)
# ---------------------------------------------------------------------------
from repro.index.signatures import hamming_band, make_projection, sign_signatures
from repro.kernels.hamming_filter.ops import hamming_filter_bitmap, hamming_filter_count
from repro.kernels.hamming_filter.ref import (
    hamming_filter_bitmap_ref,
    hamming_filter_count_ref,
)


def _sig_case(nq, nd, d, n_bits, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(sample_uniform_sphere(rng, nq, d))
    db = jnp.asarray(sample_uniform_sphere(rng, nd, d))
    proj = make_projection(d, n_bits, seed=seed + 1)
    q_sig = jnp.asarray(sign_signatures(np.asarray(q), proj))
    db_sig = jnp.asarray(sign_signatures(np.asarray(db), proj))
    return q, db, q_sig, db_sig


@pytest.mark.parametrize("nq,nd,d,n_bits", [(64, 128, 32, 64), (100, 300, 64, 96), (33, 257, 48, 32)])
@pytest.mark.parametrize("eps", [0.3, 0.7, 1.2])
@pytest.mark.parametrize("mode", ["full", "band"])
def test_hamming_filter_count_sweep(nq, nd, d, n_bits, eps, mode):
    q, db, q_sig, db_sig = _sig_case(nq, nd, d, n_bits, seed=nq + nd)
    t_lo, t_hi = hamming_band(eps, n_bits, margin=3.0)
    if mode == "full":
        t_lo = -1
    got = np.asarray(
        hamming_filter_count(
            q, db, q_sig, db_sig, eps, t_hi, t_lo=t_lo,
            q_tile=32, db_tile=64, interpret=True,
        )
    )
    ref = np.asarray(hamming_filter_count_ref(q, db, q_sig, db_sig, eps, t_lo, t_hi))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("nq,nd", [(40, 96), (64, 257)])
@pytest.mark.parametrize("mode", ["full", "band"])
def test_hamming_filter_bitmap_sweep(nq, nd, mode):
    q, db, q_sig, db_sig = _sig_case(nq, nd, 48, 64, seed=7)
    t_lo, t_hi = hamming_band(0.6, 64, margin=3.0)
    if mode == "full":
        t_lo = -1
    gc, gb = hamming_filter_bitmap(
        q, db, q_sig, db_sig, 0.6, t_hi, t_lo=t_lo,
        q_tile=32, db_tile=64, interpret=True,
    )
    rc, rb = hamming_filter_bitmap_ref(q, db, q_sig, db_sig, 0.6, t_lo, t_hi)
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(rb))


@pytest.mark.parametrize("mode", ["full", "band"])
def test_hamming_filter_stats_match_hamming_occupancy(mode):
    """return_stats=True: the (1, 3) [accept, band, reject] occupancy
    must agree with the host Hamming occupancy of the padded tile grid,
    sum to the grid's pair count, and leave counts/bitmap unchanged."""
    from repro.index.signatures import hamming_numpy

    nq, nd, q_tile, db_tile = 40, 200, 32, 64
    q, db, q_sig, db_sig = _sig_case(nq, nd, 32, 64, seed=23)
    t_lo, t_hi = hamming_band(0.6, 64, margin=3.0)
    if mode == "full":
        t_lo = -1
    plain = np.asarray(
        hamming_filter_count(
            q, db, q_sig, db_sig, 0.6, t_hi, t_lo=t_lo,
            q_tile=q_tile, db_tile=db_tile, interpret=True,
        )
    )
    gc, stats = hamming_filter_count(
        q, db, q_sig, db_sig, 0.6, t_hi, t_lo=t_lo,
        q_tile=q_tile, db_tile=db_tile, interpret=True, return_stats=True,
    )
    gc2, gb, stats2 = hamming_filter_bitmap(
        q, db, q_sig, db_sig, 0.6, t_hi, t_lo=t_lo,
        q_tile=q_tile, db_tile=db_tile, interpret=True, return_stats=True,
    )
    np.testing.assert_array_equal(np.asarray(gc), plain)
    np.testing.assert_array_equal(np.asarray(gc2), plain)
    stats, stats2 = np.asarray(stats), np.asarray(stats2)
    np.testing.assert_array_equal(stats, stats2)
    nqt, ndt = -(-nq // q_tile), -(-nd // db_tile)
    assert stats.shape == (1, 3)
    assert stats.sum() == nqt * q_tile * ndt * db_tile

    # host occupancy on the same zero-padded tile grid
    qs = np.zeros((nqt * q_tile, q_sig.shape[1]), np.uint32)
    qs[:nq] = np.asarray(q_sig)
    ds = np.zeros((ndt * db_tile, db_sig.shape[1]), np.uint32)
    ds[:nd] = np.asarray(db_sig)
    ham = hamming_numpy(qs, ds)
    accept = ham <= t_lo
    band = (ham <= t_hi) & ~accept
    assert stats[0, 0] == accept.sum()
    assert stats[0, 1] == band.sum()


def test_hamming_filter_open_threshold_equals_range_count():
    """t_hi = n_bits (full verify) disables the filter: the fused kernel
    must reproduce the plain range_count oracle exactly."""
    q, db, q_sig, db_sig = _sig_case(48, 200, 32, 64, seed=11)
    for eps in (0.4, 0.8):
        got = np.asarray(
            hamming_filter_count(
                q, db, q_sig, db_sig, eps, 64, q_tile=32, db_tile=64, interpret=True
            )
        )
        ref = np.asarray(range_count_ref(q, db, eps))
        np.testing.assert_array_equal(got, ref)


def test_hamming_filter_closed_threshold_prunes_everything():
    """t_hi = -1 prunes every pair: the zero-candidate branch must skip
    the verify matmul in every tile and still write zero counts."""
    q, db, q_sig, db_sig = _sig_case(32, 64, 32, 64, seed=13)
    got = np.asarray(
        hamming_filter_count(
            q, db, q_sig, db_sig, 0.5, -1, q_tile=32, db_tile=64, interpret=True
        )
    )
    np.testing.assert_array_equal(got, np.zeros(32, np.int32))
    gc, gb = hamming_filter_bitmap(
        q, db, q_sig, db_sig, 0.5, -1, q_tile=32, db_tile=64, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(gc), np.zeros(32, np.int32))
    np.testing.assert_array_equal(np.asarray(gb), np.zeros((32, 2), np.uint32))


def test_hamming_filter_all_sure_accept_skips_matmul():
    """t_lo = n_bits sure-accepts every pair: no tile has band
    candidates, so no verify matmul runs, yet every pair must be a hit
    (counts = nd) regardless of eps."""
    nq, nd, n_bits = 32, 100, 64  # nd not a multiple of db_tile
    q, db, q_sig, db_sig = _sig_case(nq, nd, 32, n_bits, seed=17)
    for eps in (0.3, 1.2):
        got = np.asarray(
            hamming_filter_count(
                q, db, q_sig, db_sig, eps, n_bits, t_lo=n_bits,
                q_tile=32, db_tile=64, interpret=True,
            )
        )
        np.testing.assert_array_equal(got, np.full(nq, nd, np.int32))
        gc, gb = hamming_filter_bitmap(
            q, db, q_sig, db_sig, eps, n_bits, t_lo=n_bits,
            q_tile=32, db_tile=64, interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(gc), np.full(nq, nd, np.int32))
        rc, rb = hamming_filter_bitmap_ref(q, db, q_sig, db_sig, eps, n_bits, n_bits)
        np.testing.assert_array_equal(np.asarray(gb), np.asarray(rb))


def test_hamming_filter_padded_row_sure_accept_correction():
    """Zero-padded db rows can pass the *Hamming* side of the band even
    at eps < 1 (their distance to query i is popcount(q_sig_i)); the
    dual-threshold pad correction must subtract those sure-accepts."""
    nq, nd, n_bits = 32, 70, 64  # pads 70 -> 128 db rows
    q, db, q_sig, db_sig = _sig_case(nq, nd, 32, n_bits, seed=19)
    # t_lo = n_bits: every padded row would sure-accept uncorrected
    got = np.asarray(
        hamming_filter_count(
            q, db, q_sig, db_sig, 0.5, n_bits, t_lo=n_bits,
            q_tile=32, db_tile=64, interpret=True,
        )
    )
    np.testing.assert_array_equal(got, np.full(nq, nd, np.int32))
