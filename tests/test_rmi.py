import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cardinality.features import (
    DEFAULT_EPS_GRID,
    build_training_set,
    featurize,
    multi_eps_counts,
)
from repro.core.cardinality.rmi import (
    RMIConfig,
    init_mlp,
    init_rmi,
    mlp_apply,
    rmi_predict,
    rmi_predict_counts,
    rmi_route,
)
from repro.core.cardinality.training import train_rmi
from repro.core.range_query import range_counts
from repro.data.synthetic import make_angular_clusters, train_test_split


def test_featurize_shape():
    q = np.ones((5, 8), np.float32)
    f = np.asarray(featurize(q, 0.3))
    assert f.shape == (5, 9)
    np.testing.assert_allclose(f[:, -1], 0.3)


def test_multi_eps_counts_match_single():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((40, 8)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    grid = (0.2, 0.5, 0.8)
    multi = np.asarray(multi_eps_counts(x, x, grid, block_size=16))
    for ei, e in enumerate(grid):
        single = np.asarray(range_counts(x, x, e, block_size=16))
        np.testing.assert_array_equal(multi[ei], single)


def test_build_training_set_targets():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((30, 6)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    feats, targets = build_training_set(x, (0.3, 0.6))
    assert feats.shape == (60, 7)
    assert targets.shape == (60,)
    # targets are log2(1+count); invert and check one entry exactly
    counts = np.asarray(range_counts(x, x, 0.3))
    np.testing.assert_allclose(2.0 ** targets[:30] - 1.0, counts, rtol=1e-5)


def test_mlp_paper_architecture():
    """Paper: 4 hidden layers, widths 512, 512, 256, 128."""
    params = init_mlp(jax.random.PRNGKey(0), 65, (512, 512, 256, 128))
    assert [w.shape for w, _ in params] == [
        (65, 512), (512, 512), (512, 256), (256, 128), (128, 1),
    ]
    out = mlp_apply(params, jnp.ones((3, 65)))
    assert out.shape == (3,)


def test_rmi_stage_structure():
    """Paper: 3 stages with 1, 2, 4 nets."""
    cfg = RMIConfig(input_dim=9)
    params = init_rmi(jax.random.PRNGKey(0), cfg)
    assert set(params) == {"stage0", "stage1", "stage2"}
    # stacked expert axes
    assert params["stage1"][0][0].shape[0] == 2
    assert params["stage2"][0][0].shape[0] == 4


def test_rmi_route_bounds():
    pred = jnp.array([-5.0, 0.0, 7.9, 8.0, 100.0])
    idx = np.asarray(rmi_route(pred, 4, 16.0))
    assert idx.min() >= 0 and idx.max() <= 3
    np.testing.assert_array_equal(idx, [0, 0, 1, 2, 3])


def test_rmi_predict_shapes():
    cfg = RMIConfig(input_dim=9)
    params = init_rmi(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((12, 9))
    z = rmi_predict(params, x, cfg)
    c = rmi_predict_counts(params, x, cfg)
    assert z.shape == (12,) and c.shape == (12,)
    assert (np.asarray(c) >= 0).all()


@pytest.mark.slow
def test_trained_estimator_learns(small_clustered):
    """Short training run: the estimator must clearly beat a constant
    predictor on its learned function (counts w.r.t. the train split —
    the paper's per-dataset α absorbs the train/test scale gap)."""
    data, _ = small_clustered
    train, test = train_test_split(data, 0.8, seed=0)
    est = train_rmi(train, epochs=8, batch_size=256, eps_grid=(0.15, 0.25, 0.35, 0.5))
    eps, tau = 0.25, 5
    pred = est.predict_counts(test, eps)
    # ground truth for unseen queries, against the db the estimator learned
    true = np.asarray(range_counts(test, train, eps)).astype(np.float64)
    z_pred = np.log2(1 + pred)
    z_true = np.log2(1 + true)
    resid = float(np.mean((z_pred - z_true) ** 2))
    const = float(np.var(z_true))
    assert resid < 0.5 * const, f"estimator MSE {resid} vs constant {const}"
    # classification quality at the paper's decision rule (scale-matched)
    scale = len(train) / len(test)
    true_test = np.asarray(range_counts(test, test, eps)).astype(np.float64)
    pred_core = pred >= scale * tau
    true_core = true_test >= tau
    acc = float(np.mean(pred_core == true_core))
    assert acc > 0.8, f"core classification accuracy {acc}"


def test_calibrated_prediction(small_clustered):
    """predict_counts(reference_n=...) rescales to the target dataset size."""
    data, _ = small_clustered
    train, test = train_test_split(data, 0.8, seed=0)
    from repro.core.cardinality.rmi import RMIConfig, init_rmi

    cfg = RMIConfig(input_dim=train.shape[1] + 1)
    # untrained params: just verify the scaling plumbing
    from repro.core.cardinality.training import TrainedEstimator

    est = TrainedEstimator(init_rmi(jax.random.PRNGKey(0), cfg), cfg)
    est.train_n = len(train)
    a = est.predict_counts(test[:8], 0.25)
    b = est.predict_counts(test[:8], 0.25, reference_n=len(test))
    np.testing.assert_allclose(b, a * len(test) / len(train), rtol=1e-5)
