"""Corpus OK twin: the donated argument aliases a same-shape/dtype
output — lowering carries one tf.aliasing_output per donated slot.

Imported and executed by the corpus runner via build().
"""
import jax
import jax.numpy as jnp


def _accumulate(buf, x):
    return buf + x  # same (128,) f32 shape: donation survives


def build():
    f = jax.jit(_accumulate, donate_argnums=(0,))
    args = (
        jax.ShapeDtypeStruct((128,), jnp.float32),
        jax.ShapeDtypeStruct((128,), jnp.float32),
    )
    lowered = f.lower(*args)
    return {"lowered_text": lowered.as_text(), "n_donated": 1}
