"""Corpus OK twin: sizes bucket to the next power of two (floor 64) —
the signature lattice is logarithmic in n_max.

Imported (pure python) by the corpus runner: signatures(n) / bound(n_max).
"""
import math

N_MAX = 512


def signatures(n):
    return ("sweep", max(64, 1 << (n - 1).bit_length()))


def bound(n_max):
    # buckets: 64, 128, ..., next_pow2(n_max)
    return int(math.log2(max(n_max, 64) // 64)) + 2
