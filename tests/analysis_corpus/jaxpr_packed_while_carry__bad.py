"""Corpus BAD: the packed slab rides the label-propagation while carry
— the uint32 buffer is rebuilt (re-masked) every round instead of
staying a loop-invariant operand of the round loop.

Imported and executed by the corpus runner via build().
"""
import jax
import jax.numpy as jnp


def build():
    def run(slab, labels):
        def cond(state):
            _, lab, it = state
            return it < 4

        def body(state):
            bm, lab, it = state
            counts = jnp.sum(jax.lax.population_count(bm), axis=1)
            bm = bm & jnp.uint32(0xFFFFFFFE)  # per-round slab rewrite
            return bm, jnp.minimum(lab, counts.astype(jnp.int32)), it + 1

        _, lab, _ = jax.lax.while_loop(cond, body, (slab, labels, jnp.int32(0)))
        return lab

    return {
        "jaxpr": jax.make_jaxpr(run)(
            jnp.zeros((8, 4), jnp.uint32), jnp.zeros((8,), jnp.int32)
        )
    }
