"""Corpus BAD: the donated argument matches no output shape/dtype, so
XLA silently drops the aliasing — no tf.aliasing_output in the module.

Imported and executed by the corpus runner via build().
"""
import warnings

import jax
import jax.numpy as jnp


def _consume(buf, x):
    # output is a scalar: nothing for the (128,) f32 donation to alias
    return (x * 2.0).sum()


def build():
    f = jax.jit(_consume, donate_argnums=(0,))
    args = (
        jax.ShapeDtypeStruct((128,), jnp.float32),
        jax.ShapeDtypeStruct((128,), jnp.float32),
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        lowered = f.lower(*args)
    return {"lowered_text": lowered.as_text(), "n_donated": 1}
