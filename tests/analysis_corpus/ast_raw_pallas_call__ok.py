"""Corpus OK twin: the wrapper goes through the kernel module's public
entry point instead of launching pallas itself.

Linted only — never imported or executed (imports need not resolve).
"""
from repro.kernels.hamming_filter import kernel


def sweep_tile(q, db, *, q_tile=128, db_tile=256):
    return kernel.hamming_filter_count(q, db, q_tile=q_tile, db_tile=db_tile)
