"""Corpus BAD: a buffer is read after being passed in a donated slot of
a module-level donating launch — use-after-donate.

Linted only — never imported or executed.
"""
import jax


def _launch_impl(out, x):
    return out + x


launch = jax.jit(_launch_impl, donate_argnums=(0,))


def driver(buf, xs):
    total = 0.0
    for x in xs:
        res = launch(buf, x)  # donates buf...
        total = total + buf.sum()  # ...then reads the deleted buffer
    return total, res
