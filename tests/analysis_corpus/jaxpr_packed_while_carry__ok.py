"""Corpus OK twin: the slab is masked once up front and closed over by
the while body (a loop-invariant const); only s32 labels ride the
carry.

Imported and executed by the corpus runner via build().
"""
import jax
import jax.numpy as jnp


def build():
    def run(slab, labels):
        bm = slab & jnp.uint32(0xFFFFFFFE)  # masked once, outside the loop
        counts = jnp.sum(jax.lax.population_count(bm), axis=1).astype(jnp.int32)

        def cond(state):
            _, it = state
            return it < 4

        def body(state):
            lab, it = state
            return jnp.minimum(lab, counts), it + 1

        lab, _ = jax.lax.while_loop(cond, body, (labels, jnp.int32(0)))
        return lab

    return {
        "jaxpr": jax.make_jaxpr(run)(
            jnp.zeros((8, 4), jnp.uint32), jnp.zeros((8,), jnp.int32)
        )
    }
