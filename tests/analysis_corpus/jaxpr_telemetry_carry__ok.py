"""Corpus OK twin: the telemetry carry contract done right — the label
vector (1-D, well under the size ceiling) plus per-round s32 *scalar*
counters accumulated into a small (max_iters,) vector.

Imported and executed by the corpus runner via build().
"""
import jax
import jax.numpy as jnp


def build():
    def run(labels, tele):
        def cond(state):
            _, _, it = state
            return it < 4

        def body(state):
            lab, tl, it = state
            new = jnp.minimum(lab, jnp.roll(lab, 1))
            changed = jnp.sum(new != lab, dtype=jnp.int32)
            tl = jax.lax.dynamic_update_slice(tl, changed[None], (it,))
            return new, tl, it + 1

        lab, tl, _ = jax.lax.while_loop(
            cond, body, (labels, tele, jnp.int32(0))
        )
        return lab, tl

    return {
        "jaxpr": jax.make_jaxpr(run)(
            jnp.zeros((2048,), jnp.int32), jnp.zeros((64,), jnp.int32)
        )
    }
