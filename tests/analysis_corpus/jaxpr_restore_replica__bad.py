"""Corpus BAD: state_import trims the buffers to the exact row count —
the restored replica's first sweep sees a fresh operand shape and pays
an engine recompile that the pre-crash process never compiled.

Imported (pure python) by the corpus runner: build() returns the
compile signatures observed before the crash and after the restore.
A compile signature here is what the jit cache keys the launch on:
(capacity rows, signature words, db_tile) — the query-side shapes are
identical in both runs, so only the database operands matter.
"""

DB_TILE = 64
WORDS = 2  # 64-bit signatures -> 2 uint32 words


def _capacity(n):
    # amortized doubling: fit(256) then partial_fit to n=400 -> 512
    cap = 256
    while cap < n:
        cap *= 2
    return cap


def build():
    n = 400
    cap = _capacity(n)  # 512: what the pre-crash process compiled for
    pre = [("sweep", cap, WORDS, DB_TILE)]
    # the buggy restore: np.ascontiguousarray(state["buf"][:n]) — drops
    # the append slack, so the post-restore operand is n-shaped
    restored_rows = n
    post = [("sweep", restored_rows, WORDS, DB_TILE)]
    return {"pre_signatures": pre, "post_signatures": post}
