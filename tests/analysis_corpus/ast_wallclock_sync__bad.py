"""Corpus BAD: wall-clock pair brackets async JAX dispatch with no sync —
the elapsed time measures dispatch, not execution.

Linted only — never imported or executed (names need not resolve).
"""
import time


def bench_dispatch_only(q, q_sig, db, db_sig, eps):
    t0 = time.perf_counter()
    counts = sweep_counts(q, q_sig, db, db_sig, len(db), eps, -1, 10)
    elapsed = time.perf_counter() - t0
    return counts, elapsed
