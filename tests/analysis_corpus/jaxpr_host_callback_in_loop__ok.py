"""Corpus OK twin: the same callback, hoisted — it fires once per
launch, after the scan accumulates on device.

Imported and executed by the corpus runner via build().
"""
import jax
import jax.numpy as jnp


def build():
    def step(carry, x):
        return carry + x, carry

    def run(xs):
        total, hist = jax.lax.scan(step, jnp.float32(0.0), xs)
        jax.debug.callback(lambda v: None, total)  # once, outside the loop
        return total, hist

    return {"jaxpr": jax.make_jaxpr(run)(jnp.zeros((8,), jnp.float32))}
