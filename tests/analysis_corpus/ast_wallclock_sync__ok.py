"""Corpus OK twin: the same bracket, made honest two ways — an explicit
block_until_ready, and an obs span with force=.

Linted only — never imported or executed (names need not resolve).
"""
import time

import jax


def bench_synced(q, q_sig, db, db_sig, eps):
    t0 = time.perf_counter()
    counts = sweep_counts(q, q_sig, db, db_sig, len(db), eps, -1, 10)
    jax.block_until_ready(counts)
    elapsed = time.perf_counter() - t0
    return counts, elapsed


def bench_spanned(q, q_sig, db, db_sig, eps):
    t0 = time.perf_counter()
    with span("sweep", sync=True):
        counts = sweep_counts(q, q_sig, db, db_sig, len(db), eps, -1, 10)
    elapsed = time.perf_counter() - t0
    return counts, elapsed
