"""Corpus BAD: raw pl.pallas_call in a wrapper module (this file is not
kernels/*/kernel.py, so the launch belongs behind the kernel package).

Linted only — never imported or executed.
"""
import jax
from jax.experimental import pallas as pl


def hamming_tile(q_ref, db_ref, out_ref):
    out_ref[...] = q_ref[...] @ db_ref[...]


def sweep_tile(q, db):
    return pl.pallas_call(
        hamming_tile,
        out_shape=jax.ShapeDtypeStruct((q.shape[0], db.shape[0]), q.dtype),
    )(q, db)
