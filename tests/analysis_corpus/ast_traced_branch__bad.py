"""Corpus BAD: python control flow on traced values inside jitted code.

Linted only — never imported or executed.
"""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("flip",))
def score(x, flip):
    if x.sum() > 0:  # traced predicate: runs at trace time, not per call
        return jnp.where(flip, -x, x)
    return x


@jax.jit
def guard(v):
    assert v.min() >= 0  # asserts on the tracer, not runtime data
    return jnp.sqrt(v)
