"""Corpus BAD: a 2-D per-round telemetry matrix rides the fixpoint's
while carry — an O(rounds x n) buffer rebuilt every iteration where the
carry contract allows only scalars and small 1-D vectors.

Imported and executed by the corpus runner via build().
"""
import jax
import jax.numpy as jnp


def build():
    def run(labels, per_point):
        def cond(state):
            _, _, it = state
            return it < 4

        def body(state):
            lab, tele, it = state
            new = jnp.minimum(lab, jnp.roll(lab, 1))
            # per-round *per-point* deltas: a (rounds, n) matrix in the
            # carry — slab-sized state riding the round loop
            tele = jax.lax.dynamic_update_slice(
                tele, (new != lab).astype(jnp.int32)[None, :], (it, 0)
            )
            return new, tele, it + 1

        lab, tele, _ = jax.lax.while_loop(
            cond, body, (labels, per_point, jnp.int32(0))
        )
        return lab, tele

    return {
        "jaxpr": jax.make_jaxpr(run)(
            jnp.zeros((256,), jnp.int32), jnp.zeros((8, 256), jnp.int32)
        )
    }
