"""Corpus BAD: a host callback inside a scan body — one host round-trip
per iteration serializes the device pipeline.

Imported and executed by the corpus runner via build().
"""
import jax
import jax.numpy as jnp


def build():
    def step(carry, x):
        jax.debug.callback(lambda v: None, carry)  # host hop per chunk
        return carry + x, carry

    def run(xs):
        return jax.lax.scan(step, jnp.float32(0.0), xs)

    return {"jaxpr": jax.make_jaxpr(run)(jnp.zeros((8,), jnp.float32))}
