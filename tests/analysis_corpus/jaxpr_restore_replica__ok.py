"""Corpus OK twin: state_import restores the full capacity buffers
(append slack included), so the restored replica's sweep operands are
bit-for-bit the pre-crash shapes — the first post-recovery query hits
the existing executable cache and compiles nothing.
"""

DB_TILE = 64
WORDS = 2


def _capacity(n):
    cap = 256
    while cap < n:
        cap *= 2
    return cap


def build():
    n = 400
    cap = _capacity(n)
    pre = [("sweep", cap, WORDS, DB_TILE)]
    # capacity-faithful restore: the exported buffer keeps its full
    # capacity shape, so the post-restore signature is identical
    post = [("sweep", cap, WORDS, DB_TILE)]
    return {"pre_signatures": pre, "post_signatures": post}
