"""Corpus OK ops module: tile defaults come from the kernel module —
no literal to drift out of sync."""

from .kernel import DEFAULT_DB_TILE, DEFAULT_Q_TILE


def sweep(q, db, *, q_tile=DEFAULT_Q_TILE, db_tile=DEFAULT_DB_TILE):
    return q, db, q_tile, db_tile
