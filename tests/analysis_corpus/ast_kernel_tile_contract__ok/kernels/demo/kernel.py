"""Corpus OK kernel module: defaults satisfy the kernel's asserts and
ops.py defers to these constants instead of redefining them."""

DEFAULT_Q_TILE = 128
DEFAULT_DB_TILE = 256


def hamming_kernel(q, db, *, q_tile=DEFAULT_Q_TILE, db_tile=DEFAULT_DB_TILE):
    assert q_tile % 8 == 0
    assert db_tile % 32 == 0
    return q, db
