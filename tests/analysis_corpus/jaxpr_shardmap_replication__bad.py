"""Corpus BAD: shard_map declares a replicated output (out_specs=P())
but never reduces over the mesh axis — shard-local partial sums
masquerade as a replicated value (correct on 1 device, wrong on N).

Imported and executed by the corpus runner via build().
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def build():
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))

    def local_sum(x):
        return jnp.sum(x)  # no psum over "data"

    f = shard_map(
        local_sum, mesh=mesh, in_specs=(P("data"),), out_specs=P(),
        check_rep=False,
    )
    return {"jaxpr": jax.make_jaxpr(f)(jnp.zeros((8,), jnp.float32))}
