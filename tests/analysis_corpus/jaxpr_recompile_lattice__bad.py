"""Corpus BAD: the compile signature embeds the raw input size — one
recompile per distinct n, unbounded by any lattice.

Imported (pure python) by the corpus runner: signatures(n) / bound(n_max).
"""
import math

N_MAX = 512


def signatures(n):
    return ("sweep", n)  # raw n: 512 distinct signatures over [1, 512]


def bound(n_max):
    return int(math.log2(n_max)) + 2
