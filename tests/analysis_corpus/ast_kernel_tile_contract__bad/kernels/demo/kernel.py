"""Corpus BAD kernel module: DEFAULT_DB_TILE breaks the kernel's own
divisibility assert, and ops.py (sibling) contradicts the constants."""

DEFAULT_Q_TILE = 128
DEFAULT_DB_TILE = 200  # not a multiple of 32: violates the assert below


def hamming_kernel(q, db, *, q_tile=DEFAULT_Q_TILE, db_tile=DEFAULT_DB_TILE):
    assert q_tile % 8 == 0
    assert db_tile % 32 == 0
    return q, db
