"""Corpus BAD ops module: redefines the kernel's tile constant with a
different value and ships a mismatched literal default."""

DEFAULT_DB_TILE = 256  # kernel.py says 200 — padding math and grid disagree


def sweep(q, db, *, db_tile=64):
    return q, db, db_tile
