"""Corpus OK twin: the donating call's result rebinds the donated name —
the dead reference is replaced before any read.

Linted only — never imported or executed.
"""
import jax


def _launch_impl(out, x):
    return out + x


launch = jax.jit(_launch_impl, donate_argnums=(0,))


def driver(buf, xs):
    for x in xs:
        buf = launch(buf, x)  # rebind: donated ref never read again
    return buf.sum()
