"""Corpus OK twin: the shard-local sum is psum'd over the mesh axis
before being declared replicated.

Imported and executed by the corpus runner via build().
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def build():
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))

    def global_sum(x):
        return jax.lax.psum(jnp.sum(x), "data")

    f = shard_map(
        global_sum, mesh=mesh, in_specs=(P("data"),), out_specs=P(),
        check_rep=False,
    )
    return {"jaxpr": jax.make_jaxpr(f)(jnp.zeros((8,), jnp.float32))}
