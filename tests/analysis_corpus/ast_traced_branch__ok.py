"""Corpus OK twin: every branch predicate is genuinely static —
static_argnames, shape/dtype metadata, len(), `is None`.

Linted only — never imported or executed.
"""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("normalize",))
def score(x, normalize, scale=None):
    if normalize:  # static argument
        x = x / jnp.sqrt(jnp.sum(x * x))
    if scale is not None:  # python-object identity test
        x = x * 2.0
    if x.shape[0] > 1:  # shape metadata is static under trace
        x = x[:1]
    assert x.ndim == 1
    n = len(x)
    if n > 4:
        x = x * 0.5
    return x
