import numpy as np
import pytest

from repro.core.dbscan import dbscan_parallel, dbscan_sequential
from repro.core.metrics import adjusted_rand_index
from repro.core.range_query import range_counts
from repro.data.synthetic import make_angular_clusters


@pytest.mark.parametrize("eps,tau", [(0.2, 3), (0.25, 5), (0.3, 8)])
def test_parallel_matches_sequential(small_clustered, eps, tau):
    data, _ = small_clustered
    seq = dbscan_sequential(data, eps, tau)
    par = dbscan_parallel(data, eps, tau)
    np.testing.assert_array_equal(seq.core, par.core)
    # identical partitions up to border ties -> ARI must be ~1
    assert adjusted_rand_index(seq.labels, par.labels) > 0.999
    # cluster count identical (core structure is order-invariant)
    assert seq.n_clusters == par.n_clusters
    # noise set: parallel may only differ on border ties, never on cores
    assert np.array_equal(seq.labels == -1, par.labels == -1)


def test_core_definition(small_clustered):
    data, _ = small_clustered
    eps, tau = 0.25, 5
    res = dbscan_parallel(data, eps, tau)
    counts = np.asarray(range_counts(data, data, eps))
    np.testing.assert_array_equal(res.core, counts >= tau)


def test_cores_never_noise(small_clustered):
    data, _ = small_clustered
    res = dbscan_parallel(data, 0.25, 5)
    assert (res.labels[res.core] >= 0).all()


def test_border_points_have_core_neighbor(small_clustered):
    data, _ = small_clustered
    eps = 0.25
    res = dbscan_parallel(data, eps, 5)
    border = (res.labels >= 0) & ~res.core
    idx = np.nonzero(border)[0]
    core_idx = np.nonzero(res.core)[0]
    dots = data[idx] @ data[core_idx].T
    hit = dots > 1 - eps
    assert hit.any(axis=1).all()
    # and the assigned cluster is one of the neighboring cores' clusters
    for k, i in enumerate(idx):
        neigh_clusters = set(res.labels[core_idx[hit[k]]])
        assert res.labels[i] in neigh_clusters


def test_same_cluster_core_connectivity(tiny_clustered):
    """Any two cores within eps share a cluster (maximality/connectivity)."""
    data, _ = tiny_clustered
    eps = 0.25
    res = dbscan_parallel(data, eps, 5)
    core_idx = np.nonzero(res.core)[0]
    dots = data[core_idx] @ data[core_idx].T
    close = dots > 1 - eps
    li = res.labels[core_idx]
    same = li[:, None] == li[None, :]
    assert (same | ~close).all()


def test_recovers_true_clusters(small_clustered):
    data, truth = small_clustered
    res = dbscan_parallel(data, 0.25, 5)
    assert adjusted_rand_index(res.labels, truth) > 0.9


def test_all_noise_when_eps_tiny(tiny_clustered):
    data, _ = tiny_clustered
    res = dbscan_parallel(data, 1e-6, 5)
    assert res.n_clusters == 0
    assert (res.labels == -1).all()


def test_one_cluster_when_eps_huge(tiny_clustered):
    data, _ = tiny_clustered
    res = dbscan_parallel(data, 1.99, 3)
    assert res.n_clusters == 1
    assert (res.labels == 0).all()
