"""Optimizer, checkpointing, compression, fault tolerance, data pipeline."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (
    AsyncCheckpointer,
    gc_checkpoints,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.compression import (
    compressed_wire_bytes,
    init_residuals,
    int8_codec,
    topk_codec,
)
from repro.train.fault_tolerance import GuardedStep, StragglerPolicy, plan_elastic_remesh
from repro.train.optimizer import (
    adam,
    adamw,
    adamw_update_params,
    apply_updates,
    chain_clip,
    clip_by_global_norm,
    global_norm,
    sgd,
)
from repro.train.schedule import warmup_cosine, warmup_linear


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def quad_loss(p):
    return jnp.sum(jnp.square(p["w"] - 3.0)) + jnp.sum(jnp.square(p["b"] + 1.0))


@pytest.mark.parametrize("make_opt", [lambda: sgd(0.1, 0.9), lambda: adam(0.1), lambda: adamw(0.1, weight_decay=0.0)])
def test_optimizers_converge(make_opt):
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    opt = make_opt()
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(quad_loss)(params)
        updates, state = opt.update(g, state, params)
        params = apply_updates(params, updates)
    assert float(quad_loss(params)) < 1e-2


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.ones((8,)) * 10}
    opt = adamw(lr=0.1, weight_decay=0.5)
    state = opt.init(params)
    g = {"w": jnp.zeros((8,))}
    updates, state = opt.update(g, state, params)
    params = apply_updates(params, updates)
    assert float(params["w"][0]) < 10.0


def test_adamw_bf16_state_roundtrip():
    params = {"w": jnp.ones((16,), jnp.bfloat16)}
    opt = adamw(0.01, state_dtype=jnp.bfloat16)
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((16,), jnp.bfloat16)}
    updates, state = opt.update(g, state, params)
    assert jnp.isfinite(updates["w"]).all()


def test_adamw_update_params_matches_standard():
    params = {"w": jnp.ones((4, 8)) * 2.0}
    grads = {"w": jnp.ones((4, 8)) * 0.3}
    opt = adamw(0.05)
    state = opt.init(params)
    updates, state2 = opt.update(grads, state, params)
    expect = apply_updates(params, updates)
    got, state3 = adamw_update_params(
        params, grads, opt.init(params), lr=0.05
    )
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(expect["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(state3["m"]["w"]), np.asarray(state2["m"]["w"]), rtol=1e-6)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90.0))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)
    lin = warmup_linear(1.0, 10, 110)
    assert float(lin(jnp.asarray(60))) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def tree_example():
    return {
        "layer": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
        "step": np.asarray(7, np.int32),
        "nested": [np.ones((2,), np.float32), np.zeros((5,), np.int8)],
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = tree_example()
    save_checkpoint(tmp_path, 3, tree)
    restored, step = restore_checkpoint(tmp_path, template=tree)
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_latest_and_gc(tmp_path):
    tree = tree_example()
    for s in (1, 5, 9, 12):
        save_checkpoint(tmp_path, s, tree)
    assert latest_step(tmp_path) == 12
    deleted = gc_checkpoints(tmp_path, keep=2)
    assert len(deleted) == 2
    assert latest_step(tmp_path) == 12
    restored, step = restore_checkpoint(tmp_path, template=tree)
    assert step == 12


def test_checkpoint_atomicity(tmp_path):
    """A partial .tmp directory must be invisible to readers and GC'd."""
    tree = tree_example()
    save_checkpoint(tmp_path, 1, tree)
    # simulate a crashed writer
    crash = tmp_path / "step_000000000002.tmp"
    crash.mkdir()
    (crash / "shard_000000.npz").write_bytes(b"partial")
    assert latest_step(tmp_path) == 1
    gc_checkpoints(tmp_path, keep=3)
    assert not crash.exists()


def test_async_checkpointer(tmp_path):
    tree = tree_example()
    ck = AsyncCheckpointer(tmp_path, keep=2)
    for s in (0, 1, 2):
        ck.save(s, tree)
    ck.wait()
    assert latest_step(tmp_path) == 2


def test_elastic_restore_to_new_sharding(tmp_path):
    """Restore lays out arrays for the target sharding (reshard path)."""
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": np.arange(8, dtype=np.float32)}
    save_checkpoint(tmp_path, 0, tree)
    sh = {"w": NamedSharding(mesh, P())}
    restored, _ = restore_checkpoint(tmp_path, template=tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_int8_codec_error_feedback_converges():
    """With error feedback, repeated compression of a constant gradient
    transmits the full value over time (residual -> 0 bias)."""
    codec = int8_codec()
    g = jnp.asarray(np.random.default_rng(0).standard_normal(256).astype(np.float32))
    residual = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(30):
        payload, residual = codec.compress(g, residual)
        total = total + codec.decompress(payload)
    np.testing.assert_allclose(np.asarray(total / 30), np.asarray(g), atol=1e-2)


def test_int8_codec_wire_bytes():
    codec = int8_codec()
    g = jnp.ones((1024,))
    payload, _ = codec.compress(g, jnp.zeros_like(g))
    assert codec.wire_bytes(payload) == 1024 + 4  # 4x smaller than fp32


def test_topk_codec_sparsity_and_feedback():
    codec = topk_codec(frac=0.1)
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((20, 10)).astype(np.float32))
    residual = jnp.zeros_like(g)
    payload, residual = codec.compress(g, residual)
    dense = codec.decompress(payload)
    assert int((np.asarray(dense) != 0).sum()) == 20  # 10% of 200
    # error feedback: residual holds exactly what was not sent
    np.testing.assert_allclose(
        np.asarray(dense + residual), np.asarray(g), atol=1e-6
    )


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_guarded_step_retries_then_succeeds():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("simulated preemption")
        return x + 1

    g = GuardedStep(flaky, max_retries=3)
    res = g(41)
    assert res.value == 42
    assert res.attempts == 3
    assert len(g.failures) == 2


def test_guarded_step_escalates_to_restore():
    state = {"restored": False}
    calls = {"n": 0}

    def always_fails_until_restore(x):
        calls["n"] += 1
        if not state["restored"]:
            raise RuntimeError("hard failure")
        return x

    def restore():
        state["restored"] = True

    g = GuardedStep(always_fails_until_restore, max_retries=1, on_restore=restore)
    res = g(7)
    assert res.value == 7
    assert res.recovered


def test_straggler_policy_flags_slow_steps():
    p = StragglerPolicy(tolerance=2.0, eject_after=2)
    for _ in range(5):
        v = p.observe(1.0)
        assert not v["slow"]
    v = p.observe(5.0)
    assert v["slow"] and not v["recommend_eject"]
    v = p.observe(5.0)
    assert v["recommend_eject"]


def test_elastic_remesh_plans():
    (d, m), plan = plan_elastic_remesh(512)
    assert (d, m) == (32, 16)
    (d, m), plan = plan_elastic_remesh(480)  # lost 2 hosts of 8 chips
    assert (d, m) == (30, 16)
    assert plan["devices_idle"] == 0
    (d, m), _ = plan_elastic_remesh(12, prefer_model=16)
    assert m <= 8 and d * m <= 12


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_lm_batches_deterministic():
    from repro.data.pipeline import lm_batches

    mk = lm_batches(0, 8, 16, 1000)
    a, b = mk(5), mk(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = mk(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_batches_host_sharding():
    from repro.data.pipeline import lm_batches

    mk0 = lm_batches(0, 8, 16, 1000, host_shard=0, n_host_shards=2)
    mk1 = lm_batches(0, 8, 16, 1000, host_shard=1, n_host_shards=2)
    a, b = mk0(0), mk1(0)
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_prefetcher_orders_batches():
    from repro.data.pipeline import Prefetcher

    pf = Prefetcher(lambda i: i * i, depth=2)
    got = [next(pf) for _ in range(4)]
    pf.close()
    assert got == [(0, 0), (1, 1), (2, 4), (3, 9)]


# ---------------------------------------------------------------------------
# graph sampler
# ---------------------------------------------------------------------------


def test_csr_and_fanout_sampler():
    from repro.data.graph_sampler import build_csr, sample_fanout

    rng = np.random.default_rng(0)
    n, e = 200, 2000
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    feats = rng.standard_normal((n, 7)).astype(np.float32)
    g = build_csr(src, dst, n)
    assert g.indptr[-1] == e
    # CSR correctness: neighbors of node v are exactly sources of edges into v
    v = int(dst[0])
    neigh = set(g.indices[g.indptr[v] : g.indptr[v + 1]].tolist())
    assert neigh == set(src[dst == v].tolist())

    seeds = rng.choice(n, 16, replace=False)
    block = sample_fanout(g, seeds, (5, 3), feats, rng)
    assert block["n_seeds"] == 16
    assert block["feats"].shape == (16 + 16 * 5 + 16 * 5 * 3, 7)
    assert block["src"].shape == block["dst"].shape == block["edge_mask"].shape
    # every edge's dst position is a valid block position
    assert block["dst"].max() < len(block["node_ids"])
    # sampled edges are real graph edges (where valid)
    ids = block["node_ids"]
    for s_pos, d_pos, ok in list(zip(block["src"], block["dst"], block["edge_mask"]))[:50]:
        if ok:
            s_id, d_id = ids[s_pos], ids[d_pos]
            assert np.any((src == s_id) & (dst == d_id))


def test_trainer_loop_smoke(tmp_path):
    """End-to-end tiny loop with checkpoint + resume."""
    from repro.train.trainer import TrainLoopConfig, train_loop
    from repro.train.optimizer import adam, apply_updates

    opt = adam(0.3)  # adam moves ~lr per step: 30 steps covers the gap to 2.0
    params = {"w": jnp.zeros(())}
    state = opt.init(params)

    def step(params, opt_state, batch):
        g = jax.grad(lambda p: jnp.square(p["w"] - batch))(params)
        updates, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, updates), opt_state, {"loss": jnp.square(params["w"] - batch)}

    cfg = TrainLoopConfig(total_steps=60, ckpt_dir=str(tmp_path), ckpt_every=20, log_every=100)
    out = train_loop(cfg, step, params, state, make_batch=lambda i: 2.0, log=lambda s: None)
    assert abs(float(out["params"]["w"]) - 2.0) < 0.2
    # resume from checkpoint
    out2 = train_loop(
        TrainLoopConfig(total_steps=64, ckpt_dir=str(tmp_path), ckpt_every=20, log_every=100),
        step, params, state, make_batch=lambda i: 2.0, log=lambda s: None,
    )
    assert len(out2["history"]) <= 5  # resumed near step 59
