import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.data.synthetic import make_angular_clusters

_FORCED_PRELUDE = """
import json, sys
sys.path.insert(0, "src")
"""


@pytest.fixture(scope="session")
def forced_device_run():
    """Run a python snippet under ``--xla_force_host_platform_device_count=N``.

    The device count is locked at first jax initialization, so the flag
    cannot be set inside the (already jax-initialized) test process —
    the subprocess-safe way is a fresh interpreter whose environment
    carries the flag *before* any jax import (existing XLA_FLAGS are
    appended, not clobbered).  The snippet reports results by printing
    ``RESULT:`` + a json object; the fixture returns the parsed dict.
    """

    def run(code: str, n_devices: int = 4, timeout: int = 480) -> dict:
        script = _FORCED_PRELUDE + textwrap.dedent(code)
        env = dict(os.environ)
        # drop any inherited force-count (e.g. CI's 4-device tier-1 run)
        # so the requested count wins, keep every other inherited flag
        inherited = [
            f for f in env.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")
        ]
        env["XLA_FLAGS"] = " ".join(
            [f"--xla_force_host_platform_device_count={n_devices}"] + inherited
        )
        proc = subprocess.run(
            [sys.executable, "-c", script],
            capture_output=True, text=True, timeout=timeout, cwd=".", env=env,
        )
        assert proc.returncode == 0, proc.stderr[-4000:]
        lines = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
        assert lines, f"snippet printed no RESULT line:\n{proc.stdout[-2000:]}"
        return json.loads(lines[-1][len("RESULT:"):])

    return run


@pytest.fixture(scope="session")
def small_clustered():
    """2k points, 32-d, 12 vMF clusters + 30% noise (seeded)."""
    data, truth = make_angular_clusters(2000, 32, 12, kappa=80, noise_frac=0.3, seed=1)
    return data, truth


@pytest.fixture(scope="session")
def tiny_clustered():
    data, truth = make_angular_clusters(400, 16, 5, kappa=60, noise_frac=0.25, seed=3)
    return data, truth
