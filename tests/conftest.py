import numpy as np
import pytest

from repro.data.synthetic import make_angular_clusters


@pytest.fixture(scope="session")
def small_clustered():
    """2k points, 32-d, 12 vMF clusters + 30% noise (seeded)."""
    data, truth = make_angular_clusters(2000, 32, 12, kappa=80, noise_frac=0.3, seed=1)
    return data, truth


@pytest.fixture(scope="session")
def tiny_clustered():
    data, truth = make_angular_clusters(400, 16, 5, kappa=60, noise_frac=0.25, seed=3)
    return data, truth
