"""repro.analysis (laf-lint): corpus detection, live-tree cleanliness,
baseline round-trip, and the CLI/parser seams.

The expensive jaxpr/HLO passes over the full standard-target set run in
the CI gate (``python -m repro.analysis``); here we keep tier-1 fast by
exercising the pure-AST checks over the live tree, the full golden
corpus, and one real lowered target.
"""

import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (
    CHECKS,
    Finding,
    load_all_checks,
    load_baseline,
    run_checks,
    save_baseline,
    split_suppressed,
)
from repro.analysis.corpus import run_corpus
from repro.launch.hlo_analysis import _TRIP_RE, collectives_by_computation

REPO_ROOT = Path(__file__).resolve().parents[1]
CORPUS = REPO_ROOT / "tests" / "analysis_corpus"

# the pure-AST checks: linting only, no tracing/compiling — safe to run
# over the whole live tree inside tier-1
_AST_ONLY = {
    "ast-traced-branch",
    "ast-wallclock-sync",
    "ast-raw-pallas-call",
    "ast-kernel-tile-contract",
    "jaxpr-donation-reuse",
}


def test_registry_loads_fifteen_checks():
    load_all_checks()
    assert len(CHECKS) == 15
    codes = sorted(s.code for s in CHECKS.values())
    assert codes == [
        "LAF101", "LAF102", "LAF103", "LAF104", "LAF105", "LAF106",
        "LAF107", "LAF108",
        "LAF201", "LAF202", "LAF203",
        "LAF301", "LAF302", "LAF303", "LAF304",
    ]


def test_list_checks_is_jax_free():
    # the CLI inventory path must not initialize jax (editor/pre-commit
    # latency); prove it in a fresh interpreter
    code = (
        "import sys\n"
        "from repro.analysis import load_all_checks, CHECKS\n"
        "load_all_checks()\n"
        "assert len(CHECKS) == 15\n"
        "assert 'jax' not in sys.modules, 'listing checks imported jax'\n"
        "print('JAXFREE-OK')\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=120,
        cwd=REPO_ROOT, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "JAXFREE-OK" in proc.stdout


def test_corpus_every_check_detects():
    res = run_corpus(CORPUS)
    assert res.ok, "corpus failures:\n" + "\n".join(
        f"  {entry}: {why}" for entry, why in res.failed
    )
    # one bad + one ok twin per registered check
    assert len(res.passed) == 2 * len(CHECKS)


def test_live_tree_ast_checks_clean():
    from repro.analysis.targets import Context

    ctx = Context.for_repo(REPO_ROOT, dynamic=False)
    findings = run_checks(ctx, only=_AST_ONLY)
    rules = load_baseline(REPO_ROOT / "src" / "repro" / "analysis" / "baseline.toml")
    open_findings, _ = split_suppressed(findings, rules)
    assert not open_findings, "\n".join(f.location() + ": " + f.message for f in open_findings)


def test_baseline_roundtrip(tmp_path):
    findings = [
        Finding("ast-traced-branch", "src/repro/foo.py", 10, "branch on tracer"),
        Finding("hlo-bitmap-collective", "<target:sweep>", 3, "u32 on the wire"),
    ]
    path = tmp_path / "baseline.toml"
    save_baseline(findings, path)
    rules = load_baseline(path)
    open_findings, suppressed = split_suppressed(findings, rules)
    assert not open_findings
    assert len(suppressed) == len(findings)
    # an unrelated finding stays open
    other = Finding("ast-wallclock-sync", "src/repro/bar.py", 1, "unsynced")
    open2, sup2 = split_suppressed([other], rules)
    assert open2 == [other] and not sup2
    # missing baseline file means no suppressions, not an error
    assert load_baseline(tmp_path / "absent.toml") == []


def test_trip_count_regex_variants():
    escaped = 'backend_config={"a":"{\\"known_trip_count\\":{\\"n\\":\\"7\\"}}"}'
    unescaped = 'backend_config={"known_trip_count":{"n":"12"}}'
    plain = "known_trip_count={n=3}"
    for text, expect in ((escaped, "7"), (unescaped, "12"), (plain, "3")):
        m = _TRIP_RE.search(text)
        assert m and m.group(1) == expect, text


def test_collectives_by_computation_marks_loop_bodies():
    hlo = (CORPUS / "hlo_bitmap_collective__bad.txt").read_text()
    comps = collectives_by_computation(hlo)
    body = comps["body"]
    assert body.is_loop_body and body.trip_count == 7
    assert [(c.op, c.element_type) for c in body.collectives] == [
        ("all-reduce", "u32")
    ]
    assert comps["main"].is_entry and not comps["main"].is_loop_body


def test_hlo_check_exempts_out_of_loop_gather():
    # the ok fixture carries a u32 all-gather in ENTRY (the sanctioned
    # end-of-launch out_specs gather) — it must NOT trip LAF201
    from repro.analysis.hlo_checks import check_hlo_text

    hlo = (CORPUS / "hlo_bitmap_collective__ok.txt").read_text()
    comps = collectives_by_computation(hlo)
    assert any(
        c.element_type == "u32"
        for comp in comps.values() if not comp.is_loop_body
        for c in comp.collectives
    ), "fixture lost its out-of-loop u32 gather"
    findings = check_hlo_text(hlo, "<fixture>")
    assert not [f for f in findings if f.check == "hlo-bitmap-collective"]


def test_dryrun_hook_surfaces_findings():
    from repro.launch.dryrun import _analysis_findings

    bad = (CORPUS / "hlo_loop_collective_allowlist__bad.txt").read_text()
    recs = _analysis_findings(bad, "arch__shape")
    assert recs and all(isinstance(r, dict) and "check" in r for r in recs)
    assert any(r["check"] == "hlo-loop-collective-allowlist" for r in recs)
    ok = (CORPUS / "hlo_loop_collective_allowlist__ok.txt").read_text()
    assert _analysis_findings(ok, "arch__shape") == []


def test_flake8_plugin_yields_laf_codes():
    import ast as ast_mod

    from repro.analysis.ast_lint import LafLintPlugin

    bad = CORPUS / "ast_traced_branch__bad.py"
    tree = ast_mod.parse(bad.read_text())
    hits = list(LafLintPlugin(tree, str(bad)).run())
    assert hits and all(msg.startswith("LAF3") for _, _, msg, _ in hits)
    assert any(msg.startswith("LAF301") for _, _, msg, _ in hits)


@pytest.mark.slow
def test_serve_assign_target_donation_survives():
    # one real lowered target end-to-end (the smallest): donation
    # aliasing must survive lowering and its HLO must pass the
    # loop-collective contract
    from repro.analysis.hlo_checks import check_hlo_text
    from repro.analysis.jaxpr_checks import check_donation_text
    from repro.analysis.targets import Targets

    t = Targets().get("serve_assign")
    # counts + bitmap + telemetry slabs (the target pins telemetry=True)
    assert t.n_donated == 3
    assert check_donation_text(t.lowered_text, t.n_donated, t.label) == []
    assert check_hlo_text(t.hlo, t.label, byte_budget=t.byte_budget) == []
