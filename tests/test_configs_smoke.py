"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED same-family config and runs one forward/train
step on CPU, asserting output shapes and no NaNs.  The FULL configs are
exercised only through the dry-run (ShapeDtypeStruct, no allocation) —
here we check the full configs' analytic metadata instead."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch, list_archs

ASSIGNED = [
    "llama3-8b", "granite-20b", "gemma3-27b", "deepseek-v2-236b", "grok-1-314b",
    "gat-cora", "dien", "autoint", "deepfm", "bst",
]


def test_registry_contains_all_assigned():
    archs = list_archs()
    for a in ASSIGNED:
        assert a in archs
    assert "laf_dbscan" in archs  # the paper's own workload


@pytest.mark.parametrize("name", ASSIGNED)
def test_full_config_matches_assignment(name):
    arch = get_arch(name)
    cfg = arch.make_config()
    expect = {
        "llama3-8b": dict(n_layers=32, d_model=4096, n_heads=32, kv_heads=8,
                          d_ff=14336, vocab=128256),
        "granite-20b": dict(n_layers=52, d_model=6144, n_heads=48, kv_heads=1,
                            d_ff=24576, vocab=49152),
        "gemma3-27b": dict(n_layers=62, d_model=5376, n_heads=32, kv_heads=16,
                           d_ff=21504, vocab=262144, global_every=6),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128, vocab=102400),
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48, kv_heads=8,
                            vocab=131072),
        "gat-cora": dict(d_hidden=8, n_heads=8, n_layers=2),
        "dien": dict(embed_dim=18, seq_len=100, gru_dim=108),
        "autoint": dict(embed_dim=16, n_attn_layers=3, n_heads=2, d_attn=32),
        "deepfm": dict(embed_dim=10, mlp_dims=(400, 400, 400)),
        "bst": dict(embed_dim=32, seq_len=20, n_blocks=1, n_heads=8,
                    mlp_dims=(1024, 512, 256)),
    }[name]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)
    # MoE specifics
    if name == "deepseek-v2-236b":
        assert cfg.moe.n_experts == 160 and cfg.moe.top_k == 6 and cfg.moe.n_shared == 2
        assert cfg.mla.kv_lora_rank == 512
        # ~236B params
        assert 2.0e11 < cfg.param_count() < 2.7e11
    if name == "grok-1-314b":
        assert cfg.moe.n_experts == 8 and cfg.moe.top_k == 2
        assert 2.8e11 < cfg.param_count() < 3.5e11
    if name == "llama3-8b":
        assert 7.5e9 < cfg.param_count() < 8.7e9
    if name == "deepfm":
        assert len(cfg.vocab_sizes) == 39
    if name == "autoint":
        assert len(cfg.vocab_sizes) == 39


@pytest.mark.parametrize("name", ["llama3-8b", "granite-20b", "gemma3-27b",
                                  "deepseek-v2-236b", "grok-1-314b"])
def test_lm_reduced_smoke(name):
    from repro.models.transformer import (
        transformer_forward, transformer_init, transformer_loss,
    )

    cfg = get_arch(name).make_reduced_config()
    params = transformer_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits = transformer_forward(params, cfg, toks)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), name
    # one train step: grads exist and are finite
    g = jax.grad(lambda p: transformer_loss(p, cfg, toks, toks))(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), name


def test_gat_reduced_smoke():
    from repro.data.synthetic import powerlaw_graph
    from repro.models.gnn import gat_forward, gat_init, gat_loss

    cfg = get_arch("gat-cora").make_reduced_config()
    rng = np.random.default_rng(0)
    g = powerlaw_graph(rng, 60, 240, cfg.d_in)
    p = gat_init(jax.random.PRNGKey(0), cfg)
    labels = jnp.asarray(g["labels"]) % cfg.n_classes
    logits = gat_forward(p, cfg, jnp.asarray(g["feats"]), jnp.asarray(g["src"]), jnp.asarray(g["dst"]))
    assert logits.shape == (60, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())
    grads = jax.grad(gat_loss)(p, cfg, jnp.asarray(g["feats"]),
                               jnp.asarray(g["src"]), jnp.asarray(g["dst"]), labels)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("name", ["deepfm", "autoint", "dien", "bst"])
def test_recsys_reduced_smoke(name):
    from repro.models import recsys as R

    arch = get_arch(name)
    cfg = arch.make_reduced_config()
    rng = np.random.default_rng(0)
    if name in ("deepfm", "autoint"):
        ids = jnp.asarray(
            np.stack([rng.integers(0, v, 8) for v in cfg.vocab_sizes], axis=1).astype(np.int32)
        )
        if name == "deepfm":
            p = R.deepfm_init(jax.random.PRNGKey(0), cfg)
            fwd = lambda pp: R.deepfm_forward(pp, cfg, ids)
        else:
            p = R.autoint_init(jax.random.PRNGKey(0), cfg)
            fwd = lambda pp: R.autoint_forward(pp, cfg, ids)
    else:
        hist = jnp.asarray(rng.integers(0, cfg.item_vocab, (8, cfg.seq_len)).astype(np.int32))
        tgt = jnp.asarray(rng.integers(0, cfg.item_vocab, 8).astype(np.int32))
        if name == "dien":
            p = R.dien_init(jax.random.PRNGKey(0), cfg)
            fwd = lambda pp: R.dien_forward(pp, cfg, hist, tgt)
        else:
            p = R.bst_init(jax.random.PRNGKey(0), cfg)
            fwd = lambda pp: R.bst_forward(pp, cfg, hist, tgt)
    logits = fwd(p)
    assert logits.shape == (8,)
    assert bool(jnp.isfinite(logits).all())
    g = jax.grad(lambda pp: R.bce_loss(fwd(pp), jnp.ones(8)))(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.isfinite(leaf).all())


def test_laf_reduced_smoke():
    """The paper's workload config: one cluster step on reduced shapes."""
    from repro.configs.laf_dbscan import make_reduced_config
    from repro.core.range_query import range_counts
    from repro.data.synthetic import make_angular_clusters

    cfg = make_reduced_config()
    data, _ = make_angular_clusters(cfg.n_points, cfg.dim, 8, seed=0)
    counts = np.asarray(range_counts(data[: cfg.frontier], data, cfg.eps))
    assert counts.shape == (cfg.frontier,)
    assert (counts >= 1).all()


def test_skips_documented():
    for name in ("llama3-8b", "granite-20b", "deepseek-v2-236b", "grok-1-314b"):
        arch = get_arch(name)
        assert "long_500k" in arch.skips
        assert "full-attention" in arch.skips["long_500k"]
    # gemma3 hybrid runs long_500k
    assert "long_500k" not in get_arch("gemma3-27b").skips
    # 40 assigned cells accounted for: 36 runnable + 4 documented skips
    total = runnable = 0
    for name in ASSIGNED:
        arch = get_arch(name)
        total += len(arch.shapes)
        runnable += len(arch.runnable_shapes())
    assert total == 40
    assert runnable == 36
