"""Device-resident telemetry (repro.obs v2): per-round fused-loop
counters vs a bit-exact numpy oracle (single device and 4-forced-device
mesh, ragged n), sweep occupancy slab parity vs the per-chunk kernel
stats, synthetic per-round span round-trip through the Chrome trace,
the histogram zero-clamp, the SLO plane, and the bench-trajectory
drift gate."""

import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro import obs
from repro.core.range_query import pack_bitmap
from repro.kernels.label_prop import packed_cluster_labels
from repro.obs import device as obs_device
from repro.obs import metrics, slo

BIG = np.iinfo(np.int32).max
EPS = 0.45


@pytest.fixture(autouse=True)
def obs_sandbox():
    """Clean, fully-enabled obs state (trace + metrics + device
    telemetry) per test; ambient switches restored afterwards."""
    was_trace, was_metrics = obs.trace_enabled(), obs.metrics_enabled()
    was_device = obs_device.device_enabled()
    obs.enable(trace=True, metrics_on=True, telemetry=True)
    obs.clear_trace()
    metrics.reset()
    yield
    obs.clear_trace()
    metrics.reset()
    if was_trace or was_metrics:
        obs.enable(trace=was_trace, metrics_on=was_metrics)
    else:
        obs.disable()
    (obs_device.enable_device if was_device else obs_device.disable_device)()


# ---------------------------------------------------------------------------
# cluster fixpoint per-round counters vs a numpy replay of the loop body
# ---------------------------------------------------------------------------


def _ragged_adjacency(n: int, seed: int, density: float = 0.012):
    rng = np.random.default_rng(seed)
    hit = rng.random((n, n)) < density
    hit = hit | hit.T
    np.fill_diagonal(hit, True)
    return hit


def _oracle_rounds(hit, rows, tau, n, cap, max_iters=64):
    """Numpy replay of ``packed_cluster_fixpoint``'s loop body — the
    independent definition the device counters are held to.  Single
    "shard", so the gather-win marginal degenerates to the frontier."""
    rows = np.asarray(rows, np.int64)
    valid = rows < n
    counts = np.where(valid, hit.sum(axis=1), 0)
    core_r = valid & (counts >= tau)
    safe = np.minimum(rows, cap - 1)
    core_c = np.zeros(cap, bool)
    core_c[safe[core_r]] = True
    lab = np.where(core_c, np.arange(cap, dtype=np.int64), BIG)
    tele = {f: [] for f in obs_device.CLUSTER_ROUND_FIELDS}
    rounds, changed = 0, True
    while changed and rounds < max_iters:
        # gather: per row, min label over set bits (BIG when empty)
        masked = np.where(hit, lab[None, :n], BIG)
        m = masked.min(axis=1, initial=BIG)
        wins = int(np.sum(core_r & (m < lab[safe])))
        new_r = np.where(core_r, np.minimum(lab[safe], m), BIG)
        front = int(np.sum(core_r & (new_r < lab[safe])))
        new = lab.copy()
        np.minimum.at(new, safe, new_r)
        jump = np.where(new < cap, new, 0)
        jumped = np.where(new < cap, np.minimum(new, new[jump]), new)
        hops = int(np.sum(jumped < new))
        chg = int(np.sum(jumped != lab))
        tele["frontier"].append(front)
        tele["changed"].append(chg)
        tele["hops"].append(hops)
        tele["shard_wins"].append(wins)
        lab, changed = jumped, chg > 0
        rounds += 1
    return {"labels": lab, "rounds": rounds, **tele}


def test_cluster_round_counters_match_host_oracle():
    n, tau = 613, 6  # ragged vs both the word and row tiles
    hit = _ragged_adjacency(n, seed=9)
    rows = np.arange(n, dtype=np.int32)
    slab = jnp.asarray(pack_bitmap(hit))
    outs = packed_cluster_labels(
        slab, jnp.asarray(rows), tau, n=n, telemetry=True, interpret=True
    )
    assert len(outs) == 6
    rounds = int(outs[4])
    tele_dev = [np.asarray(v) for v in outs[5]]
    cap = slab.shape[1] * 32
    oracle = _oracle_rounds(hit, rows, tau, n, cap)
    assert rounds == oracle["rounds"] >= 2
    for vec, field in zip(tele_dev, obs_device.CLUSTER_ROUND_FIELDS):
        assert vec.dtype == np.int32
        np.testing.assert_array_equal(
            vec[:rounds], np.asarray(oracle[field]), err_msg=field
        )
        # slots past the fixpoint stay zero (the harvest trims on them)
        assert not vec[rounds:].any(), field
    # single shard: every gather win is a frontier row and vice versa
    assert oracle["shard_wins"] == oracle["frontier"]
    # telemetry is an observer: the label outputs are bit-identical to
    # the telemetry-off program
    base = packed_cluster_labels(
        slab, jnp.asarray(rows), tau, n=n, telemetry=False, interpret=True
    )
    assert len(base) == 5
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(base[0]))


def test_harvest_trims_and_accumulates_counters():
    n, tau = 257, 5
    hit = _ragged_adjacency(n, seed=3, density=0.03)
    rows = np.arange(n, dtype=np.int32)
    slab = jnp.asarray(pack_bitmap(hit))
    outs = packed_cluster_labels(
        slab, jnp.asarray(rows), tau, n=n, telemetry=True, interpret=True
    )
    rounds = int(outs[4])
    host = jax.device_get(outs[5])
    per_round = obs_device.harvest_cluster_telemetry(host, rounds)
    assert set(per_round) == set(obs_device.CLUSTER_ROUND_FIELDS)
    assert all(len(v) == rounds for v in per_round.values())
    snap = metrics.snapshot()
    for f, vals in per_round.items():
        assert snap[f"laf.telemetry.{f}"] == sum(vals)


@pytest.mark.slow
def test_mesh_shard_counters_match_single_device(forced_device_run):
    """4-device mesh, ragged n: the psum'd per-round vectors must be
    bit-identical to the single-device run for the replicated
    quantities (frontier/changed/hops track the *post*-pmin state), and
    the shard-win marginal must dominate the frontier while collapsing
    to it off-mesh."""
    out = forced_device_run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.range_query import pack_bitmap
    from repro.distributed.index_plane import sharded_cluster_labels
    from repro.kernels.label_prop import packed_cluster_labels

    rng = np.random.default_rng(9)
    n, tau = 613, 6
    hit = rng.random((n, n)) < 0.012
    hit = hit | hit.T
    np.fill_diagonal(hit, True)
    slab_np = pack_bitmap(hit)
    w = slab_np.shape[1]
    pad_w = (-w) % 4  # whole words per shard
    if pad_w:
        slab_np = np.pad(slab_np, ((0, 0), (0, pad_w)))
    # pad rows so the shard-local row tile divides the slab (sentinel
    # rows >= n are no-ops in the fixpoint)
    pad_r = (-n) % 128
    slab_np = np.pad(slab_np, ((0, pad_r), (0, 0)))
    rows = np.full(n + pad_r, n, np.int32)
    rows[:n] = np.arange(n)

    slab, rows_j = jnp.asarray(slab_np), jnp.asarray(rows)
    single = packed_cluster_labels(
        slab, rows_j, tau, n=n, telemetry=True, interpret=True)
    mesh = Mesh(np.asarray(jax.devices()), ("data",))
    shard = sharded_cluster_labels(
        slab, rows_j, tau, mesh=mesh, axes=("data",), n=n,
        telemetry=True, interpret=True)
    s_rounds, m_rounds = int(single[4]), int(shard[4])
    R = m_rounds
    s_t = [np.asarray(v) for v in single[5]]
    m_t = [np.asarray(v) for v in shard[5]]
    print("RESULT:" + __import__("json").dumps({
        "rounds_equal": s_rounds == m_rounds,
        "rounds": m_rounds,
        "labels_equal": bool(np.array_equal(
            np.asarray(single[0]), np.asarray(shard[0]))),
        "frontier_equal": bool(np.array_equal(s_t[0][:R], m_t[0][:R])),
        "changed_equal": bool(np.array_equal(s_t[1][:R], m_t[1][:R])),
        "hops_equal": bool(np.array_equal(s_t[2][:R], m_t[2][:R])),
        "wins_ge_frontier": bool((m_t[3][:R] >= m_t[0][:R]).all()),
        "single_wins_eq_frontier": bool(
            np.array_equal(s_t[3][:R], s_t[0][:R])),
    }))
    """)
    assert out["rounds_equal"] and out["rounds"] >= 2
    assert out["labels_equal"]
    assert out["frontier_equal"] and out["changed_equal"] and out["hops_equal"]
    assert out["wins_ge_frontier"]
    assert out["single_wins_eq_frontier"]


# ---------------------------------------------------------------------------
# sweep occupancy slab vs the per-chunk kernel stats
# ---------------------------------------------------------------------------


def test_sweep_telemetry_slab_matches_per_chunk_stats():
    """The one-launch engine's donated stats slab must hold, per chunk
    row, exactly the tile-summed occupancy the standalone per-chunk
    kernel reports for the same operands — including the zero-padded
    tail chunk — and telemetry must not move a single count."""
    from repro.data.synthetic import make_angular_clusters
    from repro.index import RandomProjectionBackend
    from repro.kernels.hamming_filter.ops import hamming_filter_count

    n, d = 150, 16  # ragged vs chunk=64: 3 live chunks, 1 pad chunk
    data, _ = make_angular_clusters(n, d, 4, kappa=60, noise_frac=0.2, seed=2)
    bk = RandomProjectionBackend(
        n_bits=64, seed=2, device=True, interpret=True, sweep=True,
        chunk=64, chunks_per_launch=2, q_tile=32, db_tile=128,
    ).fit(data)
    rows = np.arange(n)
    counts_on = np.asarray(bk.query_counts(rows, EPS))
    slab = obs_device.last_sweep_stats()
    assert slab is not None and slab.shape[1] == 3
    snap = metrics.snapshot()
    totals = slab.sum(axis=0)
    for i, f in enumerate(obs_device.SWEEP_STAT_FIELDS):
        assert snap[f"sweep.tele.{f}"] == totals[i]

    obs_device.disable_device()
    counts_off = np.asarray(bk.query_counts(rows, EPS))
    np.testing.assert_array_equal(counts_on, counts_off)

    # reference: run each (zero-padded) chunk through the per-chunk
    # kernel with stats and tile-sum — identical operands => identical
    # padded tile grids => identical triples
    t_lo, t_hi = bk.band(EPS)
    q, q_sig = bk._sweep_q(rows)
    db, dbs = bk._sweep_db()
    chunk, n_rows = 64, slab.shape[0] * 64
    qp = np.zeros((n_rows, q.shape[1]), np.float32)
    qsp = np.zeros((n_rows, q_sig.shape[1]), np.uint32)
    qp[:n], qsp[:n] = np.asarray(q), np.asarray(q_sig)
    for k in range(slab.shape[0]):
        sl = slice(k * chunk, (k + 1) * chunk)
        _, stats = hamming_filter_count(
            jnp.asarray(qp[sl]), db, jnp.asarray(qsp[sl]), dbs,
            EPS, t_hi, t_lo=t_lo, q_tile=32, db_tile=128,
            interpret=True, return_stats=True,
        )
        ref = np.asarray(obs_device.sweep_stats_tile_sum(stats))
        np.testing.assert_array_equal(slab[k], ref, err_msg=f"chunk {k}")


# ---------------------------------------------------------------------------
# synthetic per-round spans: emission + Chrome-trace round-trip
# ---------------------------------------------------------------------------


def test_synthetic_round_spans_roundtrip_chrome_trace(tmp_path):
    import time

    with obs.span("laf.label_prop", rows=8) as sp:
        time.sleep(0.01)
    parent = sp._rec
    per_round = {
        "frontier": [5, 3, 1], "changed": [6, 3, 0],
        "hops": [2, 1, 0], "shard_wins": [5, 3, 1],
    }
    recs = obs_device.emit_round_spans(parent, per_round)
    assert len(recs) == 3
    # equal subdivision of the parent interval, fully attributing it
    assert recs[0].t0 == parent.t0
    assert all(r.dur == pytest.approx(parent.dur / 3) for r in recs)
    assert recs[-1].t0 + recs[-1].dur == pytest.approx(parent.t0 + parent.dur)
    assert obs.coverage(parent) == pytest.approx(1.0)

    p = tmp_path / "trace.json"
    obs.export_chrome_trace(str(p))
    evs = json.loads(p.read_text())["traceEvents"]
    parent_ev = next(e for e in evs if e["name"] == "laf.label_prop")
    rounds = [e for e in evs if e["name"] == "laf.cluster.round"]
    assert len(rounds) == 3
    for i, e in enumerate(sorted(rounds, key=lambda e: e["ts"])):
        assert e["args"]["parent_id"] == parent_ev["args"]["span_id"]
        assert e["args"]["synthetic"] is True
        assert e["args"]["round"] == i
        assert e["args"]["frontier"] == per_round["frontier"][i]
        assert e["ts"] >= parent_ev["ts"]


def test_emit_round_spans_noops_safely():
    # no parent record (span taken while tracing was off), no rounds,
    # zero-duration parent: all decline without touching the buffer
    before = len(obs.spans())
    assert obs_device.emit_round_spans(None, {"frontier": [1]}) == []
    with obs.span("p") as sp:
        pass
    assert obs_device.emit_round_spans(sp._rec, {"frontier": []}) == []
    assert len(obs.spans()) == before + 1


# ---------------------------------------------------------------------------
# histogram zero/sub-resolution clamp
# ---------------------------------------------------------------------------


def test_histogram_clamps_zero_to_first_bound():
    h = metrics.histogram("tele.h", bounds=(1e-4, 1e-3, 1e-2))
    for v in (0.0, -0.0, 1e-9, 1e-4):  # all at or below the first bound
        h.observe(v)
    assert h.count == 4
    assert h._counts[0] == 4
    assert h._min == 1e-4  # raw zeros must not drag the interpolation
    assert h.quantile(0.5) == pytest.approx(1e-4)
    s = h.summary()
    assert s["min"] == 1e-4 and s["p50"] == pytest.approx(1e-4)
    h.observe(5e-3)  # above the clamp: normal bucketing unaffected
    assert h._counts[0] == 4 and h.count == 5
    assert h._max == 5e-3


# ---------------------------------------------------------------------------
# SLO plane
# ---------------------------------------------------------------------------


def test_slo_evaluate_registry_and_derived_values():
    rules = [
        slo.SLO("lat-p99", "t.lat:p99", "<=", 1.0),
        slo.SLO("runs-floor", "t.runs", ">=", 1.0),
        slo.SLO("derived-ari", "run.ari", ">=", 0.99),
    ]
    # no data anywhere: every rule is "no data", nothing is violated
    res = slo.evaluate(rules)
    assert all(r.ok is None and not r.violated for r in res)

    metrics.counter("t.runs").inc(3)
    h = metrics.histogram("t.lat")
    for _ in range(100):
        h.observe(0.01)
    res = slo.evaluate(rules, values={"run.ari": 0.995})
    by = {r.slo.name: r for r in res}
    assert by["lat-p99"].ok and by["runs-floor"].ok and by["derived-ari"].ok
    # a derived value takes precedence and can violate
    res = slo.evaluate(rules, values={"run.ari": 0.5})
    assert {r.slo.name: r.violated for r in res}["derived-ari"]


def test_slo_check_and_alert_counts_and_warns(caplog):
    import logging

    rules = [slo.SLO("always-bad", "x.val", "<=", 0.0)]
    metrics.counter("x.val").inc(5)
    with caplog.at_level(logging.WARNING, logger="repro.obs.slo"):
        res = slo.check_and_alert(rules, interval_s=0.0)
    assert res[0].violated
    snap = metrics.snapshot()
    assert snap["slo.evaluations"] == 1 and snap["slo.violations"] == 1
    text = "\n".join(r.getMessage() for r in caplog.records)
    assert "slo.violation" in text and "always-bad" in text


def test_slo_invalid_op_rejected():
    with pytest.raises(ValueError):
        slo.SLO("bad", "m", "!=", 1.0)


def test_default_slo_sets_cover_the_stack():
    for kind, rules in (
        ("serve", slo.SERVE_SLOS), ("ingest", slo.INGEST_SLOS),
        ("cluster", slo.CLUSTER_SLOS),
    ):
        assert rules, kind
        assert all(isinstance(r, slo.SLO) for r in rules)


# ---------------------------------------------------------------------------
# bench-trajectory drift gate
# ---------------------------------------------------------------------------


def _trajectory():
    import importlib.util
    from pathlib import Path

    path = (
        Path(__file__).resolve().parents[1] / "benchmarks" / "trajectory.py"
    )
    spec = importlib.util.spec_from_file_location("bench_trajectory", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_trajectory_update_then_gate_roundtrip(tmp_path):
    tj = _trajectory()
    hist = {"metrics": {}}
    payload = {"rows": [{"cluster_speedup": 2.0, "ari_one_launch_vs_host": 1.0}],
               "worst_ari": 0.999}
    improved = tj.update(payload, "lineage", hist, source="a.json")
    assert set(improved) == {
        "lineage:cluster_speedup", "lineage:ari_one_launch_vs_host",
        "lineage:worst_ari",
    }
    # same payload gates clean against its own history
    assert tj.gate(payload, "lineage", hist) == []
    # tight metric: a 30% ARI drop fails at the 20% tolerance
    bad = {"worst_ari": 0.69, "rows": []}
    fails = tj.gate(bad, "lineage", hist)
    assert len(fails) == 1 and "worst_ari" in fails[0]
    # noisy metric: a 50% wall-clock regression passes the 60% band,
    # an 80% one does not
    hist2 = {"metrics": {}}
    tj.update({"best_cluster_speedup": 10.0}, "l", hist2)
    assert tj.gate({"best_cluster_speedup": 5.0}, "l", hist2) == []
    assert tj.gate({"best_cluster_speedup": 2.0}, "l", hist2)
    # an unknown lineage never fails (first observation seeds it)
    assert tj.gate(payload, "other-lineage", hist) == []
    # round-trip through disk
    p = tmp_path / "hist.json"
    tj.save_history(hist, p)
    assert tj.load_history(p) == hist


def test_trajectory_checked_in_history_self_consistent():
    tj = _trajectory()
    hist = tj.load_history()
    assert hist["metrics"], "benchmarks/history/trajectory.json is empty"
    for key, ent in hist["metrics"].items():
        name = key.split(":", 1)[1]
        assert name in tj.METRICS, key
        direction, noisy = tj.METRICS[name]
        assert ent["direction"] == direction and ent["noisy"] == noisy
        assert ent["best"] is not None and ent["history"]
        best = ent["best"]
        vals = [h["value"] for h in ent["history"]]
        assert best == (max(vals) if direction == "higher" else min(vals))
