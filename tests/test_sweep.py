"""The device-resident sweep engine (``repro.index.sweep``).

Bit-exact parity of the one-launch sweep against the retained
per-chunk paths (the host numpy oracle and the legacy per-chunk device
dispatch loop), across the shapes that exercise every padding layer:
non-chunk-multiple row counts (launch-tail padding), eps > 1 (zero pad
rows passing the dot test), capacity-padded post-``partial_fit``
operands (append slack), and the 4-device forced-host mesh (the
double-buffered sharded plane, both pipeline depths).
"""

import numpy as np
import pytest

from repro.core.range_query import unpack_bitmap
from repro.data.synthetic import make_angular_clusters
from repro.index import RandomProjectionBackend, suggest_margin
from repro.index.sweep import plan_sweep

EPS = 0.55


@pytest.fixture(scope="module")
def sweep_data():
    # 613: not a multiple of the chunk, the kernel tiles, or 32 — every
    # query sweeps through launch-tail, tile, and bitmap-word padding
    data, _ = make_angular_clusters(613, 32, 8, kappa=120, noise_frac=0.3, seed=2)
    return data


CFG = dict(n_bits=64, margin=3.0, seed=3, chunk=64, q_tile=32, db_tile=64)


def _host(data):
    return RandomProjectionBackend(device=False, **CFG).fit(data)


def _engine(data, **kw):
    cfg = dict(CFG, device=True, interpret=True, sweep=True)
    cfg.update(kw)
    return RandomProjectionBackend(**cfg).fit(data)


# ---------------------------------------------------------------------------
# launch planning
# ---------------------------------------------------------------------------


def test_plan_sweep_quantizes_launches():
    p = plan_sweep(613, chunk=60, q_tile=32, chunks_per_launch=4)
    assert p.chunk == 64  # rounded to the q tile
    assert p.cpl == 4 and p.rows_per_launch == 256
    assert p.n_launches == 3 and p.nq_padded == 768  # tail launch padded
    # small sweeps shrink the launch instead of padding 8x
    tiny = plan_sweep(40, chunk=64, q_tile=32, chunks_per_launch=8)
    assert tiny.cpl == 1 and tiny.n_launches == 1 and tiny.nq_padded == 64


# ---------------------------------------------------------------------------
# single device: one-launch == legacy per-chunk == host oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cpl", [1, 3, 8])
def test_sweep_matches_host_and_per_chunk(sweep_data, cpl):
    host = _host(sweep_data)
    legacy = RandomProjectionBackend(
        device=True, interpret=True, sweep=False, **CFG
    ).fit(sweep_data)
    eng = _engine(sweep_data, chunks_per_launch=cpl)
    rows = np.arange(0, 613, 2)  # 307 rows: not a chunk multiple
    hh = host.query_hits(rows, EPS)
    np.testing.assert_array_equal(legacy.query_hits(rows, EPS), hh)
    np.testing.assert_array_equal(eng.query_hits(rows, EPS), hh)
    np.testing.assert_array_equal(eng.query_counts(rows, EPS), hh.sum(axis=1))
    cols = np.arange(5, 600, 7)
    np.testing.assert_array_equal(
        eng.query_hits_subset(rows, cols, EPS), hh[:, cols]
    )


def test_sweep_eps_gt_one_pad_correction(sweep_data):
    """eps > 1 makes every zero pad row pass the dot test — the sweep's
    once-per-sweep correction must subtract tile pads exactly."""
    host, eng = _host(sweep_data), _engine(sweep_data)
    rows = np.arange(0, 613, 5)
    hh = host.query_hits(rows, 1.2)
    np.testing.assert_array_equal(eng.query_hits(rows, 1.2), hh)
    np.testing.assert_array_equal(eng.query_counts(rows, 1.2), hh.sum(axis=1))


@pytest.mark.parametrize("eps", [EPS, 1.2])
def test_sweep_capacity_padded_operands(sweep_data, eps):
    """Post-``partial_fit`` the device operands are capacity-shaped
    (append slack of zero rows); the sweep corrects that slack together
    with the tile pad, once per sweep."""
    host = _host(sweep_data)
    inc = RandomProjectionBackend(device=True, interpret=True, sweep=True, **CFG)
    for start in range(0, 613, 379):  # ragged batches force capacity slack
        inc.partial_fit(sweep_data[start : start + 379])
    assert inc._dev_pad or inc._data_buf.shape[0] % CFG["db_tile"] == 0
    rows = np.arange(0, 613, 3)
    np.testing.assert_array_equal(
        inc.query_hits(rows, eps), host.query_hits(rows, eps)
    )
    np.testing.assert_array_equal(
        inc.query_counts(rows, eps), host.query_counts(rows, eps)
    )


def test_query_hits_packed_is_sweep_native(sweep_data):
    host, eng = _host(sweep_data), _engine(sweep_data)
    rows = np.arange(0, 613, 4)
    hh = host.query_hits(rows, EPS)
    counts, pk = eng.query_hits_packed(rows, EPS)
    np.testing.assert_array_equal(unpack_bitmap(pk, 613), hh)
    np.testing.assert_array_equal(counts, hh.sum(axis=1))
    # host backends fall back to packing the boolean hits
    counts_h, pk_h = host.query_hits_packed(rows, EPS)
    np.testing.assert_array_equal(pk_h, pk)
    np.testing.assert_array_equal(counts_h, counts)


# ---------------------------------------------------------------------------
# margin auto-tune: device occupancy priced on real pairs only
# ---------------------------------------------------------------------------


def test_suggest_margin_tables_agree_on_padded_grid(sweep_data):
    """The kernel counters run on the padded tile grid; after the pad
    correction the device table must equal the host table exactly on a
    non-tile-multiple n (613 % 64 != 0)."""
    host = _host(sweep_data)
    dev = RandomProjectionBackend(device=True, interpret=True, **CFG).fit(sweep_data)
    m_h, tab_h = suggest_margin(host, EPS, report=True)
    m_d, tab_d = suggest_margin(dev, EPS, report=True)
    assert m_h == m_d
    for rh, rd in zip(tab_h, tab_d):
        assert rh["margin"] == rd["margin"]
        assert rh["band_frac"] == pytest.approx(rd["band_frac"], abs=1e-12)
        assert rh["accept_frac"] == pytest.approx(rd["accept_frac"], abs=1e-12)


def test_tile_counts_bincount_matches_hits(sweep_data):
    """The host counts fast-path (bincount band accumulation) must equal
    the materialized hit-matrix row sums."""
    host = _host(sweep_data)
    rows = np.arange(0, 613, 2)
    np.testing.assert_array_equal(
        host.query_counts(rows, EPS), host.query_hits(rows, EPS).sum(axis=1)
    )


# ---------------------------------------------------------------------------
# serving: assignment through the shared engine
# ---------------------------------------------------------------------------


def test_serve_assign_engine_matches_host_loop(sweep_data):
    from repro.stream import StreamingLAF
    from repro.stream.serve import ClusterIndex

    s = StreamingLAF(
        EPS, 5, backend="random_projection", device=True, interpret=True,
        n_bits=64, seed=3, chunk=64, q_tile=32, db_tile=64,
    )
    for start in range(0, 613, 250):
        s.partial_fit(sweep_data[start : start + 250])
    kw = dict(
        sigs=s.backend.signatures, projection=s.backend.projection,
        band=s.backend.band(EPS),
    )
    labels = s.state.labels()
    host_ix = ClusterIndex(s.backend.data, labels, EPS, device=False, **kw)
    dev_ix = ClusterIndex(
        s.backend.data, labels, EPS, device=True,
        sweep_kw=dict(chunk=64, q_tile=32, db_tile=64, interpret=True), **kw,
    )
    rng = np.random.default_rng(1)
    q = rng.standard_normal((300, 32)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    a, b = host_ix.assign(q), dev_ix.assign(q)
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.n_hits, b.n_hits)
    np.testing.assert_allclose(a.confidence, b.confidence)
    assert (a.labels >= 0).any()


# ---------------------------------------------------------------------------
# forced 4-host-device mesh: the double-buffered plane
# ---------------------------------------------------------------------------


def test_plane_sweep_4dev_pipelined_parity(forced_device_run):
    """Pipelined (depth 2) and serialized (depth 1) plane sweeps both
    reproduce the host oracle bit-for-bit on a non-shard-multiple n,
    incl. eps > 1 and a partial_fit growth step."""
    out = forced_device_run(
        """
        import numpy as np, jax
        from repro.data.synthetic import make_angular_clusters
        from repro.index import RandomProjectionBackend

        data, _ = make_angular_clusters(613, 32, 8, kappa=120, noise_frac=0.3, seed=2)
        mesh = jax.make_mesh((4,), ("data",))
        cfg = dict(n_bits=64, margin=3.0, seed=3, chunk=64, q_tile=32, db_tile=64)
        host = RandomProjectionBackend(device=False, **cfg).fit(data)
        rows = np.arange(0, 613, 2)
        ok = {}
        for depth in (1, 2):
            plane = RandomProjectionBackend(
                device=True, interpret=True, mesh=mesh, sweep=True,
                pipeline_depth=depth, chunks_per_launch=3, **cfg
            ).fit(data)
            assert plane._plan.n_shards == 4
            assert plane._plan.n_local % cfg["db_tile"] == 0  # tile-aligned shards
            for eps in (0.55, 1.2):
                hh = host.query_hits(rows, eps)
                np.testing.assert_array_equal(plane.query_hits(rows, eps), hh)
                np.testing.assert_array_equal(
                    plane.query_counts(rows, eps), hh.sum(axis=1)
                )
            inc = RandomProjectionBackend(
                device=True, interpret=True, mesh=mesh, sweep=True,
                pipeline_depth=depth, **cfg
            )
            inc.partial_fit(data[:230]); inc.partial_fit(data[230:])
            np.testing.assert_array_equal(
                inc.query_hits(rows, 0.55), host.query_hits(rows, 0.55)
            )
            ok[str(depth)] = True
        print("RESULT:" + __import__("json").dumps(ok))
        """
    )
    assert out["1"] and out["2"]


def test_laf_lowering_pipelined_sweep_4dev(forced_device_run):
    """The lowering's one-launch pipelined frontier round (depth 2)
    reproduces the serialized round (depth 1) and the jnp dataflow
    bit-for-bit on the 4-device mesh."""
    out = forced_device_run(
        """
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.registry import get_arch
        from repro.data.synthetic import sample_uniform_sphere
        from repro.index.signatures import make_projection, sign_signatures
        from repro.launch import laf_cluster as L

        arch = get_arch("laf_dbscan")
        base = arch.make_reduced_config()
        shape = dataclasses.replace(
            arch.shapes["nyt_150k"], meta={"n_points": 512, "dim": 32}
        )
        mesh = jax.make_mesh((4,), ("data",))

        def cell_for(index_device, depth=2):
            red = dataclasses.replace(
                base, backend="random_projection", index_device=index_device,
                index_pipeline=depth,
            )
            a = dataclasses.replace(arch, make_config=lambda: red)
            return L.build_laf_cluster(a, shape, mesh)

        pipe_cell = cell_for(True, 2)
        serial_cell = cell_for(True, 1)
        flow_cell = cell_for(False)
        assert pipe_cell.meta["index_pipeline"] == 2

        rng = np.random.default_rng(1)
        data = sample_uniform_sphere(rng, 512, 32)
        queries = data[: base.frontier]
        db_sig = sign_signatures(data, make_projection(32, base.index_bits, seed=0))
        params = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), pipe_cell.args[0]
        )
        args = (params, data, queries, jnp.asarray(db_sig))
        with mesh:
            pipe = [np.asarray(o) for o in pipe_cell.step_fn(*args)]
            serial = [np.asarray(o) for o in serial_cell.step_fn(*args)]
            flow = [np.asarray(o) for o in flow_cell.step_fn(*args)]
        assert pipe[1].sum() > 0
        np.testing.assert_array_equal(pipe[0], serial[0])
        np.testing.assert_array_equal(pipe[1], serial[1])
        np.testing.assert_array_equal(pipe[0], flow[0])
        np.testing.assert_array_equal(pipe[1], flow[1])
        print("RESULT:" + __import__("json").dumps({"ok": True}))
        """,
        timeout=600,
    )
    assert out["ok"]
