"""Model zoo tests: shapes, finiteness, grads, decode==forward equivalence,
attention oracle agreement, MoE invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import ctr_batch, powerlaw_graph, random_small_graphs
from repro.models.gnn import GATConfig, gat_forward, gat_forward_batched, gat_init, gat_loss
from repro.models.layers import blockwise_attention, cross_entropy_loss
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.models.recsys import (
    AutoIntConfig,
    BSTConfig,
    DeepFMConfig,
    DIENConfig,
    autoint_forward,
    autoint_init,
    bce_loss,
    bst_forward,
    bst_init,
    deepfm_forward,
    deepfm_init,
    dien_forward,
    dien_init,
    retrieval_scores,
)
from repro.models.transformer import (
    TransformerConfig,
    make_cache,
    transformer_decode_step,
    transformer_forward,
    transformer_init,
    transformer_loss,
)

from repro.kernels.flash_attention.ref import attention_ref


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal,window", [(True, None), (True, 16), (False, None)])
def test_blockwise_attention_matches_oracle(causal, window):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (2, 4, 64, 16)) for kk in keys)
    got = np.asarray(blockwise_attention(q, k, v, causal=causal, window=window, kv_block=16))
    ref = np.asarray(attention_ref(q, k, v, causal=causal, window=window))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_blockwise_attention_gqa():
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (2, 8, 32, 16))
    k = jax.random.normal(keys[1], (2, 2, 32, 16))
    v = jax.random.normal(keys[2], (2, 2, 32, 16))
    got = np.asarray(blockwise_attention(q, k, v, causal=True, kv_block=8))
    kr, vr = jnp.repeat(k, 4, axis=1), jnp.repeat(v, 4, axis=1)
    ref = np.asarray(attention_ref(q, kr, vr, causal=True))
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_cross_entropy_against_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0]])
    labels = jnp.asarray([0])
    expect = -jax.nn.log_softmax(logits)[0, 0]
    assert float(cross_entropy_loss(logits, labels)) == pytest.approx(float(expect), rel=1e-6)


# ---------------------------------------------------------------------------
# transformer
# ---------------------------------------------------------------------------


def tiny_cfg(**kw):
    base = dict(
        vocab=256, d_model=64, n_layers=4, n_heads=4, kv_heads=2, d_head=16,
        d_ff=128, dtype=jnp.float32,
    )
    base.update(kw)
    return TransformerConfig(**base)


def test_transformer_shapes_and_grads():
    cfg = tiny_cfg()
    params = transformer_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    logits = transformer_forward(params, cfg, toks)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    g = jax.grad(lambda p: transformer_loss(p, cfg, toks, toks))(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize(
    "kw",
    [
        {},                                                # dense GQA
        {"window": 8, "global_every": 2},                  # gemma-style hybrid
        {"kv_heads": 1},                                   # MQA (granite)
    ],
)
def test_decode_matches_forward(kw):
    cfg = tiny_cfg(**kw)
    params = transformer_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    cache = make_cache(cfg, 2, 16, dtype=jnp.float32)
    outs = []
    for t in range(8):
        lg, cache = transformer_decode_step(params, cfg, toks[:, t : t + 1], cache, t)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    fwd = transformer_forward(params, cfg, toks[:, :8])
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fwd), rtol=1e-3, atol=1e-4)


def test_mla_moe_decode_matches_forward():
    cfg = tiny_cfg(
        attention="mla",
        mla=MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
        # capacity_factor high enough that no tokens drop: decode==forward
        # only holds when both paths route identically (drops are
        # batch-size-dependent by design — GShard semantics).
        moe=MoEConfig(d_model=64, d_ff=32, n_experts=4, top_k=2, n_shared=1,
                      capacity_factor=8.0, dtype=jnp.float32),
        n_dense_layers=1,
    )
    params = transformer_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    cache = make_cache(cfg, 2, 12, dtype=jnp.float32)
    outs = []
    for t in range(6):
        lg, cache = transformer_decode_step(params, cfg, toks[:, t : t + 1], cache, t)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    fwd = transformer_forward(params, cfg, toks[:, :6])
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fwd), rtol=1e-3, atol=1e-3)


def test_param_count_analytic_matches_actual():
    cfg = tiny_cfg()
    params = transformer_init(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert cfg.param_count() == actual


def test_param_count_moe():
    cfg = tiny_cfg(
        moe=MoEConfig(d_model=64, d_ff=32, n_experts=4, top_k=2, dtype=jnp.float32)
    )
    params = transformer_init(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert cfg.param_count() == actual
    assert cfg.active_param_count() < cfg.param_count()


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------


def test_moe_capacity_dropping():
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=2, top_k=1,
                    capacity_factor=0.25, dtype=jnp.float32)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y, aux = moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert float(aux["drop_fraction"]) > 0.0  # capacity 8 << 64 tokens


def test_moe_identical_tokens_identical_outputs():
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2,
                    capacity_factor=8.0, dtype=jnp.float32)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.tile(jax.random.normal(jax.random.PRNGKey(1), (1, 16)), (8, 1))
    y, _ = moe_apply(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y - y[0]), 0.0, atol=1e-5)


def test_moe_gates_normalized():
    cfg = MoEConfig(d_model=8, d_ff=16, n_experts=4, top_k=4,
                    capacity_factor=8.0, dtype=jnp.float32)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (10, 8))
    # with top_k == n_experts and generous capacity, MoE == dense mixture;
    # compare against direct dense computation
    y, aux = moe_apply(p, cfg, x)
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", x, p["wi_gate"]))
    u = jnp.einsum("td,edf->tef", x, p["wi_up"])
    dense_out = jnp.einsum("tef,efd,te->td", g * u, p["wo"], probs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense_out), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def graph():
    rng = np.random.default_rng(0)
    return powerlaw_graph(rng, 100, 400, 16)


def test_gat_shapes_and_grads(graph):
    cfg = GATConfig(d_in=16, d_hidden=8, n_heads=8, n_classes=7)
    p = gat_init(jax.random.PRNGKey(0), cfg)
    args = (jnp.asarray(graph["feats"]), jnp.asarray(graph["src"]), jnp.asarray(graph["dst"]))
    logits = gat_forward(p, cfg, *args)
    assert logits.shape == (100, 7)
    g = jax.grad(gat_loss)(p, cfg, *args, jnp.asarray(graph["labels"]))
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.isfinite(leaf).all())


def test_gat_edge_softmax_normalized(graph):
    """Attention over incoming edges of each node sums to 1."""
    from repro.models.gnn import _edge_softmax

    scores = jnp.asarray(np.random.default_rng(1).standard_normal((400, 8)).astype(np.float32))
    dst = jnp.asarray(graph["dst"])
    attn = _edge_softmax(scores, dst, 100)
    sums = jax.ops.segment_sum(attn, dst, num_segments=100)
    has_edge = np.zeros(100, bool)
    has_edge[np.asarray(graph["dst"])] = True
    np.testing.assert_allclose(np.asarray(sums)[has_edge], 1.0, rtol=1e-5)


def test_gat_isolated_nodes_no_nan(graph):
    """Nodes with no incoming edges must produce finite (zero) outputs."""
    cfg = GATConfig(d_in=16, d_hidden=8, n_heads=8, n_classes=7)
    p = gat_init(jax.random.PRNGKey(0), cfg)
    # only edges into nodes < 50: nodes >= 50 isolated as destinations
    src = jnp.asarray(graph["src"]) % 50
    dst = jnp.asarray(graph["dst"]) % 50
    logits = gat_forward(p, cfg, jnp.asarray(graph["feats"]), src, dst)
    assert bool(jnp.isfinite(logits).all())


def test_gat_batched_molecules():
    rng = np.random.default_rng(2)
    bg = random_small_graphs(rng, 4, 30, 64, 16)
    cfg = GATConfig(d_in=16, d_hidden=8, n_heads=8, n_classes=7)
    p = gat_init(jax.random.PRNGKey(0), cfg)
    out = gat_forward_batched(p, cfg, jnp.asarray(bg["feats"]), jnp.asarray(bg["src"]), jnp.asarray(bg["dst"]))
    assert out.shape == (4, 7)


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def ctr():
    rng = np.random.default_rng(3)
    vocabs = tuple(rng.integers(50, 500, size=39).tolist())
    return vocabs, ctr_batch(rng, 32, 39, np.asarray(vocabs))


@pytest.mark.parametrize("model", ["deepfm", "autoint"])
def test_field_models(ctr, model):
    vocabs, batch = ctr
    if model == "deepfm":
        cfg = DeepFMConfig(vocab_sizes=vocabs)
        p = deepfm_init(jax.random.PRNGKey(0), cfg)
        fwd = lambda pp: deepfm_forward(pp, cfg, jnp.asarray(batch["ids"]))
    else:
        cfg = AutoIntConfig(vocab_sizes=vocabs)
        p = autoint_init(jax.random.PRNGKey(0), cfg)
        fwd = lambda pp: autoint_forward(pp, cfg, jnp.asarray(batch["ids"]))
    logits = fwd(p)
    assert logits.shape == (32,)
    assert bool(jnp.isfinite(logits).all())
    g = jax.grad(lambda pp: bce_loss(fwd(pp), jnp.asarray(batch["label"])))(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("model", ["dien", "bst"])
def test_sequence_models(model):
    rng = np.random.default_rng(4)
    hist = jnp.asarray(rng.integers(0, 1000, (16, 20)).astype(np.int32))
    tgt = jnp.asarray(rng.integers(0, 1000, 16).astype(np.int32))
    if model == "dien":
        cfg = DIENConfig(item_vocab=1000, seq_len=20)
        p = dien_init(jax.random.PRNGKey(0), cfg)
        fwd = lambda pp: dien_forward(pp, cfg, hist, tgt)
    else:
        cfg = BSTConfig(item_vocab=1000, seq_len=20)
        p = bst_init(jax.random.PRNGKey(0), cfg)
        fwd = lambda pp: bst_forward(pp, cfg, hist, tgt)
    logits = fwd(p)
    assert logits.shape == (16,)
    assert bool(jnp.isfinite(logits).all())
    g = jax.grad(lambda pp: bce_loss(fwd(pp), jnp.ones(16)))(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.isfinite(leaf).all())


def test_dien_attention_changes_output():
    """AUGRU attention must make the target item matter."""
    cfg = DIENConfig(item_vocab=100, seq_len=10)
    p = dien_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    hist = jnp.asarray(rng.integers(0, 100, (4, 10)).astype(np.int32))
    a = dien_forward(p, cfg, hist, jnp.zeros(4, jnp.int32))
    b = dien_forward(p, cfg, hist, jnp.full(4, 7, jnp.int32))
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_retrieval_scores_matmul():
    q = jnp.asarray(np.eye(4, 8, dtype=np.float32))
    c = jnp.asarray(np.eye(16, 8, dtype=np.float32))
    s = retrieval_scores(q, c)
    assert s.shape == (4, 16)
    np.testing.assert_allclose(np.asarray(s)[0, 0], 1.0)
