import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.metrics import (
    adjusted_mutual_info,
    adjusted_rand_index,
    contingency,
    entropy,
    expected_mutual_info,
    mutual_info,
)


def test_perfect_agreement():
    a = np.array([0, 0, 1, 1, 2, 2])
    b = np.array([5, 5, 9, 9, 7, 7])  # same partition, different ids
    assert adjusted_rand_index(a, b) == pytest.approx(1.0)
    assert adjusted_mutual_info(a, b) == pytest.approx(1.0)


def test_known_ari_value():
    # classic example: ARI([0,0,1,1],[0,0,1,2]) = 0.5714285714
    a = np.array([0, 0, 1, 1])
    b = np.array([0, 0, 1, 2])
    assert adjusted_rand_index(a, b) == pytest.approx(0.5714285714285714, abs=1e-12)


def test_known_mi_value():
    # MI of independent-ish small case, hand-computed
    a = np.array([0, 0, 1, 1])
    b = np.array([0, 1, 0, 1])
    # contingency = [[1,1],[1,1]] -> MI = 0
    assert mutual_info(a, b) == pytest.approx(0.0, abs=1e-12)
    assert adjusted_rand_index(a, b) == pytest.approx(-0.5, abs=1e-9)


def test_single_cluster_each():
    a = np.zeros(10, dtype=int)
    b = np.zeros(10, dtype=int)
    assert adjusted_mutual_info(a, b) == pytest.approx(1.0)


def test_emi_small_case_vs_naive():
    """E[MI] against a direct naive triple-loop on a tiny case."""
    import math

    ra = np.array([3, 2])
    cb = np.array([2, 3])
    n = 5
    # naive
    total = 0.0
    for a in ra:
        for b in cb:
            for nij in range(max(1, a + b - n), min(a, b) + 1):
                p = (
                    math.factorial(a) * math.factorial(b)
                    * math.factorial(n - a) * math.factorial(n - b)
                ) / (
                    math.factorial(n) * math.factorial(nij)
                    * math.factorial(a - nij) * math.factorial(b - nij)
                    * math.factorial(n - a - b + nij)
                )
                total += nij / n * math.log(n * nij / (a * b)) * p
    assert expected_mutual_info(ra, cb) == pytest.approx(total, rel=1e-10)


def test_ami_beats_mi_for_random_labels():
    """AMI of random labelings concentrates near 0 (chance-corrected)."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, 8, size=500)
    b = rng.integers(0, 8, size=500)
    assert abs(adjusted_mutual_info(a, b)) < 0.05
    assert mutual_info(a, b) > 0.01  # raw MI is biased > 0


@given(st.integers(min_value=2, max_value=6), st.integers(min_value=20, max_value=60))
@settings(max_examples=15, deadline=None)
def test_metric_symmetry(k, n):
    rng = np.random.default_rng(n * k)
    a = rng.integers(0, k, size=n)
    b = rng.integers(0, k, size=n)
    assert adjusted_rand_index(a, b) == pytest.approx(adjusted_rand_index(b, a), abs=1e-10)
    assert adjusted_mutual_info(a, b) == pytest.approx(adjusted_mutual_info(b, a), abs=1e-8)


@given(st.integers(min_value=2, max_value=5), st.integers(min_value=10, max_value=40))
@settings(max_examples=15, deadline=None)
def test_ari_upper_bound(k, n):
    rng = np.random.default_rng(n + k)
    a = rng.integers(0, k, size=n)
    b = rng.integers(0, k, size=n)
    assert adjusted_rand_index(a, b) <= 1.0 + 1e-12
    assert adjusted_mutual_info(a, b) <= 1.0 + 1e-8


def test_contingency_shape():
    a = np.array([0, 1, 1, 2])
    b = np.array([1, 1, 0, 0])
    m, ra, cb = contingency(a, b)
    assert m.shape == (3, 2)
    assert m.sum() == 4
    np.testing.assert_array_equal(ra, [1, 2, 1])
