"""Launch-layer contract tests: build_cell -> jit(in/out shardings) ->
lower -> compile on a small 8-host-device mesh, in a subprocess (the
device count is locked at first jax init, so the main test process must
stay at 1 device)."""

import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, "src")
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    import dataclasses
    import repro.launch.steps as S
    from repro.configs.registry import get_arch

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    # reduced configs so the 8-device compile stays fast; the production
    # builders are exercised unchanged (same sharding rules/step fns)
    results = {}

    def tiny_lm():
        arch = get_arch("llama3-8b")
        cfg = arch.make_reduced_config()
        shape = dataclasses.replace(
            arch.shapes["train_4k"], meta={"seq_len": 64, "global_batch": 8}
        )
        return dataclasses.replace(arch, make_config=lambda: cfg), shape

    arch, shape = tiny_lm()
    cell = S.build_lm_train(arch, shape, mesh)
    with mesh:
        compiled = jax.jit(
            cell.step_fn, in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        ).lower(*cell.args).compile()
    results["lm_train"] = {
        "mem": int(compiled.memory_analysis().temp_size_in_bytes),
        "ok": True,
    }

    # recsys forward cell (reduced)
    arch = get_arch("deepfm")
    red = arch.make_reduced_config()
    arch = dataclasses.replace(arch, make_config=lambda: red)
    shape = dataclasses.replace(arch.shapes["serve_p99"], meta={"batch": 16})
    cell = S.build_recsys_forward(arch, shape, mesh)
    with mesh:
        jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings).lower(*cell.args).compile()
    results["recsys_forward"] = {"ok": True}

    # LAF cluster cell (reduced)
    arch = get_arch("laf_dbscan")
    red = arch.make_reduced_config()
    arch = dataclasses.replace(arch, make_config=lambda: red)
    shape = dataclasses.replace(
        arch.shapes["nyt_150k"], meta={"n_points": 2048, "dim": 64}
    )
    cell = S.build_laf_cluster(arch, shape, mesh)
    with mesh:
        jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings).lower(*cell.args).compile()
    results["laf_cluster"] = {"ok": True}

    # LAF cluster cell, random_projection backend through the sharded
    # index plane (index_device=True forces the shard_mapped tile on the
    # 8-device two-axis mesh; compiles the shard_map + psum lowering)
    red_rp = dataclasses.replace(
        red, backend="random_projection", index_device=True
    )
    arch_rp = dataclasses.replace(arch, make_config=lambda: red_rp)
    cell = S.build_laf_cluster(arch_rp, shape, mesh)
    assert cell.meta["fused_kernel"] and cell.meta["sharded"]
    assert cell.meta["n_shards"] == 8
    with mesh:
        jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings).lower(*cell.args).compile()
    results["laf_cluster_sharded"] = {"ok": True}

    print("RESULT:" + json.dumps(results))
    """
)


@pytest.mark.dryrun
def test_build_cells_compile_on_8_devices():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=480, cwd=".",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    results = json.loads(line[len("RESULT:"):])
    assert results["lm_train"]["ok"]
    assert results["recsys_forward"]["ok"]
    assert results["laf_cluster"]["ok"]
    assert results["laf_cluster_sharded"]["ok"]


def test_hlo_analysis_loop_correction():
    """The loop-aware analyzer multiplies while bodies by trip count."""
    hlo = textwrap.dedent(
        """
        %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
          %p = (s32[], f32[8,8]) parameter(0)
          %i = s32[] get-tuple-element(%p), index=0
          %x = f32[8,8] get-tuple-element(%p), index=1
          %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
        }

        %cond (p: (s32[], f32[8,8])) -> pred[] {
          %p = (s32[], f32[8,8]) parameter(0)
          ROOT %ok = pred[] constant(true)
        }

        ENTRY %main (a: f32[8,8]) -> (s32[], f32[8,8]) {
          %a = f32[8,8] parameter(0)
          %z = s32[] constant(0)
          %init = (s32[], f32[8,8]) tuple(%z, %a)
          ROOT %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
        }
        """
    )
    from repro.launch.hlo_analysis import analyze_hlo

    a = analyze_hlo(hlo)
    # one 8x8x8 dot (1024 flops) x 5 trips
    assert a.flops == pytest.approx(5 * 2 * 8 * 8 * 8)
    assert a.n_while_loops == 1


def test_roofline_row_classification():
    from repro.launch.roofline import roofline_row

    rec = {
        "status": "ok", "arch": "x", "shape": "y", "mesh": "m", "n_devices": 256,
        "meta": {"kind": "train", "tokens_per_step": 1024,
                 "active_param_count": 1_000_000, "param_count": 1_000_000},
        "hlo_analysis": {
            "flops": 1e12, "bytes_accessed": 1e12,
            "collectives": {"total": {"bytes": 1e9}},
        },
        "memory_analysis": {"bytes_per_device": {"total": 2**30}},
    }
    row = roofline_row(rec)
    assert row.bound == "memory"          # 1e12/819e9 > 1e12/197e12, 1e9/50e9
    assert 0 < row.roofline_fraction < 1
    assert row.flops_ratio == pytest.approx(6 * 1e6 * 1024 / 256 / 1e12)
