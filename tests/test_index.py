"""repro.index: backend protocol, signatures, and engine integration.

Also carries the non-multiple-of-32 bitmap-utility coverage for
``repro.core.range_query`` (those utilities are the packing idiom the
index signatures reuse).
"""

import numpy as np
import pytest

from repro.core.dbscan import dbscan_parallel
from repro.core.laf_dbscan import laf_dbscan
from repro.core.metrics import adjusted_rand_index
from repro.core.range_query import (
    bitmap_row_to_indices,
    neighbor_lists,
    pack_bitmap,
    unpack_bitmap,
)
from repro.data.synthetic import make_angular_clusters, sample_uniform_sphere
from repro.index import (
    ExactBackend,
    RandomProjectionBackend,
    as_fitted,
    hamming_band,
    hamming_numpy,
    make_projection,
    sign_signatures,
)

EPS = 0.55


@pytest.fixture(scope="module")
def fixture_data():
    data, _ = make_angular_clusters(1500, 48, 12, kappa=160, noise_frac=0.3, seed=7)
    return data


# ---------------------------------------------------------------------------
# bitmap utilities at nd not a multiple of 32
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nd", [1, 31, 33, 77, 100])
def test_pack_unpack_roundtrip_odd_widths(nd):
    rng = np.random.default_rng(nd)
    hits = rng.random((9, nd)) < 0.4
    packed = pack_bitmap(hits)
    assert packed.shape == (9, -(-nd // 32))
    np.testing.assert_array_equal(unpack_bitmap(packed, nd), hits)


@pytest.mark.parametrize("nd", [31, 45, 97])
def test_bitmap_row_to_indices_odd_widths(nd):
    rng = np.random.default_rng(nd + 1)
    hits = rng.random((4, nd)) < 0.35
    packed = pack_bitmap(hits)
    for i in range(4):
        np.testing.assert_array_equal(
            bitmap_row_to_indices(packed[i], nd), np.nonzero(hits[i])[0]
        )


# ---------------------------------------------------------------------------
# signatures
# ---------------------------------------------------------------------------


def test_sign_signatures_match_host_packing():
    rng = np.random.default_rng(0)
    data = sample_uniform_sphere(rng, 200, 40)
    proj = make_projection(40, 64, seed=2)
    sigs = sign_signatures(data, proj)
    assert sigs.shape == (200, 2) and sigs.dtype == np.uint32
    np.testing.assert_array_equal(sigs, pack_bitmap((data @ proj) >= 0))


def test_make_projection_rejects_unaligned_bits():
    with pytest.raises(ValueError):
        make_projection(16, 40)


def test_hamming_numpy_matches_bit_xor():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 2**32, size=(6, 3), dtype=np.uint32)
    b = rng.integers(0, 2**32, size=(9, 3), dtype=np.uint32)
    got = hamming_numpy(a, b)
    ref = np.array(
        [[sum(bin(int(x) ^ int(y)).count("1") for x, y in zip(ra, rb)) for rb in b]
         for ra in a]
    )
    np.testing.assert_array_equal(got, ref)


def test_hamming_band_ordering():
    for eps in (0.2, 0.55, 0.9):
        t_lo, t_hi = hamming_band(eps, 512, margin=3.0)
        assert t_lo < t_hi <= 512


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


def test_exact_backend_matches_neighbor_lists(fixture_data):
    bk = as_fitted("exact", fixture_data)
    ref = neighbor_lists(fixture_data, EPS)
    got = bk.neighbor_lists(EPS)
    assert len(got) == len(ref)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)


def test_fit_idempotent_on_same_array(fixture_data):
    bk = RandomProjectionBackend(seed=0).fit(fixture_data)
    sigs = bk.signatures
    assert bk.fit(fixture_data) is bk
    assert bk.signatures is sigs


def test_rp_full_verify_with_open_filter_is_exact(fixture_data):
    """ham_thresh = n_bits admits every candidate; full verify then
    reproduces the exact neighbor lists bit-for-bit."""
    bk = RandomProjectionBackend(n_bits=64, margin=1e9, verify="full", seed=4)
    bk.fit(fixture_data)
    t_lo, t_hi = bk.band(EPS)
    assert t_lo == -1 and t_hi == 64
    ref = neighbor_lists(fixture_data, EPS)
    got = bk.neighbor_lists(EPS)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("verify", ["band", "full"])
def test_rp_recall_on_fixture(fixture_data, verify):
    """Default-parameter recall of the ANN backend vs exact neighbor
    lists; full-verify mode must also keep precision at 1."""
    bk = RandomProjectionBackend(seed=1, verify=verify).fit(fixture_data)
    ref = neighbor_lists(fixture_data, EPS)
    got = bk.neighbor_lists(EPS)
    tp = fp = pos = 0
    for a, b in zip(got, ref):
        inter = len(np.intersect1d(a, b, assume_unique=True))
        tp += inter
        fp += len(a) - inter
        pos += len(b)
    assert tp / pos >= 0.95
    if verify == "full":
        assert fp == 0


def test_rp_subset_consistent_with_full(fixture_data):
    bk = RandomProjectionBackend(seed=2, verify="full").fit(fixture_data)
    rows = np.arange(40)
    cols = np.arange(100, 900, 3)
    np.testing.assert_array_equal(
        bk.query_hits_subset(rows, cols, EPS), bk.query_hits(rows, EPS)[:, cols]
    )


def test_query_counts_chunking_consistent(fixture_data):
    bk = as_fitted("exact", fixture_data, block_size=128)
    rows = np.arange(300)
    np.testing.assert_array_equal(
        bk.query_counts(rows, EPS), bk.query_hits(rows, EPS).sum(axis=1)
    )


def test_make_backend_unknown_name():
    """Unknown names raise ValueError listing the registered backends
    (not the bare KeyError the lazy-registry change used to leak)."""
    with pytest.raises(ValueError, match=r"unknown range backend 'faiss'.*exact"):
        as_fitted("faiss", np.zeros((4, 4), np.float32))


# ---------------------------------------------------------------------------
# partial_fit: streaming append == one-shot fit, on every evaluator
# ---------------------------------------------------------------------------


def test_partial_fit_exact_matches_full_fit(fixture_data):
    full = ExactBackend().fit(fixture_data)
    inc = ExactBackend()
    for start in range(0, len(fixture_data), 400):
        inc.partial_fit(fixture_data[start : start + 400])
    assert inc.n_points == len(fixture_data)
    rows = np.arange(0, len(fixture_data), 13)
    np.testing.assert_array_equal(
        inc.query_hits(rows, EPS), full.query_hits(rows, EPS)
    )
    np.testing.assert_array_equal(
        inc.query_counts(rows, EPS), full.query_counts(rows, EPS)
    )


@pytest.mark.parametrize("device", [False, True])
def test_partial_fit_rp_matches_full_fit(fixture_data, device):
    """Appended rows + packed signatures reproduce the one-shot index
    bit for bit: same projection, same signatures, same hit sets, on
    the host path and through the fused tile (whose capacity-padded
    operands exercise the zero-row correction)."""
    cfg = dict(n_bits=64, margin=3.0, seed=3, chunk=64)
    if device:
        cfg.update(device=True, interpret=True, q_tile=32, db_tile=128)
    else:
        cfg.update(device=False)
    full = RandomProjectionBackend(**cfg).fit(fixture_data)
    inc = RandomProjectionBackend(**cfg)
    for start in range(0, len(fixture_data), 379):  # ragged batches
        inc.partial_fit(fixture_data[start : start + 379])
    np.testing.assert_array_equal(inc.signatures, full.signatures)
    rows = np.arange(0, len(fixture_data), 11)
    np.testing.assert_array_equal(inc.query_hits(rows, EPS), full.query_hits(rows, EPS))
    np.testing.assert_array_equal(
        inc.query_counts(rows, EPS), full.query_counts(rows, EPS)
    )
    cols = np.arange(3, 1100, 7)
    np.testing.assert_array_equal(
        inc.query_hits_subset(rows, cols, EPS),
        full.query_hits_subset(rows, cols, EPS),
    )


def test_partial_fit_rp_eps_gt_one_capacity_correction(fixture_data):
    """eps > 1 makes the zero rows in the append slack pass the dot
    test — the capacity-pad correction must subtract them exactly."""
    data = fixture_data[:700]
    cfg = dict(n_bits=64, seed=3, chunk=64, device=True, interpret=True,
               q_tile=32, db_tile=128)
    full = RandomProjectionBackend(**cfg).fit(data)
    inc = RandomProjectionBackend(**cfg)
    inc.partial_fit(data[:450])
    inc.partial_fit(data[450:])
    rows = np.arange(40)
    np.testing.assert_array_equal(
        inc.query_counts(rows, 1.2), full.query_counts(rows, 1.2)
    )
    np.testing.assert_array_equal(
        inc.query_hits(rows, 1.2), full.query_hits(rows, 1.2)
    )


def test_partial_fit_on_unfitted_backend_is_fit(fixture_data):
    bk = RandomProjectionBackend(n_bits=64, seed=3)
    bk.partial_fit(fixture_data[:300])
    ref = RandomProjectionBackend(n_bits=64, seed=3).fit(fixture_data[:300])
    np.testing.assert_array_equal(bk.signatures, ref.signatures)


def test_partial_fit_resharding_on_mesh(forced_device_run):
    """Sharded append: partial_fit under mesh= re-co-shards the rows +
    signature table and the plane's sweeps stay parity with the host
    oracle at every growth step (incl. non-shard-multiple sizes)."""
    out = forced_device_run(
        """
        import numpy as np
        import jax
        from jax.sharding import Mesh
        from repro.data.synthetic import make_angular_clusters
        from repro.index import RandomProjectionBackend

        data, _ = make_angular_clusters(610, 32, 8, kappa=200, noise_frac=0.3, seed=2)
        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        cfg = dict(n_bits=64, seed=3, chunk=64, q_tile=32, db_tile=128,
                   device=True, interpret=True)
        inc = RandomProjectionBackend(mesh=mesh, **cfg)
        host = RandomProjectionBackend(device=False, n_bits=64, seed=3, chunk=64)
        checks = []
        for cut in [(0, 230), (230, 450), (450, 610)]:
            inc.partial_fit(data[cut[0]:cut[1]])
            host.fit(np.ascontiguousarray(data[:cut[1]]))
            rows = np.arange(0, cut[1], 9)
            checks.append(bool(
                np.array_equal(inc.query_hits(rows, 0.55), host.query_hits(rows, 0.55))
                and np.array_equal(inc.query_counts(rows, 0.55), host.query_counts(rows, 0.55))
            ))
        print("RESULT:" + __import__("json").dumps({"parity": checks}))
        """
    )
    assert out["parity"] == [True, True, True]


def test_neighbor_lists_backend_dispatch(fixture_data):
    ref = neighbor_lists(fixture_data, EPS)
    got = neighbor_lists(
        fixture_data, EPS,
        backend=RandomProjectionBackend(n_bits=64, margin=1e9, verify="full", seed=4),
    )
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# device path parity: the fused Pallas tile (interpret mode) must produce
# the host band evaluator's exact hit sets — one contract, two evaluators
# ---------------------------------------------------------------------------


def _rp_pair(data, verify, **kw):
    """(host, device) backends with identical index configuration."""
    cfg = dict(n_bits=64, margin=3.0, seed=3, verify=verify, chunk=64)
    cfg.update(kw)
    host = RandomProjectionBackend(device=False, **cfg).fit(data)
    dev = RandomProjectionBackend(
        device=True, interpret=True, q_tile=32, db_tile=128, **cfg
    ).fit(data)
    return host, dev


@pytest.mark.parametrize("verify", ["band", "full"])
def test_device_backend_matches_host_hit_sets(fixture_data, verify):
    """Identical hit sets on whole-db and subset tiles; n=700 is not a
    multiple of either kernel tile, so padded rows/cols are exercised.
    verify="full" pins t_lo = -1 (every candidate exact-verified)."""
    data = fixture_data[:700]
    host, dev = _rp_pair(data, verify)
    rows = np.arange(0, 96)
    hh = host.query_hits(rows, EPS)
    np.testing.assert_array_equal(dev.query_hits(rows, EPS), hh)
    cols = np.arange(5, 643, 7)
    np.testing.assert_array_equal(
        dev.query_hits_subset(rows, cols, EPS),
        host.query_hits_subset(rows, cols, EPS),
    )
    np.testing.assert_array_equal(dev.query_counts(rows, EPS), hh.sum(axis=1))
    if verify == "full":
        # full-verify hits can never contain a false positive vs exact
        exact = ExactBackend().fit(data).query_hits(rows, EPS)
        assert not np.any(np.asarray(dev.query_hits(rows, EPS)) & ~exact)


def test_device_backend_matches_host_on_saturated_band(fixture_data):
    """max_band_frac=0 forces the host dense-fallback (saturated-tile)
    path on every tile; the kernel must still agree bit-for-bit, since
    only the evaluation strategy differs, never the predicate."""
    data = fixture_data[:500]
    host, dev = _rp_pair(data, "band", max_band_frac=0.0)
    rows = np.arange(64)
    np.testing.assert_array_equal(
        dev.query_hits(rows, EPS), host.query_hits(rows, EPS)
    )


def test_device_backend_eps_gt_one_padded_correction(fixture_data):
    """eps > 1 makes zero-padded db rows pass the dot test; the kernel
    wrappers must subtract/mask them so counts and hits stay exact."""
    data = fixture_data[:333]  # forces row and column padding
    host, dev = _rp_pair(data, "band")
    rows = np.arange(48)
    eps = 1.2
    hh = host.query_hits(rows, eps)
    np.testing.assert_array_equal(dev.query_hits(rows, eps), hh)
    np.testing.assert_array_equal(dev.query_counts(rows, eps), hh.sum(axis=1))


def test_device_flag_validation():
    with pytest.raises(ValueError):
        RandomProjectionBackend(device="tpu")


# ---------------------------------------------------------------------------
# engine integration: indexed clustering tracks exact clustering
# ---------------------------------------------------------------------------


def test_dbscan_parallel_device_backend_matches_host_backend(fixture_data):
    """End-to-end engine parity: clustering through the fused tile gives
    the identical partition to the host band evaluator."""
    data = fixture_data[:500]
    tau = 5
    host, dev = _rp_pair(data, "band")
    res_host = dbscan_parallel(data, EPS, tau, backend=host)
    res_dev = dbscan_parallel(data, EPS, tau, backend=dev)
    np.testing.assert_array_equal(res_host.core, res_dev.core)
    np.testing.assert_array_equal(res_host.labels, res_dev.labels)


def test_dbscan_parallel_rp_backend_matches_exact(fixture_data):
    tau = 5
    exact = dbscan_parallel(fixture_data, EPS, tau)
    rp = dbscan_parallel(fixture_data, EPS, tau, backend="random_projection")
    assert adjusted_rand_index(exact.labels, rp.labels) >= 0.98
    # core sets nearly identical (ANN may drop a few boundary counts)
    assert (exact.core != rp.core).mean() <= 0.01


def test_laf_dbscan_rp_backend_matches_exact(fixture_data):
    tau = 5
    bk = as_fitted("exact", fixture_data)
    pred = bk.query_counts(np.arange(len(fixture_data)), EPS)  # oracle estimator
    exact = laf_dbscan(fixture_data, EPS, tau, 1.0, pred)
    rp = laf_dbscan(fixture_data, EPS, tau, 1.0, pred, backend="random_projection")
    assert adjusted_rand_index(exact.labels, rp.labels) >= 0.98


# ---------------------------------------------------------------------------
# config -> lowered workload: LAFClusterConfig.backend/index_bits are live
# ---------------------------------------------------------------------------


def test_laf_cluster_lowering_consumes_rp_backend():
    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.configs.registry import get_arch
    from repro.launch import steps as S

    arch = get_arch("laf_dbscan")
    base = arch.make_reduced_config()
    shape = dataclasses.replace(arch.shapes["nyt_150k"], meta={"n_points": 512, "dim": 32})
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))

    def cell_for(backend):
        # full verify pins t_lo = -1, so the Hamming gate can only
        # remove pairs — the monotonicity assertion below relies on it
        # (band mode may also sure-accept, see the fused-kernel test)
        red = dataclasses.replace(base, backend=backend, index_verify="full")
        a = dataclasses.replace(arch, make_config=lambda: red)
        return S.build_laf_cluster(a, shape, mesh)

    exact_cell = cell_for("exact")
    rp_cell = cell_for("random_projection")
    assert len(exact_cell.args) == 3
    assert len(rp_cell.args) == 4  # packed db signatures ride along
    n, w = rp_cell.args[3].shape
    assert (n, w) == (512, base.index_bits // 32)

    rng = np.random.default_rng(0)
    data = sample_uniform_sphere(rng, 512, 32)
    queries = data[: base.frontier]
    db_sig = sign_signatures(data, make_projection(32, base.index_bits, seed=0))
    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), exact_cell.args[0])

    # partial_counts (output 1) is not masked by the RMI skip gate, so
    # it isolates the signature filter from the zero-initialized
    # estimator's skip decisions
    exact_partial = np.asarray(exact_cell.step_fn(params, data, queries)[1])
    rp_partial = np.asarray(
        rp_cell.step_fn(params, data, queries, jnp.asarray(db_sig))[1]
    )
    # the Hamming gate only removes pairs, and at margin=3 removes
    # almost no true neighbors
    assert np.all(rp_partial <= exact_partial)
    assert exact_partial.sum() > 0
    kept = rp_partial.sum() / exact_partial.sum()
    assert kept >= 0.95


def test_laf_cluster_lowering_fused_kernel_matches_dataflow():
    """index_device=True on a single-device mesh routes the frontier
    round through the fused hamming_filter Pallas tile (interpret mode
    here); it must produce the same hits as the shardable jnp dataflow
    evaluating the identical band predicate."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.configs.registry import get_arch
    from repro.launch import steps as S

    arch = get_arch("laf_dbscan")
    base = arch.make_reduced_config()
    shape = dataclasses.replace(arch.shapes["nyt_150k"], meta={"n_points": 512, "dim": 32})
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))

    def cell_for(index_device):
        red = dataclasses.replace(
            base, backend="random_projection", index_device=index_device
        )
        a = dataclasses.replace(arch, make_config=lambda: red)
        return S.build_laf_cluster(a, shape, mesh)

    flow_cell = cell_for(False)
    fused_cell = cell_for(True)
    assert flow_cell.meta["fused_kernel"] is False
    assert fused_cell.meta["fused_kernel"] is True
    assert flow_cell.meta["index_verify"] == "band"

    rng = np.random.default_rng(1)
    data = sample_uniform_sphere(rng, 512, 32)
    queries = data[: base.frontier]
    db_sig = sign_signatures(data, make_projection(32, base.index_bits, seed=0))
    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), flow_cell.args[0])

    args = (params, data, queries, jnp.asarray(db_sig))
    flow_counts, flow_partial, _ = (np.asarray(o) for o in flow_cell.step_fn(*args))
    fused_counts, fused_partial, _ = (np.asarray(o) for o in fused_cell.step_fn(*args))
    assert flow_partial.sum() > 0
    np.testing.assert_array_equal(fused_partial, flow_partial)
    np.testing.assert_array_equal(fused_counts, flow_counts)
