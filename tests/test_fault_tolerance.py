"""train.fault_tolerance: GuardedStep retry/backoff, straggler EWMA
deadlines, elastic re-mesh planning."""

import pytest

from repro.train.fault_tolerance import (
    GuardedStep,
    StragglerPolicy,
    plan_elastic_remesh,
)


class Flaky:
    """Fails the first ``n_failures`` calls, then returns ``value``."""

    def __init__(self, n_failures, value=42, exc=RuntimeError):
        self.n_failures = n_failures
        self.value = value
        self.exc = exc
        self.calls = 0

    def __call__(self):
        self.calls += 1
        if self.calls <= self.n_failures:
            raise self.exc(f"transient #{self.calls}")
        return self.value


class TestGuardedStep:
    def test_clean_step_single_attempt(self):
        step = GuardedStep(lambda: 7)
        res = step()
        assert res.value == 7
        assert res.attempts == 1
        assert not res.recovered
        assert step.failures == []

    def test_retries_transient_failures(self):
        fn = Flaky(2)
        res = GuardedStep(fn, max_retries=2)()
        assert res.value == 42
        assert res.attempts == 3
        assert fn.calls == 3
        assert not res.recovered

    def test_exhausted_retries_raise_without_restore(self):
        fn = Flaky(10)
        step = GuardedStep(fn, max_retries=2)
        with pytest.raises(RuntimeError):
            step()
        assert fn.calls == 3  # initial + 2 retries
        assert len(step.failures) == 3

    def test_restore_escalation_resets_attempts(self):
        fn = Flaky(4)  # needs more than max_retries+1 calls
        restores = []
        res = GuardedStep(fn, max_retries=2, on_restore=lambda: restores.append(1))()
        assert res.value == 42
        assert res.recovered
        assert restores == [1]

    def test_non_retryable_surfaces_immediately(self):
        fn = Flaky(1, exc=ValueError)
        step = GuardedStep(fn, max_retries=5)
        with pytest.raises(ValueError):
            step()
        assert fn.calls == 1
        assert step.failures == []

    def test_exponential_backoff_schedule(self):
        sleeps = []
        fn = Flaky(3)
        res = GuardedStep(
            fn, max_retries=3, backoff_s=0.1, backoff_mult=2.0,
            sleep=sleeps.append,
        )()
        assert res.value == 42
        assert sleeps == [0.1, 0.2, 0.4]

    def test_backoff_resets_after_restore(self):
        sleeps = []
        fn = Flaky(4)
        GuardedStep(
            fn, max_retries=1, backoff_s=0.1, sleep=sleeps.append,
            on_restore=lambda: None,
        )()
        # attempts 1,2 fail -> one backoff sleep between; attempt 3 fails
        # (> max_retries) -> restore, delay resets; then 4 fails -> 0.1 again
        assert sleeps[0] == pytest.approx(0.1)
        assert 0.1 in sleeps[1:]  # the post-restore delay restarted

    def test_zero_backoff_never_sleeps(self):
        sleeps = []
        GuardedStep(Flaky(2), max_retries=2, sleep=sleeps.append)()
        assert sleeps == []


class TestStragglerPolicy:
    def test_first_observation_seeds_ewma(self):
        p = StragglerPolicy()
        out = p.observe(1.0)
        assert not out["slow"]
        assert out["ewma_s"] == pytest.approx(1.0)

    def test_slow_step_flagged_and_not_folded_into_ewma(self):
        p = StragglerPolicy(tolerance=2.0)
        p.observe(1.0)
        out = p.observe(5.0)  # > 2 * ewma
        assert out["slow"]
        assert p.ewma_s == pytest.approx(1.0)  # outlier excluded
        assert p.slow_steps == [2]

    def test_fast_steps_update_ewma(self):
        p = StragglerPolicy(ewma_alpha=0.5)
        p.observe(1.0)
        p.observe(2.0)  # under 2x deadline -> folds in
        assert p.ewma_s == pytest.approx(1.5)

    def test_eject_after_consecutive_violations(self):
        p = StragglerPolicy(tolerance=2.0, eject_after=3)
        p.observe(1.0)
        outs = [p.observe(10.0) for _ in range(3)]
        assert [o["recommend_eject"] for o in outs] == [False, False, True]

    def test_fast_step_resets_consecutive_count(self):
        p = StragglerPolicy(tolerance=2.0, eject_after=2)
        p.observe(1.0)
        p.observe(10.0)
        p.observe(1.0)  # resets
        out = p.observe(10.0)
        assert not out["recommend_eject"]


class TestElasticRemesh:
    def test_full_pod_keeps_preferred_model_axis(self):
        (data, model), plan = plan_elastic_remesh(256, prefer_model=16)
        assert (data, model) == (16, 16)
        assert plan["devices_idle"] == 0

    def test_device_loss_shrinks_data_axis_first(self):
        (data, model), plan = plan_elastic_remesh(255, prefer_model=16)
        assert model == 16
        assert data == 15
        assert plan["devices_used"] == 240
        assert plan["devices_idle"] == 15

    def test_model_axis_shrinks_only_below_one_replica(self):
        (data, model), _ = plan_elastic_remesh(12, prefer_model=16, min_model=4)
        assert model == 8
        assert data == 1

    def test_too_few_devices_raises(self):
        with pytest.raises(ValueError):
            plan_elastic_remesh(2, prefer_model=16, min_model=4)
