import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.distances import (
    cos_to_euclidean,
    cosine_distance,
    euclidean_to_cos,
    l2_normalize,
    pairwise_cosine_distance,
)


def test_normalize_unit_norm():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 33)).astype(np.float32)
    y = np.asarray(l2_normalize(x))
    np.testing.assert_allclose(np.linalg.norm(y, axis=1), 1.0, rtol=1e-5)


def test_pairwise_matches_direct():
    rng = np.random.default_rng(1)
    q = np.asarray(l2_normalize(rng.standard_normal((10, 20)).astype(np.float32)))
    db = np.asarray(l2_normalize(rng.standard_normal((17, 20)).astype(np.float32)))
    m = np.asarray(pairwise_cosine_distance(q, db))
    for i in range(10):
        for j in range(17):
            assert m[i, j] == pytest.approx(1.0 - float(q[i] @ db[j]), abs=1e-5)


def test_eq1_paper_example():
    """Paper: d_cos = 0.5  =>  d_euc = 1.0."""
    assert cos_to_euclidean(0.5) == pytest.approx(1.0)
    assert euclidean_to_cos(1.0) == pytest.approx(0.5)


@given(st.floats(min_value=0.0, max_value=2.0))
@settings(max_examples=50, deadline=None)
def test_eq1_roundtrip(d_cos):
    assert euclidean_to_cos(cos_to_euclidean(d_cos)) == pytest.approx(d_cos, abs=1e-9)


def test_eq1_consistent_with_actual_norms():
    """d_euc(u,v) on unit vectors must equal sqrt(2 d_cos(u,v))."""
    rng = np.random.default_rng(2)
    u = np.asarray(l2_normalize(rng.standard_normal(16)))
    v = np.asarray(l2_normalize(rng.standard_normal(16)))
    d_cos = 1.0 - float(u @ v)
    d_euc = float(np.linalg.norm(u - v))
    assert d_euc == pytest.approx(float(cos_to_euclidean(d_cos)), abs=1e-6)
