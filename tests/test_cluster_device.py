"""One-launch device-resident clustering: kernel/ref parity for the
rectangular + transposed label-prop kernels, exact-label parity of the
packed cluster program against the host unpack→union-find oracle
(single device and 4-forced-host-device mesh, ragged n, post-
partial_fit capacity-padded operands), the streaming bipartite
connectivity, and the one-device_get contract."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core.laf_dbscan import laf_dbscan
from repro.core.metrics import adjusted_rand_index
from repro.core.range_query import pack_bitmap, range_counts
from repro.core.union_find import UnionFind, union_star
from repro.kernels.label_prop import packed_cluster_labels, packed_connectivity
from repro.kernels.label_prop.kernel import col_reduce_pallas, label_prop_rect_pallas
from repro.kernels.label_prop.ref import col_reduce_ref, label_prop_rect_ref

BIG = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# kernel vs ref (interpret-mode parity, mirrors the hamming_filter suite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("r,w,row_tile,word_tile", [
    (64, 4, 32, 2),       # multi-tile both axes
    (32, 2, 32, 2),       # single tile
    (128, 8, 64, 4),
])
def test_rect_kernel_matches_ref(r, w, row_tile, word_tile):
    rng = np.random.default_rng(r * w)
    bitmap = jnp.asarray(rng.integers(0, 2**32, (r, w), dtype=np.uint32))
    col_labels = jnp.asarray(rng.permutation(w * 32).astype(np.int32))
    # inactive rows carry BIG — the row-label side must pass through
    row_labels = np.full(r, BIG, np.int32)
    active = rng.random(r) < 0.5
    row_labels[active] = rng.integers(0, w * 32, active.sum())
    row_labels = jnp.asarray(row_labels)
    got = label_prop_rect_pallas(
        row_labels, col_labels, bitmap,
        row_tile=row_tile, word_tile=word_tile, interpret=True,
    )
    ref = label_prop_rect_ref(row_labels, col_labels, bitmap, BIG)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("r,w,row_tile,word_tile", [
    (64, 4, 32, 2),
    (96, 6, 32, 2),
])
def test_col_reduce_kernel_matches_ref(r, w, row_tile, word_tile):
    rng = np.random.default_rng(r + w)
    bitmap = jnp.asarray(rng.integers(0, 2**32, (r, w), dtype=np.uint32))
    # BIG row values model non-core rows; zero weights model padding
    row_vals = np.where(rng.random(r) < 0.4, BIG, rng.integers(0, 10_000, r)).astype(np.int32)
    row_weights = (rng.random(r) < 0.8).astype(np.int32)
    cmin, csum = col_reduce_pallas(
        bitmap, jnp.asarray(row_vals), jnp.asarray(row_weights),
        row_tile=row_tile, word_tile=word_tile, interpret=True,
    )
    rmin, rsum = col_reduce_ref(bitmap, jnp.asarray(row_vals), jnp.asarray(row_weights), BIG)
    np.testing.assert_array_equal(np.asarray(cmin), np.asarray(rmin))
    np.testing.assert_array_equal(np.asarray(csum), np.asarray(rsum))


# ---------------------------------------------------------------------------
# packed cluster program vs the host union-find oracle
# ---------------------------------------------------------------------------


def _host_cluster_oracle(hit, rows, tau, n):
    """The host pass the device program must reproduce bit-exactly."""
    counts = hit.sum(axis=1)
    core_rows = counts >= tau
    core = np.zeros(n, bool)
    core[rows[core_rows]] = True
    uf = UnionFind(n)
    owner = np.full(n, -1, np.int64)
    for bi in np.nonzero(core_rows)[0]:
        nb = np.nonzero(hit[bi] & core)[0]
        union_star(uf.parent, nb)
        noncore = np.nonzero(hit[bi] & ~core)[0]
        r = rows[bi]
        take = (owner[noncore] < 0) | (r < owner[noncore])
        owner[noncore[take]] = r
    rep = np.array([uf.find(j) if core[j] else BIG for j in range(n)])
    return counts, core, rep, owner


@pytest.mark.parametrize("n,ragged", [(96, False), (117, True), (45, True)])
def test_packed_cluster_labels_exact_vs_union_find(n, ragged):
    # ragged n exercises the tail-word mask and row/word padding (the
    # pointer-jumping carry is exercised by whatever component diameters
    # the random graphs produce; rounds < max_iters is asserted below)
    rng = np.random.default_rng(n)
    adj = rng.random((n, n)) < 0.08
    adj = adj | adj.T
    np.fill_diagonal(adj, True)
    rows = np.sort(rng.choice(n, max(8, n - 7), replace=False))
    hit = adj[rows]
    tau = 5
    # inactive (padding) rows ride along with sentinel >= n
    rows_op = np.concatenate([rows, np.full(5, n)]).astype(np.int32)
    slab = np.concatenate([pack_bitmap(hit), np.zeros((5, pack_bitmap(hit).shape[1]), np.uint32)])
    labels, owner, col_sum, counts, rounds = jax.device_get(
        packed_cluster_labels(jnp.asarray(slab), jnp.asarray(rows_op), tau,
                              n=n, row_tile=64, word_tile=2, interpret=True)
    )
    h_counts, h_core, h_rep, h_owner = _host_cluster_oracle(hit, rows, tau, n)
    np.testing.assert_array_equal(counts[: len(rows)], h_counts)
    assert (counts[len(rows):] == 0).all()
    # min-root union-find representative == min-label propagation result
    np.testing.assert_array_equal(labels[:n][h_core], h_rep[h_core])
    # border owner: min executed core row per column
    dev_owner = np.where(owner[:n] == BIG, -1, owner[:n])
    np.testing.assert_array_equal(dev_owner[~h_core], h_owner[~h_core])
    # transposed partials: every valid row's bits, summed down columns
    np.testing.assert_array_equal(col_sum[:n], hit.sum(axis=0))
    assert 0 < rounds < 64


def test_packed_cluster_chain_graph_pointer_jump():
    """Path-graph core component (worst-case diameter): rounds must stay
    logarithmic-ish, far under the trip cap — the pointer-jump carry."""
    n = 200
    adj = np.zeros((n, n), bool)
    idx = np.arange(n - 1)
    adj[idx, idx + 1] = True
    adj = adj | adj.T
    np.fill_diagonal(adj, True)
    rows = np.arange(n, dtype=np.int32)
    labels, _, _, counts, rounds = jax.device_get(
        packed_cluster_labels(jnp.asarray(pack_bitmap(adj)), jnp.asarray(rows),
                              2, n=n, row_tile=64, word_tile=2, interpret=True)
    )
    assert (labels[:n] == 0).all()          # one chain component, rep 0
    assert rounds < 16                       # ~log2(200) with jumping


def test_packed_connectivity_bipartite_vs_host():
    """Streaming block shape: rows are NOT a superset of the core set,
    so propagation must relay rows->cols->rows."""
    rng = np.random.default_rng(11)
    n = 150
    adj = rng.random((n, n)) < 0.06
    adj = adj | adj.T
    np.fill_diagonal(adj, True)
    core = rng.random(n) < 0.5
    rows = np.sort(rng.choice(n, 40, replace=False))
    hit = adj[rows]
    comp, owner, row_first, rounds = jax.device_get(
        packed_connectivity(jnp.asarray(pack_bitmap(hit)), jnp.asarray(rows),
                            jnp.asarray(core[rows]), jnp.asarray(core),
                            row_tile=32, word_tile=2, interpret=True)
    )
    # host oracle: per core row, star-union its core neighbors
    uf = UnionFind(n)
    for bi in np.nonzero(core[rows])[0]:
        union_star(uf.parent, np.nonzero(hit[bi] & core)[0])
    for j in np.nonzero(core)[0]:
        grp = np.nonzero([core[k] and uf.find(k) == uf.find(j) for k in range(n)])[0]
        if comp[j] != BIG:
            assert comp[j] == grp.min()
    # owner: min core row adjacent to each column
    core_rows_hit = hit[core[rows]]
    exp = np.where(core_rows_hit.any(axis=0),
                   np.asarray(rows[core[rows]])[core_rows_hit.argmax(axis=0)], BIG)
    np.testing.assert_array_equal(owner, exp)
    # row_first: min core column per row
    hc = hit & core[None, :]
    expf = np.where(hc.any(axis=1), hc.argmax(axis=1), BIG)
    np.testing.assert_array_equal(row_first[: len(rows)], expf)
    assert rounds < 64


# ---------------------------------------------------------------------------
# laf_dbscan cluster_device parity (the end-to-end contract)
# ---------------------------------------------------------------------------


def _preds(data, eps, noisy_seed=None):
    pred = np.asarray(range_counts(jnp.asarray(data), jnp.asarray(data), eps)).astype(float)
    if noisy_seed is None:
        return pred
    rng = np.random.default_rng(noisy_seed)
    return pred * rng.uniform(0.7, 1.3, len(pred))


class TestClusterDeviceParity:
    def test_forced_device_matches_host_exact_backend(self, tiny_clustered):
        data, _ = tiny_clustered
        eps, tau, alpha = 0.45, 4, 1.2
        # noisy predictions force skips AND rescues through both paths
        for seed in (None, 0):
            pred = _preds(data, eps, seed)
            host = laf_dbscan(data, eps, tau, alpha, pred, cluster_device=False)
            dev = laf_dbscan(data, eps, tau, alpha, pred, cluster_device=True)
            np.testing.assert_array_equal(host.labels, dev.labels)
            np.testing.assert_array_equal(host.core, dev.core)
            assert host.extras == dev.extras
            assert adjusted_rand_index(host.labels, dev.labels) == 1.0

    def test_native_backend_auto_routes_device_non_tile_multiple(self):
        from repro.data.synthetic import make_angular_clusters
        from repro.index.random_projection import RandomProjectionBackend

        n = 389  # not a multiple of any tile/word shape
        data, _ = make_angular_clusters(n, 16, 5, kappa=60, noise_frac=0.25, seed=7)
        eps, tau, alpha = 0.45, 4, 1.2
        pred = _preds(data, eps, 1)
        bk = RandomProjectionBackend(
            n_bits=128, seed=3, device=True, interpret=True,
            chunk=64, q_tile=32, db_tile=128, verify="full",
        ).fit(data)
        assert bk.packs_natively
        host = laf_dbscan(data, eps, tau, alpha, pred, backend=bk, cluster_device=False)
        dev = laf_dbscan(data, eps, tau, alpha, pred, backend=bk, cluster_device="auto")
        np.testing.assert_array_equal(host.labels, dev.labels)
        assert host.extras == dev.extras

    def test_single_device_get_per_clustering(self, tiny_clustered):
        """The one-launch contract: oracle counts at alpha=1.0 leave no
        rescue work, so the whole clustering syncs exactly once."""
        from repro import obs
        from repro.obs import metrics

        data, _ = tiny_clustered
        eps, tau = 0.45, 4
        pred = _preds(data, eps)
        was_metrics = obs.metrics_enabled()
        obs.enable(trace=False, metrics_on=True)
        try:
            fetches = metrics.counter("laf.cluster.device_get")
            rounds = metrics.counter("laf.cluster.rounds")
            f0, r0 = fetches.value, rounds.value
            res = laf_dbscan(data, eps, tau, 1.0, pred, cluster_device=True)
            assert fetches.value - f0 == 1
            assert rounds.value - r0 >= 1
            assert res.extras["n_rescued"] == 0
        finally:
            if not was_metrics:
                obs.disable()

    @pytest.mark.slow
    def test_mesh_parity_with_partial_fit(self, forced_device_run):
        """4-device mesh: sharded one-launch clustering must match the
        host oracle exactly, including after partial_fit leaves the
        backend capacity-padded."""
        out = forced_device_run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.laf_dbscan import laf_dbscan
        from repro.core.range_query import range_counts
        from repro.data.synthetic import make_angular_clusters
        from repro.index.random_projection import RandomProjectionBackend

        mesh = Mesh(np.asarray(jax.devices()), ("data",))
        n = 389
        data, _ = make_angular_clusters(n, 16, 5, kappa=60, noise_frac=0.25, seed=7)
        eps, tau, alpha = 0.45, 4, 1.2

        def preds(d, seed):
            p = np.asarray(range_counts(jnp.asarray(d), jnp.asarray(d), eps)).astype(float)
            return p * np.random.default_rng(seed).uniform(0.75, 1.25, len(p))

        bk = RandomProjectionBackend(
            mesh=mesh, n_bits=64, seed=3, device=True, interpret=True,
            chunk=64, q_tile=32, db_tile=128, verify="full",
        ).fit(data)
        host = laf_dbscan(data, eps, tau, alpha, preds(data, 1), backend=bk,
                          cluster_device=False)
        dev = laf_dbscan(data, eps, tau, alpha, preds(data, 1), backend=bk,
                         cluster_device="auto")
        base_ok = bool(np.array_equal(host.labels, dev.labels)
                       and host.extras == dev.extras)

        extra, _ = make_angular_clusters(137, 16, 5, kappa=60, noise_frac=0.25, seed=11)
        bk.partial_fit(extra)
        full = np.concatenate([data, extra])
        h2 = laf_dbscan(full, eps, tau, alpha, preds(full, 2), backend=bk,
                        cluster_device=False)
        d2 = laf_dbscan(full, eps, tau, alpha, preds(full, 2), backend=bk,
                        cluster_device="auto")
        grown_ok = bool(np.array_equal(h2.labels, d2.labels)
                        and h2.extras == d2.extras)
        print("RESULT:" + __import__("json").dumps(
            {"base_ok": base_ok, "grown_ok": grown_ok,
             "n_clusters": int(d2.labels.max() + 1)}))
        """)
        assert out["base_ok"] and out["grown_ok"]
        assert out["n_clusters"] >= 1


# ---------------------------------------------------------------------------
# streaming: packed connectivity replay parity
# ---------------------------------------------------------------------------


def test_stream_packed_apply_matches_host_path():
    from repro.data.synthetic import make_angular_clusters
    from repro.index.random_projection import RandomProjectionBackend
    from repro.stream import StreamingLAF

    data, _ = make_angular_clusters(600, 16, 5, kappa=60, noise_frac=0.25, seed=3)
    eps, tau = 0.45, 4
    # deterministic mixed predictions: some rows skip, later promote
    est = lambda v: np.where(v[:, 0] > 0, 10.0 * tau, 0.0)
    bk = RandomProjectionBackend(
        n_bits=128, seed=3, device=True, interpret=True,
        chunk=64, q_tile=32, db_tile=128, verify="full",
    )
    a = StreamingLAF(eps, tau, backend="exact", block_size=100,
                     estimator=est, use_estimator=True)
    b = StreamingLAF(eps, tau, backend=bk, block_size=100,
                     estimator=est, use_estimator=True)
    assert b.backend.packs_natively
    promoted = 0
    for start in range(0, 600, 150):
        ra = a.partial_fit(data[start : start + 150])
        rb = b.partial_fit(data[start : start + 150])
        np.testing.assert_array_equal(a.labels(), b.labels())
        np.testing.assert_array_equal(
            a.state.owner[: a.state.n], b.state.owner[: b.state.n]
        )
        np.testing.assert_array_equal(
            a.state.counts[: a.state.n], b.state.counts[: b.state.n]
        )
        assert (ra.n_promoted, ra.n_skipped) == (rb.n_promoted, rb.n_skipped)
        promoted += ra.n_promoted
    assert promoted > 0  # the packed promote path actually ran
