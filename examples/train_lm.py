"""End-to-end LM training driver: train a ~100M-param llama-style model
for a few hundred steps on CPU with the full production substrate
(data pipeline, AdamW, checkpointing + resume, straggler policy).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse

import jax
import jax.numpy as jnp

from repro.data.pipeline import lm_batches
from repro.models.transformer import TransformerConfig, transformer_init, transformer_loss
from repro.train.optimizer import adamw, apply_updates, clip_by_global_norm
from repro.train.trainer import TrainLoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="artifacts/train_lm_ckpt")
    ap.add_argument("--small", action="store_true", help="~10M params for smoke runs")
    args = ap.parse_args()

    if args.small:
        cfg = TransformerConfig(vocab=4096, d_model=256, n_layers=4, n_heads=4,
                                kv_heads=2, d_head=64, d_ff=1024,
                                dtype=jnp.float32, kv_block=128)
    else:
        # ~100M params
        cfg = TransformerConfig(vocab=16384, d_model=640, n_layers=12, n_heads=10,
                                kv_heads=2, d_head=64, d_ff=2560,
                                dtype=jnp.float32, kv_block=128)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params")

    params = transformer_init(jax.random.PRNGKey(0), cfg)
    opt = adamw(lr=3e-4, weight_decay=0.1)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: transformer_loss(p, cfg, batch["tokens"], batch["labels"])
        )(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, {"loss": loss, "grad_norm": gnorm}

    make_batch = lm_batches(0, args.batch, args.seq, cfg.vocab)
    to_dev = lambda b: jax.tree_util.tree_map(jnp.asarray, b)

    out = train_loop(
        TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                        ckpt_every=100, log_every=10),
        step, params, opt_state, make_batch, to_device=to_dev,
    )
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"\nloss: first={losses[0]:.3f} last={losses[-1]:.3f} "
              f"({'improved' if losses[-1] < losses[0] else 'no improvement'})")


if __name__ == "__main__":
    main()
