"""RecSys serving with LAF-clustered retrieval: cluster the candidate
item embeddings offline with LAF-DBSCAN, then serve retrieval requests
by scoring cluster centroids first and only expanding the best clusters
— the paper's technique as a first-class serving feature.

    PYTHONPATH=src python examples/recsys_serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core.laf_dbscan import laf_dbscan
from repro.core.range_query import range_counts
from repro.models import recsys as R
from repro.models.recsys import retrieval_scores


def main():
    cfg = get_arch("bst").make_reduced_config()
    params = R.bst_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # candidate catalogue: structured item embeddings (120 "genres")
    n_cand, d = 20000, cfg.embed_dim
    centers = rng.standard_normal((120, d)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    genre = rng.integers(0, 120, n_cand)
    cands = centers[genre] + 0.05 * rng.standard_normal((n_cand, d)).astype(np.float32)
    cands /= np.linalg.norm(cands, axis=1, keepdims=True)

    # offline: LAF-DBSCAN clusters the candidates (oracle-free estimator:
    # exact counts here stand in for a trained RMI — see quickstart)
    eps, tau = 0.12, 5
    t0 = time.time()
    pred = np.asarray(range_counts(cands, cands, eps)).astype(float)
    res = laf_dbscan(cands, eps, tau, 1.0, pred, seed=0)
    print(f"offline clustering: {res.n_clusters} clusters in {time.time()-t0:.1f}s "
          f"({np.mean(res.labels >= 0) * 100:.0f}% of items clustered)")
    centroids = np.stack([
        cands[res.labels == c].mean(axis=0) for c in range(res.n_clusters)
    ])
    centroids /= np.linalg.norm(centroids, axis=1, keepdims=True)

    # online: user query -> score centroids -> expand top clusters only
    hist = jnp.asarray(rng.integers(0, cfg.item_vocab, (4, cfg.seq_len)).astype(np.int32))
    q = np.array(R.bst_user_embedding(params, cfg, hist))
    q /= np.linalg.norm(q, axis=1, keepdims=True)

    t0 = time.time()
    full = np.asarray(retrieval_scores(jnp.asarray(q), jnp.asarray(cands)))
    top_full = np.argsort(-full, axis=1)[:, :10]
    t_full = time.time() - t0

    t0 = time.time()
    cscores = q @ centroids.T                       # (B, n_clusters)
    top_c = np.argsort(-cscores, axis=1)[:, :8]     # expand 8 best clusters
    top_pruned = []
    for b in range(len(q)):
        mask = np.isin(res.labels, top_c[b])
        idx = np.nonzero(mask)[0]
        s = q[b] @ cands[idx].T
        top_pruned.append(idx[np.argsort(-s)[:10]])
    t_pruned = time.time() - t0

    recall = np.mean([
        len(set(top_full[b]) & set(top_pruned[b])) / 10 for b in range(len(q))
    ])
    frac = np.mean([np.isin(res.labels, top_c[b]).mean() for b in range(len(q))])
    print(f"full scan:          {t_full * 1e3:.1f} ms")
    print(f"cluster-pruned:     {t_pruned * 1e3:.1f} ms "
          f"(scored {frac * 100:.0f}% of candidates)")
    print(f"recall@10 vs full:  {recall * 100:.0f}%")


if __name__ == "__main__":
    main()
