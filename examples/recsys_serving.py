"""RecSys serving with LAF-clustered retrieval: ingest the candidate
item embeddings through the **streaming** LAF-DBSCAN subsystem
(``repro.stream``) — batches append to the signed-RP index via
``partial_fit``, clusters are maintained online, no O(n^2) exact pass —
then serve retrieval requests by scoring cluster centroids first and
expanding only the best clusters (``ClusterIndex.shortlist``), plus
cluster assignment with confidence for the user embeddings themselves
(``stream.assign``).

    PYTHONPATH=src python examples/recsys_serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models import recsys as R
from repro.models.recsys import retrieval_scores
from repro.stream import StreamingLAF


def main():
    cfg = get_arch("bst").make_reduced_config()
    params = R.bst_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # candidate catalogue: structured item embeddings (120 "genres")
    n_cand, d = 20000, cfg.embed_dim
    centers = rng.standard_normal((120, d)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    genre = rng.integers(0, 120, n_cand)
    cands = centers[genre] + 0.05 * rng.standard_normal((n_cand, d)).astype(np.float32)
    cands /= np.linalg.norm(cands, axis=1, keepdims=True)

    # offline->online: the catalogue arrives in batches; the streaming
    # subsystem appends each one to the ANN index (backend=
    # "random_projection", device="auto") and maintains the clusters —
    # points crossing tau promote, clusters merge, no refits
    eps, tau, batch = 0.12, 5, 4000
    stream = StreamingLAF(eps, tau, backend="random_projection", device="auto")
    t0 = time.time()
    for start in range(0, n_cand, batch):
        rep = stream.partial_fit(cands[start : start + batch])
    labels = stream.labels()
    print(
        f"streaming ingest:   {stream.n_clusters} clusters in {time.time()-t0:.1f}s "
        f"({np.mean(labels >= 0) * 100:.0f}% of items clustered, "
        f"{n_cand // batch} batches, last batch {rep.elapsed_s*1e3:.0f} ms)"
    )
    snapshot = stream.snapshot()  # centroids + members + signature band

    # online: user query -> score centroids -> expand top clusters only
    hist = jnp.asarray(rng.integers(0, cfg.item_vocab, (4, cfg.seq_len)).astype(np.int32))
    q = np.array(R.bst_user_embedding(params, cfg, hist))
    q /= np.linalg.norm(q, axis=1, keepdims=True)

    t0 = time.time()
    full = np.asarray(retrieval_scores(jnp.asarray(q), jnp.asarray(cands)))
    top_full = np.argsort(-full, axis=1)[:, :10]
    t_full = time.time() - t0

    t0 = time.time()
    top_c = snapshot.shortlist(q, 8)                # expand 8 best clusters
    top_pruned = []
    for b in range(len(q)):
        idx = np.concatenate([snapshot.members(c) for c in top_c[b]])
        s = q[b] @ cands[idx].T
        top_pruned.append(idx[np.argsort(-s)[:10]])
    t_pruned = time.time() - t0

    recall = np.mean([
        len(set(top_full[b]) & set(top_pruned[b])) / 10 for b in range(len(q))
    ])
    frac = np.mean([np.isin(labels, top_c[b]).mean() for b in range(len(q))])
    print(f"full scan:          {t_full * 1e3:.1f} ms")
    print(f"cluster-pruned:     {t_pruned * 1e3:.1f} ms "
          f"(scored {frac * 100:.0f}% of candidates)")
    print(f"recall@10 vs full:  {recall * 100:.0f}%")

    # serving-grade assignment: which cluster does each *user* belong
    # to, and with what confidence (fraction of their eps-neighbors in
    # that cluster)?  -1 = no cluster reaches this user's taste region.
    res = stream.assign(q)
    for b in range(len(q)):
        print(f"user {b}: cluster {res.labels[b]:>3d}  "
              f"confidence {res.confidence[b]:.2f}  ({res.n_hits[b]} eps-neighbors)")


if __name__ == "__main__":
    main()
