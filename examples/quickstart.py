"""Quickstart: LAF-DBSCAN end to end on synthetic angular data.

    PYTHONPATH=src python examples/quickstart.py

Follows the paper's protocol (§3.1): generate normalized high-dim
vectors, 8:2 split, train the RMI cardinality estimator on the train
split, cluster the test split with LAF-DBSCAN, compare against exact
DBSCAN (ground truth) on quality AND speed.
"""

import time

import numpy as np

from repro.core.dbscan import dbscan_parallel
from repro.core.metrics import adjusted_mutual_info, adjusted_rand_index
from repro.core.pipeline import LAFPipeline
from repro.data.synthetic import make_angular_clusters


def main():
    print("generating 8000 x 128-d vMF mixture (40 clusters + 30% noise)...")
    data, _ = make_angular_clusters(
        8000, 128, 40, kappa=128 / 0.3, noise_frac=0.30, seed=0
    )
    eps, tau, alpha = 0.5, 5, 1.5

    pipe = LAFPipeline(eps_grid=(0.3, 0.4, 0.5, 0.6), epochs=5, seed=0)
    print("training the RMI cardinality estimator on the 80% split...")
    test = pipe.fit_split(data)
    print(f"  trained in {pipe.estimator.train_seconds:.1f}s "
          f"(excluded from clustering time, per the paper)")

    print(f"clustering the {len(test)}-point test split...")
    t0 = time.time()
    gt = dbscan_parallel(test, eps, tau)
    t_dbscan = time.time() - t0

    out = pipe.cluster_laf_dbscan(test, eps, tau, alpha)
    res = out.result

    ari = adjusted_rand_index(res.labels, gt.labels)
    ami = adjusted_mutual_info(res.labels, gt.labels)
    print(f"\nDBSCAN (ground truth): {gt.n_clusters} clusters, "
          f"noise {gt.noise_ratio:.2f}, {t_dbscan:.2f}s, {gt.n_range_queries} range queries")
    print(f"LAF-DBSCAN:            {res.n_clusters} clusters, "
          f"noise {res.noise_ratio:.2f}, {out.elapsed_s:.2f}s, {res.n_range_queries} range queries")
    print(f"  quality vs DBSCAN:   ARI={ari:.4f}  AMI={ami:.4f}")
    print(f"  speedup:             x{t_dbscan / out.elapsed_s:.2f} "
          f"({res.extras['n_skipped']} queries skipped, "
          f"{res.extras['n_rescued']} false negatives rescued by post-processing)")


if __name__ == "__main__":
    main()
