"""LAF applied to LM-produced embeddings — the framework integration the
paper targets (clustering neural embeddings).

Trains nothing: a tiny llama-style model embeds token sequences; the
final-hidden-state mean becomes each sequence's embedding; LAF-DBSCAN
clusters them with the learned estimator, vs exact DBSCAN.

    PYTHONPATH=src python examples/cluster_embeddings.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dbscan import dbscan_parallel
from repro.core.metrics import adjusted_rand_index
from repro.core.pipeline import LAFPipeline
from repro.models.transformer import TransformerConfig, transformer_hidden, transformer_init


def main():
    cfg = TransformerConfig(vocab=512, d_model=128, n_layers=2, n_heads=4,
                            kv_heads=2, d_head=32, d_ff=512, dtype=jnp.float32,
                            kv_block=64)
    params = transformer_init(jax.random.PRNGKey(0), cfg)

    # synthesize "documents": 30 topics = 30 token distributions
    rng = np.random.default_rng(0)
    n_docs, seq = 4000, 64
    n_topics = 12
    topic_of_doc = rng.integers(0, n_topics, n_docs)
    topic_vocab = rng.integers(0, cfg.vocab, size=(n_topics, 12))  # 12 words/topic
    toks = np.stack(
        [rng.choice(topic_vocab[t], size=seq) for t in topic_of_doc]
    ).astype(np.int32)

    print(f"embedding {n_docs} documents with the LM backbone...")
    embed = jax.jit(
        lambda tk: transformer_hidden(params, cfg, tk).mean(axis=1)
    )
    embs = []
    for i in range(0, n_docs, 512):
        embs.append(np.asarray(embed(jnp.asarray(toks[i : i + 512]))))
    embs = np.concatenate(embs)
    # center then normalize: raw untrained-LM embeddings share a huge
    # common component; centering exposes the topical signal (standard
    # embedding post-processing)
    embs -= embs.mean(axis=0, keepdims=True)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)  # angular space

    # auto-select eps: median distance to the tau-th neighbor (the
    # classic k-dist heuristic), so the example is robust to whatever
    # geometry the untrained backbone produces
    tau = 5
    sample = embs[:512]
    dots = sample @ embs.T
    kth = np.sort(1.0 - dots, axis=1)[:, tau]
    eps = float(np.round(np.median(kth) * 1.2, 3))
    print(f"auto-selected eps={eps} (k-dist heuristic)")
    grid = tuple(np.round(np.linspace(eps * 0.5, eps * 1.5, 4), 3))
    pipe = LAFPipeline(eps_grid=grid, epochs=4, seed=0)
    # unshuffled 8:2 split so test rows stay aligned with their topics
    k = int(0.8 * len(embs))
    pipe.fit(embs[:k])
    test, test_topics = embs[k:], topic_of_doc[k:]

    gt = dbscan_parallel(test, eps, tau)
    out = pipe.cluster_laf_dbscan(test, eps, tau, alpha=1.2)
    print(f"DBSCAN: {gt.n_clusters} clusters | LAF-DBSCAN: {out.result.n_clusters} "
          f"({out.elapsed_s:.2f}s, {out.result.extras['n_skipped']} queries skipped)")
    print(f"ARI vs DBSCAN:   {adjusted_rand_index(out.result.labels, gt.labels):.4f}")
    print(f"ARI vs topics:   {adjusted_rand_index(out.result.labels, test_topics):.4f} "
          f"(how well clusters recover the true topics)")


if __name__ == "__main__":
    main()
