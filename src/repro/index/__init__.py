"""``repro.index`` — pluggable eps-range-query backends for the engines.

``as_fitted("exact" | "random_projection", data)`` is the entry point
the ``repro.core`` engines use; see ``base`` for the protocol and the
sibling modules for the implementations.  The TPU tile of the
random-projection pipeline lives in ``repro.kernels.hamming_filter``,
and its multi-device form in ``repro.distributed.index_plane``.

``random_projection`` is imported lazily (PEP 562): its module pulls in
the kernel package, which itself leans on :mod:`repro.index.signatures`
— an eager import here would make ``import repro.kernels.…`` order-
dependent (the cycle the sharded index plane would otherwise trip).
"""

from .base import BACKENDS, RangeBackend, as_fitted, make_backend, register_backend  # noqa: F401
from .exact import ExactBackend  # noqa: F401
from .signatures import (  # noqa: F401
    collision_fraction,
    hamming_band,
    hamming_numpy,
    make_projection,
    shard_signatures,
    sign_signatures,
)

_LAZY = {"RandomProjectionBackend", "suggest_margin"}


def __getattr__(name):
    if name in _LAZY:
        from . import random_projection

        return getattr(random_projection, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | _LAZY)
