"""``repro.index`` — pluggable eps-range-query backends for the engines.

``as_fitted("exact" | "random_projection", data)`` is the entry point
the ``repro.core`` engines use; see ``base`` for the protocol and the
sibling modules for the implementations.  The TPU tile of the
random-projection pipeline lives in ``repro.kernels.hamming_filter``.
"""

from .base import BACKENDS, RangeBackend, as_fitted, make_backend, register_backend  # noqa: F401
from .exact import ExactBackend  # noqa: F401
from .random_projection import RandomProjectionBackend  # noqa: F401
from .signatures import (  # noqa: F401
    collision_fraction,
    hamming_band,
    hamming_numpy,
    make_projection,
    sign_signatures,
)
