"""Exact range backend: the blocked-matmul engine as a ``RangeBackend``.

This is the same thresholded matmul the engines inlined before the
index subsystem existed (numpy BLAS; d_cos(q, x) < eps  <=>  <q, x> >
1 - eps on normalized vectors), so swapping an engine to
``backend="exact"`` is behaviour-preserving.
"""

from __future__ import annotations

import numpy as np

from ..core.range_query import range_counts
from .base import RangeBackend, register_backend

__all__ = ["ExactBackend"]


@register_backend
class ExactBackend(RangeBackend):
    name = "exact"

    def __init__(self, *, block_size: int = 2048, device="auto"):
        # ``device`` is accepted for engine-kwarg uniformity with the
        # ANN backend and is a no-op here: whole-database counts already
        # run through the jit'd device-placed lax.scan engine, and the
        # blocked BLAS matmul is the hit-matrix oracle by definition.
        self.block_size = block_size
        self.device = device
        self._data: np.ndarray | None = None
        self._buf: np.ndarray | None = None  # amortized-doubling append buffer

    def fit(self, data: np.ndarray) -> "ExactBackend":
        if self._data is data:
            return self
        self._data = np.ascontiguousarray(data, dtype=np.float32)
        self._buf = None
        return self

    def partial_fit(self, rows: np.ndarray) -> "ExactBackend":
        """Append rows in amortized O(rows): the database lives as a view
        into a doubling buffer, so streaming ingest never re-copies the
        whole history per batch."""
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        if self._data is None:
            return self.fit(rows)
        n, b = self._data.shape[0], rows.shape[0]
        if self._buf is None or n + b > self._buf.shape[0]:
            cap = max(2 * (n if self._buf is None else self._buf.shape[0]), n + b)
            buf = np.zeros((cap, self._data.shape[1]), dtype=np.float32)
            buf[:n] = self._data
            self._buf = buf
        self._buf[n : n + b] = rows
        self._data = self._buf[: n + b]
        return self

    def state_export(self):
        assert self._data is not None, "call fit() first"
        # export the full doubling buffer (capacity contract: restored
        # shapes == pre-crash shapes), falling back to the exact-n array
        # when no append has happened yet
        buf = self._buf if self._buf is not None else self._data
        return {"n": np.int64(self._data.shape[0]), "buf": np.ascontiguousarray(buf)}

    def state_import(self, state) -> "ExactBackend":
        n = int(state["n"])
        self._buf = np.ascontiguousarray(state["buf"], dtype=np.float32)
        self._data = self._buf[:n]
        return self

    def query_hits(self, rows: np.ndarray, eps: float) -> np.ndarray:
        assert self._data is not None, "call fit() first"
        return (self._data[rows] @ self._data.T) > (1.0 - eps)

    def query_hits_subset(
        self, rows: np.ndarray, cols: np.ndarray, eps: float
    ) -> np.ndarray:
        assert self._data is not None, "call fit() first"
        return (self._data[rows] @ self._data[cols].T) > (1.0 - eps)

    def query_counts(self, rows: np.ndarray, eps: float) -> np.ndarray:
        assert self._data is not None, "call fit() first"
        rows = np.asarray(rows)
        n = self._data.shape[0]
        if len(rows) == n and np.array_equal(rows, np.arange(n)):
            # whole-database counts: the jit'd blocked lax.scan engine
            # (device-placed; bit-for-bit the pre-index dbscan_parallel)
            return np.asarray(
                range_counts(self._data, self._data, eps, block_size=self.block_size)
            ).astype(np.int64)
        return super().query_counts(rows, eps)
