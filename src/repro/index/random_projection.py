"""Signed-random-projection ANN range backend (sDBSCAN-style).

Pipeline per query block:

1. **Hamming pre-filter** — XOR + popcount between the block's packed
   sign signatures and the whole database's, as one fused jit'd pass
   (``n_bits/32`` uint32 words per pair instead of ``d`` fp32 FMAs — the
   orders-of-magnitude candidate pruning the related work reports).
2. **Band split** — Binomial concentration (see ``signatures``) puts
   true eps-neighbors below ``t_lo`` with probability ~Phi(margin) and
   non-neighbors above ``t_hi``; only the band in between is ambiguous.
3. **Exact verify** — band pairs get exact dot products (gathered
   pairwise einsum when the band is sparse; dense matmul fallback when
   a block's band saturates, so adversarial eps degrade to exact cost
   rather than wrong answers).

``verify="full"`` disables the sure-accept shortcut and exact-verifies
every candidate (hits then have no false positives; misses are bounded
by the pre-filter's margin).  ``verify="band"`` is the fast default and
what the benchmarks run.

Execution paths — **one contract, three evaluators**:

* ``device=False`` — the host numpy path above (the oracle).
* ``device=True`` — every query routes through the fused Pallas
  ``hamming_filter`` kernel (``repro.kernels.hamming_filter``), which
  implements the identical dual-threshold predicate per
  (q_tile × db_tile) tile: sure-accepts never touch the MXU and
  band-free tiles skip their verify matmul entirely.
* ``device="auto"`` (default) — the kernel when a real accelerator
  backs JAX, the host path otherwise, so CPU containers keep BLAS speed
  while TPU/GPU sessions get the fused tile with zero configuration.
* ``mesh=`` — device evaluation additionally routes whole-database
  queries through the sharded index plane
  (``repro.distributed.index_plane``): ``fit`` co-shards the database
  rows and the packed signature table over the mesh's data axes once,
  and every sweep runs the fused tile shard-locally, moving only
  per-shard counts/bitmap words.  Column-subset queries gather their
  (small) column side to one device and reuse the plain kernel.

Device evaluation runs through the **device-resident sweep engine**
(``repro.index.sweep``, ``sweep=True``, the default): all chunks of a
query sweep execute inside one jitted launch (``chunks_per_launch``
chunks per compiled program, results synced to host exactly once), with
the db tile padding and the padded-row corrections applied once per
sweep.  Under ``mesh=`` the engine software-pipelines the plane:
chunk k's cross-shard psum overlaps chunk k+1's shard-local
popcount+verify (``pipeline_depth=2``; ``1`` serializes — the parity
baseline).  ``sweep=False`` keeps the legacy per-chunk dispatch loop
(one launch + one synchronous device→host round-trip per chunk) as the
measured comparison baseline — see ``benchmarks/index_bench.py
--sweep``.

All paths evaluate :func:`repro.index.signatures.band_hits`, so hit
sets are identical (up to fp summation order on exact-boundary dots).
"""

from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.hamming_filter.ops import (
    DEFAULT_DB_TILE,
    DEFAULT_Q_TILE,
    _pad_col_hits,
    default_interpret,
    hamming_filter_bitmap,
    hamming_filter_count,
)
from ..obs import get_logger, metrics as _metrics, rate_limited_warn
from ..testing import faults as _faults
from ..train.fault_tolerance import GuardedStep
from .base import RangeBackend, register_backend
from .signatures import (
    hamming_band,
    hamming_numpy,
    hamming_words,
    make_projection,
    sign_signatures,
)
from .sweep import (
    DEFAULT_CHUNKS_PER_LAUNCH,
    sweep_bitmap,
    sweep_bitmap_device,
    sweep_counts,
)

__all__ = ["RandomProjectionBackend", "suggest_margin", "record_occupancy"]

# jit'd full-database sweep (fused XOR+popcount+reduce)
_hamming_sweep = jax.jit(hamming_words)


@register_backend
class RandomProjectionBackend(RangeBackend):
    name = "random_projection"

    def __init__(
        self,
        *,
        n_bits: int = 512,
        margin: float = 3.0,
        seed: int = 0,
        verify: str = "band",
        block_size: int = 2048,
        chunk: int = 256,
        max_band_frac: float = 0.05,
        device: Union[bool, str] = "auto",
        interpret: Optional[bool] = None,
        q_tile: int = DEFAULT_Q_TILE,
        db_tile: int = DEFAULT_DB_TILE,
        mesh=None,
        mesh_axes=None,
        sweep: bool = True,
        chunks_per_launch: int = DEFAULT_CHUNKS_PER_LAUNCH,
        pipeline_depth: int = 2,
        donate="auto",
        on_device_fault: str = "degrade",
        fault_retries: int = 2,
        fault_backoff_s: float = 0.02,
    ):
        if verify not in ("band", "full"):
            raise ValueError(f"verify must be 'band' or 'full', got {verify!r}")
        if device not in (True, False, "auto"):
            raise ValueError(f"device must be True, False, or 'auto', got {device!r}")
        if on_device_fault not in ("degrade", "raise"):
            raise ValueError(
                f"on_device_fault must be 'degrade' or 'raise', got {on_device_fault!r}"
            )
        self.n_bits = n_bits
        self.margin = margin
        self.seed = seed
        self.verify = verify
        self.block_size = block_size
        self.chunk = chunk
        self.max_band_frac = max_band_frac
        self.device = device
        self.interpret = interpret
        self.q_tile = q_tile
        self.db_tile = db_tile
        # mesh= shards device evaluation through the index plane; the
        # host path ignores it (the oracle stays single-process)
        self.mesh = mesh
        self.mesh_axes = None if mesh_axes is None else tuple(mesh_axes)
        # sweep=True: device queries run through the one-launch sweep
        # engine (repro.index.sweep); False keeps the legacy per-chunk
        # dispatch loop as the measured baseline
        self.sweep = bool(sweep)
        self.chunks_per_launch = int(chunks_per_launch)
        self.pipeline_depth = int(pipeline_depth)
        self.donate = donate
        # device-fault policy: "degrade" falls back to the bit-exact
        # host oracle after ``fault_retries`` exponential-backoff
        # retries; "raise" surfaces the failure to the caller.  Three
        # consecutive degraded queries trip the sticky device-loss
        # breaker (``_device_disabled``) — further queries go straight
        # to host with no retry latency until the breaker is reset.
        self.on_device_fault = on_device_fault
        self.fault_retries = int(fault_retries)
        self.fault_backoff_s = float(fault_backoff_s)
        self._fault_streak = 0
        self._device_disabled = False
        self._data: Optional[np.ndarray] = None
        self._sigs: Optional[np.ndarray] = None
        # append buffers: ``_data``/``_sigs`` are row views into these;
        # ``partial_fit`` grows them by amortized doubling so streaming
        # ingest is O(batch), not O(n), per batch.  Device copies hold
        # the *capacity*-shaped buffers (zero rows, zero signature words
        # past ``n`` — exactly the padded-row shape ``_pad_col_hits``
        # corrects), so the kernel and the jit'd host sweep recompile
        # once per doubling instead of once per batch.
        self._data_buf: Optional[np.ndarray] = None
        self._sigs_buf: Optional[np.ndarray] = None
        self._sigs_dev = None
        self._data_dev = None
        # sweep-engine caches: db-tile-padded capacity operands (device
        # path) and the host-view signature upload (host path) — both
        # invalidated with the raw device copies
        self._sweep_dev = None
        self._host_sigs_dev = None
        self._plan = None
        self.projection: Optional[np.ndarray] = None
        # eps values whose band occupancy was already measured into the
        # index.band.* metrics (one sampled pass per (backend, eps))
        self._occ_recorded: set = set()

    @property
    def use_device(self) -> bool:
        """Whether queries run through the fused Pallas tile."""
        if self._device_disabled:
            return False
        if self.device == "auto":
            return not default_interpret()
        return bool(self.device)

    @property
    def _launch_site(self) -> str:
        """Fault-injection site name for this backend's device dispatch."""
        if self.mesh is not None:
            return "plane.launch"
        return "sweep.launch" if self.sweep else "chunk.launch"

    def reset_device(self) -> None:
        """Re-arm the device path after a sticky device-loss degrade."""
        self._device_disabled = False
        self._fault_streak = 0

    def _guard_device(self, op: str, device_fn, host_fn):
        """Run ``device_fn`` under retry-with-backoff; on exhaustion
        degrade to ``host_fn`` (the bit-exact host oracle) per the
        ``on_device_fault`` policy.  All degradation evidence flows
        through the obs plane: ``stream.degraded.*`` counters, a
        rate-limited structured warn, and an ``slo.violation`` event via
        the degraded-SLO sweep."""
        if self._device_disabled:
            return host_fn()
        step = GuardedStep(
            device_fn,
            max_retries=self.fault_retries,
            retryable=(RuntimeError, OSError),
            backoff_s=self.fault_backoff_s,
        )
        try:
            res = step()
        except (RuntimeError, OSError) as e:
            if self.on_device_fault != "degrade":
                raise
            _metrics.counter("stream.degraded.events").inc()
            _metrics.counter(f"stream.degraded.{op}").inc()
            if len(step.failures) > 1:
                _metrics.counter("stream.degraded.retries").inc(len(step.failures) - 1)
            self._fault_streak += 1
            rate_limited_warn(
                get_logger("index"), "degraded", "device_degraded",
                op=op, error=type(e).__name__, streak=self._fault_streak,
            )
            if self._fault_streak >= 3 and not self._device_disabled:
                # device loss: every query is failing through all its
                # retries — stop paying retry latency and pin to host
                self._device_disabled = True
                _metrics.counter("stream.degraded.device_disabled").inc()
                rate_limited_warn(
                    get_logger("index"), "device_loss", "device_disabled",
                    op=op, streak=self._fault_streak,
                )
            from ..obs import slo as _slo

            _slo.check_and_alert(_slo.DEGRADED_SLOS)
            return host_fn()
        if res.attempts > 1:
            _metrics.counter("stream.degraded.retries").inc(res.attempts - 1)
        self._fault_streak = 0
        return res.value

    # -- index build -------------------------------------------------------
    def fit(self, data: np.ndarray) -> "RandomProjectionBackend":
        if self._data is data:
            return self
        data = np.ascontiguousarray(data, dtype=np.float32)
        if (
            self._data is not None
            and self._data.shape == data.shape
            and np.array_equal(self._data, data)
        ):
            # same content through a fresh array object (engines
            # re-asarray their inputs): one O(n*d) compare beats the
            # O(n*d*n_bits) rebuild; adopt the new object so the
            # identity fast-path hits next call
            self._data = data
            return self
        d = data.shape[1]
        self.projection = make_projection(d, self.n_bits, self.seed)
        self._sigs = sign_signatures(data, self.projection)
        self._data = data
        self._data_buf, self._sigs_buf = self._data, self._sigs  # cap == n
        self._sigs_dev = None  # device copies are lazy: rebuilt on demand
        self._data_dev = None
        self._sweep_dev = None
        self._host_sigs_dev = None
        self._reshard()
        return self

    def partial_fit(self, rows: np.ndarray) -> "RandomProjectionBackend":
        """Append rows + their packed signatures (streaming ingest).

        Host-side work is amortized O(rows · (d + n_bits)) per batch:
        the new rows are signed through the *existing* projection and
        written into the doubling buffers; nothing about the
        already-indexed points is recomputed.  Device copies are
        invalidated and lazily re-uploaded at capacity shape — an O(n)
        transfer on the next device-path query (kernel *compilation*
        stays amortized per doubling; a device-side in-place append is a
        possible future upgrade).  Under ``mesh=`` the database and
        signature table are likewise re-co-sharded per append through
        ``shard_database`` / ``shard_signatures`` so the index plane
        keeps its padded-tile invariants (zero pad rows with zero
        signature words).
        """
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        if self._data is None:
            return self.fit(rows)
        n, b = self._data.shape[0], rows.shape[0]
        if b == 0:
            return self
        if n + b > self._data_buf.shape[0]:
            # every doubling is also (at most) one recompile of each
            # capacity-shaped kernel signature — the pairing
            # tests/test_obs.py asserts against sweep.recompiles
            _metrics.counter("index.capacity_doublings").inc()
            # round capacity to the db tile so the capacity-padded
            # kernel operands stay tile-aligned across doublings (the
            # fit()-shaped index has cap == n and may alias caller
            # memory, so the first append always lands here and copies
            # into owned buffers)
            cap = max(2 * self._data_buf.shape[0], n + b)
            cap = -(-cap // self.db_tile) * self.db_tile
            data_buf = np.zeros((cap, self._data.shape[1]), dtype=np.float32)
            sigs_buf = np.zeros((cap, self._sigs.shape[1]), dtype=np.uint32)
            data_buf[:n] = self._data
            sigs_buf[:n] = self._sigs
            self._data_buf, self._sigs_buf = data_buf, sigs_buf
        self._data_buf[n : n + b] = rows
        self._sigs_buf[n : n + b] = sign_signatures(rows, self.projection)
        self._data = self._data_buf[: n + b]
        self._sigs = self._sigs_buf[: n + b]
        self._sigs_dev = None
        self._data_dev = None
        self._sweep_dev = None
        self._host_sigs_dev = None
        self._reshard()
        return self

    def _reshard(self) -> None:
        """(Re-)place the database + signature table on the mesh; no-op
        without one.  Called at fit and after every append — the plane's
        row plan depends on n, so an append re-pads and re-places the
        (host-resident) views in one ``device_put`` each."""
        if self.mesh is None:
            return
        from ..distributed.index_plane import shard_database

        # tile= aligns every shard to the kernel db tile so the sweep
        # engine's scanned kernel calls never re-pad inside the loop
        self._db_plane, self._sig_plane, self._plan = shard_database(
            self.mesh, self._data, self._sigs, self.mesh_axes, tile=self.db_tile
        )

    # -- durability --------------------------------------------------------
    def state_export(self):
        """Capacity-faithful snapshot: the *full* doubling buffers (rows
        + packed signatures, append slack included) plus the live row
        count and the projection.  Importing on a fresh instance
        reproduces identical operand shapes, so a restored replica
        re-enters the pre-crash jit compile caches — restore is
        recompile-free (the laf-lint restored-replica target pins this).
        """
        assert self._data is not None, "call fit() first"
        return {
            "n": np.int64(self._data.shape[0]),
            "data_buf": np.ascontiguousarray(self._data_buf),
            "sigs_buf": np.ascontiguousarray(self._sigs_buf),
            "projection": np.ascontiguousarray(self.projection),
            # config echo: a restore onto a differently-configured
            # instance would silently change signatures / tile shapes
            "n_bits": np.int64(self.n_bits),
            "seed": np.int64(self.seed),
            "db_tile": np.int64(self.db_tile),
        }

    def state_import(self, state) -> "RandomProjectionBackend":
        if int(state["n_bits"]) != self.n_bits:
            raise ValueError(
                f"snapshot n_bits={int(state['n_bits'])} != backend n_bits={self.n_bits}"
            )
        if int(state["db_tile"]) != self.db_tile:
            raise ValueError(
                f"snapshot db_tile={int(state['db_tile'])} != backend db_tile={self.db_tile}"
            )
        n = int(state["n"])
        self._data_buf = np.ascontiguousarray(state["data_buf"], dtype=np.float32)
        self._sigs_buf = np.ascontiguousarray(state["sigs_buf"], dtype=np.uint32)
        self._data = self._data_buf[:n]
        self._sigs = self._sigs_buf[:n]
        self.projection = np.ascontiguousarray(state["projection"], dtype=np.float32)
        self.seed = int(state["seed"])
        self._sigs_dev = None
        self._data_dev = None
        self._sweep_dev = None
        self._host_sigs_dev = None
        self._reshard()
        return self

    @property
    def signatures(self) -> np.ndarray:
        assert self._sigs is not None, "call fit() first"
        return self._sigs

    def band(self, eps: float) -> tuple[int, int]:
        """(t_lo, t_hi) for this index; t_lo is -1 in full-verify mode."""
        t_lo, t_hi = hamming_band(eps, self.n_bits, self.margin)
        if self.verify == "full":
            t_lo = -1
        if (
            _metrics.enabled()
            and self._data is not None
            and float(eps) not in self._occ_recorded
        ):
            # one sampled occupancy pass per (backend, eps) — feeds the
            # index.band.* metrics the acceptance snapshot reports
            self._occ_recorded.add(float(eps))
            try:
                record_occupancy(self, eps)
            except Exception as e:  # instrumentation must not break queries
                rate_limited_warn(
                    get_logger("index"), "occupancy", "occupancy_record_failed",
                    error=type(e).__name__,
                )
        return t_lo, t_hi

    # -- host evaluation ---------------------------------------------------
    def _band_split(self, ham: np.ndarray, eps: float):
        t_lo, t_hi = self.band(eps)
        accept = ham <= t_lo
        band = (ham <= t_hi) & ~accept
        return accept, band

    def _tile_hits(
        self, rows: np.ndarray, cols: Optional[np.ndarray], ham: np.ndarray, eps: float
    ) -> np.ndarray:
        """Band-split + exact verify for one (rows, cols) tile given its
        Hamming distances; ``cols=None`` means the whole database."""
        data = self._data
        thresh = 1.0 - eps
        accept, band = self._band_split(ham, eps)
        pi, pj = np.nonzero(band)
        if len(pi) > self.max_band_frac * band.size:
            # band saturated (eps in the bulk of the pair-distance
            # distribution): dense exact verify of the band for this
            # tile — same predicate as the sparse path (sure-accepts
            # stay accepted), only the evaluation strategy changes
            cdata = data if cols is None else data[cols]
            dots = data[rows] @ cdata.T
            return accept | (band & (dots > thresh))
        hit = accept
        if len(pi):
            cj = pj if cols is None else cols[pj]
            dots = np.einsum("ij,ij->i", data[rows[pi]], data[cj], optimize=True)
            hit = accept.copy()
            hit[pi, pj] = dots > thresh
        return hit

    def _tile_counts(
        self, rows: np.ndarray, ham: np.ndarray, eps: float
    ) -> np.ndarray:
        """Per-row hit counts for one tile without materializing the hit
        matrix: sure-accepts are a row reduction of the Hamming mask and
        band survivors are scatter-added from the verified pairs."""
        data = self._data
        thresh = 1.0 - eps
        accept, band = self._band_split(ham, eps)
        counts = accept.sum(axis=1, dtype=np.int64)
        pi, pj = np.nonzero(band)
        if len(pi) > self.max_band_frac * band.size:
            dots = data[rows] @ data.T
            counts += (band & (dots > thresh)).sum(axis=1, dtype=np.int64)
        elif len(pi):
            dots = np.einsum("ij,ij->i", data[rows[pi]], data[pj], optimize=True)
            # bincount over the verified rows beats np.add.at by an
            # order of magnitude (ufunc.at is unbuffered scalar-at-a-
            # time); this is the host oracle's band-accumulation loop
            counts += np.bincount(
                pi[dots > thresh], minlength=counts.shape[0]
            ).astype(np.int64)
        return counts

    # -- device evaluation (fused Pallas tile) -----------------------------
    @property
    def _dev_pad(self) -> int:
        """Zero rows past n in the capacity-shaped device operands."""
        return self._data_buf.shape[0] - self._data.shape[0]

    def _device_data(self):
        if self._data_dev is None:
            self._data_dev = jnp.asarray(self._data_buf)
        return self._data_dev

    def _device_sigs(self):
        if self._sigs_dev is None:
            self._sigs_dev = jnp.asarray(self._sigs_buf)
        return self._sigs_dev

    def _host_sigs(self):
        """Signature operand for the jit'd host-path Hamming sweep.

        For a fitted index (cap == n, the nominal host/batch case) this
        is the host ``_sigs`` view uploaded once — never the
        capacity-shaped device buffers.  With append slack (host-path
        streaming) it falls back to the capacity buffers on purpose:
        exact-n views would change shape every ``partial_fit`` and
        re-trace the jit'd sweep per batch, where the capacity shape
        amortizes recompiles to once per doubling (callers slice the
        slack columns off with ``[:, :n]``)."""
        if self._sigs_buf is not self._sigs:
            return self._device_sigs()
        if self._host_sigs_dev is None:
            self._host_sigs_dev = jnp.asarray(self._sigs)
        return self._host_sigs_dev

    # -- device-resident sweep engine (repro.index.sweep) ------------------
    def _sweep_db(self):
        """Capacity-shaped operands pre-padded to the db tile, cached so
        a sweep never re-pads.  Tile-aligned capacity (the partial_fit
        shape) shares the plain device copies; otherwise the padded
        copies are built straight from the host buffers so sweep mode
        holds ONE device-resident database, never padded + unpadded."""
        if self._sweep_dev is None:
            pad = (-self._data_buf.shape[0]) % self.db_tile
            if pad == 0:
                self._sweep_dev = (self._device_data(), self._device_sigs())
            else:
                db = np.zeros(
                    (self._data_buf.shape[0] + pad, self._data_buf.shape[1]),
                    dtype=np.float32,
                )
                db[: self._data_buf.shape[0]] = self._data_buf
                dbs = np.zeros(
                    (self._sigs_buf.shape[0] + pad, self._sigs_buf.shape[1]),
                    dtype=np.uint32,
                )
                dbs[: self._sigs_buf.shape[0]] = self._sigs_buf
                self._sweep_dev = (jnp.asarray(db), jnp.asarray(dbs))
        return self._sweep_dev

    def _sweep_q(self, rows: np.ndarray):
        """(q, q_sig) for a whole sweep — one gather, not one per chunk.
        Single-device gathers index the padded sweep operands (row
        indices are < n, so values are identical) instead of forcing a
        second, unpadded device copy into the cache."""
        if self.mesh is not None:
            return jnp.asarray(self._data[rows]), jnp.asarray(self._sigs[rows])
        db, dbs = self._sweep_db()
        ridx = jnp.asarray(rows)
        return db[ridx], dbs[ridx]

    def _sweep_kw(self):
        return dict(
            chunk=self.chunk,
            chunks_per_launch=self.chunks_per_launch,
            q_tile=self.q_tile,
            db_tile=self.db_tile,
            interpret=self.interpret,
            donate=self.donate,
        )

    def _sweep_hits(self, rows: np.ndarray, eps: float) -> np.ndarray:
        _, bitmap = self._sweep_hits_packed(rows, eps)
        from ..core.range_query import unpack_bitmap

        return unpack_bitmap(bitmap, self._data.shape[0])

    def _sweep_hits_packed(self, rows: np.ndarray, eps: float):
        _faults.maybe_fail(self._launch_site, op="hits")
        t_lo, t_hi = self.band(eps)
        q, q_sig = self._sweep_q(rows)
        n = self._data.shape[0]
        if self.mesh is not None:
            return sweep_bitmap(
                q, q_sig, self._db_plane, self._sig_plane, n, eps, t_lo, t_hi,
                mesh=self.mesh, axes=self._plan.axes, depth=self.pipeline_depth,
                **self._sweep_kw(),
            )
        db, dbs = self._sweep_db()
        return sweep_bitmap(q, q_sig, db, dbs, n, eps, t_lo, t_hi, **self._sweep_kw())

    def query_bitmap_device(self, rows: np.ndarray, eps: float):
        """Packed adjacency slab for ``rows`` as **device arrays, no
        host sync** — the feed for the one-launch cluster pass.

        Returns ``(slab, plan)`` from
        :func:`repro.index.sweep.sweep_bitmap_device`: the slab is
        ``(plan.nq_padded, W)`` uint32 over the capacity-padded column
        space with all bits past ``n_points`` cleared; under a mesh its
        words stay sharded on the index plane.  Only meaningful when
        ``packs_natively`` — host callers keep ``query_hits_packed``.
        """
        t_lo, t_hi = self.band(eps)
        q, q_sig = self._sweep_q(rows)
        n = self._data.shape[0]
        if self.mesh is not None:
            return sweep_bitmap_device(
                q, q_sig, self._db_plane, self._sig_plane, n, eps, t_lo, t_hi,
                mesh=self.mesh, axes=self._plan.axes, depth=self.pipeline_depth,
                **self._sweep_kw(),
            )
        db, dbs = self._sweep_db()
        return sweep_bitmap_device(
            q, q_sig, db, dbs, n, eps, t_lo, t_hi, **self._sweep_kw()
        )

    def _sweep_counts(self, rows: np.ndarray, eps: float) -> np.ndarray:
        _faults.maybe_fail(self._launch_site, op="counts")
        t_lo, t_hi = self.band(eps)
        q, q_sig = self._sweep_q(rows)
        n = self._data.shape[0]
        if self.mesh is not None:
            return sweep_counts(
                q, q_sig, self._db_plane, self._sig_plane, n, eps, t_lo, t_hi,
                mesh=self.mesh, axes=self._plan.axes, depth=self.pipeline_depth,
                **self._sweep_kw(),
            )
        db, dbs = self._sweep_db()
        return sweep_counts(q, q_sig, db, dbs, n, eps, t_lo, t_hi, **self._sweep_kw())

    def _q_block(self, rows: np.ndarray):
        """(q, q_sig) jnp arrays for one row chunk.  Under ``mesh=`` the
        gather runs on the host copies — queries are tiny and the device
        database is row-sharded, so a device gather would be a scattered
        collective for no benefit."""
        if self.mesh is not None:
            return jnp.asarray(self._data[rows]), jnp.asarray(self._sigs[rows])
        ridx = jnp.asarray(rows)
        return self._device_data()[ridx], self._device_sigs()[ridx]

    def _device_hits(self, q, q_sig, db, db_sig, nd: int, eps: float) -> np.ndarray:
        """Boolean hits for one query block through
        ``hamming_filter_bitmap`` against a pre-gathered (db, db_sig)
        column side."""
        from ..core.range_query import unpack_bitmap

        _faults.maybe_fail("chunk.launch", op="hits")
        t_lo, t_hi = self.band(eps)
        _, bitmap = hamming_filter_bitmap(
            q, db, q_sig, db_sig, eps, t_hi, t_lo=t_lo,
            q_tile=self.q_tile, db_tile=self.db_tile, interpret=self.interpret,
        )
        return unpack_bitmap(np.asarray(bitmap), nd)

    def _device_counts(self, rows: np.ndarray, eps: float) -> np.ndarray:
        _faults.maybe_fail("chunk.launch", op="counts")
        t_lo, t_hi = self.band(eps)
        q, q_sig = self._q_block(rows)
        counts = hamming_filter_count(
            q, self._device_data(), q_sig, self._device_sigs(),
            eps, t_hi, t_lo=t_lo,
            q_tile=self.q_tile, db_tile=self.db_tile, interpret=self.interpret,
        )
        if self._dev_pad:
            # the capacity tail past n is zero rows with zero signature
            # words — the exact shape the kernel wrappers' padded-row
            # correction models, applied here for the append slack
            counts = counts - _pad_col_hits(q_sig, eps, t_lo, t_hi, self._dev_pad)
        return np.asarray(counts).astype(np.int64)

    # -- sharded evaluation (the index plane) ------------------------------
    def _plane_hits(self, rows: np.ndarray, eps: float) -> np.ndarray:
        """One row chunk through the shard_map'd tile: only the gathered
        per-shard bitmap words come back (the plane pad rows occupy the
        trailing bits, so unpacking the true n drops them)."""
        from ..core.range_query import unpack_bitmap
        from ..distributed.index_plane import sharded_hamming_bitmap

        _faults.maybe_fail("plane.launch", op="hits")
        t_lo, t_hi = self.band(eps)
        q, q_sig = self._q_block(rows)
        _, bitmap = sharded_hamming_bitmap(
            q, self._db_plane, q_sig, self._sig_plane, eps, t_hi, t_lo=t_lo,
            mesh=self.mesh, axes=self._plan.axes,
            q_tile=self.q_tile, db_tile=self.db_tile, interpret=self.interpret,
        )
        return unpack_bitmap(np.asarray(bitmap), self._data.shape[0])

    def _plane_counts(self, rows: np.ndarray, eps: float) -> np.ndarray:
        from ..distributed.index_plane import sharded_hamming_count

        _faults.maybe_fail("plane.launch", op="counts")
        t_lo, t_hi = self.band(eps)
        q, q_sig = self._q_block(rows)
        counts = sharded_hamming_count(
            q, self._db_plane, q_sig, self._sig_plane, eps, t_hi, t_lo=t_lo,
            mesh=self.mesh, axes=self._plan.axes,
            q_tile=self.q_tile, db_tile=self.db_tile, interpret=self.interpret,
        )
        if self._plan.n_pad:
            # the plane saw a pre-padded database (pad rows are zero
            # vectors with zero signatures), so subtract their hits with
            # the same correction the kernel wrappers apply to tile pads
            counts = counts - _pad_col_hits(q_sig, eps, t_lo, t_hi, self._plan.n_pad)
        return np.asarray(counts).astype(np.int64)

    # -- queries -----------------------------------------------------------
    def _padded_chunks(self, rows: np.ndarray):
        """Fixed-size index chunks (padded with row 0) so both the jit'd
        host sweep and the kernel compile once per (chunk, n) shape."""
        c = self.chunk
        for start in range(0, len(rows), c):
            sub = rows[start : start + c]
            padded = np.zeros(c, dtype=np.int64)
            padded[: len(sub)] = sub
            yield start, sub, padded

    def _host_query_hits(self, rows: np.ndarray, eps: float) -> np.ndarray:
        """The host oracle path (also the degraded-mode fallback)."""
        n = self._data.shape[0]
        hit = np.zeros((len(rows), n), dtype=bool)
        sigs = self._host_sigs()
        for start, sub, padded in self._padded_chunks(rows):
            ham = np.asarray(_hamming_sweep(sigs[padded], sigs))[: len(sub), :n]
            hit[start : start + len(sub)] = self._tile_hits(sub, None, ham, eps)
        return hit

    def _dev_query_hits(self, rows: np.ndarray, eps: float) -> np.ndarray:
        if self.sweep:
            return self._sweep_hits(rows, eps)
        n = self._data.shape[0]
        hit = np.zeros((len(rows), n), dtype=bool)
        plane = self.mesh is not None
        for start, sub, padded in self._padded_chunks(rows):
            if plane:
                hit[start : start + len(sub)] = self._plane_hits(padded, eps)[
                    : len(sub)
                ]
                continue
            q, q_sig = self._q_block(padded)
            # nd=n truncates the capacity-pad columns off the bitmap
            hit[start : start + len(sub)] = self._device_hits(
                q, q_sig, self._device_data(), self._device_sigs(), n, eps
            )[: len(sub)]
        return hit

    def query_hits(self, rows: np.ndarray, eps: float) -> np.ndarray:
        assert self._data is not None, "call fit() first"
        rows = np.asarray(rows, dtype=np.int64)
        if self.use_device:
            return self._guard_device(
                "hits",
                lambda: self._dev_query_hits(rows, eps),
                lambda: self._host_query_hits(rows, eps),
            )
        return self._host_query_hits(rows, eps)

    @property
    def packs_natively(self) -> bool:
        return self.use_device and self.sweep

    def _host_query_hits_packed(self, rows: np.ndarray, eps: float):
        from ..core.range_query import pack_bitmap

        hit = self._host_query_hits(rows, eps)
        return hit.sum(axis=1, dtype=np.int64), pack_bitmap(hit)

    def query_hits_packed(self, rows: np.ndarray, eps: float):
        """(counts, packed bitmap) — the sweep engine's native output;
        streaming ingest stores/replays adjacency packed, so this skips
        an unpack→repack round-trip per batch.  Falls back to packing
        the boolean hits on the non-sweep paths."""
        assert self._data is not None, "call fit() first"
        rows = np.asarray(rows, dtype=np.int64)
        if self.packs_natively:
            return self._guard_device(
                "packed",
                lambda: self._sweep_hits_packed(rows, eps),
                lambda: self._host_query_hits_packed(rows, eps),
            )
        return super().query_hits_packed(rows, eps)

    def _dev_query_hits_subset(
        self, rows: np.ndarray, cols: np.ndarray, eps: float
    ) -> np.ndarray:
        # gather the column side once, not per row chunk; subset
        # queries stay single-device even under mesh= (the gathered
        # column side is small, the row-sharded plane only pays off
        # on whole-database sweeps)
        if self.mesh is not None:
            db, db_sig = jnp.asarray(self._data[cols]), jnp.asarray(self._sigs[cols])
        elif self.sweep:
            sdb, sdbs = self._sweep_db()
            cidx = jnp.asarray(cols)
            db, db_sig = sdb[cidx], sdbs[cidx]
        else:
            cidx = jnp.asarray(cols)
            db, db_sig = self._device_data()[cidx], self._device_sigs()[cidx]
        if self.sweep:
            from ..core.range_query import unpack_bitmap

            _faults.maybe_fail(self._launch_site, op="subset")
            t_lo, t_hi = self.band(eps)
            q, q_sig = self._sweep_q(rows)
            _, bitmap = sweep_bitmap(
                q, q_sig, db, db_sig, len(cols), eps, t_lo, t_hi,
                **self._sweep_kw(),
            )
            return unpack_bitmap(bitmap, len(cols))
        hit = np.zeros((len(rows), len(cols)), dtype=bool)
        for start, sub, padded in self._padded_chunks(rows):
            q, q_sig = self._q_block(padded)
            hit[start : start + len(sub)] = self._device_hits(
                q, q_sig, db, db_sig, len(cols), eps
            )[: len(sub)]
        return hit

    def _host_query_hits_subset(
        self, rows: np.ndarray, cols: np.ndarray, eps: float
    ) -> np.ndarray:
        # tile both axes: the host popcount materializes a
        # (rows, cols, words) XOR tensor, so keep tiles bounded even
        # when cols is a large core set
        hit = np.zeros((len(rows), len(cols)), dtype=bool)
        col_tile = 2048
        for rs in range(0, len(rows), self.chunk):
            rsub = rows[rs : rs + self.chunk]
            for cs in range(0, len(cols), col_tile):
                csub = cols[cs : cs + col_tile]
                ham = hamming_numpy(self._sigs[rsub], self._sigs[csub])
                hit[rs : rs + len(rsub), cs : cs + len(csub)] = self._tile_hits(
                    rsub, csub, ham, eps
                )
        return hit

    def query_hits_subset(
        self, rows: np.ndarray, cols: np.ndarray, eps: float
    ) -> np.ndarray:
        assert self._data is not None and self._sigs is not None
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        if self.use_device:
            return self._guard_device(
                "subset",
                lambda: self._dev_query_hits_subset(rows, cols, eps),
                lambda: self._host_query_hits_subset(rows, cols, eps),
            )
        return self._host_query_hits_subset(rows, cols, eps)

    def query_counts(self, rows: np.ndarray, eps: float) -> np.ndarray:
        """Counts fast-path: never materializes a (block, n) hit matrix.

        On device the fused count kernel (no bitmap output) runs per
        chunk; on host each chunk reduces its accepts and scatter-adds
        its verified band pairs directly into the counts vector.
        """
        assert self._data is not None, "call fit() first"
        rows = np.asarray(rows, dtype=np.int64)
        if self.use_device:
            return self._guard_device(
                "counts",
                lambda: self._dev_query_counts(rows, eps),
                lambda: self._host_query_counts(rows, eps),
            )
        return self._host_query_counts(rows, eps)

    def _dev_query_counts(self, rows: np.ndarray, eps: float) -> np.ndarray:
        if self.sweep:
            return self._sweep_counts(rows, eps)
        counts = np.zeros(len(rows), dtype=np.int64)
        plane = self.mesh is not None
        for start, sub, padded in self._padded_chunks(rows):
            if plane:
                counts[start : start + len(sub)] = self._plane_counts(padded, eps)[
                    : len(sub)
                ]
                continue
            counts[start : start + len(sub)] = self._device_counts(padded, eps)[
                : len(sub)
            ]
        return counts

    def _host_query_counts(self, rows: np.ndarray, eps: float) -> np.ndarray:
        counts = np.zeros(len(rows), dtype=np.int64)
        sigs = self._host_sigs()
        for start, sub, padded in self._padded_chunks(rows):
            ham = np.asarray(_hamming_sweep(sigs[padded], sigs))[
                : len(sub), : self._data.shape[0]
            ]
            counts[start : start + len(sub)] = self._tile_counts(sub, ham, eps)
        return counts


# ---------------------------------------------------------------------------
# margin auto-tune: price candidate Hamming bands with the kernel's
# occupancy stats (or the host Hamming sweep) and pick the
# widest band — best recall, ~Phi(margin) — the verify budget affords
# ---------------------------------------------------------------------------


def suggest_margin(
    backend: RandomProjectionBackend,
    eps: float,
    rows: Optional[np.ndarray] = None,
    *,
    margins=(4.0, 3.5, 3.0, 2.5, 2.0, 1.5, 1.0),
    max_band_frac: Optional[float] = None,
    report: bool = False,
):
    """Suggest an ``index_margin`` for a fitted backend at one eps.

    Recall of the dual-threshold contract is set by the band's upper
    edge (misses are pairs beyond ``t_hi``, probability ~1 - Phi(margin))
    while its *cost* is the exact-verify work on band pairs — so the
    auto-tune question is "what is the widest band whose band-pair
    fraction stays under ``max_band_frac``" (default: the backend's own
    saturation threshold).  Occupancy is measured on a deterministic row
    sample: through ``hamming_filter_count(..., return_stats=True)``
    (the kernel's [accept, band, reject] occupancy counters) when the
    backend evaluates on device, through one host Hamming sweep
    otherwise.  Both thresholds are traced in the kernel, so sweeping
    candidate margins re-runs nothing but the popcount pass.

    Returns the chosen margin, or ``(margin, rows)`` with the per-margin
    ``{margin, t_lo, t_hi, band_frac, accept_frac}`` table when
    ``report=True``.  If no candidate fits the budget the narrowest
    (cheapest) one is returned.
    """
    assert backend._data is not None, "call fit() first"
    if max_band_frac is None:
        max_band_frac = backend.max_band_frac
    n = backend._data.shape[0]
    if rows is None:
        rows = np.unique(np.linspace(0, n - 1, min(n, 4 * backend.q_tile)).astype(np.int64))
    rows = np.asarray(rows, dtype=np.int64)

    dev = backend.use_device
    if dev:
        q = jnp.asarray(backend._data[rows])
        q_sig = jnp.asarray(backend._sigs[rows])
        # occupancy stats must price real pairs only, never streaming
        # append slack — reuse the cached device buffers when they are
        # exactly the fitted rows, upload exact-shaped copies otherwise
        if backend._dev_pad:
            db, db_sig = jnp.asarray(backend._data), jnp.asarray(backend._sigs)
        else:
            db, db_sig = backend._device_data(), backend._device_sigs()
        # the kernel's counters run on the *padded* tile grid; pad rows
        # and cols are zero-signature pairs whose Hamming distance to a
        # real row is that row's signature popcount — classify those
        # popcounts per band and subtract, so the table prices real
        # pairs only and agrees with the host table on any n
        zero = np.zeros((1, backend._sigs.shape[1]), np.uint32)
        q_pop = hamming_numpy(backend._sigs[rows], zero)[:, 0].astype(np.int64)
        db_pop = hamming_numpy(backend._sigs, zero)[:, 0].astype(np.int64)
        q_pad = (-len(rows)) % backend.q_tile
        db_pad = (-n) % backend.db_tile
    else:
        ham = hamming_numpy(backend._sigs[rows], backend._sigs)

    table = []
    for m in sorted(margins, reverse=True):
        t_lo, t_hi = hamming_band(eps, backend.n_bits, m)
        if backend.verify == "full":
            t_lo = -1
        if dev:
            _, stats = hamming_filter_count(
                q, db, q_sig, db_sig, eps, t_hi, t_lo=t_lo,
                q_tile=backend.q_tile, db_tile=backend.db_tile,
                interpret=backend.interpret, return_stats=True,
            )
            stats = np.asarray(stats, dtype=np.int64).reshape(-1, 3).sum(axis=0)
            acc, bnd = int(stats[0]), int(stats[1])
            if q_pad or db_pad:
                # real q rows vs zero-padded db cols
                acc -= db_pad * int((q_pop <= t_lo).sum())
                bnd -= db_pad * int(((q_pop > t_lo) & (q_pop <= t_hi)).sum())
                # zero-padded q rows vs real db rows
                acc -= q_pad * int((db_pop <= t_lo).sum())
                bnd -= q_pad * int(((db_pop > t_lo) & (db_pop <= t_hi)).sum())
                # pad-vs-pad corner: Hamming distance 0
                if t_lo >= 0:
                    acc -= q_pad * db_pad
                else:
                    bnd -= q_pad * db_pad
            total = len(rows) * n
            acc_frac, band_frac = acc / total, bnd / total
        else:
            accept = ham <= t_lo
            band = (ham <= t_hi) & ~accept
            acc_frac = accept.mean()
            band_frac = band.mean()
        table.append(
            dict(margin=m, t_lo=t_lo, t_hi=t_hi,
                 band_frac=float(band_frac), accept_frac=float(acc_frac))
        )

    fits = [r for r in table if r["band_frac"] <= max_band_frac]
    chosen = fits[0]["margin"] if fits else table[-1]["margin"]
    chosen_row = next(r for r in table if r["margin"] == chosen)
    _feed_occupancy(chosen_row, len(rows), n)
    return (chosen, table) if report else chosen


def _feed_occupancy(row: dict, nq: int, n: int) -> None:
    """Write one occupancy measurement into the index.band.* metrics:
    raw pair counts (counters, accumulated over measurements) and the
    latest fractions (gauges)."""
    total = nq * n
    acc = int(round(row["accept_frac"] * total))
    bnd = int(round(row["band_frac"] * total))
    _metrics.counter("index.band.accept").inc(acc)
    _metrics.counter("index.band.band").inc(bnd)
    _metrics.counter("index.band.reject").inc(total - acc - bnd)
    _metrics.gauge("index.band.accept_frac").set(row["accept_frac"])
    _metrics.gauge("index.band.band_frac").set(row["band_frac"])
    _metrics.gauge("index.band.reject_frac").set(
        1.0 - row["accept_frac"] - row["band_frac"]
    )


def record_occupancy(
    backend: RandomProjectionBackend, eps: float, rows: Optional[np.ndarray] = None
) -> dict:
    """Measure the dual-threshold occupancy of the backend's own band at
    one eps and feed the ``index.band.*`` metrics.

    Rides the :func:`suggest_margin` machinery with a single candidate
    (the backend's configured margin), so the device path uses the
    kernel's ``return_stats=`` [accept, band, reject] occupancy counters
    with the exact pad-row corrections — on any n, device and host
    measurements agree (the ``tests/test_obs.py`` parity assert).
    Returns the ``{margin, t_lo, t_hi, band_frac, accept_frac}`` row.
    """
    n = backend._data.shape[0]
    if rows is None:
        rows = np.unique(
            np.linspace(0, n - 1, min(n, 4 * backend.q_tile)).astype(np.int64)
        )
    _, table = suggest_margin(
        backend, eps, rows, margins=(backend.margin,),
        max_band_frac=backend.max_band_frac, report=True,
    )
    return table[0]
