"""Signed-random-projection signatures + Hamming-threshold calibration.

For unit vectors x, y and a Gaussian direction r, ``P[sign<x,r> !=
sign<y,r>] = theta(x, y) / pi`` (Goemans–Williamson / SimHash).  With
``n_bits`` independent directions the Hamming distance between sign
signatures is Binomial(n_bits, theta/pi), so an eps-ball in cosine
distance maps to a Hamming band around ``n_bits * arccos(1-eps) / pi``
whose width shrinks like ``sqrt(n_bits)``.  That concentration is what
the ``random_projection`` backend and the ``hamming_filter`` kernel
exploit.

Signatures are packed 32 bits per uint32 word with the same bit order as
:func:`repro.core.range_query.pack_bitmap` (bit j of word w = bit
``32*w + j``), here as a jit'd jnp pipeline so projection + packing is
one fused device pass.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "make_projection",
    "pack_bits",
    "unpack_bits",
    "sign_signatures",
    "shard_signatures",
    "collision_fraction",
    "hamming_band",
    "band_hits",
    "hamming_words",
    "hamming_numpy",
]


def make_projection(d: int, n_bits: int, seed: int = 0) -> np.ndarray:
    """(d, n_bits) float32 Gaussian projection; n_bits % 32 == 0."""
    if n_bits % 32 != 0:
        raise ValueError(f"n_bits must be a multiple of 32, got {n_bits}")
    rng = np.random.default_rng(seed)
    return rng.standard_normal((d, n_bits)).astype(np.float32)


def pack_bits(bits: jax.Array) -> jax.Array:
    """(n, n_bits) bool -> (n, n_bits // 32) packed uint32 (traceable;
    the single definition of the signature bit order — kernel, backend,
    and launch lowering all pack through here)."""
    n, nb = bits.shape
    words = bits.reshape(n, nb // 32, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(words << shifts[None, None, :], axis=2, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, n_bits: int) -> jax.Array:
    """(n, n_words) packed uint32 -> (n, n_bits) bool (traceable inverse
    of :func:`pack_bits`; same LSB-first bit order)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(words.shape[0], -1)[:, :n_bits].astype(bool)


def band_hits(dots, ham, eps, t_lo, t_hi):
    """The unified band predicate shared by every execution path.

    hit  <=>  ham <= t_lo  (sure-accept, no exact verify)
           or (ham <= t_hi and dot > 1 - eps)  (band, exact-verified).

    ``t_lo = -1`` is full-verify mode (no sure-accepts).  Works on numpy
    and jnp operands alike — the host backend, the kernel oracle, and
    the sharded lowering all evaluate this one definition.
    """
    return (ham <= t_lo) | ((ham <= t_hi) & (dots > 1.0 - eps))


def hamming_words(a: jax.Array, b: jax.Array) -> jax.Array:
    """(na, nb) int32 Hamming distances between packed signature rows
    (traceable; static unrolled word loop, XOR + popcount per word —
    usable inside jit and inside Pallas kernels)."""
    ham = jnp.zeros((a.shape[0], b.shape[0]), jnp.int32)
    for k in range(a.shape[1]):
        x = a[:, k][:, None] ^ b[:, k][None, :]
        ham = ham + jax.lax.population_count(x).astype(jnp.int32)
    return ham


@jax.jit
def _sign_pack(data: jax.Array, proj: jax.Array) -> jax.Array:
    return pack_bits((data @ proj) >= 0.0)


def sign_signatures(data: np.ndarray, proj: np.ndarray) -> np.ndarray:
    """Packed (n, n_bits // 32) uint32 sign signatures of ``data @ proj``."""
    return np.asarray(_sign_pack(jnp.asarray(data, jnp.float32), jnp.asarray(proj)))


def shard_signatures(mesh, sigs, spec=None, *, n_padded: int | None = None):
    """Place a packed signature table co-sharded with the database rows
    it summarizes.

    ``spec`` defaults to ``P(data_axes(mesh), None)`` — rows over the
    mesh's data axes, words replicated — the one layout the index plane
    (``repro.distributed.index_plane``) accepts; pass an explicit
    ``PartitionSpec`` to shard over other axes.  ``n_padded`` zero-pads
    the row axis first (plane plans require a shard multiple; zero
    signature words are exactly what the kernel wrappers' padded-row
    correction models).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..distributed.sharding import data_axes

    sigs = jnp.asarray(sigs, jnp.uint32)
    if n_padded is not None and n_padded > sigs.shape[0]:
        sigs = jnp.pad(sigs, ((0, n_padded - sigs.shape[0]), (0, 0)))
    if spec is None:
        spec = P(data_axes(mesh), None)
    return jax.device_put(sigs, NamedSharding(mesh, spec))


def collision_fraction(eps: float) -> float:
    """Expected differing-bit fraction for a pair at cosine distance eps."""
    return math.acos(float(np.clip(1.0 - eps, -1.0, 1.0))) / math.pi


def hamming_band(eps: float, n_bits: int, margin: float = 3.0) -> tuple[int, int]:
    """(t_lo, t_hi) Hamming thresholds for an eps-ball at ``margin`` sigmas.

    Pairs with distance <= t_lo are (with prob ~Phi(margin)) inside the
    ball; pairs with distance > t_hi are outside; the band in between is
    where exact verification is required.  t_lo < 0 means "no sure
    accepts" (small n_bits or eps near 0).
    """
    p = collision_fraction(eps)
    sd = math.sqrt(max(p * (1.0 - p), 1e-12) / n_bits)
    t_hi = min(n_bits, int(math.ceil(n_bits * (p + margin * sd))))
    t_lo = int(math.floor(n_bits * (p - margin * sd)))
    return t_lo, t_hi


_POPCOUNT8 = np.array([bin(i).count("1") for i in range(256)], dtype=np.uint8)


def hamming_numpy(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(na, nb) Hamming distances between packed uint32 signature rows.

    Host-side path for small column subsets (the jit'd popcount pass in
    the backend covers full-database sweeps).
    """
    a = np.ascontiguousarray(a, dtype=np.uint32)
    b = np.ascontiguousarray(b, dtype=np.uint32)
    x = np.ascontiguousarray(a[:, None, :] ^ b[None, :, :])  # (na, nb, w)
    if hasattr(np, "bitwise_count"):  # numpy >= 2.0
        per_word = np.bitwise_count(x)
    else:
        per_word = _POPCOUNT8[x.view(np.uint8)].reshape(*x.shape[:2], -1)
    return per_word.sum(axis=-1, dtype=np.int32)
