"""Device-resident sweep engine: one launch per query sweep.

The per-chunk query paths dispatch one kernel launch plus one
synchronous device->host round-trip *per 256-row chunk* — at 40k rows
that is ~160 dispatches and ~160 pipeline stalls for a single
whole-database sweep, with the band thresholds, the db tile padding,
and the padded-row correction re-materialized every chunk.  This module
replaces that loop with a device-resident sweep:

* all query chunks of a launch run inside one jitted
  ``lax.fori_loop`` over the capacity-shaped operands, each iteration
  writing its chunk's counts (and packed bitmap words) into
  preallocated output slabs;
* the slabs are **donated** back into every subsequent launch
  (``donate_argnums``) so a multi-launch sweep threads one buffer
  through the whole sweep instead of copying it per launch —
  ``donate=False`` is the opt-out for backends that reject aliasing;
* the db-side tile padding, the dual-threshold padded-row correction
  (``_pad_col_hits``) and the bitmap tail mask are computed **once per
  sweep**, not once per chunk;
* results are synced to host exactly once, via a single ``device_get``
  at sweep end — every launch in between is dispatched asynchronously.

Launch shapes are quantized so compilation stays amortized: a sweep is
cut into launches of ``chunks_per_launch`` fixed-size chunks (the tail
launch is padded with zero query rows, which are sliced off after the
final sync), so the engine compiles one program per
``(chunk, chunks_per_launch, n, d)`` signature regardless of how many
rows a caller sweeps.

Under ``mesh=`` the same driver routes each launch through the sharded
index plane's pipelined evaluator
(:func:`repro.distributed.index_plane.sharded_sweep_launch`): chunks
are software-pipelined through a ``lax.scan`` carry so chunk *k*'s
cross-shard ``psum`` overlaps chunk *k+1*'s shard-local
popcount+verify (the plane's double-buffer — ``depth=1`` serializes
them, the parity baseline).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.hamming_filter.kernel import (
    DEFAULT_DB_TILE,
    DEFAULT_Q_TILE,
    hamming_filter_pallas,
)
from ..kernels.hamming_filter.ops import (
    _pad_col_hits,
    _tail_word_mask,
    default_interpret,
)
from ..obs import device as _obs_device
from ..obs import metrics as _metrics, span as _span, watch_recompiles

__all__ = [
    "SweepPlan",
    "plan_sweep",
    "sweep_counts",
    "sweep_bitmap",
    "sweep_bitmap_device",
]

DEFAULT_CHUNKS_PER_LAUNCH = 8


@dataclass(frozen=True)
class SweepPlan:
    """Launch layout of one query sweep.

    ``chunk`` is the caller's chunk rounded up to the q-tile multiple;
    a launch processes ``cpl`` chunks, and the sweep issues
    ``n_launches`` launches whose last one is padded with zero query
    rows up to ``nq_padded``.
    """

    nq: int
    chunk: int
    cpl: int
    n_launches: int

    @property
    def rows_per_launch(self) -> int:
        return self.chunk * self.cpl

    @property
    def nq_padded(self) -> int:
        return self.n_launches * self.rows_per_launch


def plan_sweep(
    nq: int,
    chunk: int,
    q_tile: int = DEFAULT_Q_TILE,
    chunks_per_launch: int = DEFAULT_CHUNKS_PER_LAUNCH,
) -> SweepPlan:
    chunk = -(-max(chunk, 1) // q_tile) * q_tile
    n_chunks = max(1, -(-nq // chunk))
    cpl = max(1, min(chunks_per_launch, n_chunks))
    n_launches = -(-n_chunks // cpl)
    return SweepPlan(nq, chunk, cpl, n_launches)


# ---------------------------------------------------------------------------
# launch bodies: fori_loop over chunks, slab accumulators
# ---------------------------------------------------------------------------


def _counts_launch_impl(
    out, tele, start, q, q_sig, db, db_sig, eps, band,
    *, chunk, q_tile, db_tile, interpret, telemetry=False,
):
    """One launch: ``cpl`` chunks of band-contract counts written into
    the (donated) ``out`` slab at ``start``.

    ``tele`` is the sweep-wide (n_chunks, 3) s32 per-chunk occupancy
    slab (donated alongside ``out``); with ``telemetry`` each chunk's
    kernel-tile ``[accept, band, reject]`` triple is written into row
    ``start // chunk + k``, otherwise the slab passes through untouched
    (the pass-through still aliases, so donation is unconditional)."""
    cpl = q.shape[0] // chunk
    qs = q.reshape(cpl, chunk, q.shape[1])
    qss = q_sig.reshape(cpl, chunk, q_sig.shape[1])

    def body(k, carry):
        acc, tl = carry
        qk = jax.lax.dynamic_index_in_dim(qs, k, 0, keepdims=False)
        qsk = jax.lax.dynamic_index_in_dim(qss, k, 0, keepdims=False)
        c = hamming_filter_pallas(
            qk, db, qsk, db_sig, eps[0], band[0], band[1],
            q_tile=q_tile, db_tile=db_tile, interpret=interpret,
            with_stats=telemetry,
        )
        if telemetry:
            c, s = c
            tl = jax.lax.dynamic_update_slice(
                tl, _obs_device.sweep_stats_tile_sum(s)[None],
                (start // chunk + k, 0),
            )
        acc = jax.lax.dynamic_update_slice(acc, c, (start + k * chunk,))
        return acc, tl

    return jax.lax.fori_loop(0, cpl, body, (out, tele))


def _bitmap_launch_impl(
    out, bm_out, tele, start, q, q_sig, db, db_sig, eps, band,
    *, chunk, q_tile, db_tile, interpret, telemetry=False,
):
    cpl = q.shape[0] // chunk
    qs = q.reshape(cpl, chunk, q.shape[1])
    qss = q_sig.reshape(cpl, chunk, q_sig.shape[1])

    def body(k, carry):
        acc, bm, tl = carry
        qk = jax.lax.dynamic_index_in_dim(qs, k, 0, keepdims=False)
        qsk = jax.lax.dynamic_index_in_dim(qss, k, 0, keepdims=False)
        outk = hamming_filter_pallas(
            qk, db, qsk, db_sig, eps[0], band[0], band[1],
            q_tile=q_tile, db_tile=db_tile, interpret=interpret,
            with_bitmap=True, with_stats=telemetry,
        )
        c, w = outk[0], outk[1]
        if telemetry:
            tl = jax.lax.dynamic_update_slice(
                tl, _obs_device.sweep_stats_tile_sum(outk[2])[None],
                (start // chunk + k, 0),
            )
        acc = jax.lax.dynamic_update_slice(acc, c, (start + k * chunk,))
        bm = jax.lax.dynamic_update_slice(bm, w, (start + k * chunk, 0))
        return acc, bm, tl

    return jax.lax.fori_loop(0, cpl, body, (out, bm_out, tele))


_STATIC = ("chunk", "q_tile", "db_tile", "interpret", "telemetry")
_counts_launch = jax.jit(_counts_launch_impl, static_argnames=_STATIC)
_counts_launch_donated = jax.jit(
    _counts_launch_impl, static_argnames=_STATIC, donate_argnums=(0, 1)
)
_bitmap_launch = jax.jit(_bitmap_launch_impl, static_argnames=_STATIC)
_bitmap_launch_donated = jax.jit(
    _bitmap_launch_impl, static_argnames=_STATIC, donate_argnums=(0, 1, 2)
)


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------


def _resolve_donate(donate) -> bool:
    # "auto" donates everywhere: XLA aliases the slabs in place on every
    # current backend (incl. CPU), so a multi-launch sweep threads one
    # buffer through all launches instead of copying it per launch;
    # donate=False is the escape hatch for backends that reject aliasing
    return True if donate == "auto" else bool(donate)


def _pad_q(q, q_sig, nq_padded: int):
    """Zero query rows up to the launch multiple (results sliced off)."""
    q = jnp.asarray(q, jnp.float32)
    q_sig = jnp.asarray(q_sig, jnp.uint32)
    pad = nq_padded - q.shape[0]
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        q_sig = jnp.pad(q_sig, ((0, pad), (0, 0)))
    return q, q_sig


def _pad_db(db, db_sig, db_tile: int):
    db = jnp.asarray(db)
    db_sig = jnp.asarray(db_sig, jnp.uint32)
    pad = (-db.shape[0]) % db_tile
    if pad:
        db = jnp.pad(db, ((0, pad), (0, 0)))
        db_sig = jnp.pad(db_sig, ((0, pad), (0, 0)))
    return db, db_sig


@functools.partial(jax.jit, static_argnames=("n_pad",))
def _count_correction(q_sig, eps, band, n_pad: int):
    return _pad_col_hits(q_sig, eps[0], band[0], band[1], n_pad)


def _prep(nq, eps, t_lo, t_hi, chunk, q_tile, chunks_per_launch, interpret):
    if interpret is None:
        interpret = default_interpret()
    plan = plan_sweep(nq, chunk, q_tile, chunks_per_launch)
    eps_op = jnp.asarray([eps], jnp.float32)
    band_op = jnp.stack(
        [jnp.asarray(t_lo, jnp.int32), jnp.asarray(t_hi, jnp.int32)]
    )
    return plan, eps_op, band_op, interpret


def _sweep(
    kind: str,
    q,
    q_sig,
    db,
    db_sig,
    n: int,
    eps,
    t_lo,
    t_hi,
    *,
    chunk: int,
    chunks_per_launch: int,
    q_tile: int,
    db_tile: int,
    interpret,
    donate,
    mesh,
    axes,
    depth: int,
):
    """Shared driver for both sweep variants — one place owns the
    launch loop, the donate selection, the pad corrections, and the
    single end-of-sweep host sync."""
    nq = q.shape[0]
    plan, eps_op, band_op, interpret = _prep(
        nq, eps, t_lo, t_hi, chunk, q_tile, chunks_per_launch, interpret
    )
    sweep_span = _span(
        "sweep.sweep", kind=kind, nq=nq, n=n, chunk=plan.chunk,
        launches=plan.n_launches, chunks_per_launch=plan.cpl,
        sharded=mesh is not None, pipelined=mesh is not None and depth >= 2,
    )
    sweep_span.__enter__()
    try:
        _metrics.counter("sweep.sweeps").inc()
        _metrics.counter("sweep.launches").inc(plan.n_launches)
        q, q_sig = _pad_q(q, q_sig, plan.nq_padded)
        bitmap = kind == "bitmap"
        # per-chunk occupancy telemetry rides the COUNT sweeps only (the
        # engine's scan behind query_counts / serve / stream).  The bitmap
        # sweeps feed the one-launch cluster pass, whose band occupancy is
        # the *same* statistic the count path and the auto-tuner's
        # record_occupancy already measure — and on interpret-mode backends
        # the per-tile stats ops cost real wall time per chunk, so the
        # clustering hot path keeps only its own per-round counters.
        telemetry = _obs_device.device_enabled() and not bitmap
        tele = None
        if mesh is not None:
            from ..distributed.index_plane import sharded_sweep_launch

            n_pad, parts = None, []
            for L in range(plan.n_launches):
                sl = slice(L * plan.rows_per_launch, (L + 1) * plan.rows_per_launch)
                # per-launch spans record dispatch wall time only — the
                # engine's point is async launches with ONE sync at
                # sweep end, so nothing blocks here (synced=False)
                with _span("sweep.launch", L=L, sharded=True, synced=False,
                           pipelined=depth >= 2):
                    part, n_pad = sharded_sweep_launch(
                        kind, q[sl], q_sig[sl], db, db_sig, eps_op, band_op,
                        mesh=mesh, axes=axes, chunk=plan.chunk, q_tile=q_tile,
                        db_tile=db_tile, interpret=interpret, depth=depth, n=n,
                        telemetry=telemetry,
                    )
                parts.append(part if isinstance(part, tuple) else (part,))
            outs = tuple(
                jnp.concatenate(p) if len(p) > 1 else p[0] for p in zip(*parts)
            )
            if telemetry:
                outs, tele = outs[:-1], outs[-1]
        else:
            db, db_sig = _pad_db(db, db_sig, db_tile)
            n_pad = db.shape[0] - n
            donated = _resolve_donate(donate)
            tele0 = jnp.zeros((plan.n_launches * plan.cpl, 3), jnp.int32)
            if bitmap:
                launch = _bitmap_launch_donated if donated else _bitmap_launch
                outs = (
                    jnp.zeros((plan.nq_padded,), jnp.int32),
                    jnp.zeros((plan.nq_padded, db.shape[0] // 32), jnp.uint32),
                    tele0,
                )
            else:
                launch = _counts_launch_donated if donated else _counts_launch
                outs = (jnp.zeros((plan.nq_padded,), jnp.int32), tele0)
            # donated-slab accounting: one fresh allocation per sweep;
            # every launch past the first threads (or copies) the slab
            _metrics.counter("sweep.slab_alloc").inc()
            _metrics.counter(
                "sweep.slab_donated" if donated else "sweep.slab_copied"
            ).inc(max(plan.n_launches - 1, 0))
            recompiles = watch_recompiles(
                (_counts_launch, _counts_launch_donated,
                 _bitmap_launch, _bitmap_launch_donated),
                "sweep.recompiles",
            )
            for L in range(plan.n_launches):
                sl = slice(L * plan.rows_per_launch, (L + 1) * plan.rows_per_launch)
                with _span("sweep.launch", L=L, donated=donated, synced=False):
                    outs = launch(
                        *outs, jnp.int32(L * plan.rows_per_launch), q[sl], q_sig[sl],
                        db, db_sig, eps_op, band_op,
                        chunk=plan.chunk, q_tile=q_tile, db_tile=db_tile,
                        interpret=interpret, telemetry=telemetry,
                    )
                recompiles.delta()
            if telemetry:
                outs, tele = outs[:-1], outs[-1]
            else:
                outs = outs[:-1]
        out = outs[0]
        words_needed = -(-n // 32)
        if n_pad:
            out = out - _count_correction(q_sig, eps_op, band_op, n_pad)
        if not bitmap:
            # THE sweep sync: counts (and the telemetry slab) in one get
            host = jax.device_get((out, tele) if telemetry else (out,))
            if telemetry:
                _obs_device.harvest_sweep_telemetry(host[1])
            return np.asarray(host[0][:nq]).astype(np.int64)
        bm_out = outs[1]
        if n_pad:
            bm_out = (
                bm_out[:, :words_needed] & _tail_word_mask(words_needed, n)[None, :]
            )
        # bitmap kind: telemetry is scoped off above, so the single sync
        # fetches exactly the PR 8 pair
        host = jax.device_get((out, bm_out))
        counts, bm = host[0], host[1]
        return (
            np.asarray(counts)[:nq].astype(np.int64),
            np.ascontiguousarray(np.asarray(bm)[:nq, :words_needed]),
        )
    finally:
        # the device_get above IS the sweep's single host sync, so the
        # span closing here measures execution, not dispatch
        sweep_span.__exit__(None, None, None)


def sweep_bitmap_device(
    q,
    q_sig,
    db,
    db_sig,
    n: int,
    eps,
    t_lo,
    t_hi,
    *,
    chunk: int = 256,
    chunks_per_launch: int = DEFAULT_CHUNKS_PER_LAUNCH,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret=None,
    donate="auto",
    mesh=None,
    axes=None,
    depth: int = 2,
):
    """Device-resident sweep with **no host sync**: the packed bitmap
    slab stays on device for a downstream consumer (the one-launch
    cluster pass).

    Same launch layout and donation discipline as :func:`sweep_bitmap`,
    but the result is the *capacity-width* device slab
    ``(plan.nq_padded, W)`` with every bit for columns >= n cleared
    (tail mask applied on device) — under ``mesh=`` its words stay
    physically sharded across the plane.  Returns ``(slab, plan)``;
    rows past ``plan.nq`` are zero-query padding.
    """
    nq = q.shape[0]
    plan, eps_op, band_op, interpret = _prep(
        nq, eps, t_lo, t_hi, chunk, q_tile, chunks_per_launch, interpret
    )
    with _span(
        "sweep.sweep", kind="bitmap_device", nq=nq, n=n, chunk=plan.chunk,
        launches=plan.n_launches, chunks_per_launch=plan.cpl,
        sharded=mesh is not None, synced=False,
    ):
        _metrics.counter("sweep.sweeps").inc()
        _metrics.counter("sweep.launches").inc(plan.n_launches)
        q, q_sig = _pad_q(q, q_sig, plan.nq_padded)
        # no occupancy telemetry on this path (see _sweep): the bitmap
        # feeds the one-launch cluster pass, which carries its own
        # per-round counters — the band-occupancy statistic is already
        # measured by the count sweeps and record_occupancy, and keeping
        # the stats ops out of the interpreted kernel keeps the fused
        # clustering's telemetry-on build within the SLO of the plain one
        if mesh is not None:
            from ..distributed.index_plane import sharded_sweep_launch

            parts = []
            for L in range(plan.n_launches):
                sl = slice(L * plan.rows_per_launch, (L + 1) * plan.rows_per_launch)
                with _span("sweep.launch", L=L, sharded=True, synced=False,
                           pipelined=depth >= 2):
                    part, _ = sharded_sweep_launch(
                        "bitmap", q[sl], q_sig[sl], db, db_sig, eps_op, band_op,
                        mesh=mesh, axes=axes, chunk=plan.chunk, q_tile=q_tile,
                        db_tile=db_tile, interpret=interpret, depth=depth, n=n,
                    )
                parts.append(part)
            bms = [p[1] for p in parts]
            bm_out = jnp.concatenate(bms) if len(bms) > 1 else bms[0]
        else:
            db, db_sig = _pad_db(db, db_sig, db_tile)
            donated = _resolve_donate(donate)
            launch = _bitmap_launch_donated if donated else _bitmap_launch
            outs = (
                jnp.zeros((plan.nq_padded,), jnp.int32),
                jnp.zeros((plan.nq_padded, db.shape[0] // 32), jnp.uint32),
                # stats placeholder: the launch signature always carries a
                # telemetry slab (so the donated aliasing is unconditional);
                # with telemetry off it passes through untouched
                jnp.zeros((plan.n_launches * plan.cpl, 3), jnp.int32),
            )
            _metrics.counter("sweep.slab_alloc").inc()
            _metrics.counter(
                "sweep.slab_donated" if donated else "sweep.slab_copied"
            ).inc(max(plan.n_launches - 1, 0))
            recompiles = watch_recompiles(
                (_counts_launch, _counts_launch_donated,
                 _bitmap_launch, _bitmap_launch_donated),
                "sweep.recompiles",
            )
            for L in range(plan.n_launches):
                sl = slice(L * plan.rows_per_launch, (L + 1) * plan.rows_per_launch)
                with _span("sweep.launch", L=L, donated=donated, synced=False):
                    outs = launch(
                        *outs, jnp.int32(L * plan.rows_per_launch), q[sl], q_sig[sl],
                        db, db_sig, eps_op, band_op,
                        chunk=plan.chunk, q_tile=q_tile, db_tile=db_tile,
                        interpret=interpret,
                    )
                recompiles.delta()
            bm_out = outs[1]
        bm_out = bm_out & _tail_word_mask(bm_out.shape[1], n)[None, :]
        return bm_out, plan


def sweep_counts(
    q,
    q_sig,
    db,
    db_sig,
    n: int,
    eps,
    t_lo,
    t_hi,
    *,
    chunk: int = 256,
    chunks_per_launch: int = DEFAULT_CHUNKS_PER_LAUNCH,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret=None,
    donate="auto",
    mesh=None,
    axes=None,
    depth: int = 2,
) -> np.ndarray:
    """Band-contract neighbor counts of every query row against the
    first ``n`` db rows, as one device-resident sweep.

    ``db``/``db_sig`` may carry capacity slack past ``n`` — rows there
    must be zero vectors with zero signature words (the streaming append
    shape); tile padding and the dual-threshold correction for *all*
    pad rows are applied once per sweep.  Under ``mesh=`` they must be
    the plane-sharded arrays from ``shard_database`` and each launch
    runs the pipelined sharded evaluator instead.  Returns int64
    ``(nq,)`` counts after exactly one host sync.
    """
    return _sweep(
        "count", q, q_sig, db, db_sig, n, eps, t_lo, t_hi,
        chunk=chunk, chunks_per_launch=chunks_per_launch, q_tile=q_tile,
        db_tile=db_tile, interpret=interpret, donate=donate,
        mesh=mesh, axes=axes, depth=depth,
    )


def sweep_bitmap(
    q,
    q_sig,
    db,
    db_sig,
    n: int,
    eps,
    t_lo,
    t_hi,
    *,
    chunk: int = 256,
    chunks_per_launch: int = DEFAULT_CHUNKS_PER_LAUNCH,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret=None,
    donate="auto",
    mesh=None,
    axes=None,
    depth: int = 2,
):
    """(counts int64 ``(nq,)``, packed adjacency uint32
    ``(nq, ceil(n/32))``) for every query row vs the first ``n`` db
    rows — the one-launch counterpart of the per-chunk
    ``hamming_filter_bitmap`` loop; pad bits are cleared and results
    sync to host exactly once.
    """
    return _sweep(
        "bitmap", q, q_sig, db, db_sig, n, eps, t_lo, t_hi,
        chunk=chunk, chunks_per_launch=chunks_per_launch, q_tile=q_tile,
        db_tile=db_tile, interpret=interpret, donate=donate,
        mesh=mesh, axes=axes, depth=depth,
    )
