"""Range-query backend protocol + registry.

Every clustering engine in ``repro.core`` consumes eps-neighborhoods
through three primitives — boolean hit rows against the whole database,
hit rows against a column subset, and neighbor counts.  A
``RangeBackend`` supplies those primitives for one database (``fit``
binds the data; queries are rows *of that database*, which is exactly
how DBSCAN uses them).  Backends are interchangeable:

* ``exact``             — the blocked-matmul oracle (bit-for-bit the
                          engine behaviour before this subsystem).
* ``random_projection`` — signed-random-projection ANN prefilter with
                          exact verification (sDBSCAN-style).

Engines accept ``backend=`` as either a registry name, a
``(name, kwargs)``-style constructed instance, or an already-fit
instance; ``as_fitted`` normalizes all three.
"""

from __future__ import annotations

from typing import Dict, List, Type, Union

import numpy as np

__all__ = ["RangeBackend", "BACKENDS", "register_backend", "make_backend", "as_fitted"]


class RangeBackend:
    """Interface + shared glue for eps-range query backends.

    Subclasses must implement ``fit`` and ``query_hits``; the remaining
    primitives have correct (if not always optimal) defaults on top.
    ``fit`` must be idempotent when handed the same array object so
    engines can re-enter with a shared backend without paying a rebuild.
    """

    name: str = "base"

    def fit(self, data: np.ndarray) -> "RangeBackend":
        raise NotImplementedError

    def partial_fit(self, rows: np.ndarray) -> "RangeBackend":
        """Append ``rows`` to the fitted database (streaming ingest).

        Row indices of the appended points are ``n_points_before ..
        n_points_after - 1`` — existing indices never move, which is the
        invariant the streaming cluster state builds on.  The base
        implementation is the correct-but-quadratic fallback
        (concatenate + refit); incremental backends override it with a
        real append (see ``RandomProjectionBackend.partial_fit``).
        Calling it on an unfitted backend is the same as ``fit``.
        """
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        data = getattr(self, "_data", None)
        if data is None:
            return self.fit(rows)
        return self.fit(np.concatenate([data, rows], axis=0))

    # -- primitives --------------------------------------------------------
    def query_hits(self, rows: np.ndarray, eps: float) -> np.ndarray:
        """Boolean (len(rows), n) adjacency of db[rows] against the db."""
        raise NotImplementedError

    def query_hits_subset(
        self, rows: np.ndarray, cols: np.ndarray, eps: float
    ) -> np.ndarray:
        """Boolean (len(rows), len(cols)) adjacency against db[cols]."""
        return self.query_hits(rows, eps)[:, cols]

    @property
    def packs_natively(self) -> bool:
        """True when ``query_hits_packed`` produces packed words without
        materializing (and re-packing) the boolean hit matrix — callers
        that need *both* forms (streaming ingest) branch on this so the
        host paths never pay an unpack→repack round-trip."""
        return False

    def query_hits_packed(self, rows: np.ndarray, eps: float):
        """(counts int64 (len(rows),), packed uint32 bitmap of the hit
        rows in ``repro.core.range_query.pack_bitmap`` bit order).

        Streaming ingest stores and replays adjacency packed; backends
        whose evaluator produces packed words natively (the sweep
        engine) override this to skip the unpack→repack round-trip.
        """
        from ..core.range_query import pack_bitmap

        hit = self.query_hits(rows, eps)
        return hit.sum(axis=1, dtype=np.int64), pack_bitmap(hit)

    def query_counts(self, rows: np.ndarray, eps: float) -> np.ndarray:
        """Neighbor counts |N_eps(db[i])| for i in rows (int64).

        Chunked over rows so the boolean hit matrix never exceeds
        (block, n) even when asked for counts of the whole database.
        """
        rows = np.asarray(rows)
        block = getattr(self, "block_size", 2048)
        counts = np.zeros(len(rows), dtype=np.int64)
        for start in range(0, len(rows), block):
            sub = rows[start : start + block]
            counts[start : start + len(sub)] = self.query_hits(sub, eps).sum(axis=1)
        return counts

    # -- durability --------------------------------------------------------
    def state_export(self) -> Dict[str, np.ndarray]:
        """Snapshot the fitted state as a flat dict of host arrays.

        The contract is **capacity-faithful**: backends that keep
        capacity-padded append buffers (amortized-doubling slabs whose
        shapes key the jit compile-signature lattice) export the *full*
        buffers plus the live row count, so ``state_import`` on a fresh
        instance reproduces identical operand shapes and a restored
        replica re-enters the pre-crash compile cache — restore is
        recompile-free by construction, not by luck.
        """
        raise NotImplementedError(f"{self.name!r} backend does not export state")

    def state_import(self, state: Dict[str, np.ndarray]) -> "RangeBackend":
        """Rebuild fitted state from a ``state_export`` dict (see its
        capacity contract).  Returns self."""
        raise NotImplementedError(f"{self.name!r} backend does not import state")

    # -- conveniences ------------------------------------------------------
    def neighbor_lists(self, eps: float, block_size: int = 2048) -> List[np.ndarray]:
        """Per-point sorted neighbor index arrays for the whole database."""
        n = self.n_points
        out: List[np.ndarray] = []
        for start in range(0, n, block_size):
            rows = np.arange(start, min(start + block_size, n))
            hit = self.query_hits(rows, eps)
            for i in range(hit.shape[0]):
                out.append(np.nonzero(hit[i])[0])
        return out

    @property
    def n_points(self) -> int:
        return self._data.shape[0]  # type: ignore[attr-defined]

    @property
    def data(self) -> np.ndarray:
        """The fitted database rows (read-only view; row i is query row i)."""
        assert getattr(self, "_data", None) is not None, "call fit() first"
        return self._data  # type: ignore[attr-defined]


BACKENDS: Dict[str, Type[RangeBackend]] = {}


def register_backend(cls: Type[RangeBackend]) -> Type[RangeBackend]:
    BACKENDS[cls.name] = cls
    return cls


def make_backend(spec: Union[str, RangeBackend], **kwargs) -> RangeBackend:
    """Normalize a backend spec (registry name or instance) to an instance."""
    if isinstance(spec, RangeBackend):
        return spec
    if spec not in BACKENDS:
        # registration happens at module import; the heavyweight backends
        # are imported lazily (see the package __init__), so pull in any
        # sibling module named after the backend before giving up
        import importlib

        mod_name = f"{__package__}.{spec}"
        try:
            importlib.import_module(mod_name)
        except ModuleNotFoundError as e:
            # only "no such sibling module" means unknown backend; a
            # missing dependency *inside* an existing module must surface
            if e.name != mod_name:
                raise
        except (TypeError, ValueError):
            pass  # not a module-path-shaped spec: fall through to KeyError
    if spec not in BACKENDS:
        raise ValueError(
            f"unknown range backend {spec!r}; registered backends: {sorted(BACKENDS)}"
        )
    return BACKENDS[spec](**kwargs)


def as_fitted(spec: Union[str, RangeBackend], data: np.ndarray, **kwargs) -> RangeBackend:
    """Backend instance bound to ``data`` (no-op refit on the same array).

    ``kwargs`` configure construction when ``spec`` is a registry name;
    an already-constructed instance keeps its own configuration.
    """
    return make_backend(spec, **kwargs).fit(data)
