"""Seeded fault injection for the launch surface.

Every device-dispatch site in the sweep engine / one-launch cluster /
sharded plane calls :func:`maybe_fail` with a stable **site name**
before launching.  With no plan installed (the production default) that
is one ``None`` check; with a plan installed it draws from a per-site
seeded RNG and raises :class:`InjectedFault` — an ``RuntimeError``
subclass, so it flows through exactly the retry/degrade machinery a
real ``XlaRuntimeError`` (preemption, link flap, device loss) would.

Sites (stable names — tests and ``REPRO_FAULTS`` plans reference them):

* ``sweep.launch``   — one-launch device sweep (counts/bitmap engine)
* ``plane.launch``   — the sharded index plane's sweep dispatch
* ``chunk.launch``   — legacy per-chunk device dispatch loop
* ``cluster.launch`` — the one-launch device-resident clustering
* ``dryrun.cell``    — launch dry-run cell build/compile

Plans are **seeded and deterministic**: site ``s``'s k-th eligible call
fails iff the k-th draw of ``default_rng([seed, crc32(s)])`` falls
under the site's probability (and the rule's ``max_count`` is not
exhausted), independent of every other site — so a failing CI run
replays bit-identically from its ``REPRO_FAULTS`` string.

``REPRO_FAULTS`` grammar (comma-separated)::

    REPRO_FAULTS="seed=7,sweep.launch=0.5,cluster.launch=1.0:2"

``site=prob`` injects with probability ``prob``; an optional ``:N``
caps total injections at that site (``prob=1.0`` with no cap simulates
a dead device — every retry fails until the caller degrades).  The plan
installs at import of this module (streaming/index modules import it),
so a plain ``REPRO_FAULTS=... pytest`` run is a degraded-mode re-run.

Checkpoint-shard corruption is *file* tampering, not call-site
injection — :func:`corrupt_file` / :func:`truncate_file` are the seeded
helpers the durability tests (and any chaos harness) use.
"""

from __future__ import annotations

import contextlib
import os
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional

import numpy as np

__all__ = [
    "InjectedFault",
    "FaultRule",
    "FaultPlan",
    "install",
    "install_from_env",
    "clear",
    "active",
    "inject",
    "maybe_fail",
    "corrupt_file",
    "truncate_file",
]


class InjectedFault(RuntimeError):
    """A deterministic, injected launch failure (retryable)."""


@dataclass
class FaultRule:
    """Injection rule for one site: fire with ``prob`` per eligible
    call, at most ``max_count`` times total (None = unbounded)."""

    prob: float = 1.0
    max_count: Optional[int] = None


class FaultPlan:
    """A seeded set of per-site fault rules.

    Determinism contract: each site draws from its own
    ``default_rng([seed, crc32(site)])`` stream advanced once per
    eligible call, so whether call k at site s fails depends only on
    (seed, s, k) — never on interleaving with other sites.
    """

    def __init__(self, seed: int = 0, rules: Optional[Dict[str, FaultRule]] = None):
        self.seed = int(seed)
        self.rules: Dict[str, FaultRule] = dict(rules or {})
        self._rngs: Dict[str, np.random.Generator] = {}
        self.calls: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse a ``REPRO_FAULTS``-style plan string (see module doc)."""
        seed = 0
        rules: Dict[str, FaultRule] = {}
        for part in str(spec).split(","):
            part = part.strip()
            if not part:
                continue
            key, _, val = part.partition("=")
            key, val = key.strip(), val.strip()
            if not val:
                raise ValueError(f"fault plan entry {part!r} is not site=prob[:max]")
            if key == "seed":
                seed = int(val)
                continue
            prob_s, _, max_s = val.partition(":")
            rules[key] = FaultRule(
                prob=float(prob_s), max_count=int(max_s) if max_s else None
            )
        return cls(seed, rules)

    def should_fail(self, site: str) -> bool:
        rule = self.rules.get(site)
        if rule is None:
            return False
        self.calls[site] = self.calls.get(site, 0) + 1
        if rule.max_count is not None and self.fired.get(site, 0) >= rule.max_count:
            return False
        rng = self._rngs.get(site)
        if rng is None:
            rng = self._rngs[site] = np.random.default_rng(
                [self.seed, zlib.crc32(site.encode())]
            )
        # always advance the stream (determinism is per eligible call)
        hit = bool(rng.random() < rule.prob)
        if hit:
            self.fired[site] = self.fired.get(site, 0) + 1
        return hit

    def summary(self) -> dict:
        """JSON-able description (dry-run records, bench payloads)."""
        return {
            "seed": self.seed,
            "rules": {
                s: {"prob": r.prob, "max_count": r.max_count}
                for s, r in sorted(self.rules.items())
            },
            "fired": dict(sorted(self.fired.items())),
            "calls": dict(sorted(self.calls.items())),
        }


_active: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> FaultPlan:
    """Make ``plan`` the process-wide active plan."""
    global _active
    _active = plan
    return plan


def clear() -> None:
    global _active
    _active = None


def active() -> Optional[FaultPlan]:
    return _active


@contextlib.contextmanager
def inject(plan_or_spec):
    """Scoped install: ``with faults.inject("seed=3,sweep.launch=1:1"):``."""
    plan = (
        plan_or_spec
        if isinstance(plan_or_spec, FaultPlan)
        else FaultPlan.parse(plan_or_spec)
    )
    global _active
    prev = _active
    install(plan)
    try:
        yield plan
    finally:
        _active = prev


def maybe_fail(site: str, **ctx) -> None:
    """Raise :class:`InjectedFault` iff the active plan says so.

    The hot-path cost with no plan installed is a single global read;
    instrumented sites can therefore call this unconditionally.
    """
    plan = _active
    if plan is None:
        return
    if plan.should_fail(site):
        from ..obs import metrics as _metrics

        _metrics.counter("faults.injected").inc()
        _metrics.counter(f"faults.injected.{site}").inc()
        extra = f" ({ctx})" if ctx else ""
        raise InjectedFault(f"injected fault at {site}{extra}")


def install_from_env(environ=None) -> bool:
    """Apply the ``REPRO_FAULTS`` knob; returns whether a plan installed."""
    spec = (environ if environ is not None else os.environ).get("REPRO_FAULTS", "")
    spec = spec.strip()
    if not spec or spec in ("0", "off", "none"):
        return False
    install(FaultPlan.parse(spec))
    return True


# -- file tampering (checkpoint shards, WAL tails) --------------------------


def corrupt_file(path, *, seed: int = 0, nbytes: int = 8) -> int:
    """Flip ``nbytes`` seeded-random bytes of ``path`` in place; returns
    how many were flipped (0 on an empty file)."""
    p = Path(path)
    raw = bytearray(p.read_bytes())
    if not raw:
        return 0
    rng = np.random.default_rng([seed, zlib.crc32(p.name.encode())])
    idx = rng.integers(0, len(raw), size=min(nbytes, len(raw)))
    for i in idx:
        raw[int(i)] ^= 0xFF
    p.write_bytes(bytes(raw))
    return len(idx)


def truncate_file(path, *, drop_bytes: Optional[int] = None, keep_frac: float = 0.5) -> int:
    """Cut the tail off ``path`` (the un-fsynced-tail simulation);
    returns the new size.  ``drop_bytes`` wins over ``keep_frac``."""
    p = Path(path)
    size = p.stat().st_size
    keep = size - int(drop_bytes) if drop_bytes is not None else int(size * keep_frac)
    keep = max(keep, 0)
    with open(p, "r+b") as f:
        f.truncate(keep)
    return keep


# a plain `REPRO_FAULTS=... pytest` run injects with zero test changes:
# the plan installs when the first instrumented module imports this one
install_from_env()
