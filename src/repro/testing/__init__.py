"""``repro.testing`` — deterministic fault injection for the launch
surface (``repro.testing.faults``) plus checkpoint/WAL corruption
helpers.  Test-and-CI infrastructure: everything here is a no-op unless
a fault plan is explicitly installed (or ``REPRO_FAULTS`` is set)."""

from .faults import (
    FaultPlan,
    FaultRule,
    InjectedFault,
    active,
    clear,
    corrupt_file,
    inject,
    install,
    install_from_env,
    maybe_fail,
    truncate_file,
)

__all__ = [
    "FaultPlan",
    "FaultRule",
    "InjectedFault",
    "active",
    "clear",
    "corrupt_file",
    "inject",
    "install",
    "install_from_env",
    "maybe_fail",
    "truncate_file",
]
