"""jaxpr passes: invariants checked on the traced form of the real
entry points (the standard targets in :mod:`.targets`).

* ``jaxpr-donation-alias`` (LAF101) — every ``donate_argnums`` slot of a
  donated launch actually aliases an output in the lowered module
  (``tf.aliasing_output``).  XLA silently *drops* infeasible donations
  (shape/dtype mismatch between the donated operand and every output),
  so a refactor that breaks aliasing costs a slab copy per launch with
  no error anywhere — this is the only place it shows up.
* ``jaxpr-donation-reuse`` (LAF102) — no Python-level read of a buffer
  after it was passed into a donating jitted callable without being
  rebound (use-after-donate is undefined behavior on real backends).
  AST dataflow over the source tree: module-level
  ``X = jax.jit(f, donate_argnums=...)`` products and their local
  aliases are tracked; the donated argument slots poison bare-``Name``
  arguments, assignment rebinds heal them.
* ``jaxpr-host-callback-in-loop`` (LAF103) — no
  ``pure_callback``/``io_callback``/``debug_callback`` primitive inside
  a ``scan``/``while`` body of any standard target: a host round-trip
  per loop iteration serializes the device pipeline the sweep engine
  exists to keep full.
* ``jaxpr-shardmap-replication`` (LAF104) — taint analysis of every
  ``shard_map`` eqn: an output whose value still depends on a mesh axis
  (sharded inputs, ``axis_index``) must declare that axis in its
  ``out_names``.  The plane runs ``check_rep=False`` (the pallas calls
  defeat JAX's own rep checker), so this is the replication safety net:
  a dropped ``psum`` otherwise returns shard-local counts as if global.
* ``jaxpr-packed-while-carry`` (LAF106) — no unsigned-dtype (packed
  word) array in a ``lax.while_loop`` carry of any standard target.
  The one-launch cluster program iterates label-propagation rounds
  under ``while`` with the packed slab closed over as a loop-invariant
  operand; a slab that ends up in the carry is copied (or worse,
  re-masked) every round and on a mesh invites per-round packed-word
  collectives (the LAF202 violation).  ``fori_loop`` lowers to
  ``scan``, so the sweep engine's legitimate packed accumulator is not
  flagged.
* ``jaxpr-recompile-lattice`` (LAF105) — the compile-signature lattices
  stay bounded: ``plan_sweep``'s launch shapes over any nq, the serving
  ``bucket_shape`` image over any traffic, and (dynamic, probed with
  metrics on) the ``obs.PAIRED_COUNTERS`` contract that sweep
  recompiles move 1:1 with capacity doublings.
* ``jaxpr-restore-replica`` (LAF108) — a replica restored from a
  snapshot reuses the pre-crash compile signatures: ``state_import``
  must reproduce the *capacity-shaped* device operands (including
  post-``partial_fit`` append slack), so re-running the same query
  shapes after a restore adds zero new entries to the recompile
  lattice.  A restore that trims buffers to the exact row count
  compiles a fresh signature on the very first post-recovery sweep —
  recovery time then includes a silent engine recompile.

jax imports are deferred to call time so ``--list-checks`` stays
jax-free.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional, Tuple

from .ast_lint import (
    _call_name,
    _rel,
    filter_inline_suppressed,
    iter_py_files,
    parse_file,
)
from .registry import Finding, register

__all__ = [
    "check_donation_text",
    "check_file_donation_reuse",
    "check_jaxpr_callbacks",
    "check_jaxpr_packed_while_carry",
    "check_jaxpr_shardmaps",
    "check_restore_signatures",
    "taint_shard_map_outputs",
]

Taint = FrozenSet[str]
_EMPTY: Taint = frozenset()

_CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback"}
_LOOP_PRIMS = {"scan", "while"}
_AXIS_CLEARING_PRIMS = {
    "psum", "pmax", "pmin", "all_gather", "all_to_all", "reduce_scatter",
    "psum2",
}


# ---------------------------------------------------------------------------
# jaxpr plumbing
# ---------------------------------------------------------------------------


def _as_open(j):
    """ClosedJaxpr | Jaxpr -> Jaxpr."""
    return j.jaxpr if hasattr(j, "jaxpr") else j


def _param_jaxprs(eqn):
    """Every sub-jaxpr in an eqn's params, opened."""
    out = []
    for v in eqn.params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if hasattr(x, "eqns") or hasattr(x, "jaxpr") and hasattr(
                _as_open(x), "eqns"
            ):
                try:
                    out.append(_as_open(x))
                except Exception:
                    pass
    return [j for j in out if hasattr(j, "eqns")]


def _walk_eqns(jaxpr, depth: int = 0):
    """Yield (eqn, loop_depth) over the whole nest."""
    for eqn in _as_open(jaxpr).eqns:
        yield eqn, depth
        bump = 1 if eqn.primitive.name in _LOOP_PRIMS else 0
        for sub in _param_jaxprs(eqn):
            yield from _walk_eqns(sub, depth + bump)


# ---------------------------------------------------------------------------
# LAF101: donation survives lowering
# ---------------------------------------------------------------------------


def check_donation_text(lowered_text: str, n_donated: int, label: str) -> List[Finding]:
    """Donation survives lowering: the module must carry one
    ``tf.aliasing_output`` attribute per donated argument."""
    aliased = lowered_text.count("tf.aliasing_output")
    if n_donated and aliased < n_donated:
        return [
            Finding(
                "jaxpr-donation-alias", label, 0,
                f"{n_donated} argument(s) are donated but only "
                f"{aliased} alias an output in the lowered module — "
                f"XLA dropped the donation silently (slab copy per "
                f"launch)",
                hint="donated operands must match an output's "
                "shape+dtype exactly; check the launch signature "
                "against its slab outputs",
            )
        ]
    return []


@register(
    "jaxpr-donation-alias", family="jaxpr", code="LAF101",
    description="every donate_argnums slot aliases an output after lowering",
)
def _check_donation_alias(ctx) -> List[Finding]:
    findings = []
    for t in ctx.targets.all():
        findings.extend(check_donation_text(t.lowered_text, t.n_donated, t.label))
    return findings


# ---------------------------------------------------------------------------
# LAF102: no use-after-donate (AST dataflow)
# ---------------------------------------------------------------------------


def _donating_defs(tree: ast.AST) -> Dict[str, Tuple[int, ...]]:
    """Module-level ``X = jax.jit(f, donate_argnums=...)`` bindings."""
    out: Dict[str, Tuple[int, ...]] = {}
    for stmt in getattr(tree, "body", []):
        if not (
            isinstance(stmt, ast.Assign)
            and len(stmt.targets) == 1
            and isinstance(stmt.targets[0], ast.Name)
            and isinstance(stmt.value, ast.Call)
            and _call_name(stmt.value) == "jit"
        ):
            continue
        for kw in stmt.value.keywords:
            if kw.arg != "donate_argnums":
                continue
            try:
                v = ast.literal_eval(kw.value)
            except (ValueError, SyntaxError):
                continue
            nums = (v,) if isinstance(v, int) else tuple(
                x for x in v if isinstance(x, int)
            )
            if nums:
                out[stmt.targets[0].id] = nums
    return out


def check_file_donation_reuse(path: Path, tree: ast.AST, rel: str) -> List[Finding]:
    donated = _donating_defs(tree)
    if not donated:
        return []
    findings: List[Finding] = []
    seen = set()

    def scan_fn(fn) -> None:
        donating = dict(donated)   # name -> donate slots (plus aliases)
        poisoned: Dict[str, int] = {}   # var -> donating call line

        def flat(stmts):
            # loop bodies twice: a donate in iteration k poisons reads
            # in iteration k+1
            out = []
            for s in stmts:
                out.append(s)
                for block in ("body", "orelse", "finalbody"):
                    sub = getattr(s, block, None)
                    if sub and not isinstance(
                        s, (ast.FunctionDef, ast.AsyncFunctionDef)
                    ):
                        rep = 2 if isinstance(s, (ast.For, ast.While)) else 1
                        for _ in range(rep):
                            out.extend(flat(sub))
                for h in getattr(s, "handlers", []):
                    out.extend(flat(h.body))
            return out

        def scan_roots(stmt):
            # compound statements appear in flat() AND contribute their
            # nested statements separately — scanning the whole subtree
            # here would process body effects one statement early, so
            # restrict compounds to their header expressions
            if isinstance(stmt, (ast.If, ast.While)):
                return [stmt.test]
            if isinstance(stmt, ast.For):
                return [stmt.iter]
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                return [i.context_expr for i in stmt.items]
            if isinstance(stmt, (ast.Try, ast.FunctionDef, ast.AsyncFunctionDef)):
                return []
            return [stmt]

        def walk_headers(stmt):
            for root in scan_roots(stmt):
                yield from ast.walk(root)

        for stmt in flat(fn.body):
            # alias creation: `launch = _donated if cond else _plain`
            if (
                isinstance(stmt, ast.Assign)
                and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and not isinstance(stmt.value, ast.Call)
            ):
                refs = {
                    n.id
                    for n in ast.walk(stmt.value)
                    if isinstance(n, ast.Name) and n.id in donating
                }
                if refs:
                    nums: set = set()
                    for r in refs:
                        nums.update(donating[r])
                    donating[stmt.targets[0].id] = tuple(sorted(nums))

            # reads of a poisoned buffer = use-after-donate
            for node in walk_headers(stmt):
                if (
                    isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)
                    and node.id in poisoned
                ):
                    key = (node.id, node.lineno)
                    if key not in seen:
                        seen.add(key)
                        findings.append(
                            Finding(
                                "jaxpr-donation-reuse", rel, node.lineno,
                                f"`{node.id}` is read after being donated "
                                f"to a donate_argnums call on line "
                                f"{poisoned[node.id]} — the buffer is "
                                f"consumed; reading it is undefined",
                                hint="rebind the variable to the call's "
                                "result, or pass a copy",
                            )
                        )

            # donating calls poison their donated bare-Name args
            for node in walk_headers(stmt):
                if not (
                    isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in donating
                ):
                    continue
                nums = donating[node.func.id]
                for i, a in enumerate(node.args):
                    if isinstance(a, ast.Starred) and isinstance(
                        a.value, ast.Name
                    ):
                        if any(n >= i for n in nums):
                            poisoned[a.value.id] = node.lineno
                    elif i in nums and isinstance(a, ast.Name):
                        poisoned[a.id] = node.lineno

            # assignment rebinds heal the poison
            if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (
                    stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
                )
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            poisoned.pop(n.id, None)

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan_fn(node)
    return findings


@register(
    "jaxpr-donation-reuse", family="jaxpr", code="LAF102",
    description="no read of a buffer after donating it to a jitted call",
)
def _check_donation_reuse(ctx) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(ctx.ast_roots):
        tree, lines = parse_file(path)
        if tree is None:
            continue
        rel = _rel(path, ctx.repo_root)
        findings.extend(
            filter_inline_suppressed(
                check_file_donation_reuse(path, tree, rel), lines
            )
        )
    return findings


# ---------------------------------------------------------------------------
# LAF103: host callbacks in hot loops
# ---------------------------------------------------------------------------


def check_jaxpr_callbacks(jaxpr, label: str) -> List[Finding]:
    findings = []
    for eqn, depth in _walk_eqns(jaxpr):
        if depth > 0 and eqn.primitive.name in _CALLBACK_PRIMS:
            findings.append(
                Finding(
                    "jaxpr-host-callback-in-loop", label, 0,
                    f"`{eqn.primitive.name}` inside a loop body (depth "
                    f"{depth}) — one host round-trip per iteration "
                    f"serializes the device pipeline",
                    hint="hoist the callback out of the loop, or "
                    "accumulate on device and call back once per launch",
                )
            )
    return findings


@register(
    "jaxpr-host-callback-in-loop", family="jaxpr", code="LAF103",
    description="no host callback primitive inside a scan/while body",
)
def _check_host_callback(ctx) -> List[Finding]:
    findings = []
    for t in ctx.targets.all():
        findings.extend(check_jaxpr_callbacks(t.jaxpr, t.label))
    return findings


# ---------------------------------------------------------------------------
# LAF106: packed words stay loop-invariant in while carries
# ---------------------------------------------------------------------------


def check_jaxpr_packed_while_carry(jaxpr, label: str) -> List[Finding]:
    findings = []
    for eqn, _ in _walk_eqns(jaxpr):
        if eqn.primitive.name != "while":
            continue
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        for k, v in enumerate(eqn.invars[cn + bn :]):
            dtype = getattr(getattr(v, "aval", None), "dtype", None)
            if dtype is not None and dtype.kind == "u":
                findings.append(
                    Finding(
                        "jaxpr-packed-while-carry", label, 0,
                        f"while-loop carry slot {k} is {dtype.name} — a "
                        f"packed-word buffer riding the round loop is "
                        f"rebuilt/copied every iteration instead of "
                        f"staying a loop-invariant operand",
                        hint="close over the packed slab (while body "
                        "consts) and carry only the s32 label vectors; "
                        "fori_loop accumulators belong in scan",
                    )
                )
    return findings


@register(
    "jaxpr-packed-while-carry", family="jaxpr", code="LAF106",
    description="no packed (unsigned) words in a lax.while_loop carry — "
    "the slab is a loop-invariant operand of the round loop",
)
def _check_packed_while_carry(ctx) -> List[Finding]:
    findings = []
    for t in ctx.targets.all():
        findings.extend(check_jaxpr_packed_while_carry(t.jaxpr, t.label))
    return findings


# ---------------------------------------------------------------------------
# LAF107: telemetry carries are scalars / small vectors only
# ---------------------------------------------------------------------------

# a while carry slot may be 1-D up to this many elements (the label
# vector at the standard config is (2048,), the telemetry vectors are
# (64,)); anything 2-D+, or 1-D past this, is slab-sized state being
# rebuilt every round instead of riding as a loop-invariant operand
TELEMETRY_CARRY_MAX_ELEMS = 65536


def check_jaxpr_telemetry_carry(jaxpr, label: str) -> List[Finding]:
    findings = []
    for eqn, _ in _walk_eqns(jaxpr):
        if eqn.primitive.name != "while":
            continue
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        for k, v in enumerate(eqn.invars[cn + bn :]):
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", None)
            if shape is None:
                continue
            ndim = len(shape)
            elems = 1
            for s in shape:
                elems *= int(s)
            if ndim >= 2 or elems > TELEMETRY_CARRY_MAX_ELEMS:
                findings.append(
                    Finding(
                        "jaxpr-telemetry-carry", label, 0,
                        f"while-loop carry slot {k} is "
                        f"{getattr(aval, 'dtype', '?')}{tuple(shape)} — "
                        f"telemetry/state riding the round loop must be "
                        f"s32/f32 scalars or small vectors (<= "
                        f"{TELEMETRY_CARRY_MAX_ELEMS} elems, 1-D), not a "
                        f"matrix rebuilt every iteration",
                        hint="accumulate per-round scalars into a "
                        "(max_iters,) vector via dynamic_update_slice; "
                        "O(n) slabs belong in the loop-invariant consts "
                        "(or a fori_loop/scan accumulator outside the "
                        "fixpoint)",
                    )
                )
    return findings


@register(
    "jaxpr-telemetry-carry", family="jaxpr", code="LAF107",
    description="while-loop carries are scalars or small 1-D vectors — "
    "no matrices / O(n)-per-round arrays riding the fixpoint",
)
def _check_telemetry_carry(ctx) -> List[Finding]:
    findings = []
    for t in ctx.targets.all():
        findings.extend(check_jaxpr_telemetry_carry(t.jaxpr, t.label))
    return findings


# ---------------------------------------------------------------------------
# LAF104: shard_map replication safety (taint)
# ---------------------------------------------------------------------------


def _norm_axes(v) -> Taint:
    if v is None:
        return _EMPTY
    if isinstance(v, str):
        return frozenset((v,))
    if isinstance(v, (tuple, list)):
        out = set()
        for x in v:
            if isinstance(x, str):
                out.add(x)
            elif isinstance(x, (tuple, list)):
                out.update(y for y in x if isinstance(y, str))
        return frozenset(out)
    return _EMPTY


def _names_axes(names) -> Taint:
    """shard_map in_names/out_names entry ({dim: (axes...)}) -> axis set."""
    out = set()
    for axes in dict(names).values():
        out.update(_norm_axes(axes))
    return frozenset(out)


def _taint_closed(closed, ins: List[Taint]) -> List[Taint]:
    jaxpr = _as_open(closed)
    if len(ins) != len(jaxpr.invars):
        # arity mismatch (transform-wrapped call): be conservative
        u = frozenset().union(*ins) if ins else _EMPTY
        return [u] * len(jaxpr.outvars)
    return _taint_jaxpr(jaxpr, ins)


def _taint_jaxpr(jaxpr, in_taints: List[Taint]) -> List[Taint]:
    env: Dict[object, Taint] = {}

    def read(v) -> Taint:
        if type(v).__name__ == "Literal":
            return _EMPTY
        return env.get(v, _EMPTY)

    for v in jaxpr.constvars:
        env[v] = _EMPTY
    for v, t in zip(jaxpr.invars, in_taints):
        env[v] = t

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        ins = [read(v) for v in eqn.invars]
        union = frozenset().union(*ins) if ins else _EMPTY
        if prim in _AXIS_CLEARING_PRIMS:
            cleared = _norm_axes(
                eqn.params.get("axes", eqn.params.get("axis_name"))
            )
            outs = [union - cleared] * len(eqn.outvars)
        elif prim == "axis_index":
            outs = [_norm_axes(eqn.params.get("axis_name"))]
        elif prim == "scan":
            outs = _taint_scan(eqn, ins)
        elif prim == "while":
            outs = _taint_while(eqn, ins)
        elif prim == "cond":
            outs = _taint_cond(eqn, ins)
        else:
            sub = None
            for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                if key in eqn.params and hasattr(
                    _as_open(eqn.params[key]), "eqns"
                ):
                    sub = eqn.params[key]
                    break
            if sub is not None:
                outs = _taint_closed(sub, ins)
                if len(outs) != len(eqn.outvars):
                    outs = [union] * len(eqn.outvars)
            else:
                outs = [union] * len(eqn.outvars)
        for v, t in zip(eqn.outvars, outs):
            env[v] = t

    return [read(v) for v in jaxpr.outvars]


def _taint_scan(eqn, ins: List[Taint]) -> List[Taint]:
    closed = eqn.params["jaxpr"]
    nc, nk = eqn.params["num_consts"], eqn.params["num_carry"]
    consts, carry, xs = list(ins[:nc]), list(ins[nc : nc + nk]), list(ins[nc + nk :])
    # carry fixpoint: a psum inside the body keeps the carry clean even
    # though the conservative union would not — precision matters here
    # (the plane's count psum lives inside its pipeline scan)
    for _ in range(8):
        outs = _taint_closed(closed, consts + carry + xs)
        new = [c | o for c, o in zip(carry, outs[:nk])]
        if new == carry:
            break
        carry = new
    outs = _taint_closed(closed, consts + carry + xs)
    return list(outs[:nk]) + list(outs[nk:])


def _taint_while(eqn, ins: List[Taint]) -> List[Taint]:
    cn, bn = eqn.params["cond_nconsts"], eqn.params["body_nconsts"]
    cond, body = eqn.params["cond_jaxpr"], eqn.params["body_jaxpr"]
    cconsts, bconsts, carry = (
        list(ins[:cn]), list(ins[cn : cn + bn]), list(ins[cn + bn :]),
    )
    for _ in range(8):
        pred = _taint_closed(cond, cconsts + carry)
        pred_t = pred[0] if pred else _EMPTY
        outs = _taint_closed(body, bconsts + carry)
        new = [c | o | pred_t for c, o in zip(carry, outs)]
        if new == carry:
            break
        carry = new
    return carry


def _taint_cond(eqn, ins: List[Taint]) -> List[Taint]:
    branches = eqn.params.get("branches", ())
    idx_t, operands = ins[0] if ins else _EMPTY, ins[1:]
    n_out = len(eqn.outvars)
    outs = [idx_t] * n_out
    for br in branches:
        b_outs = _taint_closed(br, list(operands))
        if len(b_outs) == n_out:
            outs = [o | b for o, b in zip(outs, b_outs)]
        else:
            u = frozenset().union(*operands) if operands else _EMPTY
            outs = [o | u | idx_t for o in outs]
    return outs


def taint_shard_map_outputs(eqn) -> List[Tuple[Taint, Taint]]:
    """Per shard_map output: (residual_taint, declared_axes)."""
    in_names = eqn.params["in_names"]
    out_names = eqn.params["out_names"]
    body = _as_open(eqn.params["jaxpr"])
    ins = [_names_axes(n) for n in in_names]
    outs = _taint_closed(body, ins)
    result = []
    for t, names in zip(outs, out_names):
        declared = _names_axes(names)
        result.append((t - declared, declared))
    return result


def _find_shard_maps(jaxpr):
    for eqn, _ in _walk_eqns(jaxpr):
        if eqn.primitive.name == "shard_map":
            yield eqn


def check_jaxpr_shardmaps(jaxpr, label: str) -> List[Finding]:
    findings = []
    for eqn in _find_shard_maps(jaxpr):
        for k, (resid, declared) in enumerate(taint_shard_map_outputs(eqn)):
            if resid:
                findings.append(
                    Finding(
                        "jaxpr-shardmap-replication", label, 0,
                        f"shard_map output {k} still depends on mesh "
                        f"axes {sorted(resid)} but out_names declares "
                        f"only {sorted(declared) or 'replicated'} — "
                        f"with check_rep=False each device returns its "
                        f"shard-local value as if it were global",
                        hint="psum/all_gather over the residual axes "
                        "before returning, or declare the output "
                        "sharded over them",
                    )
                )
    return findings


@register(
    "jaxpr-shardmap-replication", family="jaxpr", code="LAF104",
    description="shard_map outputs declared replicated are actually replicated",
)
def _check_shardmap_replication(ctx) -> List[Finding]:
    findings = []
    for t in ctx.targets.all():
        findings.extend(check_jaxpr_shardmaps(t.jaxpr, t.label))
    return findings


# ---------------------------------------------------------------------------
# LAF105: recompile lattice boundedness (+ the paired-counter probe)
# ---------------------------------------------------------------------------


def _lattice_static_findings() -> List[Finding]:
    from ..index.sweep import DEFAULT_CHUNKS_PER_LAUNCH, plan_sweep
    from ..stream.serve import bucket_shape

    findings = []
    sigs = {
        (p.rows_per_launch, p.chunk, p.cpl)
        for p in (plan_sweep(nq, 256) for nq in range(1, 4097))
    }
    bound = DEFAULT_CHUNKS_PER_LAUNCH + 2
    if len(sigs) > bound:
        findings.append(
            Finding(
                "jaxpr-recompile-lattice", "src/repro/index/sweep.py", 0,
                f"plan_sweep emits {len(sigs)} distinct launch signatures "
                f"over nq in [1, 4096] at chunk=256 (bound: {bound}) — "
                f"each is one engine compile",
                hint="launch shapes must quantize to the "
                "chunks_per_launch ladder; check the cpl clamp",
            )
        )
    import math

    buckets = {
        bucket_shape(nc, nb, db_tile=256, chunk=256, q_tile=128)
        for nc in range(1, 4097, 7)
        for nb in range(1, 257, 3)
    }
    b_bound = (int(math.log2(4096 // 256)) + 1) * (int(math.log2(256 // 128)) + 1)
    if len(buckets) > b_bound:
        findings.append(
            Finding(
                "jaxpr-recompile-lattice", "src/repro/stream/serve.py", 0,
                f"bucket_shape's image has {len(buckets)} shapes over "
                f"candidates<=4096, blocks<=256 (O(log n) bound: "
                f"{b_bound}) — serving compiles are not log-bounded",
                hint="bucket and chunk must both quantize to powers of "
                "two clamped to the tile bounds",
            )
        )
    return findings


def _paired_counter_findings() -> List[Finding]:
    """Dynamic probe of ``obs.PAIRED_COUNTERS``: run the steady-shape
    append workload (mirrors tests/test_obs.py) and require each pair's
    deltas to move in lockstep."""
    import numpy as np

    from .. import obs
    from ..data.synthetic import make_angular_clusters
    from ..index import RandomProjectionBackend
    from ..obs import metrics

    was_trace, was_metrics = obs.trace_enabled(), obs.metrics_enabled()
    obs.enable(trace=False, metrics_on=True)
    findings: List[Finding] = []
    try:
        data, _ = make_angular_clusters(
            613, 32, 8, kappa=120, noise_frac=0.3, seed=2
        )
        bk = RandomProjectionBackend(
            device=True, interpret=True, sweep=True,
            n_bits=64, margin=3.0, seed=3, chunk=64, q_tile=32, db_tile=64,
        )
        bk.fit(data[:128])
        rows = np.arange(64)
        bk.query_counts(rows, 0.55)  # first sweep pays the initial compile
        names = {n for pair in obs.PAIRED_COUNTERS for n in pair}
        base = {n: metrics.counter(n).value for n in names}
        for start in range(128, 613, 97):
            bk.partial_fit(data[start : start + 97])
            bk.query_counts(rows, 0.55)
        delta = {n: metrics.counter(n).value - base[n] for n in names}
        for left, right in obs.PAIRED_COUNTERS:
            if delta[left] != delta[right]:
                findings.append(
                    Finding(
                        "jaxpr-recompile-lattice", f"<probe:{left}>", 0,
                        f"paired counters diverged over a steady-query-"
                        f"shape append workload: {left} moved "
                        f"{delta[left]}, {right} moved {delta[right]} — "
                        f"a recompile happened without (or beyond) its "
                        f"capacity doubling",
                        hint="a static arg or operand shape other than "
                        "capacity changed across appends; diff the jit "
                        "signatures",
                    )
                )
    finally:
        if was_trace or was_metrics:
            obs.enable(trace=was_trace, metrics_on=was_metrics)
        else:
            obs.disable()
    return findings


@register(
    "jaxpr-recompile-lattice", family="jaxpr", code="LAF105",
    description="compile-signature lattices are bounded; recompiles pair "
    "1:1 with capacity doublings",
)
def _check_recompile_lattice(ctx) -> List[Finding]:
    findings = _lattice_static_findings()
    if getattr(ctx, "dynamic", True):
        findings.extend(_paired_counter_findings())
    return findings


# ---------------------------------------------------------------------------
# LAF108: restored replicas reuse pre-crash compile signatures
# ---------------------------------------------------------------------------


def check_restore_signatures(pre, post, label: str) -> List[Finding]:
    """The restore contract as a pure predicate: every compile signature
    observed after a restore must already exist in the pre-crash set.

    ``pre`` / ``post`` are iterables of hashable signatures (operand
    shape tuples, or whatever the caller quantizes compiles by).  The
    corpus twins feed this directly; the dynamic probe asserts the same
    thing through the live ``sweep.recompiles`` counter.
    """
    pre_set = set(pre)
    fresh = sorted({s for s in post if s not in pre_set}, key=repr)
    if fresh:
        return [
            Finding(
                "jaxpr-restore-replica", label, 0,
                f"restore introduced {len(fresh)} compile signature(s) "
                f"absent before the crash: {fresh[:3]!r} — the restored "
                f"replica pays an engine recompile on its first query",
                hint="state_import must rebuild the capacity-shaped "
                "buffers (append slack included), not trim to the exact "
                "row count",
            )
        ]
    return []


def _restore_probe_findings() -> List[Finding]:
    """Dynamic probe: warm the sweep compile lattice on a backend with
    post-``partial_fit`` append slack, export/import its state into a
    fresh instance, re-run the same query shapes, and require zero new
    ``sweep.recompiles`` (the jitted launches are module-level, so a
    faithful restore hits the pre-crash executable cache)."""
    import numpy as np

    from .. import obs
    from ..data.synthetic import make_angular_clusters
    from ..index import RandomProjectionBackend
    from ..obs import metrics

    # geometry deliberately disjoint from the LAF105 probe / test_obs
    # workload (d=48, n_bits=128): this probe also runs in-process from
    # tier-1, and sharing operand shapes with the recompile-lattice
    # workload would pre-warm the module-level jit caches it measures
    kw = dict(
        device=True, interpret=True, sweep=True,
        n_bits=128, margin=3.0, seed=3, chunk=64, q_tile=32, db_tile=64,
    )
    was_trace, was_metrics = obs.trace_enabled(), obs.metrics_enabled()
    obs.enable(trace=False, metrics_on=True)
    findings: List[Finding] = []
    try:
        data, _ = make_angular_clusters(
            400, 48, 8, kappa=120, noise_frac=0.3, seed=5
        )
        bk = RandomProjectionBackend(**kw)
        bk.fit(data[:256])
        bk.partial_fit(data[256:])  # capacity doubles: append slack on board
        rows = np.arange(48)
        bk.query_counts(rows, 0.55)  # warm the lattice at this query shape
        bk.query_hits(rows, 0.55)
        state = bk.state_export()

        pre = metrics.counter("sweep.recompiles").value
        bk2 = RandomProjectionBackend(**kw).state_import(state)
        bk2.query_counts(rows, 0.55)
        bk2.query_hits(rows, 0.55)
        delta = metrics.counter("sweep.recompiles").value - pre
        if delta:
            findings.append(
                Finding(
                    "jaxpr-restore-replica", "src/repro/index/random_projection.py",
                    0,
                    f"restored replica compiled {delta} new sweep "
                    f"signature(s) re-running the pre-crash query shapes "
                    f"— state_import does not reproduce the capacity-"
                    f"shaped operands",
                    hint="export/import the full capacity buffers "
                    "(_data_buf/_sigs_buf), not the n-row views",
                )
            )
    finally:
        if was_trace or was_metrics:
            obs.enable(trace=was_trace, metrics_on=was_metrics)
        else:
            obs.disable()
    return findings


@register(
    "jaxpr-restore-replica", family="jaxpr", code="LAF108",
    description="snapshot restore reuses pre-crash compile signatures "
    "(recompile-free recovery)",
)
def _check_restore_replica(ctx) -> List[Finding]:
    if getattr(ctx, "dynamic", True):
        return _restore_probe_findings()
    return []
