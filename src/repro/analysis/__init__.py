"""``repro.analysis`` — laf-lint: jaxpr/HLO/AST invariant checks over
the launch surface, with a CI gate.

Three pass families, one stable check id + LAF-code each::

    python -m repro.analysis                  # run everything
    python -m repro.analysis --list-checks    # jax-free inventory
    python -m repro.analysis --only=hlo-bitmap-collective
    python -m repro.analysis --corpus tests/analysis_corpus

* **jaxpr** (LAF1xx) — donation safety, host callbacks in hot loops,
  shard_map replication taint, recompile-lattice boundedness; traced
  from the real entry points (:mod:`.targets`).
* **hlo** (LAF2xx) — collective hygiene + fusion-boundary byte budgets
  on the optimized HLO, via :mod:`repro.launch.hlo_analysis`.
* **ast** (LAF3xx) — source lint: traced branches, unsynced wall-clock
  timing, raw ``pallas_call`` placement, kernel tile contracts; also a
  flake8 plugin (:class:`.ast_lint.LafLintPlugin`).

Findings exit nonzero unless suppressed by ``analysis/baseline.toml``
or an inline ``# laf-lint: disable=<check-id>``.

This package root is import-light (no jax) so ``--list-checks`` and
the flake8 plugin load instantly; jax is touched only when checks run.
"""

from .registry import CHECKS, CheckSpec, Finding, load_all_checks, run_checks
from .report import (
    DEFAULT_BASELINE,
    load_baseline,
    render_console,
    save_baseline,
    split_suppressed,
    to_json,
)

__all__ = [
    "CHECKS",
    "CheckSpec",
    "Finding",
    "load_all_checks",
    "run_checks",
    "DEFAULT_BASELINE",
    "load_baseline",
    "save_baseline",
    "split_suppressed",
    "render_console",
    "to_json",
]
