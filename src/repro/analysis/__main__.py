"""laf-lint CLI: ``python -m repro.analysis``.

Exit status: 0 when every selected check is clean (modulo the
baseline) and, with ``--corpus``, every golden entry detects; 1
otherwise — this is the CI gate.
"""

import os

# the sharded-plane/laf_cluster targets want a multi-device mesh; force
# 4 host devices BEFORE jax initializes, unless the caller already
# forced a count themselves
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    )

import argparse
import sys
from pathlib import Path

from .registry import CHECKS, load_all_checks, run_checks
from .report import (
    DEFAULT_BASELINE,
    load_baseline,
    render_console,
    save_baseline,
    split_suppressed,
    to_json,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="laf-lint: jaxpr/HLO/AST invariant checks over the "
        "launch surface",
    )
    ap.add_argument("--list-checks", action="store_true",
                    help="print the check inventory (no jax, no tracing) and exit")
    ap.add_argument("--only", default="",
                    help="comma-separated check ids to run (default: all)")
    ap.add_argument("--skip", default="",
                    help="comma-separated check ids to skip")
    ap.add_argument("--family", default="",
                    help="comma-separated families to run (jaxpr,hlo,ast)")
    ap.add_argument("--format", choices=("console", "json"), default="console")
    ap.add_argument("--out", default="",
                    help="also write the report (always JSON) to this path")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="suppression baseline TOML (default: the checked-in one)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="suppress every current finding into the baseline and exit 0")
    ap.add_argument("--corpus", default="",
                    help="also run the golden-violation corpus at this directory")
    ap.add_argument("--repo-root", default="",
                    help="repository root (default: derived from this package)")
    ap.add_argument("--no-dynamic", action="store_true",
                    help="skip checks' dynamic probes (paired-counter workload)")
    args = ap.parse_args(argv)

    load_all_checks()

    if args.list_checks:
        for spec in sorted(CHECKS.values(), key=lambda s: s.code):
            print(f"{spec.code}  {spec.id:32s} [{spec.family}] {spec.description}")
        return 0

    def id_set(csv):
        ids = {s.strip() for s in csv.split(",") if s.strip()}
        unknown = ids - set(CHECKS)
        if unknown:
            ap.error(
                f"unknown check id(s): {', '.join(sorted(unknown))} "
                f"(see --list-checks)"
            )
        return ids or None

    only, skip = id_set(args.only), id_set(args.skip)
    families = {s.strip() for s in args.family.split(",") if s.strip()} or None

    from .targets import Context

    ctx = Context.for_repo(
        args.repo_root or None, dynamic=not args.no_dynamic
    )
    findings = run_checks(ctx, only=only, skip=skip, families=families)

    if args.write_baseline:
        save_baseline(findings, args.baseline)
        print(f"baselined {len(findings)} finding(s) -> {args.baseline}")
        return 0

    rules = load_baseline(args.baseline)
    open_findings, suppressed = split_suppressed(findings, rules)
    checks_run = [
        s.id for s in CHECKS.values()
        if (only is None or s.id in only)
        and (skip is None or s.id not in skip)
        and (families is None or s.family in families)
    ]

    corpus_failures = []
    if args.corpus:
        from .corpus import run_corpus

        res = run_corpus(Path(args.corpus))
        corpus_failures = res.failed
        print(
            f"corpus: {len(res.passed)} entries detected correctly, "
            f"{len(res.failed)} failed"
        )
        for entry, why in res.failed:
            print(f"  CORPUS FAIL {entry}: {why}")

    if args.format == "json":
        print(to_json(open_findings, suppressed, checks_run))
    else:
        print(render_console(open_findings, suppressed, checks_run))
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(to_json(open_findings, suppressed, checks_run))

    return 1 if open_findings or corpus_failures else 0


if __name__ == "__main__":
    sys.exit(main())
