"""AST passes (stdlib ``ast``): repo idiom enforcement.

Checks (family ``ast``, flake8 codes LAF3xx):

* ``ast-traced-branch`` — no Python ``if``/``assert``/``while`` on a
  traced value inside a jit-compiled function.  A value is *static*
  when it derives only from ``static_argnames``/``static_argnums``
  parameters, shape/dtype metadata (``x.shape``, ``x.ndim``, ``len(x)``,
  ...), literals, or names from outside the function; everything else
  reaching a branch predicate is a tracer and the branch is a trace
  error (or worse, a silent per-trace specialization).
* ``ast-wallclock-sync`` — no ``time.time()``/``perf_counter()`` pair
  bracketing a JAX-dispatching call without a sync (``block_until_ready``
  / ``jax.device_get`` / an ``obs.span`` with ``sync=``/``force=``) —
  an unsynced bracket measures dispatch, not execution.
* ``ast-raw-pallas-call`` — ``pl.pallas_call`` appears only in
  ``kernels/*/kernel.py``; wrappers/ops layers go through the kernel
  module's public entry points.
* ``ast-kernel-tile-contract`` — a kernel package's ``ops.py`` must not
  redefine or contradict ``kernel.py``'s ``DEFAULT_*_TILE`` constants,
  and each default must satisfy the divisibility asserts the kernel
  body itself states (e.g. ``db_tile % 32 == 0``).

Suppress a single site with ``# laf-lint: disable=<check-id>`` on the
flagged line (or the line above); whole-path suppressions belong in
``analysis/baseline.toml``.

The module doubles as a flake8 plugin (``LafLintPlugin``) so editors
wired to flake8 report the same findings with LAF3xx codes.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .registry import Finding, register

__all__ = [
    "iter_py_files",
    "parse_file",
    "filter_inline_suppressed",
    "check_file_traced_branch",
    "check_file_wallclock_sync",
    "check_file_raw_pallas_call",
    "check_tree_kernel_tile_contract",
    "LafLintPlugin",
]


# ---------------------------------------------------------------------------
# shared file machinery
# ---------------------------------------------------------------------------


def iter_py_files(roots: Iterable[Path]) -> List[Path]:
    out = []
    for root in roots:
        root = Path(root)
        if root.is_file() and root.suffix == ".py":
            out.append(root)
        elif root.is_dir():
            out.extend(sorted(root.rglob("*.py")))
    return out


def parse_file(path: Path) -> Tuple[Optional[ast.AST], List[str]]:
    src = Path(path).read_text()
    lines = src.splitlines()
    try:
        return ast.parse(src), lines
    except SyntaxError:
        return None, lines


def filter_inline_suppressed(
    findings: List[Finding], lines: List[str]
) -> List[Finding]:
    """Drop findings whose line (or the one above) carries
    ``# laf-lint: disable=<check-id>``."""
    out = []
    for f in findings:
        tag = f"laf-lint: disable={f.check}"
        near = [
            lines[i]
            for i in (f.line - 1, f.line - 2)
            if 0 <= i < len(lines)
        ]
        if not any(tag in ln for ln in near):
            out.append(f)
    return out


def _call_name(node: ast.AST) -> str:
    """Trailing identifier of a call target: ``a.b.c(...)`` -> ``c``."""
    if isinstance(node, ast.Call):
        node = node.func
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _module_constants(tree: ast.AST) -> Dict[str, object]:
    """Module-level literal assignments (for resolving ``_STATIC``-style
    static_argnames constants)."""
    consts: Dict[str, object] = {}
    for stmt in getattr(tree, "body", []):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            t = stmt.targets[0]
            if isinstance(t, ast.Name):
                try:
                    consts[t.id] = ast.literal_eval(stmt.value)
                except (ValueError, SyntaxError):
                    pass
    return consts


# ---------------------------------------------------------------------------
# ast-traced-branch
# ---------------------------------------------------------------------------

_STATIC_ATTRS = {
    "shape", "ndim", "dtype", "size", "itemsize", "sharding", "weak_type",
    "aval",
}
_STATIC_CALLS = {
    "len", "isinstance", "issubclass", "hasattr", "type", "callable",
    "range", "id",
}


def _resolve_static_spec(value: ast.AST, consts: Dict[str, object]):
    """static_argnames/static_argnums value -> python object or None."""
    try:
        return ast.literal_eval(value)
    except (ValueError, SyntaxError):
        if isinstance(value, ast.Name):
            return consts.get(value.id)
    return None


def _jit_call_static(call: ast.Call, consts: Dict[str, object]):
    """If ``call`` is ``jax.jit(...)``/``jit(...)`` (possibly through
    ``functools.partial``), return (names, nums); else None."""
    name = _call_name(call)
    if name == "partial" and call.args:
        inner = call.args[0]
        if _call_name(inner) == "jit":
            names, nums = _jit_kwargs(call, consts)
            return names, nums
        return None
    if name == "jit":
        return _jit_kwargs(call, consts)
    return None


def _jit_kwargs(call: ast.Call, consts) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = _resolve_static_spec(kw.value, consts)
            if isinstance(v, str):
                names.add(v)
            elif isinstance(v, (tuple, list)):
                names.update(x for x in v if isinstance(x, str))
        elif kw.arg == "static_argnums":
            v = _resolve_static_spec(kw.value, consts)
            if isinstance(v, int):
                nums.add(v)
            elif isinstance(v, (tuple, list)):
                nums.update(x for x in v if isinstance(x, int))
    return names, nums


def _jitted_functions(tree: ast.AST, consts) -> List[Tuple[ast.AST, Set[str]]]:
    """(function_def, static_param_names) for every function the module
    jit-compiles — via decorator or a module-level ``X = jax.jit(F, ...)``."""
    defs = {
        n.name: n
        for n in getattr(tree, "body", [])
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    out = []
    seen = set()

    def param_names(fn) -> List[str]:
        a = fn.args
        return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]

    def add(fn, names: Set[str], nums: Set[int]):
        if id(fn) in seen:
            return
        seen.add(id(fn))
        params = param_names(fn)
        static = set(names)
        static.update(params[i] for i in nums if i < len(params))
        out.append((fn, static))

    for fn in defs.values():
        for dec in fn.decorator_list:
            if _call_name(dec) == "jit" and not isinstance(dec, ast.Call):
                add(fn, set(), set())
            elif isinstance(dec, ast.Call):
                spec = _jit_call_static(dec, consts)
                if spec is not None:
                    add(fn, *spec)
    for stmt in getattr(tree, "body", []):
        if not (isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call)):
            continue
        call = stmt.value
        if _call_name(call) != "jit":
            continue
        names, nums = _jit_kwargs(call, consts)
        if call.args and isinstance(call.args[0], ast.Name):
            fn = defs.get(call.args[0].id)
            if fn is not None:
                add(fn, names, nums)
    return out


def _expr_traced(node: ast.AST, traced: Set[str]) -> bool:
    """Does this expression's value depend on a traced name?  Shape/
    dtype extractors and type predicates prune to static."""
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False
        return _expr_traced(node.value, traced)
    if isinstance(node, ast.Call):
        if _call_name(node) in _STATIC_CALLS:
            return False
        parts = [node.func, *node.args, *(kw.value for kw in node.keywords)]
        return any(_expr_traced(p, traced) for p in parts)
    if isinstance(node, ast.Name):
        return node.id in traced
    if isinstance(node, ast.Compare):
        # `x is None` / `x is not None` inspects the python object, not
        # the traced value — the idiomatic default-argument test
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return False
    return any(_expr_traced(c, traced) for c in ast.iter_child_nodes(node))


def _assigned_names(target: ast.AST) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out = []
        for el in target.elts:
            out.extend(_assigned_names(el.value if isinstance(el, ast.Starred) else el))
        return out
    return []


def _scan_traced_branches(
    fn: ast.AST, static: Set[str], path: str
) -> List[Finding]:
    a = fn.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    traced: Set[str] = {p for p in params if p not in static}

    def propagate(stmts, traced: Set[str]) -> Set[str]:
        for stmt in stmts:
            if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (
                    stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
                )
                value = stmt.value
                if value is not None:
                    is_traced = _expr_traced(value, traced)
                    for t in targets:
                        for name in _assigned_names(t):
                            if is_traced:
                                traced.add(name)
                            else:
                                traced.discard(name)
            elif isinstance(stmt, ast.For):
                if _expr_traced(stmt.iter, traced):
                    traced.update(_assigned_names(stmt.target))
                traced = propagate(stmt.body, traced)
                traced = propagate(stmt.orelse, traced)
            elif isinstance(stmt, (ast.If, ast.While)):
                traced = propagate(stmt.body, traced)
                traced = propagate(stmt.orelse, traced)
            elif isinstance(stmt, ast.With):
                traced = propagate(stmt.body, traced)
            elif isinstance(stmt, ast.Try):
                for block in (stmt.body, stmt.orelse, stmt.finalbody):
                    traced = propagate(block, traced)
                for h in stmt.handlers:
                    traced = propagate(h.body, traced)
        return traced

    # fixpoint the assignment dataflow (loop-carried reassignments),
    # then report in a second pass
    for _ in range(3):
        before = set(traced)
        traced = propagate(fn.body, traced)
        if traced == before:
            break

    findings: List[Finding] = []

    def report(stmts, traced: Set[str]):
        for stmt in stmts:
            if isinstance(stmt, (ast.If, ast.While)) and _expr_traced(
                stmt.test, traced
            ):
                kind = "if" if isinstance(stmt, ast.If) else "while"
                findings.append(
                    Finding(
                        "ast-traced-branch", path, stmt.lineno,
                        f"python `{kind}` on a traced value inside jitted "
                        f"`{fn.name}` — the branch runs at trace time, not "
                        f"per element",
                        hint="use lax.cond/lax.select/jnp.where, or mark the "
                        "argument static (static_argnames)",
                    )
                )
            elif isinstance(stmt, ast.Assert) and _expr_traced(stmt.test, traced):
                findings.append(
                    Finding(
                        "ast-traced-branch", path, stmt.lineno,
                        f"`assert` on a traced value inside jitted `{fn.name}` "
                        f"— it checks the tracer, not runtime data",
                        hint="assert on .shape/.dtype (static), or use "
                        "checkify for runtime value checks",
                    )
                )
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested defs are traced bodies (scan/fori/cond callees):
                # their params are tracers
                inner = set(traced)
                ia = stmt.args
                inner.update(
                    p.arg for p in ia.posonlyargs + ia.args + ia.kwonlyargs
                )
                report(stmt.body, inner)
            for block in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, block, None)
                if sub and not isinstance(
                    stmt, (ast.FunctionDef, ast.AsyncFunctionDef)
                ):
                    report(sub, traced)
            for h in getattr(stmt, "handlers", []):
                report(h.body, traced)

    report(fn.body, traced)
    return findings


def check_file_traced_branch(path: Path, tree: ast.AST, rel: str) -> List[Finding]:
    consts = _module_constants(tree)
    findings: List[Finding] = []
    for fn, static in _jitted_functions(tree, consts):
        findings.extend(_scan_traced_branches(fn, static, rel))
    return findings


# ---------------------------------------------------------------------------
# ast-wallclock-sync
# ---------------------------------------------------------------------------

_TIME_FNS = {"time", "perf_counter", "monotonic", "process_time"}

# call names that dispatch JAX work asynchronously — a wall-clock pair
# around any of these without a sync measures dispatch, not execution
DISPATCH_CALLS = {
    "laf_dbscan", "dbscan_parallel", "dbscan_pp", "laf_dbscan_pp",
    "sweep_counts", "sweep_bitmap", "sharded_sweep_launch",
    "sharded_sweep_marginals", "sharded_band_marginals",
    "hamming_filter_pallas", "hamming_filter_count", "hamming_filter_bitmap",
    "query_hits", "query_counts", "query_hits_subset", "query_hits_packed",
    "partial_fit", "rmi_predict_counts", "predict_counts", "cluster_step",
}
_SYNC_CALLS = {"block_until_ready", "device_get", "sync_on"}
_SPAN_NAMES = {"span", "_span"}


def _is_time_call(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and _call_name(node) in _TIME_FNS and (
        isinstance(node.func, ast.Attribute)
        and isinstance(node.func.value, ast.Name)
        and node.func.value.id == "time"
        or isinstance(node.func, ast.Name)
    )


def _region_status(stmts: List[ast.stmt]) -> Tuple[Optional[str], Optional[int]]:
    """(dispatch_call_name, line) if the statements dispatch without a
    sync; (None, None) when clean."""
    dispatch: Optional[Tuple[str, int]] = None
    for stmt in stmts:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if name in _SYNC_CALLS:
                return None, None
            if name in _SPAN_NAMES and any(
                kw.arg in ("sync", "force") for kw in node.keywords
            ):
                return None, None
            if name in DISPATCH_CALLS and dispatch is None:
                dispatch = (name, node.lineno)
    return dispatch if dispatch else (None, None)


def _scan_wallclock(fn_body: List[ast.stmt], rel: str) -> List[Finding]:
    findings: List[Finding] = []

    def flat(stmts) -> List[ast.stmt]:
        out = []
        for s in stmts:
            out.append(s)
            for block in ("body", "orelse", "finalbody"):
                sub = getattr(s, block, None)
                if sub and not isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    out.extend(flat(sub))
            for h in getattr(s, "handlers", []):
                out.extend(flat(h.body))
        return out

    stmts = flat(fn_body)
    for i, stmt in enumerate(stmts):
        if not (
            isinstance(stmt, ast.Assign)
            and len(stmt.targets) == 1
            and isinstance(stmt.targets[0], ast.Name)
            and _is_time_call(stmt.value)
        ):
            continue
        timer = stmt.targets[0].id
        for j in range(i + 1, len(stmts)):
            reads = any(
                isinstance(n, ast.Name)
                and n.id == timer
                and isinstance(n.ctx, ast.Load)
                for n in ast.walk(stmts[j])
            )
            if not reads:
                continue
            name, line = _region_status(stmts[i + 1 : j + 1])
            if name is not None:
                findings.append(
                    Finding(
                        "ast-wallclock-sync", rel, stmt.lineno,
                        f"wall-clock pair `{timer}` brackets async JAX "
                        f"dispatch `{name}(...)` (line {line}) without a "
                        f"sync — it measures dispatch, not execution",
                        hint="wrap the region in obs.span(..., force=True) "
                        "with .sync_on(outputs), or jax.block_until_ready "
                        "the results before reading the clock",
                    )
                )
            break
    return findings


def check_file_wallclock_sync(path: Path, tree: ast.AST, rel: str) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            findings.extend(_scan_wallclock(node.body, rel))
    return findings


# ---------------------------------------------------------------------------
# ast-raw-pallas-call
# ---------------------------------------------------------------------------


def _is_kernel_module(rel: str) -> bool:
    parts = Path(rel).parts
    return (
        len(parts) >= 3
        and parts[-1] == "kernel.py"
        and "kernels" in parts[:-1]
    )


def check_file_raw_pallas_call(path: Path, tree: ast.AST, rel: str) -> List[Finding]:
    if _is_kernel_module(rel):
        return []
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _call_name(node) == "pallas_call":
            findings.append(
                Finding(
                    "ast-raw-pallas-call", rel, node.lineno,
                    "raw pl.pallas_call outside kernels/*/kernel.py — "
                    "kernel launches live in the kernel module, wrappers "
                    "go through its public entry points",
                    hint="move the pallas_call into the kernel package's "
                    "kernel.py and export a wrapper",
                )
            )
    return findings


# ---------------------------------------------------------------------------
# ast-kernel-tile-contract
# ---------------------------------------------------------------------------


def _tile_constants(tree: ast.AST) -> Dict[str, int]:
    return {
        k: v
        for k, v in _module_constants(tree).items()
        if k.startswith("DEFAULT_") and isinstance(v, int)
    }


def _param_defaults(tree: ast.AST) -> List[Tuple[str, int, int]]:
    """(param_name, literal_default, lineno) for tile-like params."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        a = node.args
        pairs = list(zip(reversed(a.args + a.posonlyargs), reversed(a.defaults)))
        pairs += [
            (p, d) for p, d in zip(a.kwonlyargs, a.kw_defaults) if d is not None
        ]
        for param, default in pairs:
            if not param.arg.endswith("_tile"):
                continue
            if isinstance(default, ast.Constant) and isinstance(
                default.value, int
            ):
                out.append((param.arg, default.value, default.lineno))
    return out


def _divisibility_asserts(tree: ast.AST) -> List[Tuple[str, int, int]]:
    """(name, modulus, lineno) from ``assert ... name % N == 0 ...``."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        if not (
            isinstance(node.left, ast.BinOp)
            and isinstance(node.left.op, ast.Mod)
            and isinstance(node.left.left, ast.Name)
            and isinstance(node.left.right, ast.Constant)
            and isinstance(node.left.right.value, int)
            and len(node.ops) == 1
            and isinstance(node.ops[0], ast.Eq)
            and isinstance(node.comparators[0], ast.Constant)
            and node.comparators[0].value == 0
        ):
            continue
        out.append((node.left.left.id, node.left.right.value, node.lineno))
    return out


def check_tree_kernel_tile_contract(roots: Iterable[Path], rel_to: Path) -> List[Finding]:
    findings: List[Finding] = []
    for kernel_py in iter_py_files(roots):
        if kernel_py.name != "kernel.py":
            continue
        k_tree, k_lines = parse_file(kernel_py)
        if k_tree is None:
            continue
        k_rel = _rel(kernel_py, rel_to)
        consts = _tile_constants(k_tree)
        # (3) the kernel's own divisibility asserts must hold for its
        # shipped defaults
        for name, mod, line in _divisibility_asserts(k_tree):
            const = consts.get("DEFAULT_" + name.upper())
            if const is not None and const % mod:
                findings.append(
                    Finding(
                        "ast-kernel-tile-contract", k_rel, line,
                        f"kernel asserts `{name} % {mod} == 0` but its own "
                        f"DEFAULT_{name.upper()} = {const} violates it",
                        hint=f"make DEFAULT_{name.upper()} a multiple of {mod}",
                    )
                )
        ops_py = kernel_py.with_name("ops.py")
        if not ops_py.exists():
            continue
        o_tree, _ = parse_file(ops_py)
        if o_tree is None:
            continue
        o_rel = _rel(ops_py, rel_to)
        # (1) ops.py must not redefine a kernel tile constant
        for name, val in _tile_constants(o_tree).items():
            if name in consts and val != consts[name]:
                findings.append(
                    Finding(
                        "ast-kernel-tile-contract", o_rel, 1,
                        f"ops.py redefines {name} = {val}, kernel.py has "
                        f"{consts[name]} — the padding math and the kernel "
                        f"grid disagree",
                        hint=f"import {name} from .kernel instead of "
                        "redefining it",
                    )
                )
        # (2) literal tile defaults in ops.py signatures must match
        for pname, val, line in _param_defaults(o_tree):
            const_name = "DEFAULT_" + pname.upper()
            if const_name in consts and val != consts[const_name]:
                findings.append(
                    Finding(
                        "ast-kernel-tile-contract", o_rel, line,
                        f"ops.py defaults {pname}={val} but kernel.py "
                        f"{const_name} = {consts[const_name]}",
                        hint=f"default the parameter to {const_name} "
                        "imported from .kernel",
                    )
                )
    return findings


def _rel(path: Path, rel_to: Path) -> str:
    try:
        return str(Path(path).resolve().relative_to(Path(rel_to).resolve()))
    except ValueError:
        return str(path)


# ---------------------------------------------------------------------------
# registered checks (ctx-driven)
# ---------------------------------------------------------------------------


def _run_file_check(ctx, per_file) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(ctx.ast_roots):
        tree, lines = parse_file(path)
        if tree is None:
            continue
        rel = _rel(path, ctx.repo_root)
        findings.extend(filter_inline_suppressed(per_file(path, tree, rel), lines))
    return findings


@register(
    "ast-traced-branch", family="ast", code="LAF301",
    description="no python if/assert/while on traced values in jitted code",
)
def _check_traced_branch(ctx) -> List[Finding]:
    return _run_file_check(ctx, check_file_traced_branch)


@register(
    "ast-wallclock-sync", family="ast", code="LAF302",
    description="no wall-clock timing around JAX dispatch without a sync",
)
def _check_wallclock(ctx) -> List[Finding]:
    return _run_file_check(ctx, check_file_wallclock_sync)


@register(
    "ast-raw-pallas-call", family="ast", code="LAF303",
    description="pl.pallas_call only inside kernels/*/kernel.py",
)
def _check_pallas(ctx) -> List[Finding]:
    return _run_file_check(ctx, check_file_raw_pallas_call)


@register(
    "ast-kernel-tile-contract", family="ast", code="LAF304",
    description="kernel.py/ops.py tile constants and divisibility agree",
)
def _check_tiles(ctx) -> List[Finding]:
    return check_tree_kernel_tile_contract(ctx.ast_roots, ctx.repo_root)


# ---------------------------------------------------------------------------
# flake8 plugin
# ---------------------------------------------------------------------------


class LafLintPlugin:
    """flake8 plugin entry point (AST-family checks only — jaxpr/HLO
    passes need live tracing and stay in ``python -m repro.analysis``).

    Register in setup.cfg/pyproject under ``flake8.extension`` as
    ``LAF = repro.analysis.ast_lint:LafLintPlugin``.
    """

    name = "laf-lint"
    version = "1.0.0"

    def __init__(self, tree: ast.AST, filename: str = "<unknown>"):
        self._tree = tree
        self._filename = filename

    def run(self):
        from .registry import CHECKS, load_all_checks

        load_all_checks()
        path = Path(self._filename)
        rel = str(path)
        findings: List[Finding] = []
        for per_file in (
            check_file_traced_branch,
            check_file_wallclock_sync,
            check_file_raw_pallas_call,
        ):
            findings.extend(per_file(path, self._tree, rel))
        try:
            lines = path.read_text().splitlines()
            findings = filter_inline_suppressed(findings, lines)
        except OSError:
            pass
        for f in findings:
            code = CHECKS[f.check].code if f.check in CHECKS else "LAF300"
            yield f.line, 0, f"{code} {f.message}", type(self)
