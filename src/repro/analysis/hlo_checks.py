"""HLO passes: invariants on the optimized HLO of the standard targets,
built on :mod:`repro.launch.hlo_analysis`'s parser.

* ``hlo-bitmap-collective`` (LAF201) — no collective moves packed
  bitmap words (u32/u64/u16/u8 element types) inside a loop body.  The
  plane's contract is that only per-query *count* psums (s32) run per
  chunk; the packed adjacency crosses the network exactly once, at
  launch end, via the ``out_specs`` gather — a loop-rooted
  unsigned-word collective means an adjacency slab went on the wire
  per chunk.
* ``hlo-loop-collective-allowlist`` (LAF202) — collectives inside while
  bodies are restricted to the allowlist (per-chunk s32 count
  all-reduce).  Anything else in a loop body multiplies by the trip
  count.
* ``hlo-fusion-bytes-budget`` (LAF203) — ``analyze_hlo``'s
  fusion-boundary ``bytes_accessed`` stays under the per-target budget
  (:data:`repro.analysis.targets.BYTE_BUDGETS`, ~6x measured) — the
  tripwire for an accidental f32 bitmap or a materialized (nq, n)
  intermediate.

``check_hlo_text`` is the shared core: the corpus runner feeds it
fixture HLO and ``repro.launch.dryrun`` calls it per compiled cell.
jax imports are deferred so ``--list-checks`` stays jax-free (the
HLO parser itself is pure-regex and safe to import).
"""

from __future__ import annotations

from typing import List, Optional, Set, Tuple

from .registry import Finding, register

__all__ = ["PACKED_WORD_TYPES", "LOOP_COLLECTIVE_ALLOWLIST", "check_hlo_text"]

PACKED_WORD_TYPES: Set[str] = {"u8", "u16", "u32", "u64"}

# (op, element_type) pairs allowed inside a while body: the per-chunk
# count psum is the only collective the pipelined plane is specified to
# run per iteration
LOOP_COLLECTIVE_ALLOWLIST: Set[Tuple[str, str]] = {
    ("all-reduce", "s32"),
}


def check_hlo_text(
    hlo: str,
    label: str,
    *,
    byte_budget: Optional[int] = None,
    loop_allowlist: Set[Tuple[str, str]] = LOOP_COLLECTIVE_ALLOWLIST,
) -> List[Finding]:
    """All HLO findings for one compiled module (shared by the target
    checks, the corpus runner, and the dryrun hook)."""
    from ..launch.hlo_analysis import analyze_hlo, collectives_by_computation

    findings: List[Finding] = []
    for comp in collectives_by_computation(hlo).values():
        for c in comp.collectives:
            # the single sanctioned packed-word collective is the
            # end-of-launch out_specs gather, which lives OUTSIDE the
            # chunk loop; any loop-rooted one is per-chunk wire traffic
            if c.element_type in PACKED_WORD_TYPES and comp.is_loop_body:
                findings.append(
                    Finding(
                        "hlo-bitmap-collective", label, c.line,
                        f"{c.op} moves {c.element_type} "
                        f"({c.bytes:,} bytes) inside loop body "
                        f"`{comp.name}` — packed bitmap words on the wire "
                        f"per chunk; only s32 count psums may run inside "
                        f"the loop (the bitmap gathers once, at launch "
                        f"end, via out_specs)",
                        hint="keep bitmap words shard-local until the "
                        "shard_map out_specs gather; psum counts, not "
                        "words",
                    )
                )
            if comp.is_loop_body and (c.op, c.element_type) not in loop_allowlist:
                trip = (
                    f"x{comp.trip_count} iterations"
                    if comp.trip_count
                    else "unknown trip count"
                )
                findings.append(
                    Finding(
                        "hlo-loop-collective-allowlist", label, c.line,
                        f"{c.op}({c.element_type}, {c.bytes:,} bytes) "
                        f"inside while body `{comp.name}` ({trip}) is not "
                        f"on the loop-collective allowlist "
                        f"{sorted(loop_allowlist)}",
                        hint="hoist the collective out of the loop or "
                        "extend the allowlist deliberately (with a "
                        "baseline entry explaining why)",
                    )
                )
    if byte_budget is not None:
        measured = analyze_hlo(hlo).bytes_accessed
        if measured > byte_budget:
            findings.append(
                Finding(
                    "hlo-fusion-bytes-budget", label, 0,
                    f"fusion-boundary traffic {measured:,.0f} bytes "
                    f"exceeds the target budget {byte_budget:,} — a "
                    f"fusion boundary regressed (f32 bitmap? "
                    f"materialized (nq, n) intermediate?)",
                    hint="diff analyze_hlo(...).fusion_boundaries against "
                    "a known-good build; retune BYTE_BUDGETS only for "
                    "intentional changes",
                )
            )
    return findings


def _target_findings(ctx, wanted: str) -> List[Finding]:
    findings = []
    for t in ctx.targets.all():
        fs = check_hlo_text(t.hlo, t.label, byte_budget=t.byte_budget)
        findings.extend(f for f in fs if f.check == wanted)
    return findings


@register(
    "hlo-bitmap-collective", family="hlo", code="LAF201",
    description="no collective moves packed bitmap words (u32 et al.)",
)
def _check_bitmap_collective(ctx) -> List[Finding]:
    return _target_findings(ctx, "hlo-bitmap-collective")


@register(
    "hlo-loop-collective-allowlist", family="hlo", code="LAF202",
    description="loop-body collectives restricted to s32 count psums",
)
def _check_loop_allowlist(ctx) -> List[Finding]:
    return _target_findings(ctx, "hlo-loop-collective-allowlist")


@register(
    "hlo-fusion-bytes-budget", family="hlo", code="LAF203",
    description="fusion-boundary bytes_accessed within per-target budget",
)
def _check_bytes_budget(ctx) -> List[Finding]:
    return _target_findings(ctx, "hlo-fusion-bytes-budget")
