"""Check registry: stable IDs, families, findings.

Every pass registers itself under a **stable check id** (the name CI
logs, ``--only=``, and ``baseline.toml`` all reference) plus a flake8
code (``LAF1xx`` jaxpr, ``LAF2xx`` HLO, ``LAF3xx`` AST).  A check is a
function ``fn(ctx) -> list[Finding]``; the registry is populated by
importing the three pass modules (``load_all_checks``), which keeps
``--list-checks`` jax-free — the pass modules defer their jax imports
to call time.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

__all__ = [
    "Finding",
    "CheckSpec",
    "CHECKS",
    "register",
    "load_all_checks",
    "run_checks",
]


@dataclass
class Finding:
    """One invariant violation, anchored to a file:line (AST passes) or
    a traced/compiled target label (jaxpr/HLO passes)."""

    check: str           # stable check id, e.g. "ast-wallclock-sync"
    path: str            # repo-relative file path or "<target:name>"
    line: int            # 1-based; 0 for whole-target findings
    message: str         # what is wrong
    hint: str = ""       # how to fix it
    severity: str = "error"

    def location(self) -> str:
        return f"{self.path}:{self.line}" if self.line else self.path

    def to_dict(self) -> dict:
        return {
            "check": self.check,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "hint": self.hint,
            "severity": self.severity,
        }


@dataclass(frozen=True)
class CheckSpec:
    id: str
    family: str          # "jaxpr" | "hlo" | "ast"
    code: str            # flake8-style code (LAF101, ...)
    description: str
    fn: Callable = field(compare=False)


CHECKS: Dict[str, CheckSpec] = {}


def register(check_id: str, *, family: str, code: str, description: str):
    """Decorator registering a pass under its stable id."""

    def deco(fn):
        if check_id in CHECKS:
            raise ValueError(f"duplicate check id {check_id!r}")
        CHECKS[check_id] = CheckSpec(check_id, family, code, description, fn)
        return fn

    return deco


_loaded = False


def load_all_checks() -> Dict[str, CheckSpec]:
    """Import the pass modules (idempotent) and return the registry."""
    global _loaded
    if not _loaded:
        from . import ast_lint, hlo_checks, jaxpr_checks  # noqa: F401

        _loaded = True
    return CHECKS


def run_checks(
    ctx,
    only: Optional[set] = None,
    skip: Optional[set] = None,
    families: Optional[set] = None,
) -> List[Finding]:
    """Run every selected registered check over ``ctx``; findings are
    ordered (check id, path, line) so reports and baselines are stable."""
    load_all_checks()
    findings: List[Finding] = []
    for spec in CHECKS.values():
        if only is not None and spec.id not in only:
            continue
        if skip is not None and spec.id in skip:
            continue
        if families is not None and spec.family not in families:
            continue
        findings.extend(spec.fn(ctx))
    findings.sort(key=lambda f: (f.check, f.path, f.line))
    return findings
