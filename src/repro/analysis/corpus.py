"""Golden-violation corpus runner.

The corpus (``tests/analysis_corpus/``) holds one *bad* + one *ok* twin
per check — the detection contract every pass is held to: the bad entry
must produce at least one finding of its check, the ok twin none.

Entry naming: ``<check id, dashes as underscores>__bad`` /
``...__ok``, with the extension/shape the check's evaluator expects:

* AST checks + ``jaxpr-donation-reuse`` — a ``.py`` file, linted
  directly (never imported);
* ``ast-kernel-tile-contract`` — a directory containing
  ``kernels/<pkg>/kernel.py`` (+ ``ops.py``), walked like a tree;
* HLO checks — a ``.txt`` HLO fixture, optionally opening with a
  ``// byte_budget: N`` line (consumed by the fusion-budget check);
* ``jaxpr-donation-alias`` / ``jaxpr-host-callback-in-loop`` /
  ``jaxpr-packed-while-carry`` / ``jaxpr-telemetry-carry`` /
  ``jaxpr-shardmap-replication`` — a
  ``.py`` module **imported and executed** (it builds a tiny traced/lowered program): it must expose
  ``build()`` returning ``{"jaxpr": ...}`` or
  ``{"lowered_text": str, "n_donated": int}``;
* ``jaxpr-recompile-lattice`` — a ``.py`` module exposing
  ``signatures(n) -> hashable`` (the compile signature for input size
  ``n``) and ``bound(n_max) -> int``; the runner counts distinct
  signatures over ``1..n_max`` against the bound.
* ``jaxpr-restore-replica`` — a ``.py`` module whose ``build()``
  returns ``{"pre_signatures": [...], "post_signatures": [...]}`` (the
  compile signatures a replica observed before the crash and after its
  restore); the runner flags any post-restore signature absent from
  the pre-crash set.
"""

from __future__ import annotations

import importlib.util
import re
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from . import ast_lint
from .registry import CHECKS, Finding, load_all_checks

__all__ = ["CorpusResult", "discover", "run_corpus"]

_BUDGET_RE = re.compile(r"^//\s*byte_budget:\s*(\d+)")


class CorpusResult:
    def __init__(self):
        self.passed: List[str] = []
        self.failed: List[Tuple[str, str]] = []  # (entry, why)

    @property
    def ok(self) -> bool:
        return not self.failed

    def record(self, entry: str, why: Optional[str]) -> None:
        if why is None:
            self.passed.append(entry)
        else:
            self.failed.append((entry, why))


def discover(corpus_dir: Path) -> List[Tuple[str, bool, Path]]:
    """(check_id, is_bad, path) per entry, sorted for stable output."""
    out = []
    for p in sorted(Path(corpus_dir).iterdir()):
        stem = p.stem if p.is_file() else p.name
        if "__" not in stem:
            continue
        check_us, _, kind = stem.rpartition("__")
        if kind not in ("bad", "ok"):
            continue
        check_id = check_us.replace("_", "-")
        if check_id in CHECKS:
            out.append((check_id, kind == "bad", p))
    return out


def _load_module(path: Path):
    spec = importlib.util.spec_from_file_location(
        f"analysis_corpus_{path.stem}", path
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _eval_ast_file(check_id: str, path: Path) -> List[Finding]:
    per_file = {
        "ast-traced-branch": ast_lint.check_file_traced_branch,
        "ast-wallclock-sync": ast_lint.check_file_wallclock_sync,
        "ast-raw-pallas-call": ast_lint.check_file_raw_pallas_call,
    }[check_id]
    tree, lines = ast_lint.parse_file(path)
    if tree is None:
        return [
            Finding(check_id, str(path), 0, "corpus entry failed to parse")
        ]
    return ast_lint.filter_inline_suppressed(
        per_file(path, tree, str(path)), lines
    )


def _eval_entry(check_id: str, path: Path) -> List[Finding]:
    from . import hlo_checks, jaxpr_checks

    label = f"<corpus:{path.name}>"
    if check_id.startswith("hlo-"):
        text = path.read_text()
        m = _BUDGET_RE.match(text.splitlines()[0]) if text else None
        budget = int(m.group(1)) if m else None
        return check_hlo_filtered(text, label, budget, check_id)
    if check_id in (
        "ast-traced-branch", "ast-wallclock-sync", "ast-raw-pallas-call",
    ):
        return _eval_ast_file(check_id, path)
    if check_id == "ast-kernel-tile-contract":
        return ast_lint.check_tree_kernel_tile_contract([path], path)
    if check_id == "jaxpr-donation-reuse":
        tree, lines = ast_lint.parse_file(path)
        if tree is None:
            return [Finding(check_id, str(path), 0, "corpus entry failed to parse")]
        return ast_lint.filter_inline_suppressed(
            jaxpr_checks.check_file_donation_reuse(path, tree, str(path)), lines
        )
    if check_id == "jaxpr-restore-replica":
        mod = _load_module(path)
        built = mod.build()
        return jaxpr_checks.check_restore_signatures(
            built["pre_signatures"], built["post_signatures"], label
        )
    if check_id == "jaxpr-recompile-lattice":
        mod = _load_module(path)
        n_max = getattr(mod, "N_MAX", 4096)
        sigs = {mod.signatures(n) for n in range(1, n_max + 1)}
        if len(sigs) > mod.bound(n_max):
            return [
                Finding(
                    check_id, label, 0,
                    f"{len(sigs)} distinct compile signatures over "
                    f"n in [1, {n_max}] (bound: {mod.bound(n_max)})",
                )
            ]
        return []
    # executed jaxpr entries
    mod = _load_module(path)
    built = mod.build()
    if "lowered_text" in built:
        return jaxpr_checks.check_donation_text(
            built["lowered_text"], built["n_donated"], label
        )
    jaxpr = built["jaxpr"]
    if check_id == "jaxpr-host-callback-in-loop":
        return jaxpr_checks.check_jaxpr_callbacks(jaxpr, label)
    if check_id == "jaxpr-packed-while-carry":
        return jaxpr_checks.check_jaxpr_packed_while_carry(jaxpr, label)
    if check_id == "jaxpr-telemetry-carry":
        return jaxpr_checks.check_jaxpr_telemetry_carry(jaxpr, label)
    if check_id == "jaxpr-shardmap-replication":
        return jaxpr_checks.check_jaxpr_shardmaps(jaxpr, label)
    raise ValueError(f"no corpus evaluator for {check_id!r}")


def check_hlo_filtered(text, label, budget, check_id) -> List[Finding]:
    from .hlo_checks import check_hlo_text

    return [
        f
        for f in check_hlo_text(text, label, byte_budget=budget)
        if f.check == check_id
    ]


def run_corpus(corpus_dir: Path) -> CorpusResult:
    """Run every entry; a bad entry must yield >=1 finding of its own
    check, an ok twin exactly 0.  Every registered check must have at
    least one bad entry (the corpus is the detection proof)."""
    load_all_checks()
    result = CorpusResult()
    entries = discover(Path(corpus_dir))
    covered = set()
    for check_id, is_bad, path in entries:
        name = path.name
        try:
            findings = [f for f in _eval_entry(check_id, path) if f.check == check_id]
        except Exception as exc:  # an evaluator crash is a corpus failure
            result.record(name, f"evaluator raised {type(exc).__name__}: {exc}")
            continue
        if is_bad:
            covered.add(check_id)
            result.record(
                name,
                None if findings else "bad entry produced no finding",
            )
        else:
            result.record(
                name,
                None
                if not findings
                else "ok twin produced finding(s): "
                + "; ".join(f.message[:80] for f in findings[:3]),
            )
    missing = sorted(set(CHECKS) - covered)
    if missing:
        result.record(
            "<coverage>",
            f"checks with no bad corpus entry: {', '.join(missing)}",
        )
    return result
