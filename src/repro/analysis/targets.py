"""The standard launch surface the jaxpr/HLO passes run over.

A :class:`Target` is one real entry point traced/lowered at a pinned
"standard config" — small enough to compile in about a second on the
forced-host mesh, shaped to exercise the structure the checks care
about (donation, a ≥2-iteration chunk loop so XLA emits a real
``while``, the sharded plane's psum/gather, the cluster lowering's
scan).  The four targets:

* ``sweep_engine_counts`` / ``sweep_engine_bitmap`` — the donated
  one-launch sweep bodies (``repro.index.sweep``) at nq=512, d=64,
  chunk=256 (cpl=2: the fori_loop survives as an HLO while);
* ``sharded_plane`` — the pipelined bitmap sweep plane
  (``repro.distributed.index_plane``) on a ``min(4, n_devices)``-way
  ``("data",)`` mesh, 1024 queries × 8 chunks (scan trip count 7);
* ``laf_cluster`` — ``build_laf_cluster`` at the reduced config with
  ``backend="random_projection"``, ``index_device=True`` (the fused
  tile through the plane — the paper's workload);
* ``one_launch_cluster`` — ``build_one_launch_cluster`` at the same
  reduced config: the device-resident cluster-formation program (tau
  core test + packed label-prop ``while`` rounds + border rule) with
  ``rows`` donated into the counts output — the donation, while-carry,
  and collective checks all have teeth here;
* ``serve_assign`` — the serving verify launch at the smallest
  ``bucket_shape`` bucket (256 candidates, 128-query chunk).

``BYTE_BUDGETS`` pins each target's fusion-boundary traffic
(``analyze_hlo().bytes_accessed``) at ~6x the measured value on the
standard config — a regression gate against fusion-boundary blowups
(an accidental f32 bitmap, a broadcasted (nq, n) intermediate), not a
performance target.

Everything here imports jax, so the CLI/registry layers import this
module lazily (``--list-checks`` stays jax-free).
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

__all__ = ["Target", "Targets", "Context", "BYTE_BUDGETS", "STANDARD_MESH_AXES"]

STANDARD_MESH_AXES = ("data",)

# fusion-boundary bytes_accessed ceilings per target (~6x the value
# measured on the standard config, CPU/forced-host mesh) — see module
# docstring.  Retune by running:
#   python -m repro.analysis --only=hlo-fusion-bytes-budget  (prints on fail)
BYTE_BUDGETS: Dict[str, int] = {
    "sweep_engine_counts": 112_000_000,   # measured 18.6 MB
    "sweep_engine_bitmap": 130_000_000,   # measured 21.6 MB
    "sharded_plane": 75_000_000,          # measured 12.3 MB (4-dev mesh)
    "laf_cluster": 410_000_000,           # measured 68.1 MB (4-dev mesh)
    "one_launch_cluster": 22_000_000,     # measured 3.7 MB (4-dev mesh)
    "serve_assign": 8_500_000,            # measured 1.35 MB
}


@dataclass
class Target:
    """One traced + compiled entry point.

    ``jaxpr`` is the closed jaxpr of the *implementation* (higher-order
    eqns — scan/while/shard_map/pjit — intact for the jaxpr walkers);
    ``lowered_text`` is the pre-optimization StableHLO (donation
    aliasing lives here as ``tf.aliasing_output``); ``hlo`` is the
    optimized HLO the collective/fusion passes parse.
    """

    name: str
    jaxpr: object
    lowered_text: str
    hlo: str
    n_donated: int = 0
    sharded: bool = False
    byte_budget: Optional[int] = None

    @property
    def label(self) -> str:
        return f"<target:{self.name}>"


def _sds(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(shape, dtype)


def _standard_mesh():
    import jax
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    return Mesh(np.asarray(devs[: min(4, len(devs))]), STANDARD_MESH_AXES)


class Targets:
    """Lazy build-once cache of the standard targets."""

    def __init__(self):
        self._cache: Dict[str, Target] = {}

    NAMES = (
        "sweep_engine_counts",
        "sweep_engine_bitmap",
        "sharded_plane",
        "laf_cluster",
        "one_launch_cluster",
        "serve_assign",
    )

    def get(self, name: str) -> Target:
        if name not in self._cache:
            self._cache[name] = getattr(self, f"_build_{name}")()
        return self._cache[name]

    def all(self) -> List[Target]:
        return [self.get(n) for n in self.NAMES]

    # -- sweep engine -------------------------------------------------

    def _sweep_args(self, *, bitmap: bool, nq: int, n_db: int, chunk: int,
                    d: int = 64, sig_words: int = 2):
        import jax.numpy as jnp

        outs = (_sds((nq,), jnp.int32),)
        if bitmap:
            outs += (_sds((nq, n_db // 32), jnp.uint32),)
        # the per-chunk telemetry slab rides as the last donated output
        # slab (one [accept, band, reject] row per chunk)
        outs += (_sds((nq // chunk, 3), jnp.int32),)
        return outs + (
            _sds((), jnp.int32),              # start
            _sds((nq, d), jnp.float32),       # q
            _sds((nq, sig_words), jnp.uint32),
            _sds((n_db, d), jnp.float32),     # db
            _sds((n_db, sig_words), jnp.uint32),
            _sds((1,), jnp.float32),          # eps
            _sds((2,), jnp.int32),            # band
        )

    def _build_sweep(self, *, bitmap: bool, nq: int, n_db: int,
                     chunk: int, name: str) -> Target:
        import jax

        from ..index import sweep as sw

        # telemetry=True pins the *enlarged* carries/donation set — the
        # shape the lint invariants must keep holding when the in-launch
        # counters are on (telemetry=False is a strict subset program)
        static = dict(chunk=chunk, q_tile=128, db_tile=256, interpret=True,
                      telemetry=True)
        impl = sw._bitmap_launch_impl if bitmap else sw._counts_launch_impl
        jitted = sw._bitmap_launch_donated if bitmap else sw._counts_launch_donated
        args = self._sweep_args(bitmap=bitmap, nq=nq, n_db=n_db, chunk=chunk)
        jaxpr = jax.make_jaxpr(functools.partial(impl, **static))(*args)
        lowered = jitted.lower(*args, **static)
        return Target(
            name, jaxpr, lowered.as_text(), lowered.compile().as_text(),
            n_donated=3 if bitmap else 2, byte_budget=BYTE_BUDGETS.get(name),
        )

    def _build_sweep_engine_counts(self) -> Target:
        # chunk=256 over 512 rows: cpl=2, so the chunk fori_loop lowers
        # to a real HLO while (length-1 loops unroll away)
        return self._build_sweep(
            bitmap=False, nq=512, n_db=512, chunk=256,
            name="sweep_engine_counts",
        )

    def _build_sweep_engine_bitmap(self) -> Target:
        return self._build_sweep(
            bitmap=True, nq=512, n_db=512, chunk=256,
            name="sweep_engine_bitmap",
        )

    # -- sharded plane ------------------------------------------------

    def _build_sharded_plane(self) -> Target:
        import jax
        import jax.numpy as jnp

        from ..distributed.index_plane import _build_sweep_plane_fn

        mesh = _standard_mesh()
        fn = _build_sweep_plane_fn(
            mesh, STANDARD_MESH_AXES, "bitmap",
            128, 128, 256, True, 2,   # chunk, q_tile, db_tile, interpret, depth
        )
        nq, d, w, n_db = 1024, 64, 2, 1024  # 8 chunks -> scan trip count 7
        args = (
            _sds((nq, d), jnp.float32),
            _sds((nq, w), jnp.uint32),
            _sds((n_db, d), jnp.float32),
            _sds((n_db, w), jnp.uint32),
            _sds((1,), jnp.float32),
            _sds((2,), jnp.int32),
        )
        jaxpr = jax.make_jaxpr(fn)(*args)
        lowered = fn.lower(*args)
        return Target(
            "sharded_plane", jaxpr, lowered.as_text(),
            lowered.compile().as_text(),
            sharded=len(mesh.devices.ravel()) > 1,
            byte_budget=BYTE_BUDGETS.get("sharded_plane"),
        )

    # -- laf_cluster lowering -----------------------------------------

    def _build_laf_cluster(self) -> Target:
        import jax

        from ..configs.laf_dbscan import make_reduced_config
        from ..configs.registry import ShapeSpec, get_arch
        from ..launch.laf_cluster import build_laf_cluster

        mesh = _standard_mesh()
        base = dataclasses.replace(
            make_reduced_config(), backend="random_projection",
            index_device=True,
        )
        arch = dataclasses.replace(get_arch("laf_dbscan"), make_config=lambda: base)
        shape = ShapeSpec(
            "analysis_reduced", "cluster", {"n_points": 2048, "dim": 64}
        )
        cell = build_laf_cluster(arch, shape, mesh)
        jaxpr = jax.make_jaxpr(cell.step_fn)(*cell.args)
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        )
        lowered = jitted.lower(*cell.args)
        return Target(
            "laf_cluster", jaxpr, lowered.as_text(),
            lowered.compile().as_text(),
            sharded=len(mesh.devices.ravel()) > 1,
            byte_budget=BYTE_BUDGETS.get("laf_cluster"),
        )

    def _build_one_launch_cluster(self) -> Target:
        import jax

        from ..configs.laf_dbscan import make_reduced_config
        from ..configs.registry import ShapeSpec, get_arch
        from ..launch.laf_cluster import build_one_launch_cluster

        mesh = _standard_mesh()
        # telemetry=True pins the enlarged while carry (the four (64,)
        # s32 per-round vectors) — LAF106/LAF107 and the donation check
        # must hold on the telemetry-on program, not just the subset
        base = dataclasses.replace(
            make_reduced_config(), backend="random_projection",
            index_device=True, telemetry=True,
        )
        arch = dataclasses.replace(get_arch("laf_dbscan"), make_config=lambda: base)
        shape = ShapeSpec(
            "analysis_reduced", "cluster", {"n_points": 2048, "dim": 64}
        )
        cell = build_one_launch_cluster(arch, shape, mesh)
        jaxpr = jax.make_jaxpr(cell.step_fn)(*cell.args)
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.meta["donate_argnums"],
        )
        lowered = jitted.lower(*cell.args)
        return Target(
            "one_launch_cluster", jaxpr, lowered.as_text(),
            lowered.compile().as_text(),
            n_donated=len(cell.meta["donate_argnums"]),
            sharded=len(mesh.devices.ravel()) > 1,
            byte_budget=BYTE_BUDGETS.get("one_launch_cluster"),
        )

    # -- serving verify launch ----------------------------------------

    def _build_serve_assign(self) -> Target:
        import jax

        from ..index import sweep as sw
        from ..stream.serve import bucket_shape

        # the smallest serving bucket: 200 candidates, 100-query block
        bucket, chunk = bucket_shape(200, 100, db_tile=256, chunk=256, q_tile=128)
        static = dict(chunk=chunk, q_tile=128, db_tile=256, interpret=True,
                      telemetry=True)
        args = self._sweep_args(bitmap=True, nq=chunk, n_db=bucket, chunk=chunk)
        jaxpr = jax.make_jaxpr(functools.partial(sw._bitmap_launch_impl, **static))(
            *args
        )
        lowered = sw._bitmap_launch_donated.lower(*args, **static)
        return Target(
            "serve_assign", jaxpr, lowered.as_text(),
            lowered.compile().as_text(),
            n_donated=3, byte_budget=BYTE_BUDGETS.get("serve_assign"),
        )


@dataclass
class Context:
    """What a check sees: the repo layout for the AST passes plus the
    lazily-built standard targets for the jaxpr/HLO passes."""

    repo_root: Path
    src_root: Path
    ast_roots: Tuple[Path, ...] = ()
    targets: Targets = field(default_factory=Targets)
    # checks with a dynamic component (the paired-counter probe) honor
    # this switch so pure-static runs stay cheap/deterministic
    dynamic: bool = True

    @classmethod
    def for_repo(cls, repo_root=None, *, dynamic: bool = True) -> "Context":
        root = Path(repo_root) if repo_root else Path(__file__).resolve().parents[3]
        src = root / "src"
        return cls(
            repo_root=root,
            src_root=src,
            ast_roots=(src / "repro",),
            dynamic=dynamic,
        )
