"""Finding rendering + the checked-in suppression baseline.

The baseline (``analysis/baseline.toml``) is a list of ``[[suppress]]``
tables; a finding is suppressed when a rule's ``check`` matches exactly
and its optional ``path`` / ``contains`` substrings match the finding's
path / message.  The file is read with a minimal TOML-subset parser
(``[[suppress]]`` + ``key = "string" | int`` + ``#`` comments) because
the floor Python here is 3.10 (no stdlib ``tomllib``); the writer emits
the same subset, so ``--write-baseline`` round-trips.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Tuple

from .registry import CHECKS, Finding

__all__ = [
    "load_baseline",
    "save_baseline",
    "split_suppressed",
    "render_console",
    "to_json",
]

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.toml"


def _parse_value(raw: str):
    raw = raw.strip()
    if raw.startswith('"') and raw.endswith('"') and len(raw) >= 2:
        return raw[1:-1]
    try:
        return int(raw)
    except ValueError:
        return raw


def load_baseline(path=DEFAULT_BASELINE) -> List[Dict]:
    """Parse ``[[suppress]]`` rules; a missing file is an empty baseline."""
    path = Path(path)
    if not path.exists():
        return []
    rules: List[Dict] = []
    current = None
    for ln, raw in enumerate(path.read_text().splitlines(), 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        if line == "[[suppress]]":
            current = {}
            rules.append(current)
            continue
        if line.startswith("["):
            current = None  # unknown table: ignore its keys
            continue
        if "=" in line and current is not None:
            key, val = line.split("=", 1)
            current[key.strip()] = _parse_value(val)
        elif "=" in line:
            continue
        else:
            raise ValueError(f"{path}:{ln}: unparseable baseline line {raw!r}")
    bad = [r for r in rules if "check" not in r]
    if bad:
        raise ValueError(f"{path}: every [[suppress]] rule needs a check = \"...\"")
    return rules


def save_baseline(findings: List[Finding], path=DEFAULT_BASELINE) -> None:
    """Write one ``[[suppress]]`` rule per (check, path) pair — coarse on
    purpose so rules survive line drift."""
    seen = set()
    lines = [
        "# repro.analysis suppression baseline — each [[suppress]] rule",
        "# hides findings whose check matches exactly and whose path/",
        "# message contain the optional path=/contains= substrings.",
        "# Regenerate with: python -m repro.analysis --write-baseline",
        "",
    ]
    for f in sorted(findings, key=lambda f: (f.check, f.path)):
        key = (f.check, f.path)
        if key in seen:
            continue
        seen.add(key)
        lines += [
            "[[suppress]]",
            f'check = "{f.check}"',
            f'path = "{f.path}"',
            f'reason = "baselined {f.message[:60]}"',
            "",
        ]
    Path(path).write_text("\n".join(lines))


def split_suppressed(
    findings: List[Finding], rules: List[Dict]
) -> Tuple[List[Finding], List[Finding]]:
    """(open, suppressed) partition of ``findings`` under the baseline."""
    open_, suppressed = [], []
    for f in findings:
        hit = any(
            r.get("check") == f.check
            and str(r.get("path", "")) in f.path
            and str(r.get("contains", "")) in f.message
            for r in rules
        )
        (suppressed if hit else open_).append(f)
    return open_, suppressed


def render_console(
    open_findings: List[Finding],
    suppressed: List[Finding],
    checks_run: List[str],
) -> str:
    out = []
    for f in open_findings:
        spec = CHECKS.get(f.check)
        code = f" [{spec.code}]" if spec else ""
        out.append(f"{f.location()}: {f.check}{code}: {f.message}")
        if f.hint:
            out.append(f"    hint: {f.hint}")
    out.append(
        f"laf-lint: {len(checks_run)} checks, "
        f"{len(open_findings)} finding(s), {len(suppressed)} suppressed"
    )
    return "\n".join(out)


def to_json(
    open_findings: List[Finding],
    suppressed: List[Finding],
    checks_run: List[str],
) -> str:
    return json.dumps(
        {
            "version": 1,
            "ok": not open_findings,
            "checks": checks_run,
            "findings": [f.to_dict() for f in open_findings],
            "suppressed": [f.to_dict() for f in suppressed],
        },
        indent=2,
    )
