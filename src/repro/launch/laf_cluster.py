"""LAF clustering lowering (the paper's workload), built on the sharded
index plane.

One frontier round = batched RMI cardinality prediction for the
frontier + range counting of the whole frontier against the
device-sharded database.  With ``backend="random_projection"`` the
round carries the ANN index: packed sign signatures ride along
row-sharded *with* the database (``repro.distributed.index_plane``'s
co-sharding contract), frontier signatures are projected in-step, and
hits follow the backend's dual-threshold band contract (sure-accept
below ``t_lo``, exact-verify only the band).

``index_device`` picks the evaluator for that predicate:

* ``True``  — the fused ``hamming_filter`` Pallas tile on every mesh
  size: single-device meshes call the wrapper directly and multi-device
  meshes run it shard-locally through
  :func:`repro.distributed.index_plane.sharded_band_marginals`
  (the same tile per shard, one psum of per-query counts, partial
  per-row counts left sharded in place).
* ``False`` — the shardable jnp dataflow of the identical
  :func:`repro.index.signatures.band_hits` predicate (XLA partitions
  the matmul + popcount).
* ``"auto"`` (default) — the fused tile whenever it earns its keep: on
  any multi-device mesh (the sharded plane is the only evaluator that
  keeps range queries local to the data shard) and on single-device
  meshes backed by a real accelerator; a single CPU device keeps the
  BLAS dataflow.  There is no single-device special case left in the
  routing — the plane degenerates to the plain wrapper on one device.

``index_axes`` ("auto" = every mesh axis, matching the database's
row sharding) names the mesh axes the database and signature table are
co-sharded over.

:func:`build_one_launch_cluster` is the second lowering: cluster
*formation*.  Where ``build_laf_cluster`` lowers one frontier round
(predict + sweep), the one-launch cell consumes the sweep's packed
bitmap slab and runs the entire clustering — exact counts (popcount),
the tau core test, min-label propagation over the core-core graph to
fixpoint under ``lax.while_loop`` (with pointer jumping), and the
min-core-neighbor border rule — as a single jitted ``shard_map``
program.  The slab stays column-sharded over ``index_axes`` and the
packed words never enter a collective: per round only the (R,) s32 row
minima cross the network (``lax.pmin``), plus one counts psum up front
(the LAF202 invariant).  ``rows`` is donated and aliases the exact
counts output, so the launch adds no slab-sized live buffer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..configs.registry import ArchSpec, ShapeSpec
from ..distributed.sharding import axis_size, named, replicated, tree_replicated
from .cell import LoweredCell

F32 = jnp.float32
I32 = jnp.int32

__all__ = ["build_laf_cluster", "build_one_launch_cluster"]


def build_laf_cluster(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> LoweredCell:
    from ..configs.laf_dbscan import LAFClusterConfig
    from ..core.cardinality.rmi import RMIConfig, init_rmi, rmi_predict_counts

    base: LAFClusterConfig = arch.make_config()
    n, d = shape.meta["n_points"], shape.meta["dim"]
    # pad the database to a device multiple (zero rows never pass the
    # eps threshold for eps < 1, and counts subtract exactly otherwise;
    # the fused sharded path masks zero rows inside each shard)
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    n = -(-n // n_dev) * n_dev
    dtype = jnp.bfloat16 if n > 10_000_000 else F32
    frontier = base.frontier
    rmi_cfg = RMIConfig(input_dim=d + 1)
    abstract_rmi = jax.eval_shape(lambda: init_rmi(jax.random.PRNGKey(0), rmi_cfg))
    all_axes = tuple(mesh.axis_names)
    thresh = 1.0 - base.eps

    use_rp = base.backend == "random_projection"
    use_kernel = False
    if use_rp:
        from ..index.signatures import hamming_band, make_projection
        from ..kernels.hamming_filter.ops import default_interpret

        n_bits = base.index_bits
        sig_words = n_bits // 32
        # the projection is part of the cell contract: db_sig passed in
        # must be packed with this (index_seed, index_bits) projection —
        # both are recorded in the cell meta below
        proj = jnp.asarray(make_projection(d, n_bits, seed=base.index_seed))
        t_lo, t_hi = hamming_band(base.eps, n_bits, margin=base.index_margin)
        if base.index_verify == "full":
            t_lo = -1
        # which mesh axes co-shard the db rows + signature table
        # ("auto" = all of them, i.e. exactly the db's row sharding)
        axes = all_axes if base.index_axes == "auto" else tuple(base.index_axes)
        n_shards = axis_size(mesh, axes)
        if base.index_device == "auto":
            use_kernel = n_dev > 1 or not default_interpret()
        else:
            use_kernel = bool(base.index_device)
    else:
        axes = all_axes
        n_shards = n_dev

    def cluster_step(rmi_params, db, queries, db_sig=None):
        """One frontier round: RMI predicts frontier cardinalities; the
        whole frontier's range counts + partial-neighbor increments are
        computed against the device-sharded database, as one
        device-resident sweep (frontier signatures packed once, chunks
        software-pipelined through the plane)."""
        # named scopes (not host spans — this whole function is traced
        # once and replayed) label the phases inside XLA profiler
        # captures, mirroring the host-side laf.* span names
        with jax.named_scope("laf.predict"):
            feats = jnp.concatenate(
                [queries, jnp.full((queries.shape[0], 1), base.eps, queries.dtype)],
                axis=1,
            )
            pred = rmi_predict_counts(rmi_params, feats.astype(F32), rmi_cfg)
            gate = (pred >= base.alpha * base.tau).astype(F32)  # skip decisions

        if use_rp and not use_kernel:
            # caller-level padding (n rounded to a device multiple) adds
            # zero db rows whose *signatures* are not zero (sign(0) >= 0
            # packs to all-ones); sure-accepts bypass the dot test, so
            # padded columns must be masked out explicitly (the sharded
            # plane applies the same mask shard-locally)
            db_valid = jnp.any(db != 0, axis=1)

        # bound the live (chunk, n_local) fp32 score tile to ~0.5 GiB
        # the rp path adds a (chunk, n_local) int32 ham matrix + uint32
        # XOR temporaries on top of the fp32 score tile: halve the budget
        elems_budget = 0.625e8 if use_rp else 1.25e8
        rows_budget = max(32, int(elems_budget / max(n // n_dev, 1)))
        n_chunks = 1
        while frontier // n_chunks > rows_budget and n_chunks < frontier:
            n_chunks *= 2
        qs = queries.reshape(n_chunks, frontier // n_chunks, d)

        if use_rp:
            from ..index.signatures import band_hits, hamming_words, pack_bits

            # signatures for the *whole frontier* packed once per sweep
            # (one matmul + one pack), not once per chunk
            with jax.named_scope("laf.pack_sigs"):
                q_sig_all = pack_bits((queries.astype(F32) @ proj) >= 0.0)
            q_sigs = q_sig_all.reshape(n_chunks, frontier // n_chunks, sig_words)

        if use_kernel:
            from ..distributed.index_plane import sharded_sweep_marginals

            # the fused tile, shard-local on every mesh size, all
            # chunks in one launch: popcount band split + MXU verify of
            # band tiles only (band-free tiles skip their matmul); only
            # per-query count psums cross the network — double-buffered
            # against the next chunk's popcount+verify at
            # index_pipeline >= 2 — and per-row partials stay sharded
            # where the database lives
            with jax.named_scope("laf.sweep"):
                counts, partial_counts = sharded_sweep_marginals(
                    qs.astype(F32), db, q_sigs, db_sig, base.eps, t_hi,
                    t_lo=t_lo, mesh=mesh, axes=axes, depth=base.index_pipeline,
                )
            counts = counts.reshape(frontier)
            counts = (counts.astype(F32) * gate).astype(I32)
            return counts, partial_counts, pred

        def chunk_counts(xs):
            qc = xs[0] if use_rp else xs
            # native-dtype MXU dot with fp32 accumulation: upcasting the
            # database to f32 first doubles HBM traffic and halves the
            # bf16 MXU rate (§Perf iteration on web_1b)
            dots = jax.lax.dot_general(
                qc, db, (((1,), (1,)), ((), ())),
                preferred_element_type=F32,
            )                                                  # (C, n)
            if use_rp:
                ham = hamming_words(xs[1], db_sig)
                hit = band_hits(dots, ham, base.eps, t_lo, t_hi) & db_valid[None, :]
            else:
                hit = dots > thresh
            return hit.sum(axis=1, dtype=I32), hit.sum(axis=0, dtype=I32)

        with jax.named_scope("laf.sweep"):
            counts, partials = jax.lax.map(
                chunk_counts, (qs, q_sigs) if use_rp else qs
            )
        counts = counts.reshape(frontier)
        partial_counts = partials.sum(axis=0)
        # masked by skip decisions (skipped queries contribute nothing)
        counts = (counts.astype(F32) * gate).astype(I32)
        return counts, partial_counts, pred

    args = (
        abstract_rmi,
        jax.ShapeDtypeStruct((n, d), dtype),
        jax.ShapeDtypeStruct((frontier, d), dtype),
    )
    in_sh = (
        tree_replicated(mesh, abstract_rmi),
        named(mesh, axes, None),       # db row-sharded over the index axes
        replicated(mesh),
    )
    if use_rp:
        # packed signatures row-sharded exactly like the database
        args = args + (jax.ShapeDtypeStruct((n, sig_words), jnp.uint32),)
        in_sh = in_sh + (named(mesh, axes, None),)
    out_sh = (replicated(mesh), named(mesh, axes), replicated(mesh))
    meta = {
        "kind": "cluster", "n_points": n, "dim": d, "frontier": frontier,
        # the XLA-profiler scope names cluster_step's phases carry (the
        # host-side span names in core.pipeline/core.laf_dbscan mirror
        # these, so traces from either layer line up)
        "obs_scopes": ("laf.predict", "laf.pack_sigs", "laf.sweep"),
    }
    if use_rp:
        # the db_sig contract: signatures must be packed with this exact
        # projection (repro.index.make_projection(dim, bits, seed))
        meta.update(
            index_bits=base.index_bits,
            index_seed=base.index_seed,
            index_margin=base.index_margin,
            index_verify=base.index_verify,
            index_band=(t_lo, t_hi),
            index_axes=axes,
            n_shards=n_shards,
            fused_kernel=use_kernel,
            sharded=use_kernel and n_shards > 1,
            index_pipeline=base.index_pipeline,
        )
    return LoweredCell(
        f"{arch.name}:{shape.name}", cluster_step, args, in_sh, out_sh, meta,
    )


def build_one_launch_cluster(
    arch: ArchSpec, shape: ShapeSpec, mesh: Mesh
) -> LoweredCell:
    """Lower the one-launch device-resident cluster pass (see module
    docstring).  Inputs: the packed slab (R, cap/32) uint32 from the
    bitmap sweep (column-words sharded over ``index_axes``, tail bits
    past n cleared), the (R,) int32 row->database-index map (sentinel
    >= n on padding rows), and tau as a (1,) int32 operand.  Outputs:
    ``(labels, owner, col_sum, counts, rounds)`` exactly as
    :func:`repro.kernels.label_prop.packed_cluster_fixpoint` documents,
    with owner/col_sum left column-sharded where the slab lives.
    """
    import math

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from ..configs.laf_dbscan import LAFClusterConfig
    from ..kernels.hamming_filter.ops import default_interpret
    from ..kernels.label_prop import packed_cluster_fixpoint

    base: LAFClusterConfig = arch.make_config()
    n = shape.meta["n_points"]
    frontier = base.frontier
    all_axes = tuple(mesh.axis_names)
    axes = all_axes if base.index_axes == "auto" else tuple(base.index_axes)
    n_shards = axis_size(mesh, axes)
    # the column capacity rounds n up so every shard holds whole words
    cap = -(-n // (32 * n_shards)) * (32 * n_shards)
    w = cap // 32
    # tiles must divide the shard-local slab exactly (local padding
    # would shift every later shard's global column indices)
    row_tile = math.gcd(frontier, 256)
    word_tile = math.gcd(w // n_shards, 64)
    interpret = default_interpret()
    if base.telemetry == "auto":
        from ..obs import device_enabled

        tele_on = device_enabled()
    else:
        tele_on = bool(base.telemetry)

    def cluster_one_launch(bitmap, rows, tau):
        cap_loc = bitmap.shape[1] * 32
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return packed_cluster_fixpoint(
            bitmap, rows, tau[0], idx * cap_loc,
            n=n, cap=cap, row_tile=row_tile, word_tile=word_tile,
            interpret=interpret, axes=axes, telemetry=tele_on,
        )

    out_specs = (P(None), P(axes), P(axes), P(None), P(None))
    if tele_on:
        # per-round telemetry vectors: psum'd in-loop, replicated out
        out_specs = out_specs + ((P(None),) * 4,)
    step = shard_map(
        cluster_one_launch,
        mesh=mesh,
        in_specs=(P(None, axes), P(None), P(None)),
        out_specs=out_specs,
        check_rep=False,
    )
    args = (
        jax.ShapeDtypeStruct((frontier, w), jnp.uint32),
        jax.ShapeDtypeStruct((frontier,), I32),
        jax.ShapeDtypeStruct((1,), I32),
    )
    in_sh = (named(mesh, None, axes), replicated(mesh), replicated(mesh))
    out_sh = (
        replicated(mesh),      # labels (cap,) — the while-loop carry
        named(mesh, axes),     # owner, column-sharded with the slab
        named(mesh, axes),     # col_sum, likewise
        replicated(mesh),      # counts (R,) — aliases the donated rows
        replicated(mesh),      # rounds
    )
    if tele_on:
        out_sh = out_sh + (tuple(replicated(mesh) for _ in range(4)),)
    meta = {
        "kind": "one_launch_cluster", "n_points": n, "cap": cap,
        "frontier": frontier, "index_axes": axes, "n_shards": n_shards,
        "row_tile": row_tile, "word_tile": word_tile, "telemetry": tele_on,
        # rows (R,) i32 -> counts (R,) i32: same shape/dtype/sharding
        "donate_argnums": (1,),
    }
    return LoweredCell(
        f"{arch.name}:{shape.name}:one_launch", step, args, in_sh, out_sh, meta,
    )
