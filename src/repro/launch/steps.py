"""Step builders: for every (arch × shape) cell, produce

    (step_fn, abstract_args, in_shardings, out_shardings)

ready for ``jax.jit(step_fn, ...).lower(*abstract_args)``.  Abstract
params come from ``jax.eval_shape`` over the pure init functions — a
236B model never materializes.  Train steps are REAL steps: loss, grads,
AdamW update with fp32 m/v (so memory_analysis covers optimizer state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.registry import ArchSpec, ShapeSpec, get_arch
from ..distributed.sharding import (
    axis_size,
    data_axes,
    named,
    param_sharding_rule,
    replicated,
    tree_param_shardings,
    tree_replicated,
)
from .cell import LoweredCell  # noqa: F401  (re-export: the cell contract)
from .laf_cluster import build_laf_cluster  # noqa: F401  (re-export)
from ..models import gnn as gnn_mod
from ..models import recsys as rec_mod
from ..models.layers import cross_entropy_loss
from ..models.transformer import (
    TransformerConfig,
    make_cache,
    make_cache_windowed,
    transformer_decode_step_windowed,
    transformer_decode_step,
    transformer_forward,
    transformer_init,
    transformer_loss,
    transformer_prefill,
)
from ..train.optimizer import adamw, adamw_update_params, apply_updates, clip_by_global_norm

F32 = jnp.float32
I32 = jnp.int32


def _dp(mesh: Mesh):
    # the shared data_axes definition, collapsed to a bare name on
    # single-axis meshes (what the P specs below historically used)
    axes = data_axes(mesh)
    return axes if len(axes) > 1 else axes[0]


def _adamw_abstract_state(abstract_params, dtype=F32):
    return {
        "m": jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, dtype), abstract_params
        ),
        "v": jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, dtype), abstract_params
        ),
        "step": jax.ShapeDtypeStruct((), I32),
    }


def _opt_shardings(mesh, param_shardings):
    return {
        "m": param_shardings,
        "v": param_shardings,
        "step": replicated(mesh),
    }


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def _lm_shard_act(mesh: Mesh):
    dp = _dp(mesh)

    def shard(x):
        if x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, named(mesh, dp, "model", None))
        return x

    return shard


def _lm_leaf_spec(mesh: Mesh, pstr: str, shape) -> NamedSharding:
    """Shared FSDP×TP leaf rule (used for both full stacks and the
    per-layer slices re-pinned inside the scan body — they MUST agree,
    or the scan-interior constraint overrides the EP/TP MoE layout)."""
    model = axis_size(mesh, "model")
    dp = _dp(mesh)
    dp_size = axis_size(mesh, dp)
    ndim = len(shape)
    if "moe" in pstr and ndim >= 3 and "router" not in pstr:
        # stacked expert weights: (L, E, d, f) or sliced (E, d, f)
        e_ax = ndim - 3
        spec = [None] * ndim
        if shape[e_ax] % model == 0:
            spec[e_ax] = "model"                          # expert parallel
            if shape[-2] % dp_size == 0:
                spec[-2] = dp
        else:
            # TP regime (E < model): canonical Megatron pair — wi
            # column-parallel (f over model), wo row-parallel (f over
            # model, partial-sum outputs).  A dp-sharded wo f-dim
            # mismatches the f/model hidden and forces a full all-gather
            # of the (E, C, f) activation (measured 10 GiB on grok).
            if "wo" in pstr:
                if shape[-2] % model == 0:
                    spec[-2] = "model"
                if shape[-1] % dp_size == 0:
                    spec[-1] = dp
            else:
                if shape[-2] % dp_size == 0:
                    spec[-2] = dp
                if shape[-1] % model == 0:
                    spec[-1] = "model"
        return NamedSharding(mesh, P(*spec))
    return param_sharding_rule(mesh, shape)


def _lm_param_shardings(mesh: Mesh, abstract_params):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _lm_leaf_spec(mesh, jax.tree_util.keystr(path), leaf.shape),
        abstract_params,
    )


def _moe_group_config(cfg: TransformerConfig, mesh: Mesh) -> TransformerConfig:
    """Rebuild cfg with data-shard-aligned MoE dispatch groups + hooks."""
    if cfg.moe is None:
        return cfg
    import dataclasses

    model = axis_size(mesh, "model")
    dp = _dp(mesh)
    dp_size = axis_size(mesh, dp)

    # two regimes:
    #  * EP (E % model == 0, deepseek 160/16): experts sharded over the
    #    model axis; dispatch scatters partition on (G, d); the
    #    d-sharded -> E-sharded layout switch is the canonical all-to-all.
    #  * TP (E < model, grok 8 experts): buffers stay G-sharded only;
    #    tensor parallelism lives in the experts' f dim (weights
    #    P(None, dp, model)) — sharding C or d on the buffers just forces
    #    layout thrash (measured 42 GiB/dev + 58 TiB collectives).
    ep = cfg.moe.n_experts % model == 0

    def shard_buf(b):
        g, e, c, d = b.shape
        spec = P(dp, "model", None, None) if ep else P(dp, None, None, None)
        return jax.lax.with_sharding_constraint(b, NamedSharding(mesh, spec))

    def shard_tok(x):  # (G, Tg, d)
        g, tg, d = x.shape
        spec = P(dp, None, "model") if (ep and d % model == 0) else P(dp, None, None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def shard_ent(x):  # (G, T*k, d)
        g, tk, d = x.shape
        spec = P(dp, None, "model") if (ep and d % model == 0) else P(dp, None, None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def shard_disp(b):  # scatter/gather layout: (G, E, C, d)
        g, e, c, d = b.shape
        spec = (
            P(dp, None, None, "model")
            if (ep and d % model == 0)
            else P(dp, None, None, None)
        )
        return jax.lax.with_sharding_constraint(b, NamedSharding(mesh, spec))

    moe = dataclasses.replace(
        cfg.moe, groups=dp_size, shard_buffers=shard_buf, shard_tokens=shard_tok,
        shard_entries=shard_ent, shard_dispatch=shard_disp,
    )
    return dataclasses.replace(cfg, moe=moe)


def _lm_shard_layer_params(mesh: Mesh):
    """Pin per-layer param slices inside the scan body (the stacked
    leading L axis is gone, so the slice takes the 2D FSDP×TP rule).
    Keeps reverse-scan grad accumulators sharded — see
    transformer_forward docstring."""

    def shard(layer_p):
        return jax.tree_util.tree_map_with_path(
            lambda path, l: jax.lax.with_sharding_constraint(
                l, _lm_leaf_spec(mesh, jax.tree_util.keystr(path), l.shape)
            )
            if l.ndim >= 2
            else l,
            layer_p,
        )

    return shard


def _lm_microbatches(cfg: TransformerConfig, batch: int, mesh: Mesh) -> int:
    """Gradient-accumulation factor: 100B+ models on a single 256-chip pod
    cannot hold a full global batch's activations — the production answer
    is microbatching.  Must divide the per-dp-shard batch."""
    n = cfg.param_count()
    dp_size = axis_size(mesh, _dp(mesh))
    per_shard = batch // dp_size
    want = 16 if n > 1e11 else (2 if n > 3e10 else 1)
    while per_shard % want:
        want //= 2
    return max(want, 1)


def build_lm_train(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> LoweredCell:
    cfg: TransformerConfig = _moe_group_config(arch.make_config(), mesh)
    b, s = shape.meta["global_batch"], shape.meta["seq_len"]
    abstract_params = jax.eval_shape(
        lambda: transformer_init(jax.random.PRNGKey(0), cfg)
    )
    p_shard = _lm_param_shardings(mesh, abstract_params)
    huge = cfg.param_count() > 1e11
    opt_state_dtype = jnp.bfloat16 if huge else F32
    shard_act = _lm_shard_act(mesh)
    dp = _dp(mesh)
    n_mb = _lm_microbatches(cfg, b, mesh)

    shard_layer = _lm_shard_layer_params(mesh)

    def shard_logits(x):  # (B, chunk, V): batch over dp, vocab over model
        return jax.lax.with_sharding_constraint(x, named(mesh, dp, None, "model"))

    model_size = axis_size(mesh, "model")

    def shard_qkv(x):  # (B, H, S, D): heads over model (Ulysses layout);
        # GQA kv heads that don't divide the axis stay replicated (one
        # gather per layer instead of one per kv block)
        h_ax = "model" if x.shape[1] % model_size == 0 else None
        return jax.lax.with_sharding_constraint(
            x, named(mesh, dp, h_ax, None, None)
        )

    def shard_grads(grads):
        return jax.tree_util.tree_map(
            lambda g, sh: jax.lax.with_sharding_constraint(g, sh), grads, p_shard
        )

    def loss_fn(p, tokens, labels):
        return transformer_loss(
            p, cfg, tokens, labels, shard_act=shard_act,
            shard_layer_params=shard_layer, ce_chunk=256 if huge else 512,
            shard_logits=shard_logits, shard_qkv=shard_qkv,
        )

    def train_step(params, opt_state, batch):
        if n_mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(
                params, batch["tokens"], batch["labels"]
            )
        else:
            # microbatch split preserves the dp sharding of the batch dim
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(x.shape[0] // n_mb, n_mb, *x.shape[1:])
                .swapaxes(0, 1),
                batch,
            )

            # bf16 accumulation for 100B+ models: the fp32 accumulator
            # alone is 3.7 GiB/device (x2 while double-buffering) on
            # deepseek-236b @ 256 chips; bf16 costs ~3 mantissa bits over
            # 16 microbatches — the standard trade at this scale.
            acc_dtype = jnp.bfloat16 if huge else F32

            def acc_body(carry, mb_i):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(
                    params, mb_i["tokens"], mb_i["labels"]
                )
                grad_acc = shard_grads(
                    jax.tree_util.tree_map(
                        lambda a, g: (a.astype(F32) + g.astype(F32)).astype(acc_dtype),
                        grad_acc, grads,
                    )
                )
                return (loss_acc + loss, grad_acc), None

            zeros = shard_grads(
                jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, acc_dtype), params
                )
            )
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), F32), zeros), mb
            )
            loss = loss / n_mb
            grads = jax.tree_util.tree_map(lambda g: g / n_mb, grads)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        opt = adamw(lr=3e-4, state_dtype=opt_state_dtype)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    batch_spec = {
        "tokens": jax.ShapeDtypeStruct((b, s), I32),
        "labels": jax.ShapeDtypeStruct((b, s), I32),
    }
    abstract_opt = _adamw_abstract_state(abstract_params, opt_state_dtype)
    batch_shard = {
        "tokens": named(mesh, dp, None),
        "labels": named(mesh, dp, None),
    }
    in_sh = (p_shard, _opt_shardings(mesh, p_shard), batch_shard)
    out_sh = (p_shard, _opt_shardings(mesh, p_shard),
              {"loss": replicated(mesh), "grad_norm": replicated(mesh)})
    return LoweredCell(
        f"{arch.name}:{shape.name}", train_step,
        (abstract_params, abstract_opt, batch_spec), in_sh, out_sh,
        {"tokens_per_step": b * s, "param_count": cfg.param_count(),
         "active_param_count": cfg.active_param_count(), "kind": "train",
         "microbatches": n_mb, "opt_state_dtype": "bf16" if huge else "f32",
         "donate": (0, 1)},
    )


def build_lm_prefill(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> LoweredCell:
    cfg: TransformerConfig = _moe_group_config(arch.make_config(), mesh)
    b, s = shape.meta["global_batch"], shape.meta["seq_len"]
    abstract_params = jax.eval_shape(lambda: transformer_init(jax.random.PRNGKey(0), cfg))
    p_shard = _lm_param_shardings(mesh, abstract_params)
    shard_act = _lm_shard_act(mesh)
    dp = _dp(mesh)

    shard_layer = _lm_shard_layer_params(mesh)
    n_chunks = _lm_microbatches(cfg, b, mesh)

    def prefill_step(params, tokens):
        if n_chunks == 1:
            return transformer_prefill(
                params, cfg, tokens, shard_act=shard_act, shard_layer_params=shard_layer
            )
        # 100B+ models: chunk the prefill batch (sequential lax.map) so
        # full-seq activations for only batch/n_chunks rows are live.
        chunks = tokens.reshape(n_chunks, b // n_chunks, s)

        def one(chunk):
            return transformer_prefill(
                params, cfg, chunk, shard_act=shard_act,
                shard_layer_params=shard_layer,
            )

        out = jax.lax.map(one, chunks)
        return out.reshape(b, -1)

    args = (abstract_params, jax.ShapeDtypeStruct((b, s), I32))
    in_sh = (p_shard, named(mesh, dp, None))
    out_sh = named(mesh, dp, "model")
    return LoweredCell(
        f"{arch.name}:{shape.name}", prefill_step, args, in_sh, out_sh,
        {"tokens_per_step": b * s, "param_count": cfg.param_count(),
         "active_param_count": cfg.active_param_count(), "kind": "prefill"},
    )


def _cache_shardings(cfg: TransformerConfig, mesh: Mesh, batch: int):
    """Shard KV cache: batch over dp when divisible; heads over model when
    divisible, else sequence over model."""
    dp = _dp(mesh)
    dp_size = axis_size(mesh, dp)
    b_ax = dp if batch % dp_size == 0 else None
    if cfg.attention == "mla":
        # (L, B, S, r): latent has no head axis -> shard S over model
        spec = P(None, b_ax, "model", None)
        return {
            k: NamedSharding(mesh, spec)
            for k in ("ckv", "krope", "prefix_ckv", "prefix_krope")
        }
    if cfg.kv_heads % axis_size(mesh, "model") == 0:
        spec = P(None, b_ax, "model", None, None)     # heads over model
    else:
        spec = P(None, b_ax, None, "model", None)     # seq over model
    return {k: NamedSharding(mesh, spec) for k in ("k", "v", "prefix_k", "prefix_v")}


def build_lm_decode(
    arch: ArchSpec, shape: ShapeSpec, mesh: Mesh, variant: str = "baseline"
) -> LoweredCell:
    cfg: TransformerConfig = arch.make_config()
    b, s = shape.meta["global_batch"], shape.meta["seq_len"]
    abstract_params = jax.eval_shape(lambda: transformer_init(jax.random.PRNGKey(0), cfg))
    if variant == "windowed":
        # §Perf hillclimb: ring-buffer caches for local layers +
        # TP-resident weights.  FSDP re-gathers the whole parameter set
        # per decoded token (measured 38 GiB collectives/token at B=1);
        # serving wants weights sharded over EVERY mesh axis and kept
        # resident — zero per-step weight traffic.
        assert cfg.window is not None and cfg.global_every > 0
        abstract_cache = jax.eval_shape(lambda: make_cache_windowed(cfg, b, s))
        all_axes = tuple(mesh.axis_names)
        total = axis_size(mesh, all_axes)

        def serve_param_spec(leaf):
            if leaf.ndim < 2:
                return replicated(mesh)
            spec = [None] * leaf.ndim
            if leaf.shape[-1] % total == 0:
                spec[-1] = all_axes
            elif leaf.shape[-2] % total == 0:
                spec[-2] = all_axes
            elif leaf.shape[-1] % axis_size(mesh, "model") == 0:
                spec[-1] = "model"
            return NamedSharding(mesh, P(*spec))

        p_shard = jax.tree_util.tree_map(serve_param_spec, abstract_params)
        dp = _dp(mesh)
        dp_size = axis_size(mesh, dp)
        b_ax = dp if b % dp_size == 0 else None

        def cache_sh_one(leaf):
            # leading block axes, then (B, H, S_or_W, D)
            lead = [None] * (leaf.ndim - 4)
            model_ok = cfg.kv_heads % axis_size(mesh, "model") == 0
            if b_ax is None and model_ok and leaf.shape[-2] % dp_size == 0:
                # B unshardable: heads over model AND seq over dp
                return NamedSharding(mesh, P(*lead, None, "model", dp, None))
            if model_ok:
                return NamedSharding(mesh, P(*lead, b_ax, "model", None, None))
            return NamedSharding(mesh, P(*lead, b_ax, None, "model", None))

        cache_sh = jax.tree_util.tree_map(cache_sh_one, abstract_cache)

        def decode_step(params, token, cache, cur_len):
            # no residual constraint: (B=1, 1, D) activations are
            # unshardable, and the training-oriented seq constraint only
            # forces gathers at serve time
            return transformer_decode_step_windowed(
                params, cfg, token, cache, cur_len
            )

        args = (
            abstract_params,
            jax.ShapeDtypeStruct((b, 1), I32),
            abstract_cache,
            jax.ShapeDtypeStruct((), I32),
        )
        in_sh = (p_shard, named(mesh, b_ax, None), cache_sh, replicated(mesh))
        out_sh = (named(mesh, b_ax, "model"), cache_sh)
        return LoweredCell(
            f"{arch.name}:{shape.name}", decode_step, args, in_sh, out_sh,
            {"tokens_per_step": b, "param_count": cfg.param_count(),
             "active_param_count": cfg.active_param_count(), "kind": "decode",
             "kv_len": s, "donate": (2,), "variant": "windowed"},
        )
    abstract_cache = jax.eval_shape(lambda: make_cache(cfg, b, s))
    p_shard = _lm_param_shardings(mesh, abstract_params)
    cache_sh_all = _cache_shardings(cfg, mesh, b)
    cache_sh = {k: cache_sh_all[k] for k in abstract_cache}
    dp = _dp(mesh)
    dp_size = axis_size(mesh, dp)
    b_ax = dp if b % dp_size == 0 else None

    def decode_step(params, token, cache, cur_len):
        return transformer_decode_step(params, cfg, token, cache, cur_len)

    args = (
        abstract_params,
        jax.ShapeDtypeStruct((b, 1), I32),
        abstract_cache,
        jax.ShapeDtypeStruct((), I32),
    )
    in_sh = (p_shard, named(mesh, b_ax, None), cache_sh, replicated(mesh))
    out_sh = (named(mesh, b_ax, "model"), cache_sh)
    return LoweredCell(
        f"{arch.name}:{shape.name}", decode_step, args, in_sh, out_sh,
        {"tokens_per_step": b, "param_count": cfg.param_count(),
         "active_param_count": cfg.active_param_count(), "kind": "decode",
         "kv_len": s, "donate": (2,)},
    )


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def build_gnn_train(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> LoweredCell:
    from ..configs.gat_cora import config_for_shape

    cfg = config_for_shape(shape.name)
    abstract_params = jax.eval_shape(lambda: gnn_mod.gat_init(jax.random.PRNGKey(0), cfg))
    p_shard = tree_replicated(mesh, abstract_params)  # tiny params: replicate
    opt = adamw(lr=1e-3)
    dp = _dp(mesh)
    edge_axes = ("pod", "data", "model") if "pod" in mesh.axis_names else ("data", "model")

    if shape.name == "molecule":
        b = shape.meta["batch"]
        n, e, d = shape.meta["n_nodes"], shape.meta["n_edges"], shape.meta["d_feat"]

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                logits = gnn_mod.gat_forward_batched(p, cfg, batch["feats"], batch["src"], batch["dst"])
                return jnp.mean(jnp.square(logits.sum(-1) - batch["y"]))

            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, {"loss": loss}

        batch_spec = {
            "feats": jax.ShapeDtypeStruct((b, n, d), F32),
            "src": jax.ShapeDtypeStruct((b, e), I32),
            "dst": jax.ShapeDtypeStruct((b, e), I32),
            "y": jax.ShapeDtypeStruct((b,), F32),
        }
        batch_sh = {
            "feats": named(mesh, dp, None, None),
            "src": named(mesh, dp, None),
            "dst": named(mesh, dp, None),
            "y": named(mesh, dp),
        }
        n_edges_total = b * e
    else:
        if shape.name == "minibatch_lg":
            bn, f1, f2 = shape.meta["batch_nodes"], shape.meta["fanout1"], shape.meta["fanout2"]
            n = bn + bn * f1 + bn * f1 * f2
            e = bn * f1 + bn * f1 * f2
            n_labeled = bn
        else:
            n, e = shape.meta["n_nodes"], shape.meta["n_edges"]
            n_labeled = n
        d = shape.meta["d_feat"]
        # pad the edge axis to a device multiple (edge_mask covers pads)
        n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        e = -(-e // n_dev) * n_dev

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                return gnn_mod.gat_loss(
                    p, cfg, batch["feats"], batch["src"], batch["dst"],
                    batch["labels"], label_mask=batch["label_mask"],
                    edge_mask=batch["edge_mask"],
                )

            loss, grads = jax.value_and_grad(loss_fn)(params)
            updates, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, {"loss": loss}

        batch_spec = {
            "feats": jax.ShapeDtypeStruct((n, d), F32),
            "src": jax.ShapeDtypeStruct((e,), I32),
            "dst": jax.ShapeDtypeStruct((e,), I32),
            "labels": jax.ShapeDtypeStruct((n,), I32),
            "label_mask": jax.ShapeDtypeStruct((n,), F32),
            "edge_mask": jax.ShapeDtypeStruct((e,), jnp.bool_),
        }
        # edges sharded over ALL mesh axes; node arrays replicated
        e_sh = named(mesh, edge_axes)
        batch_sh = {
            "feats": replicated(mesh),
            "src": e_sh,
            "dst": e_sh,
            "labels": replicated(mesh),
            "label_mask": replicated(mesh),
            "edge_mask": e_sh,
        }
        n_edges_total = e

    abstract_opt = _adamw_abstract_state(abstract_params)
    in_sh = (p_shard, _opt_shardings(mesh, p_shard), batch_sh)
    out_sh = (p_shard, _opt_shardings(mesh, p_shard), {"loss": replicated(mesh)})
    return LoweredCell(
        f"{arch.name}:{shape.name}", train_step,
        (abstract_params, abstract_opt, batch_spec), in_sh, out_sh,
        {"kind": "train", "donate": (0, 1), "n_edges": n_edges_total,
         "param_count": sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(abstract_params))},
    )


# ---------------------------------------------------------------------------
# recsys family
# ---------------------------------------------------------------------------


def _recsys_model_fns(arch: ArchSpec):
    name = arch.name
    cfg = arch.make_config()
    if name == "deepfm":
        init = lambda: rec_mod.deepfm_init(jax.random.PRNGKey(0), cfg)
        fwd = lambda p, b: rec_mod.deepfm_forward(p, cfg, b["ids"])
        user = lambda p, b: rec_mod.deepfm_user_embedding(p, cfg, b["ids"])
        emb_dim, inputs = cfg.embed_dim, "fields"
    elif name == "autoint":
        init = lambda: rec_mod.autoint_init(jax.random.PRNGKey(0), cfg)
        fwd = lambda p, b: rec_mod.autoint_forward(p, cfg, b["ids"])
        user = lambda p, b: rec_mod.autoint_user_embedding(p, cfg, b["ids"])
        emb_dim, inputs = cfg.embed_dim, "fields"
    elif name == "dien":
        init = lambda: rec_mod.dien_init(jax.random.PRNGKey(0), cfg)
        fwd = lambda p, b: rec_mod.dien_forward(p, cfg, b["hist"], b["target"])
        user = lambda p, b: rec_mod.dien_user_embedding(p, cfg, b["hist"])
        emb_dim, inputs = cfg.embed_dim, "seq"
    elif name == "bst":
        init = lambda: rec_mod.bst_init(jax.random.PRNGKey(0), cfg)
        fwd = lambda p, b: rec_mod.bst_forward(p, cfg, b["hist"], b["target"])
        user = lambda p, b: rec_mod.bst_user_embedding(p, cfg, b["hist"])
        emb_dim, inputs = cfg.embed_dim, "seq"
    else:
        raise KeyError(name)
    return cfg, init, fwd, user, emb_dim, inputs


def _recsys_batch_spec(arch: ArchSpec, cfg, batch: int, mesh: Mesh, with_label: bool):
    dp = _dp(mesh)
    dp_size = axis_size(mesh, dp)
    b_ax = dp if batch % dp_size == 0 else None
    name = arch.name
    if name in ("deepfm", "autoint"):
        spec = {"ids": jax.ShapeDtypeStruct((batch, cfg.n_fields), I32)}
        sh = {"ids": named(mesh, b_ax, None)}
    else:
        spec = {
            "hist": jax.ShapeDtypeStruct((batch, cfg.seq_len), I32),
            "target": jax.ShapeDtypeStruct((batch,), I32),
        }
        sh = {"hist": named(mesh, b_ax, None), "target": named(mesh, b_ax)}
    if with_label:
        spec["label"] = jax.ShapeDtypeStruct((batch,), F32)
        sh["label"] = named(mesh, b_ax)
    return spec, sh


def _recsys_param_shardings(mesh: Mesh, abstract_params):
    """Embedding tables row-sharded over every mesh axis; towers replicated."""
    all_axes = tuple(mesh.axis_names)

    def rule(leaf):
        if leaf.ndim == 2 and leaf.shape[0] >= 4096:  # big table
            if leaf.shape[0] % axis_size(mesh, all_axes) == 0:
                return NamedSharding(mesh, P(all_axes, None))
        return param_sharding_rule(mesh, leaf.shape) if leaf.ndim >= 2 else replicated(mesh)

    return jax.tree_util.tree_map(rule, abstract_params)


def build_recsys_train(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> LoweredCell:
    cfg, init, fwd, _user, _d, _inp = _recsys_model_fns(arch)
    b = shape.meta["batch"]
    abstract_params = jax.eval_shape(init)
    p_shard = _recsys_param_shardings(mesh, abstract_params)
    opt = adamw(lr=1e-3)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return rec_mod.bce_loss(fwd(p, batch), batch["label"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, {"loss": loss}

    batch_spec, batch_sh = _recsys_batch_spec(arch, cfg, b, mesh, with_label=True)
    abstract_opt = _adamw_abstract_state(abstract_params)
    in_sh = (p_shard, _opt_shardings(mesh, p_shard), batch_sh)
    out_sh = (p_shard, _opt_shardings(mesh, p_shard), {"loss": replicated(mesh)})
    return LoweredCell(
        f"{arch.name}:{shape.name}", train_step,
        (abstract_params, abstract_opt, batch_spec), in_sh, out_sh,
        {"kind": "train", "donate": (0, 1), "batch": b,
         "param_count": sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(abstract_params))},
    )


def build_recsys_forward(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> LoweredCell:
    cfg, init, fwd, _user, _d, _inp = _recsys_model_fns(arch)
    b = shape.meta["batch"]
    abstract_params = jax.eval_shape(init)
    p_shard = _recsys_param_shardings(mesh, abstract_params)

    def serve_step(params, batch):
        return jax.nn.sigmoid(fwd(params, batch))

    batch_spec, batch_sh = _recsys_batch_spec(arch, cfg, b, mesh, with_label=False)
    dp = _dp(mesh)
    b_ax = dp if b % axis_size(mesh, dp) == 0 else None
    return LoweredCell(
        f"{arch.name}:{shape.name}", serve_step,
        (abstract_params, batch_spec), (p_shard, batch_sh), named(mesh, b_ax),
        {"kind": "forward", "batch": b,
         "param_count": sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(abstract_params))},
    )


def build_recsys_retrieval(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> LoweredCell:
    cfg, init, _fwd, user, emb_dim, _inp = _recsys_model_fns(arch)
    b, nc = shape.meta["batch"], shape.meta["n_candidates"]
    abstract_params = jax.eval_shape(init)
    p_shard = _recsys_param_shardings(mesh, abstract_params)

    def retrieval_step(params, batch, candidates):
        q = user(params, batch)                      # (B, emb_dim)
        return rec_mod.retrieval_scores(q, candidates)

    batch_spec, batch_sh = _recsys_batch_spec(arch, cfg, b, mesh, with_label=False)
    cand_spec = jax.ShapeDtypeStruct((nc, emb_dim), F32)
    cand_sh = named(mesh, "model", None)            # candidates row-sharded
    return LoweredCell(
        f"{arch.name}:{shape.name}", retrieval_step,
        (abstract_params, batch_spec, cand_spec),
        (p_shard, batch_sh, cand_sh), named(mesh, None, "model"),
        {"kind": "retrieval", "batch": b, "n_candidates": nc,
         "param_count": sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(abstract_params))},
    )


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


def build_cell(
    arch_name: str, shape_name: str, mesh: Mesh, variant: str = "baseline"
) -> LoweredCell:
    arch = get_arch(arch_name)
    shape = arch.shapes[shape_name]
    if shape_name in arch.skips:
        raise ValueError(f"{arch_name}:{shape_name} is a documented skip: {arch.skips[shape_name]}")
    if arch.family == "lm":
        if shape.kind == "train":
            return build_lm_train(arch, shape, mesh)
        if shape.kind == "prefill":
            return build_lm_prefill(arch, shape, mesh)
        if shape.kind == "decode":
            return build_lm_decode(arch, shape, mesh, variant=variant)
    if arch.family == "gnn":
        return build_gnn_train(arch, shape, mesh)
    if arch.family == "recsys":
        if shape.kind == "train":
            return build_recsys_train(arch, shape, mesh)
        if shape.kind == "forward":
            return build_recsys_forward(arch, shape, mesh)
        if shape.kind == "retrieval":
            return build_recsys_retrieval(arch, shape, mesh)
    if arch.family == "cluster":
        return build_laf_cluster(arch, shape, mesh)
    raise KeyError(f"no builder for {arch.family}/{shape.kind}")
