"""Loop-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE —
a scan-over-layers transformer therefore under-reports FLOPs/bytes by
the layer count, and collective ops inside scan bodies are likewise
under-counted.  This module parses the optimized HLO, builds the
computation call graph, multiplies by ``known_trip_count`` loop
attributes, and produces:

  * flops            — dots counted exactly (2·numel(out)·contract),
                       elementwise ~1/elem, loop-corrected
  * bytes_accessed   — fusion-boundary traffic model (operands+results
                       of top-level ops; fusion internals free),
                       loop-corrected
  * collectives      — per-op-kind {count, bytes} (output-size proxy),
                       loop-corrected; wire bytes scaled by the
                       collective's algorithmic factor

The parser is calibrated against JAX 0.8 CPU-backend SPMD HLO text (the
dry-run artifact of record; see tests/test_hlo_analysis.py).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = [
    "analyze_hlo",
    "HLOAnalysis",
    "collectives_by_computation",
    "ComputationCollectives",
    "CollectiveRecord",
]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f8e4m3fn|f8e5m2|[suf]\d+|c64|c128|token)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
# trip counts appear escaped inside backend_config JSON strings
# (known_trip_count\":{\"n\":\"7\"), unescaped ("known_trip_count":{"n":"7"}),
# and as a plain HLO attribute (known_trip_count={n=7}) depending on the
# XLA version/printer — accept all three
_TRIP_RE = re.compile(
    r'known_trip_count\\?"?\s*[:=]\s*\{\s*\\?"?n\\?"?\s*[:=]\s*\\?"?(\d+)'
)
_CALL_ATTR_RE = re.compile(r"(?:body|calls)=%?([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_REF_RE = re.compile(r"%([\w.\-]+)")

# ops that move no data (layout/meta only); while/conditional carries are
# in-place — their bodies' ops are what count
_FREE_OPS = {
    "get-tuple-element", "tuple", "parameter", "bitcast", "constant",
    "after-all", "opt-barrier", "while", "conditional",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}
# elementwise-ish ops that count ~1 flop per output element
_ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "sign", "remainder", "power",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_TRANSCENDENTAL_OPS = {
    "exponential", "log", "log-plus-one", "expm1", "tanh", "rsqrt", "sqrt",
    "sine", "cosine", "logistic", "erf", "cbrt", "atan2", "exponential-minus-one",
}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _first_shape_numel(type_str: str) -> Tuple[int, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0, []
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    n = 1
    for d in dims:
        n *= d
    return n, dims


@dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class _Computation:
    name: str
    is_entry: bool
    params: Dict[str, str] = field(default_factory=dict)
    ops: List[_Op] = field(default_factory=list)
    symtab: Dict[str, str] = field(default_factory=dict)  # name -> type str


@dataclass
class HLOAnalysis:
    flops: float
    bytes_accessed: float
    transcendentals: float
    collectives: Dict[str, Dict[str, float]]
    n_while_loops: int
    notes: List[str] = field(default_factory=list)

    def to_dict(self):
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "transcendentals": self.transcendentals,
            "collectives": self.collectives,
            "n_while_loops": self.n_while_loops,
            "notes": self.notes,
        }


def _parse_computations(hlo: str) -> Tuple[Dict[str, _Computation], Optional[str]]:
    comps: Dict[str, _Computation] = {}
    entry_name = None
    current: Optional[_Computation] = None
    for raw in hlo.splitlines():
        header = _COMP_HEADER_RE.match(raw)
        if header:
            is_entry, name, params_str, _ret = header.groups()
            current = _Computation(name=name, is_entry=bool(is_entry))
            if is_entry:
                entry_name = name
            # parse params "x.58: f32[], y.58: f32[...]"
            depth = 0
            tok = ""
            parts = []
            for ch in params_str:
                if ch == "," and depth == 0:
                    parts.append(tok)
                    tok = ""
                    continue
                if ch in "[{(":
                    depth += 1
                elif ch in "]})":
                    depth -= 1
                tok += ch
            if tok.strip():
                parts.append(tok)
            for part in parts:
                if ":" in part:
                    pname, ptype = part.split(":", 1)
                    current.params[pname.strip().lstrip("%")] = ptype.strip()
                    current.symtab[pname.strip().lstrip("%")] = ptype.strip()
            comps[name] = current
            continue
        if current is None:
            continue
        if raw.strip() == "}":
            current = None
            continue
        m = _OP_RE.match(raw)
        if not m:
            continue
        name, rtype, opcode = m.groups()
        # operands: inside the first top-level parens after the opcode
        idx = raw.index(opcode + "(") + len(opcode) + 1
        depth = 1
        j = idx
        while j < len(raw) and depth:
            if raw[j] == "(":
                depth += 1
            elif raw[j] == ")":
                depth -= 1
            j += 1
        operand_str = raw[idx : j - 1]
        operands = _OPERAND_REF_RE.findall(operand_str)
        op = _Op(name=name, result_type=rtype, opcode=opcode, line=raw, operands=operands)
        current.ops.append(op)
        current.symtab[name] = rtype
    return comps, entry_name


@dataclass
class CollectiveRecord:
    """One collective op as it appears in a computation body."""

    op: str            # normalized opcode ("-start" stripped)
    name: str          # HLO result name
    result_type: str   # full result type string, e.g. "s32[4,256]"
    element_type: str  # first shape dtype, e.g. "s32", "u32"
    bytes: int         # output-size wire proxy (matches analyze_hlo)
    line: str


@dataclass
class ComputationCollectives:
    """Per-computation collective inventory for contract checks."""

    name: str
    is_entry: bool
    is_loop_body: bool          # reachable from a while body/cond
    trip_count: Optional[int]   # known_trip_count of the owning loop, if any
    collectives: List[CollectiveRecord] = field(default_factory=list)


def collectives_by_computation(hlo: str) -> Dict[str, ComputationCollectives]:
    """Structured per-computation collective table over optimized HLO.

    Marks every computation reachable from a ``while`` body/condition
    (transitively, through fusion/call targets) as a loop body and
    attaches the loop's ``known_trip_count`` when the attribute is
    present.  ``repro.analysis.hlo_checks`` consumes this to enforce
    the plane's dataflow contracts (no packed-word collectives, loop
    bodies restricted to the count-psum allowlist).
    """
    comps, entry = _parse_computations(hlo)
    trip_by_comp: Dict[str, Optional[int]] = {}
    loop_rooted = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode != "while":
                continue
            m = _TRIP_RE.search(op.line)
            trip = int(m.group(1)) if m else None
            for rx in (_CALL_ATTR_RE, _COND_ATTR_RE):
                t = rx.search(op.line)
                if t and t.group(1) in comps:
                    loop_rooted.add(t.group(1))
                    trip_by_comp[t.group(1)] = trip
    # transitive closure: a collective inside a fusion called from a loop
    # body still executes once per trip
    callees: Dict[str, set] = {name: set() for name in comps}
    for name, comp in comps.items():
        for op in comp.ops:
            for rx in (_CALL_ATTR_RE, _COND_ATTR_RE):
                m = rx.search(op.line)
                if m and m.group(1) in comps:
                    callees[name].add(m.group(1))
    in_loop = set(loop_rooted)
    frontier = list(loop_rooted)
    while frontier:
        cur = frontier.pop()
        for nxt in callees.get(cur, ()):
            if nxt not in in_loop:
                in_loop.add(nxt)
                trip_by_comp.setdefault(nxt, trip_by_comp.get(cur))
                frontier.append(nxt)
    out: Dict[str, ComputationCollectives] = {}
    for name, comp in comps.items():
        recs = []
        for op in comp.ops:
            if op.opcode not in _COLLECTIVES:
                continue
            sm = _SHAPE_RE.search(op.result_type)
            recs.append(
                CollectiveRecord(
                    op=op.opcode.replace("-start", ""),
                    name=op.name,
                    result_type=op.result_type,
                    element_type=sm.group(1) if sm else "",
                    bytes=_type_bytes(op.result_type),
                    line=op.line.strip(),
                )
            )
        out[name] = ComputationCollectives(
            name=name,
            is_entry=(name == entry),
            is_loop_body=name in in_loop,
            trip_count=trip_by_comp.get(name),
            collectives=recs,
        )
    return out


def _collective_wire_factor(opcode: str, line: str) -> float:
    """Scale output-size to wire bytes: all-gather output is the gathered
    size (each device receives (g-1)/g of it); all-reduce moves ~2x the
    shard in a ring; use 1.0 as the uniform, comparable proxy."""
    return 1.0


def analyze_hlo(hlo: str) -> HLOAnalysis:
    comps, entry = _parse_computations(hlo)
    notes: List[str] = []
    if entry is None:
        # single-computation module without ENTRY marker
        entry = next(iter(comps)) if comps else None
        if entry is None:
            return HLOAnalysis(0, 0, 0, {}, 0, ["no computations parsed"])

    # execution counts via call-graph walk
    exec_count: Dict[str, float] = {name: 0.0 for name in comps}
    n_while = 0

    def visit(name: str, mult: float):
        nonlocal n_while
        comp = comps.get(name)
        if comp is None:
            return
        exec_count[name] += mult
        for op in comp.ops:
            if op.opcode == "while":
                n_while += 1
                trip_m = _TRIP_RE.search(op.line)
                trip = float(trip_m.group(1)) if trip_m else 1.0
                if not trip_m:
                    notes.append(f"while {op.name}: unknown trip count, counted once")
                body = _CALL_ATTR_RE.search(op.line)
                cond = _COND_ATTR_RE.search(op.line)
                if body:
                    visit(body.group(1), mult * trip)
                if cond:
                    visit(cond.group(1), mult * (trip + 1))
            elif op.opcode in ("fusion", "call", "map", "async-start"):
                callee = _CALL_ATTR_RE.search(op.line)
                if callee:
                    visit(callee.group(1), mult)
            elif op.opcode == "conditional":
                for b in _BRANCHES_RE.findall(op.line):
                    for branch in b.split(","):
                        visit(branch.strip().lstrip("%"), mult)  # upper bound

    visit(entry, 1.0)

    flops = 0.0
    transcendentals = 0.0
    bytes_accessed = 0.0
    collectives: Dict[str, Dict[str, float]] = {}
    # computations reached via fusion vs. control flow: bytes only counted
    # for "executable" comps (entry + while bodies/conds + branches); we
    # approximate by counting bytes in comps whose ops include control or
    # that are reached as while/branch targets.  Simpler robust rule:
    # count bytes at every top-level op of every comp EXCEPT fused
    # computations (reached via `calls=`).
    fused_targets = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode in ("fusion", "map"):
                callee = _CALL_ATTR_RE.search(op.line)
                if callee:
                    fused_targets.add(callee.group(1))
            # reduce/sort/scatter appliers are tiny: treat as fused
            for attr in ("to_apply", "comparator", "scatter"):
                m = re.search(attr + r"=%?([\w.\-]+)", op.line)
                if m:
                    fused_targets.add(m.group(1))

    for name, comp in comps.items():
        mult = exec_count.get(name, 0.0)
        if mult == 0.0:
            continue
        count_bytes = name not in fused_targets
        for op in comp.ops:
            numel, dims = _first_shape_numel(op.result_type)
            if op.opcode == "dot":
                lhs_type = comp.symtab.get(op.operands[0], "") if op.operands else ""
                _, lhs_dims = _first_shape_numel(lhs_type)
                cm = _LHS_CONTRACT_RE.search(op.line)
                contract = 1
                if cm and lhs_dims:
                    for d in cm.group(1).split(","):
                        if d:
                            contract *= lhs_dims[int(d)]
                out_numel, _ = _first_shape_numel(op.result_type)
                flops += mult * 2.0 * out_numel * contract
            elif op.opcode in _ARITH_OPS:
                flops += mult * numel
            elif op.opcode in _TRANSCENDENTAL_OPS:
                transcendentals += mult * numel
            elif op.opcode == "reduce":
                in_numel = 0
                if op.operands:
                    in_numel, _ = _first_shape_numel(comp.symtab.get(op.operands[0], ""))
                flops += mult * max(in_numel, numel)
            elif op.opcode == "convolution":
                # not used by this model zoo; count as dot-free marker
                notes.append("convolution encountered: flops not modeled")

            if op.opcode in _COLLECTIVES:
                key = op.opcode.replace("-start", "")
                wire = _type_bytes(op.result_type) * _collective_wire_factor(op.opcode, op.line)
                ent = collectives.setdefault(key, {"count": 0.0, "bytes": 0.0})
                ent["count"] += mult
                ent["bytes"] += mult * wire

            if count_bytes and op.opcode == "fusion":
                # look inside the fusion: operands consumed only via
                # slicing ops contribute slice-sized reads, not the full
                # buffer (scan carries are 2 GiB+; the body reads one
                # block per trip).  In-place dynamic-update-slice roots
                # likewise write only the update.
                callee = _CALL_ATTR_RE.search(op.line)
                body = comps.get(callee.group(1)) if callee else None
                b = 0
                if body is not None:
                    pnames = list(body.params)
                    for pos, operand in enumerate(op.operands):
                        full = _type_bytes(comp.symtab.get(operand, ""))
                        if pos < len(pnames):
                            uses = [
                                u for u in body.ops if pnames[pos] in u.operands
                            ]
                            if uses and all(
                                u.opcode in ("dynamic-slice", "slice", "gather")
                                or (u.opcode == "dynamic-update-slice" and u.operands[0] == pnames[pos])
                                for u in uses
                            ):
                                sliced = 0
                                for u in uses:
                                    if u.opcode == "dynamic-update-slice":
                                        upd = body.symtab.get(u.operands[1], "")
                                        sliced += 2 * _type_bytes(upd)
                                    else:
                                        sliced += _type_bytes(u.result_type)
                                b += min(sliced, full)
                                continue
                        b += full
                    root = body.ops[-1] if body.ops else None
                    if root is not None and root.opcode == "dynamic-update-slice":
                        b += 0  # write already counted at the dus use above
                    else:
                        b += _type_bytes(op.result_type)
                else:
                    b = _type_bytes(op.result_type)
                    for operand in op.operands:
                        b += _type_bytes(comp.symtab.get(operand, ""))
                bytes_accessed += mult * b
            elif count_bytes and op.opcode not in _FREE_OPS:
                # slicing ops move the slice, not the buffer they index
                if op.opcode == "dynamic-update-slice":
                    upd = comp.symtab.get(op.operands[1], "") if len(op.operands) > 1 else ""
                    b = 2 * _type_bytes(upd)
                elif op.opcode in ("dynamic-slice", "slice", "concatenate", "pad", "reverse"):
                    b = 2 * _type_bytes(op.result_type)
                elif op.opcode == "gather":
                    idx = comp.symtab.get(op.operands[1], "") if len(op.operands) > 1 else ""
                    b = 2 * _type_bytes(op.result_type) + _type_bytes(idx)
                elif op.opcode == "scatter":
                    upd = comp.symtab.get(op.operands[2], "") if len(op.operands) > 2 else ""
                    b = 3 * _type_bytes(upd)
                elif op.opcode == "broadcast":
                    src = comp.symtab.get(op.operands[0], "") if op.operands else ""
                    b = _type_bytes(op.result_type) + _type_bytes(src)
                else:
                    b = _type_bytes(op.result_type)
                    for operand in op.operands:
                        b += _type_bytes(comp.symtab.get(operand, ""))
                bytes_accessed += mult * b

    total = {"count": 0.0, "bytes": 0.0}
    for v in collectives.values():
        total["count"] += v["count"]
        total["bytes"] += v["bytes"]
    collectives["total"] = total
    return HLOAnalysis(
        flops=flops,
        bytes_accessed=bytes_accessed,
        transcendentals=transcendentals,
        collectives=collectives,
        n_while_loops=n_while,
        notes=notes[:20],
    )
