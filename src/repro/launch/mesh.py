"""Production mesh factory.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is
pure data parallelism over the inter-pod DCI links (gradient all-reduce
is hierarchically scheduled — see repro.distributed and DESIGN.md §5).

A FUNCTION, not a module constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py
forces 512 host devices via XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int = 8):
    """Small mesh for CPU sharding tests (n must divide available devices)."""
    return jax.make_mesh((n_devices // 4, 4), ("data", "model"))
