"""Roofline analysis over dry-run artifacts (§Roofline of EXPERIMENTS.md).

Per (arch × shape × mesh) cell, derive from the loop-corrected HLO
analysis:

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  The dominant term is the step-time lower bound; the
roofline fraction = compute / max(terms) is the MFU-like score the perf
loop drives up.  MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) over
HLO_FLOPs exposes remat/redundancy waste.

Biases (documented, consistent across cells): HLO bytes use the
fusion-boundary model on CPU-backend HLO — TPU fuses more aggressively,
so the memory term is an upper bound; collective bytes use op output
size (all-gather counts the gathered tensor).
"""

from __future__ import annotations

import argparse
import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

from ..obs import configure_logging, get_logger, log_event

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link

logger = get_logger("launch.roofline")

__all__ = ["model_flops", "roofline_row", "build_table", "main"]


def model_flops(meta: dict, kind: str, n_devices: int) -> Optional[float]:
    """Analytic useful-FLOPs per device (6ND convention)."""
    if kind == "train" and "tokens_per_step" in meta:
        return 6.0 * meta["active_param_count"] * meta["tokens_per_step"] / n_devices
    if kind == "prefill":
        return 2.0 * meta["active_param_count"] * meta["tokens_per_step"] / n_devices
    if kind == "decode":
        return 2.0 * meta["active_param_count"] * meta["tokens_per_step"] / n_devices
    if kind == "train" and "n_edges" in meta:  # GNN: projection-dominated
        return None  # reported as n/a: no community-standard 6ND analogue
    if kind == "cluster":
        n, d, f = meta["n_points"], meta["dim"], meta["frontier"]
        return 2.0 * n * d * f / n_devices  # the range-count matmul
    return None


@dataclass
class Row:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bound: str = ""
    mem_gib: float = 0.0
    hlo_flops: float = 0.0
    model_flops: Optional[float] = None
    flops_ratio: Optional[float] = None
    roofline_fraction: float = 0.0
    note: str = ""

    def as_dict(self):
        return self.__dict__.copy()


def roofline_row(rec: dict) -> Row:
    if rec.get("status") == "skip":
        return Row(rec["arch"], rec["shape"], rec["mesh"], "skip", note=rec.get("reason", ""))
    if rec.get("status") != "ok":
        return Row(rec["arch"], rec["shape"], rec["mesh"], "error",
                   note=rec.get("error", "")[:120])
    h = rec["hlo_analysis"]
    variant = rec.get("meta", {}).get("variant")
    shape_label = rec["shape"] + (f" ({variant})" if variant else "")
    flops = h["flops"]
    mem_bytes = h["bytes_accessed"]
    coll = h["collectives"].get("total", {}).get("bytes", 0.0)
    ct = flops / PEAK_FLOPS
    mt = mem_bytes / HBM_BW
    lt = coll / LINK_BW
    terms = {"compute": ct, "memory": mt, "collective": lt}
    bound = max(terms, key=terms.get)
    mf = model_flops(rec.get("meta", {}), rec.get("meta", {}).get("kind", ""), rec["n_devices"])
    return Row(
        rec["arch"], shape_label, rec["mesh"], "ok",
        compute_s=ct, memory_s=mt, collective_s=lt, bound=bound,
        mem_gib=rec["memory_analysis"]["bytes_per_device"]["total"] / 2**30,
        hlo_flops=flops, model_flops=mf,
        flops_ratio=(mf / flops) if (mf and flops) else None,
        roofline_fraction=(ct / max(terms.values())) if max(terms.values()) > 0 else 0.0,
    )


def improvement_hint(row: Row) -> str:
    if row.bound == "collective":
        return ("reduce re-gather traffic: bf16 collectives, fewer remat-induced "
                "all-gathers, overlap with compute")
    if row.bound == "memory":
        return ("fuse the softmax/score chain (Pallas flash kernel on TPU) / "
                "cut fp32 intermediates")
    return "increase arithmetic intensity (larger tiles/batch) or cut remat recompute"


def build_table(art_dir: Path) -> Dict[str, List[Row]]:
    out: Dict[str, List[Row]] = {}
    for mesh_dir in sorted(art_dir.iterdir()):
        if not mesh_dir.is_dir():
            continue
        rows = []
        for f in sorted(mesh_dir.glob("*.json")):
            rows.append(roofline_row(json.loads(f.read_text())))
        out[mesh_dir.name] = rows
    return out


def to_markdown(rows: List[Row], mesh: str) -> str:
    lines = [
        f"### Mesh {mesh}",
        "",
        "| arch | shape | compute s | memory s | collective s | bound | "
        "roofline frac | mem GiB/dev | MODEL/HLO flops | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.status == "skip":
            lines.append(f"| {r.arch} | {r.shape} | — | — | — | skip | — | — | — | {r.note[:60]} |")
            continue
        if r.status == "error":
            lines.append(f"| {r.arch} | {r.shape} | — | — | — | ERROR | — | — | — | {r.note[:60]} |")
            continue
        ratio = f"{r.flops_ratio:.2f}" if r.flops_ratio else "n/a"
        lines.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.3f} | {r.memory_s:.3f} | "
            f"{r.collective_s:.3f} | {r.bound} | {r.roofline_fraction:.2f} | "
            f"{r.mem_gib:.1f} | {ratio} | {improvement_hint(r)[:60]} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="artifacts/dryrun")
    ap.add_argument("--out", default="artifacts/roofline")
    ap.add_argument("--quiet", action="store_true",
                    help="write artifacts only; no table on the console")
    args = ap.parse_args()
    configure_logging(quiet=args.quiet)
    art = Path(args.artifacts)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    tables = build_table(art)
    md_parts, js = [], {}
    for mesh, rows in tables.items():
        md_parts.append(to_markdown(rows, mesh))
        js[mesh] = [r.as_dict() for r in rows]
        ok = [r for r in rows if r.status == "ok"]
        if ok:
            worst = min(ok, key=lambda r: r.roofline_fraction)
            coll = max(ok, key=lambda r: r.collective_s)
            md_parts.append(
                f"\nworst roofline fraction: **{worst.arch}:{worst.shape}** "
                f"({worst.roofline_fraction:.2f}); most collective-bound: "
                f"**{coll.arch}:{coll.shape}** ({coll.collective_s:.1f}s)\n"
            )
            log_event(
                logger, "roofline_mesh", mesh=mesh, cells=len(rows),
                worst_cell=f"{worst.arch}:{worst.shape}",
                worst_fraction=round(worst.roofline_fraction, 3),
                most_collective=f"{coll.arch}:{coll.shape}",
                collective_s=round(coll.collective_s, 2),
            )
    (out_dir / "roofline.md").write_text("\n\n".join(md_parts))
    (out_dir / "roofline.json").write_text(json.dumps(js, indent=2))
    if logger.isEnabledFor(20):  # the table itself is INFO-level output
        logger.info("roofline table\n%s", "\n\n".join(md_parts))
    log_event(logger, "roofline_written",
              md=str(out_dir / "roofline.md"), json=str(out_dir / "roofline.json"))


if __name__ == "__main__":
    main()
