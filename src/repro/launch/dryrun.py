import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (arch × shape × mesh) cell.

The two lines above run before any other import (jax locks device count
on first init).  For each cell we record memory_analysis (proves it
fits), cost_analysis (FLOPs/bytes for §Roofline) and the collective
operand bytes parsed from the optimized HLO, written incrementally to
``artifacts/dryrun/<mesh>/<arch>__<shape>.json``.

Usage:
  python -m repro.launch.dryrun --all
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""

import argparse
import json
import logging
import re
import time
import traceback
from pathlib import Path

import jax

from .hlo_analysis import analyze_hlo
from ..configs.registry import get_arch, list_archs
from ..obs import configure_logging, get_logger, log_event
from ..testing import faults as _faults
from .mesh import make_production_mesh
from .steps import build_cell

logger = get_logger("launch.dryrun")

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[dims]' occurrence."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return int(n * _DTYPE_BYTES.get(dt, 4))


def collective_bytes_from_hlo(hlo_text: str):
    """Sum operand bytes of every collective op in optimized HLO.

    Returns {op_name: {"count": int, "bytes": int}} plus "total".
    Operand shapes are read from the op's result type (for all-reduce the
    result equals the operand; for all-gather the result is the gathered
    size — we take the op's *output* bytes, the wire-realistic proxy).
    """
    per_op = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match '%name = TYPE all-gather(...)' or fusion-inlined variants
        for op in COLLECTIVE_OPS:
            if f"= {op}" in s or f" {op}(" in s and "=" in s:
                # find the result shape: first 'dtype[...]' after '='
                after_eq = s.split("=", 1)[1] if "=" in s else s
                shapes = _SHAPE_RE.findall(after_eq.split(op)[0])
                total = 0
                for dt, dims in shapes:
                    n = 1
                    if dims:
                        for d in dims.split(","):
                            n *= int(d)
                    total += int(n * _DTYPE_BYTES.get(dt, 4))
                if total == 0:
                    # tuple results: sum every shape on the line before the op
                    total = sum(
                        _shape_bytes(f"{dt}[{dims}]")
                        for dt, dims in _SHAPE_RE.findall(after_eq)
                    )
                ent = per_op.setdefault(op, {"count": 0, "bytes": 0})
                ent["count"] += 1
                ent["bytes"] += total
                break
    per_op["total"] = {
        "count": sum(v["count"] for v in per_op.values()),
        "bytes": sum(v["bytes"] for v in per_op.values()),
    }
    return per_op


def _analysis_findings(hlo: str, label: str):
    """laf-lint HLO invariants over the freshly compiled cell (no byte
    budget: dry-run cells are arbitrary shapes, not the standard
    configs) — surfaced in the JSON record so a sweep over the table
    doubles as a lint of every compiled module."""
    from ..analysis.hlo_checks import check_hlo_text

    return [f.to_dict() for f in check_hlo_text(hlo, label)]


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, out_dir: Path,
             verbose: bool = True, variant: str = "baseline"):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = "" if variant == "baseline" else f"__{variant}"
    out_path = out_dir / mesh_name / f"{arch_name}__{shape_name}{suffix}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    record = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "n_devices": 512 if multi_pod else 256,
    }
    plan = _faults.active()
    if plan is not None:
        record["fault_plan"] = plan.summary()
    try:
        _faults.maybe_fail("dryrun.cell", arch=arch_name, shape=shape_name)
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell = build_cell(arch_name, shape_name, mesh, variant=variant)
        with mesh:
            jitted = jax.jit(
                cell.step_fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=tuple(cell.meta.get("donate", ())),
            )
            t_build = time.time()
            lowered = jitted.lower(*cell.args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        record.update(
            status="ok",
            meta=cell.meta,
            lower_s=round(t_lower - t_build, 2),
            compile_s=round(t_compile - t_lower, 2),
            memory_analysis={
                "bytes_per_device": {
                    "argument": int(mem.argument_size_in_bytes),
                    "output": int(mem.output_size_in_bytes),
                    "temp": int(mem.temp_size_in_bytes),
                    "alias": int(mem.alias_size_in_bytes),
                    "generated_code": int(mem.generated_code_size_in_bytes),
                    # donated outputs alias their argument buffers
                    "total": int(
                        mem.argument_size_in_bytes
                        + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes
                        - mem.alias_size_in_bytes
                    ),
                },
            },
            cost_analysis={
                # raw XLA numbers: while bodies counted ONCE (kept for
                # reference; the loop-corrected values below are the
                # roofline inputs — see hlo_analysis.py)
                "flops_loop_once": float(cost.get("flops", 0.0)),
                "bytes_accessed_loop_once": float(cost.get("bytes accessed", 0.0)),
                "transcendentals_loop_once": float(cost.get("transcendentals", 0.0)),
            },
            hlo_analysis=analyze_hlo(hlo).to_dict(),
            collectives_loop_once=collective_bytes_from_hlo(hlo),
            hlo_bytes=len(hlo),
            analysis_findings=_analysis_findings(hlo, f"{arch_name}__{shape_name}"),
        )
        if verbose and record["analysis_findings"]:
            log_event(
                logger, "cell_lint", logging.WARNING,
                arch=arch_name, shape=shape_name, mesh=mesh_name,
                findings=[f["message"][:120] for f in record["analysis_findings"]],
            )
        if verbose:
            bpd = record["memory_analysis"]["bytes_per_device"]["total"] / 2**30
            log_event(
                logger, "cell_ok",
                arch=arch_name, shape=shape_name, mesh=mesh_name,
                compile_s=record["compile_s"], mem_gib=round(bpd, 2),
                flops=record["hlo_analysis"]["flops"],
                coll_gib=round(
                    record["hlo_analysis"]["collectives"].get("total", {}).get("bytes", 0)
                    / 2**30, 3,
                ),
            )
    except Exception as exc:  # record failures; the dry-run table must be complete
        record.update(status="error", error=f"{type(exc).__name__}: {exc}",
                      traceback=traceback.format_exc()[-4000:])
        if verbose:
            log_event(logger, "cell_fail", logging.WARNING,
                      arch=arch_name, shape=shape_name, mesh=mesh_name,
                      error=record["error"])
    record["wall_s"] = round(time.time() - t0, 2)
    out_path.write_text(json.dumps(record, indent=2))
    return record


def iter_cells(include_laf: bool = True):
    for arch_name in list_archs():
        arch = get_arch(arch_name)
        if arch.family == "cluster" and not include_laf:
            continue
        for shape_name in arch.shapes:
            if shape_name in arch.skips:
                yield arch_name, shape_name, True
            else:
                yield arch_name, shape_name, False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--multi-pod", action="store_true", help="alias for --mesh multi")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="install a seeded fault plan for this run, e.g. "
                    "'seed=7,dryrun.cell=0.5' (same grammar as REPRO_FAULTS); "
                    "injected cells are recorded as status=error with the "
                    "plan summary in each record")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-cell progress lines (warnings still shown)")
    args = ap.parse_args()
    configure_logging(quiet=args.quiet)
    if args.faults:
        _faults.install(_faults.FaultPlan.parse(args.faults))
    out_dir = Path(args.out)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        "multi" if args.multi_pod else args.mesh
    ]

    cells = []
    if args.all:
        for arch_name, shape_name, skipped in iter_cells():
            if skipped:
                arch = get_arch(arch_name)
                for mp in meshes:
                    mesh_name = "pod2x16x16" if mp else "pod16x16"
                    p = out_dir / mesh_name / f"{arch_name}__{shape_name}.json"
                    p.parent.mkdir(parents=True, exist_ok=True)
                    p.write_text(json.dumps({
                        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                        "status": "skip", "reason": arch.skips[shape_name],
                    }, indent=2))
                log_event(logger, "cell_skip", arch=arch_name, shape=shape_name,
                          reason=arch.skips[shape_name])
                continue
            cells.append((arch_name, shape_name))
    else:
        cells.append((args.arch, args.shape))

    n_fail = 0
    for arch_name, shape_name in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            p = out_dir / mesh_name / f"{arch_name}__{shape_name}.json"
            if args.skip_existing and p.exists():
                rec = json.loads(p.read_text())
                if rec.get("status") == "ok":
                    log_event(logger, "cell_cached", arch=arch_name,
                              shape=shape_name, mesh=mesh_name)
                    continue
            rec = run_cell(arch_name, shape_name, mp, out_dir, variant=args.variant)
            n_fail += rec["status"] == "error"
    log_event(logger, "dryrun_done",
              logging.WARNING if n_fail else logging.INFO,
              cells=len(cells), failures=n_fail)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
