"""The launch layer's unit of work: one (arch × shape × mesh) cell,
lowered.  Shared by the family step builders in ``steps`` and the
clustering lowering in ``laf_cluster`` (a separate module so the LAF
workload can build on the sharded index plane without dragging the
model families' dependency surface along)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

__all__ = ["LoweredCell"]


@dataclass
class LoweredCell:
    name: str
    step_fn: Callable
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    meta: Dict[str, Any]
