"""Gradient compression with error feedback — for the slow cross-pod hop.

Two codecs, both with error-feedback residual accumulation (the residual
makes biased compressors converge — Karimireddy et al. 2019):

* ``int8_codec`` — per-tensor-scaled int8 quantization (4x over fp32,
  2x over bf16 wire bytes).
* ``topk_codec`` — magnitude top-k with index transmission (k as a
  fraction), for the extreme-ratio regime.

Usage: compress the *cross-pod* gradient contribution only; in-pod
reduce-scatter stays uncompressed (DESIGN.md §5).  ``compress`` returns
(payload, new_residual); ``decompress`` reconstructs the dense update.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["int8_codec", "topk_codec", "Codec", "init_residuals", "compressed_wire_bytes"]


@dataclass(frozen=True)
class Codec:
    compress: Callable   # (grad, residual) -> (payload, new_residual)
    decompress: Callable  # payload -> dense grad
    wire_bytes: Callable  # payload -> int


def init_residuals(params: Any):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def int8_codec() -> Codec:
    def compress(g: jax.Array, residual: jax.Array):
        x = g.astype(jnp.float32) + residual
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        reconstructed = q.astype(jnp.float32) * scale
        return {"q": q, "scale": scale}, x - reconstructed

    def decompress(payload):
        return payload["q"].astype(jnp.float32) * payload["scale"]

    def wire_bytes(payload):
        return payload["q"].size + 4

    return Codec(compress, decompress, wire_bytes)


def topk_codec(frac: float = 0.01) -> Codec:
    def compress(g: jax.Array, residual: jax.Array):
        x = (g.astype(jnp.float32) + residual).reshape(-1)
        k = max(1, int(frac * x.size))
        vals, idx = jax.lax.top_k(jnp.abs(x), k)
        sel = x[idx]
        reconstructed = jnp.zeros_like(x).at[idx].set(sel)
        return (
            {"idx": idx.astype(jnp.int32), "vals": sel, "shape": g.shape},
            (x - reconstructed).reshape(g.shape),
        )

    def decompress(payload):
        flat_size = 1
        for s in payload["shape"]:
            flat_size *= s
        dense = jnp.zeros((flat_size,), jnp.float32).at[payload["idx"]].set(payload["vals"])
        return dense.reshape(payload["shape"])

    def wire_bytes(payload):
        return payload["idx"].size * 4 + payload["vals"].size * 4

    return Codec(compress, decompress, wire_bytes)


def compressed_wire_bytes(codec: Codec, payload_tree) -> int:
    leaves = jax.tree_util.tree_leaves(
        payload_tree, is_leaf=lambda x: isinstance(x, dict)
    )
    return sum(codec.wire_bytes(p) for p in leaves)
