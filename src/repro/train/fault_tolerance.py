"""Fault tolerance: retry/heartbeat step guard, straggler mitigation,
elastic re-mesh planning.

Single-process simulation of multi-host failure handling (this container
has one host; on a fleet the same state machine runs per-host against
the coordination service):

* ``GuardedStep`` — wraps a step fn: heartbeat timestamps, bounded
  retries on transient failure (preemption, link flap -> XlaRuntimeError),
  checkpoint-restore escalation after ``max_retries``.
* ``StragglerPolicy`` — per-step deadline from a running latency EWMA;
  slow steps are logged, and after ``k`` consecutive violations the
  policy recommends shrinking the mesh (ejecting the slow host) — with
  gradient accumulation the lost microbatch does not bias the update.
* ``plan_elastic_remesh`` — given a device loss, picks the largest
  (data, model) mesh that fits the survivors and returns the checkpoint
  resharding plan (restore handles the actual relayout).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

__all__ = ["GuardedStep", "StragglerPolicy", "plan_elastic_remesh", "StepResult"]


@dataclass
class StepResult:
    value: Any
    attempts: int
    elapsed_s: float
    recovered: bool


class GuardedStep:
    """Retry wrapper with heartbeat + restore escalation."""

    def __init__(
        self,
        step_fn: Callable,
        *,
        max_retries: int = 2,
        on_restore: Optional[Callable[[], Any]] = None,
        retryable: Tuple[type, ...] = (RuntimeError, OSError),
        backoff_s: float = 0.0,
        backoff_mult: float = 2.0,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.step_fn = step_fn
        self.max_retries = max_retries
        self.on_restore = on_restore
        self.retryable = retryable
        self.backoff_s = backoff_s
        self.backoff_mult = backoff_mult
        self._sleep = sleep
        self.last_heartbeat = time.time()
        self.failures: List[str] = []

    def __call__(self, *args, **kwargs) -> StepResult:
        t0 = time.time()
        attempts = 0
        recovered = False
        delay = self.backoff_s
        while True:
            attempts += 1
            self.last_heartbeat = time.time()
            try:
                out = self.step_fn(*args, **kwargs)
                return StepResult(out, attempts, time.time() - t0, recovered)
            except self.retryable as e:
                self.failures.append(f"{type(e).__name__}: {e}")
                if attempts > self.max_retries:
                    if self.on_restore is not None:
                        self.on_restore()
                        recovered = True
                        attempts = 0
                        delay = self.backoff_s
                        continue
                    raise
                if delay > 0:
                    self._sleep(delay)
                    delay *= self.backoff_mult


@dataclass
class StragglerPolicy:
    """EWMA-deadline straggler detection."""

    tolerance: float = 2.0        # deadline = tolerance * ewma
    ewma_alpha: float = 0.2
    eject_after: int = 3          # consecutive violations
    ewma_s: Optional[float] = None
    consecutive_slow: int = 0
    slow_steps: List[int] = field(default_factory=list)
    step_idx: int = 0

    def observe(self, elapsed_s: float) -> dict:
        self.step_idx += 1
        first = self.ewma_s is None
        if first:
            self.ewma_s = elapsed_s
        deadline = self.tolerance * self.ewma_s
        slow = (not first) and elapsed_s > deadline
        if slow:
            self.consecutive_slow += 1
            self.slow_steps.append(self.step_idx)
        else:
            self.consecutive_slow = 0
            self.ewma_s = (1 - self.ewma_alpha) * self.ewma_s + self.ewma_alpha * elapsed_s
        return {
            "slow": slow,
            "deadline_s": deadline,
            "recommend_eject": self.consecutive_slow >= self.eject_after,
            "ewma_s": self.ewma_s,
        }


def plan_elastic_remesh(
    n_devices_alive: int,
    *,
    prefer_model: int = 16,
    min_model: int = 4,
) -> Tuple[Tuple[int, int], dict]:
    """Largest (data, model) mesh fitting the survivors.

    Keeps the model axis at ``prefer_model`` when possible (TP degree is
    architecture-matched), shrinking data parallelism first; only if even
    one data replica does not fit does the model axis shrink.
    Returns ((data, model), plan) where plan documents the restore path.
    """
    model = prefer_model
    while model >= min_model:
        data = n_devices_alive // model
        if data >= 1:
            used = data * model
            plan = {
                "devices_used": used,
                "devices_idle": n_devices_alive - used,
                "action": "restore latest checkpoint with new mesh shardings "
                          "(restore_checkpoint(..., shardings=new)); global "
                          "batch preserved via gradient accumulation "
                          f"x{max(1, 16 // max(data, 1))}",
            }
            return (data, model), plan
        model //= 2
    raise ValueError(f"cannot build a mesh from {n_devices_alive} devices")
