from . import optimizer, schedule  # noqa: F401
