"""Minimal optax-style optimizers in pure JAX (optax is not in the env).

An optimizer is a pair of pure functions:
    init(params)                  -> opt_state
    update(grads, state, params)  -> (updates, new_state)
with ``apply_updates(params, updates)`` adding them in.  All state is a
pytree so it shards/checkpoints like params.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "sgd",
    "adam",
    "adamw",
    "clip_by_global_norm",
    "global_norm",
    "apply_updates",
    "chain_clip",
]

PyTree = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., Tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p, params, updates
    )


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda x: x * scale, tree), norm


def sgd(lr: float | Callable[[jax.Array], jax.Array], momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        mu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"mu": mu, "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
        )
        lr_t = lr_fn(step)
        updates = jax.tree_util.tree_map(lambda m: -lr_t * m, mu)
        return updates, {"mu": mu, "step": step}

    return Optimizer(init, update)


def _adam_core(lr, b1, b2, eps, weight_decay, state_dtype=jnp.float32):
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=state_dtype)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        b1t = 1.0 - b1 ** step.astype(jnp.float32)
        b2t = 1.0 - b2 ** step.astype(jnp.float32)
        m = jax.tree_util.tree_map(
            lambda m_, g: (b1 * m_.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(state_dtype),
            state["m"], grads
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: (b2 * v_.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(state_dtype),
            state["v"],
            grads,
        )
        lr_t = lr_fn(step)

        def upd(m_, v_, p):
            m_, v_ = m_.astype(jnp.float32), v_.astype(jnp.float32)
            u = -(lr_t) * (m_ / b1t) / (jnp.sqrt(v_ / b2t) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if weight_decay and params is not None:
            updates = jax.tree_util.tree_map(upd, m, v, params)
        else:
            updates = jax.tree_util.tree_map(lambda m_, v_: upd(m_, v_, None), m, v)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def adam(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          state_dtype=jnp.float32) -> Optimizer:
    """``state_dtype=bf16`` halves optimizer-state HBM — the standard
    100B+-scale trade (8/16-bit optimizers); update math stays fp32."""
    return _adam_core(lr, b1, b2, eps, weight_decay=weight_decay,
                      state_dtype=state_dtype)


def adamw_update_params(
    params: PyTree,
    grads: PyTree,
    state: PyTree,
    *,
    lr,
    b1=0.9,
    b2=0.95,
    eps=1e-8,
    weight_decay=0.1,
    state_dtype=jnp.float32,
    chunk_threshold_bytes: int = 256 * 2**20,
):
    """Fused AdamW: params/m/v updated in one pass, with the fp32 update
    math **chunked over the leading (stacked-layer) axis** for huge
    leaves via ``lax.map``.  The unchunked tree-wide update materializes
    fp32 m/v/u for the full (L, E, d, f) MoE stacks — measured ~6 GiB of
    fp32 temporaries per device on deepseek-v2-236b @ 256 chips; chunked,
    the fp32 working set is one layer slice."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    b1t = 1.0 - b1**sf
    b2t = 1.0 - b2**sf
    lr_t = lr_fn(step)

    def math(p, g, m_, v_):
        gf = g.astype(jnp.float32)
        m1 = b1 * m_.astype(jnp.float32) + (1 - b1) * gf
        v1 = b2 * v_.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        u = -(lr_t) * (m1 / b1t) / (jnp.sqrt(v1 / b2t) + eps)
        if weight_decay:
            u = u - lr_t * weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) + u).astype(p.dtype),
            m1.astype(state_dtype),
            v1.astype(state_dtype),
        )

    def upd_leaf(p, g, m_, v_):
        if p.ndim >= 2 and p.size * 4 > chunk_threshold_bytes and p.shape[0] > 1:
            return jax.lax.map(lambda a: math(*a), (p, g, m_, v_))
        return math(p, g, m_, v_)

    out = jax.tree_util.tree_map(upd_leaf, params, grads, state["m"], state["v"])
    treedef = jax.tree_util.tree_structure(params)
    flat = jax.tree_util.tree_leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree_util.tree_unflatten(treedef, [t[0] for t in flat])
    new_m = jax.tree_util.tree_unflatten(treedef, [t[1] for t in flat])
    new_v = jax.tree_util.tree_unflatten(treedef, [t[2] for t in flat])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def chain_clip(optimizer: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""

    def update(grads, state, params=None):
        clipped, _ = clip_by_global_norm(grads, max_norm)
        return optimizer.update(clipped, state, params)

    return Optimizer(optimizer.init, update)
