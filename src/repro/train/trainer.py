"""Training loop: ties steps, data pipeline, checkpointing, fault
tolerance, straggler policy and metrics together.

Used by examples/train_lm.py (CPU, reduced configs) and by
launch/train.py (production mesh).  The loop is deliberately dumb and
observable: every component it calls is separately tested.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from .checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from .fault_tolerance import GuardedStep, StragglerPolicy

__all__ = ["TrainLoopConfig", "train_loop"]


@dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    keep_ckpts: int = 3
    log_every: int = 10
    max_retries: int = 2
    resume: bool = True


def train_loop(
    cfg: TrainLoopConfig,
    step_fn: Callable,                    # (params, opt_state, batch) -> (params, opt_state, metrics)
    params: Any,
    opt_state: Any,
    make_batch: Callable[[int], Any],     # step -> host batch
    *,
    to_device: Callable[[Any], Any] = lambda x: x,
    log: Callable[[str], None] = print,
) -> Dict[str, Any]:
    start = 0
    ckpt = AsyncCheckpointer(cfg.ckpt_dir, cfg.keep_ckpts) if cfg.ckpt_dir else None
    if ckpt and cfg.resume and latest_step(cfg.ckpt_dir) is not None:
        (params, opt_state), start = restore_checkpoint(
            cfg.ckpt_dir, template=(params, opt_state)
        )
        start += 1
        log(f"resumed from step {start - 1}")

    state = {"params": params, "opt_state": opt_state}

    def restore():
        if not ckpt:
            raise RuntimeError("unrecoverable failure without checkpointing")
        (state["params"], state["opt_state"]), s = restore_checkpoint(
            cfg.ckpt_dir, template=(state["params"], state["opt_state"])
        )
        log(f"restored from checkpoint step {s} after repeated failures")

    guarded = GuardedStep(step_fn, max_retries=cfg.max_retries, on_restore=restore)
    straggler = StragglerPolicy()
    history: List[Dict[str, float]] = []

    for step in range(start, cfg.total_steps):
        batch = to_device(make_batch(step))
        res = guarded(state["params"], state["opt_state"], batch)
        state["params"], state["opt_state"], metrics = res.value
        verdict = straggler.observe(res.elapsed_s)
        row = {
            "step": step,
            "loss": float(metrics.get("loss", np.nan)),
            "step_s": res.elapsed_s,
            "slow": bool(verdict["slow"]),
        }
        history.append(row)
        if step % cfg.log_every == 0:
            log(f"step {step}: loss={row['loss']:.4f} ({res.elapsed_s:.2f}s)"
                + (" [straggler]" if verdict["slow"] else ""))
        if verdict["recommend_eject"]:
            log("straggler policy: recommend ejecting slow host / re-mesh")
        if ckpt and (step + 1) % cfg.ckpt_every == 0:
            ckpt.save(step, (state["params"], state["opt_state"]))
    if ckpt:
        ckpt.save(cfg.total_steps - 1, (state["params"], state["opt_state"]))
        ckpt.wait()
    return {"params": state["params"], "opt_state": state["opt_state"], "history": history}
