"""Sharded, atomic, async checkpointing with elastic resharding.

Layout (one directory per step):
    <root>/tmp-step_<N>/            written + fsynced first
        manifest.json               pytree structure + per-leaf metadata
                                    (incl. per-array crc32 checksums)
        shard_<i>.npz               leaf arrays (flat index -> array)
    <root>/step_<N>/                atomic rename on completion

Fault-tolerance properties:
  * atomic: readers never see partial checkpoints (rename-commit); the
    temp dir carries a ``tmp-`` *prefix* so no ``step_*`` glob or
    prefix check can ever pick a partial dir up, and an interrupted
    writer leaves only a ``tmp-`` dir that GC removes.
  * durable: every shard + the manifest are fsynced before the rename,
    and the parent directory is fsynced after it, so a crash right
    after ``save_checkpoint`` returns cannot lose the commit.
  * verified: the manifest records one crc32 per leaf array;
    ``restore_checkpoint`` re-checksums on read (``verify=False`` opts
    out) and raises :class:`CheckpointCorruptError` on any mismatch or
    truncated shard — callers fall back to an earlier step instead of
    serving silently corrupt state.
  * keep-k GC with never-delete-newest-complete.
  * async: ``AsyncCheckpointer`` snapshots device arrays to host, then
    writes on a background thread — the train loop blocks only on the
    previous write (single in-flight, bounded memory).
  * elastic: ``restore`` takes the *current* mesh/shardings and lays the
    saved arrays out for it — a checkpoint written on 256 chips restores
    onto 512 or 64 (values are saved unsharded per leaf here since hosts
    in this container see every shard; on a real multi-host fleet each
    host writes its addressable shards and the manifest carries the
    global shape — the reshard path is identical).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "list_steps",
    "AsyncCheckpointer",
    "gc_checkpoints",
    "CheckpointCorruptError",
]


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed its read-back integrity check (missing or
    truncated shard, checksum mismatch, unreadable manifest)."""


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    paths = [
        jax.tree_util.keystr(p)
        for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]
    return flat, paths, treedef


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def _fsync_file(path: Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: Path) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform without dir-open
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(
    root: str | Path, step: int, tree: Any, *, shard_size: int = 64,
    fsync: bool = True,
) -> Path:
    """Write one checkpoint atomically + durably.  Returns the final
    directory.  ``fsync=False`` skips the physical syncs (tests,
    throwaway scratch dirs) — atomicity is kept either way."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f"tmp-step_{step:012d}"
    final = root / f"step_{step:012d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat, paths, treedef = _flatten_with_paths(tree)
    arrays = [np.asarray(x) for x in flat]
    manifest = {
        "step": step,
        "n_leaves": len(flat),
        "paths": paths,
        "dtypes": [str(a.dtype) for a in arrays],
        "shapes": [list(a.shape) for a in arrays],
        "checksums": [_crc(a) for a in arrays],
        "shards": [],
        "written_at": time.time(),
    }
    for start in range(0, len(arrays), shard_size):
        idx = list(range(start, min(start + shard_size, len(arrays))))
        fname = f"shard_{start // shard_size:06d}.npz"
        np.savez(tmp / fname, **{f"leaf_{i}": arrays[i] for i in idx})
        if fsync:
            _fsync_file(tmp / fname)
        manifest["shards"].append({"file": fname, "leaves": idx})
    mpath = tmp / "manifest.json"
    mpath.write_text(json.dumps(manifest))
    if fsync:
        _fsync_file(mpath)
        _fsync_dir(tmp)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    if fsync:
        _fsync_dir(root)  # the rename itself must survive a crash
    return final


def list_steps(root: str | Path) -> List[int]:
    """Complete checkpoint steps under ``root``, ascending.  Partial
    dirs (``tmp-`` prefixed, legacy ``.tmp`` suffixed, or missing their
    manifest) never appear."""
    root = Path(root)
    if not root.exists():
        return []
    return sorted(
        int(p.name.split("_")[1])
        for p in root.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        and (p / "manifest.json").exists()
    )


def latest_step(root: str | Path) -> Optional[int]:
    steps = list_steps(root)
    return steps[-1] if steps else None


def restore_checkpoint(
    root: str | Path,
    step: Optional[int] = None,
    *,
    template: Any = None,
    shardings: Any = None,
    verify: bool = True,
):
    """Restore a checkpoint; lays arrays out for ``shardings`` if given
    (elastic restore onto a different mesh).  ``verify=True`` (default)
    re-checksums every leaf against the manifest and raises
    :class:`CheckpointCorruptError` on mismatch or a short read."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:012d}"
    try:
        manifest = json.loads((d / "manifest.json").read_text())
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(f"{d}: unreadable manifest ({e})") from e
    leaves: List[Optional[np.ndarray]] = [None] * manifest["n_leaves"]
    checksums = manifest.get("checksums")  # absent on pre-durability dirs
    for shard in manifest["shards"]:
        try:
            with np.load(d / shard["file"]) as z:
                for i in shard["leaves"]:
                    leaves[i] = z[f"leaf_{i}"]
        except Exception as e:
            raise CheckpointCorruptError(
                f"{d}: shard {shard['file']} unreadable ({type(e).__name__}: {e})"
            ) from e
    if any(leaf is None for leaf in leaves):
        raise CheckpointCorruptError(f"{d}: manifest shards do not cover all leaves")
    if verify and checksums is not None:
        for i, (leaf, want) in enumerate(zip(leaves, checksums)):
            got = _crc(leaf)
            if got != want:
                raise CheckpointCorruptError(
                    f"{d}: leaf {i} ({manifest['paths'][i]}) checksum mismatch "
                    f"(crc32 {got:#010x} != manifest {want:#010x})"
                )
    if template is not None:
        treedef = jax.tree_util.tree_structure(template)
    else:
        raise ValueError("restore requires a template pytree for structure")
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda arr, sh: jax.device_put(arr, sh), tree, shardings
        )
    return tree, step


def gc_checkpoints(root: str | Path, keep: int = 3) -> List[Path]:
    """Delete all but the newest ``keep`` complete checkpoints + any
    orphaned partial dirs (``tmp-`` prefixed, legacy ``.tmp`` suffixed,
    or manifest-less step dirs).  Returns deleted paths."""
    root = Path(root)
    if not root.exists():
        return []
    deleted = []
    for p in list(root.glob("tmp-step_*")) + list(root.glob("step_*.tmp")):
        shutil.rmtree(p)
        deleted.append(p)
    # a crash can also leave a committed-looking dir without a manifest
    # (pre-durability writers): treat manifest-less step dirs as partial
    for p in root.glob("step_*"):
        if p.is_dir() and not (p / "manifest.json").exists():
            shutil.rmtree(p)
            deleted.append(p)
    complete = sorted(
        (p for p in root.iterdir() if p.is_dir() and p.name.startswith("step_")
         and (p / "manifest.json").exists()),
        key=lambda p: p.name,
    )
    for p in complete[:-keep] if keep else complete:
        shutil.rmtree(p)
        deleted.append(p)
    return deleted


class AsyncCheckpointer:
    """Single-in-flight async writer: snapshot to host sync, write async."""

    def __init__(self, root: str | Path, keep: int = 3):
        self.root = Path(root)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save(self, step: int, tree: Any):
        self.wait()  # one in flight
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.root, step, host_tree)
                gc_checkpoints(self.root, self.keep)
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
