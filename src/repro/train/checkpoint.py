"""Sharded, atomic, async checkpointing with elastic resharding.

Layout (one directory per step):
    <root>/step_<N>.tmp/            written first
        manifest.json               pytree structure + per-leaf metadata
        shard_<i>.npz               leaf arrays (flat index -> array)
    <root>/step_<N>/                atomic rename on completion

Fault-tolerance properties:
  * atomic: readers never see partial checkpoints (rename-commit);
    an interrupted writer leaves only a .tmp dir that GC removes.
  * keep-k GC with never-delete-newest-complete.
  * async: ``AsyncCheckpointer`` snapshots device arrays to host, then
    writes on a background thread — the train loop blocks only on the
    previous write (single in-flight, bounded memory).
  * elastic: ``restore`` takes the *current* mesh/shardings and lays the
    saved arrays out for it — a checkpoint written on 256 chips restores
    onto 512 or 64 (values are saved unsharded per leaf here since hosts
    in this container see every shard; on a real multi-host fleet each
    host writes its addressable shards and the manifest carries the
    global shape — the reshard path is identical).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "AsyncCheckpointer", "gc_checkpoints"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    paths = [
        jax.tree_util.keystr(p)
        for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]
    return flat, paths, treedef


def save_checkpoint(root: str | Path, step: int, tree: Any, *, shard_size: int = 64) -> Path:
    """Write one checkpoint atomically.  Returns the final directory."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f"step_{step:012d}.tmp"
    final = root / f"step_{step:012d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat, paths, treedef = _flatten_with_paths(tree)
    arrays = [np.asarray(x) for x in flat]
    manifest = {
        "step": step,
        "n_leaves": len(flat),
        "paths": paths,
        "dtypes": [str(a.dtype) for a in arrays],
        "shapes": [list(a.shape) for a in arrays],
        "shards": [],
        "written_at": time.time(),
    }
    for start in range(0, len(arrays), shard_size):
        idx = list(range(start, min(start + shard_size, len(arrays))))
        fname = f"shard_{start // shard_size:06d}.npz"
        np.savez(tmp / fname, **{f"leaf_{i}": arrays[i] for i in idx})
        manifest["shards"].append({"file": fname, "leaves": idx})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def latest_step(root: str | Path) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in root.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    root: str | Path,
    step: Optional[int] = None,
    *,
    template: Any = None,
    shardings: Any = None,
):
    """Restore a checkpoint; lays arrays out for ``shardings`` if given
    (elastic restore onto a different mesh)."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:012d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves: List[Optional[np.ndarray]] = [None] * manifest["n_leaves"]
    for shard in manifest["shards"]:
        with np.load(d / shard["file"]) as z:
            for i in shard["leaves"]:
                leaves[i] = z[f"leaf_{i}"]
    if template is not None:
        treedef = jax.tree_util.tree_structure(template)
    else:
        raise ValueError("restore requires a template pytree for structure")
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda arr, sh: jax.device_put(arr, sh), tree, shardings
        )
    return tree, step


def gc_checkpoints(root: str | Path, keep: int = 3) -> List[Path]:
    """Delete all but the newest ``keep`` complete checkpoints + any
    orphaned .tmp dirs.  Returns deleted paths."""
    root = Path(root)
    if not root.exists():
        return []
    deleted = []
    for p in root.glob("step_*.tmp"):
        shutil.rmtree(p)
        deleted.append(p)
    complete = sorted(
        (p for p in root.iterdir() if p.is_dir() and not p.name.endswith(".tmp")
         and (p / "manifest.json").exists()),
        key=lambda p: p.name,
    )
    for p in complete[:-keep] if keep else complete:
        shutil.rmtree(p)
        deleted.append(p)
    return deleted


class AsyncCheckpointer:
    """Single-in-flight async writer: snapshot to host sync, write async."""

    def __init__(self, root: str | Path, keep: int = 3):
        self.root = Path(root)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save(self, step: int, tree: Any):
        self.wait()  # one in flight
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.root, step, host_tree)
                gc_checkpoints(self.root, self.keep)
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
