"""Graph attention network (GAT, Veličković et al. 2018).

Message passing over an explicit edge list via ``jax.ops.segment_*`` —
JAX has no CSR SpMM, so SDDMM (edge scores) -> segment-softmax ->
scatter-SpMM IS the implementation, per the assignment spec.  Supports
full-graph, edge-sharded full-graph (the launcher shard_maps over the
edge axis) and padded sampled subgraphs from the neighbor sampler.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import dense, dense_init


@dataclass(frozen=True)
class GATConfig:
    d_in: int
    d_hidden: int            # per-head hidden dim (cora: 8)
    n_heads: int             # (cora: 8)
    n_layers: int = 2
    n_classes: int = 7
    negative_slope: float = 0.2
    dtype: object = jnp.float32


def gat_init(key, cfg: GATConfig):
    layers = []
    d_in = cfg.d_in
    for li in range(cfg.n_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        last = li == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        layers.append(
            {
                "w": dense_init(k1, d_in, heads * d_out, cfg.dtype),
                "a_src": (jax.random.normal(k2, (heads, d_out), jnp.float32) * 0.1).astype(cfg.dtype),
                "a_dst": (jax.random.normal(k3, (heads, d_out), jnp.float32) * 0.1).astype(cfg.dtype),
            }
        )
        d_in = heads * d_out if not last else d_out
    return {"layers": layers}


def _edge_softmax(scores, dst, n_nodes):
    """Per-destination softmax over edge scores (E, H)."""
    smax = jax.ops.segment_max(scores, dst, num_segments=n_nodes)  # (N, H)
    ex = jnp.exp(scores - smax[dst])
    denom = jax.ops.segment_sum(ex, dst, num_segments=n_nodes)
    return ex / jnp.maximum(denom[dst], 1e-16)


def gat_layer(p, x, src, dst, n_nodes, *, heads, d_out, slope, edge_mask=None):
    """x (N, d_in); src/dst (E,) int32 -> (N, heads*d_out)."""
    h = dense(p["w"], x).reshape(-1, heads, d_out)                  # (N, H, D)
    e_src = (h * p["a_src"].astype(h.dtype)[None]).sum(-1)          # (N, H)
    e_dst = (h * p["a_dst"].astype(h.dtype)[None]).sum(-1)
    scores = e_src[src] + e_dst[dst]                                # (E, H)
    scores = jax.nn.leaky_relu(scores.astype(jnp.float32), slope)
    if edge_mask is not None:
        scores = jnp.where(edge_mask[:, None], scores, -1e30)
    attn = _edge_softmax(scores, dst, n_nodes)                      # (E, H)
    if edge_mask is not None:
        attn = jnp.where(edge_mask[:, None], attn, 0.0)
    msgs = h[src].astype(jnp.float32) * attn[:, :, None]            # (E, H, D)
    agg = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)      # (N, H, D)
    return agg.reshape(n_nodes, heads * d_out).astype(x.dtype)


def gat_forward(params, cfg: GATConfig, feats, src, dst, *, edge_mask=None):
    """Full forward -> per-node class logits (N, n_classes)."""
    n = feats.shape[0]
    x = feats.astype(cfg.dtype)
    for li, p in enumerate(params["layers"]):
        last = li == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        x = gat_layer(
            p, x, src, dst, n,
            heads=heads, d_out=d_out, slope=cfg.negative_slope, edge_mask=edge_mask,
        )
        if not last:
            x = jax.nn.elu(x.astype(jnp.float32)).astype(cfg.dtype)
    return x


def gat_loss(params, cfg, feats, src, dst, labels, *, label_mask=None, edge_mask=None):
    logits = gat_forward(params, cfg, feats, src, dst, edge_mask=edge_mask)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
    nll = logz - gold
    if label_mask is not None:
        return (nll * label_mask).sum() / jnp.maximum(label_mask.sum(), 1)
    return nll.mean()


def gat_forward_batched(params, cfg: GATConfig, feats, src, dst):
    """Batched small graphs (molecule shape): vmap over the batch axis,
    then mean-pool node logits to a graph-level prediction."""
    per_graph = jax.vmap(lambda f, s, d: gat_forward(params, cfg, f, s, d))
    logits = per_graph(feats, src, dst)          # (B, N, C)
    return logits.mean(axis=1)                   # (B, C)
