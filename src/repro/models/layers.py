"""Shared layers: norms, rotary embeddings, GQA attention (blockwise
online-softmax in pure jnp — compiles on any backend; the Pallas
``flash_attention`` kernel is the TPU-executed twin), gated MLPs."""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in, d_out, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def dense(w, x):
    return x @ w.astype(x.dtype)


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """x (..., S, D); positions (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (blockwise online-softmax; exact)
# ---------------------------------------------------------------------------


def _attend_block(q, k, v, mask, scale):
    """q (B,H,Tq,D) k/v (B,H,Tk,D) mask (B|1,1,Tq,Tk) -> partial (o,m,l).

    Inputs stay in their native dtype (bf16 on TPU) with fp32 MXU
    accumulation — upcasting q/k/v BEFORE the dots doubles the bytes of
    every layout-transition collective the partitioner places on them
    (measured: 30% of llama3-8b/train_4k collective traffic)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    m = s.max(axis=-1)                                  # (B,H,Tq)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return o, m, l


def blockwise_attention(
    q: jax.Array,          # (B, Hq, Sq, D)
    k: jax.Array,          # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,            # None or int/traced scalar: kpos > qpos - window
    q_offset=None,          # absolute position of q[0] (decode); default Sk-Sq
    kv_block: int = 1024,
    valid_len=None,         # number of valid kv entries (decode w/ cache)
):
    """Exact attention, scanned over KV blocks with online softmax; the
    (Sq, Sk) score matrix never materializes (memory ∝ Sq × kv_block)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]                                      # may differ (MLA)
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / math.sqrt(d)
    if q_offset is None:
        q_offset = sk - sq
    qpos = jnp.arange(sq) + q_offset                      # (Sq,)

    nblk = -(-sk // kv_block)
    pad = nblk * kv_block - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    ks = k.reshape(b, hq, nblk, kv_block, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, hq, nblk, kv_block, dv).transpose(2, 0, 1, 3, 4)

    def body(carry, blk):
        o_acc, m_acc, l_acc, j = carry
        kb, vb = blk
        kpos = j * kv_block + jnp.arange(kv_block)        # (Tk,)
        mask = jnp.ones((sq, kv_block), bool)
        if pad:
            mask &= kpos[None, :] < sk
        if valid_len is not None:
            mask &= kpos[None, :] < valid_len
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        o, m, l = _attend_block(q, kb, vb, mask[None, None], scale)
        m_new = jnp.maximum(m_acc, m)
        corr_old = jnp.exp(m_acc - m_new)
        corr_new = jnp.exp(m - m_new)
        o_acc = o_acc * corr_old[..., None] + o * corr_new[..., None]
        l_acc = l_acc * corr_old + l * corr_new
        return (o_acc, m_new, l_acc, j + 1), None

    # remat the block body: without it the inner scan saves every (Sq,
    # kv_block) fp32 score tile for backward — the full score matrix in
    # aggregate (measured 4x2 GiB buffers on llama3-8b/train_4k @ 256
    # devices).  Recomputing scores in the backward is the flash-attention
    # trade and costs ~30% more attention FLOPs for O(Sq*Sk) -> O(Sq)
    # memory.
    body = jax.checkpoint(body)

    o0 = jnp.zeros((b, hq, sq, dv), jnp.float32)
    m0 = jnp.full((b, hq, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    (o, m, l, _), _ = jax.lax.scan(body, (o0, m0, l0, 0), (ks, vs))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# gated MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff, dtype),
        "wi_up": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(p, x):
    g = jax.nn.silu(dense(p["wi_gate"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["wo"], g * dense(p["wi_up"], x))


def geglu_init(key, d_model, d_ff, dtype=jnp.float32):
    return swiglu_init(key, d_model, d_ff, dtype)


def geglu(p, x):
    g = jax.nn.gelu(dense(p["wi_gate"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["wo"], g * dense(p["wi_up"], x))


def mlp_init(key, dims, dtype=jnp.float32, bias=True):
    """Plain ReLU MLP tower (recsys towers): dims = [in, h1, ..., out]."""
    params = []
    for i in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        layer = {"w": dense_init(sub, dims[i], dims[i + 1], dtype)}
        if bias:
            layer["b"] = jnp.zeros((dims[i + 1],), dtype)
        params.append(layer)
    return params


def mlp_apply(params, x, final_activation=False):
    for i, layer in enumerate(params):
        x = x @ layer["w"].astype(x.dtype)
        if "b" in layer:
            x = x + layer["b"].astype(x.dtype)
        if i < len(params) - 1 or final_activation:
            x = jax.nn.relu(x)
    return x


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logits (..., V), labels (...) int32."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    return jnp.mean(logz - gold)


def chunked_cross_entropy(
    w_head: jax.Array,        # (D, V)
    h: jax.Array,             # (B, S, D) final hidden states
    labels: jax.Array,        # (B, S)
    *,
    chunk: int = 512,
    shard_logits=None,        # optional constraint fn for the chunk logits
) -> jax.Array:
    """LM loss without materializing the full (B, S, V) fp32 logits:
    scan over sequence chunks, each chunk's logits live only inside its
    (rematted) body.  At 128k vocab the full fp32 logits are ~2 GiB per
    device at production shapes — this brings the live set down to
    (B, chunk, V_shard)."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    hs = h.reshape(b, s // chunk, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, s // chunk, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        hc, lc = xs
        logits = hc @ w_head.astype(hc.dtype)
        if shard_logits is not None:
            logits = shard_logits(logits)
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), lc[..., None], axis=-1
        )[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32), (hs, ls))
    return total / (b * s)
