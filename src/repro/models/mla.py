"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV compression: x -> c_kv (kv_lora_rank=512) + shared RoPE key (64); per
head K_nope/V expand from c_kv.  Queries go through their own low-rank
path (q_lora_rank=1536) and split into nope(128) + rope(64) parts.

Decode caches ONLY (c_kv, k_rope) — 576 floats/token vs 32k for dense
KV at 128 heads — and uses the *absorbed* formulation: W_uk folds into
the query (scores computed in latent space) and W_uv folds into the
output projection, so the per-step cost never expands the cache.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init


@dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128
    rope_theta: float = 10000.0


def mla_init(key, cfg: MLAConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    h = cfg.n_heads
    return {
        "wq_a": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype),
        "q_norm": rmsnorm_init(cfg.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], cfg.q_lora_rank, h * (cfg.qk_nope_dim + cfg.qk_rope_dim), dtype),
        "wkv_a": dense_init(ks[2], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim, dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wk_b": dense_init(ks[3], cfg.kv_lora_rank, h * cfg.qk_nope_dim, dtype),
        "wv_b": dense_init(ks[4], cfg.kv_lora_rank, h * cfg.v_dim, dtype),
        "wo": dense_init(ks[5], h * cfg.v_dim, cfg.d_model, dtype),
    }


def _project_q(params, cfg: MLAConfig, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    q = dense(params["wq_b"], rmsnorm(params["q_norm"], dense(params["wq_a"], x)))
    q = q.reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim).transpose(0, 2, 1, 3)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions[:, None, :], cfg.rope_theta)
    return q_nope, q_rope  # (B, H, S, 128), (B, H, S, 64)


def _compress_kv(params, cfg: MLAConfig, x, positions):
    ckv = dense(params["wkv_a"], x)  # (B, S, 512+64)
    c_kv, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(params["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, None, :, :], positions[:, None, :], cfg.rope_theta)
    return c_kv, k_rope[:, 0]  # (B, S, 512), (B, S, 64)


def mla_attention(params, cfg: MLAConfig, x, positions, *, causal=True, kv_block=1024):
    """Training/prefill path.

    Scores decompose as q_nope·k_nope + q_rope·k_rope, so concatenating
    the nope and (head-broadcast) rope features gives a standard
    attention problem with d_qk = 192 — which runs through the blockwise
    online-softmax path (the naive einsum materializes the full (B, H,
    S, S) fp32 score matrix: measured 8 GiB buffers per device on
    deepseek-v2-236b/train_4k @ 256 devices)."""
    from .layers import blockwise_attention

    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _project_q(params, cfg, x, positions)        # (B,H,S,*)
    c_kv, k_rope = _compress_kv(params, cfg, x, positions)        # (B,S,512),(B,S,64)
    k_nope = dense(params["wk_b"], c_kv).reshape(b, s, h, cfg.qk_nope_dim).transpose(0, 2, 1, 3)
    v = dense(params["wv_b"], c_kv).reshape(b, s, h, cfg.v_dim).transpose(0, 2, 1, 3)

    # MLA uses 1/sqrt(d_nope + d_rope); blockwise_attention scales by
    # 1/sqrt(d_cat) with d_cat = d_nope + d_rope — identical.
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)            # (B,H,S,192)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None], (b, h, s, cfg.qk_rope_dim))],
        axis=-1,
    )
    out = blockwise_attention(q_cat, k_cat, v, causal=causal, kv_block=kv_block)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * cfg.v_dim)
    return dense(params["wo"], out), (c_kv, k_rope)


def mla_decode_step(params, cfg: MLAConfig, x, cache_ckv, cache_krope, cur_len):
    """Absorbed decode: scores and values stay in the 512-d latent space.

    x (B, 1, d); cache_ckv (B, S, 512); cache_krope (B, S, 64).
    """
    b, _, _ = x.shape
    h = cfg.n_heads
    s_max = cache_ckv.shape[1]
    positions = jnp.full((b, 1), cur_len, jnp.int32)
    q_nope, q_rope = _project_q(params, cfg, x, positions)     # (B,H,1,*)
    c_new, krope_new = _compress_kv(params, cfg, x, positions)  # (B,1,512),(B,1,64)
    cache_ckv = jax.lax.dynamic_update_slice(cache_ckv, c_new.astype(cache_ckv.dtype), (0, cur_len, 0))
    cache_krope = jax.lax.dynamic_update_slice(cache_krope, krope_new.astype(cache_krope.dtype), (0, cur_len, 0))

    # absorb W_uk: q_lat (B,H,1,512) = q_nope @ W_uk(per head)
    wk_b = params["wk_b"].astype(jnp.float32).reshape(cfg.kv_lora_rank, h, cfg.qk_nope_dim)
    q_lat = jnp.einsum("bhqd,rhd->bhqr", q_nope.astype(jnp.float32), wk_b)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    logits = (
        jnp.einsum("bhqr,bkr->bhqk", q_lat, cache_ckv.astype(jnp.float32))
        + jnp.einsum("bhqd,bkd->bhqk", q_rope.astype(jnp.float32), cache_krope.astype(jnp.float32))
    ) * scale
    valid = jnp.arange(s_max)[None, None, None, :] <= cur_len
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    # attend in latent space, then absorb W_uv
    o_lat = jnp.einsum("bhqk,bkr->bhqr", probs, cache_ckv.astype(jnp.float32))  # (B,H,1,512)
    wv_b = params["wv_b"].astype(jnp.float32).reshape(cfg.kv_lora_rank, h, cfg.v_dim)
    o = jnp.einsum("bhqr,rhd->bhqd", o_lat, wv_b)               # (B,H,1,128)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * cfg.v_dim).astype(x.dtype)
    return dense(params["wo"], o), cache_ckv, cache_krope
