"""Mixture-of-Experts FFN: top-k routing with capacity-bounded
scatter dispatch (the TPU-idiomatic GShard formulation).

Tokens are organized into **groups** (one per data-parallel shard at
production scale): routing, slot assignment and the capacity bound are
group-local, so the one-hot/cumsum bookkeeping never crosses shards.
The (G, E, C, d) dispatch buffers shard G over the data axis and E over
the model axis (expert parallelism) — under pjit the group->expert
exchange lowers to the canonical all-to-all.  Without grouping, XLA is
forced to materialize global dispatch state: measured 226 GiB/device
(vs 8 GiB grouped) on deepseek-v2-236b/train_4k @ 256 devices.

Tokens beyond capacity are dropped (standard GShard/Switch semantics,
droppage reported as an aux stat).  Shared experts (DeepSeek-V2) run
densely beside the routed path.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .layers import dense, dense_init, swiglu, swiglu_init

Identity = lambda x: x


@dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden dim
    n_experts: int
    top_k: int
    n_shared: int = 0         # always-on shared experts (DeepSeek-V2)
    capacity_factor: float = 1.25
    groups: int = 1           # dispatch groups (= data shards at scale)
    shard_buffers: Optional[Callable] = None   # hook: (G,E,C,d) expert-compute layout
    shard_dispatch: Optional[Callable] = None  # hook: (G,E,C,d) scatter/gather layout
    shard_tokens: Optional[Callable] = None    # hook: (G,T,d) constraint
    shard_entries: Optional[Callable] = None   # hook: (G,T*k,d) constraint
    dtype: object = jnp.float32


def moe_init(key, cfg: MoEConfig):
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    scale = 1.0 / math.sqrt(d)
    params = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router in fp32
        "wi_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(cfg.dtype),
        "wi_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(cfg.dtype),
        "wo": (jax.random.normal(ks[3], (e, f, d), jnp.float32) / math.sqrt(f)).astype(cfg.dtype),
    }
    if cfg.n_shared:
        key, sub = jax.random.split(key)
        params["shared"] = swiglu_init(sub, d, f * cfg.n_shared, cfg.dtype)
    return params


def _capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = int(math.ceil(cfg.capacity_factor * cfg.top_k * tokens_per_group / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane friendliness


def moe_apply(params, cfg: MoEConfig, x: jax.Array):
    """x (T, d) -> (T, d), aux dict.  Callers flatten (B, S) -> T.
    T must divide by cfg.groups (groups=1 for single-host use).

    No vmap: everything carries an explicit leading G axis so the
    sharding hooks can pin the (G, T·k, d) entry matrices — inside vmap,
    with_sharding_constraint cannot express the batched spec, and the
    gathers end up replicated over the model axis (measured 7.5 GiB
    fp32 buffers on deepseek-v2-236b/train_4k)."""
    t, d = x.shape
    g = cfg.groups
    e, k = cfg.n_experts, cfg.top_k
    assert t % g == 0, (t, g)
    tg = t // g
    cap = _capacity(tg, cfg)
    shard_tok = cfg.shard_tokens or Identity
    shard_buf = cfg.shard_buffers or Identity
    shard_disp = cfg.shard_dispatch or Identity
    shard_ent = cfg.shard_entries or Identity

    xg = shard_tok(x.reshape(g, tg, d))
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                        # (G, Tg, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_expert = expert_idx.reshape(g, tg * k)                    # (G, TK)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)       # (G, TK, E)
    ranks = jnp.cumsum(onehot, axis=1) - onehot                    # entries before me, per group
    slot = jnp.take_along_axis(ranks, flat_expert[..., None], axis=2)[..., 0]
    keep = slot < cap
    safe_slot = jnp.where(keep, slot, cap - 1)                     # (G, TK)
    token_of_entry = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), k)[None], (g, tg * k)
    )

    entries = jnp.take_along_axis(xg, token_of_entry[..., None], axis=1)  # (G, TK, d)
    entries = shard_ent(jnp.where(keep[..., None], entries, 0).astype(x.dtype))
    # per-group 2-index scatter (batched via vmap — GSPMD partitions the
    # G and d dims; a flat 3-index scatter defeats partitioning entirely:
    # measured 519 GiB/dev + 70 TiB collectives on deepseek train_4k)
    buf = jax.vmap(
        lambda ent, fe, ss: jnp.zeros((e, cap, d), x.dtype).at[fe, ss].add(ent, mode="drop")
    )(entries, flat_expert, safe_slot)
    # scatter partitions on (G, d); the expert einsum wants (G, E) — the
    # layout switch below is the canonical MoE all-to-all.
    buf = shard_disp(buf)
    buf = shard_buf(buf)                                           # (G, E, C, d)

    bf = buf.astype(jnp.float32)
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", bf, params["wi_gate"].astype(jnp.float32)))
    up = jnp.einsum("gecd,edf->gecf", bf, params["wi_up"].astype(jnp.float32))
    y = jnp.einsum("gecf,efd->gecd", gate * up, params["wo"].astype(jnp.float32))
    y = shard_buf(y.astype(x.dtype))
    y = shard_disp(y)                                              # all-to-all back

    gathered = jax.vmap(lambda yy, fe, ss: yy[fe, ss])(y, flat_expert, safe_slot)
    gathered = shard_ent(jnp.where(keep[..., None], gathered, 0))
    weighted = shard_ent(
        gathered.astype(jnp.float32) * gate_vals.reshape(g, tg * k)[..., None]
    )
    out = jax.vmap(
        lambda w, toe: jnp.zeros((tg, d), jnp.float32).at[toe].add(w)
    )(weighted, token_of_entry)
    # cast BEFORE the layout transition back to the residual sharding:
    # the (G·Tg, d) boundary tensor (and its cotangent) then moves as
    # bf16, halving the seq<->feature all-to-all bytes.
    out = shard_tok(out.astype(x.dtype))
    out = out.reshape(t, d)

    aux = {
        "drop_fraction": 1.0 - keep.mean(),
        "router_entropy": -(probs * jnp.log(probs + 1e-9)).sum(-1).mean(),
        "lb_loss": e * jnp.mean(
            probs.mean((0, 1)) * onehot.sum((0, 1)) / max(t * k, 1)
        ),
    }

    if cfg.n_shared:
        out = out + swiglu(params["shared"], x)
    return out.astype(x.dtype), aux
