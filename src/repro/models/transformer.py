"""Decoder-only transformer LM: GQA/MQA + RoPE, optional per-layer
sliding-window pattern (Gemma-3's 5:1 local:global), optional MoE FFN
(Grok-1, DeepSeek-V2) and optional MLA attention (DeepSeek-V2).

Layers are ``lax.scan``-stacked (one compiled layer body regardless of
depth — essential for 60-layer dry-run compiles) with ``jax.checkpoint``
around the body so only the residual stream is saved across layers.
Non-uniform prefixes (DeepSeek's first-layer dense FFN) run unstacked
before the scan.  An optional ``shard_act`` hook lets the launcher pin
residual shardings without the model knowing about meshes.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import (
    apply_rope,
    blockwise_attention,
    cross_entropy_loss,
    dense,
    dense_init,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
)
from .mla import MLAConfig, mla_attention, mla_decode_step, mla_init
from .moe import MoEConfig, moe_apply, moe_init

Identity = lambda x: x


@dataclass(frozen=True)
class TransformerConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    kv_heads: int
    d_head: int
    d_ff: int
    rope_theta: float = 10000.0
    window: Optional[int] = None     # sliding window for local layers
    global_every: int = 0            # 0: all layers global; k: layer i global iff (i+1)%k==0
    moe: Optional[MoEConfig] = None
    n_dense_layers: int = 0          # leading layers with dense FFN even when moe set
    attention: str = "gqa"           # "gqa" | "mla"
    mla: Optional[MLAConfig] = None
    dtype: Any = jnp.bfloat16
    kv_block: int = 1024             # attention KV chunk
    remat: bool = True

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.d_head

    def layer_is_global(self, i: int) -> bool:
        if self.global_every <= 0 or self.window is None:
            return True
        return (i + 1) % self.global_every == 0

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        if self.attention == "mla":
            m = self.mla
            attn = (
                d * m.q_lora_rank + m.q_lora_rank * m.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                + d * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * m.n_heads * (m.qk_nope_dim + m.v_dim)
                + m.n_heads * m.v_dim * d
            )
        else:
            attn = d * self.attn_dim + 2 * d * self.kv_heads * self.d_head + self.attn_dim * d
        dense_ffn = 3 * d * f
        if self.moe is not None:
            moe_ffn = 3 * self.moe.d_ff * d * self.moe.n_experts + d * self.moe.n_experts
            moe_ffn += 3 * d * self.moe.d_ff * self.moe.n_shared
            n_moe = self.n_layers - self.n_dense_layers
            ffn_total = n_moe * moe_ffn + self.n_dense_layers * dense_ffn
        else:
            ffn_total = self.n_layers * dense_ffn
        return self.n_layers * attn + ffn_total + 2 * v * d + self.n_layers * 2 * d + d

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        all_experts = 3 * self.d_model * self.moe.d_ff * self.moe.n_experts
        active_experts = 3 * self.d_model * self.moe.d_ff * self.moe.top_k
        n_moe = self.n_layers - self.n_dense_layers
        return full - n_moe * (all_experts - active_experts)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _attn_init(key, cfg: TransformerConfig):
    if cfg.attention == "mla":
        return mla_init(key, cfg.mla, cfg.dtype)
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "wq": dense_init(ks[0], d, cfg.attn_dim, cfg.dtype),
        "wk": dense_init(ks[1], d, cfg.kv_heads * cfg.d_head, cfg.dtype),
        "wv": dense_init(ks[2], d, cfg.kv_heads * cfg.d_head, cfg.dtype),
        "wo": dense_init(ks[3], cfg.attn_dim, d, cfg.dtype),
    }


def _layer_init(key, cfg: TransformerConfig, dense_ffn: bool):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, cfg.dtype),
        "ln2": rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": _attn_init(k1, cfg),
    }
    if cfg.moe is not None and not dense_ffn:
        p["moe"] = moe_init(k2, cfg.moe)
    else:
        p["ffn"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def transformer_init(key, cfg: TransformerConfig):
    ke, kl, kh = jax.random.split(key, 3)
    n_stacked = cfg.n_layers - cfg.n_dense_layers
    layer_keys = jax.random.split(kl, cfg.n_layers)
    prefix = [
        _layer_init(layer_keys[i], cfg, dense_ffn=True)
        for i in range(cfg.n_dense_layers)
    ]
    stacked = jax.vmap(lambda k: _layer_init(k, cfg, dense_ffn=False))(
        layer_keys[cfg.n_dense_layers :]
    )
    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(cfg.dtype),
        "layers": stacked,
        "ln_f": rmsnorm_init(cfg.d_model, cfg.dtype),
        "lm_head": dense_init(kh, cfg.d_model, cfg.vocab, cfg.dtype),
    }
    if prefix:
        params["prefix_layers"] = prefix
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _gqa_attend(p, cfg: TransformerConfig, h, positions, *, window,
                shard_act=Identity, shard_qkv=Identity):
    b, s, _ = h.shape
    q = dense(p["wq"], h).reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = dense(p["wk"], h).reshape(b, s, cfg.kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = dense(p["wv"], h).reshape(b, s, cfg.kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    # Ulysses-style layout switch: residual is seq-sharded; attention
    # runs head-sharded with the full sequence local.  Without this, the
    # partitioner re-gathers every K/V block inside the online-softmax
    # scan — per-block, per-layer, per-pass (measured 380 GiB of the
    # 502 GiB step collectives on llama3-8b/train_4k @ 256 chips).
    q, k, v = shard_qkv(q), shard_qkv(k), shard_qkv(v)
    q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = apply_rope(k, positions[:, None, :], cfg.rope_theta)
    o = blockwise_attention(q, k, v, causal=True, window=window, kv_block=cfg.kv_block)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.attn_dim)
    return dense(p["wo"], o), (k, v)


def _layer_forward(p, cfg: TransformerConfig, h, positions, window,
                   shard_act=Identity, shard_qkv=Identity):
    if cfg.attention == "mla":
        attn_out, _ = mla_attention(p["attn"], cfg.mla, rmsnorm(p["ln1"], h), positions)
    else:
        attn_out, _ = _gqa_attend(
            p["attn"], cfg, rmsnorm(p["ln1"], h), positions, window=window,
            shard_act=shard_act, shard_qkv=shard_qkv,
        )
    h = shard_act(h + attn_out)
    x = rmsnorm(p["ln2"], h)
    if "moe" in p:
        b, s, d = x.shape
        y, _aux = moe_apply(p["moe"], cfg.moe, x.reshape(b * s, d))
        y = y.reshape(b, s, d)
    else:
        y = swiglu(p["ffn"], x)
    return shard_act(h + y)


def transformer_hidden(
    params,
    cfg: TransformerConfig,
    tokens: jax.Array,                 # (B, S) int32
    *,
    shard_act: Callable = Identity,
    shard_layer_params: Callable = Identity,
    shard_qkv: Callable = Identity,
):
    """Backbone forward -> final hidden states (B, S, D) after ln_f.

    ``shard_layer_params`` re-pins the per-layer param slice inside the
    scan body: without it GSPMD lets the reverse-scan gradient
    accumulators go unsharded (measured: 17 GiB temp vs 5 GiB on
    llama3-8b/train_4k @ 256 devices — see EXPERIMENTS.md §Perf).
    """
    b, s = tokens.shape
    h = shard_act(params["embed"].astype(cfg.dtype)[tokens])
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    for p in params.get("prefix_layers", []):
        h = _layer_forward(p, cfg, h, positions, None, shard_act, shard_qkv)

    windows = jnp.asarray(
        [
            (1 << 30) if cfg.layer_is_global(i + cfg.n_dense_layers) else cfg.window
            for i in range(cfg.n_layers - cfg.n_dense_layers)
        ],
        jnp.int32,
    )

    def body(h, xs):
        layer_p, window = xs
        layer_p = shard_layer_params(layer_p)
        return _layer_forward(
            layer_p, cfg, h, positions, window, shard_act, shard_qkv
        ), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, (params["layers"], windows))
    return rmsnorm(params["ln_f"], h)


def transformer_forward(
    params,
    cfg: TransformerConfig,
    tokens: jax.Array,
    *,
    shard_act: Callable = Identity,
    shard_layer_params: Callable = Identity,
):
    """Training forward -> logits (B, S, V)."""
    h = transformer_hidden(
        params, cfg, tokens, shard_act=shard_act, shard_layer_params=shard_layer_params
    )
    return dense(params["lm_head"], h)


def transformer_loss(
    params, cfg, tokens, labels, *, shard_act=Identity, shard_layer_params=Identity,
    ce_chunk: Optional[int] = None, shard_logits=None, shard_qkv=Identity,
):
    h = transformer_hidden(
        params, cfg, tokens, shard_act=shard_act,
        shard_layer_params=shard_layer_params, shard_qkv=shard_qkv,
    )
    if ce_chunk:
        from .layers import chunked_cross_entropy

        return chunked_cross_entropy(
            params["lm_head"], h, labels, chunk=ce_chunk, shard_logits=shard_logits
        )
    return cross_entropy_loss(dense(params["lm_head"], h), labels)


# ---------------------------------------------------------------------------
# decode (KV cache)
# ---------------------------------------------------------------------------


def make_cache(cfg: TransformerConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    n_stacked = cfg.n_layers - cfg.n_dense_layers
    if cfg.attention == "mla":
        m = cfg.mla
        cache = {
            "ckv": jnp.zeros((n_stacked, batch, max_len, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((n_stacked, batch, max_len, m.qk_rope_dim), dtype),
        }
        if cfg.n_dense_layers:
            cache["prefix_ckv"] = jnp.zeros((cfg.n_dense_layers, batch, max_len, m.kv_lora_rank), dtype)
            cache["prefix_krope"] = jnp.zeros((cfg.n_dense_layers, batch, max_len, m.qk_rope_dim), dtype)
        return cache
    shape = (n_stacked, batch, cfg.kv_heads, max_len, cfg.d_head)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if cfg.n_dense_layers:
        pshape = (cfg.n_dense_layers, batch, cfg.kv_heads, max_len, cfg.d_head)
        cache["prefix_k"] = jnp.zeros(pshape, dtype)
        cache["prefix_v"] = jnp.zeros(pshape, dtype)
    return cache


def _gqa_decode_layer(p, cfg, h, k_cache, v_cache, cur_len, window):
    """h (B,1,d); k/v_cache (B,Hkv,S,Dh)."""
    b = h.shape[0]
    x = rmsnorm(p["ln1"], h)
    q = dense(p["attn"]["wq"], x).reshape(b, 1, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = dense(p["attn"]["wk"], x).reshape(b, 1, cfg.kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = dense(p["attn"]["wv"], x).reshape(b, 1, cfg.kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    pos = jnp.full((b, 1), cur_len, jnp.int32)
    q = apply_rope(q, pos[:, None, :], cfg.rope_theta)
    k = apply_rope(k, pos[:, None, :], cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, 0, cur_len, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, 0, cur_len, 0))
    o = blockwise_attention(
        q, k_cache, v_cache, causal=True, window=window,
        q_offset=cur_len, kv_block=cfg.kv_block, valid_len=cur_len + 1,
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.attn_dim)
    h = h + dense(p["attn"]["wo"], o)
    x2 = rmsnorm(p["ln2"], h)
    if "moe" in p:
        y, _ = moe_apply(p["moe"], cfg.moe, x2.reshape(b, -1))
        y = y.reshape(b, 1, -1)
    else:
        y = swiglu(p["ffn"], x2)
    return h + y, k_cache, v_cache


def transformer_decode_step(
    params,
    cfg: TransformerConfig,
    token: jax.Array,    # (B, 1) int32
    cache,
    cur_len,             # scalar int32: number of tokens already cached
    *,
    shard_act: Callable = Identity,
):
    """One decode step -> (logits (B, V), updated cache)."""
    b = token.shape[0]
    h = shard_act(params["embed"].astype(cfg.dtype)[token])
    new_cache = dict(cache)

    windows = jnp.asarray(
        [
            (1 << 30) if cfg.layer_is_global(i + cfg.n_dense_layers) else cfg.window
            for i in range(cfg.n_layers - cfg.n_dense_layers)
        ],
        jnp.int32,
    )

    if cfg.attention == "mla":
        for i, p in enumerate(params.get("prefix_layers", [])):
            x = rmsnorm(p["ln1"], h)
            attn, ck, kr = mla_decode_step(
                p["attn"], cfg.mla, x, cache["prefix_ckv"][i], cache["prefix_krope"][i], cur_len
            )
            new_cache["prefix_ckv"] = cache["prefix_ckv"].at[i].set(ck)
            new_cache["prefix_krope"] = cache["prefix_krope"].at[i].set(kr)
            h = h + attn
            h = h + swiglu(p["ffn"], rmsnorm(p["ln2"], h))

        def body(h, xs):
            layer_p, ckv, krope, _w = xs
            x = rmsnorm(layer_p["ln1"], h)
            attn, ckv, krope = mla_decode_step(layer_p["attn"], cfg.mla, x, ckv, krope, cur_len)
            h = h + attn
            x2 = rmsnorm(layer_p["ln2"], h)
            if "moe" in layer_p:
                y, _ = moe_apply(layer_p["moe"], cfg.moe, x2.reshape(b, -1))
                y = y.reshape(b, 1, -1)
            else:
                y = swiglu(layer_p["ffn"], x2)
            return shard_act(h + y), (ckv, krope)

        h, (ckvs, kropes) = jax.lax.scan(
            body, h, (params["layers"], cache["ckv"], cache["krope"], windows)
        )
        new_cache["ckv"] = ckvs
        new_cache["krope"] = kropes
    else:
        for i, p in enumerate(params.get("prefix_layers", [])):
            h, kc, vc = _gqa_decode_layer(
                p, cfg, h, cache["prefix_k"][i], cache["prefix_v"][i], cur_len, None
            )
            new_cache["prefix_k"] = cache["prefix_k"].at[i].set(kc)
            new_cache["prefix_v"] = cache["prefix_v"].at[i].set(vc)

        def body(h, xs):
            layer_p, kc, vc, window = xs
            h, kc, vc = _gqa_decode_layer(layer_p, cfg, h, kc, vc, cur_len, window)
            return shard_act(h), (kc, vc)

        h, (kcs, vcs) = jax.lax.scan(
            body, h, (params["layers"], cache["k"], cache["v"], windows)
        )
        new_cache["k"] = kcs
        new_cache["v"] = vcs

    h = rmsnorm(params["ln_f"], h)
    logits = dense(params["lm_head"], h)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# windowed decode (beyond-paper §Perf optimization for hybrid local/global)
# ---------------------------------------------------------------------------


def _hybrid_blocks(cfg: TransformerConfig):
    """(n_blocks, per_block, n_suffix): the local:global repeat pattern.
    gemma3: 62 layers @ global_every=6 -> 10 blocks of (5 local + 1
    global) + 2 suffix local layers."""
    ge = cfg.global_every
    n_blocks = cfg.n_layers // ge
    n_suffix = cfg.n_layers - n_blocks * ge
    return n_blocks, ge, n_suffix


def make_cache_windowed(cfg: TransformerConfig, batch: int, max_len: int, dtype=None):
    """Heterogeneous caches for hybrid local/global decode: local layers
    get rolling ring buffers of the window size; only the global layers
    carry the full sequence.  For gemma3-27b @ 500k decode this is a ~6x
    KV-residency reduction (52 of 62 layers hold 1024 slots).  Stacked
    by block so the decode step scans (weight copies stay loop-local —
    unrolling 62 layers let XLA hoist 62 fp32 weight converts = 26 GiB)."""
    dtype = dtype or cfg.dtype
    assert cfg.attention == "gqa" and cfg.window is not None
    nb, ge, ns = _hybrid_blocks(cfg)
    w = min(cfg.window, max_len)
    h, d = cfg.kv_heads, cfg.d_head
    return {
        "loc_k": jnp.zeros((nb, ge - 1, batch, h, w, d), dtype),
        "loc_v": jnp.zeros((nb, ge - 1, batch, h, w, d), dtype),
        "glob_k": jnp.zeros((nb, batch, h, max_len, d), dtype),
        "glob_v": jnp.zeros((nb, batch, h, max_len, d), dtype),
        "suf_k": jnp.zeros((ns, batch, h, w, d), dtype),
        "suf_v": jnp.zeros((ns, batch, h, w, d), dtype),
    }


def _grouped_decode_attention(q, kc, vc, mask):
    """Dense single-query attention WITHOUT the GQA jnp.repeat expansion
    or the blockwise restack: grouped einsum reads the cache in place
    (one pass of the KV — the optimal decode traffic).

    q (B, Hq, 1, D); kc/vc (B, Hkv, S, D); mask (S,) bool."""
    b, hq, _, d = q.shape
    hkv, s = kc.shape[1], kc.shape[2]
    rep = hq // hkv
    qg = q.reshape(b, hkv, rep, d)
    scores = jnp.einsum(
        "bgrd,bgsd->bgrs", qg.astype(jnp.float32), kc.astype(jnp.float32)
    ) / math.sqrt(d)
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bgrs,bgsd->bgrd", probs, vc.astype(jnp.float32))
    return o.reshape(b, hq, 1, d).astype(q.dtype)


def _windowed_decode_layer(p, cfg: TransformerConfig, h, kc, vc, cur_len, is_global):
    """One decode layer against a full (global) or ring-buffer (local)
    cache.  Ring buffer: position t lives in slot t % W; RoPE is applied
    at write time so stored keys carry absolute positions."""
    b = h.shape[0]
    s_cache = kc.shape[2]
    x = rmsnorm(p["ln1"], h)
    q = dense(p["attn"]["wq"], x).reshape(b, 1, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = dense(p["attn"]["wk"], x).reshape(b, 1, cfg.kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = dense(p["attn"]["wv"], x).reshape(b, 1, cfg.kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    pos = jnp.full((b, 1), cur_len, jnp.int32)
    q = apply_rope(q, pos[:, None, :], cfg.rope_theta)
    k = apply_rope(k, pos[:, None, :], cfg.rope_theta)

    if is_global:
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, 0, cur_len, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, 0, cur_len, 0))
        mask = jnp.arange(s_cache) <= cur_len
    else:
        w = s_cache
        slot = cur_len % w
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, 0, slot, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, 0, slot, 0))
        slot_pos = cur_len - jnp.mod(cur_len - jnp.arange(w), w)
        mask = slot_pos >= 0
    o = _grouped_decode_attention(q, kc, vc, mask)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.attn_dim)
    h = h + dense(p["attn"]["wo"], o)
    x2 = rmsnorm(p["ln2"], h)
    if "moe" in p:
        y, _ = moe_apply(p["moe"], cfg.moe, x2.reshape(b, -1))
        y = y.reshape(b, 1, -1)
    else:
        y = swiglu(p["ffn"], x2)
    return h + y, kc, vc


def transformer_decode_step_windowed(
    params, cfg: TransformerConfig, token, cache, cur_len,
    *, shard_act: Callable = Identity,
):
    """Block-scan decode over heterogeneous caches: scan over the
    (local^(ge-1), global) repeat blocks so per-layer weight converts
    stay loop-local (unrolled layers let XLA hoist them all — measured
    26 GiB of fp32 weight copies on gemma3 @ 62 layers), then the local
    suffix.  Output matches transformer_decode_step exactly."""
    b = token.shape[0]
    nb, ge, ns = _hybrid_blocks(cfg)
    h = shard_act(params["embed"].astype(cfg.dtype)[token])
    assert not params.get("prefix_layers"), "hybrid decode assumes uniform stack"

    blocks = jax.tree_util.tree_map(
        lambda x: x[: nb * ge].reshape(nb, ge, *x.shape[1:]), params["layers"]
    )
    suffix = jax.tree_util.tree_map(lambda x: x[nb * ge :], params["layers"])

    def body(h, xs):
        bp, lk, lv, gk, gv = xs
        for j in range(ge - 1):
            lp = jax.tree_util.tree_map(lambda x: x[j], bp)
            h, lkj, lvj = _windowed_decode_layer(
                lp, cfg, h, lk[j], lv[j], cur_len, is_global=False
            )
            lk = lk.at[j].set(lkj)
            lv = lv.at[j].set(lvj)
            h = shard_act(h)
        gp = jax.tree_util.tree_map(lambda x: x[ge - 1], bp)
        h, gk, gv = _windowed_decode_layer(gp, cfg, h, gk, gv, cur_len, is_global=True)
        h = shard_act(h)
        return h, (lk, lv, gk, gv)

    h, (lk, lv, gk, gv) = jax.lax.scan(
        body, h, (blocks, cache["loc_k"], cache["loc_v"], cache["glob_k"], cache["glob_v"]),
    )
    new_cache = {"loc_k": lk, "loc_v": lv, "glob_k": gk, "glob_v": gv}

    sk, sv = [], []
    for i in range(ns):
        sp = jax.tree_util.tree_map(lambda x: x[i], suffix)
        h, ki, vi = _windowed_decode_layer(
            sp, cfg, h, cache["suf_k"][i], cache["suf_v"][i], cur_len, is_global=False
        )
        sk.append(ki)
        sv.append(vi)
    new_cache["suf_k"] = jnp.stack(sk) if sk else cache["suf_k"]
    new_cache["suf_v"] = jnp.stack(sv) if sv else cache["suf_v"]

    h = rmsnorm(params["ln_f"], h)
    logits = dense(params["lm_head"], h)[:, 0]
    return logits, new_cache


def transformer_prefill(
    params, cfg: TransformerConfig, tokens: jax.Array, *,
    shard_act: Callable = Identity, shard_layer_params: Callable = Identity,
):
    """Prefill: full-seq forward returning last-position logits.

    (Cache extraction for subsequent decode reuses the training forward's
    per-layer K/V — for the dry-run shapes the artifact of record is the
    full-seq compute; serve_step owns the incremental path.)
    """
    h = transformer_hidden(
        params, cfg, tokens, shard_act=shard_act,
        shard_layer_params=shard_layer_params,
    )
    return dense(params["lm_head"], h[:, -1])
