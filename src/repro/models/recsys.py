"""RecSys ranking models: DeepFM, AutoInt, DIEN (GRU+AUGRU), BST.

Common substrate: per-field embedding lookup over huge row-sharded
tables (``jnp.take`` — JAX has no nn.EmbeddingBag; the multi-hot variant
lives in ``repro.kernels.embedding_bag``), feature-interaction ops (FM /
self-attention / attention-GRU / transformer block), small MLP towers,
sigmoid CTR head.  ``retrieval_cand`` scoring is one batched dot against
10^6 candidate embeddings — matmul, not a loop.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .layers import dense, dense_init, layernorm, layernorm_init, mlp_apply, mlp_init


# ---------------------------------------------------------------------------
# embedding substrate
# ---------------------------------------------------------------------------


def embedding_tables_init(key, vocab_sizes: Sequence[int], dim: int, dtype=jnp.float32):
    """One (V_f, dim) table per sparse field."""
    tables = []
    for v in vocab_sizes:
        key, sub = jax.random.split(key)
        tables.append((jax.random.normal(sub, (v, dim), jnp.float32) * 0.01).astype(dtype))
    return tables


def lookup_fields(tables, ids: jax.Array) -> jax.Array:
    """ids (B, F) -> (B, F, dim)."""
    cols = [jnp.take(t, ids[:, f], axis=0) for f, t in enumerate(tables)]
    return jnp.stack(cols, axis=1)


def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    z = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z))))


# ---------------------------------------------------------------------------
# DeepFM (Guo et al. 2017): FM interaction + deep tower, shared embeddings
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DeepFMConfig:
    vocab_sizes: Tuple[int, ...]
    embed_dim: int = 10
    mlp_dims: Tuple[int, ...] = (400, 400, 400)
    dtype: object = jnp.float32

    @property
    def n_fields(self):
        return len(self.vocab_sizes)


def deepfm_init(key, cfg: DeepFMConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    first_order = []
    for v in cfg.vocab_sizes:
        k2, sub = jax.random.split(k2)
        first_order.append((jax.random.normal(sub, (v, 1), jnp.float32) * 0.01).astype(cfg.dtype))
    return {
        "tables": embedding_tables_init(k1, cfg.vocab_sizes, cfg.embed_dim, cfg.dtype),
        "first_order": first_order,
        "mlp": mlp_init(k3, [cfg.n_fields * cfg.embed_dim, *cfg.mlp_dims, 1], cfg.dtype),
        "bias": jnp.zeros((), jnp.float32),
    }


def deepfm_forward(params, cfg: DeepFMConfig, ids: jax.Array) -> jax.Array:
    """ids (B, F) -> CTR logits (B,)."""
    emb = lookup_fields(params["tables"], ids)                     # (B, F, D)
    # FM second order: 0.5 * ((sum_f v)^2 - sum_f v^2)
    s = emb.sum(axis=1)
    fm2 = 0.5 * (jnp.square(s) - jnp.square(emb).sum(axis=1)).sum(axis=-1)
    fm1 = jnp.concatenate(
        [jnp.take(t, ids[:, f], axis=0) for f, t in enumerate(params["first_order"])],
        axis=1,
    ).sum(axis=1)
    deep = mlp_apply(params["mlp"], emb.reshape(emb.shape[0], -1))[:, 0]
    return (fm1 + fm2 + deep).astype(jnp.float32) + params["bias"]


# ---------------------------------------------------------------------------
# AutoInt (Song et al. 2019): multi-head self-attention over field embeds
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AutoIntConfig:
    vocab_sizes: Tuple[int, ...]
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    dtype: object = jnp.float32

    @property
    def n_fields(self):
        return len(self.vocab_sizes)


def autoint_init(key, cfg: AutoIntConfig):
    k1, key = jax.random.split(key)
    layers = []
    d = cfg.embed_dim
    for _ in range(cfg.n_attn_layers):
        key, kq, kk, kv, kr = jax.random.split(key, 5)
        layers.append(
            {
                "wq": dense_init(kq, d, cfg.n_heads * cfg.d_attn, cfg.dtype),
                "wk": dense_init(kk, d, cfg.n_heads * cfg.d_attn, cfg.dtype),
                "wv": dense_init(kv, d, cfg.n_heads * cfg.d_attn, cfg.dtype),
                "wres": dense_init(kr, d, cfg.n_heads * cfg.d_attn, cfg.dtype),
            }
        )
        d = cfg.n_heads * cfg.d_attn
    key, kh = jax.random.split(key)
    return {
        "tables": embedding_tables_init(k1, cfg.vocab_sizes, cfg.embed_dim, cfg.dtype),
        "attn_layers": layers,
        "head": dense_init(kh, cfg.n_fields * d, 1, cfg.dtype),
    }


def autoint_forward(params, cfg: AutoIntConfig, ids: jax.Array) -> jax.Array:
    x = lookup_fields(params["tables"], ids)                        # (B, F, D)
    b, f, _ = x.shape
    for p in params["attn_layers"]:
        q = dense(p["wq"], x).reshape(b, f, cfg.n_heads, cfg.d_attn)
        k = dense(p["wk"], x).reshape(b, f, cfg.n_heads, cfg.d_attn)
        v = dense(p["wv"], x).reshape(b, f, cfg.n_heads, cfg.d_attn)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
        attn = jax.nn.softmax(logits / math.sqrt(cfg.d_attn), axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", attn, v.astype(jnp.float32))
        o = o.reshape(b, f, cfg.n_heads * cfg.d_attn).astype(x.dtype)
        x = jax.nn.relu(o + dense(p["wres"], x))
    return dense(params["head"], x.reshape(b, -1))[:, 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# DIEN (Zhou et al. 2018): interest extraction GRU + AUGRU evolution
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DIENConfig:
    item_vocab: int
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: Tuple[int, ...] = (200, 80)
    dtype: object = jnp.float32


def _gru_init(key, d_in, d_h, dtype):
    ks = jax.random.split(key, 3)
    def gate(k):
        k1, k2 = jax.random.split(k)
        return {
            "wx": dense_init(k1, d_in, d_h, dtype),
            "wh": dense_init(k2, d_h, d_h, dtype),
            "b": jnp.zeros((d_h,), dtype),
        }
    return {"update": gate(ks[0]), "reset": gate(ks[1]), "cand": gate(ks[2])}


def _gru_cell(p, h, x, att=None):
    def gate(g, hh):
        return x @ g["wx"].astype(x.dtype) + hh @ g["wh"].astype(x.dtype) + g["b"].astype(x.dtype)

    z = jax.nn.sigmoid(gate(p["update"], h).astype(jnp.float32))
    r = jax.nn.sigmoid(gate(p["reset"], h).astype(jnp.float32))
    hc = jnp.tanh(gate(p["cand"], (r.astype(h.dtype) * h)).astype(jnp.float32))
    if att is not None:  # AUGRU: attention scales the update gate
        z = z * att[:, None]
    out = (1 - z) * h.astype(jnp.float32) + z * hc
    return out.astype(h.dtype)


def dien_init(key, cfg: DIENConfig):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d_concat = cfg.gru_dim + cfg.embed_dim  # final interest + target embed
    return {
        "item_table": (jax.random.normal(k1, (cfg.item_vocab, cfg.embed_dim), jnp.float32) * 0.01).astype(cfg.dtype),
        "gru1": _gru_init(k2, cfg.embed_dim, cfg.gru_dim, cfg.dtype),
        "augru": _gru_init(k3, cfg.gru_dim, cfg.gru_dim, cfg.dtype),
        "att_w": dense_init(k4, cfg.gru_dim, cfg.gru_dim, cfg.dtype),
        "mlp": mlp_init(k5, [d_concat, *cfg.mlp_dims, 1], cfg.dtype),
    }


def dien_forward(params, cfg: DIENConfig, hist: jax.Array, target: jax.Array) -> jax.Array:
    """hist (B, L) item ids; target (B,) item ids -> CTR logits (B,)."""
    b, l = hist.shape
    emb = jnp.take(params["item_table"], hist, axis=0)               # (B, L, D)
    tgt = jnp.take(params["item_table"], target, axis=0)             # (B, D)

    # interest extraction GRU over the behavior sequence
    def step1(h, x_t):
        h = _gru_cell(params["gru1"], h, x_t)
        return h, h

    h0 = jnp.zeros((b, cfg.gru_dim), cfg.dtype)
    _, interests = jax.lax.scan(step1, h0, emb.transpose(1, 0, 2))   # (L, B, G)

    # attention of target on each interest state (for AUGRU update gates)
    tgt_proj = jnp.pad(tgt, ((0, 0), (0, cfg.gru_dim - cfg.embed_dim)))
    att_logits = jnp.einsum(
        "lbg,bg->lb",
        dense(params["att_w"], interests).astype(jnp.float32),
        tgt_proj.astype(jnp.float32),
    ) / math.sqrt(cfg.gru_dim)
    att = jax.nn.softmax(att_logits, axis=0)                          # (L, B)

    # interest evolution AUGRU
    def step2(h, xs):
        x_t, a_t = xs
        h = _gru_cell(params["augru"], h, x_t, att=a_t)
        return h, None

    h_final, _ = jax.lax.scan(step2, h0, (interests, att))            # (B, G)
    feat = jnp.concatenate([h_final, tgt], axis=-1)
    return mlp_apply(params["mlp"], feat)[:, 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# BST (Chen et al. 2019): transformer block over the behavior sequence
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BSTConfig:
    item_vocab: int
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    mlp_dims: Tuple[int, ...] = (1024, 512, 256)
    dtype: object = jnp.float32


def bst_init(key, cfg: BSTConfig):
    k1, k2, key = jax.random.split(key, 3)
    d = cfg.embed_dim
    blocks = []
    for _ in range(cfg.n_blocks):
        key, kq, kk, kv, ko, kf1, kf2 = jax.random.split(key, 7)
        blocks.append(
            {
                "wq": dense_init(kq, d, d, cfg.dtype),
                "wk": dense_init(kk, d, d, cfg.dtype),
                "wv": dense_init(kv, d, d, cfg.dtype),
                "wo": dense_init(ko, d, d, cfg.dtype),
                "ln1": layernorm_init(d, cfg.dtype),
                "ln2": layernorm_init(d, cfg.dtype),
                "ff1": dense_init(kf1, d, 4 * d, cfg.dtype),
                "ff2": dense_init(kf2, 4 * d, d, cfg.dtype),
            }
        )
    key, kh = jax.random.split(key)
    seq_total = cfg.seq_len + 1  # behavior seq + target item
    return {
        "item_table": (jax.random.normal(k1, (cfg.item_vocab, d), jnp.float32) * 0.01).astype(cfg.dtype),
        "pos_table": (jax.random.normal(k2, (seq_total, d), jnp.float32) * 0.01).astype(cfg.dtype),
        "blocks": blocks,
        "mlp": mlp_init(kh, [seq_total * d, *cfg.mlp_dims, 1], cfg.dtype),
    }


def bst_forward(params, cfg: BSTConfig, hist: jax.Array, target: jax.Array) -> jax.Array:
    b, l = hist.shape
    seq = jnp.concatenate([hist, target[:, None]], axis=1)           # (B, L+1)
    x = jnp.take(params["item_table"], seq, axis=0) + params["pos_table"][None]
    d, h = cfg.embed_dim, cfg.n_heads
    dh = d // h
    for p in params["blocks"]:
        xn = layernorm(p["ln1"], x)
        q = dense(p["wq"], xn).reshape(b, l + 1, h, dh)
        k = dense(p["wk"], xn).reshape(b, l + 1, h, dh)
        v = dense(p["wv"], xn).reshape(b, l + 1, h, dh)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
        attn = jax.nn.softmax(logits / math.sqrt(dh), axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", attn, v.astype(jnp.float32)).reshape(b, l + 1, d)
        x = x + dense(p["wo"], o.astype(x.dtype))
        xn = layernorm(p["ln2"], x)
        x = x + dense(p["ff2"], jax.nn.leaky_relu(dense(p["ff1"], xn).astype(jnp.float32)).astype(x.dtype))
    return mlp_apply(params["mlp"], x.reshape(b, -1))[:, 0].astype(jnp.float32)


# ---------------------------------------------------------------------------
# retrieval scoring (shared): one query tower output vs 1M candidates
# ---------------------------------------------------------------------------


def retrieval_scores(query_emb: jax.Array, candidates: jax.Array) -> jax.Array:
    """(B, D) x (N, D) -> (B, N) dot scores — batched matmul, not a loop."""
    return query_emb.astype(jnp.float32) @ candidates.astype(jnp.float32).T


def deepfm_user_embedding(params, cfg: DeepFMConfig, ids: jax.Array) -> jax.Array:
    """User tower for retrieval: pooled field embeddings (B, embed_dim)."""
    return lookup_fields(params["tables"], ids).sum(axis=1)


def autoint_user_embedding(params, cfg: AutoIntConfig, ids: jax.Array) -> jax.Array:
    emb = lookup_fields(params["tables"], ids)
    return emb.mean(axis=1)


def dien_user_embedding(params, cfg: DIENConfig, hist: jax.Array) -> jax.Array:
    """Final interest state truncated to embed_dim (item-embedding space)."""
    b, l = hist.shape
    emb = jnp.take(params["item_table"], hist, axis=0)

    def step(h, x_t):
        h = _gru_cell(params["gru1"], h, x_t)
        return h, None

    h0 = jnp.zeros((b, cfg.gru_dim), cfg.dtype)
    h_final, _ = jax.lax.scan(step, h0, emb.transpose(1, 0, 2))
    return h_final[:, : cfg.embed_dim]


def bst_user_embedding(params, cfg: BSTConfig, hist: jax.Array) -> jax.Array:
    """Mean-pooled behavior-sequence embedding (B, embed_dim)."""
    x = jnp.take(params["item_table"], hist, axis=0)
    return x.mean(axis=1)
