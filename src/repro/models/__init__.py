"""Architecture zoo: pure-pytree JAX modules (init_fn + apply_fn pairs).

No flax/haiku in the environment — params are nested dicts, every init
is a pure function of a PRNG key (so ``jax.eval_shape`` builds abstract
params for the multi-pod dry-run without materializing 100B+ weights).
"""
