"""``repro.obs`` — zero-dep observability: tracing spans, a metrics
registry, recompile accounting, and structured logging.

Everything is off by default and costs one branch per instrumented
site.  Turn it on explicitly::

    from repro import obs
    obs.enable()                      # trace + metrics
    obs.enable(trace=False)           # metrics only
    ... run ...
    obs.export_chrome_trace("laf_trace.json")   # open in Perfetto
    print(obs.metrics.to_json())

or via the environment — ``REPRO_OBS=1`` enables both at import time
(``REPRO_OBS=trace`` / ``REPRO_OBS=metrics`` select one); tier-1 runs
under ``REPRO_OBS=1`` in CI to catch instrumentation breaking the hot
path.

Recompile accounting rides two complementary sources:

* a global ``jax.monitoring`` listener counts every
  ``backend_compile`` event into ``jax.compile.events`` /
  ``jax.compile.seconds`` (registered once, on first ``enable``);
* :class:`RecompileWatcher` tracks a *specific* jitted callable's
  executable-cache size across calls — the per-sweep-signature counter
  the sweep engine and the serving bucket path use, precise where the
  global listener is process-wide.
"""

from __future__ import annotations

import os
from typing import Optional

from . import device as device_telemetry
from . import metrics
from . import slo
from .device import device_enabled, disable_device, enable_device
from .log import configure as configure_logging
from .log import get_logger, log_event, rate_limited_warn
from .trace import (
    SpanRecord,
    clear as clear_trace,
    coverage,
    export_chrome_trace,
    span,
    spans,
)
from .trace import _state as _trace_state

__all__ = [
    "enable",
    "disable",
    "trace_enabled",
    "metrics_enabled",
    "device_telemetry",
    "device_enabled",
    "enable_device",
    "disable_device",
    "span",
    "spans",
    "clear_trace",
    "coverage",
    "export_chrome_trace",
    "SpanRecord",
    "metrics",
    "slo",
    "get_logger",
    "log_event",
    "rate_limited_warn",
    "configure_logging",
    "RecompileWatcher",
    "watch_recompiles",
    "PAIRED_COUNTERS",
]

# counters that must move in lockstep over a steady-query-shape
# workload: each new left-counter signature must be explained by one
# right-counter event (PR 6's "recompiles pair 1:1 with capacity
# doublings" contract).  tests/test_obs.py pins this dynamically;
# repro.analysis's jaxpr-recompile-lattice check re-probes it as part
# of the static-analysis gate.  Add a pair here and both enforcers
# pick it up.
PAIRED_COUNTERS = (
    ("sweep.recompiles", "index.capacity_doublings"),
)

_monitor_registered = False


def _register_jax_monitor() -> None:
    """Count every XLA backend compile into the registry (idempotent).

    jax.monitoring has no deregistration API, so the listener is
    installed once per process and filters on the metrics switch
    itself — with metrics off the counters silently drop the event.
    """
    global _monitor_registered
    if _monitor_registered:
        return
    try:
        import jax.monitoring as jmon
    except ImportError:  # pragma: no cover
        return
    compiles = metrics.counter(
        "jax.compile.events", "XLA backend_compile events (process-wide)"
    )
    seconds = metrics.counter(
        "jax.compile.ms", "cumulative XLA backend compile time (ms)"
    )

    def _on_duration(event: str, duration_secs: float, **kw) -> None:
        if event.endswith("backend_compile_duration"):
            compiles.inc()
            seconds.inc(int(duration_secs * 1e3))

    jmon.register_event_duration_secs_listener(_on_duration)
    _monitor_registered = True


def enable(
    trace: bool = True,
    metrics_on: Optional[bool] = None,
    *,
    jax_annotations: bool = False,
    telemetry: Optional[bool] = None,
) -> None:
    """Turn observability on.

    ``trace`` — record spans + allow Chrome/Perfetto export;
    ``metrics_on`` (default: same as ``trace``... both on when called
    bare) — counters/gauges/histograms record; ``jax_annotations`` —
    additionally wrap every span in ``jax.profiler.TraceAnnotation`` so
    span names land inside XLA profiler captures; ``telemetry`` —
    device-resident in-launch counters (per-round cluster vectors,
    per-chunk sweep occupancy) riding the fused loop carries, harvested
    at the existing single ``device_get``.  ``telemetry=None`` leaves
    the device switch as-is (so a bare re-``enable()`` never toggles
    compiled program shapes under a caller's feet).
    """
    if metrics_on is None:
        metrics_on = True
    _trace_state.trace = bool(trace)
    _trace_state.jax_annotations = bool(jax_annotations)
    if metrics_on:
        metrics.enable()
        _register_jax_monitor()
    else:
        metrics.disable()
    if telemetry is not None:
        (enable_device if telemetry else disable_device)()


def disable() -> None:
    _trace_state.trace = False
    _trace_state.jax_annotations = False
    metrics.disable()
    disable_device()


def trace_enabled() -> bool:
    return _trace_state.trace


def metrics_enabled() -> bool:
    return metrics.enabled()


def enable_from_env(environ=None) -> bool:
    """Apply the ``REPRO_OBS`` knob; returns whether anything enabled.

    ``1``/``true``/``both`` — trace + metrics; ``trace`` / ``metrics``
    — just that half; ``device`` — trace + metrics + device-resident
    telemetry (the in-launch counters); unset/``0`` — leave everything
    off.
    """
    val = (environ if environ is not None else os.environ).get("REPRO_OBS", "")
    val = val.strip().lower()
    if val in ("1", "true", "yes", "on", "both", "all"):
        enable(trace=True, metrics_on=True)
    elif val == "trace":
        enable(trace=True, metrics_on=False)
    elif val == "metrics":
        enable(trace=False, metrics_on=True)
    elif val == "device":
        enable(trace=True, metrics_on=True, telemetry=True)
    else:
        return False
    return True


class RecompileWatcher:
    """Cache-miss-based recompile counter for one jitted callable.

    ``jax.jit`` products expose their executable-cache size; a growth
    across a call means that call compiled a new (shape, static-args)
    signature.  ``delta()`` reads-and-latches, incrementing ``counter``
    by the growth — wrap the call site::

        w = watch_recompiles(_counts_launch, "sweep.recompiles")
        out = _counts_launch(...)
        w.delta()            # 1 on a fresh signature, 0 on a cache hit

    Precision beats the process-wide ``jax.monitoring`` counter:
    this attributes compiles to *this* function, which is what "the
    sweep engine compiles once per capacity doubling" asserts.
    """

    __slots__ = ("fns", "_counter", "_last")

    def __init__(self, fns, counter_name: str):
        self.fns = tuple(fns) if isinstance(fns, (tuple, list)) else (fns,)
        self._counter = metrics.counter(counter_name)
        self._last = self._size()

    def _size(self) -> int:
        total = 0
        for f in self.fns:
            try:
                total += f._cache_size()
            except Exception:
                pass
        return total

    def delta(self) -> int:
        """New signatures compiled since the previous ``delta()``."""
        size = self._size()
        d = max(size - self._last, 0)
        self._last = size
        if d:
            self._counter.inc(d)
        return d


_watchers = {}


def watch_recompiles(fn, counter_name: str) -> RecompileWatcher:
    """Get-or-create the watcher for (fn, counter) — call sites in hot
    loops reuse one watcher instead of re-reading the baseline."""
    key = (id(fn) if not isinstance(fn, (tuple, list)) else tuple(id(f) for f in fn),
           counter_name)
    w = _watchers.get(key)
    if w is None:
        w = _watchers[key] = RecompileWatcher(fn, counter_name)
    return w


# the env knob: REPRO_OBS=1 in the environment enables at import time
enable_from_env()
