"""Tracing spans: nestable, thread-safe, Chrome/Perfetto-exportable.

The repro's whole claim is an efficiency trade (skip range queries via
the learned estimator, pay it back in post-processing), so a run must
be attributable phase by phase: estimator predict vs. sweep vs.
unpack vs. union-find vs. host sync.  ``span("sweep.launch", **attrs)``
brackets one phase:

* wall time comes from ``perf_counter`` pairs;
* **device work is synced before the span closes** when the caller
  hands the span its output pytree (``sync=``) — JAX dispatch is
  asynchronous, so an unsynced bracket measures *dispatch*, not
  execution.  The span records both: ``dispatch_s`` (time to the sync
  point) and ``dur`` (wall including the ``block_until_ready``), so
  the host-sync cost ROADMAP item 1 is about shows up as the
  difference;
* spans nest through a thread-local stack (each record carries its
  parent id), and the buffer is guarded by one lock so engines that
  thread their sweeps stay safe;
* ``export_chrome_trace()`` emits the ``trace_event`` JSON that Chrome
  ``about:tracing`` and Perfetto load directly; an optional passthrough
  wraps every span in ``jax.profiler.TraceAnnotation`` so the same
  names land inside XLA profiler captures.

Everything is **off by default**: with tracing disabled, ``span()``
returns a shared no-op context manager (one dict lookup + one branch),
so tier-1 timing-sensitive paths are untouched.  ``force=True`` makes
a span measure (but not record) even while tracing is off — what the
benchmark ``timed()`` helper rides so benches always get synced wall
times whether or not a trace is being collected.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

__all__ = [
    "span",
    "spans",
    "clear",
    "export_chrome_trace",
    "coverage",
    "SpanRecord",
]

_lock = threading.Lock()
_records: List["SpanRecord"] = []
_ids = itertools.count(1)
_tls = threading.local()

# epoch anchor so perf_counter timestamps are comparable across export
_T0_PERF = time.perf_counter()
_T0_EPOCH = time.time()


class _State:
    trace: bool = False
    jax_annotations: bool = False


_state = _State()


@dataclass
class SpanRecord:
    """One closed span.  Times are seconds on the perf_counter clock,
    relative to the module's epoch anchor."""

    name: str
    t0: float
    dur: float = 0.0
    dispatch_s: Optional[float] = None  # time to the sync point (dur - wait)
    span_id: int = 0
    parent_id: int = 0
    tid: int = 0
    attrs: Dict[str, Any] = field(default_factory=dict)


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


class _NullSpan:
    """Disabled-tracing fast path: no timing, no allocation per call."""

    __slots__ = ()
    dur = 0.0
    dispatch_s = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def sync_on(self, out):
        return self


_NULL = _NullSpan()


class Span:
    """Active span handle (context manager).  ``.dur`` is valid after
    exit; ``.set(**attrs)`` adds attributes mid-flight."""

    __slots__ = ("name", "attrs", "_sync", "_record", "_t0", "_rec", "_ann")

    def __init__(self, name: str, sync=None, attrs=None, record: bool = True):
        self.name = name
        self.attrs = dict(attrs or {})
        self._sync = sync
        self._record = record
        self._rec: Optional[SpanRecord] = None
        self._ann = None

    @property
    def dur(self) -> float:
        return self._rec.dur if self._rec is not None else 0.0

    @property
    def dispatch_s(self) -> Optional[float]:
        return self._rec.dispatch_s if self._rec is not None else None

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def sync_on(self, out) -> "Span":
        """Arrange for ``out`` (any pytree; jax leaves are blocked on)
        to be synced at span exit."""
        self._sync = out
        return self

    def __enter__(self) -> "Span":
        rec = SpanRecord(
            self.name, 0.0, span_id=next(_ids),
            tid=threading.get_ident(),
        )
        st = _stack()
        rec.parent_id = st[-1].span_id if st else 0
        st.append(rec)
        self._rec = rec
        if _state.jax_annotations:
            try:
                from jax.profiler import TraceAnnotation

                self._ann = TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:  # profiler unavailable: spans still work
                self._ann = None
        rec.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        rec = self._rec
        if self._sync is not None:
            rec.dispatch_s = time.perf_counter() - rec.t0
            _block(self._sync)
        rec.dur = time.perf_counter() - rec.t0
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
        st = _stack()
        if st and st[-1] is rec:
            st.pop()
        else:  # tolerate mis-nested exits rather than corrupt the stack
            try:
                st.remove(rec)
            except ValueError:
                pass
        rec.attrs = self.attrs
        if self._record:
            if exc_type is not None:
                rec.attrs = dict(rec.attrs, error=exc_type.__name__)
            with _lock:
                _records.append(rec)
        return False


def _block(out) -> None:
    """block_until_ready over any pytree; numpy/python leaves pass
    through untouched (jax.block_until_ready handles both)."""
    try:
        import jax

        jax.block_until_ready(out)
    except ImportError:  # pragma: no cover - jax is a hard dep in-repo
        pass


def span(name: str, *, sync=None, force: bool = False, **attrs):
    """Context manager bracketing one phase.

    ``sync=`` — a pytree whose jax leaves are ``block_until_ready``'d
    before the span closes (measure execution, not dispatch); the
    pre-sync time is recorded as ``dispatch_s``.  ``force=True``
    measures even when tracing is disabled (without appending to the
    buffer) so callers can read ``.dur`` — the benchmark path.
    """
    if not _state.trace and not force:
        return _NULL
    return Span(name, sync=sync, attrs=attrs, record=_state.trace)


def spans(name: Optional[str] = None) -> List[SpanRecord]:
    """Closed spans recorded so far (optionally filtered by name)."""
    with _lock:
        out = list(_records)
    if name is not None:
        out = [r for r in out if r.name == name]
    return out


def clear() -> None:
    with _lock:
        _records.clear()


def coverage(root: SpanRecord, records: Optional[List[SpanRecord]] = None) -> float:
    """Fraction of ``root``'s wall time covered by the union of its
    direct children's intervals — the acceptance metric for "the trace
    accounts for the run" (uninstrumented gaps pull it below 1)."""
    if root.dur <= 0:
        return 0.0
    records = spans() if records is None else records
    ivals = sorted(
        (r.t0, r.t0 + r.dur) for r in records if r.parent_id == root.span_id
    )
    covered, cur_s, cur_e = 0.0, None, None
    for s, e in ivals:
        s, e = max(s, root.t0), min(e, root.t0 + root.dur)
        if e <= s:
            continue
        if cur_e is None or s > cur_e:
            if cur_e is not None:
                covered += cur_e - cur_s
            cur_s, cur_e = s, e
        else:
            cur_e = max(cur_e, e)
    if cur_e is not None:
        covered += cur_e - cur_s
    return covered / root.dur


def export_chrome_trace(path: Optional[str] = None) -> dict:
    """Chrome/Perfetto ``trace_event`` JSON of every recorded span.

    Complete ("X") events, microsecond timestamps on a common epoch
    base; span attributes ride in ``args``.  Load the file straight
    into https://ui.perfetto.dev or ``chrome://tracing``.  Returns the
    dict (and writes it to ``path`` when given).
    """
    pid = os.getpid()
    events = []
    for r in spans():
        events.append(
            {
                "name": r.name,
                "cat": r.name.split(".", 1)[0],
                "ph": "X",
                "ts": (_T0_EPOCH + (r.t0 - _T0_PERF)) * 1e6,
                "dur": r.dur * 1e6,
                "pid": pid,
                "tid": r.tid % 2**31,
                "args": {
                    k: (v if isinstance(v, (int, float, bool, str)) else repr(v))
                    for k, v in dict(
                        r.attrs,
                        span_id=r.span_id,
                        parent_id=r.parent_id,
                        **(
                            {"dispatch_us": r.dispatch_s * 1e6}
                            if r.dispatch_s is not None
                            else {}
                        ),
                    ).items()
                },
            }
        )
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        from pathlib import Path

        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(doc))
    return doc
