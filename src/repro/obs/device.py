"""Device-resident telemetry: in-launch counters riding the fused loops.

PR 8 fused the entire clustering into one jitted ``lax.while_loop``
with exactly one ``device_get`` — which made the host-span layer
structurally blind inside the launch: a Perfetto trace shows one
opaque ``laf.label_prop`` interval where the per-round dynamics
(frontier collapse, pointer-jump savings, shard balance) actually
live.  This module restores that visibility without adding a single
host sync:

* a small **s32 telemetry pytree** rides the carry of every fused
  loop — per-round ``(max_iters,)`` vectors in
  ``packed_cluster_fixpoint`` (frontier size, labels changed,
  pointer-jump hops, psum'd shard gather wins), per-chunk
  ``[accept, band, reject]`` occupancy triples in the *count*-sweep
  engine's chunk loop (from the kernel's ``with_stats=`` counters —
  the bitmap sweep feeding the cluster pass skips them: same
  statistic, and interpret-mode stats ops would tax the hot path);
* the vectors are **harvested at the existing single** ``device_get``
  (the one-launch discipline is untouched — ``laf.cluster.device_get``
  stays 1 with telemetry on) and folded into the metrics registry;
* per-round values become **synthetic child spans** under the
  measured ``laf.label_prop`` interval, so Perfetto shows the round
  structure of the fused program and ``coverage()`` of the one-launch
  cluster pass stays attributable.

Everything is **off by default** (``_state.on``): with device
telemetry disabled the fused programs compile without the extra carry
slots and outputs — byte-identical to the PR 8 lowerings.  Enable via
``obs.enable(telemetry=True)`` or ``REPRO_OBS=device``.

The carry contract the laf-lint LAF107 check pins: telemetry carries
are s32/f32 **scalars or small fixed-size vectors** only — never
packed words (LAF106 territory), never O(n)-per-round matrices.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

from . import metrics as _metrics
from . import trace as _trace

__all__ = [
    "enable_device",
    "disable_device",
    "device_enabled",
    "MAX_ROUNDS",
    "SWEEP_STAT_FIELDS",
    "CLUSTER_ROUND_FIELDS",
    "cluster_telemetry_init",
    "cluster_telemetry_record",
    "sweep_stats_tile_sum",
    "harvest_cluster_telemetry",
    "harvest_sweep_telemetry",
    "emit_round_spans",
    "last_sweep_stats",
]

# default round budget of the cluster fixpoint (mirrors the
# ``max_iters=64`` default of ``packed_cluster_fixpoint``) — the
# telemetry vectors are sized to it, so they stay "small vectors"
# under the LAF107 carry contract regardless of n
MAX_ROUNDS = 64

SWEEP_STAT_FIELDS = ("accept", "band", "reject")
CLUSTER_ROUND_FIELDS = ("frontier", "changed", "hops", "shard_wins")


class _State:
    on: bool = False


_state = _State()
_lock = threading.Lock()
# last harvested per-chunk sweep occupancy (host ndarray (n_chunks, 3))
# — the bench/auto-tuner read side of the in-launch counters
_last_sweep_stats = None


def enable_device() -> None:
    _state.on = True


def disable_device() -> None:
    _state.on = False


def device_enabled() -> bool:
    return _state.on


# ---------------------------------------------------------------------------
# traced side: init + per-round record (called from inside fused loops)
# ---------------------------------------------------------------------------


def cluster_telemetry_init(max_iters: int = MAX_ROUNDS):
    """Fresh per-round telemetry pytree for one cluster fixpoint: a
    tuple of four ``(max_iters,)`` s32 vectors, one slot per round, in
    ``CLUSTER_ROUND_FIELDS`` order.  Lives in the ``while`` carry —
    s32 small vectors only (the LAF106/LAF107 carry contract)."""
    import jax.numpy as jnp

    return tuple(
        jnp.zeros((max_iters,), jnp.int32) for _ in CLUSTER_ROUND_FIELDS
    )


def cluster_telemetry_record(tele, it, frontier, changed, hops, shard_wins):
    """Write one round's scalars into slot ``it`` of each vector
    (traced; ``it`` is the loop counter riding the same carry)."""
    import jax
    import jax.numpy as jnp

    vals = (frontier, changed, hops, shard_wins)
    return tuple(
        jax.lax.dynamic_update_slice(
            vec, jnp.asarray(v, jnp.int32)[None], (it,)
        )
        for vec, v in zip(tele, vals)
    )


def sweep_stats_tile_sum(stats):
    """Reduce the kernel's raw ``(..., 3)`` occupancy output (a (1, 3)
    whole-call block since the in-kernel grid accumulation) to one
    ``(3,)`` s32 triple for the chunk (traced)."""
    import jax.numpy as jnp

    return stats.reshape(-1, 3).sum(axis=0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# host side: harvest at the single device_get, fold into metrics/spans
# ---------------------------------------------------------------------------


def harvest_cluster_telemetry(tele_host, rounds: int) -> Dict[str, List[int]]:
    """Fold fetched per-round vectors into the metrics registry.

    ``tele_host`` is the host-side tuple (the fixpoint's telemetry
    output after the caller's ``device_get`` — this function never
    syncs).  Returns ``{field: [per-round values]}`` trimmed to the
    executed ``rounds``; counters ``laf.telemetry.<field>`` accumulate
    the per-run totals.
    """
    rounds = int(rounds)
    out: Dict[str, List[int]] = {}
    for name, vec in zip(CLUSTER_ROUND_FIELDS, tele_host):
        vals = [int(v) for v in list(vec)[:rounds]]
        out[name] = vals
        _metrics.counter(f"laf.telemetry.{name}").inc(sum(vals))
    return out


def harvest_sweep_telemetry(stats_host) -> Optional[Dict[str, int]]:
    """Fold the fetched per-chunk ``(n_chunks, 3)`` occupancy slab
    into ``sweep.tele.{accept,band,reject}`` counters (raw kernel-grid
    values — pad tiles included, same convention as the auto-tuner's
    ``record_occupancy``).  Keeps the slab for :func:`last_sweep_stats`.
    """
    global _last_sweep_stats
    if stats_host is None:
        return None
    import numpy as np

    arr = np.asarray(stats_host)
    with _lock:
        _last_sweep_stats = arr
    totals = arr.sum(axis=0)
    out = {}
    for i, name in enumerate(SWEEP_STAT_FIELDS):
        out[name] = int(totals[i])
        _metrics.counter(f"sweep.tele.{name}").inc(int(totals[i]))
    return out


def last_sweep_stats():
    """Most recent harvested per-chunk occupancy slab (host ndarray
    ``(n_chunks, 3)``) or None."""
    with _lock:
        return _last_sweep_stats


def emit_round_spans(
    parent: Optional["_trace.SpanRecord"],
    per_round: Dict[str, List[int]],
    name: str = "laf.cluster.round",
) -> List["_trace.SpanRecord"]:
    """Synthesize per-round child spans under a measured parent span.

    The fused loop's rounds have no host-observable boundaries — the
    parent interval (the ``laf.label_prop`` span, which closes at the
    single ``device_get``) is subdivided into ``rounds`` equal slices,
    each carrying that round's telemetry as attributes.  The records
    ride the normal trace buffer, so ``export_chrome_trace`` shows
    them nested under the parent in Perfetto and ``coverage(parent)``
    sees the fused interval fully attributed.
    """
    if parent is None or not _trace._state.trace:
        return []
    rounds = len(next(iter(per_round.values()), []))
    if rounds <= 0 or parent.dur <= 0:
        return []
    slice_dur = parent.dur / rounds
    recs = []
    for i in range(rounds):
        rec = _trace.SpanRecord(
            name,
            t0=parent.t0 + i * slice_dur,
            dur=slice_dur,
            span_id=next(_trace._ids),
            parent_id=parent.span_id,
            tid=parent.tid,
            attrs=dict(
                {f: vals[i] for f, vals in per_round.items()},
                round=i, synthetic=True,
            ),
        )
        recs.append(rec)
    with _trace._lock:
        _trace._records.extend(recs)
    return recs
