"""Declarative SLO thresholds over the metrics registry.

The observability stack records *what happened* (counters, histograms,
spans, device telemetry); this module says *what is acceptable* and
turns the gap into an alert.  An :class:`SLO` is one declarative rule —
``metric op threshold`` — where ``metric`` names a registry instrument
(``"serve.assign.latency_s:p99"`` selects a histogram summary field,
plain names read counters/gauges) or a caller-supplied derived value
(skip rate, ARI, device_get count per run).

Evaluation never raises on missing data: a metric with no observations
yields ``ok=None`` ("no data"), so SLOs can be declared up front and
only start firing once the path they guard actually runs.  Violations
are emitted as structured, rate-limited log lines
(``slo.violation name=... value=... threshold=...``) — grep-stable for
CI and quiet enough for a serving loop to call per batch.

``serve.assign`` evaluates :data:`SERVE_SLOS` every
:data:`EVAL_EVERY_CALLS` calls; ``stream.partial_fit`` evaluates
:data:`INGEST_SLOS` per batch with the batch's derived skip rate.  The
default thresholds are intentionally loose sanity floors — deployment
configs replace them via :func:`set_slos`.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from . import metrics as _metrics
from .log import get_logger, rate_limited_warn

__all__ = [
    "SLO",
    "SLOResult",
    "SERVE_SLOS",
    "INGEST_SLOS",
    "CLUSTER_SLOS",
    "DEGRADED_SLOS",
    "EVAL_EVERY_CALLS",
    "set_slos",
    "resolve_metric",
    "evaluate",
    "check_and_alert",
]

_log = get_logger("obs.slo")

_OPS = {
    "<=": lambda v, t: v <= t,
    ">=": lambda v, t: v >= t,
    "<": lambda v, t: v < t,
    ">": lambda v, t: v > t,
    "==": lambda v, t: v == t,
}


@dataclass(frozen=True)
class SLO:
    """One declarative rule: ``metric op threshold``.

    ``metric`` is a registry name, optionally ``name:field`` to select
    one field of a histogram summary (p50/p95/p99/min/max/count/sum),
    or any key the caller passes via ``values=`` for derived quantities
    the registry does not hold (per-batch skip rate, run ARI).
    """

    name: str
    metric: str
    op: str
    threshold: float
    description: str = ""

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown SLO op {self.op!r} (use one of {sorted(_OPS)})")


@dataclass(frozen=True)
class SLOResult:
    slo: SLO
    value: Optional[float]
    ok: Optional[bool]  # None = no data (metric absent / no observations)

    @property
    def violated(self) -> bool:
        return self.ok is False


# default rule sets — loose sanity floors, replaced per deployment via
# set_slos(); thresholds mirror the bench-trajectory gate's quantities
SERVE_SLOS: List[SLO] = [
    SLO(
        "serve-assign-p99", "serve.assign.latency_s:p99", "<=", 0.5,
        "p99 assign() wall seconds per call",
    ),
]
INGEST_SLOS: List[SLO] = [
    SLO(
        "ingest-skip-floor", "ingest.skip_rate", ">=", 0.0,
        "estimator fast-path fraction of the batch (derived per batch)",
    ),
]
CLUSTER_SLOS: List[SLO] = [
    SLO(
        "cluster-one-device-get", "cluster.device_get_per_run", "==", 1.0,
        "host syncs per device-resident cluster pass (derived per run)",
    ),
    SLO("cluster-ari", "cluster.ari", ">=", 0.99, "parity vs the host oracle"),
]

DEGRADED_SLOS: List[SLO] = [
    SLO(
        "stream-degraded", "stream.degraded.events", "<=", 0.0,
        "device query paths degraded to the host oracle (fault fallback)",
    ),
]

# serve evaluates its rules every N assign() calls — cheap enough to
# leave on in production, frequent enough to catch a latency regression
# within one traffic burst
EVAL_EVERY_CALLS = 64

_lock = threading.Lock()


def set_slos(kind: str, slos: Sequence[SLO]) -> None:
    """Replace a default rule set ("serve" | "ingest" | "cluster" |
    "degraded")."""
    target = {
        "serve": SERVE_SLOS,
        "ingest": INGEST_SLOS,
        "cluster": CLUSTER_SLOS,
        "degraded": DEGRADED_SLOS,
    }[kind]
    with _lock:
        target[:] = list(slos)


def resolve_metric(metric: str, values: Optional[Dict[str, float]] = None):
    """Current value of ``metric``: caller-supplied ``values`` win, then
    the registry (histograms via ``name:field``).  None = no data."""
    if values and metric in values:
        return float(values[metric])
    name, _, field = metric.partition(":")
    snap = _metrics.snapshot(prefix=name)
    v = snap.get(name)
    if v is None:
        return None
    if isinstance(v, dict):  # histogram summary
        if not v.get("count"):
            return None
        return float(v.get(field or "p99", 0.0))
    return float(v)


def evaluate(
    slos: Sequence[SLO], values: Optional[Dict[str, float]] = None
) -> List[SLOResult]:
    """Evaluate rules against ``values`` + the live registry."""
    out = []
    for s in slos:
        v = resolve_metric(s.metric, values)
        ok = None if v is None else _OPS[s.op](v, s.threshold)
        out.append(SLOResult(s, v, ok))
    return out


def check_and_alert(
    slos: Sequence[SLO],
    values: Optional[Dict[str, float]] = None,
    *,
    interval_s: float = 60.0,
) -> List[SLOResult]:
    """Evaluate and emit one rate-limited structured warning per
    violated rule (``slo.violation name=... value=... threshold=...``);
    every evaluation also bumps ``slo.evaluations`` /
    ``slo.violations`` counters so the SLO plane is itself observable.
    """
    results = evaluate(slos, values)
    _metrics.counter("slo.evaluations").inc(len(results))
    for r in results:
        if r.violated:
            _metrics.counter("slo.violations").inc()
            rate_limited_warn(
                _log, f"slo:{r.slo.name}", "slo.violation",
                interval_s=interval_s,
                name=r.slo.name, metric=r.slo.metric, value=r.value,
                op=r.slo.op, threshold=r.slo.threshold,
            )
    return results
