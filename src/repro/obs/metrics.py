"""Process-global metrics registry: counters, gauges, log-bucketed
histograms.

The instruments the hot paths feed:

* **Counter** — monotonic (kernel launches, recompilations, collective
  psums/bytes, fast-path skips).  ``inc()`` while metrics are disabled
  is one attribute load + one branch, so instrumentation can stay
  inline in hot loops.
* **Gauge** — last-write-wins scalar (shortlist size, band fractions).
* **Histogram** — fixed log-spaced buckets (default 60 per three
  decades: ~12% resolution) covering 1 µs .. 100 s, the serving
  latency range.  Quantiles are computed from the cumulative bucket
  counts with geometric interpolation inside the landing bucket, so
  p50/p95/p99 are exact up to one bucket's width — and min/max/sum are
  tracked exactly.  Recording is O(1) (one ``bisect``), never stores
  samples, so a serving process can observe every assign forever.

``snapshot()`` returns a plain ``{name: value}`` dict (histograms
expand to count/sum/min/max/p50/p95/p99); ``to_json()`` is its
serialized form — what the benches put into their CI artifacts.

A fresh registry starts **disabled**: instruments exist and are
callable but record nothing until :func:`enable` (or ``REPRO_OBS=1``
via ``repro.obs.enable``), keeping tier-1 timing-sensitive tests
untouched.
"""

from __future__ import annotations

import json
import math
import threading
from bisect import bisect_right
from typing import Dict, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "counter",
    "gauge",
    "histogram",
    "enable",
    "disable",
    "enabled",
    "snapshot",
    "to_json",
    "reset",
]

_lock = threading.Lock()
_instruments: Dict[str, object] = {}


class _State:
    on: bool = False


_state = _State()


class Counter:
    """Monotonic counter; ``inc`` is a no-op while metrics are off."""

    __slots__ = ("name", "help", "_v", "_lk")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._v = 0
        self._lk = threading.Lock()

    def inc(self, n: int = 1) -> None:
        if not _state.on:
            return
        with self._lk:
            self._v += n

    @property
    def value(self) -> int:
        return self._v

    def _reset(self) -> None:
        self._v = 0


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "help", "_v", "_set")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._v = 0.0
        self._set = False

    def set(self, v: float) -> None:
        if not _state.on:
            return
        self._v = float(v)
        self._set = True

    @property
    def value(self) -> float:
        return self._v

    def _reset(self) -> None:
        self._v, self._set = 0.0, False


def default_buckets(
    lo: float = 1e-6, hi: float = 100.0, per_decade: int = 20
) -> Tuple[float, ...]:
    """Log-spaced bucket upper bounds, ``per_decade`` per decade of
    [lo, hi] — at 20/decade adjacent bounds differ by ~12%, which is
    the histogram's quantile resolution."""
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(lo * (hi / lo) ** (i / n) for i in range(n + 1))


class Histogram:
    """Fixed log-bucket histogram with interpolated quantiles.

    Values at or below the first bound — including the exact zeros a
    sub-clock-resolution duration measures to on fast assigns — are
    **clamped to the first bound** and land in bucket 0: a log-bucket
    layout has no bucket for 0, and letting raw zeros drive ``_min``
    used to drag the geometric interpolation toward 1e-12, skewing p50
    far below anything that was ever observed.  Values above the last
    bound land in the overflow bucket.  quantile() interpolates
    geometrically inside the landing bucket (log-uniform within-bucket
    assumption — the natural prior for latencies), so against exact
    percentiles the error is bounded by one bucket ratio (~12% at the
    default layout).
    """

    __slots__ = ("name", "help", "bounds", "_counts", "_n", "_sum", "_min", "_max", "_lk")

    def __init__(self, name: str, help: str = "", bounds: Optional[Tuple[float, ...]] = None):
        self.name, self.help = name, help
        self.bounds: Tuple[float, ...] = tuple(bounds) if bounds else default_buckets()
        self._counts = [0] * (len(self.bounds) + 1)
        self._n = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lk = threading.Lock()

    def observe(self, v: float) -> None:
        if not _state.on:
            return
        v = float(v)
        if v <= self.bounds[0]:
            # clock-resolution artifact (0.0 from perf_counter pairs on
            # a fast path, or any sub-resolution duration): clamp into
            # the first bucket so min/quantiles stay on the bucket grid
            v = self.bounds[0]
            i = 0
        else:
            i = bisect_right(self.bounds, v)
        with self._lk:
            self._counts[i] += 1
            self._n += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """q in [0, 1]; 0 with no observations."""
        if self._n == 0:
            return 0.0
        if q <= 0:
            return self._min
        if q >= 1:
            return self._max
        target = q * self._n
        acc = 0
        for i, c in enumerate(self._counts):
            if acc + c >= target:
                lo = self.bounds[i - 1] if i > 0 else (
                    min(self._min, self.bounds[0])
                )
                hi = self.bounds[i] if i < len(self.bounds) else self._max
                lo = max(lo, 1e-12)
                hi = max(hi, lo)
                frac = (target - acc) / c
                # geometric interpolation inside the log-spaced bucket
                val = lo * (hi / lo) ** frac
                return float(min(max(val, self._min), self._max))
            acc += c
        return self._max

    def _reset(self) -> None:
        self._counts = [0] * (len(self.bounds) + 1)
        self._n, self._sum = 0, 0.0
        self._min, self._max = math.inf, -math.inf

    def summary(self) -> Dict[str, float]:
        if self._n == 0:
            return {"count": 0}
        return {
            "count": self._n,
            "sum": self._sum,
            "min": self._min,
            "max": self._max,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


def _get(name: str, cls, **kw):
    with _lock:
        inst = _instruments.get(name)
        if inst is None:
            inst = _instruments[name] = cls(name, **kw)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {type(inst).__name__}"
            )
        return inst


def counter(name: str, help: str = "") -> Counter:
    """Get-or-create the named monotonic counter."""
    return _get(name, Counter, help=help)


def gauge(name: str, help: str = "") -> Gauge:
    return _get(name, Gauge, help=help)


def histogram(name: str, help: str = "", bounds=None) -> Histogram:
    return _get(name, Histogram, help=help, bounds=bounds)


def enable() -> None:
    _state.on = True


def disable() -> None:
    _state.on = False


def enabled() -> bool:
    return _state.on


def reset() -> None:
    """Zero every instrument (registrations are kept)."""
    with _lock:
        for inst in _instruments.values():
            inst._reset()


def snapshot(prefix: str = "") -> Dict[str, object]:
    """Plain-dict view of every instrument (histograms expand to their
    summary), optionally filtered to names starting with ``prefix``."""
    with _lock:
        items = sorted(_instruments.items())
    out: Dict[str, object] = {}
    for name, inst in items:
        if prefix and not name.startswith(prefix):
            continue
        if isinstance(inst, Histogram):
            out[name] = inst.summary()
        elif isinstance(inst, Gauge):
            if inst._set:
                out[name] = inst.value
        else:
            out[name] = inst.value
    return out


def to_json(prefix: str = "", indent: int = 2) -> str:
    return json.dumps(snapshot(prefix), indent=indent, default=float)
