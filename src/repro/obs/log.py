"""Structured stdlib-logging wrapper for the ``repro.*`` namespace.

Three things the ad-hoc ``print(`` reporting scattered through
``launch/`` could not do:

* one switch (``configure(quiet=True)`` / ``--quiet`` in the CLIs)
  silences every human-readable line without touching stdout users;
* events carry machine-readable ``key=value`` fields appended to the
  message, so a grep of a CI log reconstructs the numbers;
* ``rate_limited_warn`` keeps per-item warnings (e.g. a counter
  overflowing per batch) from flooding a serving log — at most one
  line per key per ``interval_s``.

Handlers are only attached to the ``repro`` root logger and only once,
and propagation to the global root is disabled, so embedding apps keep
full control via standard ``logging`` configuration.
"""

from __future__ import annotations

import logging
import sys
import threading
import time
from typing import Dict

__all__ = ["get_logger", "configure", "log_event", "rate_limited_warn"]

_ROOT = "repro"
_lock = threading.Lock()
_configured = False
_last_warn: Dict[str, float] = {}


def configure(
    level: int = logging.INFO, quiet: bool = False, stream=None, force: bool = False
) -> logging.Logger:
    """Attach one stderr handler to the ``repro`` root logger.

    Idempotent (re-calls adjust the level only, unless ``force``);
    ``quiet=True`` is shorthand for WARNING level — what the ``--quiet``
    CLI flags map to.
    """
    global _configured
    root = logging.getLogger(_ROOT)
    with _lock:
        if quiet:
            level = logging.WARNING
        if not _configured or force:
            if force:
                for h in list(root.handlers):
                    root.removeHandler(h)
            h = logging.StreamHandler(stream or sys.stderr)
            h.setFormatter(
                logging.Formatter("%(asctime)s %(levelname).1s %(name)s: %(message)s",
                                  datefmt="%H:%M:%S")
            )
            root.addHandler(h)
            root.propagate = False
            _configured = True
        root.setLevel(level)
    return root


def get_logger(name: str = "") -> logging.Logger:
    """Logger under the ``repro.`` namespace (``get_logger("launch")``
    -> ``repro.launch``).  Does not attach handlers — call
    :func:`configure` (CLIs do) or configure ``logging`` yourself."""
    if name.startswith(_ROOT):
        return logging.getLogger(name)
    return logging.getLogger(f"{_ROOT}.{name}" if name else _ROOT)


def _fmt_fields(fields: dict) -> str:
    parts = []
    for k, v in fields.items():
        if isinstance(v, float):
            v = f"{v:.6g}"
        parts.append(f"{k}={v}")
    return " ".join(parts)


def log_event(logger: logging.Logger, event: str, _level: int = logging.INFO, **fields):
    """``event key=value ...`` — grep-stable structured line."""
    if logger.isEnabledFor(_level):
        msg = f"{event} {_fmt_fields(fields)}" if fields else event
        logger.log(_level, msg)


def rate_limited_warn(
    logger: logging.Logger, key: str, msg: str, *, interval_s: float = 60.0, **fields
) -> bool:
    """Warn at most once per ``key`` per ``interval_s``; returns whether
    the line was emitted (suppressed repeats are counted in the
    ``suppressed=`` field of the next emitted line)."""
    now = time.monotonic()
    with _lock:
        last = _last_warn.get(key)
        suppressed = _last_warn.get(key + "#n", 0)
        if last is not None and now - last < interval_s:
            _last_warn[key + "#n"] = suppressed + 1
            return False
        _last_warn[key] = now
        _last_warn[key + "#n"] = 0
    if suppressed:
        fields = dict(fields, suppressed=suppressed)
    log_event(logger, msg, logging.WARNING, **fields)
    return True
