from .synthetic import (  # noqa: F401
    sample_vmf,
    make_angular_clusters,
    train_test_split,
)
