"""Host data pipeline: deterministic seeded generation + background
prefetch, yielding device-ready global batches.

Production shape: each host generates/loads only the rows its data-shard
owns (``host_shard`` / ``n_host_shards``); a background thread keeps a
bounded queue of ready batches so step time never blocks on input.
Determinism: batch i is a pure function of (seed, i) — restarts resume
bit-identically from any step.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

__all__ = ["BatchSpecFn", "Prefetcher", "lm_batches", "ctr_batches", "clustering_batches"]

BatchSpecFn = Callable[[np.random.Generator, int], Dict[str, np.ndarray]]


class Prefetcher:
    """Bounded background prefetch over a deterministic batch function."""

    def __init__(
        self,
        make_batch: Callable[[int], Any],
        *,
        depth: int = 2,
        start_step: int = 0,
    ):
        self.make_batch = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        i = self._step
        while not self._stop.is_set():
            try:
                self._q.put((i, self.make_batch(i)), timeout=0.1)
                i += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def lm_batches(
    seed: int, batch: int, seq_len: int, vocab: int,
    *, host_shard: int = 0, n_host_shards: int = 1,
) -> Callable[[int], Dict[str, np.ndarray]]:
    """Deterministic zipf token batches; host sees its shard's rows."""
    rows = batch // n_host_shards

    def make(step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, host_shard])
        )
        z = rng.zipf(1.3, size=(rows, seq_len + 1))
        toks = np.minimum(z - 1, vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return make


def ctr_batches(
    seed: int, batch: int, vocab_sizes, *, seq_len: int = 0,
    host_shard: int = 0, n_host_shards: int = 1,
) -> Callable[[int], Dict[str, np.ndarray]]:
    rows = batch // n_host_shards
    vocab_sizes = np.asarray(vocab_sizes)

    def make(step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([seed, step, host_shard]))
        out = {
            "ids": np.stack(
                [rng.integers(0, v, size=rows) for v in vocab_sizes], axis=1
            ).astype(np.int32),
            "label": rng.integers(0, 2, size=rows).astype(np.float32),
        }
        if seq_len:
            out["hist"] = rng.integers(0, vocab_sizes[0], size=(rows, seq_len)).astype(np.int32)
            out["target"] = rng.integers(0, vocab_sizes[0], size=rows).astype(np.int32)
        return out

    return make


def clustering_batches(
    data: np.ndarray, frontier_size: int, seed: int
) -> Callable[[int], Dict[str, np.ndarray]]:
    """Frontier batches for the distributed LAF cluster step."""
    n = data.shape[0]

    def make(step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
        idx = rng.choice(n, size=frontier_size, replace=False)
        return {"queries": data[idx], "indices": idx.astype(np.int32)}

    return make
