"""Neighbor sampling for GNN minibatch training (the ``minibatch_lg``
shape's real sampler — GraphSAGE-style uniform fanout over CSR).

``build_csr`` converts an edge list once; ``sample_fanout`` draws seed
nodes' k-hop neighborhoods with per-hop fanouts (15, 10), emitting a
padded, fixed-shape subgraph block (src/dst/feats/mask) ready for the
fixed-shape GAT train step — padding with a dead node keeps XLA shapes
static across steps.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

import numpy as np

__all__ = ["CSRGraph", "build_csr", "sample_fanout"]


@dataclass
class CSRGraph:
    indptr: np.ndarray    # (N+1,)
    indices: np.ndarray   # (E,) neighbor ids
    n_nodes: int

    def degree(self, nodes):
        return self.indptr[np.asarray(nodes) + 1] - self.indptr[np.asarray(nodes)]


def build_csr(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> CSRGraph:
    """CSR over incoming edges: neighbors(v) = sources of edges into v."""
    order = np.argsort(dst, kind="stable")
    sorted_dst = dst[order]
    sorted_src = src[order]
    counts = np.bincount(sorted_dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr, sorted_src.astype(np.int32), n_nodes)


def _sample_neighbors(
    g: CSRGraph, nodes: np.ndarray, fanout: int, rng: np.random.Generator
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """For each node draw ``fanout`` incoming neighbors (with replacement
    when degree < fanout; isolated nodes yield masked self-edges).

    Returns (src (n*f,), dst (n*f,), valid (n*f,)).
    """
    n = len(nodes)
    deg = g.degree(nodes)
    starts = g.indptr[nodes]
    offs = rng.integers(0, np.maximum(deg, 1)[:, None], size=(n, fanout))
    idx = starts[:, None] + offs
    src = g.indices[np.minimum(idx, len(g.indices) - 1 if len(g.indices) else 0)]
    valid = np.broadcast_to((deg > 0)[:, None], (n, fanout)).copy()
    src = np.where(valid, src, nodes[:, None])  # masked self-edge placeholder
    dst = np.broadcast_to(nodes[:, None], (n, fanout))
    return src.reshape(-1), dst.reshape(-1).astype(np.int32), valid.reshape(-1)


def sample_fanout(
    g: CSRGraph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    feats: np.ndarray,
    rng: np.random.Generator,
) -> Dict[str, np.ndarray]:
    """k-hop fanout sample -> fixed-shape padded subgraph block.

    Block node order: [seeds | hop-1 samples | hop-2 samples | ...] with
    duplicates allowed (each sampled edge brings its own slot — the
    standard trade for static shapes; dedup happens in the aggregation
    by node id).  Edges point child -> parent (message flows to seeds).
    """
    frontier = np.asarray(seeds, dtype=np.int32)
    all_nodes = [frontier]
    srcs, dsts, valids = [], [], []
    offset = len(frontier)
    frontier_pos = np.arange(len(frontier), dtype=np.int32)
    for fanout in fanouts:
        src, dst_nodes, valid = _sample_neighbors(g, frontier, fanout, rng)
        n_new = len(src)
        src_pos = np.arange(offset, offset + n_new, dtype=np.int32)
        dst_pos = np.repeat(frontier_pos, fanout)
        srcs.append(src_pos)
        dsts.append(dst_pos)
        valids.append(valid)
        all_nodes.append(src.astype(np.int32))
        frontier = src.astype(np.int32)
        frontier_pos = src_pos
        offset += n_new

    node_ids = np.concatenate(all_nodes)
    return {
        "node_ids": node_ids,
        "feats": feats[node_ids],
        "src": np.concatenate(srcs),
        "dst": np.concatenate(dsts),
        "edge_mask": np.concatenate(valids),
        "n_seeds": len(seeds),
    }
