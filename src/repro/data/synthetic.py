"""Synthetic dataset generators.

The paper evaluates on normalized high-dimensional neural embeddings
(NYT bag-of-words 256-d, Glove 200-d, MS-MARCO passage embeddings
768-d).  Offline we generate seeded **von Mises-Fisher mixtures** on the
unit sphere — the canonical generative model for angular-distance
clustering — matched to the paper's operating points (n, d, noise ratio,
cluster count; Table 1 / Table 2).  Also: token streams, CTR click logs
and power-law graphs for the assigned non-LAF architectures.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "sample_uniform_sphere",
    "sample_vmf",
    "make_angular_clusters",
    "train_test_split",
    "token_stream",
    "ctr_batch",
    "powerlaw_graph",
    "random_small_graphs",
]


def sample_uniform_sphere(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    x = rng.standard_normal((n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _sample_vmf_w(rng: np.random.Generator, kappa: float, d: int, n: int) -> np.ndarray:
    """Wood (1994) rejection sampler for the vMF marginal cos-angle w."""
    b = (-2.0 * kappa + math.sqrt(4.0 * kappa**2 + (d - 1.0) ** 2)) / (d - 1.0)
    x0 = (1.0 - b) / (1.0 + b)
    c = kappa * x0 + (d - 1.0) * math.log(1.0 - x0**2)
    out = np.empty(n, dtype=np.float64)
    filled = 0
    while filled < n:
        m = (n - filled) * 2 + 16
        z = rng.beta((d - 1.0) / 2.0, (d - 1.0) / 2.0, size=m)
        w = (1.0 - (1.0 + b) * z) / (1.0 - (1.0 - b) * z)
        u = rng.uniform(size=m)
        ok = kappa * w + (d - 1.0) * np.log1p(-x0 * w) - c >= np.log(u)
        take = min(int(ok.sum()), n - filled)
        out[filled : filled + take] = w[ok][:take]
        filled += take
    return out


def sample_vmf(rng: np.random.Generator, mu: np.ndarray, kappa: float, n: int) -> np.ndarray:
    """n samples from vMF(mu, kappa) on S^{d-1}."""
    d = mu.shape[0]
    if kappa <= 0:
        return sample_uniform_sphere(rng, n, d)
    w = _sample_vmf_w(rng, kappa, d, n)  # (n,)
    v = rng.standard_normal((n, d))
    v -= (v @ mu)[:, None] * mu[None, :]  # orthogonalize against mu
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    x = w[:, None] * mu[None, :] + np.sqrt(np.maximum(1.0 - w**2, 0.0))[:, None] * v
    return x.astype(np.float32)


def make_angular_clusters(
    n: int,
    d: int,
    n_clusters: int,
    *,
    kappa: float = 120.0,
    noise_frac: float = 0.3,
    cluster_size_alpha: float = 1.2,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Seeded vMF mixture + uniform noise on the sphere.

    Returns (data (n,d) float32 L2-normalized, true_labels (n,) with -1
    noise).  Cluster sizes follow a power law (the paper's datasets have
    heavy-tailed cluster sizes — Table 6's tiny missed clusters).
    """
    rng = np.random.default_rng(seed)
    n_noise = int(round(n * noise_frac))
    n_clustered = n - n_noise
    raw = rng.pareto(cluster_size_alpha, size=n_clusters) + 1.0
    sizes = np.maximum((raw / raw.sum() * n_clustered).astype(int), 1)
    while sizes.sum() < n_clustered:
        sizes[rng.integers(n_clusters)] += 1
    while sizes.sum() > n_clustered:
        i = rng.integers(n_clusters)
        if sizes[i] > 1:
            sizes[i] -= 1
    centers = sample_uniform_sphere(rng, n_clusters, d)
    xs, ys = [], []
    for k in range(n_clusters):
        xs.append(sample_vmf(rng, centers[k].astype(np.float64), kappa, int(sizes[k])))
        ys.append(np.full(int(sizes[k]), k, dtype=np.int64))
    if n_noise:
        xs.append(sample_uniform_sphere(rng, n_noise, d))
        ys.append(np.full(n_noise, -1, dtype=np.int64))
    data = np.concatenate(xs, axis=0)
    labels = np.concatenate(ys, axis=0)
    perm = rng.permutation(n)
    data = data[perm]
    data /= np.linalg.norm(data, axis=1, keepdims=True)
    return data.astype(np.float32), labels[perm]


def train_test_split(
    data: np.ndarray, frac_train: float = 0.8, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Paper §3.1: 8:2 split; estimator trains on train, clustering on test."""
    rng = np.random.default_rng(seed)
    n = data.shape[0]
    perm = rng.permutation(n)
    k = int(round(n * frac_train))
    return data[perm[:k]], data[perm[k:]]


# ---------------------------------------------------------------------------
# generators for the assigned (non-LAF) architectures
# ---------------------------------------------------------------------------


def token_stream(rng: np.random.Generator, batch: int, seq_len: int, vocab: int):
    """Zipf-ish token batch + next-token labels."""
    z = rng.zipf(1.3, size=(batch, seq_len + 1))
    toks = np.minimum(z - 1, vocab - 1).astype(np.int32)
    return toks[:, :-1], toks[:, 1:]


def ctr_batch(
    rng: np.random.Generator,
    batch: int,
    n_fields: int,
    vocab_sizes: np.ndarray,
    seq_len: int = 0,
):
    """Criteo-style CTR batch: sparse ids per field (+ optional behavior seq)."""
    ids = np.stack(
        [rng.integers(0, v, size=batch) for v in vocab_sizes], axis=1
    ).astype(np.int32)
    out = {"ids": ids, "label": rng.integers(0, 2, size=batch).astype(np.float32)}
    if seq_len:
        out["hist"] = rng.integers(0, vocab_sizes[0], size=(batch, seq_len)).astype(np.int32)
    return out


def powerlaw_graph(rng: np.random.Generator, n_nodes: int, n_edges: int, d_feat: int):
    """Random graph with power-law-ish degree: preferential src sampling."""
    w = 1.0 / (np.arange(1, n_nodes + 1) ** 0.8)
    w /= w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=w).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    feats = rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, 7, size=n_nodes).astype(np.int32)
    return {"src": src, "dst": dst, "feats": feats, "labels": labels}


def random_small_graphs(
    rng: np.random.Generator, batch: int, n_nodes: int, n_edges: int, d_feat: int
):
    """Batched molecule-style small graphs (padded dense edge lists)."""
    src = rng.integers(0, n_nodes, size=(batch, n_edges)).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=(batch, n_edges)).astype(np.int32)
    feats = rng.standard_normal((batch, n_nodes, d_feat)).astype(np.float32)
    y = rng.standard_normal((batch,)).astype(np.float32)
    return {"src": src, "dst": dst, "feats": feats, "y": y}
