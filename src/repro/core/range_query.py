"""Blocked range-query engine (the DBSCAN hot path).

A DBSCAN range query for point P returns N = {Q : d(P, Q) < eps}.  On
normalized vectors with cosine distance this is a thresholded matmul.
The engine processes the database in blocks so the working set stays
bounded (HBM->VMEM streaming on TPU; cache-friendly on CPU), producing:

  * counts         -- |N(P)| per query                  (exact cardinality)
  * bitmap         -- packed uint32 adjacency rows       (for label propagation)
  * neighbor lists -- host-side python lists              (for the faithful
                      sequential Algorithm-1 transcription)

The Pallas kernel in ``repro.kernels.range_count`` implements the fused
tile (distance + threshold + count + bitmap) for TPU; this module is the
pure-jnp engine and the oracle the kernel is validated against.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "range_counts",
    "range_bitmap",
    "range_counts_and_bitmap",
    "bitmap_row_to_indices",
    "neighbor_lists",
    "pack_bitmap",
    "unpack_bitmap",
]


def _num_words(n: int) -> int:
    return (n + 31) // 32


@functools.partial(jax.jit, static_argnames=("block_size",))
def range_counts(
    queries: jax.Array, db: jax.Array, eps: float, *, block_size: int = 2048
) -> jax.Array:
    """Exact neighbor counts |{j : d_cos(q_i, db_j) < eps}| per query.

    Streams the database in ``block_size`` chunks via ``lax.scan`` so the
    (nq, block) score tile is the only large intermediate.
    """
    nq, d = queries.shape
    nd = db.shape[0]
    nblocks = -(-nd // block_size)
    pad = nblocks * block_size - nd
    dbp = jnp.pad(db, ((0, pad), (0, 0)))
    valid = jnp.arange(nblocks * block_size) < nd
    dbp = dbp.reshape(nblocks, block_size, d)
    validb = valid.reshape(nblocks, block_size)

    def body(acc, blk):
        dbb, vb = blk
        # distance < eps  <=>  dot > 1 - eps
        dots = queries @ dbb.T
        hit = (dots > 1.0 - eps) & vb[None, :]
        return acc + jnp.sum(hit, axis=1, dtype=jnp.int32), None

    counts, _ = jax.lax.scan(body, jnp.zeros((nq,), jnp.int32), (dbp, validb))
    return counts


def pack_bitmap(hits: np.ndarray) -> np.ndarray:
    """Pack a boolean (nq, nd) matrix into uint32 words (nq, ceil(nd/32)).

    Bit j of word w in row i is set iff hits[i, 32*w + j].
    """
    nq, nd = hits.shape
    nw = _num_words(nd)
    padded = np.zeros((nq, nw * 32), dtype=bool)
    padded[:, :nd] = hits
    bits = padded.reshape(nq, nw, 32).astype(np.uint32)
    shifts = np.arange(32, dtype=np.uint32)
    return (bits << shifts[None, None, :]).sum(axis=2, dtype=np.uint32)


def unpack_bitmap(bitmap: np.ndarray, nd: int) -> np.ndarray:
    """Inverse of :func:`pack_bitmap`."""
    bitmap = np.asarray(bitmap)
    nq, nw = bitmap.shape
    shifts = np.arange(32, dtype=np.uint32)
    bits = (bitmap[:, :, None] >> shifts[None, None, :]) & np.uint32(1)
    return bits.reshape(nq, nw * 32)[:, :nd].astype(bool)


@functools.partial(jax.jit, static_argnames=("block_size",))
def range_bitmap(
    queries: jax.Array, db: jax.Array, eps: float, *, block_size: int = 2048
) -> jax.Array:
    """Packed uint32 adjacency rows: bit j of row i set iff d(q_i, db_j) < eps.

    block_size must be a multiple of 32.
    """
    assert block_size % 32 == 0
    nq, d = queries.shape
    nd = db.shape[0]
    nblocks = -(-nd // block_size)
    pad = nblocks * block_size - nd
    dbp = jnp.pad(db, ((0, pad), (0, 0))).reshape(nblocks, block_size, d)
    valid = (jnp.arange(nblocks * block_size) < nd).reshape(nblocks, block_size)
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def body(_, blk):
        dbb, vb = blk
        dots = queries @ dbb.T
        hit = (dots > 1.0 - eps) & vb[None, :]
        words = hit.reshape(nq, block_size // 32, 32).astype(jnp.uint32)
        packed = jnp.sum(words << shifts[None, None, :], axis=2, dtype=jnp.uint32)
        return None, packed

    _, packed = jax.lax.scan(body, None, (dbp, valid))
    # (nblocks, nq, words_per_block) -> (nq, total_words)
    packed = jnp.transpose(packed, (1, 0, 2)).reshape(nq, -1)
    return packed[:, : _num_words(nd)]


@functools.partial(jax.jit, static_argnames=("block_size",))
def range_counts_and_bitmap(
    queries: jax.Array, db: jax.Array, eps: float, *, block_size: int = 2048
) -> Tuple[jax.Array, jax.Array]:
    """Counts and packed adjacency in one database pass."""
    assert block_size % 32 == 0
    nq, d = queries.shape
    nd = db.shape[0]
    nblocks = -(-nd // block_size)
    pad = nblocks * block_size - nd
    dbp = jnp.pad(db, ((0, pad), (0, 0))).reshape(nblocks, block_size, d)
    valid = (jnp.arange(nblocks * block_size) < nd).reshape(nblocks, block_size)
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def body(acc, blk):
        dbb, vb = blk
        dots = queries @ dbb.T
        hit = (dots > 1.0 - eps) & vb[None, :]
        cnt = acc + jnp.sum(hit, axis=1, dtype=jnp.int32)
        words = hit.reshape(nq, block_size // 32, 32).astype(jnp.uint32)
        packed = jnp.sum(words << shifts[None, None, :], axis=2, dtype=jnp.uint32)
        return cnt, packed

    counts, packed = jax.lax.scan(body, jnp.zeros((nq,), jnp.int32), (dbp, valid))
    packed = jnp.transpose(packed, (1, 0, 2)).reshape(nq, -1)
    return counts, packed[:, : _num_words(nd)]


def bitmap_row_to_indices(row: np.ndarray, nd: int) -> np.ndarray:
    """Decode one packed row to sorted neighbor indices (host-side)."""
    return np.nonzero(unpack_bitmap(row[None, :], nd)[0])[0]


def neighbor_lists(
    data: np.ndarray, eps: float, block_size: int = 4096, *, backend="exact",
    device="auto",
):
    """Host-side neighbor lists for the whole dataset.

    Returns ``list[np.ndarray]`` — used by the faithful sequential
    Algorithm-1 transcription and by tests.  Self is included (d(P,P)=0).
    ``backend`` selects the range-query engine (``repro.index``); any
    non-default backend is fit on ``data`` and queried block by block,
    with ``device`` choosing its evaluator (fused Pallas tile vs host).
    """
    data = np.asarray(data)
    if backend != "exact":  # name or RangeBackend instance
        from ..index import as_fitted  # deferred: repro.index imports this module

        return as_fitted(
            backend, np.asarray(data, np.float32), device=device
        ).neighbor_lists(eps, block_size=block_size)
    n = data.shape[0]
    out = []
    thresh = 1.0 - eps
    for start in range(0, n, block_size):
        q = data[start : start + block_size]
        dots = q @ data.T
        for i in range(q.shape[0]):
            out.append(np.nonzero(dots[i] > thresh)[0])
    return out
