"""LAF-DBSCAN — Algorithm 1 of the paper.

Two interchangeable engines:

* ``laf_dbscan_sequential`` — a line-by-line transcription of the
  pseudocode (black + red text), used for validation.  The red-text LAF
  insertions are marked ``# LAF:`` inline.

* ``laf_dbscan`` — the batch-parallel TPU-shaped engine (DESIGN.md §2).
  Identical skip/execute decisions (every predicted-core point executes
  exactly one range query in both engines — see DESIGN.md §2), identical
  executed-core cluster structure, and a partial-neighbor map 𝓔 that is
  a superset of the sequential one (post-processing can only rescue
  *more* false negatives).  Range queries for the whole predicted-core
  set are blocked matmuls; cluster formation is vectorized star-unions
  over the executed-core graph.

Both report ``n_range_queries`` — the paper's unit of saved work.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Optional

import numpy as np

from ..obs import device as _obs_device, get_logger, metrics as _metrics, rate_limited_warn, span as _span
from ..testing import faults as _faults
from .dbscan import NOISE, UNDEFINED, DBSCANResult
from .postprocess import PartialNeighborMap, post_processing, update_partial_neighbors
from .range_query import pack_bitmap, unpack_bitmap
from .union_find import compact_labels, compact_labels_from_parent, union_star

__all__ = ["laf_dbscan_sequential", "laf_dbscan"]


def laf_dbscan_sequential(
    data: np.ndarray,
    eps: float,
    tau: int,
    alpha: float,
    card_est: Callable[[int], float],
    *,
    seed: int = 0,
) -> DBSCANResult:
    """Algorithm 1, faithful transcription.

    ``card_est(i)`` returns the predicted cardinality of point i (the
    RMI estimator, or an oracle in tests).
    """
    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    labels = np.full(n, UNDEFINED, dtype=np.int64)
    core = np.zeros(n, dtype=bool)
    queries = 0
    emap = PartialNeighborMap()                        # LAF: map 𝓔 (line 2)
    thresh = 1.0 - eps

    def range_query(i: int) -> np.ndarray:
        nonlocal queries
        queries += 1
        return np.nonzero(data[i] @ data.T > thresh)[0]

    c = 0
    for p in range(n):
        if labels[p] != UNDEFINED:                     # line 5
            continue
        if card_est(p) < alpha * tau:                  # LAF: line 6
            labels[p] = NOISE                          # line 7
            emap.register(p)                           # LAF: line 8
            continue                                   # line 9
        nbrs = range_query(p)                          # line 10
        update_partial_neighbors(p, nbrs, emap)        # LAF: line 11
        if len(nbrs) < tau:                            # line 12
            labels[p] = NOISE                          # line 13
            continue                                   # line 14
        core[p] = True
        labels[p] = c                                  # line 15
        seeds = deque(int(q) for q in nbrs if q != p)  # line 16: S := N - {P}
        while seeds:                                   # line 17
            q = seeds.popleft()
            if labels[q] == NOISE:                     # line 18
                labels[q] = c
            if labels[q] != UNDEFINED:                 # line 19
                continue
            labels[q] = c                              # line 21
            if card_est(q) >= alpha * tau:             # LAF: line 22
                qn = range_query(q)                    # line 23
                update_partial_neighbors(q, qn, emap)  # LAF: line 24
                if len(qn) >= tau:                     # line 25
                    core[q] = True
                    seeds.extend(int(x) for x in qn)
            else:
                emap.register(q)                       # LAF: line 26-27
        c += 1
    labels = post_processing(                          # LAF: line 28
        labels, emap, tau, rng=np.random.default_rng(seed)
    )
    labels = _compact(labels)
    n_clusters = int(labels.max()) + 1 if labels.max() >= 0 else 0
    return DBSCANResult(labels, core, n_clusters, queries, {"n_registered": len(emap)})


# single-pass np.unique relabeling shared with the union-find module
_compact = compact_labels


def laf_dbscan(
    data: np.ndarray,
    eps: float,
    tau: int,
    alpha: float,
    predicted_counts: np.ndarray,
    *,
    block_size: int = 2048,
    seed: int = 0,
    backend="exact",
    device="auto",
    cluster_device="auto",
    on_device_fault: str = "degrade",
) -> DBSCANResult:
    """Batch-parallel LAF-DBSCAN engine.

    Args:
      predicted_counts: (n,) estimator predictions for every point at
        this eps (one batched RMI pass by the caller — kept as an input
        so engines and estimators compose freely; tests pass oracles).
      backend: range-query backend (``repro.index``) — LAF's skip rule
        composes with an ANN backend: the estimator skips whole queries,
        the index then prunes the candidates inside each executed one.
      device: backend evaluator choice (fused Pallas tile vs host; see
        ``dbscan_parallel``); ignored by constructed instances.
      cluster_device: where cluster formation (core test + core-graph
        components + border rule) runs.  ``"auto"`` follows the
        backend: when it packs adjacency natively on device
        (``packs_natively``), the sweep's bitmap slab feeds the packed
        label-propagation program directly and the entire clustering
        syncs to the host exactly once (final labels); otherwise the
        host unpack -> union-find pass runs (the parity oracle).
        ``True`` forces the device program even for host backends (the
        packed blocks are uploaded once — the exact-backend parity
        mode); ``False`` forces the host pass.
      on_device_fault: ``"degrade"`` (default) falls back to the
        bit-exact host unpack → union-find pass when the device cluster
        launch fails (recording ``stream.degraded.cluster`` and an
        ``slo.violation``); ``"raise"`` surfaces the failure.
    """
    from ..index import as_fitted

    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    cluster_span = _span("laf.cluster", n=n, eps=float(eps), tau=int(tau))
    cluster_span.__enter__()
    try:
        return _laf_dbscan_body(
            data, eps, tau, alpha, predicted_counts, as_fitted,
            block_size=block_size, seed=seed, backend=backend, device=device,
            cluster_device=cluster_device, on_device_fault=on_device_fault,
        )
    finally:
        cluster_span.__exit__(None, None, None)


def _cluster_pass_device(bk, eps, tau, exec_idx, n, native, block_size):
    """Device-resident pass 1 + pass 2: sweep slab -> packed label
    propagation, one ``device_get`` of the results.

    Returns ``(labels, core, exact_counts, partial_counts)`` with
    identical values to the host pass (min-core-index component
    representatives are what ``union_star``'s min-root merging produces,
    so even the label *numbers* match after ``np.unique``).
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.label_prop import packed_cluster_labels

    _faults.maybe_fail("cluster.launch", n=int(n), n_exec=int(len(exec_idx)))
    n_exec = len(exec_idx)
    mesh = getattr(bk, "mesh", None) if native else None
    with _span("laf.pass1", n=n, n_exec=int(n_exec), block_size=block_size,
               device=True):
        if native:
            # async dispatch: the slab never leaves the device
            with _span("laf.sweep", rows=int(n_exec), synced=False):
                slab, plan = bk.query_bitmap_device(exec_idx, eps)
            rows_op = np.full(plan.nq_padded, n, dtype=np.int64)
            rows_op[:n_exec] = exec_idx
        else:
            # forced parity mode for host backends: pack per block on
            # the host, upload the slab once
            blocks = []
            for start in range(0, n_exec, block_size):
                rows = exec_idx[start : start + block_size]
                with _span("laf.sweep", block=start // block_size, rows=len(rows)):
                    blocks.append(pack_bitmap(bk.query_hits(rows, eps)))
            slab = jnp.asarray(np.concatenate(blocks, axis=0))
            rows_op = exec_idx
    telemetry = _obs_device.device_enabled()
    # only the per-round cluster counters ride this launch: the bitmap
    # sweep carries no occupancy slab (that statistic lives on the count
    # sweeps — see index/sweep.py), so THE device_get fetches exactly
    # the fixpoint outputs
    lp_span = _span("laf.label_prop", rows=int(len(rows_op)), n=n,
                    telemetry=telemetry)
    with lp_span:
        if mesh is not None:
            from ..distributed.index_plane import sharded_cluster_labels

            outs = sharded_cluster_labels(
                slab, rows_op, tau, mesh=mesh, axes=bk._plan.axes, n=n,
                telemetry=telemetry,
            )
        else:
            outs = packed_cluster_labels(
                slab, jnp.asarray(rows_op), tau, n=n, telemetry=telemetry,
            )
        # THE host sync: everything above dispatched asynchronously —
        # telemetry rides the same get, never a second one
        outs_h = jax.device_get(outs)
        _metrics.counter("laf.cluster.device_get").inc()
    rep, owner, col_sum, counts, rounds = outs_h[:5]
    _metrics.counter("laf.cluster.rounds").inc(int(rounds))
    if telemetry and len(outs_h) > 5:
        per_round = _obs_device.harvest_cluster_telemetry(outs_h[5], rounds)
        _obs_device.emit_round_spans(getattr(lp_span, "_rec", None), per_round)

    exact_counts = np.zeros(n, dtype=np.int64)
    exact_counts[exec_idx] = np.asarray(counts[:n_exec], dtype=np.int64)
    partial_counts = np.asarray(col_sum[:n], dtype=np.int64)
    core = np.zeros(n, dtype=bool)
    core[exec_idx] = exact_counts[exec_idx] >= tau
    rep = np.asarray(rep[:n])
    owner = np.asarray(owner[:n], dtype=np.int64)
    labels = np.full(n, -1, dtype=np.int64)
    ci = np.nonzero(core)[0]
    if len(ci):
        # rep = min core index per component == the union-find root the
        # host pass produces (union_star merges by min root)
        _, inv = np.unique(rep[ci], return_inverse=True)
        labels[ci] = inv
    borders = np.nonzero(~core & (owner < n))[0]
    labels[borders] = labels[owner[borders]]
    return labels, core, exact_counts, partial_counts


def _laf_dbscan_body(
    data, eps, tau, alpha, predicted_counts, as_fitted,
    *, block_size, seed, backend, device, cluster_device="auto",
    on_device_fault="degrade",
):
    n = data.shape[0]
    with _span("laf.fit_index", backend=str(backend)):
        bk = as_fitted(backend, data, block_size=block_size, device=device)
    predicted_core = np.asarray(predicted_counts) >= alpha * tau  # LAF skip rule
    exec_idx = np.nonzero(predicted_core)[0]
    n_exec = len(exec_idx)

    _metrics.counter("laf.runs").inc()
    _metrics.counter("laf.predicted_core").inc(int(n_exec))
    _metrics.counter("laf.skipped").inc(int(n - n_exec))

    native = bool(getattr(bk, "packs_natively", False))
    use_device_cluster = (
        native if cluster_device == "auto" else bool(cluster_device)
    )
    if use_device_cluster and n_exec:
        # ---- device-resident pass 1 + pass 2: one host sync ------------
        try:
            labels, core, exact_counts, partial_counts = _cluster_pass_device(
                bk, eps, tau, exec_idx, n, native, block_size
            )
        except (RuntimeError, OSError) as exc:
            if on_device_fault != "degrade":
                raise
            # fall through to the bit-exact host unpack -> union-find pass
            from ..obs import slo as _slo

            _metrics.counter("stream.degraded.events").inc()
            _metrics.counter("stream.degraded.cluster").inc()
            rate_limited_warn(
                get_logger("cluster"), "degraded", "cluster_degraded",
                error=type(exc).__name__, n=int(n), n_exec=int(n_exec),
            )
            _slo.check_and_alert(_slo.DEGRADED_SLOS)
        else:
            partial_counts[predicted_core] = 0  # 𝓔 keys: predicted-stop only
            return _rescue_and_finish(
                bk, eps, tau, seed, block_size, n, exec_idx, predicted_core,
                labels, core, partial_counts,
            )

    exact_counts = np.zeros(n, dtype=np.int64)
    partial_counts = np.zeros(n, dtype=np.int64)  # |𝓔(q)| for predicted-stop q

    # ---- pass 1 (the only range-query pass): predicted-core queries ----
    packed_blocks: list[tuple[np.ndarray, np.ndarray]] = []
    with _span("laf.pass1", n=n, n_exec=int(n_exec), block_size=block_size):
        for start in range(0, n_exec, block_size):
            rows = exec_idx[start : start + block_size]
            with _span("laf.sweep", block=start // block_size, rows=len(rows)):
                hit = bk.query_hits(rows, eps)  # (b, n)
            exact_counts[rows] = hit.sum(axis=1)
            # Alg.2 superset: every predicted-stop neighbor of an executed
            # query gains one partial neighbor.
            partial_counts += hit.sum(axis=0)
            # pack in the shared LSB-first uint32 word order (pack_bitmap ==
            # index signatures == device kernel bitmaps), so a backend that
            # returns packed adjacency can feed pass 2 without a re-pack
            packed_blocks.append((rows, pack_bitmap(hit)))
    partial_counts[predicted_core] = 0  # 𝓔 keys are predicted-stop points only

    core = np.zeros(n, dtype=bool)
    core[exec_idx] = exact_counts[exec_idx] >= tau

    # ---- pass 2 (no matmul): core-core unions + border ownership -------
    parent = np.arange(n, dtype=np.int64)
    owner = np.full(n, -1, dtype=np.int64)
    with _span("laf.union_find", blocks=len(packed_blocks)):
        for rows, packed in packed_blocks:
            with _span("laf.unpack", rows=len(rows)):
                hit = unpack_bitmap(packed, n)
            row_is_core = core[rows]
            hit_core = hit & core[None, :]
            for bi in np.nonzero(row_is_core)[0]:
                union_star(parent, np.nonzero(hit_core[bi])[0])
            if row_is_core.any():
                sub = hit[row_is_core]
                subrows = rows[row_is_core]
                claimed = sub.any(axis=0)
                todo = claimed & (owner < 0) & ~core
                if todo.any():
                    first = sub[:, todo].argmax(axis=0)
                    owner[todo] = subrows[first]

        labels = compact_labels_from_parent(parent, core)
        borders = np.nonzero(~core & (owner >= 0))[0]
        labels[borders] = labels[owner[borders]]
    return _rescue_and_finish(
        bk, eps, tau, seed, block_size, n, exec_idx, predicted_core,
        labels, core, partial_counts,
    )


def _rescue_and_finish(
    bk, eps, tau, seed, block_size, n, exec_idx, predicted_core,
    labels, core, partial_counts,
):
    """Post-processing rescue (Algorithm 3) + result assembly, shared by
    the host and device cluster passes."""
    n_exec = len(exec_idx)
    n_pre_clusters = int(labels.max()) + 1 if labels.max() >= 0 else 0

    # ---- post-processing: rescue false negatives (Algorithm 3) ---------
    rescue_idx = np.nonzero(~predicted_core & (partial_counts >= tau))[0]
    _metrics.counter("laf.rescued").inc(int(len(rescue_idx)))
    with _span("laf.postprocess", n_rescue=int(len(rescue_idx))):
        emap = PartialNeighborMap()
        if len(rescue_idx) > 0:
            for start in range(0, n_exec, block_size):
                rows = exec_idx[start : start + block_size]
                hit = bk.query_hits_subset(rows, rescue_idx, eps)  # (b, n_rescue)
                for ri in np.nonzero(hit.any(axis=0))[0]:
                    r = int(rescue_idx[ri])
                    emap.register(r)
                    emap[r].update(int(f) for f in rows[hit[:, ri]])
        labels = post_processing(labels, emap, tau, rng=np.random.default_rng(seed))
        labels = _compact(labels)

    extras = {
        "n_predicted_core": int(n_exec),
        "n_skipped": int(n - n_exec),
        "n_rescued": int(len(rescue_idx)),
        "n_pre_merge_clusters": n_pre_clusters,
        "false_negative_core": int(np.sum(~predicted_core & (partial_counts >= tau))),
    }
    n_clusters = int(labels.max()) + 1 if labels.max() >= 0 else 0
    return DBSCANResult(labels, core, n_clusters, n_exec, extras)
