"""Clustering quality metrics: ARI (Hubert & Arabie 1985) and AMI
(Vinh, Epps & Bailey 2010) — the paper's two effectiveness metrics.

Implemented from scratch (no sklearn/scipy in the environment); AMI uses
the exact hypergeometric E[MI] with an (a_i, b_j)-value cache so large
contingency tables stay tractable.  Both treat label values opaquely;
noise (-1) is a regular label, matching how the paper scores against
DBSCAN ground truth.
"""

from __future__ import annotations

import math
from typing import Tuple

import numpy as np

__all__ = [
    "contingency",
    "adjusted_rand_index",
    "mutual_info",
    "expected_mutual_info",
    "adjusted_mutual_info",
    "entropy",
]


def contingency(a: np.ndarray, b: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Contingency matrix between two labelings plus marginals."""
    a = np.asarray(a).ravel()
    b = np.asarray(b).ravel()
    if a.shape != b.shape:
        raise ValueError("labelings must have equal length")
    _, ai = np.unique(a, return_inverse=True)
    _, bi = np.unique(b, return_inverse=True)
    ra, rb = ai.max() + 1, bi.max() + 1
    m = np.zeros((ra, rb), dtype=np.int64)
    np.add.at(m, (ai, bi), 1)
    return m, m.sum(axis=1), m.sum(axis=0)


def _comb2(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.float64)
    return x * (x - 1.0) / 2.0


def adjusted_rand_index(a: np.ndarray, b: np.ndarray) -> float:
    m, ra, cb = contingency(a, b)
    n = ra.sum()
    sum_comb = _comb2(m).sum()
    sum_a = _comb2(ra).sum()
    sum_b = _comb2(cb).sum()
    total = _comb2(np.asarray([n]))[0]
    expected = sum_a * sum_b / total if total > 0 else 0.0
    max_index = 0.5 * (sum_a + sum_b)
    if max_index == expected:
        return 1.0
    return float((sum_comb - expected) / (max_index - expected))


def entropy(counts: np.ndarray) -> float:
    counts = counts[counts > 0].astype(np.float64)
    n = counts.sum()
    p = counts / n
    return float(-(p * np.log(p)).sum())


def mutual_info(a: np.ndarray, b: np.ndarray) -> float:
    m, ra, cb = contingency(a, b)
    n = float(ra.sum())
    nz = m > 0
    nij = m[nz].astype(np.float64)
    outer = np.outer(ra, cb)[nz].astype(np.float64)
    return float((nij / n * (np.log(nij * n) - np.log(outer))).sum())


def expected_mutual_info(ra: np.ndarray, cb: np.ndarray) -> float:
    """Exact E[MI] under the permutation model (Vinh et al. 2010, Eq. 24a).

    Vectorized over the hypergeometric support per (a_i, b_j) pair, with a
    cache keyed on the (a, b) values — contingency tables from DBSCAN runs
    have many repeated marginal values (singleton clusters), so this is
    orders of magnitude faster than the naive triple loop.
    """
    n = int(ra.sum())
    lg = np.zeros(n + 2, dtype=np.float64)
    for i in range(2, n + 2):
        lg[i] = lg[i - 1] + math.log(i - 1)  # lg[k] = log((k-1)!)
    log_n = math.log(n)

    cache: dict[Tuple[int, int], float] = {}
    emi = 0.0
    for a in ra:
        a = int(a)
        for b in cb:
            b = int(b)
            key = (a, b)
            if key in cache:
                emi += cache[key]
                continue
            start = max(1, a + b - n)
            end = min(a, b)
            if end < start:
                cache[key] = 0.0
                continue
            nij = np.arange(start, end + 1, dtype=np.int64)
            term1 = nij / n * (np.log(nij) + log_n - math.log(a) - math.log(b))
            logw = (
                lg[a + 1]
                + lg[b + 1]
                + lg[n - a + 1]
                + lg[n - b + 1]
                - lg[n + 1]
                - lg[nij + 1]
                - lg[a - nij + 1]
                - lg[b - nij + 1]
                - lg[n - a - b + nij + 1]
            )
            val = float((term1 * np.exp(logw)).sum())
            cache[key] = val
            emi += val
    return emi


def adjusted_mutual_info(a: np.ndarray, b: np.ndarray) -> float:
    """AMI with arithmetic mean normalization (sklearn default)."""
    m, ra, cb = contingency(a, b)
    if len(ra) == 1 and len(cb) == 1:
        return 1.0
    mi = mutual_info(a, b)
    emi = expected_mutual_info(ra, cb)
    h = 0.5 * (entropy(ra) + entropy(cb))
    denom = h - emi
    if abs(denom) < 1e-15:
        return 0.0 if abs(mi - emi) > 1e-15 else 1.0
    return float((mi - emi) / denom)
