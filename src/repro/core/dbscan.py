"""Exact DBSCAN: faithful sequential transcription + batch-parallel engine.

``dbscan_sequential`` is the line-by-line classic algorithm (Ester et al.
1996) exactly as the black text of the paper's Algorithm 1 — it is the
ground-truth producer (the paper uses original DBSCAN's output as ground
truth for ARI/AMI).

``dbscan_parallel`` is the TPU-shaped reformulation (see DESIGN.md §2):
   1. neighbor counts for ALL points via blocked matmul  -> core mask
   2. connected components of the core-core eps-graph    -> cluster ids
      (vectorized star-unions: one union-find hook per core row, no
      per-edge Python — dense clusters are cliques, per-edge is O(n^2))
   3. border points attach to their first core finder's cluster
Both return labels with the same convention: -1 noise, clusters 0..k-1.
The partitions are identical up to border-point ties (a border point
within eps of two clusters may legally join either); tests compare via
ARI and structural invariants.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from .range_query import range_counts
from .union_find import compact_labels_from_parent, union_star

__all__ = ["DBSCANResult", "dbscan_sequential", "dbscan_parallel", "core_mask", "NOISE", "UNDEFINED"]

UNDEFINED = -2
NOISE = -1


@dataclass
class DBSCANResult:
    labels: np.ndarray          # (n,) int64: -1 noise, else cluster id
    core: np.ndarray            # (n,) bool
    n_clusters: int
    n_range_queries: int        # executed range queries (the paper's cost unit)
    extras: dict = field(default_factory=dict)

    @property
    def noise_ratio(self) -> float:
        return float(np.mean(self.labels == NOISE))


def dbscan_sequential(
    data: np.ndarray, eps: float, tau: int, *, precomputed_neighbors=None
) -> DBSCANResult:
    """Classic DBSCAN (the black text of the paper's Algorithm 1)."""
    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    neigh = precomputed_neighbors
    queries = 0
    thresh = 1.0 - eps

    def range_query(i: int) -> np.ndarray:
        nonlocal queries
        queries += 1
        if neigh is not None:
            return neigh[i]
        return np.nonzero(data[i] @ data.T > thresh)[0]

    labels = np.full(n, UNDEFINED, dtype=np.int64)
    core = np.zeros(n, dtype=bool)
    c = 0
    for p in range(n):
        if labels[p] != UNDEFINED:
            continue
        nbrs = range_query(p)
        if len(nbrs) < tau:
            labels[p] = NOISE
            continue
        core[p] = True
        labels[p] = c
        seeds = deque(int(q) for q in nbrs if q != p)
        while seeds:
            q = seeds.popleft()
            if labels[q] == NOISE:
                labels[q] = c  # noise -> border
            if labels[q] != UNDEFINED:
                continue
            labels[q] = c
            qn = range_query(q)
            if len(qn) >= tau:
                core[q] = True
                seeds.extend(int(x) for x in qn)
        c += 1
    return DBSCANResult(labels, core, c, queries)


def core_mask(data: np.ndarray, eps: float, tau: int, block_size: int = 2048) -> np.ndarray:
    counts = np.asarray(range_counts(data, data, eps, block_size=block_size))
    return counts >= tau


def dbscan_parallel(
    data: np.ndarray,
    eps: float,
    tau: int,
    *,
    block_size: int = 2048,
    backend="exact",
    device="auto",
) -> DBSCANResult:
    """Batch-parallel DBSCAN (blocked core detection + star unions).

    ``backend`` selects the range-query engine (``repro.index``): the
    default ``"exact"`` reproduces brute-force DBSCAN; an ANN backend
    (``"random_projection"`` or a fit instance) makes every range query
    cheaper at a bounded recall cost.  ``device`` picks the backend's
    evaluator (``True`` = fused Pallas tile, ``False`` = host numpy,
    ``"auto"`` = tile iff a TPU/GPU is present); constructed backend
    instances keep their own setting.
    """
    from ..index import as_fitted

    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    bk = as_fitted(backend, data, block_size=block_size, device=device)
    counts = bk.query_counts(np.arange(n), eps)
    core = counts >= tau
    core_idx = np.nonzero(core)[0]

    parent = np.arange(n, dtype=np.int64)
    owner = np.full(n, -1, dtype=np.int64)  # first core finder per column

    for start in range(0, len(core_idx), block_size):
        rows = core_idx[start : start + block_size]
        hit = bk.query_hits(rows, eps)  # (b, n)
        hit_core = hit & core[None, :]
        for bi, i in enumerate(rows):
            members = np.nonzero(hit_core[bi])[0]
            union_star(parent, members)
        # border claim: first core row in this block to hit an unclaimed col
        claimed = hit.any(axis=0)
        todo = claimed & (owner < 0) & ~core
        if todo.any():
            first = hit[:, todo].argmax(axis=0)
            owner[todo] = rows[first]

    labels = compact_labels_from_parent(parent, core)
    borders = np.nonzero(~core & (owner >= 0))[0]
    labels[borders] = labels[owner[borders]]
    n_clusters = int(labels.max()) + 1 if labels.max() >= 0 else 0
    return DBSCANResult(labels, core, n_clusters, n)
