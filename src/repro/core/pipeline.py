"""End-to-end LAF pipeline: train estimator on the 80% split, cluster the
20% split, with the paper's timing discipline (prediction time counts,
training time does not — §3.1 Metrics).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from ..data.synthetic import train_test_split
from ..obs import span as _span
from .cardinality import TrainedEstimator, train_rmi
from .dbscan import DBSCANResult, dbscan_parallel
from .dbscan_pp import auto_sample_fraction, dbscan_pp, laf_dbscan_pp
from .laf_dbscan import laf_dbscan

__all__ = ["LAFPipeline", "ClusterOutcome"]


@dataclass
class ClusterOutcome:
    result: DBSCANResult
    elapsed_s: float               # clustering time incl. estimator predict
    predict_s: float = 0.0         # estimator prediction share
    method: str = ""
    params: Dict = field(default_factory=dict)


class LAFPipeline:
    """Owns a trained cardinality estimator + the LAF-enhanced engines.

    ``backend`` selects the range-query engine for every clustering
    method (``repro.index``): ``"exact"`` (default), ``"random_projection"``,
    or a constructed ``RangeBackend`` instance; per-call ``backend=``
    kwargs override it.  ``device`` picks the backend evaluator (fused
    Pallas tile vs host numpy; ``"auto"`` = tile iff TPU/GPU present)
    and is likewise overridable per call.  ``cluster_device`` routes
    cluster formation (``laf_dbscan``'s packed one-launch program vs
    the host union-find oracle; see ``LAFClusterConfig``).
    """

    def __init__(
        self,
        *,
        eps_grid=None,
        epochs: int = 200,
        batch_size: int = 512,
        lr: float = 1e-3,
        seed: int = 0,
        backend="exact",
        device="auto",
        cluster_device="auto",
    ):
        self.eps_grid = eps_grid
        self.epochs = epochs
        self.batch_size = batch_size
        self.lr = lr
        self.seed = seed
        self.backend = backend
        self.device = device
        self.cluster_device = cluster_device
        self.estimator: Optional[TrainedEstimator] = None
        self._stream = None  # StreamingLAF, created by the first partial_fit

    # -- estimator ---------------------------------------------------------
    def fit(self, train_vectors: np.ndarray) -> "LAFPipeline":
        self.estimator = train_rmi(
            train_vectors,
            eps_grid=self.eps_grid,
            epochs=self.epochs,
            batch_size=self.batch_size,
            lr=self.lr,
            seed=self.seed,
        )
        return self

    def fit_split(self, data: np.ndarray, frac_train: float = 0.8):
        """Paper protocol: 8:2 split; returns the test split to cluster."""
        train, test = train_test_split(data, frac_train, self.seed)
        self.fit(train)
        return test

    def predict_counts(self, vectors: np.ndarray, eps: float) -> np.ndarray:
        assert self.estimator is not None, "call fit() first"
        return self.estimator.predict_counts(vectors, eps)

    # -- streaming (repro.stream) ------------------------------------------
    @property
    def stream(self):
        """The live ``StreamingLAF`` (None until the first ``partial_fit``)."""
        return self._stream

    def partial_fit(self, batch: np.ndarray, *, eps: float = None, tau: int = None, **kw):
        """Ingest an embedding batch into the maintained online clustering.

        The first call fixes the (eps, tau) operating point and builds a
        ``repro.stream.StreamingLAF`` on this pipeline's backend/device;
        a trained estimator (from ``fit``) is wired in as the ingest
        fast path automatically (pass ``use_estimator=False`` to force
        the exact path).  Later calls just stream batches in — the
        maintained counts are eps-specific, so changing eps/tau
        mid-stream is an error, not a silent no-op.  Returns the
        per-batch ``IngestReport``.
        """
        if self._stream is None:
            if eps is None or tau is None:
                raise ValueError("the first partial_fit must fix eps= and tau=")
            from ..stream import StreamingLAF

            from ..index.base import RangeBackend

            if self.estimator is not None:
                kw.setdefault("estimator", self.estimator)
                kw.setdefault("use_estimator", True)
            kw.setdefault("backend", self.backend)
            if not isinstance(kw["backend"], RangeBackend):
                # a constructed instance keeps its own evaluator; only
                # registry names take the pipeline's device choice
                kw.setdefault("device", self.device)
            self._stream = StreamingLAF(eps, tau, **kw)
            return self._stream.partial_fit(batch)
        if (eps is not None and eps != self._stream.eps) or (
            tau is not None and tau != self._stream.tau
        ):
            raise ValueError(
                f"stream is live at eps={self._stream.eps}, tau={self._stream.tau}; "
                f"got eps={eps}, tau={tau} — the maintained counts are "
                f"operating-point-specific (start a new pipeline/stream to change)"
            )
        if kw:
            raise ValueError(
                f"stream is live; constructor kwargs {sorted(kw)} cannot be "
                f"applied after the first partial_fit"
            )
        return self._stream.partial_fit(batch)

    def assign(self, queries: np.ndarray, **kw):
        """Serving API: cluster ids + confidence for unseen vectors
        against the streamed clustering (``repro.stream.serve``)."""
        assert self._stream is not None, "call partial_fit() first"
        return self._stream.assign(queries, **kw)

    # -- engines -----------------------------------------------------------
    def cluster_laf_dbscan(
        self, vectors: np.ndarray, eps: float, tau: int, alpha: float, **kw
    ) -> ClusterOutcome:
        kw.setdefault("backend", self.backend)
        kw.setdefault("device", self.device)
        kw.setdefault("cluster_device", self.cluster_device)
        # forced spans: JAX dispatch is async, so reported phase times
        # must come from synced span durations, not bare wall clocks
        with _span("laf.run", n=len(vectors), eps=float(eps), tau=int(tau),
                   force=True) as run:
            with _span("laf.predict", n=len(vectors), force=True) as pre:
                pred = self.predict_counts(vectors, eps)
                pre.sync_on(pred)
            res = laf_dbscan(vectors, eps, tau, alpha, pred, seed=self.seed, **kw)
            run.sync_on((res.labels, res.core))
        return ClusterOutcome(res, run.dur, pre.dur, "LAF-DBSCAN",
                              {"eps": eps, "tau": tau, "alpha": alpha})

    def cluster_dbscan(self, vectors: np.ndarray, eps: float, tau: int, **kw) -> ClusterOutcome:
        kw.setdefault("backend", self.backend)
        kw.setdefault("device", self.device)
        with _span("dbscan.run", n=len(vectors), force=True) as run:
            res = dbscan_parallel(vectors, eps, tau, **kw)
            run.sync_on((res.labels, res.core))
        return ClusterOutcome(res, run.dur, 0.0, "DBSCAN", {"eps": eps, "tau": tau})

    def cluster_dbscan_pp(
        self, vectors: np.ndarray, eps: float, tau: int,
        *, delta: float = 0.2, alpha: float = 1.0, p: Optional[float] = None, **kw
    ) -> ClusterOutcome:
        kw.setdefault("backend", self.backend)
        kw.setdefault("device", self.device)
        with _span("dbscanpp.run", n=len(vectors), force=True) as run:
            if p is None:
                pred = self.predict_counts(vectors, eps)
                p = auto_sample_fraction(pred, tau, alpha, delta)
            res = dbscan_pp(vectors, eps, tau, p, seed=self.seed, **kw)
            run.sync_on((res.labels, res.core))
        return ClusterOutcome(res, run.dur, 0.0, "DBSCAN++",
                              {"eps": eps, "tau": tau, "p": p})

    def cluster_laf_dbscan_pp(
        self, vectors: np.ndarray, eps: float, tau: int,
        *, delta: float = 0.2, alpha: float = 1.0, p: Optional[float] = None, **kw
    ) -> ClusterOutcome:
        kw.setdefault("backend", self.backend)
        kw.setdefault("device", self.device)
        with _span("laf.run", n=len(vectors), force=True) as run:
            with _span("laf.predict", n=len(vectors), force=True) as pre:
                pred_all = self.predict_counts(vectors, eps)
                if p is None:
                    p = auto_sample_fraction(pred_all, tau, alpha, delta)
                n = vectors.shape[0]
                m = max(1, int(round(p * n)))
                rng = np.random.default_rng(self.seed)
                sample_idx = np.sort(rng.choice(n, size=m, replace=False))
                pre.sync_on(pred_all)
            res = laf_dbscan_pp(
                vectors, eps, tau, p, pred_all[sample_idx],
                alpha=alpha, seed=self.seed, sample_idx=sample_idx, **kw
            )
            run.sync_on((res.labels, res.core))
        return ClusterOutcome(res, run.dur, pre.dur, "LAF-DBSCAN++",
                              {"eps": eps, "tau": tau, "p": p, "alpha": alpha})
