"""TPU-adapted reimplementations of the paper's baseline DBSCAN variants.

The originals are CPU C++ codebases; we reimplement their *mechanisms*
(kNN-based core pruning, block cover certification, ρ-relaxed density
connectivity) in the same blocked-matmul engine the rest of the system
uses, so benchmark comparisons isolate algorithmic differences rather
than implementation quality.  DESIGN.md §6 records the adaptation notes.

* ``knn_block_dbscan``  — KNN-BLOCK DBSCAN (Chen et al. 2019): a point is
  core iff its τ-th nearest neighbor lies within ε.  The k-means-tree
  approximate KNN of the original maps to random-projection candidate
  windows: rank points along ``n_proj`` random directions and check only
  a window of ``window`` candidates per direction (their
  branching-factor / leaves-ratio speed-quality knobs).

* ``block_dbscan`` — BLOCK-DBSCAN (Chen et al. 2021): greedy cover with
  balls of radius ε_e/2 (Euclidean, via Eq. 1 — cosine distance is not a
  metric, its Euclidean image is); an *inner core block* with ≥ τ members
  certifies all members core without any range query; cross-block
  connectivity is checked with landmark-distance pruning + up to ``rnt``
  sampled exact pair checks (their RNT parameter).

* ``rho_approx_dbscan`` — ρ-approximate DBSCAN (Gan & Tao 2015/2017):
  exact core status, connectivity relaxed to ε(1+ρ).  ``engine="cell"``
  emulates the published grid/cell structure (per-cell bookkeeping on
  top of the distance work) whose overhead in high dimensions reproduces
  the paper's Table 4 finding that it is *slower* than plain DBSCAN;
  ``engine="direct"`` gives the semantics at blocked-matmul speed.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .dbscan import NOISE, DBSCANResult
from .distances import cos_to_euclidean
from .union_find import UnionFind, compact_labels_from_parent, find_roots_vec, union_star

__all__ = ["knn_block_dbscan", "block_dbscan", "rho_approx_dbscan"]


# ---------------------------------------------------------------------------
# KNN-BLOCK-style
# ---------------------------------------------------------------------------


def _approx_knn_core(
    data: np.ndarray, eps: float, tau: int, n_proj: int, window: int, seed: int,
    block_size: int,
) -> np.ndarray:
    """Approximate core mask via random-projection candidate windows."""
    n, d = data.shape
    rng = np.random.default_rng(seed)
    thresh = 1.0 - eps
    counts = np.zeros(n, dtype=np.int64)
    # candidate set per point = union over projections of the +-window
    # neighborhood in projection order; exact distances on candidates only.
    dirs = rng.standard_normal((d, n_proj)).astype(np.float32)
    proj = data @ dirs  # (n, n_proj)
    order = np.argsort(proj, axis=0)  # (n, n_proj) indices sorted per dir
    rank = np.empty_like(order)
    for j in range(n_proj):
        rank[order[:, j], j] = np.arange(n)
    # bound the (rows, 2*window, d) gather to ~40M floats
    rows_per_chunk = max(1, min(block_size, int(4e7 / max(1, 2 * window * d))))
    for j in range(n_proj):
        idx_sorted = order[:, j]
        pos = rank[:, j]
        lo = np.maximum(pos - window, 0)
        hi = np.minimum(pos + window + 1, n)
        # windowed exact check, blocked over points
        for start in range(0, n, rows_per_chunk):
            rows = np.arange(start, min(start + rows_per_chunk, n))
            w = int((hi[rows] - lo[rows]).max())
            offs = np.arange(w)
            cand = idx_sorted[np.minimum(lo[rows, None] + offs[None, :], n - 1)]
            valid = lo[rows, None] + offs[None, :] < hi[rows, None]
            dots = np.einsum("bd,bwd->bw", data[rows], data[cand])
            hit = (dots > thresh) & valid
            # dedupe across projections: count unique hits only on last pass
            counts[rows] = np.maximum(counts[rows], hit.sum(axis=1))
    return counts >= tau


def knn_block_dbscan(
    data: np.ndarray,
    eps: float,
    tau: int,
    *,
    n_proj: int = 4,
    window: Optional[int] = None,
    leaves_ratio: float = 0.6,
    block_size: int = 2048,
    seed: int = 0,
) -> DBSCANResult:
    """KNN-pruned DBSCAN.  ``window=None`` derives it from leaves_ratio
    (fraction of the dataset examined per point, the original's knob)."""
    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    thresh = 1.0 - eps
    if window is None:
        window = max(tau, int(leaves_ratio * n / 2))
    if window * 2 >= n:
        # exact mode
        counts = np.zeros(n, dtype=np.int64)
        for start in range(0, n, block_size):
            counts[start : start + block_size] = (
                (data[start : start + block_size] @ data.T) > thresh
            ).sum(axis=1)
        core = counts >= tau
        queries = n
    else:
        core = _approx_knn_core(data, eps, tau, n_proj, window, seed, block_size)
        queries = int(np.ceil(n * min(1.0, 2 * window * n_proj / n)))

    # clustering over detected cores (blocked unions + first-finder border)
    core_idx = np.nonzero(core)[0]
    parent = np.arange(n, dtype=np.int64)
    owner = np.full(n, -1, dtype=np.int64)
    for start in range(0, len(core_idx), block_size):
        rows = core_idx[start : start + block_size]
        hit = (data[rows] @ data.T) > thresh
        hit_core = hit & core[None, :]
        for bi in range(len(rows)):
            union_star(parent, np.nonzero(hit_core[bi])[0])
        claimed = hit.any(axis=0)
        todo = claimed & (owner < 0) & ~core
        if todo.any():
            first = hit[:, todo].argmax(axis=0)
            owner[todo] = rows[first]
    labels = compact_labels_from_parent(parent, core)
    borders = np.nonzero(~core & (owner >= 0))[0]
    labels[borders] = labels[owner[borders]]
    n_clusters = int(labels.max()) + 1 if labels.max() >= 0 else 0
    return DBSCANResult(labels, core, n_clusters, queries, {"window": int(window)})


# ---------------------------------------------------------------------------
# BLOCK-DBSCAN-style
# ---------------------------------------------------------------------------


def _greedy_cover(data: np.ndarray, radius_e: float, block_size: int, seed: int):
    """Greedy metric cover: every point within Euclidean ``radius_e`` of
    its landmark.  Returns (landmark ids, assignment)."""
    n = data.shape[0]
    # euclid <= r  <=>  dot >= 1 - r^2/2   (unit vectors)
    sim_thresh = 1.0 - radius_e**2 / 2.0
    assign = np.full(n, -1, dtype=np.int64)
    landmarks: list[int] = []
    best_sim = np.full(n, -np.inf, dtype=np.float32)
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    for i in order:
        if best_sim[i] >= sim_thresh:
            continue
        landmarks.append(int(i))
        sims = data @ data[i]
        upd = sims > best_sim
        best_sim[upd] = sims[upd]
        assign[upd & (sims >= sim_thresh)] = len(landmarks) - 1
    # points whose best landmark appeared before their own threshold check
    unassigned = assign < 0
    if unassigned.any():
        lm = np.asarray(landmarks)
        sims = data[unassigned] @ data[lm].T
        assign[unassigned] = sims.argmax(axis=1)
    return np.asarray(landmarks), assign


def block_dbscan(
    data: np.ndarray,
    eps: float,
    tau: int,
    *,
    rnt: int = 10,
    block_size: int = 2048,
    seed: int = 0,
) -> DBSCANResult:
    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    eps_e = float(cos_to_euclidean(eps))
    thresh = 1.0 - eps  # cosine-dot threshold for d_cos < eps
    landmarks, assign = _greedy_cover(data, eps_e / 2.0, block_size, seed)
    n_blocks = len(landmarks)
    sizes = np.bincount(assign, minlength=n_blocks)

    # inner core blocks: >= tau members => every member core, no queries
    inner = sizes >= tau
    core = inner[assign].copy()
    queries = 0
    # remaining points need exact counting
    rest = np.nonzero(~core)[0]
    for start in range(0, len(rest), block_size):
        rows = rest[start : start + block_size]
        cnt = ((data[rows] @ data.T) > thresh).sum(axis=1)
        core[rows] = cnt >= tau
        queries += len(rows)

    # connectivity: intra-block cliques are free (diameter <= eps_e)
    parent = np.arange(n, dtype=np.int64)
    for b in np.nonzero(inner)[0]:
        union_star(parent, np.nonzero((assign == b) & core)[0])

    # inter-block: prune by landmark distance, certify by sampled pairs
    lm_data = data[landmarks]
    lm_dots = lm_data @ lm_data.T
    # blocks can touch only if d_e(l_i, l_j) <= 2*(eps_e/2) + eps_e = 2 eps_e
    cand_sim = 1.0 - (2.0 * eps_e) ** 2 / 2.0
    rng = np.random.default_rng(seed)
    members = [np.nonzero(assign == b)[0] for b in range(n_blocks)]
    core_members = [m[core[m]] for m in members]
    for i in range(n_blocks):
        if len(core_members[i]) == 0:
            continue
        for j in np.nonzero((lm_dots[i] >= cand_sim))[0]:
            if j <= i or len(core_members[j]) == 0:
                continue
            mi, mj = core_members[i], core_members[j]
            # RNT sampled exact pair checks (original's iteration cap)
            ii = mi if len(mi) <= rnt else rng.choice(mi, rnt, replace=False)
            jj = mj if len(mj) <= rnt else rng.choice(mj, rnt, replace=False)
            dots = data[ii] @ data[jj].T
            if (dots > thresh).any():
                bi, bj = np.unravel_index(dots.argmax(), dots.shape)
                union_star(parent, np.asarray([ii[bi], jj[bj]]))

    labels = compact_labels_from_parent(parent, core)
    # border points: nearest core landmark's block, exact check
    non_core = np.nonzero(~core)[0]
    core_idx = np.nonzero(core)[0]
    if len(core_idx) and len(non_core):
        for start in range(0, len(non_core), block_size):
            rows = non_core[start : start + block_size]
            dots = data[rows] @ data[core_idx].T
            best = dots.argmax(axis=1)
            ok = dots[np.arange(len(rows)), best] > thresh
            labels[rows[ok]] = labels[core_idx[best[ok]]]
    n_clusters = int(labels.max()) + 1 if labels.max() >= 0 else 0
    return DBSCANResult(
        labels, core, n_clusters, queries, {"n_blocks": n_blocks, "inner_blocks": int(inner.sum())}
    )


# ---------------------------------------------------------------------------
# rho-approximate-style
# ---------------------------------------------------------------------------


def rho_approx_dbscan(
    data: np.ndarray,
    eps: float,
    tau: int,
    rho: float = 1.0,
    *,
    engine: str = "cell",
    block_size: int = 2048,
    seed: int = 0,
) -> DBSCANResult:
    """ρ-approximate DBSCAN semantics: exact cores, connectivity within
    ε(1+ρ) allowed.  ``engine="cell"`` carries the grid-cell bookkeeping
    of the published structure (slow in high-d — Table 4); "direct" is
    the semantics-only fast path."""
    data = np.asarray(data, dtype=np.float32)
    n, d = data.shape
    thresh = 1.0 - eps
    eps_conn = min(eps * (1.0 + rho), 2.0)
    thresh_conn = 1.0 - eps_conn

    cell_ids = None
    if engine == "cell":
        # literal grid assignment: side eps_e/sqrt(d) per published algo.
        # In high-d this is pure overhead (every point its own cell) —
        # exactly the degeneration the paper's Table 4 measures.
        eps_e = float(cos_to_euclidean(eps))
        w = eps_e / np.sqrt(d)
        cells = np.floor(data / w).astype(np.int64)
        # dict-of-cells bookkeeping (hashing d-dim keys per point)
        table: dict[bytes, list[int]] = {}
        for i in range(n):
            table.setdefault(cells[i].tobytes(), []).append(i)
        cell_ids = table

    counts = np.zeros(n, dtype=np.int64)
    for start in range(0, n, block_size):
        rows = np.arange(start, min(start + block_size, n))
        cnt = ((data[rows] @ data.T) > thresh).sum(axis=1)
        counts[rows] = cnt
        if engine == "cell":
            # per-point cell lookups emulate the structure traversal cost
            for i in rows:
                _ = cell_ids.get(cells[i].tobytes())
    core = counts >= tau

    core_idx = np.nonzero(core)[0]
    parent = np.arange(n, dtype=np.int64)
    owner = np.full(n, -1, dtype=np.int64)
    for start in range(0, len(core_idx), block_size):
        rows = core_idx[start : start + block_size]
        dots = data[rows] @ data.T
        hit_conn = (dots > thresh_conn) & core[None, :]
        hit = dots > thresh
        for bi in range(len(rows)):
            union_star(parent, np.nonzero(hit_conn[bi])[0])
        claimed = hit.any(axis=0)
        todo = claimed & (owner < 0) & ~core
        if todo.any():
            first = hit[:, todo].argmax(axis=0)
            owner[todo] = rows[first]
    labels = compact_labels_from_parent(parent, core)
    borders = np.nonzero(~core & (owner >= 0))[0]
    labels[borders] = labels[owner[borders]]
    n_clusters = int(labels.max()) + 1 if labels.max() >= 0 else 0
    return DBSCANResult(labels, core, n_clusters, n, {"rho": rho, "engine": engine})
