"""LAF post-processing — Algorithms 2 and 3 of the paper.

``UpdatePartialNeighbors`` (Alg. 2): after every *executed* range query
(P, N), every neighbor P_n already registered in the partial-neighbor
map 𝓔 gains P as a partial neighbor.

``PostProcessing`` (Alg. 3): a registered point P with |𝓔(P)| ≥ τ is a
detected false-negative core prediction.  The clusters of its partial
neighbors were wrongly separated by P, so they are merged into one
destination cluster (that of a randomly selected non-noise member).  We
additionally assign P itself to the destination cluster — P is a proven
core point, and leaving it noise would contradict DBSCAN semantics; the
paper's published code does the same (merge implies membership).
Merging is transitive across rescue points; a union-find over cluster
ids realizes exactly the sequential chain of merges.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set

import numpy as np

from .union_find import UnionFind

__all__ = ["PartialNeighborMap", "update_partial_neighbors", "post_processing"]

NOISE = -1
UNDEFINED = -2


class PartialNeighborMap:
    """The map 𝓔: predicted-stop point -> set of partial neighbors."""

    def __init__(self):
        self._map: Dict[int, Set[int]] = {}

    def register(self, p: int) -> None:
        """Lines 8 / 27 of Algorithm 1: ``if P not in 𝓔 then 𝓔(P) := ∅``."""
        self._map.setdefault(int(p), set())

    def __contains__(self, p: int) -> bool:
        return int(p) in self._map

    def __getitem__(self, p: int) -> Set[int]:
        return self._map[int(p)]

    def items(self):
        return self._map.items()

    def __len__(self):
        return len(self._map)


def update_partial_neighbors(p: int, neighbors, emap: PartialNeighborMap) -> PartialNeighborMap:
    """Algorithm 2, verbatim."""
    for pn in neighbors:
        pn = int(pn)
        if pn in emap:
            emap[pn].add(int(p))
    return emap


def post_processing(
    labels: np.ndarray,
    emap: PartialNeighborMap,
    tau: int,
    *,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Algorithm 3 with transitive merges via union-find.

    Returns updated labels (same id space; merged clusters collapse onto
    the destination's representative id).
    """
    rng = rng or np.random.default_rng(0)
    labels = labels.copy()
    n_clusters = int(labels.max()) + 1 if labels.max() >= 0 else 0
    if n_clusters == 0:
        return labels
    uf = UnionFind(n_clusters)
    rescued: List[tuple[int, int]] = []  # (point, destination cluster id)

    for p, partial in emap.items():
        if len(partial) < tau:
            continue
        members = np.fromiter(partial, dtype=np.int64)
        member_labels = labels[members]
        non_noise = member_labels[member_labels >= 0]
        if len(non_noise) == 0:
            continue
        # line 3: randomly select a non-noise neighbor P' in 𝓔(P)
        dest = int(rng.choice(non_noise))
        # line 5: merge the clusters of 𝓔(P) into the destination cluster
        for c in np.unique(non_noise):
            uf.union(dest, int(c))
        rescued.append((int(p), dest))

    remap = np.array([uf.find(c) for c in range(n_clusters)], dtype=np.int64)
    mask = labels >= 0
    labels[mask] = remap[labels[mask]]
    for p, dest in rescued:
        labels[p] = remap[dest]
    return labels
