from .dbscan import DBSCANResult, dbscan_parallel, dbscan_sequential, NOISE, UNDEFINED  # noqa: F401
from .laf_dbscan import laf_dbscan, laf_dbscan_sequential  # noqa: F401
from .metrics import adjusted_mutual_info, adjusted_rand_index  # noqa: F401
