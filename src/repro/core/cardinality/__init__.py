from .rmi import RMIConfig, init_rmi, rmi_predict, rmi_predict_counts, mlp_apply  # noqa: F401
from .features import featurize, build_training_set  # noqa: F401
from .training import train_rmi, TrainedEstimator  # noqa: F401
