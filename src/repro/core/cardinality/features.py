"""Featurization + training-set construction for the cardinality estimator.

Paper §1: the estimator input is (query point, distance threshold); the
training set uses cosine thresholds 0.1..0.9 ("enough to cover most
cases" because cosine distance is bounded).  Ground-truth counts come
from one blocked matmul pass per training batch: all thresholds share
the same dot products, so the eps-grid costs one comparison per
threshold, not one matmul per threshold.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["featurize", "multi_eps_counts", "build_training_set", "DEFAULT_EPS_GRID"]

DEFAULT_EPS_GRID: Tuple[float, ...] = tuple(np.round(np.arange(0.1, 0.91, 0.1), 2))


def featurize(queries: jax.Array, eps) -> jax.Array:
    """Concat query vectors with the (broadcast) eps feature -> (n, d+1)."""
    queries = jnp.asarray(queries)
    e = jnp.broadcast_to(jnp.asarray(eps, queries.dtype).reshape(-1), (queries.shape[0],))
    return jnp.concatenate([queries, e[:, None]], axis=1)


@functools.partial(jax.jit, static_argnames=("eps_grid", "block_size"))
def multi_eps_counts(
    queries: jax.Array,
    db: jax.Array,
    eps_grid: Tuple[float, ...],
    *,
    block_size: int = 2048,
) -> jax.Array:
    """Exact counts for every (query, eps) pair: (n_eps, nq) int32."""
    nq, d = queries.shape
    nd = db.shape[0]
    nblocks = -(-nd // block_size)
    pad = nblocks * block_size - nd
    dbp = jnp.pad(db, ((0, pad), (0, 0))).reshape(nblocks, block_size, d)
    valid = (jnp.arange(nblocks * block_size) < nd).reshape(nblocks, block_size)
    thresholds = 1.0 - jnp.asarray(eps_grid)  # dot > 1 - eps

    def body(acc, blk):
        dbb, vb = blk
        dots = queries @ dbb.T  # (nq, block)
        hit = (dots[None, :, :] > thresholds[:, None, None]) & vb[None, None, :]
        return acc + jnp.sum(hit, axis=2, dtype=jnp.int32), None

    init = jnp.zeros((len(eps_grid), nq), jnp.int32)
    counts, _ = jax.lax.scan(body, init, (dbp, valid))
    return counts


def build_training_set(
    train_vectors: np.ndarray,
    eps_grid: Sequence[float] = DEFAULT_EPS_GRID,
    *,
    query_batch: int = 4096,
    block_size: int = 2048,
) -> Tuple[np.ndarray, np.ndarray]:
    """(features, targets) over the full (train point × eps) grid.

    features: (n*|grid|, d+1) float32;  targets: z = log2(1+count) float32.
    Counts are w.r.t. the training split itself (paper trains the
    estimator on the 80% split and clusters the 20% split).
    """
    train_vectors = np.asarray(train_vectors, np.float32)
    n, d = train_vectors.shape
    grid = tuple(float(e) for e in eps_grid)
    feats, targets = [], []
    for start in range(0, n, query_batch):
        q = train_vectors[start : start + query_batch]
        counts = np.asarray(
            multi_eps_counts(q, train_vectors, grid, block_size=block_size)
        )  # (n_eps, b)
        for ei, e in enumerate(grid):
            f = np.concatenate([q, np.full((q.shape[0], 1), e, np.float32)], axis=1)
            feats.append(f)
            targets.append(np.log2(1.0 + counts[ei]).astype(np.float32))
    return np.concatenate(feats, axis=0), np.concatenate(targets, axis=0)
