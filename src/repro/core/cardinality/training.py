"""RMI estimator training — stage-by-stage, per Kraska et al. / the paper.

Paper §3.1: "On each training set, the cardinality estimator is trained
for 200 epochs with batch size 512."  Stage 0 trains on all examples;
examples are then routed by the *trained* stage-0 predictions to the
stage-1 experts, each of which trains on its share; likewise stage 2.
Loss is MSE on z = log2(1 + count).
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...train.optimizer import adam, apply_updates
from .features import build_training_set, featurize
from .rmi import RMIConfig, init_mlp, mlp_apply, rmi_predict, rmi_predict_counts, rmi_route, stack_stage

__all__ = ["TrainedEstimator", "train_mlp", "train_rmi"]


@dataclass
class TrainedEstimator:
    params: Dict[str, Any]
    cfg: RMIConfig
    history: Dict[str, List[float]] = field(default_factory=dict)
    train_seconds: float = 0.0
    train_n: int = 0  # size of the split the counts were learned against

    def predict_z(self, queries, eps) -> jax.Array:
        return rmi_predict(self.params, featurize(queries, eps), self.cfg)

    def predict_counts(self, queries, eps, *, reference_n: Optional[int] = None) -> np.ndarray:
        """Predicted cardinalities.  ``reference_n`` rescales from the
        training-split scale to a target dataset size (the paper instead
        absorbs this gap in the per-dataset error factor α)."""
        c = np.asarray(rmi_predict_counts(self.params, featurize(queries, eps), self.cfg))
        if reference_n is not None and self.train_n:
            c = c * (reference_n / self.train_n)
        return c


@functools.partial(jax.jit, static_argnames=("opt_update",), donate_argnums=(0, 1))
def _train_step(params, opt_state, x, y, opt_update):
    def loss_fn(p):
        pred = mlp_apply(p, x)
        return jnp.mean(jnp.square(pred - y))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    updates, opt_state = opt_update(grads, opt_state)
    params = apply_updates(params, updates)
    return params, opt_state, loss


def train_mlp(
    key: jax.Array,
    feats: np.ndarray,
    targets: np.ndarray,
    cfg: RMIConfig,
    *,
    epochs: int,
    batch_size: int,
    lr: float,
) -> Tuple[Any, List[float]]:
    """Train one FC net (4 hidden layers, widths per cfg) with Adam/MSE."""
    n = feats.shape[0]
    params = init_mlp(key, cfg.input_dim, cfg.hidden, cfg.dtype)
    opt = adam(lr)
    opt_state = opt.init(params)
    losses: List[float] = []
    rng = np.random.default_rng(np.asarray(jax.random.key_data(key)).ravel()[-1])
    nb = max(1, n // batch_size)
    for _ in range(epochs):
        perm = rng.permutation(n)
        epoch_loss = 0.0
        for b in range(nb):
            idx = perm[b * batch_size : (b + 1) * batch_size]
            if len(idx) == 0:
                continue
            x = jnp.asarray(feats[idx])
            y = jnp.asarray(targets[idx])
            params, opt_state, loss = _train_step(params, opt_state, x, y, opt.update)
            epoch_loss += float(loss)
        losses.append(epoch_loss / nb)
    return params, losses


def train_rmi(
    train_vectors: np.ndarray,
    *,
    eps_grid=None,
    epochs: int = 200,
    batch_size: int = 512,
    lr: float = 1e-3,
    seed: int = 0,
    feats_targets: Optional[Tuple[np.ndarray, np.ndarray]] = None,
) -> TrainedEstimator:
    """Full stage-wise RMI training on a training split."""
    from .features import DEFAULT_EPS_GRID

    t0 = time.time()
    if feats_targets is None:
        feats, targets = build_training_set(
            train_vectors, eps_grid or DEFAULT_EPS_GRID
        )
    else:
        feats, targets = feats_targets
    cfg = RMIConfig(input_dim=feats.shape[1], target_max=float(targets.max()) + 1e-6)
    key = jax.random.PRNGKey(seed)
    history: Dict[str, List[float]] = {}

    # ---- stage 0: one net on everything -------------------------------
    key, sub = jax.random.split(key)
    stage0, losses0 = train_mlp(
        sub, feats, targets, cfg, epochs=epochs, batch_size=batch_size, lr=lr
    )
    history["stage0"] = losses0
    params: Dict[str, Any] = {"stage0": stage0}

    # ---- deeper stages: route by previous stage's prediction ----------
    feats_j = jnp.asarray(feats)
    pred = np.asarray(mlp_apply(stage0, feats_j))
    for s in range(1, len(cfg.stage_sizes)):
        n_exp = cfg.stage_sizes[s]
        route = np.asarray(rmi_route(jnp.asarray(pred), n_exp, cfg.target_max))
        nets, new_pred = [], np.zeros_like(pred)
        for e in range(n_exp):
            sel = route == e
            key, sub = jax.random.split(key)
            if sel.sum() < 2:  # degenerate share: clone previous-stage behaviour
                net = init_mlp(sub, cfg.input_dim, cfg.hidden, cfg.dtype)
                losses = []
            else:
                net, losses = train_mlp(
                    sub, feats[sel], targets[sel], cfg,
                    epochs=epochs, batch_size=batch_size, lr=lr,
                )
            history[f"stage{s}_expert{e}"] = losses
            nets.append(net)
            if sel.any():
                new_pred[sel] = np.asarray(mlp_apply(net, jnp.asarray(feats[sel])))
        params[f"stage{s}"] = stack_stage(nets)
        pred = new_pred

    return TrainedEstimator(params, cfg, history, time.time() - t0, train_n=len(train_vectors))
