"""Recursive Model Index (RMI) cardinality estimator.

Per the paper §3.1: an RMI with three stages of 1 / 2 / 4 fully-connected
neural networks (top to bottom); every net has 4 hidden layers of widths
512, 512, 256, 128.  Input = (query vector ⊕ distance threshold), output
= predicted cardinality (we regress z = log2(1 + count), the standard
monotone stabilizing transform; inverted at prediction time).

Routing (Kraska et al. 2018): the stage-k prediction, scaled by the
training-set maximum target, picks which stage-(k+1) expert refines it.
On TPU we evaluate *all* experts of a stage in one batched matmul and
select by one-hot — branchless, MXU-friendly (experts-as-batch).  The
fused single-kernel version lives in ``repro.kernels.rmi_mlp``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "RMIConfig",
    "init_mlp",
    "mlp_apply",
    "init_rmi",
    "rmi_route",
    "rmi_predict",
    "rmi_predict_counts",
    "stack_stage",
]

HIDDEN = (512, 512, 256, 128)  # paper: 4 hidden layers, widths 512,512,256,128
STAGE_SIZES = (1, 2, 4)        # paper: 3 stages with 1, 2, 4 nets


@dataclass(frozen=True)
class RMIConfig:
    input_dim: int                      # d + 1 (query ⊕ eps)
    hidden: Sequence[int] = HIDDEN
    stage_sizes: Sequence[int] = STAGE_SIZES
    target_max: float = 16.0            # max of z = log2(1+count) on train set
    dtype: Any = jnp.float32


def init_mlp(key: jax.Array, input_dim: int, hidden: Sequence[int], dtype=jnp.float32):
    """He-initialized MLP params: list of (W, b), final layer -> scalar."""
    dims = [input_dim, *hidden, 1]
    params = []
    for i in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (dims[i], dims[i + 1]), dtype) * jnp.sqrt(
            2.0 / dims[i]
        ).astype(dtype)
        b = jnp.zeros((dims[i + 1],), dtype)
        params.append((w, b))
    return params


def mlp_apply(params, x: jax.Array) -> jax.Array:
    """(batch, input_dim) -> (batch,) regression output; ReLU hidden layers."""
    h = x
    for w, b in params[:-1]:
        h = jax.nn.relu(h @ w + b)
    w, b = params[-1]
    return (h @ w + b)[:, 0]


def stack_stage(nets: List[Any]):
    """Stack per-expert param pytrees along a leading expert axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *nets)


def init_rmi(key: jax.Array, cfg: RMIConfig) -> Dict[str, Any]:
    """Params: {"stage0": mlp, "stage1": stacked(2), "stage2": stacked(4)}."""
    keys = jax.random.split(key, sum(cfg.stage_sizes))
    ki = iter(keys)
    stages = {}
    for s, size in enumerate(cfg.stage_sizes):
        nets = [init_mlp(next(ki), cfg.input_dim, cfg.hidden, cfg.dtype) for _ in range(size)]
        stages[f"stage{s}"] = stack_stage(nets) if size > 1 else nets[0]
    return stages


def rmi_route(pred: jax.Array, n_next: int, target_max: float) -> jax.Array:
    """Map a stage prediction to the next-stage expert index."""
    idx = jnp.floor(pred / target_max * n_next).astype(jnp.int32)
    return jnp.clip(idx, 0, n_next - 1)


def _stage_apply_all(stacked_params, x: jax.Array) -> jax.Array:
    """Evaluate all E experts of a stage: (batch, dim) -> (E, batch)."""
    return jax.vmap(lambda p: mlp_apply(p, x))(stacked_params)


@functools.partial(jax.jit, static_argnames=("stage_sizes",))
def _rmi_predict_impl(params, x, target_max, stage_sizes: Tuple[int, ...]):
    pred = mlp_apply(params["stage0"], x)
    for s in range(1, len(stage_sizes)):
        n = stage_sizes[s]
        idx = rmi_route(pred, n, target_max)
        all_preds = _stage_apply_all(params[f"stage{s}"], x)  # (n, batch)
        pred = jnp.take_along_axis(all_preds, idx[None, :], axis=0)[0]
    return pred


def rmi_predict(params, x: jax.Array, cfg: RMIConfig) -> jax.Array:
    """Predict z = log2(1 + count) for featurized inputs (batch, d+1)."""
    return _rmi_predict_impl(params, x, cfg.target_max, tuple(cfg.stage_sizes))


def rmi_predict_counts(params, x: jax.Array, cfg: RMIConfig) -> jax.Array:
    """Predict raw cardinalities (>= 0)."""
    z = rmi_predict(params, x, cfg)
    return jnp.maximum(jnp.exp2(z) - 1.0, 0.0)
