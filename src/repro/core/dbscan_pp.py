"""DBSCAN++ (Jang & Jiang 2019) and its LAF-enhanced variant.

DBSCAN++ samples a subset S (uniform or greedy k-center), detects core
points *within S but w.r.t. the entire dataset*, grows clusters over the
sampled cores, and assigns every remaining point to the cluster of its
closest sampled core within eps (else noise).

LAF-DBSCAN++ (paper §3.1, α fixed at 1.0): the cardinality estimator
runs before each *sampled* point's range query; predicted-stop samples
are skipped and registered in 𝓔; partial neighbors accumulate from the
executed sample queries (which scan the full dataset); Algorithm 3
rescues false negatives exactly as in LAF-DBSCAN.

The paper's automatic sample fraction: p = δ + R_c, with R_c the ratio
of points the estimator predicts core and δ ∈ [0.1, 0.3].
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .dbscan import NOISE, DBSCANResult
from .postprocess import PartialNeighborMap, post_processing
from .union_find import compact_labels_from_parent, union_star

__all__ = ["auto_sample_fraction", "kcenter_sample", "dbscan_pp", "laf_dbscan_pp"]


def auto_sample_fraction(
    predicted_counts: np.ndarray, tau: int, alpha: float, delta: float = 0.2
) -> float:
    """Paper §3.1 parameter rule: p = δ + R_c (clipped to (0, 1])."""
    r_c = float(np.mean(np.asarray(predicted_counts) >= alpha * tau))
    return float(np.clip(delta + r_c, 0.01, 1.0))


def kcenter_sample(data: np.ndarray, m: int, seed: int = 0) -> np.ndarray:
    """Greedy k-center (farthest-first) sample of m indices — the
    initialization DBSCAN++ reports best results with."""
    rng = np.random.default_rng(seed)
    n = data.shape[0]
    m = min(m, n)
    first = int(rng.integers(n))
    chosen = [first]
    # max cosine similarity to any chosen center (=> min distance)
    best_sim = data @ data[first]
    for _ in range(m - 1):
        nxt = int(np.argmin(best_sim))
        chosen.append(nxt)
        best_sim = np.maximum(best_sim, data @ data[nxt])
    return np.asarray(sorted(chosen))


def _cluster_from_sampled_cores(
    data: np.ndarray,
    sample_idx: np.ndarray,
    core_in_sample: np.ndarray,
    eps: float,
    block_size: int,
    bk,
) -> np.ndarray:
    """Connected components over sampled cores + nearest-core assignment.

    Core-core edges go through the range backend; the nearest-core
    assignment below is an argmax (closest-point) query outside the
    ``RangeBackend`` contract, so it stays an exact matmul.
    """
    n = data.shape[0]
    thresh = 1.0 - eps
    core_idx = sample_idx[core_in_sample]
    labels = np.full(n, NOISE, dtype=np.int64)
    if len(core_idx) == 0:
        return labels
    core_data = data[core_idx]
    parent = np.arange(len(core_idx), dtype=np.int64)
    # core-core unions within the sample
    for start in range(0, len(core_idx), block_size):
        hit = bk.query_hits_subset(core_idx[start : start + block_size], core_idx, eps)
        for bi in range(hit.shape[0]):
            union_star(parent, np.nonzero(hit[bi])[0])
    comp = compact_labels_from_parent(parent, np.ones(len(core_idx), bool))
    # assign every point to its closest sampled core within eps
    for start in range(0, n, block_size):
        dots = data[start : start + block_size] @ core_data.T  # (b, m_core)
        best = dots.argmax(axis=1)
        ok = dots[np.arange(dots.shape[0]), best] > thresh
        rows = np.arange(start, start + dots.shape[0])
        labels[rows[ok]] = comp[best[ok]]
    return labels


def dbscan_pp(
    data: np.ndarray,
    eps: float,
    tau: int,
    p: float,
    *,
    init: str = "uniform",
    block_size: int = 2048,
    seed: int = 0,
    backend="exact",
    device="auto",
) -> DBSCANResult:
    """DBSCAN++ with sample fraction p (``device`` as in
    ``dbscan_parallel``: fused-tile vs host evaluator of the backend)."""
    from ..index import as_fitted

    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    bk = as_fitted(backend, data, block_size=block_size, device=device)
    m = max(1, int(round(p * n)))
    rng = np.random.default_rng(seed)
    if init == "kcenter":
        sample_idx = kcenter_sample(data, m, seed)
    else:
        sample_idx = np.sort(rng.choice(n, size=m, replace=False))

    # core detection: sampled queries against the ENTIRE dataset
    counts = bk.query_counts(sample_idx, eps)
    core_in_sample = counts >= tau

    labels = _cluster_from_sampled_cores(
        data, sample_idx, core_in_sample, eps, block_size, bk
    )
    core = np.zeros(n, dtype=bool)
    core[sample_idx[core_in_sample]] = True
    n_clusters = int(labels.max()) + 1 if labels.max() >= 0 else 0
    return DBSCANResult(
        labels, core, n_clusters, int(m), {"sample_fraction": p, "m": m}
    )


def laf_dbscan_pp(
    data: np.ndarray,
    eps: float,
    tau: int,
    p: float,
    predicted_counts_sample: np.ndarray,
    *,
    alpha: float = 1.0,
    init: str = "uniform",
    block_size: int = 2048,
    seed: int = 0,
    sample_idx: Optional[np.ndarray] = None,
    backend="exact",
    device="auto",
) -> DBSCANResult:
    """LAF-DBSCAN++: skip sampled range queries for predicted-stop samples.

    ``predicted_counts_sample`` aligns with the sample (either the given
    ``sample_idx`` or the one this function draws with ``seed`` — drawn
    identically to :func:`dbscan_pp` so the two share samples in
    benchmarks).  ``device`` as in ``dbscan_parallel``.
    """
    from ..index import as_fitted

    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    bk = as_fitted(backend, data, block_size=block_size, device=device)
    m = max(1, int(round(p * n)))
    rng = np.random.default_rng(seed)
    if sample_idx is None:
        if init == "kcenter":
            sample_idx = kcenter_sample(data, m, seed)
        else:
            sample_idx = np.sort(rng.choice(n, size=m, replace=False))
    m = len(sample_idx)

    predicted_core = np.asarray(predicted_counts_sample) >= alpha * tau
    exec_rows = sample_idx[predicted_core]

    counts = np.zeros(m, dtype=np.int64)
    partial_counts = np.zeros(n, dtype=np.int64)
    for start in range(0, len(exec_rows), block_size):
        rows = exec_rows[start : start + block_size]
        hit = bk.query_hits(rows, eps)
        # map back to sample positions
        pos = np.searchsorted(sample_idx, rows)
        counts[pos] = hit.sum(axis=1)
        partial_counts += hit.sum(axis=0)
    core_in_sample = predicted_core & (counts >= tau)

    labels = _cluster_from_sampled_cores(
        data, sample_idx, core_in_sample, eps, block_size, bk
    )

    # ---- post-processing (Algorithm 3) over predicted-stop samples -----
    in_sample_stop = np.zeros(n, dtype=bool)
    in_sample_stop[sample_idx[~predicted_core]] = True
    rescue_mask = in_sample_stop & (partial_counts >= tau)
    rescue_idx = np.nonzero(rescue_mask)[0]
    emap = PartialNeighborMap()
    if len(rescue_idx) > 0:
        for start in range(0, len(exec_rows), block_size):
            rows = exec_rows[start : start + block_size]
            hit = bk.query_hits_subset(rows, rescue_idx, eps)
            for ri in np.nonzero(hit.any(axis=0))[0]:
                r = int(rescue_idx[ri])
                emap.register(r)
                emap[r].update(int(f) for f in rows[hit[:, ri]])
    labels = post_processing(labels, emap, tau, rng=np.random.default_rng(seed))

    core = np.zeros(n, dtype=bool)
    core[sample_idx[core_in_sample]] = True
    n_clusters = len(np.unique(labels[labels >= 0]))
    extras = {
        "sample_fraction": p,
        "m": int(m),
        "n_skipped": int(m - len(exec_rows)),
        "n_rescued": int(len(rescue_idx)),
    }
    return DBSCANResult(labels, core, n_clusters, int(len(exec_rows)), extras)
