"""Distance primitives for angular-distance clustering.

The paper (LAF, §1) targets *angular* metrics — cosine distance on
L2-normalized neural embeddings — because the bounded range (0..2) makes
the learned cardinality estimator trainable.  Equation 1 of the paper
converts cosine thresholds to Euclidean ones for Euclidean-only
baselines:  d_euc = sqrt(2 * d_cos)  when |u| = |v| = 1.

All batch distance computation is expressed as matmul so the TPU MXU is
the execution engine; the Pallas kernel in ``repro.kernels.range_count``
fuses the threshold/count step into the same VMEM tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "l2_normalize",
    "cosine_distance",
    "pairwise_cosine_distance",
    "cos_to_euclidean",
    "euclidean_to_cos",
]


def l2_normalize(x: jax.Array, axis: int = -1, eps: float = 1e-12) -> jax.Array:
    """L2-normalize vectors along ``axis`` (paper §3.1: all data normalized)."""
    norm = jnp.sqrt(jnp.sum(jnp.square(x), axis=axis, keepdims=True))
    return x / jnp.maximum(norm, eps)


def cosine_distance(u: jax.Array, v: jax.Array) -> jax.Array:
    """Cosine distance 1 - <u,v> for *normalized* u, v (elementwise batched)."""
    return 1.0 - jnp.sum(u * v, axis=-1)


def pairwise_cosine_distance(q: jax.Array, db: jax.Array) -> jax.Array:
    """All-pairs cosine distance: (nq, d) x (nd, d) -> (nq, nd).

    Inputs must be L2-normalized.  This is the matmul form used by the
    range-query engine: one MXU pass, distance = 1 - Q @ D^T.
    """
    return 1.0 - q @ db.T


def cos_to_euclidean(d_cos):
    """Paper Eq. 1: d_euc = sqrt(2 * d_cos) for unit vectors."""
    return np.sqrt(2.0 * np.asarray(d_cos))


def euclidean_to_cos(d_euc):
    """Inverse of Eq. 1: d_cos = d_euc^2 / 2."""
    d = np.asarray(d_euc)
    return d * d / 2.0
