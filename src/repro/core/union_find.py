"""Connected components: host union-find + JAX min-label propagation.

Cluster formation in the batch-parallel DBSCAN engine is the connected
components of the core-core eps-graph.  Two interchangeable backends:

* ``UnionFind`` / ``connected_components_host`` — classic path-halving
  union-find on the host, used by the CPU benchmark engine (fast for the
  paper's 50k-150k scale).
* ``label_propagation`` — pure-JAX iterated min-label propagation with
  pointer jumping over packed uint32 adjacency bitmaps; this is the form
  that runs sharded on the TPU mesh (and the oracle for the
  ``label_prop`` Pallas kernel).
"""

from __future__ import annotations

import functools
from typing import Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "UnionFind",
    "connected_components_host",
    "find_roots_vec",
    "union_star",
    "compact_labels",
    "compact_labels_from_parent",
    "label_propagation",
    "label_propagation_dense",
]


def compact_labels(labels: np.ndarray) -> np.ndarray:
    """Renumber non-negative labels to 0..k-1 (order-preserving), one
    ``np.unique`` pass; negative labels (noise) are kept as-is."""
    out = labels.copy()
    pos = labels >= 0
    if pos.any():
        _, inv = np.unique(labels[pos], return_inverse=True)
        out[pos] = inv
    return out


def find_roots_vec(parent: np.ndarray, nodes: np.ndarray) -> np.ndarray:
    """Vectorized multi-find with path halving over a parent array.

    Loops only graph-depth times (tiny under constant compression) with
    full-vector numpy ops — no per-element Python.
    """
    roots = np.asarray(nodes, dtype=np.int64)
    while True:
        p = parent[roots]
        gp = parent[p]
        parent[roots] = gp  # path halving
        if np.array_equal(p, gp):
            return p
        roots = gp


def union_star(parent: np.ndarray, members: np.ndarray) -> None:
    """Union all ``members`` into one component (vectorized star union)."""
    if len(members) == 0:
        return
    roots = find_roots_vec(parent, members)
    m = roots.min()
    parent[roots] = m


def compact_labels_from_parent(
    parent: np.ndarray, active: np.ndarray
) -> np.ndarray:
    """-1 for inactive nodes; components renumbered 0..k-1 by smallest member."""
    n = len(parent)
    labels = np.full(n, -1, dtype=np.int64)
    idx = np.nonzero(active)[0]
    if len(idx) == 0:
        return labels
    roots = find_roots_vec(parent, idx)
    uniq, inv = np.unique(roots, return_inverse=True)
    labels[idx] = inv
    return labels


class UnionFind:
    """Array-based union-find with path halving + union by size.

    ``grow`` extends the element universe in place (new elements start
    as singletons; existing components and their roots are untouched),
    which is what lets the streaming cluster state add points without
    rebuilding the forest.  ``parent`` is a plain array, so the
    vectorized helpers above (``find_roots_vec`` / ``union_star``)
    compose with it — they union by min root rather than by size, which
    path halving tolerates (any forest stays a valid forest).
    """

    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)
        self.size = np.ones(n, dtype=np.int64)

    def __len__(self) -> int:
        return len(self.parent)

    def grow(self, n: int) -> None:
        """Extend to ``n`` elements; no-op when already that large.

        Amortized O(new elements): ``parent``/``size`` become views into
        doubling capacity buffers, so per-batch growth in the streaming
        state never recopies the whole forest.  The buffer tails are
        pre-initialized to identity parents / unit sizes and nothing
        ever writes past the logical length (unions and path halving
        only touch existing elements), so exposing a longer view always
        reveals fresh singletons.
        """
        old = len(self.parent)
        if n <= old:
            return
        buf = getattr(self, "_parent_buf", None)
        if buf is None or n > buf.shape[0]:
            cap = max(2 * old, n, 64)
            pbuf = np.arange(cap, dtype=np.int64)
            sbuf = np.ones(cap, dtype=np.int64)
            pbuf[:old] = self.parent
            sbuf[:old] = self.size
            self._parent_buf, self._size_buf = pbuf, sbuf
        self.parent = self._parent_buf[:n]
        self.size = self._size_buf[:n]

    def find(self, x: int) -> int:
        p = self.parent
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return int(x)

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]

    def roots(self) -> np.ndarray:
        return np.array([self.find(i) for i in range(len(self.parent))])


def connected_components_host(
    n: int, edges: Iterable[Tuple[int, int]], mask: np.ndarray | None = None
) -> np.ndarray:
    """Component label per node (-1 where ``mask`` is False).

    Labels are compacted to 0..k-1 ordered by smallest member index, so
    the result is deterministic regardless of edge order.
    """
    uf = UnionFind(n)
    for a, b in edges:
        uf.union(int(a), int(b))
    roots = uf.roots()
    labels = np.full(n, -1, dtype=np.int64)
    active = np.arange(n) if mask is None else np.nonzero(mask)[0]
    remap: dict[int, int] = {}
    for i in active:
        r = roots[i]
        if r not in remap:
            remap[r] = len(remap)
        labels[i] = remap[r]
    return labels


def _min_over_neighbors(labels: jax.Array, bitmap: jax.Array, big: jax.Array):
    """For each row i: min over {labels[j] : bit j set in bitmap[i]}."""
    n = labels.shape[0]
    nw = bitmap.shape[1]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    # (n, nw*32) bool adjacency, recovered word by word to bound memory
    padded = jnp.full((nw * 32,), big, dtype=labels.dtype).at[:n].set(labels)

    def per_row(row_bits):
        bits = ((row_bits[:, None] >> shifts[None, :]) & 1).astype(bool).reshape(-1)
        return jnp.min(jnp.where(bits, padded, big))

    return jax.vmap(per_row)(bitmap)


@functools.partial(jax.jit, static_argnames=("max_iters",))
def label_propagation(
    bitmap: jax.Array, active: jax.Array, *, max_iters: int = 64
) -> jax.Array:
    """Connected-component ids by min-label propagation + pointer jumping.

    Args:
      bitmap: (n, ceil(n/32)) packed uint32 adjacency (must be symmetric
        over active nodes; self-bits are fine).
      active: (n,) bool; inactive nodes get label ``n`` (sentinel).
      max_iters: propagation rounds; with pointer jumping the number of
        required rounds is O(log n) for any topology.

    Returns (n,) int32: min active-node index of each component, or n.
    """
    n = active.shape[0]
    big = jnp.int32(n)
    init = jnp.where(active, jnp.arange(n, dtype=jnp.int32), big)

    def cond(state):
        labels, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        labels, _, it = state
        neigh = _min_over_neighbors(labels, bitmap, big)
        new = jnp.minimum(labels, jnp.where(active, neigh, big))
        # pointer jumping: label <- label of my label (labels index nodes)
        jump = jnp.where(new < n, new, 0)
        new = jnp.where(new < n, jnp.minimum(new, new[jump]), new)
        return new, jnp.any(new != labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (init, jnp.bool_(True), 0))
    return labels


@functools.partial(jax.jit, static_argnames=("max_iters",))
def label_propagation_dense(
    adj: jax.Array, active: jax.Array, *, max_iters: int = 64
) -> jax.Array:
    """Same as :func:`label_propagation` but over a dense bool adjacency."""
    n = active.shape[0]
    big = jnp.int32(n)
    init = jnp.where(active, jnp.arange(n, dtype=jnp.int32), big)

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        labels, _, it = state
        neigh = jnp.min(jnp.where(adj, labels[None, :], big), axis=1)
        new = jnp.minimum(labels, jnp.where(active, neigh, big))
        jump = jnp.where(new < n, new, 0)
        new = jnp.where(new < n, jnp.minimum(new, new[jump]), new)
        return new, jnp.any(new != labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (init, jnp.bool_(True), 0))
    return labels
