"""Jit'd wrapper: (B, H, S, D) layout, GQA head expansion, padding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_KV_BLOCK, DEFAULT_Q_BLOCK, flash_attention_pallas

__all__ = ["flash_attention"]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "kv_block", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,
    *,
    causal: bool = False,
    window=None,
    q_block: int = DEFAULT_Q_BLOCK,
    kv_block: int = DEFAULT_KV_BLOCK,
    interpret: bool = True,
):
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if hkv != hq:  # GQA: expand kv heads
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qb = min(q_block, sq) if sq % q_block else q_block
    while sq % qb:
        qb //= 2
    kb = min(kv_block, sk) if sk % kv_block else kv_block
    while sk % kb:
        kb //= 2
    out = flash_attention_pallas(
        q.reshape(b * hq, sq, d),
        k.reshape(b * hq, sk, d),
        v.reshape(b * hq, sk, d),
        causal=causal,
        window=window,
        q_block=qb,
        kv_block=kb,
        interpret=interpret,
    )
    return out.reshape(b, hq, sq, d)
