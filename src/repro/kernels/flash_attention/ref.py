"""Pure-jnp oracle: exact softmax attention (optionally causal/windowed)."""

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=False, window=None, scale=None):
    """q (B, H, Sq, D); k/v (B, H, Sk, D) -> (B, H, Sq, D) fp32."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    sq, sk = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned for decode
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = jnp.where(mask[None, None], probs, 0.0)
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
