"""Blocked online-softmax (Flash) attention Pallas kernel.

IO-aware attention for the LM training hot path: the (Sq, Sk) score
matrix never exists in HBM — each grid step owns one (q-block, kv-block)
tile and maintains the running max / normalizer / output accumulator in
VMEM scratch across the kv-block axis (the innermost grid dim).

Tiling (v5e): q block 256 × d_head 128 and kv block 512 × 128 keep the
fp32 score tile at 256·512·4 = 512 KiB and the accumulator at 128 KiB.
Causal masking skips fully-masked kv blocks via ``pl.when`` on block
coordinates, halving the causal-training FLOPs.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_Q_BLOCK = 256
DEFAULT_KV_BLOCK = 512

NEG_INF = -1e30


def _make_kernel(causal: bool, window, scale: float, kv_blocks: int,
                 q_block: int, kv_block: int, sk: int, sq: int):
    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        qi = pl.program_id(1)
        kj = pl.program_id(2)

        @pl.when(kj == 0)
        def _reset():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # absolute positions (queries right-aligned when sq < sk: decode)
        q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0) + (sk - sq)
        k_pos = kj * kv_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)

        block_needed = True
        if causal:
            # skip blocks entirely above the diagonal
            first_q = qi * q_block + (sk - sq)
            block_needed = kj * kv_block <= first_q + q_block - 1

        @pl.when(block_needed)
        def _compute():
            q = q_ref[0].astype(jnp.float32)   # (q_block, d)
            k = k_ref[0].astype(jnp.float32)   # (kv_block, d)
            v = v_ref[0].astype(jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            ) * scale                           # (q_block, kv_block)
            mask = jnp.ones_like(s, dtype=jnp.bool_)
            if causal:
                mask &= k_pos <= q_pos
            if window is not None:
                mask &= k_pos > q_pos - window
            s = jnp.where(mask, s, NEG_INF)

            m_prev = m_ref[...]                 # (q_block,)
            m_cur = jnp.maximum(m_prev, s.max(axis=1))
            p = jnp.exp(s - m_cur[:, None])
            p = jnp.where(mask, p, 0.0)
            corr = jnp.exp(m_prev - m_cur)
            l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
            acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
            m_ref[...] = m_cur

        @pl.when(kj == kv_blocks - 1)
        def _finalize():
            l = jnp.maximum(l_ref[...], 1e-30)
            o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "kv_block", "interpret", "scale"),
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,  # (BH, Sk, D)
    v: jax.Array,
    *,
    causal: bool = False,
    window=None,
    scale=None,
    q_block: int = DEFAULT_Q_BLOCK,
    kv_block: int = DEFAULT_KV_BLOCK,
    interpret: bool = False,
):
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % q_block == 0 and sk % kv_block == 0
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(d)
    grid = (bh, sq // q_block, sk // kv_block)
    kernel = _make_kernel(
        causal, window, scale, sk // kv_block, q_block, kv_block, sk, sq
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
