"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel lives in its own subpackage:
  kernel.py -- pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    -- jit'd public wrapper (padding, dtype policy, interpret switch)
  ref.py    -- pure-jnp oracle the kernel is validated against

On this CPU-only container the kernels execute via ``interpret=True``;
the BlockSpecs and grids are written for TPU v5e VMEM budgets.
"""
