"""Fused Hamming-filter + exact-verify Pallas kernel (dual-threshold).

The TPU tile of the ``random_projection`` range backend, implementing
the backend's real ``verify="band"`` contract: for a (query-tile,
db-tile) pair the kernel XOR+popcounts the packed sign signatures (VPU,
``n_bits/32`` uint32 words per pair) and splits pairs on the
``(t_lo, t_hi)`` Hamming band —

  * ``ham <= t_lo``         sure-accept, **no MXU work at all**;
  * ``t_lo < ham <= t_hi``  ambiguous band, exact dot verify (MXU);
  * ``ham > t_hi``          pruned.

Only if the tile contains a *band* candidate does the exact-dot
verification matmul run — a tile whose pairs are all sure-accepts or
all pruned skips its matmul entirely, which is where the pre-filter's
pruning turns into saved FLOPs.  ``t_lo = -1`` recovers full-verify
semantics (every candidate exact-checked).  Outputs match
``range_count``'s contract (per-query int32 counts, optional packed
uint32 adjacency in the shared ``pack_bits`` bit order) so the two
kernels are drop-in alternates for the engines.

Tiling: q tile 128×d, db tile 256×d keeps q/db/score tiles plus the two
signature tiles (128·w + 256·w uint32 words, w = n_bits/32 ≤ 32) well
under VMEM; both matmul dims stay multiples of the 128-lane MXU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the shared traceable helpers work inside the kernel body too — one
# definition of the popcount reduction and bit order across host/device
from ...index.signatures import hamming_words as _tile_hamming
from ...index.signatures import pack_bits as _pack_bits

DEFAULT_Q_TILE = 128
DEFAULT_DB_TILE = 256


def _tile_masks(qs_ref, dbs_ref, band_ref):
    """(accept, band) masks for one tile from its packed signatures;
    band_ref holds [t_lo, t_hi]."""
    ham = _tile_hamming(qs_ref[...], dbs_ref[...])
    accept = ham <= band_ref[0]
    band = (ham <= band_ref[1]) & ~accept
    return accept, band


def _verify_dots(q_ref, db_ref, thresh_ref):
    q = q_ref[...].astype(jnp.float32)
    db = db_ref[...].astype(jnp.float32)
    dots = jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    return dots > thresh_ref[0]


def _filter_count_kernel(q_ref, db_ref, qs_ref, dbs_ref, thresh_ref, band_ref, counts_ref):
    """Grid (nq_tiles, nd_tiles); counts accumulate over the db axis."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    accept, band = _tile_masks(qs_ref, dbs_ref, band_ref)
    # sure-accepts count without touching the MXU
    counts_ref[...] += jnp.sum(accept, axis=1, dtype=jnp.int32)

    @pl.when(jnp.any(band))
    def _verify():
        hit = band & _verify_dots(q_ref, db_ref, thresh_ref)
        counts_ref[...] += jnp.sum(hit, axis=1, dtype=jnp.int32)


def _filter_count_bitmap_kernel(
    q_ref, db_ref, qs_ref, dbs_ref, thresh_ref, band_ref, counts_ref, bitmap_ref
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    accept, band = _tile_masks(qs_ref, dbs_ref, band_ref)
    any_band = jnp.any(band)

    @pl.when(any_band)
    def _verify():
        hit = accept | (band & _verify_dots(q_ref, db_ref, thresh_ref))
        counts_ref[...] += jnp.sum(hit, axis=1, dtype=jnp.int32)
        bitmap_ref[...] = _pack_bits(hit)

    @pl.when(~any_band)
    def _prune():
        # band-free tile: sure-accepts (possibly none) are the whole
        # answer — still no matmul
        counts_ref[...] += jnp.sum(accept, axis=1, dtype=jnp.int32)
        bitmap_ref[...] = _pack_bits(accept)


def _tile_stats(accept, band):
    """[sure-accepts, band candidates, rejects] for one tile — the
    occupancy triple the margin auto-tuner consumes (a tile's verify
    matmul runs iff its band count is nonzero)."""
    n_acc = jnp.sum(accept, dtype=jnp.int32)
    n_band = jnp.sum(band, dtype=jnp.int32)
    total = jnp.int32(accept.shape[0] * accept.shape[1])
    return jnp.stack([n_acc, n_band, total - n_acc - n_band]).reshape(1, 3)


def _accumulate_stats(stats_ref, accept, band):
    """Fold one tile's occupancy into the single whole-call (1, 3)
    stats block (revisited on every grid step, counts-style): cheaper
    than a per-tile output — one small accumulate per tile instead of
    a (q_tiles, db_tiles, 3) slab write, which is what keeps the
    telemetry build of the sweep near the plain build's cost."""
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        stats_ref[...] = jnp.zeros_like(stats_ref)

    stats_ref[...] += _tile_stats(accept, band)


def _filter_count_stats_kernel(
    q_ref, db_ref, qs_ref, dbs_ref, thresh_ref, band_ref, counts_ref, stats_ref
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    accept, band = _tile_masks(qs_ref, dbs_ref, band_ref)
    _accumulate_stats(stats_ref, accept, band)
    counts_ref[...] += jnp.sum(accept, axis=1, dtype=jnp.int32)

    @pl.when(jnp.any(band))
    def _verify():
        hit = band & _verify_dots(q_ref, db_ref, thresh_ref)
        counts_ref[...] += jnp.sum(hit, axis=1, dtype=jnp.int32)


def _filter_count_bitmap_stats_kernel(
    q_ref, db_ref, qs_ref, dbs_ref, thresh_ref, band_ref, counts_ref, bitmap_ref, stats_ref
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    accept, band = _tile_masks(qs_ref, dbs_ref, band_ref)
    _accumulate_stats(stats_ref, accept, band)
    any_band = jnp.any(band)

    @pl.when(any_band)
    def _verify():
        hit = accept | (band & _verify_dots(q_ref, db_ref, thresh_ref))
        counts_ref[...] += jnp.sum(hit, axis=1, dtype=jnp.int32)
        bitmap_ref[...] = _pack_bits(hit)

    @pl.when(~any_band)
    def _prune():
        counts_ref[...] += jnp.sum(accept, axis=1, dtype=jnp.int32)
        bitmap_ref[...] = _pack_bits(accept)


@functools.partial(
    jax.jit,
    static_argnames=("q_tile", "db_tile", "interpret", "with_bitmap", "with_stats"),
)
def hamming_filter_pallas(
    q: jax.Array,
    db: jax.Array,
    q_sig: jax.Array,
    db_sig: jax.Array,
    eps: jax.Array | float,
    t_lo: jax.Array | int,
    t_hi: jax.Array | int,
    *,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: bool = False,
    with_bitmap: bool = False,
    with_stats: bool = False,
):
    """Raw kernel entry; inputs must already be tile-aligned (see ops.py).

    ``q_sig``/``db_sig`` are packed uint32 sign signatures (same bit
    order as ``repro.index.signatures``, one row per q/db row);
    ``(t_lo, t_hi)`` is the Hamming band (``t_lo = -1`` = full verify).
    Both thresholds are traced, so sweeping eps never recompiles.

    ``with_stats`` appends a (1, 3) int32 whole-call occupancy output:
    [sure-accepts, band candidates, rejects] summed over every tile's
    ``q_tile * db_tile`` pairs (padded rows included — the caller sees
    raw pair occupancy, which is what decides how many verify matmuls
    ran).  Accumulated in-kernel across the sequential grid, so the
    telemetry build adds one small block to the launch instead of a
    per-tile slab.
    """
    nq, d = q.shape
    nd = db.shape[0]
    w = q_sig.shape[1]
    assert db_sig.shape[1] == w
    assert nq % q_tile == 0 and nd % db_tile == 0 and db_tile % 32 == 0
    grid = (nq // q_tile, nd // db_tile)
    thresh = jnp.asarray([1.0 - eps], jnp.float32)
    band_t = jnp.stack(
        [jnp.asarray(t_lo, jnp.int32), jnp.asarray(t_hi, jnp.int32)]
    )

    q_spec = pl.BlockSpec((q_tile, d), lambda i, j: (i, 0))
    db_spec = pl.BlockSpec((db_tile, d), lambda i, j: (j, 0))
    qs_spec = pl.BlockSpec((q_tile, w), lambda i, j: (i, 0))
    dbs_spec = pl.BlockSpec((db_tile, w), lambda i, j: (j, 0))
    scalar_spec = pl.BlockSpec(memory_space=pl.ANY)
    counts_spec = pl.BlockSpec((q_tile,), lambda i, j: (i,))
    stats_spec = pl.BlockSpec((1, 3), lambda i, j: (0, 0))
    stats_shape = jax.ShapeDtypeStruct((1, 3), jnp.int32)
    in_specs = [q_spec, db_spec, qs_spec, dbs_spec, scalar_spec, scalar_spec]
    operands = (q, db, q_sig, db_sig, thresh, band_t)

    if not with_bitmap:
        if with_stats:
            return pl.pallas_call(
                _filter_count_stats_kernel,
                grid=grid,
                in_specs=in_specs,
                out_specs=[counts_spec, stats_spec],
                out_shape=[jax.ShapeDtypeStruct((nq,), jnp.int32), stats_shape],
                interpret=interpret,
            )(*operands)
        return pl.pallas_call(
            _filter_count_kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=counts_spec,
            out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
            interpret=interpret,
        )(*operands)

    bitmap_spec = pl.BlockSpec((q_tile, db_tile // 32), lambda i, j: (i, j))
    bitmap_shape = jax.ShapeDtypeStruct((nq, nd // 32), jnp.uint32)
    if with_stats:
        return pl.pallas_call(
            _filter_count_bitmap_stats_kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=[counts_spec, bitmap_spec, stats_spec],
            out_shape=[
                jax.ShapeDtypeStruct((nq,), jnp.int32), bitmap_shape, stats_shape,
            ],
            interpret=interpret,
        )(*operands)
    return pl.pallas_call(
        _filter_count_bitmap_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[counts_spec, bitmap_spec],
        out_shape=[jax.ShapeDtypeStruct((nq,), jnp.int32), bitmap_shape],
        interpret=interpret,
    )(*operands)
