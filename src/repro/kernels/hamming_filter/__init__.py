from .ops import (  # noqa: F401
    default_interpret,
    hamming_filter_bitmap,
    hamming_filter_count,
)
