from .ops import hamming_filter_bitmap, hamming_filter_count  # noqa: F401
