"""Pure-jnp oracle for the fused Hamming-filter + exact-verify kernel."""

import jax
import jax.numpy as jnp


def _hamming(q_sig, db_sig):
    x = q_sig[:, None, :] ^ db_sig[None, :, :]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def hamming_filter_count_ref(q, db, q_sig, db_sig, eps, ham_thresh):
    """Counts of {j : hamming(sig_i, sig_j) <= t  and  1 - <q_i, db_j> < eps}."""
    dots = q.astype(jnp.float32) @ db.astype(jnp.float32).T
    hit = (_hamming(q_sig, db_sig) <= ham_thresh) & (dots > 1.0 - eps)
    return jnp.sum(hit, axis=1, dtype=jnp.int32)


def hamming_filter_bitmap_ref(q, db, q_sig, db_sig, eps, ham_thresh):
    """(counts, packed uint32 adjacency rows) under the same predicate."""
    dots = q.astype(jnp.float32) @ db.astype(jnp.float32).T
    hit = (_hamming(q_sig, db_sig) <= ham_thresh) & (dots > 1.0 - eps)
    counts = jnp.sum(hit, axis=1, dtype=jnp.int32)
    nq, nd = hit.shape
    pad = (-nd) % 32
    hitp = jnp.pad(hit, ((0, 0), (0, pad)))
    words = hitp.reshape(nq, -1, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    packed = jnp.sum(words << shifts[None, None, :], axis=2, dtype=jnp.uint32)
    return counts, packed
