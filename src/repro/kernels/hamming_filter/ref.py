"""Pure-jnp oracle for the fused dual-threshold Hamming-filter +
exact-verify kernel.  The predicate is the shared
:func:`repro.index.signatures.band_hits` definition — the same one the
host ``random_projection`` backend and the sharded lowering evaluate."""

import jax
import jax.numpy as jnp

from ...index.signatures import band_hits


def _hamming(q_sig, db_sig):
    x = q_sig[:, None, :] ^ db_sig[None, :, :]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def _hits(q, db, q_sig, db_sig, eps, t_lo, t_hi):
    dots = q.astype(jnp.float32) @ db.astype(jnp.float32).T
    return band_hits(dots, _hamming(q_sig, db_sig), eps, t_lo, t_hi)


def hamming_filter_count_ref(q, db, q_sig, db_sig, eps, t_lo, t_hi):
    """Counts of {j : ham <= t_lo  or  (ham <= t_hi and d_cos < eps)}."""
    hit = _hits(q, db, q_sig, db_sig, eps, t_lo, t_hi)
    return jnp.sum(hit, axis=1, dtype=jnp.int32)


def hamming_filter_bitmap_ref(q, db, q_sig, db_sig, eps, t_lo, t_hi):
    """(counts, packed uint32 adjacency rows) under the same predicate."""
    hit = _hits(q, db, q_sig, db_sig, eps, t_lo, t_hi)
    counts = jnp.sum(hit, axis=1, dtype=jnp.int32)
    nq, nd = hit.shape
    pad = (-nd) % 32
    hitp = jnp.pad(hit, ((0, 0), (0, pad)))
    words = hitp.reshape(nq, -1, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    packed = jnp.sum(words << shifts[None, None, :], axis=2, dtype=jnp.uint32)
    return counts, packed
