"""Public jit'd wrappers for the Hamming-filter kernel: padding to tile
alignment, padded-row corrections, interpret switch — mirroring
``repro.kernels.range_count.ops``."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_DB_TILE, DEFAULT_Q_TILE, hamming_filter_pallas

__all__ = ["hamming_filter_count", "hamming_filter_bitmap"]


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def _pad_col_hits(q_sig: jax.Array, eps, ham_thresh, n_pad: int) -> jax.Array:
    """Per-query hits contributed by zero-padded db rows.

    A padded db row has signature 0 and vector 0, so it passes the
    Hamming filter iff popcount(q_sig_i) <= t and the dot test iff
    0 > 1 - eps (i.e. eps > 1) — exactly computable, like range_count's
    padded-hit correction but signature-dependent.
    """
    pop = jnp.sum(jax.lax.population_count(q_sig).astype(jnp.int32), axis=1)
    passes = (pop <= jnp.asarray(ham_thresh, jnp.int32)) & (
        jnp.asarray(eps, jnp.float32) > 1.0
    )
    return jnp.where(passes, n_pad, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("q_tile", "db_tile", "interpret"))
def hamming_filter_count(
    q: jax.Array,
    db: jax.Array,
    q_sig: jax.Array,
    db_sig: jax.Array,
    eps,
    ham_thresh,
    *,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: bool = True,
):
    """Filtered-and-verified neighbor counts; pads to tiles and subtracts
    the padded-row hits exactly."""
    nq, nd = q.shape[0], db.shape[0]
    qp, dbp = _pad_rows(q, q_tile), _pad_rows(db, db_tile)
    qsp, dbsp = _pad_rows(q_sig, q_tile), _pad_rows(db_sig, db_tile)
    counts = hamming_filter_pallas(
        qp, dbp, qsp, dbsp, eps, ham_thresh,
        q_tile=q_tile, db_tile=db_tile, interpret=interpret,
    )[:nq]
    n_pad = dbp.shape[0] - nd
    if n_pad:
        counts = counts - _pad_col_hits(q_sig, eps, ham_thresh, n_pad)
    return counts


@functools.partial(jax.jit, static_argnames=("q_tile", "db_tile", "interpret"))
def hamming_filter_bitmap(
    q: jax.Array,
    db: jax.Array,
    q_sig: jax.Array,
    db_sig: jax.Array,
    eps,
    ham_thresh,
    *,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: bool = True,
):
    """(counts, packed adjacency) with padded bits cleared; the bitmap
    covers ceil(nd/32) words."""
    nq, nd = q.shape[0], db.shape[0]
    qp, dbp = _pad_rows(q, q_tile), _pad_rows(db, db_tile)
    qsp, dbsp = _pad_rows(q_sig, q_tile), _pad_rows(db_sig, db_tile)
    counts, bitmap = hamming_filter_pallas(
        qp, dbp, qsp, dbsp, eps, ham_thresh,
        q_tile=q_tile, db_tile=db_tile, interpret=interpret, with_bitmap=True,
    )
    counts = counts[:nq]
    bitmap = bitmap[:nq]
    n_pad = dbp.shape[0] - nd
    if n_pad:
        counts = counts - _pad_col_hits(q_sig, eps, ham_thresh, n_pad)
        nw = bitmap.shape[1]
        bit_idx = jnp.arange(nw * 32) < nd
        word_mask = jnp.sum(
            bit_idx.reshape(nw, 32).astype(jnp.uint32)
            << jnp.arange(32, dtype=jnp.uint32)[None, :],
            axis=1,
            dtype=jnp.uint32,
        )
        bitmap = bitmap & word_mask[None, :]
    words_needed = -(-nd // 32)
    return counts, bitmap[:, :words_needed]
