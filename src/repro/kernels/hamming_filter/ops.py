"""Public jit'd wrappers for the Hamming-filter kernel: padding to tile
alignment, padded-row corrections, interpret switch — mirroring
``repro.kernels.range_count.ops``.

``interpret=None`` (the default) resolves per platform: the compiled
kernel runs whenever a real accelerator backs the default JAX backend,
and the Pallas interpreter is used otherwise (CPU containers, CI) — so
callers get the fast path automatically without every call site having
to remember the switch.  Tests pin ``interpret=True`` explicitly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...index.signatures import band_hits
from .kernel import DEFAULT_DB_TILE, DEFAULT_Q_TILE, hamming_filter_pallas

__all__ = ["hamming_filter_count", "hamming_filter_bitmap", "default_interpret"]


def default_interpret() -> bool:
    """True iff the compiled kernel cannot run here (no TPU/GPU)."""
    return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def _tail_word_mask(n_words: int, n: int) -> jax.Array:
    """uint32 per-word masks clearing bitmap bits for rows >= n — the
    single definition of the LSB-first tail mask (the bitmap wrapper and
    the sharded index plane both clear pad bits through here)."""
    bit_valid = jnp.arange(n_words * 32) < n
    return jnp.sum(
        bit_valid.reshape(n_words, 32).astype(jnp.uint32)
        << jnp.arange(32, dtype=jnp.uint32)[None, :],
        axis=1,
        dtype=jnp.uint32,
    )


def _pad_col_hits(q_sig: jax.Array, eps, t_lo, t_hi, n_pad: int) -> jax.Array:
    """Per-query hits contributed by zero-padded db rows.

    A padded db row has signature 0 and vector 0, so its Hamming
    distance to query i is popcount(q_sig_i) and its dot is 0 — feeding
    those into the shared ``band_hits`` predicate gives the exact count
    to subtract: a sure-accept when popcount <= t_lo, a band hit only
    when eps > 1 (like range_count's padded-hit correction, but
    signature-dependent on both thresholds)."""
    pop = jnp.sum(jax.lax.population_count(q_sig).astype(jnp.int32), axis=1)
    passes = band_hits(
        jnp.float32(0.0),
        pop,
        jnp.asarray(eps, jnp.float32),
        jnp.asarray(t_lo, jnp.int32),
        jnp.asarray(t_hi, jnp.int32),
    )
    return jnp.where(passes, n_pad, 0).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("q_tile", "db_tile", "interpret", "return_stats")
)
def hamming_filter_count(
    q: jax.Array,
    db: jax.Array,
    q_sig: jax.Array,
    db_sig: jax.Array,
    eps,
    t_hi,
    *,
    t_lo=-1,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: bool | None = None,
    return_stats: bool = False,
):
    """Filtered-and-verified neighbor counts; pads to tiles and subtracts
    the padded-row hits exactly.  ``t_lo=-1`` is full-verify mode.

    ``return_stats=True`` returns ``(counts, stats)`` where stats is the
    kernel's raw (1, 3) whole-call occupancy — [sure-accepts, band
    candidates, rejects] summed over the *padded* tile grid (see
    ``hamming_filter_pallas``); the margin auto-tuner reads the band
    column to price the verify matmuls a margin would cost.
    """
    if interpret is None:
        interpret = default_interpret()
    nq, nd = q.shape[0], db.shape[0]
    qp, dbp = _pad_rows(q, q_tile), _pad_rows(db, db_tile)
    qsp, dbsp = _pad_rows(q_sig, q_tile), _pad_rows(db_sig, db_tile)
    out = hamming_filter_pallas(
        qp, dbp, qsp, dbsp, eps, t_lo, t_hi,
        q_tile=q_tile, db_tile=db_tile, interpret=interpret,
        with_stats=return_stats,
    )
    counts, stats = out if return_stats else (out, None)
    counts = counts[:nq]
    n_pad = dbp.shape[0] - nd
    if n_pad:
        counts = counts - _pad_col_hits(q_sig, eps, t_lo, t_hi, n_pad)
    return (counts, stats) if return_stats else counts


@functools.partial(
    jax.jit, static_argnames=("q_tile", "db_tile", "interpret", "return_stats")
)
def hamming_filter_bitmap(
    q: jax.Array,
    db: jax.Array,
    q_sig: jax.Array,
    db_sig: jax.Array,
    eps,
    t_hi,
    *,
    t_lo=-1,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: bool | None = None,
    return_stats: bool = False,
):
    """(counts, packed adjacency) with padded bits cleared; the bitmap
    covers ceil(nd/32) words.  ``t_lo=-1`` is full-verify mode.
    ``return_stats=True`` appends the raw (1, 3) occupancy triple
    (see ``hamming_filter_count``)."""
    if interpret is None:
        interpret = default_interpret()
    nq, nd = q.shape[0], db.shape[0]
    qp, dbp = _pad_rows(q, q_tile), _pad_rows(db, db_tile)
    qsp, dbsp = _pad_rows(q_sig, q_tile), _pad_rows(db_sig, db_tile)
    out = hamming_filter_pallas(
        qp, dbp, qsp, dbsp, eps, t_lo, t_hi,
        q_tile=q_tile, db_tile=db_tile, interpret=interpret, with_bitmap=True,
        with_stats=return_stats,
    )
    counts, bitmap = out[0], out[1]
    stats = out[2] if return_stats else None
    counts = counts[:nq]
    bitmap = bitmap[:nq]
    n_pad = dbp.shape[0] - nd
    if n_pad:
        counts = counts - _pad_col_hits(q_sig, eps, t_lo, t_hi, n_pad)
        bitmap = bitmap & _tail_word_mask(bitmap.shape[1], nd)[None, :]
    words_needed = -(-nd // 32)
    bitmap = bitmap[:, :words_needed]
    return (counts, bitmap, stats) if return_stats else (counts, bitmap)
