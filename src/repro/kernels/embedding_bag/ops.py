"""Jit'd wrapper for the EmbeddingBag kernel (padding + combiner)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BATCH_TILE, embedding_bag_pallas

__all__ = ["embedding_bag"]


@functools.partial(jax.jit, static_argnames=("combiner", "batch_tile", "interpret"))
def embedding_bag(
    table: jax.Array,
    ids: jax.Array,
    *,
    combiner: str = "sum",
    batch_tile: int = DEFAULT_BATCH_TILE,
    interpret: bool = True,
):
    """EmbeddingBag: (V, D) table, (B, L) ids (-1 padded) -> (B, D)."""
    b, l = ids.shape
    pad = (-b) % batch_tile
    idp = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1) if pad else ids
    out = embedding_bag_pallas(
        table, idp, batch_tile=batch_tile, interpret=interpret
    )[:b]
    if combiner == "mean":
        denom = jnp.maximum((ids >= 0).sum(axis=1, keepdims=True), 1)
        out = out / denom
    return out
