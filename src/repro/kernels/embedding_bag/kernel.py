"""EmbeddingBag Pallas kernel — the recsys lookup hot path.

Huge sparse tables (10^6-10^9 rows) live in HBM; only the gathered rows
ever enter VMEM.  The kernel uses scalar prefetch (PrefetchScalarGridSpec)
for the bag indices so the index stream is available to DMA row slices
of the HBM-resident table, accumulating the bag reduction in a VMEM
accumulator — one pass, no (B, L, D) intermediate (the jnp formulation
materializes it; at B=65536, L=64, D=128 that is 2 TiB — the reason this
kernel exists).

Grid: (batch_tiles,).  Each step owns a (TB, L) slice of the index
matrix and accumulates TB bags of width D.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific pieces are unavailable in some CPU-only installs
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

DEFAULT_BATCH_TILE = 8


def _make_kernel(bag_len: int, batch_tile: int):
    def kernel(ids_ref, table_ref, out_ref):
        # ids_ref: (TB, L) int32 (scalar-prefetched); table_ref: full (V, D)
        # in ANY/HBM; out_ref: (TB, D) VMEM accumulator.
        d = out_ref.shape[-1]
        acc = jnp.zeros((batch_tile, d), jnp.float32)

        def body(l, acc):
            idx = ids_ref[:, l]                      # (TB,)
            safe = jnp.where(idx >= 0, idx, 0)
            rows = table_ref[safe, :]                # dynamic row gather
            rows = jnp.where((idx >= 0)[:, None], rows.astype(jnp.float32), 0.0)
            return acc + rows

        acc = jax.lax.fori_loop(0, bag_len, body, acc)
        out_ref[...] = acc

    return kernel


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def embedding_bag_pallas(
    table: jax.Array,
    ids: jax.Array,
    *,
    batch_tile: int = DEFAULT_BATCH_TILE,
    interpret: bool = False,
):
    """table (V, D); ids (B, L) -> (B, D) fp32 bag sums.  B % TB == 0."""
    b, l = ids.shape
    v, d = table.shape
    assert b % batch_tile == 0
    grid = (b // batch_tile,)
    kernel = _make_kernel(l, batch_tile)
    ids_spec = pl.BlockSpec((batch_tile, l), lambda i: (i, 0))
    table_spec = pl.BlockSpec(memory_space=pl.ANY)  # stays in HBM; rows DMA'd
    out_spec = pl.BlockSpec((batch_tile, d), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[ids_spec, table_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(ids, table)
