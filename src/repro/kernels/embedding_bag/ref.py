"""Pure-jnp oracle for EmbeddingBag (sum/mean over multi-hot bags).

JAX has no native nn.EmbeddingBag; the canonical formulation is
gather + masked segment reduction.  ids are padded with -1.
"""

import jax.numpy as jnp


def embedding_bag_ref(table, ids, *, combiner: str = "sum"):
    """table (V, D); ids (B, L) int32 with -1 padding -> (B, D)."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    gathered = table[safe]                      # (B, L, D)
    gathered = jnp.where(valid[:, :, None], gathered, 0.0)
    out = gathered.sum(axis=1)
    if combiner == "mean":
        denom = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        out = out / denom
    return out
