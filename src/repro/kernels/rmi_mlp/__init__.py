from .ops import rmi_mlp_forward, rmi_stage_forward  # noqa: F401
