"""Pure-jnp oracle for the fused RMI-MLP kernel."""

import jax
import jax.numpy as jnp


def mlp_forward_ref(x, weights, biases):
    """4 ReLU hidden layers + linear head -> (batch,) fp32."""
    h = x.astype(jnp.float32)
    for w, b in zip(weights[:-1], biases[:-1]):
        h = jax.nn.relu(h @ w.astype(jnp.float32) + b.astype(jnp.float32))
    return (h @ weights[-1].astype(jnp.float32) + biases[-1].astype(jnp.float32))[:, 0]


def stage_forward_ref(x, stacked_weights, stacked_biases):
    """All E experts of one RMI stage: -> (E, batch) fp32."""
    def one(ws, bs):
        return mlp_forward_ref(x, [w for w in ws], [b for b in bs])

    return jax.vmap(
        lambda ws, bs: mlp_forward_ref(x, list(ws), list(bs))
    )(stacked_weights, stacked_biases)
