"""Jit'd wrappers for the fused RMI-MLP kernel: pad input dim to lane
multiples, pad batch to tiles, run all experts of a stage, and expose a
drop-in replacement for ``repro.core.cardinality.rmi.mlp_apply``."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BATCH_TILE, rmi_mlp_pallas

__all__ = ["rmi_mlp_forward", "rmi_stage_forward"]

LANE = 128


def _pad_cols(x, mult=LANE):
    pad = (-x.shape[-1]) % mult
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x


def _prep_params(params):
    """params: list[(W,b)] from core.cardinality.rmi.  Pads the input dim
    of W1 (rows) and the scalar head (cols) to lane multiples."""
    weights, biases = [], []
    for li, (w, b) in enumerate(params):
        if li == 0:
            pad = (-w.shape[0]) % LANE
            if pad:
                w = jnp.pad(w, ((0, pad), (0, 0)))
        if li == len(params) - 1:
            w = _pad_cols(w)
            b = _pad_cols(b[None, :])[0]
        weights.append(w)
        biases.append(b)
    return weights, biases


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def rmi_mlp_forward(
    params,
    x: jax.Array,
    *,
    batch_tile: int = DEFAULT_BATCH_TILE,
    interpret: bool = True,
) -> jax.Array:
    """(batch, d_in) -> (batch,) — fused equivalent of ``mlp_apply``."""
    n, d = x.shape
    weights, biases = _prep_params(params)
    xp = _pad_cols(x)
    pad_rows = (-n) % batch_tile
    if pad_rows:
        xp = jnp.pad(xp, ((0, pad_rows), (0, 0)))
    out = rmi_mlp_pallas(
        xp, weights, biases, batch_tile=batch_tile, interpret=interpret
    )
    return out[:n, 0]


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def rmi_stage_forward(
    stacked_params,
    x: jax.Array,
    *,
    batch_tile: int = DEFAULT_BATCH_TILE,
    interpret: bool = True,
) -> jax.Array:
    """All E experts of one stacked RMI stage -> (E, batch)."""
    return jax.vmap(
        lambda p: rmi_mlp_forward(p, x, batch_tile=batch_tile, interpret=interpret)
    )(stacked_params)
