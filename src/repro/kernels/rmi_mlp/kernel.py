"""Fused RMI-MLP inference Pallas kernel.

The paper's estimator nets are tiny (4 hidden layers 512·512·256·128 ≈
0.5 M params ≈ 1.9 MiB fp32): the entire net fits in VMEM, so the whole
4-layer forward runs on one batch tile without any HBM round-trip
between layers.  Unfused, each layer writes + reads a (B, width)
activation to HBM; fused, HBM traffic is x-in + scalar-out only, turning
a memory-bound chain into one MXU-resident pass.

Grid: (batch_tiles,).  Weights use no grid indexing (same block every
step — Pallas keeps them resident).  Dims are padded to lane multiples
(128) in ops.py; hidden widths 512/512/256/128 are already aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BATCH_TILE = 256


def _mlp_kernel(x_ref, w1, b1, w2, b2, w3, b3, w4, b4, w5, b5, out_ref):
    h = x_ref[...].astype(jnp.float32)

    def layer(h, w_ref, b_ref, relu=True):
        o = (
            jax.lax.dot_general(
                h, w_ref[...].astype(jnp.float32),
                (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
            )
            + b_ref[...].astype(jnp.float32)[None, :]
        )
        return jax.nn.relu(o) if relu else o

    h = layer(h, w1, b1)
    h = layer(h, w2, b2)
    h = layer(h, w3, b3)
    h = layer(h, w4, b4)
    out = layer(h, w5, b5, relu=False)  # (B, head_pad) — col 0 is the output
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def rmi_mlp_pallas(
    x: jax.Array,
    weights,
    biases,
    *,
    batch_tile: int = DEFAULT_BATCH_TILE,
    interpret: bool = False,
):
    """x (B, Din) + 5 (W, b) pairs -> (B, head) fp32.  B % batch_tile == 0."""
    n = x.shape[0]
    assert n % batch_tile == 0
    grid = (n // batch_tile,)
    x_spec = pl.BlockSpec((batch_tile, x.shape[1]), lambda i: (i, 0))
    w_specs = []
    args = []
    for w, b in zip(weights, biases):
        w_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        w_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
        args.extend([w, b])
    head = weights[-1].shape[1]
    out_spec = pl.BlockSpec((batch_tile, head), lambda i: (i, 0))
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[x_spec, *w_specs],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, head), jnp.float32),
        interpret=interpret,
    )(x, *args)
