"""Min-label-propagation Pallas kernel over packed adjacency bitmaps.

Connected components (cluster formation) on the core-core ε-graph is the
second DBSCAN hot spot after range counting.  The adjacency rows are the
packed uint32 bitmaps the ``range_count`` kernel already emits; one
kernel round computes   labels'[i] = min(labels[i], min over set bits of
labels[j])   streaming the bitmap tile-by-tile through VMEM.

Tiling: rows 256 × words 64 (=2048 columns) per grid step: the uint32
tile is 64 KiB, the unpacked bool tile 512 KiB, and the label slice 8
KiB — VMEM-resident with room for double buffering.  The driver in
ops.py iterates rounds with pointer jumping until fixpoint (O(log n)
rounds for any topology).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 256
DEFAULT_WORD_TILE = 64  # 64 words = 2048 columns per step


def _label_prop_kernel(bitmap_ref, labels_col_ref, labels_row_ref, out_ref):
    """Grid (row_tiles, word_tiles); accumulates the running min over
    column tiles into out (one row tile)."""
    j = pl.program_id(1)
    words = bitmap_ref[...]                         # (TR, TW) uint32
    col_labels = labels_col_ref[...]                # (TW*32,) int32
    tr, tw = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((words[:, :, None] >> shifts[None, None, :]) & 1).astype(jnp.bool_)
    bits = bits.reshape(tr, tw * 32)
    big = jnp.iinfo(jnp.int32).max
    neigh = jnp.min(
        jnp.where(bits, col_labels[None, :], jnp.int32(big)), axis=1
    )  # (TR,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.minimum(labels_row_ref[...], neigh)

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = jnp.minimum(out_ref[...], neigh)


@functools.partial(
    jax.jit, static_argnames=("row_tile", "word_tile", "interpret")
)
def label_prop_round_pallas(
    labels: jax.Array,
    bitmap: jax.Array,
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret: bool = False,
):
    """One propagation round.  labels (N,) int32; bitmap (N, W) uint32
    with N % row_tile == 0 and W % word_tile == 0 and W*32 >= N (padded
    bits must be zero; padded labels must be INT32_MAX)."""
    n = labels.shape[0]
    w = bitmap.shape[1]
    assert n % row_tile == 0 and w % word_tile == 0
    grid = (n // row_tile, w // word_tile)
    # column labels padded out to the bitmap's bit capacity
    cap = w * 32
    col_labels = jnp.full((cap,), jnp.iinfo(jnp.int32).max, jnp.int32).at[:n].set(labels)

    bitmap_spec = pl.BlockSpec((row_tile, word_tile), lambda i, j: (i, j))
    col_spec = pl.BlockSpec((word_tile * 32,), lambda i, j: (j,))
    row_spec = pl.BlockSpec((row_tile,), lambda i, j: (i,))
    out_spec = pl.BlockSpec((row_tile,), lambda i, j: (i,))
    return pl.pallas_call(
        _label_prop_kernel,
        grid=grid,
        in_specs=[bitmap_spec, col_spec, row_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(bitmap, col_labels, labels)
