"""Min-label-propagation Pallas kernel over packed adjacency bitmaps.

Connected components (cluster formation) on the core-core ε-graph is the
second DBSCAN hot spot after range counting.  The adjacency rows are the
packed uint32 bitmaps the ``range_count`` kernel already emits; one
kernel round computes   labels'[i] = min(labels[i], min over set bits of
labels[j])   streaming the bitmap tile-by-tile through VMEM.

Tiling: rows 256 × words 64 (=2048 columns) per grid step: the uint32
tile is 64 KiB, the unpacked bool tile 512 KiB, and the label slice 8
KiB — VMEM-resident with room for double buffering.  The driver in
ops.py iterates rounds with pointer jumping until fixpoint (O(log n)
rounds for any topology).

Two grid orientations over the same packed words:

* row reduction (``label_prop_rect_pallas``) — per slab row, the min
  label over set bits; grid (row_tiles, word_tiles), word tiles
  accumulate.  This is the gather half of a propagation round, and it
  works on *rectangular* slabs (R executed rows × W words of database
  columns), which is the shape the sweep engine emits.
* column reduction (``col_reduce_pallas``) — per database column, the
  min row-value over set bits plus a weighted popcount down the rows;
  grid (word_tiles, row_tiles), row tiles accumulate.  One launch
  yields both the min-core-neighbor border owner and the transposed
  partial-count column sums without ever unpacking the slab.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 256
DEFAULT_WORD_TILE = 64  # 64 words = 2048 columns per step


def _label_prop_kernel(bitmap_ref, labels_col_ref, labels_row_ref, out_ref):
    """Grid (row_tiles, word_tiles); accumulates the running min over
    column tiles into out (one row tile)."""
    j = pl.program_id(1)
    words = bitmap_ref[...]                         # (TR, TW) uint32
    col_labels = labels_col_ref[...]                # (TW*32,) int32
    tr, tw = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((words[:, :, None] >> shifts[None, None, :]) & 1).astype(jnp.bool_)
    bits = bits.reshape(tr, tw * 32)
    big = jnp.iinfo(jnp.int32).max
    neigh = jnp.min(
        jnp.where(bits, col_labels[None, :], jnp.int32(big)), axis=1
    )  # (TR,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.minimum(labels_row_ref[...], neigh)

    @pl.when(j != 0)
    def _acc():
        out_ref[...] = jnp.minimum(out_ref[...], neigh)


@functools.partial(
    jax.jit, static_argnames=("row_tile", "word_tile", "interpret")
)
def label_prop_round_pallas(
    labels: jax.Array,
    bitmap: jax.Array,
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret: bool = False,
):
    """One propagation round.  labels (N,) int32; bitmap (N, W) uint32
    with N % row_tile == 0 and W % word_tile == 0 and W*32 >= N (padded
    bits must be zero; padded labels must be INT32_MAX)."""
    n = labels.shape[0]
    w = bitmap.shape[1]
    assert n % row_tile == 0 and w % word_tile == 0
    grid = (n // row_tile, w // word_tile)
    # column labels padded out to the bitmap's bit capacity
    cap = w * 32
    col_labels = jnp.full((cap,), jnp.iinfo(jnp.int32).max, jnp.int32).at[:n].set(labels)

    bitmap_spec = pl.BlockSpec((row_tile, word_tile), lambda i, j: (i, j))
    col_spec = pl.BlockSpec((word_tile * 32,), lambda i, j: (j,))
    row_spec = pl.BlockSpec((row_tile,), lambda i, j: (i,))
    out_spec = pl.BlockSpec((row_tile,), lambda i, j: (i,))
    return pl.pallas_call(
        _label_prop_kernel,
        grid=grid,
        in_specs=[bitmap_spec, col_spec, row_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(bitmap, col_labels, labels)


@functools.partial(
    jax.jit, static_argnames=("row_tile", "word_tile", "interpret")
)
def label_prop_rect_pallas(
    row_labels: jax.Array,
    col_labels: jax.Array,
    bitmap: jax.Array,
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret: bool = False,
):
    """Rectangular row reduction: ``out[i] = min(row_labels[i],
    min over set bits of bitmap[i] of col_labels)``.

    ``bitmap`` is an (R, W) slab — R executed rows against W*32 database
    columns — with R % row_tile == 0 and W % word_tile == 0;
    ``col_labels`` is (W*32,) int32 (pad columns must hold INT32_MAX or
    have zero bits).  The square round above is the R == W*32 special
    case of this entry.
    """
    r, w = bitmap.shape
    assert r % row_tile == 0 and w % word_tile == 0
    assert row_labels.shape[0] == r and col_labels.shape[0] == w * 32
    grid = (r // row_tile, w // word_tile)
    bitmap_spec = pl.BlockSpec((row_tile, word_tile), lambda i, j: (i, j))
    col_spec = pl.BlockSpec((word_tile * 32,), lambda i, j: (j,))
    row_spec = pl.BlockSpec((row_tile,), lambda i, j: (i,))
    out_spec = pl.BlockSpec((row_tile,), lambda i, j: (i,))
    return pl.pallas_call(
        _label_prop_kernel,
        grid=grid,
        in_specs=[bitmap_spec, col_spec, row_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((r,), jnp.int32),
        interpret=interpret,
    )(bitmap, col_labels, row_labels)


def _col_reduce_kernel(bitmap_ref, row_vals_ref, row_weights_ref, min_ref, sum_ref):
    """Grid (word_tiles, row_tiles); accumulates the per-column min of
    ``row_vals`` and the per-column weighted popcount over row tiles."""
    j = pl.program_id(1)
    words = bitmap_ref[...]                         # (TR, TW) uint32
    row_vals = row_vals_ref[...]                    # (TR,) int32
    row_weights = row_weights_ref[...]              # (TR,) int32
    tr, tw = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((words[:, :, None] >> shifts[None, None, :]) & 1).astype(jnp.bool_)
    bits = bits.reshape(tr, tw * 32)
    big = jnp.iinfo(jnp.int32).max
    cmin = jnp.min(
        jnp.where(bits, row_vals[:, None], jnp.int32(big)), axis=0
    )  # (TW*32,)
    csum = jnp.sum(
        jnp.where(bits, row_weights[:, None], jnp.int32(0)), axis=0
    ).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        min_ref[...] = cmin
        sum_ref[...] = csum

    @pl.when(j != 0)
    def _acc():
        min_ref[...] = jnp.minimum(min_ref[...], cmin)
        sum_ref[...] = sum_ref[...] + csum


@functools.partial(
    jax.jit, static_argnames=("row_tile", "word_tile", "interpret")
)
def col_reduce_pallas(
    bitmap: jax.Array,
    row_vals: jax.Array,
    row_weights: jax.Array,
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret: bool = False,
):
    """Transposed reduction over a packed (R, W) slab, one launch:

    * ``col_min[j] = min over rows i with bit (i, j) of row_vals[i]``
      (INT32_MAX where no bit is set) — with ``row_vals =
      where(core_row, row_index, MAX)`` this is exactly the
      min-core-neighbor border-owner rule;
    * ``col_sum[j] = sum over those rows of row_weights[i]`` — with unit
      weights on valid rows this is the transposed partial-count bump
      (``hit.sum(axis=0)``) without unpacking.
    """
    r, w = bitmap.shape
    assert r % row_tile == 0 and w % word_tile == 0
    assert row_vals.shape[0] == r and row_weights.shape[0] == r
    grid = (w // word_tile, r // row_tile)
    bitmap_spec = pl.BlockSpec((row_tile, word_tile), lambda i, j: (j, i))
    vals_spec = pl.BlockSpec((row_tile,), lambda i, j: (j,))
    out_spec = pl.BlockSpec((word_tile * 32,), lambda i, j: (i,))
    return pl.pallas_call(
        _col_reduce_kernel,
        grid=grid,
        in_specs=[bitmap_spec, vals_spec, vals_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((w * 32,), jnp.int32),
            jax.ShapeDtypeStruct((w * 32,), jnp.int32),
        ],
        interpret=interpret,
    )(bitmap, row_vals.astype(jnp.int32), row_weights.astype(jnp.int32))
