"""Drivers over the packed label-prop kernels.

* ``label_prop_round`` / ``label_propagation_pallas`` — the square
  connected-components pair (pad, iterate rounds with pointer jumping
  to fixpoint) used as the standalone CC engine.
* ``packed_cluster_labels`` — the device-resident DBSCAN cluster pass:
  one traced program that takes the sweep engine's rectangular packed
  slab (R executed rows × W words of database columns) and computes,
  without ever unpacking and without a host round-trip, the exact
  neighbor counts (popcount), the tau core test, the min-label
  connected components of the core-core graph (``lax.while_loop`` with
  pointer jumping), the min-core-neighbor border owner per column, and
  the transposed partial-count sums.  ``axes=`` switches the gather to
  a shard-local slice + ``lax.pmin`` of the s32 row minima, so on a
  mesh only label vectors ride collectives — the packed words stay
  shard-local (the LAF202 invariant).
* ``packed_connectivity`` — the streaming (bipartite) variant: the
  block's rows are *not* a superset of the core set, so labels must
  alternate rows -> columns -> rows each round; used by
  ``StreamingClusterState.apply_core_rows_packed`` to merge components
  per ingest batch with the adjacency kept packed end-to-end.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..hamming_filter.ops import _tail_word_mask, default_interpret
from ...obs import device as _obs_device
from ...obs import metrics as _metrics
from .kernel import (
    DEFAULT_ROW_TILE,
    DEFAULT_WORD_TILE,
    col_reduce_pallas,
    label_prop_rect_pallas,
    label_prop_round_pallas,
)

__all__ = [
    "label_prop_round",
    "label_propagation_pallas",
    "packed_cluster_labels",
    "packed_connectivity",
]

BIG = jnp.iinfo(jnp.int32).max


def _pad(labels, bitmap, row_tile, word_tile):
    n = labels.shape[0]
    w = bitmap.shape[1]
    n_pad = (-n) % row_tile
    w_req = max(w, -(-(n + n_pad) // 32))
    w_pad = (-w_req) % word_tile + (w_req - w)
    labels_p = jnp.pad(labels, (0, n_pad), constant_values=BIG)
    bitmap_p = jnp.pad(bitmap, ((0, n_pad), (0, w_pad)))
    return labels_p, bitmap_p, n


@functools.partial(jax.jit, static_argnames=("row_tile", "word_tile", "interpret"))
def label_prop_round(
    labels: jax.Array,
    bitmap: jax.Array,
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret: bool = True,
):
    """One masked min-propagation round (arbitrary N, W)."""
    labels = labels.astype(jnp.int32)
    labels_p, bitmap_p, n = _pad(labels, bitmap, row_tile, word_tile)
    out = label_prop_round_pallas(
        labels_p, bitmap_p, row_tile=row_tile, word_tile=word_tile, interpret=interpret
    )
    return out[:n]


@functools.partial(
    jax.jit, static_argnames=("max_iters", "row_tile", "word_tile", "interpret")
)
def label_propagation_pallas(
    bitmap: jax.Array,
    active: jax.Array,
    *,
    max_iters: int = 64,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret: bool = True,
):
    """Connected components over a packed symmetric adjacency: same
    contract as ``repro.core.union_find.label_propagation`` (inactive
    nodes -> sentinel n)."""
    n = active.shape[0]
    init = jnp.where(active, jnp.arange(n, dtype=jnp.int32), jnp.int32(n))

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        labels, _, it = state
        masked = jnp.where(active, labels, BIG)
        neigh = label_prop_round(
            masked, bitmap, row_tile=row_tile, word_tile=word_tile, interpret=interpret
        )
        new = jnp.where(active, jnp.minimum(labels, neigh), jnp.int32(n))
        jump = jnp.where(new < n, new, 0)
        new = jnp.where(new < n, jnp.minimum(new, new[jump]), new)
        return new, jnp.any(new != labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (init, jnp.bool_(True), 0))
    return labels


# ---------------------------------------------------------------------------
# device-resident clustering over a rectangular sweep slab
# ---------------------------------------------------------------------------


def packed_cluster_fixpoint(
    bitmap: jax.Array,
    rows: jax.Array,
    tau,
    col_off,
    *,
    n: int,
    cap: int,
    max_iters: int = 64,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret: bool = False,
    axes=None,
    telemetry: bool = False,
):
    """Traceable core of the one-launch cluster pass.

    Args:
      bitmap: (R, W_local) packed adjacency slab, tile-aligned, with
        every bit for columns >= n already cleared (tail mask).  Under
        ``axes=`` this is the shard-local word slice of a column-sharded
        slab; otherwise W_local*32 == cap.
      rows: (R,) int32 — database index of each slab row (the executed
        query set), sentinel >= n on padding rows.  Every core point
        must appear as a slab row (DBSCAN executes every predicted
        core), which is what makes the gather/scatter round below a
        full propagation round on the core-core graph.
      tau: core threshold (traced scalar).
      col_off: global column offset of this shard's words (0 off-mesh).
      n / cap: live points / total column capacity (static).
      axes: mesh axis name(s); per round only the (R,) s32 row minima
        ride a ``lax.pmin`` — packed words never enter a collective.
      telemetry: ride four ``(max_iters,)`` s32 per-round vectors in
        the while carry (frontier size, labels changed, pointer-jump
        hops, psum'd shard gather wins) and return them as a sixth
        output — small s32 vectors only, so the carry stays inside the
        LAF106/LAF107 contract and the collective stays s32 (LAF202).

    Returns ``(labels (cap,), owner (cap,), col_sum (cap_local,),
    counts (R,), rounds)`` — labels[j] = min core index of j's core
    component (INT32_MAX on non-core columns), owner[j] = min executed
    core row adjacent to column j (border rule), col_sum = transposed
    partial-count sums for this shard's columns, counts = exact
    neighbor counts per slab row.  With ``telemetry=True`` a trailing
    ``tele`` tuple (4 × (max_iters,) s32, replicated under ``axes=``)
    is appended.
    """
    r, w_loc = bitmap.shape
    cap_loc = w_loc * 32
    rows = rows.astype(jnp.int32)
    valid_r = rows < n
    counts = jnp.sum(jax.lax.population_count(bitmap), axis=1).astype(jnp.int32)
    if axes is not None:
        counts = jax.lax.psum(counts, axes)
    counts = jnp.where(valid_r, counts, 0)
    core_r = valid_r & (counts >= jnp.int32(tau))
    safe_rows = jnp.minimum(rows, cap - 1)
    core_c = (
        jnp.zeros((cap,), jnp.int32).at[safe_rows].max(core_r.astype(jnp.int32)) > 0
    )
    init = jnp.where(core_c, jnp.arange(cap, dtype=jnp.int32), BIG)
    big_rows = jnp.full((r,), BIG, jnp.int32)

    def cond(state):
        changed, it = state[1], state[2]
        return changed & (it < max_iters)

    def body(state):
        lab, _, it = state[0], state[1], state[2]
        # gather: per core row, the min label over its set bits —
        # shard-local slice of the replicated label vector, then an s32
        # min-reduce across shards
        lab_loc = jax.lax.dynamic_slice(lab, (col_off,), (cap_loc,))
        m = label_prop_rect_pallas(
            big_rows, lab_loc, bitmap,
            row_tile=row_tile, word_tile=word_tile, interpret=interpret,
        )
        if telemetry:
            # shard marginal: rows whose *local* gather already beats
            # the incoming label — recorded shard-local here and psum'd
            # once after the loop (a per-round collective would add a
            # rendezvous to every round; the deferred vector psum is one)
            wins = jnp.sum(core_r & (m < lab[safe_rows]), dtype=jnp.int32)
        if axes is not None:
            m = jax.lax.pmin(m, axes)
        new_r = jnp.where(core_r, jnp.minimum(lab[safe_rows], m), BIG)
        # scatter-min back into each row's own column (core ⊆ rows, so
        # this updates every core column); BIG rows are no-ops
        new = lab.at[safe_rows].min(new_r)
        # pointer jumping: label <- label of my label
        jump = jnp.where(new < cap, new, 0)
        jumped = jnp.where(new < cap, jnp.minimum(new, new[jump]), new)
        if not telemetry:
            return jumped, jnp.any(jumped != lab), it + 1
        front = jnp.sum(core_r & (new_r < lab[safe_rows]), dtype=jnp.int32)
        hops = jnp.sum(jumped < new, dtype=jnp.int32)
        chg = jnp.sum(jumped != lab, dtype=jnp.int32)
        tele = _obs_device.cluster_telemetry_record(
            state[3], it, front, chg, hops, wins
        )
        return jumped, chg > 0, it + 1, tele

    state0 = (init, jnp.bool_(True), jnp.int32(0))
    if telemetry:
        state0 = state0 + (_obs_device.cluster_telemetry_init(max_iters),)
    final = jax.lax.while_loop(cond, body, state0)
    labels, rounds = final[0], final[2]
    # border owner (min executed-core-row index per column) + transposed
    # partial-count sums, one launch, loop-invariant so outside the loop
    owner_loc, col_sum = col_reduce_pallas(
        bitmap,
        jnp.where(core_r, rows, BIG),
        valid_r.astype(jnp.int32),
        row_tile=row_tile, word_tile=word_tile, interpret=interpret,
    )
    outs = (labels, owner_loc, col_sum, counts, rounds)
    if not telemetry:
        return outs
    tele = final[3]
    if axes is not None:
        # sum the shard-local gather wins across the mesh in ONE vector
        # collective (frontier/changed/hops are computed from post-pmin
        # quantities, replica-identical by construction)
        tele = tele[:3] + (jax.lax.psum(tele[3], axes),)
    return outs + (tele,)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n", "max_iters", "row_tile", "word_tile", "interpret", "telemetry"
    ),
)
def _packed_cluster_jit(
    bitmap, rows, tau, *, n, max_iters, row_tile, word_tile, interpret,
    telemetry=False,
):
    r, w = bitmap.shape
    bitmap = bitmap & _tail_word_mask(w, n)[None, :]
    r_pad = (-r) % row_tile
    w_pad = (-w) % word_tile
    if r_pad or w_pad:
        bitmap = jnp.pad(bitmap, ((0, r_pad), (0, w_pad)))
        rows = jnp.pad(rows.astype(jnp.int32), (0, r_pad), constant_values=n)
    cap = (w + w_pad) * 32
    outs = packed_cluster_fixpoint(
        bitmap, rows, tau, jnp.int32(0),
        n=n, cap=cap, max_iters=max_iters,
        row_tile=row_tile, word_tile=word_tile, interpret=interpret,
        telemetry=telemetry,
    )
    labels, owner, col_sum, counts, rounds = outs[:5]
    head = (labels, owner, col_sum, counts[:r], rounds)
    return head + outs[5:]


def packed_cluster_labels(
    bitmap: jax.Array,
    rows: jax.Array,
    tau,
    *,
    n: int,
    max_iters: int = 64,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret=None,
    telemetry=None,
):
    """One-launch single-device cluster pass over a packed sweep slab.

    ``bitmap`` is the (R, W) slab of executed-query adjacency rows
    (W*32 >= n; capacity slack past n is tolerated — the tail mask is
    applied here), ``rows`` the (R,) database indices those rows
    represent.  Returns device arrays
    ``(labels, owner, col_sum, counts, rounds)`` — see
    :func:`packed_cluster_fixpoint`; nothing syncs to the host.
    ``telemetry`` (default: the ``repro.obs`` device switch) appends
    the per-round telemetry tuple as a sixth output.
    """
    if interpret is None:
        interpret = default_interpret()
    if telemetry is None:
        telemetry = _obs_device.device_enabled()
    row_tile = min(row_tile, max(bitmap.shape[0], 1))
    word_tile = min(word_tile, max(bitmap.shape[1], 1))
    _metrics.counter("labelprop.launches").inc()
    return _packed_cluster_jit(
        bitmap, jnp.asarray(rows, jnp.int32), tau,
        n=n, max_iters=max_iters,
        row_tile=row_tile, word_tile=word_tile, interpret=interpret,
        telemetry=bool(telemetry),
    )


# ---------------------------------------------------------------------------
# streaming connectivity: bipartite rows <-> columns propagation
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("max_iters", "row_tile", "word_tile", "interpret")
)
def _packed_connectivity_jit(
    bitmap, rows, row_core, core_cols, *, max_iters, row_tile, word_tile, interpret
):
    r, w = bitmap.shape
    n = core_cols.shape[0]
    r_pad = (-r) % row_tile
    w_pad = (-w) % word_tile
    if r_pad or w_pad:
        bitmap = jnp.pad(bitmap, ((0, r_pad), (0, w_pad)))
        rows = jnp.pad(rows.astype(jnp.int32), (0, r_pad))
        row_core = jnp.pad(row_core, (0, r_pad))
    cap = (w + w_pad) * 32
    core_c = jnp.pad(core_cols, (0, cap - n))
    rp = r + r_pad
    big_rows = jnp.full((rp,), BIG, jnp.int32)
    init = jnp.where(core_c, jnp.arange(cap, dtype=jnp.int32), BIG)
    zeros = jnp.zeros((rp,), jnp.int32)

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        lab, _, it = state
        # rows gather from columns... (a streaming block's rows are NOT
        # a superset of the core set, so rows only *relay*: a core row
        # carries the min label of its core columns back down)
        m = label_prop_rect_pallas(
            big_rows, lab, bitmap,
            row_tile=row_tile, word_tile=word_tile, interpret=interpret,
        )
        row_lab = jnp.where(row_core, m, BIG)
        # ...columns gather back from rows
        cmin, _ = col_reduce_pallas(
            bitmap, row_lab, zeros,
            row_tile=row_tile, word_tile=word_tile, interpret=interpret,
        )
        new = jnp.where(core_c, jnp.minimum(lab, cmin), BIG)
        jump = jnp.where(new < cap, new, 0)
        new = jnp.where(new < cap, jnp.minimum(new, new[jump]), new)
        return new, jnp.any(new != lab), it + 1

    lab, _, rounds = jax.lax.while_loop(
        cond, body, (init, jnp.bool_(True), jnp.int32(0))
    )
    owner, _ = col_reduce_pallas(
        bitmap,
        jnp.where(row_core, rows.astype(jnp.int32), BIG),
        zeros,
        row_tile=row_tile, word_tile=word_tile, interpret=interpret,
    )
    row_first = label_prop_rect_pallas(
        big_rows, init, bitmap,
        row_tile=row_tile, word_tile=word_tile, interpret=interpret,
    )
    return lab[:n], owner[:n], row_first[:r], rounds


def packed_connectivity(
    bitmap: jax.Array,
    rows: jax.Array,
    row_core: jax.Array,
    core_cols: jax.Array,
    *,
    max_iters: int = 64,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret=None,
):
    """Connectivity of one packed hit block, bipartite propagation.

    ``bitmap`` (R, W) is a block of (alive-masked) adjacency rows whose
    database indices are ``rows`` (R,); ``row_core`` flags which of
    those rows are core; ``core_cols`` (n,) flags core columns.  Bits
    past n in the last word must be zero (the pack contract).

    Returns device arrays ``(comp, owner, row_first, rounds)``:
    ``comp[j]`` = min core column index reachable from core column j
    through this block's core rows (INT32_MAX on non-core columns) —
    exactly the transitive closure of the per-row star unions the host
    pass applies; ``owner[j]`` = min core row index adjacent to column
    j; ``row_first[i]`` = min core column adjacent to row i.
    """
    if interpret is None:
        interpret = default_interpret()
    row_tile = min(row_tile, max(bitmap.shape[0], 1))
    word_tile = min(word_tile, max(bitmap.shape[1], 1))
    _metrics.counter("labelprop.launches").inc()
    return _packed_connectivity_jit(
        bitmap,
        jnp.asarray(rows, jnp.int32),
        jnp.asarray(row_core, bool),
        jnp.asarray(core_cols, bool),
        max_iters=max_iters,
        row_tile=row_tile, word_tile=word_tile, interpret=interpret,
    )
