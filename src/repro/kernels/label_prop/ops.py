"""Driver: pad, iterate kernel rounds with pointer jumping to fixpoint."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_ROW_TILE, DEFAULT_WORD_TILE, label_prop_round_pallas

__all__ = ["label_prop_round", "label_propagation_pallas"]

BIG = jnp.iinfo(jnp.int32).max


def _pad(labels, bitmap, row_tile, word_tile):
    n = labels.shape[0]
    w = bitmap.shape[1]
    n_pad = (-n) % row_tile
    w_req = max(w, -(-(n + n_pad) // 32))
    w_pad = (-w_req) % word_tile + (w_req - w)
    labels_p = jnp.pad(labels, (0, n_pad), constant_values=BIG)
    bitmap_p = jnp.pad(bitmap, ((0, n_pad), (0, w_pad)))
    return labels_p, bitmap_p, n


@functools.partial(jax.jit, static_argnames=("row_tile", "word_tile", "interpret"))
def label_prop_round(
    labels: jax.Array,
    bitmap: jax.Array,
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret: bool = True,
):
    """One masked min-propagation round (arbitrary N, W)."""
    labels = labels.astype(jnp.int32)
    labels_p, bitmap_p, n = _pad(labels, bitmap, row_tile, word_tile)
    out = label_prop_round_pallas(
        labels_p, bitmap_p, row_tile=row_tile, word_tile=word_tile, interpret=interpret
    )
    return out[:n]


@functools.partial(
    jax.jit, static_argnames=("max_iters", "row_tile", "word_tile", "interpret")
)
def label_propagation_pallas(
    bitmap: jax.Array,
    active: jax.Array,
    *,
    max_iters: int = 64,
    row_tile: int = DEFAULT_ROW_TILE,
    word_tile: int = DEFAULT_WORD_TILE,
    interpret: bool = True,
):
    """Connected components over a packed symmetric adjacency: same
    contract as ``repro.core.union_find.label_propagation`` (inactive
    nodes -> sentinel n)."""
    n = active.shape[0]
    init = jnp.where(active, jnp.arange(n, dtype=jnp.int32), jnp.int32(n))

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        labels, _, it = state
        masked = jnp.where(active, labels, BIG)
        neigh = label_prop_round(
            masked, bitmap, row_tile=row_tile, word_tile=word_tile, interpret=interpret
        )
        new = jnp.where(active, jnp.minimum(labels, neigh), jnp.int32(n))
        jump = jnp.where(new < n, new, 0)
        new = jnp.where(new < n, jnp.minimum(new, new[jump]), new)
        return new, jnp.any(new != labels), it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (init, jnp.bool_(True), 0))
    return labels
