"""Pure-jnp oracles for the packed-bitmap label-propagation kernels:
the square round, the rectangular row reduction, and the transposed
column reduction (all unpack-based — the thing the kernels avoid)."""

import jax.numpy as jnp


def _unpack(bitmap):
    """(R, W) uint32 -> (R, W*32) bool, LSB-first within each word."""
    r, nw = bitmap.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((bitmap[:, :, None] >> shifts[None, None, :]) & 1).astype(bool)
    return bits.reshape(r, nw * 32)


def label_prop_round_ref(labels, bitmap, big):
    """new_labels[i] = min(labels[i], min_{j: bit ij set} labels[j])."""
    n = labels.shape[0]
    bits = _unpack(bitmap)[:, :n]
    neigh = jnp.min(jnp.where(bits, labels[None, :], big), axis=1)
    return jnp.minimum(labels, neigh)


def label_prop_rect_ref(row_labels, col_labels, bitmap, big):
    """Rectangular gather: min(row_labels[i], min over bits of
    col_labels) — oracle for ``label_prop_rect_pallas``."""
    bits = _unpack(bitmap)
    neigh = jnp.min(jnp.where(bits, col_labels[None, :], big), axis=1)
    return jnp.minimum(row_labels, neigh)


def col_reduce_ref(bitmap, row_vals, row_weights, big):
    """Transposed reductions — oracle for ``col_reduce_pallas``:
    per column the min of ``row_vals`` over set bits (``big`` where no
    bit) and the weighted popcount down the rows."""
    bits = _unpack(bitmap)
    cmin = jnp.min(jnp.where(bits, row_vals[:, None], big), axis=0)
    csum = jnp.sum(jnp.where(bits, row_weights[:, None], 0), axis=0)
    return cmin.astype(jnp.int32), csum.astype(jnp.int32)
