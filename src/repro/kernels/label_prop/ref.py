"""Pure-jnp oracle for one min-label-propagation round over a packed
uint32 adjacency bitmap."""

import jax.numpy as jnp


def label_prop_round_ref(labels, bitmap, big):
    """new_labels[i] = min(labels[i], min_{j: bit ij set} labels[j])."""
    n = labels.shape[0]
    nw = bitmap.shape[1]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((bitmap[:, :, None] >> shifts[None, None, :]) & 1).astype(bool)
    bits = bits.reshape(n, nw * 32)[:, :n]
    neigh = jnp.min(jnp.where(bits, labels[None, :], big), axis=1)
    return jnp.minimum(labels, neigh)
