from .ops import label_prop_round, label_propagation_pallas  # noqa: F401
