from .ops import (  # noqa: F401
    label_prop_round,
    label_propagation_pallas,
    packed_cluster_fixpoint,
    packed_cluster_labels,
    packed_connectivity,
)
