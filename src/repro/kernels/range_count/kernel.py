"""Fused range-count Pallas kernel — the DBSCAN range-query hot path.

One MXU pass per (query-tile, db-tile): distance-as-dot, ε-threshold,
population count, and (optionally) packed adjacency-bitmap emission all
happen inside the VMEM tile; only per-query int32 counts and uint32
bitmap words are written back to HBM.  Compared to the two-pass
distance-then-threshold formulation this removes the (nq × nd) fp32
score matrix round-trip entirely — the kernel's HBM traffic is
nq·d + nd·d reads + nq·(1 + nd/32)·4B writes.

Tiling (TPU v5e, 16 MiB VMEM): q tile 256×d, db tile 512×d.  For d=768
(MS-MARCO embeddings) that is 256·768·4 + 512·768·4 ≈ 2.3 MiB plus the
256×512 fp32 score tile (0.5 MiB) — comfortably resident, and both
matmul dims are multiples of the 128-lane MXU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q_TILE = 256
DEFAULT_DB_TILE = 512


def _count_kernel(q_ref, db_ref, thresh_ref, counts_ref):
    """Grid (nq_tiles, nd_tiles); counts accumulate over the db axis."""
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    db = db_ref[...].astype(jnp.float32)
    dots = jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TQ, TD)
    hit = dots > thresh_ref[0]
    tile_counts = jnp.sum(hit, axis=1, dtype=jnp.int32)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = tile_counts

    @pl.when(j != 0)
    def _acc():
        counts_ref[...] += tile_counts


def _count_bitmap_kernel(q_ref, db_ref, thresh_ref, counts_ref, bitmap_ref):
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    db = db_ref[...].astype(jnp.float32)
    dots = jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    hit = dots > thresh_ref[0]
    tile_counts = jnp.sum(hit, axis=1, dtype=jnp.int32)
    tq, td = hit.shape
    words = hit.reshape(tq, td // 32, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bitmap_ref[...] = jnp.sum(words << shifts[None, None, :], axis=2, dtype=jnp.uint32)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = tile_counts

    @pl.when(j != 0)
    def _acc():
        counts_ref[...] += tile_counts


@functools.partial(
    jax.jit, static_argnames=("q_tile", "db_tile", "interpret", "with_bitmap")
)
def range_count_pallas(
    q: jax.Array,
    db: jax.Array,
    eps: jax.Array | float,
    *,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: bool = False,
    with_bitmap: bool = False,
):
    """Raw kernel entry; inputs must already be tile-aligned (see ops.py)."""
    nq, d = q.shape
    nd = db.shape[0]
    assert nq % q_tile == 0 and nd % db_tile == 0 and db_tile % 32 == 0
    grid = (nq // q_tile, nd // db_tile)
    thresh = jnp.asarray([1.0 - eps], jnp.float32)

    q_spec = pl.BlockSpec((q_tile, d), lambda i, j: (i, 0))
    db_spec = pl.BlockSpec((db_tile, d), lambda i, j: (j, 0))
    thresh_spec = pl.BlockSpec(memory_space=pl.ANY)
    counts_spec = pl.BlockSpec((q_tile,), lambda i, j: (i,))

    if not with_bitmap:
        return pl.pallas_call(
            _count_kernel,
            grid=grid,
            in_specs=[q_spec, db_spec, thresh_spec],
            out_specs=counts_spec,
            out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
            interpret=interpret,
        )(q, db, thresh)

    bitmap_spec = pl.BlockSpec((q_tile, db_tile // 32), lambda i, j: (i, j))
    return pl.pallas_call(
        _count_bitmap_kernel,
        grid=grid,
        in_specs=[q_spec, db_spec, thresh_spec],
        out_specs=[counts_spec, bitmap_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nq,), jnp.int32),
            jax.ShapeDtypeStruct((nq, nd // 32), jnp.uint32),
        ],
        interpret=interpret,
    )(q, db, thresh)
