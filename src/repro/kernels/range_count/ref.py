"""Pure-jnp oracle for the fused range-count kernel."""

import jax.numpy as jnp


def range_count_ref(q, db, eps):
    """Counts: |{j : 1 - <q_i, db_j> < eps}| per query (int32)."""
    dots = q.astype(jnp.float32) @ db.astype(jnp.float32).T
    return jnp.sum(dots > 1.0 - eps, axis=1, dtype=jnp.int32)


def range_count_bitmap_ref(q, db, eps):
    """(counts, packed uint32 adjacency rows)."""
    dots = q.astype(jnp.float32) @ db.astype(jnp.float32).T
    hit = dots > 1.0 - eps
    counts = jnp.sum(hit, axis=1, dtype=jnp.int32)
    nq, nd = hit.shape
    pad = (-nd) % 32
    hitp = jnp.pad(hit, ((0, 0), (0, pad)))
    words = hitp.reshape(nq, -1, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    packed = jnp.sum(words << shifts[None, None, :], axis=2, dtype=jnp.uint32)
    return counts, packed
