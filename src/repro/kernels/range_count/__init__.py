from .ops import range_count, range_count_bitmap  # noqa: F401
