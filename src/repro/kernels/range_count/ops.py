"""Public jit'd wrappers for the range-count kernel: padding to tile
alignment, validity masking, dtype policy, interpret switch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_DB_TILE, DEFAULT_Q_TILE, range_count_pallas

__all__ = ["range_count", "range_count_bitmap"]


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


@functools.partial(
    jax.jit, static_argnames=("q_tile", "db_tile", "interpret")
)
def range_count(
    q: jax.Array,
    db: jax.Array,
    eps,
    *,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: bool = True,
):
    """Fused neighbor counts.  Pads to tiles; padded db rows are zero
    vectors whose dot is 0 — they can false-hit when eps > 1, so counts
    subtract the padded-hit correction exactly."""
    nq, nd = q.shape[0], db.shape[0]
    qp = _pad_rows(q, q_tile)
    dbp = _pad_rows(db, db_tile)
    counts = range_count_pallas(
        qp, dbp, eps, q_tile=q_tile, db_tile=db_tile, interpret=interpret
    )[:nq]
    n_pad = dbp.shape[0] - nd
    if n_pad:
        # zero-vector rows hit iff 0 > 1 - eps  <=>  eps > 1
        pad_hits = jnp.where(jnp.asarray(eps, jnp.float32) > 1.0, n_pad, 0)
        counts = counts - pad_hits.astype(jnp.int32)
    return counts


@functools.partial(
    jax.jit, static_argnames=("q_tile", "db_tile", "interpret")
)
def range_count_bitmap(
    q: jax.Array,
    db: jax.Array,
    eps,
    *,
    q_tile: int = DEFAULT_Q_TILE,
    db_tile: int = DEFAULT_DB_TILE,
    interpret: bool = True,
):
    """(counts, packed adjacency) with the same padding corrections; the
    returned bitmap covers ceil(nd/32) words with padded bits cleared."""
    nq, nd = q.shape[0], db.shape[0]
    qp = _pad_rows(q, q_tile)
    dbp = _pad_rows(db, db_tile)
    counts, bitmap = range_count_pallas(
        qp, dbp, eps, q_tile=q_tile, db_tile=db_tile, interpret=interpret,
        with_bitmap=True,
    )
    counts = counts[:nq]
    bitmap = bitmap[:nq]
    n_pad = dbp.shape[0] - nd
    if n_pad:
        pad_hits = jnp.where(jnp.asarray(eps, jnp.float32) > 1.0, n_pad, 0)
        counts = counts - pad_hits.astype(jnp.int32)
        # clear padded bits: build a validity mask over words
        nw = bitmap.shape[1]
        bit_idx = jnp.arange(nw * 32) < nd
        word_mask = jnp.sum(
            bit_idx.reshape(nw, 32).astype(jnp.uint32)
            << jnp.arange(32, dtype=jnp.uint32)[None, :],
            axis=1,
            dtype=jnp.uint32,
        )
        bitmap = bitmap & word_mask[None, :]
    words_needed = -(-nd // 32)
    return counts, bitmap[:, :words_needed]
