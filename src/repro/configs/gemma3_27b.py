"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global sliding-window hybrid, 128k context
[hf:google/gemma-3]; the ONE assigned LM arch that runs long_500k
(sub-quadratic local layers)."""

import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .registry import ArchSpec, LM_SHAPES, register


def make_config():
    return TransformerConfig(
        vocab=262144,
        d_model=5376,
        n_layers=62,
        n_heads=32,
        kv_heads=16,
        d_head=128,
        d_ff=21504,
        window=1024,       # local sliding window
        global_every=6,    # 5 local : 1 global
        rope_theta=1000000.0,
        dtype=jnp.bfloat16,
    )


def make_reduced_config():
    return TransformerConfig(
        vocab=512, d_model=128, n_layers=6, n_heads=4, kv_heads=2, d_head=32,
        d_ff=512, window=8, global_every=6, dtype=jnp.float32, kv_block=64,
    )


SPEC = register(
    ArchSpec(
        name="gemma3-27b",
        family="lm",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=LM_SHAPES,
        notes="runs long_500k (5:1 local:global hybrid attention)",
    )
)
