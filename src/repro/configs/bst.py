"""bst [recsys]: Behavior Sequence Transformer (Alibaba): embed_dim=32,
seq_len=20, 1 block, 8 heads, MLP 1024-512-256 [arXiv:1905.06874]."""

import jax.numpy as jnp

from ..models.recsys import BSTConfig
from .registry import ArchSpec, RECSYS_SHAPES, register
from .dien import ITEM_VOCAB


def make_config():
    return BSTConfig(item_vocab=ITEM_VOCAB, embed_dim=32, seq_len=20,
                     n_blocks=1, n_heads=8, mlp_dims=(1024, 512, 256),
                     dtype=jnp.float32)


def make_reduced_config():
    return BSTConfig(item_vocab=1000, embed_dim=16, seq_len=8,
                     n_blocks=1, n_heads=2, mlp_dims=(32, 16), dtype=jnp.float32)


SPEC = register(
    ArchSpec(
        name="bst",
        family="recsys",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=RECSYS_SHAPES,
    )
)
