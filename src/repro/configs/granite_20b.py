"""granite-20b [dense]: 52L d_model=6144 48H (GQA kv=1 → MQA) d_ff=24576
vocab=49152 — llama-arch, code [arXiv:2405.04324]."""

import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .registry import ArchSpec, FULL_ATTENTION_SKIP, LM_SHAPES, register


def make_config():
    return TransformerConfig(
        vocab=49152,
        d_model=6144,
        n_layers=52,
        n_heads=48,
        kv_heads=1,   # MQA
        d_head=128,
        d_ff=24576,
        dtype=jnp.bfloat16,
    )


def make_reduced_config():
    return TransformerConfig(
        vocab=512, d_model=96, n_layers=2, n_heads=6, kv_heads=1, d_head=16,
        d_ff=384, dtype=jnp.float32, kv_block=64,
    )


SPEC = register(
    ArchSpec(
        name="granite-20b",
        family="lm",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=LM_SHAPES,
        skips={"long_500k": FULL_ATTENTION_SKIP},
    )
)
