"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1]."""

import jax.numpy as jnp

from ..models.moe import MoEConfig
from ..models.transformer import TransformerConfig
from .registry import ArchSpec, FULL_ATTENTION_SKIP, LM_SHAPES, register


def make_config():
    return TransformerConfig(
        vocab=131072,
        d_model=6144,
        n_layers=64,
        n_heads=48,
        kv_heads=8,
        d_head=128,
        d_ff=32768,
        moe=MoEConfig(
            d_model=6144, d_ff=32768, n_experts=8, top_k=2,
            capacity_factor=1.25, dtype=jnp.bfloat16,
        ),
        dtype=jnp.bfloat16,
    )


def make_reduced_config():
    return TransformerConfig(
        vocab=512, d_model=64, n_layers=2, n_heads=4, kv_heads=2, d_head=16,
        d_ff=256,
        moe=MoEConfig(d_model=64, d_ff=256, n_experts=4, top_k=2,
                      capacity_factor=2.0, dtype=jnp.float32),
        dtype=jnp.float32, kv_block=64,
    )


SPEC = register(
    ArchSpec(
        name="grok-1-314b",
        family="lm",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=LM_SHAPES,
        skips={"long_500k": FULL_ATTENTION_SKIP},
    )
)
