"""autoint [recsys]: 39 fields, embed_dim=16, 3 self-attn layers,
2 heads, d_attn=32 [arXiv:1810.11921]."""

import jax.numpy as jnp

from ..models.recsys import AutoIntConfig
from .registry import ArchSpec, RECSYS_SHAPES, register
from .deepfm import CRITEO39_VOCABS, REDUCED_VOCABS


def make_config():
    return AutoIntConfig(vocab_sizes=CRITEO39_VOCABS, embed_dim=16,
                         n_attn_layers=3, n_heads=2, d_attn=32, dtype=jnp.float32)


def make_reduced_config():
    return AutoIntConfig(vocab_sizes=REDUCED_VOCABS, embed_dim=8,
                         n_attn_layers=2, n_heads=2, d_attn=8, dtype=jnp.float32)


SPEC = register(
    ArchSpec(
        name="autoint",
        family="recsys",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=RECSYS_SHAPES,
    )
)
