"""dien [recsys]: embed_dim=18, behavior seq_len=100, gru_dim=108,
MLP 200-80, AUGRU interaction [arXiv:1809.03672]."""

import jax.numpy as jnp

from ..models.recsys import DIENConfig
from .registry import ArchSpec, RECSYS_SHAPES, register

ITEM_VOCAB = 5_000_000  # production-scale item catalogue


def make_config():
    return DIENConfig(item_vocab=ITEM_VOCAB, embed_dim=18, seq_len=100,
                      gru_dim=108, mlp_dims=(200, 80), dtype=jnp.float32)


def make_reduced_config():
    return DIENConfig(item_vocab=1000, embed_dim=8, seq_len=12,
                      gru_dim=16, mlp_dims=(16, 8), dtype=jnp.float32)


SPEC = register(
    ArchSpec(
        name="dien",
        family="recsys",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=RECSYS_SHAPES,
    )
)
