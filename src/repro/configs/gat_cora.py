"""gat-cora [gnn]: 2 layers, d_hidden=8, 8 heads, attention aggregator
[arXiv:1710.10903].  Shapes: full-batch Cora, sampled Reddit-scale
minibatch (fanout 15-10 — real neighbor sampler in repro.data), OGB
products full-batch-large (edge-sharded), batched molecules."""

import jax.numpy as jnp

from ..models.gnn import GATConfig
from .registry import ArchSpec, GNN_SHAPES, register


def make_config():
    return GATConfig(d_in=1433, d_hidden=8, n_heads=8, n_layers=2, n_classes=7)


def make_reduced_config():
    return GATConfig(d_in=32, d_hidden=4, n_heads=2, n_layers=2, n_classes=5)


# per-shape input feature dims differ (cora 1433 / reddit 602 / products 100);
# the launcher builds a shape-matched GATConfig via ``config_for_shape``.
def config_for_shape(shape_name: str) -> GATConfig:
    d_feat = {
        "full_graph_sm": 1433,
        "minibatch_lg": 602,
        "ogb_products": 100,
        "molecule": 64,
    }[shape_name]
    n_classes = {"full_graph_sm": 7, "minibatch_lg": 41, "ogb_products": 47, "molecule": 7}[
        shape_name
    ]
    return GATConfig(d_in=d_feat, d_hidden=8, n_heads=8, n_layers=2, n_classes=n_classes)


SPEC = register(
    ArchSpec(
        name="gat-cora",
        family="gnn",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=GNN_SHAPES,
        notes="LAF inapplicable (message passing over given edges; no range queries) — DESIGN.md §4",
    )
)
