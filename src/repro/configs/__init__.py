from .registry import ArchSpec, ShapeSpec, get_arch, list_archs  # noqa: F401
