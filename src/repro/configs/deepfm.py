"""deepfm [recsys]: 39 sparse fields, embed_dim=10, MLP 400-400-400, FM
interaction [arXiv:1703.04247].  Criteo-style vocab distribution (heavy
tail: a few 10M-row tables + many small ones) — ~19.7M total rows."""

import jax.numpy as jnp

from ..models.recsys import DeepFMConfig
from .registry import ArchSpec, RECSYS_SHAPES, register

# deterministic heavy-tailed vocab sizes, 39 fields, ~19.7M rows total
CRITEO39_VOCABS = tuple(
    [10_000_000, 4_000_000, 2_000_000, 1_000_000]
    + [500_000] * 3
    + [100_000] * 4
    + [10_000] * 8
    + [1_000] * 12
    + [100] * 8
)
assert len(CRITEO39_VOCABS) == 39

REDUCED_VOCABS = tuple([1000, 500] + [100] * 6)


def make_config():
    return DeepFMConfig(vocab_sizes=CRITEO39_VOCABS, embed_dim=10,
                        mlp_dims=(400, 400, 400), dtype=jnp.float32)


def make_reduced_config():
    return DeepFMConfig(vocab_sizes=REDUCED_VOCABS, embed_dim=4,
                        mlp_dims=(16, 16), dtype=jnp.float32)


SPEC = register(
    ArchSpec(
        name="deepfm",
        family="recsys",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=RECSYS_SHAPES,
    )
)
