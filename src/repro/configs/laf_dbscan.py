"""laf_dbscan: the paper's own workload as a first-class config — the
distributed clustering step (sharded range counting + RMI estimation)
lowered on the production mesh alongside the assigned architectures.

Dataset operating points follow the paper's Table 1 (n, d); the dry-run
lowers ``cluster_step`` = one frontier round: batched RMI prediction for
the frontier + fused range counting of predicted-core queries against
the device-sharded database + one label-propagation round.
"""

from dataclasses import dataclass
from typing import Mapping

import jax.numpy as jnp

from .registry import ArchSpec, ShapeSpec, register


@dataclass(frozen=True)
class StreamConfig:
    """Knobs for the streaming subsystem (``repro.stream``).

    ``alpha`` is the online analog of the paper's skip factor: a new
    point whose predicted cardinality is below ``alpha * tau`` skips its
    full range query at ingest (it is verified against the core set
    only, and promoted later if its partial count crosses tau).
    ``use_estimator=False`` disables the skip entirely — every ingested
    row pays one range query, which is the exact (parity) mode.
    """

    batch_rows: int = 4096      # driver-side ingest chunking
    use_estimator: bool = False  # RMI predict-core fast path at ingest
    alpha: float = 1.0           # online skip factor (pred < alpha*tau skips)
    shortlist: int = 8           # serve: centroid clusters expanded per query
    min_hits: int = 1            # serve: eps-neighbors required to assign
    max_dead_frac: float = 0.25  # eviction: tombstone fraction forcing rebuild
    snapshot_every: int = 8      # durability: WAL batches between snapshots


@dataclass(frozen=True)
class LAFClusterConfig:
    n_points: int
    dim: int
    eps: float = 0.55
    tau: int = 5
    alpha: float = 1.5
    frontier: int = 4096      # queries per frontier round
    dtype: object = jnp.float32
    # range-query backend (repro.index): "exact" = brute-force matmul,
    # "random_projection" = sign-signature Hamming prefilter + verify
    # (kernels.hamming_filter on device); index_bits sizes the signature,
    # index_seed fixes the projection (db signatures MUST be packed with
    # the same seed/bits), index_margin sets the Hamming band width.
    # index_verify picks the backend's dual-threshold semantics
    # ("band" = sure-accept below t_lo + exact-verify the band; "full" =
    # t_lo disabled, every candidate verified).  index_device routes the
    # frontier round through the fused hamming_filter Pallas tile
    # (True | False | "auto") on ANY mesh size: multi-device meshes run
    # the tile shard-locally via the index plane
    # (repro.distributed.index_plane), with the packed signature table
    # co-sharded with the database rows; "auto" = the tile on every
    # multi-device mesh and on accelerator-backed single devices (a
    # lone CPU device keeps the shardable jnp dataflow of the same
    # predicate).  index_axes names the mesh axes the db rows +
    # signature table are co-sharded over ("auto" = every mesh axis).
    # index_pipeline sets the frontier sweep's software-pipeline depth
    # through the sharded plane: 2 (default) double-buffers chunks so
    # chunk k's cross-shard count psum overlaps chunk k+1's shard-local
    # popcount+verify; 1 serializes them (the parity baseline).
    backend: str = "exact"
    index_bits: int = 512
    index_seed: int = 0
    index_margin: float = 3.0
    index_verify: str = "band"
    index_device: object = "auto"
    index_axes: object = "auto"
    index_pipeline: int = 2
    # cluster_device routes cluster *formation* (tau core test +
    # core-graph components + border rule): "auto" follows the backend
    # — when it packs adjacency on device (packs_natively) the sweep's
    # bitmap slab feeds the packed label-propagation while_loop program
    # and the whole clustering syncs to the host exactly once (final
    # labels); True forces the device program even for host backends
    # (packed blocks uploaded once — the parity mode); False forces the
    # host unpack -> union-find pass (the parity oracle).
    cluster_device: object = "auto"
    # device-resident telemetry (repro.obs.device): "auto" follows the
    # process-wide switch (obs.enable(telemetry=True) / REPRO_OBS=device)
    # at build time; True/False pin it per config.  When on, the fused
    # loops carry small s32 counter vectors (per-round frontier/changed/
    # hops/shard-wins in the cluster fixpoint, per-chunk accept/band/
    # reject in the sweep) harvested at the existing single device_get.
    telemetry: object = "auto"
    # streaming subsystem (repro.stream): online ingest + serving knobs
    stream: StreamConfig = StreamConfig()


def make_config():
    # MS-150k operating point (paper Table 1: 152,185 x 768)
    return LAFClusterConfig(n_points=152185, dim=768)


def make_reduced_config():
    return LAFClusterConfig(n_points=2048, dim=64, frontier=256, index_bits=128)


LAF_SHAPES: Mapping[str, ShapeSpec] = {
    "nyt_150k": ShapeSpec("nyt_150k", "cluster", {"n_points": 150000, "dim": 256}),
    "glove_150k": ShapeSpec("glove_150k", "cluster", {"n_points": 150000, "dim": 200}),
    "ms_150k": ShapeSpec("ms_150k", "cluster", {"n_points": 152185, "dim": 768}),
    "web_1b": ShapeSpec("web_1b", "cluster", {"n_points": 1_073_741_824, "dim": 768}),
}

SPEC = register(
    ArchSpec(
        name="laf_dbscan",
        family="cluster",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=LAF_SHAPES,
        notes="the paper's technique itself; web_1b is the 1000+-node scale target",
    )
)
