"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536 vocab=102400,
MLA kv_lora=512, MoE 2 shared + 160 routed top-6 [arXiv:2405.04434]."""

import jax.numpy as jnp

from ..models.mla import MLAConfig
from ..models.moe import MoEConfig
from ..models.transformer import TransformerConfig
from .registry import ArchSpec, FULL_ATTENTION_SKIP, LM_SHAPES, register


def make_config():
    return TransformerConfig(
        vocab=102400,
        d_model=5120,
        n_layers=60,
        n_heads=128,
        kv_heads=128,
        d_head=128,
        d_ff=12288,        # first (dense) layer FFN
        attention="mla",
        mla=MLAConfig(
            d_model=5120,
            n_heads=128,
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_dim=128,
            qk_rope_dim=64,
            v_dim=128,
        ),
        moe=MoEConfig(
            d_model=5120, d_ff=1536, n_experts=160, top_k=6, n_shared=2,
            capacity_factor=1.25, dtype=jnp.bfloat16,
        ),
        n_dense_layers=1,
        dtype=jnp.bfloat16,
    )


def make_reduced_config():
    return TransformerConfig(
        vocab=512, d_model=64, n_layers=3, n_heads=4, kv_heads=4, d_head=16,
        d_ff=192, attention="mla",
        mla=MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_dim=16, qk_rope_dim=8, v_dim=16),
        moe=MoEConfig(d_model=64, d_ff=32, n_experts=8, top_k=2, n_shared=1,
                      capacity_factor=2.0, dtype=jnp.float32),
        n_dense_layers=1, dtype=jnp.float32, kv_block=64,
    )


SPEC = register(
    ArchSpec(
        name="deepseek-v2-236b",
        family="lm",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=LM_SHAPES,
        skips={"long_500k": FULL_ATTENTION_SKIP},
        notes="MLA latent cache: decode_32k caches (ckv 512 + krope 64) per token",
    )
)
