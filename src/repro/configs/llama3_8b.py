"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA, 128k vocab [arXiv:2407.21783]."""

import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from .registry import ArchSpec, FULL_ATTENTION_SKIP, LM_SHAPES, register


def make_config():
    return TransformerConfig(
        vocab=128256,
        d_model=4096,
        n_layers=32,
        n_heads=32,
        kv_heads=8,
        d_head=128,
        d_ff=14336,
        rope_theta=500000.0,
        dtype=jnp.bfloat16,
    )


def make_reduced_config():
    return TransformerConfig(
        vocab=512, d_model=128, n_layers=2, n_heads=4, kv_heads=1, d_head=32,
        d_ff=448, rope_theta=500000.0, dtype=jnp.float32, kv_block=64,
    )


SPEC = register(
    ArchSpec(
        name="llama3-8b",
        family="lm",
        make_config=make_config,
        make_reduced_config=make_reduced_config,
        shapes=LM_SHAPES,
        skips={"long_500k": FULL_ATTENTION_SKIP},
    )
)
