"""Architecture registry: every assigned arch as a selectable config.

Each arch module registers an ``ArchSpec`` carrying: the exact full
config from the assignment, a reduced same-family config for CPU smoke
tests, its shape table, and documented skips (DESIGN.md §4).  The
launcher (``repro.launch``) resolves ``--arch <id>`` here.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Optional

__all__ = ["ShapeSpec", "ArchSpec", "register", "get_arch", "list_archs", "REGISTRY"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode | forward | retrieval
    meta: Mapping[str, int]


@dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str          # lm | gnn | recsys
    make_config: Callable[[], Any]
    make_reduced_config: Callable[[], Any]
    shapes: Mapping[str, ShapeSpec]
    skips: Mapping[str, str] = field(default_factory=dict)
    notes: str = ""

    def runnable_shapes(self):
        return {k: v for k, v in self.shapes.items() if k not in self.skips}


REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    REGISTRY[spec.name] = spec
    return spec


def get_arch(name: str) -> ArchSpec:
    _ensure_loaded()
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs():
    _ensure_loaded()
    return sorted(REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    from . import (  # noqa: F401
        autoint,
        bst,
        deepfm,
        deepseek_v2_236b,
        dien,
        gat_cora,
        gemma3_27b,
        granite_20b,
        grok1_314b,
        laf_dbscan,
        llama3_8b,
    )

    _LOADED = True


# ---------------------------------------------------------------------------
# shared shape tables
# ---------------------------------------------------------------------------

LM_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    "decode_32k": ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    "long_500k": ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
}

FULL_ATTENTION_SKIP = (
    "long_500k skipped: pure full-attention arch; the 500k-token decode "
    "regime is reserved for sub-quadratic/hybrid archs per the assignment "
    "(DESIGN.md §4)."
)

GNN_SHAPES: Dict[str, ShapeSpec] = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train", {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg",
        "train",
        {
            "n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
            "fanout1": 15, "fanout2": 10, "d_feat": 602,
        },
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "train", {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100}
    ),
    "molecule": ShapeSpec(
        "molecule", "train", {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 64}
    ),
}

RECSYS_SHAPES: Dict[str, ShapeSpec] = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeSpec("serve_p99", "forward", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "forward", {"batch": 262144}),
    "retrieval_cand": ShapeSpec(
        "retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1000000}
    ),
}
